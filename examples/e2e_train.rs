//! End-to-end driver — the §V.C accuracy-parity experiment, real numerics.
//!
//! Trains the scaled MobileNetV2 on the synthetic TinyImageNet-class
//! dataset twice over the SAME image budget:
//!   * single node  (host alone, batch 32)
//!   * six nodes    (1 host @ batch 16 + 5 CSDs @ batch 4 = 36/step),
//! through the full stack: AOT-compiled PJRT train steps per worker,
//! ring-allreduce gradient mean, per-replica SGD with the Goyal
//! linear-scaling + warm-up schedule, privacy-checked shards. Loss
//! curves go to `e2e_loss.csv`; the paper-scale modeled timeline and
//! energy are reported for the distributed run.
//!
//! Paper result: loss 1.1859 (1 node) vs 1.1907 (6 nodes), +0.5%; same
//! accuracy. Ours reports the analogous pair on the scaled setup.
//!
//! Run: `cargo run --release --example e2e_train [-- --steps 300]`

// Example binaries report real wall-clock; the crate-wide clippy gate
// on time sources is lifted here like in the benches.
#![allow(clippy::disallowed_methods)]

use std::io::Write;

use stannis::config::ExperimentConfig;
use stannis::coordinator::{ScheduleConfig, Scheduler};
use stannis::csd::CsdConfig;
use stannis::perfmodel::PerfModel;
use stannis::power::{account_interval, EnergyMeter, PowerConfig};
use stannis::tunnel::TunnelConfig;
use stannis::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let steps: usize = args.parse_or("steps", 220)?;
    let seed: i64 = args.parse_or("seed", 7)?;

    let base = ExperimentConfig {
        network: "mobilenet_v2_s".into(),
        steps,
        seed,
        base_lr: 0.008,
        momentum: 0.9,
        warmup_steps: 25,
        public_images: 4096,
        private_per_csd: 512,
        ..Default::default()
    };

    // --- run A: single node (host alone, the paper's 1-node baseline) ----
    println!("=== run A: single node (host, bs 32, {steps} steps) ===");
    let cfg_a = ExperimentConfig {
        num_csds: 0,
        include_host: true,
        bs_csd: 4, // unused with 0 CSDs
        bs_host: 32,
        ..base.clone()
    };
    let cluster_a = stannis::cluster::Cluster::bring_up(cfg_a)?;
    let t0 = std::time::Instant::now();
    let mut trainer_a = cluster_a.trainer()?;
    let rep_a = trainer_a.train(steps)?;
    let wall_a = t0.elapsed().as_secs_f64();
    let (eval_loss_a, acc_a) = trainer_a.evaluate(8)?;

    // --- run B: six nodes (1 host + 5 CSDs) ------------------------------
    println!("=== run B: six nodes (host bs 16 + 5 CSDs bs 4, {steps} steps) ===");
    let cfg_b = ExperimentConfig {
        num_csds: 5,
        include_host: true,
        bs_csd: 4,
        bs_host: 16,
        ..base.clone()
    };
    let cluster_b = stannis::cluster::Cluster::bring_up(cfg_b.clone())?;
    let t0 = std::time::Instant::now();
    let mut trainer_b = cluster_b.trainer()?;
    let rep_b = trainer_b.train(steps)?;
    let wall_b = t0.elapsed().as_secs_f64();
    let (eval_loss_b, acc_b) = trainer_b.evaluate(8)?;

    // --- loss curves -------------------------------------------------------
    let mut csv = std::fs::File::create("e2e_loss.csv")?;
    writeln!(csv, "step,single_node_loss,six_node_loss")?;
    for i in 0..steps {
        writeln!(
            csv,
            "{},{:.5},{:.5}",
            i,
            rep_a.losses.get(i).copied().unwrap_or(f32::NAN),
            rep_b.losses.get(i).copied().unwrap_or(f32::NAN),
        )?;
    }
    println!("wrote e2e_loss.csv ({} rows)", steps);

    // --- §V.C parity report -------------------------------------------------
    let delta = (eval_loss_b - eval_loss_a) / eval_loss_a * 100.0;
    println!("\n=== accuracy parity (paper §V.C) ===");
    println!(
        "single node : train {:.4} -> {:.4}, eval loss {:.4}, acc {:.3} ({:.0} imgs, {:.0}s wall)",
        rep_a.first_loss(), rep_a.last_loss(), eval_loss_a, acc_a,
        rep_a.images_processed as f64, wall_a
    );
    println!(
        "six nodes   : train {:.4} -> {:.4}, eval loss {:.4}, acc {:.3} ({:.0} imgs, {:.0}s wall)",
        rep_b.first_loss(), rep_b.last_loss(), eval_loss_b, acc_b,
        rep_b.images_processed as f64, wall_b
    );
    println!(
        "eval-loss delta: {delta:+.2}%  (paper: +0.5%);  replica divergence {:.2e}",
        rep_b.max_replica_divergence
    );

    // --- modeled paper-scale timeline + energy for run B --------------------
    let mut sched = Scheduler::new(
        PerfModel::default(),
        5,
        TunnelConfig::default(),
        CsdConfig::default(),
    );
    sched.preload_data(64)?;
    let modeled = sched.run(&ScheduleConfig {
        network: "mobilenet_v2".into(),
        num_csds: 5,
        include_host: true,
        bs_csd: 25,
        bs_host: 315,
        steps,
        image_bytes: 12 * 1024,
        stage_io: true,
        per_step: false,
    })?;
    let mut meter = EnergyMeter::new();
    account_interval(
        &mut meter,
        &PowerConfig::default(),
        modeled.elapsed,
        5,
        24,
        true,
        modeled.link_bytes,
        modeled.flash_reads,
        0,
    );
    let images = modeled.images_per_sec * modeled.elapsed.as_secs_f64();
    println!("\n=== modeled paper-scale run (host + 5 Newports, tuned batches) ===");
    println!(
        "{} steps: {:.1} img/s aggregate, sync share {:.1}%, {:.2} J/img",
        steps,
        modeled.images_per_sec,
        modeled.sync_fraction * 100.0,
        meter.total_joules() / images
    );

    anyhow::ensure!(rep_a.last_loss() < rep_a.first_loss());
    anyhow::ensure!(rep_b.last_loss() < rep_b.first_loss());
    anyhow::ensure!(delta.abs() < 15.0, "parity broken: {delta}%");
    println!("\ne2e_train OK");
    Ok(())
}
