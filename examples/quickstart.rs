//! Quickstart: the whole Stannis pipeline in one minute.
//!
//! 1. Algorithm 1 tunes batch sizes on the modeled testbed.
//! 2. A real cluster (1 host + 2 CSDs) comes up on the AOT artifacts.
//! 3. Twenty steps of *real* distributed training run: PJRT executes
//!    each worker's train step, gradients cross the ring allreduce,
//!    replicas stay in lockstep.
//!
//! Run: `cargo run --release --example quickstart`
//! (requires `make artifacts` first)

use stannis::config::ExperimentConfig;
use stannis::coordinator::{tune, TuneConfig};
use stannis::perfmodel::PerfModel;

fn main() -> anyhow::Result<()> {
    // --- 1. modeled tuning (paper Table I) ------------------------------
    let mut model = PerfModel::default();
    let t = tune(&mut model, "mobilenet_v2", &TuneConfig::default())?;
    println!(
        "Algorithm 1: newport bs {} ({:.2} img/s), host bs {} ({:.2} img/s)",
        t.newport_bs, t.newport_ips, t.host_bs, t.host_ips
    );

    // --- 2. real cluster --------------------------------------------------
    let cfg = ExperimentConfig {
        network: "mobilenet_v2_s".into(),
        num_csds: 2,
        include_host: true,
        bs_csd: 4,
        bs_host: 16,
        steps: 20,
        public_images: 512,
        private_per_csd: 128,
        ..Default::default()
    };
    println!(
        "\nbringing up: 1 host (bs {}) + {} CSDs (bs {}) on {}",
        cfg.bs_host, cfg.num_csds, cfg.bs_csd, cfg.network
    );
    let cluster = stannis::cluster::Cluster::bring_up(cfg.clone())?;
    println!(
        "placement: {} steps/epoch, host {} imgs, {} per CSD (privacy-checked)",
        cluster.placement.steps_per_epoch,
        cluster.placement.host_ids.len(),
        cluster.placement.csd_ids[0].len()
    );

    // --- 3. real training --------------------------------------------------
    let mut trainer = cluster.trainer()?;
    let report = trainer.train(cfg.steps)?;
    println!("\nstep losses (mean across {} workers):", trainer.num_workers());
    for (i, loss) in report.losses.iter().enumerate() {
        if i % 4 == 0 || i + 1 == report.losses.len() {
            println!("  step {i:>3}: {loss:.4}");
        }
    }
    println!(
        "\n{} images, loss {:.4} -> {:.4}, replica divergence {:.2e} (lockstep)",
        report.images_processed,
        report.first_loss(),
        report.last_loss(),
        report.max_replica_divergence
    );
    anyhow::ensure!(report.last_loss() < report.first_loss(), "loss must decrease");
    println!("quickstart OK");
    Ok(())
}
