//! Energy deep-dive — Table II with the component breakdown the paper's
//! wall-meter could not see: where the joules go as Newports replace
//! idle SSDs, and why energy/image falls while the rack's wall power
//! barely moves.
//!
//! Run: `cargo run --release --example energy_report`

use stannis::coordinator::{tune, ScheduleConfig, Scheduler, TuneConfig};
use stannis::csd::CsdConfig;
use stannis::metrics::{f, print_table};
use stannis::perfmodel::PerfModel;
use stannis::power::{account_interval, EnergyMeter, PowerConfig};
use stannis::tunnel::TunnelConfig;

fn main() -> anyhow::Result<()> {
    let mut m = PerfModel::default();
    let t = tune(&mut m, "mobilenet_v2", &TuneConfig::default())?;
    let power = PowerConfig::default();

    let mut rows = Vec::new();
    let mut base_j_img = 0.0;
    for n in [0usize, 4, 8, 16, 24] {
        let mut sched =
            Scheduler::new(PerfModel::default(), n, TunnelConfig::default(), CsdConfig::default());
        sched.preload_data(64)?;
        let r = sched.run(&ScheduleConfig {
            network: "mobilenet_v2".into(),
            num_csds: n,
            include_host: true,
            bs_csd: t.newport_bs,
            bs_host: t.host_bs,
            steps: 3,
            image_bytes: 12 * 1024,
            stage_io: true,
            per_step: false,
        })?;
        let mut meter = EnergyMeter::new();
        account_interval(&mut meter, &power, r.elapsed, n, 24, true, r.link_bytes, r.flash_reads, 0);
        let images = r.images_per_sec * r.elapsed.as_secs_f64();
        let j_img = meter.total_joules() / images;
        if n == 0 {
            base_j_img = j_img;
        }
        let b: std::collections::BTreeMap<_, _> = meter.breakdown().collect();
        rows.push(vec![
            n.to_string(),
            f(r.images_per_sec, 1),
            f(power.system_power_w(n, 24, true), 0),
            f(j_img, 2),
            format!("{}%", f(100.0 * (1.0 - j_img / base_j_img), 0)),
            f(b.get("host").copied().unwrap_or(0.0) / images, 2),
            f(b.get("idle_storage").copied().unwrap_or(0.0) / images, 2),
            f(b.get("newport").copied().unwrap_or(0.0) / images, 2),
            format!("{:.4}", b.get("link").copied().unwrap_or(0.0) / images),
        ]);
    }
    print_table(
        "Table II extended — energy per image with component breakdown (J/img)",
        &["CSDs", "img/s", "wall W", "J/img", "saving", "host", "idle SSDs", "newports", "link"],
        &rows,
    );

    println!(
        "\nreading: the win is NOT that Newports are cheap to run ({}W training),",
        f(power.newport_idle_w + power.newport_isp_active_w, 1)
    );
    println!(
        "it is that throughput scales {}x while wall power stays ~flat — fixed host+chassis",
        f(2.7, 1)
    );
    println!("energy amortizes over ~3x the images. The idle-SSD column shows the paper's");
    println!("baseline server was already paying {}W for storage that computed nothing.", f(24.0 * power.storage_idle_w, 0));
    println!("\nenergy_report OK");
    Ok(())
}
