//! Privacy-aware placement demo — the paper's §IV data-protection story.
//!
//! Builds a hybrid public/private dataset with *unequal* private shards
//! (the §IV corner case), balances it with Eq. 1, and demonstrates:
//!   1. private images never leave their home CSD (enforced + audited),
//!   2. short CSDs are topped up from the public pool,
//!   3. when the pool runs dry, private data is duplicated instead,
//!   4. host/ISP concurrent access to shared public files goes through
//!      the OCFS2-style DLM over the TCP/IP tunnel.
//!
//! Run: `cargo run --release --example privacy_placement`

use stannis::coordinator::balance;
use stannis::data::{Dataset, DatasetConfig, Visibility};
use stannis::fsync::{Dlm, LockMode, LockReply};
use stannis::metrics::print_table;
use stannis::sim::SimTime;
use stannis::tunnel::{NodeId, Tunnel, TunnelConfig};

fn main() -> anyhow::Result<()> {
    // Unequal private shards: csd2 is data-poor, csd3 nearly empty.
    let dataset = Dataset::new(DatasetConfig {
        public_images: 3000,
        private_per_csd: vec![800, 600, 250, 40],
        ..Default::default()
    })?;
    let placement = balance(&dataset, 4, 25, 315, true)?;

    // --- placement accounting -------------------------------------------
    let mut rows = Vec::new();
    for (c, ids) in placement.csd_ids.iter().enumerate() {
        let (mut private, mut public) = (0usize, 0usize);
        for &id in ids {
            match dataset.visibility(id)? {
                Visibility::Private { .. } => private += 1,
                Visibility::Public => public += 1,
            }
        }
        rows.push(vec![
            format!("csd{c}"),
            dataset.private_ids(c)?.len().to_string(),
            private.to_string(),
            public.to_string(),
            placement.duplicated[c].to_string(),
            ids.len().to_string(),
        ]);
    }
    rows.push(vec![
        "host".into(),
        "0".into(),
        "0".into(),
        placement.host_ids.len().to_string(),
        "0".into(),
        placement.host_ids.len().to_string(),
    ]);
    print_table(
        &format!(
            "Eq. 1 placement — {} steps/epoch (bs 25/CSD, 315/host)",
            placement.steps_per_epoch
        ),
        &["worker", "private owned", "private used", "public used", "duplicated", "total/epoch"],
        &rows,
    );

    // --- privacy audit ----------------------------------------------------
    let mut violations = 0;
    for &id in &placement.host_ids {
        if !matches!(dataset.visibility(id)?, Visibility::Public) {
            violations += 1;
        }
    }
    for (c, ids) in placement.csd_ids.iter().enumerate() {
        for &id in ids {
            if let Visibility::Private { csd } = dataset.visibility(id)? {
                if csd != c {
                    violations += 1;
                }
            }
        }
    }
    println!("\nprivacy audit: {violations} violations across {} placed images", placement.images_per_epoch());
    anyhow::ensure!(violations == 0);

    // --- OCFS2 metadata sync over the tunnel ------------------------------
    let mut tunnel = Tunnel::new(4, TunnelConfig::default());
    let mut dlm = Dlm::new();
    // Epoch start: every worker takes a protected-read on the public
    // manifest; the host then takes EX to rebalance, which must wait.
    let mut grants = 0;
    for c in 0..4 {
        if let LockReply::Granted { .. } =
            dlm.request(&mut tunnel, NodeId::Csd(c), "meta:/public/manifest", LockMode::Pr, SimTime::ZERO)
        {
            grants += 1;
        }
    }
    let host_req = dlm.request(
        &mut tunnel,
        NodeId::Host,
        "meta:/public/manifest",
        LockMode::Ex,
        SimTime::ms(1),
    );
    println!("\nDLM: {grants} concurrent PR grants; host EX while readers hold -> {host_req:?}");
    anyhow::ensure!(host_req == LockReply::Queued);
    // Readers drain; the EX grant arrives with a bumped journal version
    // after the last release.
    let mut granted_at = None;
    for c in 0..4 {
        let g = dlm.release(&mut tunnel, NodeId::Csd(c), "meta:/public/manifest", SimTime::ms(2 + c as u64))?;
        if let Some((node, at, _v)) = g.first() {
            granted_at = Some((*node, *at));
        }
    }
    let (node, at) = granted_at.expect("host EX must be granted after readers drain");
    println!("DLM: EX granted to {node} at t={at} (after all PR releases)");
    dlm.check_invariants()?;
    println!(
        "tunnel carried {} DLM messages / {} bytes",
        tunnel.stats().messages,
        tunnel.stats().bytes
    );
    println!("\nprivacy_placement OK");
    Ok(())
}
