//! Eq. 1 load balancing + privacy-aware data placement (paper §IV).
//!
//! After tuning fixes per-device batch sizes, an *epoch* must take the
//! same number of steps on every worker or the fast ones stall at the
//! epoch boundary. Eq. 1:
//!
//!   steps = dataset_card / batchsize_card
//!   dataset_host = steps · batchsize_host
//!
//! plus the paper's §IV provisions for unequal private shards: a CSD
//! short on private data is topped up from the public pool, or
//! duplicates its private data when the pool runs dry. Private data
//! never moves — the placement only ever assigns a CSD's own private
//! ids to that CSD (enforced again downstream by `data::Shard`).

use anyhow::{ensure, Result};

use crate::data::{Dataset, ImageId};

/// Per-worker dataset assignment for one epoch schedule.
#[derive(Debug, Clone)]
pub struct Placement {
    pub steps_per_epoch: usize,
    /// Public ids assigned to the host.
    pub host_ids: Vec<ImageId>,
    /// Per CSD: its full id list (private + any public top-up,
    /// duplicates appended when the pool was exhausted).
    pub csd_ids: Vec<Vec<ImageId>>,
    /// Accounting.
    pub public_used: usize,
    pub duplicated: Vec<usize>,
}

impl Placement {
    /// Images per epoch across all workers (duplicates count).
    pub fn images_per_epoch(&self) -> usize {
        self.host_ids.len() + self.csd_ids.iter().map(Vec::len).sum::<usize>()
    }
}

/// Compute the placement. `bs_csd`/`bs_host` come from Algorithm 1.
pub fn balance(
    dataset: &Dataset,
    num_csds: usize,
    bs_csd: usize,
    bs_host: usize,
    include_host: bool,
) -> Result<Placement> {
    balance_weighted(dataset, num_csds, bs_csd, bs_host, include_host, &[])
}

/// [`balance`] with per-CSD health weights: the public top-up is dealt
/// to CSDs in descending-health order (ties keep index order), so the
/// earliest — most-reused — public ids sit on the healthiest devices,
/// whose flash staging and movement relays are the least contended.
/// Shard *sizes* are untouched (Eq. 1 fixes them), only which public
/// ids land where. After a degradation the deal order changes and the
/// affected ids physically move; the fleet's data plane charges that
/// movement (DESIGN.md §Data-Plane). Uniform (or empty) weights
/// reproduce [`balance`] exactly.
pub fn balance_weighted(
    dataset: &Dataset,
    num_csds: usize,
    bs_csd: usize,
    bs_host: usize,
    include_host: bool,
    health: &[f64],
) -> Result<Placement> {
    ensure!(bs_csd > 0 && bs_host > 0, "zero batch size");
    ensure!(
        health.is_empty() || health.len() >= num_csds,
        "got {} health weights for {num_csds} CSDs",
        health.len()
    );
    ensure!(
        health.iter().all(|h| h.is_finite()),
        "non-finite health weight in {health:?}"
    );
    ensure!(
        num_csds > 0 || include_host,
        "cluster needs at least one worker"
    );
    ensure!(
        dataset.config().private_per_csd.len() >= num_csds,
        "dataset has private shards for {} CSDs, need {num_csds}",
        dataset.config().private_per_csd.len()
    );

    // Host-only degenerate case (the paper's 0-CSD baseline): one epoch
    // = one pass over the public pool.
    if num_csds == 0 {
        let steps = (dataset.num_public() / bs_host).max(1);
        let host_ids: Vec<ImageId> =
            (0..steps * bs_host).map(|i| i % dataset.num_public()).collect();
        return Ok(Placement {
            steps_per_epoch: steps,
            host_ids,
            csd_ids: Vec::new(),
            public_used: 0,
            duplicated: Vec::new(),
        });
    }

    // Eq. 1 anchor: the largest private shard sets steps_per_epoch so
    // no private image is dropped.
    let steps = (0..num_csds)
        .map(|c| dataset.config().private_per_csd[c].div_ceil(bs_csd))
        .max()
        .unwrap()
        .max(1);
    let per_csd = steps * bs_csd;

    // Public pool, dealt round-robin. The host draws after CSD top-ups:
    // the paper sizes the host's share from what remains ("the host has
    // access to more data than each individual CSD").
    let mut next_public: ImageId = 0;
    let total_public = dataset.num_public();
    let mut public_used = 0usize;

    // Deal order: healthiest first (stable on ties, so uniform weights
    // keep the plain 0..n order and the unweighted behaviour).
    let mut order: Vec<usize> = (0..num_csds).collect();
    if !health.is_empty() {
        order.sort_by(|&a, &b| {
            health[b].partial_cmp(&health[a]).expect("finite ensured").then(a.cmp(&b))
        });
    }

    let mut csd_ids = vec![Vec::new(); num_csds];
    let mut duplicated = vec![0usize; num_csds];
    for &c in &order {
        let mut ids: Vec<ImageId> = dataset.private_ids(c)?.collect();
        // Top up from the public pool.
        while ids.len() < per_csd && next_public < total_public {
            ids.push(next_public);
            next_public += 1;
            public_used += 1;
        }
        // Pool dry: duplicate private data (paper §IV) to keep the
        // image rate up.
        let private_len = dataset.config().private_per_csd[c];
        ensure!(
            private_len > 0 || ids.len() >= per_csd,
            "csd{c} has no private data and the public pool is dry"
        );
        let mut dup_cursor = 0usize;
        while ids.len() < per_csd {
            ids.push(dataset.private_ids(c)?.start + (dup_cursor % private_len));
            dup_cursor += 1;
            duplicated[c] += 1;
        }
        csd_ids[c] = ids;
    }

    // Host: Eq. 1 — steps * bs_host public images (wrapping the pool if
    // it is smaller; the host re-reads public data freely).
    let host_ids: Vec<ImageId> = if include_host {
        let need = steps * bs_host;
        (0..need).map(|i| (next_public + i) % total_public).collect()
    } else {
        Vec::new()
    };

    Ok(Placement { steps_per_epoch: steps, host_ids, csd_ids, public_used, duplicated })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DatasetConfig, Shard, Visibility};

    fn dataset(public: usize, private: Vec<usize>) -> Dataset {
        Dataset::new(DatasetConfig {
            public_images: public,
            private_per_csd: private,
            hw: 8,
            classes: 4,
            seed: 2,
            noise: 0.5,
        })
        .unwrap()
    }

    #[test]
    fn eq1_host_sizing() {
        // dataset_card = 500, bs_card = 25 -> 20 steps; bs_host = 315
        // -> host gets 6300 images (Eq. 1).
        let d = dataset(10_000, vec![500, 500]);
        let p = balance(&d, 2, 25, 315, true).unwrap();
        assert_eq!(p.steps_per_epoch, 20);
        assert_eq!(p.host_ids.len(), 20 * 315);
        for ids in &p.csd_ids {
            assert_eq!(ids.len(), 20 * 25);
        }
    }

    #[test]
    fn equal_steps_for_all_nodes() {
        let d = dataset(5000, vec![300, 200, 100]);
        let p = balance(&d, 3, 16, 100, true).unwrap();
        for ids in &p.csd_ids {
            assert_eq!(ids.len() % 16, 0);
            assert_eq!(ids.len() / 16, p.steps_per_epoch);
        }
        assert_eq!(p.host_ids.len() / 100, p.steps_per_epoch);
    }

    #[test]
    fn unequal_private_topped_up_from_public() {
        let d = dataset(5000, vec![400, 100]);
        let p = balance(&d, 2, 20, 50, true).unwrap();
        // csd0 sets the pace: 400/20 = 20 steps; csd1 needs 400 images
        // but has 100 private -> 300 public top-up.
        assert_eq!(p.steps_per_epoch, 20);
        assert_eq!(p.csd_ids[1].len(), 400);
        let public_in_csd1 = p.csd_ids[1]
            .iter()
            .filter(|&&id| matches!(d.visibility(id).unwrap(), Visibility::Public))
            .count();
        assert_eq!(public_in_csd1, 300);
        assert_eq!(p.duplicated, vec![0, 0]);
    }

    #[test]
    fn dry_pool_duplicates_private() {
        // Public pool far too small to top up csd1.
        let d = dataset(10, vec![400, 100]);
        let p = balance(&d, 2, 20, 50, true).unwrap();
        assert_eq!(p.csd_ids[1].len(), 400);
        assert!(p.duplicated[1] > 0, "must duplicate when pool is dry");
        // All ids in csd1 are its own private ones or public — never
        // csd0's private range.
        for &id in &p.csd_ids[1] {
            match d.visibility(id).unwrap() {
                Visibility::Private { csd } => assert_eq!(csd, 1),
                Visibility::Public => {}
            }
        }
    }

    #[test]
    fn placement_feeds_shards_without_privacy_violation() {
        let d = dataset(1000, vec![64, 32]);
        let p = balance(&d, 2, 8, 32, true).unwrap();
        // Constructing shards re-checks privacy; must not error.
        Shard::new(&d, None, p.host_ids.clone(), 1).unwrap();
        for (c, ids) in p.csd_ids.iter().enumerate() {
            Shard::new(&d, Some(c), ids.clone(), 2 + c as u64).unwrap();
        }
        // Host ids are all public.
        for &id in &p.host_ids {
            assert!(matches!(d.visibility(id).unwrap(), Visibility::Public));
        }
    }

    #[test]
    fn weighted_balance_uniform_matches_unweighted() {
        let d = dataset(5000, vec![300, 200, 100]);
        let plain = balance(&d, 3, 16, 100, true).unwrap();
        let weighted = balance_weighted(&d, 3, 16, 100, true, &[1.0, 1.0, 1.0]).unwrap();
        assert_eq!(plain.csd_ids, weighted.csd_ids);
        assert_eq!(plain.host_ids, weighted.host_ids);
        assert_eq!(plain.steps_per_epoch, weighted.steps_per_epoch);
    }

    #[test]
    fn weighted_balance_moves_public_topup_to_healthy_devices() {
        // Equal private shards of 50 at bs 20: Eq. 1 rounds the epoch
        // to 3 steps = 60 images per CSD, so each tops up 10 public
        // images — and the deal order decides which block lands where.
        let d = dataset(5000, vec![50, 50]);
        let before = balance_weighted(&d, 2, 20, 50, false, &[1.0, 1.0]).unwrap();
        let after = balance_weighted(&d, 2, 20, 50, false, &[0.5, 1.0]).unwrap();
        // Healthy csd1 now draws first: it holds the block csd0 held.
        let publics = |p: &Placement, c: usize| -> Vec<ImageId> {
            p.csd_ids[c]
                .iter()
                .copied()
                .filter(|&id| matches!(d.visibility(id).unwrap(), Visibility::Public))
                .collect()
        };
        assert_eq!(publics(&before, 0), publics(&after, 1), "public block must swap");
        assert_eq!(publics(&before, 1), publics(&after, 0));
        // Private data never moves, sizes and host share are untouched.
        for c in 0..2 {
            assert!(after.csd_ids[c].contains(&d.private_ids(c).unwrap().start));
            assert_eq!(after.csd_ids[c].len(), before.csd_ids[c].len());
        }
        assert_eq!(before.host_ids, after.host_ids);
        assert!(balance_weighted(&d, 2, 20, 50, false, &[1.0]).is_err(), "short weights");
        assert!(balance_weighted(&d, 2, 20, 50, false, &[f64::NAN, 1.0]).is_err());
    }

    #[test]
    fn no_host_mode() {
        let d = dataset(100, vec![40]);
        let p = balance(&d, 1, 8, 32, false).unwrap();
        assert!(p.host_ids.is_empty());
        assert_eq!(p.steps_per_epoch, 5);
    }

    #[test]
    fn bad_inputs_rejected() {
        let d = dataset(100, vec![40]);
        assert!(balance(&d, 0, 8, 8, false).is_err(), "no workers at all");
        assert!(balance(&d, 1, 0, 8, true).is_err());
        assert!(balance(&d, 2, 8, 8, true).is_err(), "more CSDs than shards");
    }

    #[test]
    fn host_only_baseline_placement() {
        // The paper's 0-CSD baseline: one epoch = one public pass.
        let d = dataset(100, vec![40]);
        let p = balance(&d, 0, 8, 25, true).unwrap();
        assert_eq!(p.steps_per_epoch, 4);
        assert_eq!(p.host_ids.len(), 100);
        assert!(p.csd_ids.is_empty());
    }
}
