//! The Stannis façade: tune → balance → train, with *real* numerics.
//!
//! This is the paper's end-to-end flow on the real-execution path: the
//! PJRT engine runs every worker's AOT-compiled train step, gradients
//! cross a faithful ring allreduce, and each worker applies SGD to its
//! own replica. Replicas provably stay in lockstep (asserted), which is
//! the §V.C accuracy-parity claim in its strongest form.
//!
//! Modeled time is accounted in parallel via the scheduler components,
//! so a real run also yields the paper-scale timeline it *would* have
//! had on the Xeon + 24-Newport testbed.

use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::allreduce::ring_allreduce_mean;
use crate::data::{Dataset, Shard};
use crate::model::{ParamStore, Sgd, SgdConfig};
use crate::runtime::Engine;
use crate::tunnel::NodeId;

/// Configuration for a real-execution training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub network: String,
    pub num_csds: usize,
    pub include_host: bool,
    /// Batch sizes (must have matching AOT artifacts).
    pub bs_csd: usize,
    pub bs_host: usize,
    pub steps: usize,
    pub sgd: SgdConfig,
    pub seed: i32,
    /// Check replica consistency every k steps (0 = never).
    pub consistency_every: usize,
    /// Weight gradients by batch size before averaging (the unbiased
    /// estimator for heterogeneous batches; plain Horovod averages
    /// unweighted, which over-weights small noisy CSD batches — set
    /// false to reproduce that behaviour as an ablation).
    pub weighted_grads: bool,
}

/// One worker's live state.
struct WorkerState {
    node: NodeId,
    batch_size: usize,
    params: ParamStore,
    opt: Sgd,
    shard: Shard,
}

/// Step-by-step training record.
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    /// Mean worker loss per step.
    pub losses: Vec<f32>,
    /// Max divergence observed between replicas at the checks.
    pub max_replica_divergence: f32,
    pub images_processed: usize,
}

impl TrainReport {
    pub fn first_loss(&self) -> f32 {
        self.losses.first().copied().unwrap_or(f32::NAN)
    }

    pub fn last_loss(&self) -> f32 {
        self.losses.last().copied().unwrap_or(f32::NAN)
    }
}

/// The real-execution trainer.
pub struct StannisTrainer {
    engine: Arc<Engine>,
    dataset: Dataset,
    workers: Vec<WorkerState>,
    cfg: TrainConfig,
}

impl StannisTrainer {
    /// Build workers from a placement (see [`super::balance`]).
    pub fn new(
        engine: Arc<Engine>,
        dataset: Dataset,
        placement: &super::Placement,
        cfg: TrainConfig,
    ) -> Result<Self> {
        ensure!(
            placement.csd_ids.len() >= cfg.num_csds,
            "placement covers {} CSDs, need {}",
            placement.csd_ids.len(),
            cfg.num_csds
        );
        let net = engine.network(&cfg.network)?;
        ensure!(
            net.train_artifact(cfg.bs_csd).is_some(),
            "no train artifact for CSD batch {}",
            cfg.bs_csd
        );
        if cfg.include_host {
            ensure!(
                net.train_artifact(cfg.bs_host).is_some(),
                "no train artifact for host batch {}",
                cfg.bs_host
            );
        }

        // All replicas start identical: one init, cloned. The SGD config
        // (incl. the total-batch lr scaling) comes from the caller.
        let init = engine.init_params(&cfg.network, cfg.seed)?;
        let num_workers = cfg.num_csds + usize::from(cfg.include_host);
        let sgd = cfg.sgd;

        let mut workers = Vec::with_capacity(num_workers);
        if cfg.include_host {
            workers.push(WorkerState {
                node: NodeId::Host,
                batch_size: cfg.bs_host,
                params: init.clone(),
                opt: Sgd::new(sgd),
                shard: Shard::new(&dataset, None, placement.host_ids.clone(), 91)?,
            });
        }
        for c in 0..cfg.num_csds {
            workers.push(WorkerState {
                node: NodeId::Csd(c),
                batch_size: cfg.bs_csd,
                params: init.clone(),
                opt: Sgd::new(sgd),
                shard: Shard::new(&dataset, Some(c), placement.csd_ids[c].clone(), 101 + c as u64)?,
            });
        }
        Ok(Self { engine, dataset, workers, cfg })
    }

    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    /// Run `steps` synchronous steps of real training.
    pub fn train(&mut self, steps: usize) -> Result<TrainReport> {
        let num_workers = self.workers.len();
        let mut report = TrainReport::default();
        for step in 0..steps {
            
            // 1. Every worker computes loss + grads on its own shard.
            let mut flats: Vec<Vec<f32>> = Vec::with_capacity(self.workers.len());
            let mut loss_sum = 0.0f32;
            let total_batch: usize = self.workers.iter().map(|w| w.batch_size).sum();
            for w in &mut self.workers {
                let (x, y) = w.shard.batch(&self.dataset, w.batch_size)?;
                let out = self
                    .engine
                    .train_step(&self.cfg.network, w.batch_size, &w.params, &x, &y)?;
                loss_sum += out.loss;
                report.images_processed += w.batch_size;
                let mut flat = out.grads.to_flat();
                if self.cfg.weighted_grads {
                    // Pre-scale so the ring's plain mean yields the
                    // batch-weighted mean: Σ bs_i·g_i / Σ bs_i.
                    let k = w.batch_size as f32 * num_workers as f32
                        / total_batch as f32;
                    for g in &mut flat {
                        *g *= k;
                    }
                }
                flats.push(flat);
            }
            report.losses.push(loss_sum / self.workers.len() as f32);

            // 2. Ring allreduce (mean) across the replicas.
            ring_allreduce_mean(&mut flats)?;

            // 3. Local SGD with the shared averaged gradient.
            for (w, flat) in self.workers.iter_mut().zip(&flats) {
                let mut grads = ParamStore::zeros_like_specs(
                    &self.engine.network(&self.cfg.network)?.params,
                );
                grads.load_flat(flat)?;
                w.opt.apply(&mut w.params, &grads)?;
            }

            // 4. Lockstep check.
            if self.cfg.consistency_every > 0 && (step + 1) % self.cfg.consistency_every == 0 {
                let d = self.replica_divergence();
                report.max_replica_divergence = report.max_replica_divergence.max(d);
                ensure!(
                    d < 1e-4,
                    "replicas diverged at step {step}: max |Δ| = {d}"
                );
            }
        }
        Ok(report)
    }

    /// Max parameter divergence across replicas (0 in exact lockstep).
    pub fn replica_divergence(&self) -> f32 {
        let first = &self.workers[0].params;
        self.workers[1..]
            .iter()
            .map(|w| w.params.max_abs_diff(first))
            .fold(0.0, f32::max)
    }

    /// Evaluate the (shared) model on freshly drawn public data.
    pub fn evaluate(&mut self, batches: usize) -> Result<(f32, f32)> {
        let net = self.engine.network(&self.cfg.network)?.clone();
        let bs = net.eval_batch_size;
        let params = self.workers[0].params.clone();
        let mut shard = Shard::new(
            &self.dataset,
            None,
            (0..self.dataset.num_public()).collect(),
            777,
        )?;
        let mut loss = 0.0f32;
        let mut correct = 0i32;
        for _ in 0..batches {
            let (x, y) = shard.batch(&self.dataset, bs)?;
            let out = self.engine.eval_step(&self.cfg.network, &params, &x, &y)?;
            loss += out.loss;
            correct += out.correct;
        }
        Ok((loss / batches as f32, correct as f32 / (batches * bs) as f32))
    }

    /// Which node holds each worker (placement introspection).
    pub fn topology(&self) -> Vec<NodeId> {
        self.workers.iter().map(|w| w.node).collect()
    }
}
