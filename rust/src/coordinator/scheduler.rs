//! Modeled epoch scheduler: composes the calibrated device model, the
//! CSD flash staging path and the tunnel-borne ring allreduce into the
//! per-step timeline behind Fig. 6/7 and Table II.
//!
//! One synchronous data-parallel step is:
//!   1. every worker stages its batch (CSD: flash → ISP DRAM over the
//!      internal bus; host: flash → NVMe → host DRAM from its CSDs),
//!   2. every worker computes fwd/bwd (calibrated step time),
//!   3. the ring allreduce of paper-scale gradient bytes runs over the
//!      TCP/IP tunnel (barrier),
//!   4. SGD applies locally (absorbed into compute).

use anyhow::Result;

use crate::allreduce::ring_time;
use crate::csd::{CsdConfig, NewportCsd};
use crate::perfmodel::{Device, NetId, PerfModel};
use crate::sim::SimTime;
use crate::tunnel::{NodeId, Tunnel, TunnelConfig};

/// Modeled-cluster schedule parameters.
#[derive(Debug, Clone)]
pub struct ScheduleConfig {
    pub network: String,
    pub num_csds: usize,
    pub include_host: bool,
    pub bs_csd: usize,
    pub bs_host: usize,
    pub steps: usize,
    /// Bytes of one staged image on flash (dataset-dependent).
    pub image_bytes: usize,
    /// Model I/O staging through the CSD flash substrate (off for pure
    /// compute/sync studies, on for Table II energy accounting).
    pub stage_io: bool,
    /// Force the per-step reference loop even where the steady-state
    /// closed form applies (equivalence tests, overhead benches).
    /// With `stage_io` off every step is an exact repeat, so the run
    /// collapses to `steps ×` one modeled step — bit-identical either
    /// way (DESIGN.md §Perf).
    pub per_step: bool,
}

/// Per-run report.
#[derive(Debug, Clone)]
pub struct EpochReport {
    pub steps: usize,
    /// Total modeled wall time.
    pub elapsed: SimTime,
    /// Aggregate throughput, img/s.
    pub images_per_sec: f64,
    /// Per-worker throughput (host first if present), img/s.
    pub per_worker_ips: Vec<f64>,
    /// Mean share of a step spent synchronizing.
    pub sync_fraction: f64,
    /// Flash + link traffic for the energy model.
    pub flash_reads: u64,
    pub link_bytes: u64,
}

/// The modeled cluster (host + N CSDs + tunnel).
pub struct Scheduler {
    model: PerfModel,
    tunnel: Tunnel,
    csds: Vec<NewportCsd>,
}

impl Scheduler {
    pub fn new(model: PerfModel, num_csds: usize, tunnel_cfg: TunnelConfig, csd_cfg: CsdConfig) -> Self {
        let csds = (0..num_csds)
            .map(|i| NewportCsd::new(i, csd_cfg.clone(), 0xC5D0 + i as u64))
            .collect();
        Self { model, tunnel: Tunnel::new(num_csds, tunnel_cfg), csds }
    }

    /// Pre-stage `images` logical pages of dataset on every CSD so
    /// training reads hit mapped flash.
    pub fn preload_data(&mut self, pages_per_csd: u32) -> Result<()> {
        for csd in &mut self.csds {
            for lpn in 0..pages_per_csd {
                csd.write_page(lpn, lpn as u64, SimTime::ZERO)?;
            }
        }
        Ok(())
    }

    /// Simulate `cfg.steps` synchronous steps; returns the timeline.
    ///
    /// With staging off, every step is an exact repeat (pure compute
    /// model + shift-invariant fluid ring), so the run is computed in
    /// closed form from one modeled step unless `cfg.per_step` forces
    /// the reference loop — the two are bit-identical.
    pub fn run(&mut self, cfg: &ScheduleConfig) -> Result<EpochReport> {
        let n_workers = cfg.num_csds + usize::from(cfg.include_host);
        anyhow::ensure!(n_workers > 0, "no workers");
        let net = NetId::resolve(&cfg.network)?;
        let sync_bytes = net.sync_bytes();
        let pages_per_image = cfg.image_bytes.div_ceil(
            self.csds.first().map_or(16 * 1024, |c| c.page_bytes()),
        );

        let ranks: Vec<NodeId> = (if cfg.include_host {
            vec![NodeId::Host]
        } else {
            vec![]
        })
        .into_iter()
        .chain((0..cfg.num_csds).map(NodeId::Csd))
        .collect();

        let host_compute = if cfg.include_host {
            Some(self.model.step_time_id(Device::HostXeon, net, cfg.bs_host)?)
        } else {
            None
        };
        let csd_compute = self.model.step_time_id(Device::NewportIsp, net, cfg.bs_csd)?;

        let mut now = SimTime::ZERO;
        let mut sync_total = SimTime::ZERO;
        let mut flash_reads = 0u64;
        let mut data_cursor = 0u32;

        if !cfg.stage_io && !cfg.per_step && cfg.steps > 0 {
            // Steady-state fast-forward: model one step, then scale its
            // integer time/traffic totals by the step count — exactly
            // what the loop below would accumulate one step at a time.
            let mut compute_done = SimTime::ZERO;
            if let Some(hc) = host_compute {
                compute_done = compute_done.max(hc);
            }
            // Mirror the reference loop exactly: it iterates the
            // *constructed* CSDs, which a caller may have sized
            // differently from `cfg.num_csds`.
            if !self.csds.is_empty() {
                compute_done = compute_done.max(csd_compute);
            }
            let before = self.tunnel.stats();
            let step_end = if ranks.len() > 1 {
                ring_time(&mut self.tunnel, &ranks, sync_bytes, compute_done)
            } else {
                compute_done
            };
            let after = self.tunnel.stats();
            let k = cfg.steps as u64;
            // Credit the remaining k-1 rings on the fabric ledger.
            self.tunnel.note_aggregate(
                (k - 1) * (after.messages - before.messages),
                (k - 1) * (after.bytes - before.bytes),
            );
            now = step_end * k;
            sync_total = (step_end - compute_done) * k;
            return Ok(self.summarize(cfg, now, sync_total, flash_reads));
        }

        for _step in 0..cfg.steps {
            let mut compute_done = now;
            // Host batch staging: public data streamed from the CSDs
            // over NVMe (round-robin source).
            if let Some(hc) = host_compute {
                let ready = if cfg.stage_io && !self.csds.is_empty() {
                    let mut ready = now;
                    let per_csd = (cfg.bs_host * pages_per_image).div_ceil(self.csds.len().max(1));
                    for csd in &mut self.csds {
                        // Wrapping LPN range over the preloaded pages —
                        // scratch-free (no per-step `Vec<u32>`).
                        ready = ready
                            .max(csd.read_for_host_wrapped(data_cursor, per_csd as u32, 64, now)?);
                        flash_reads += per_csd as u64;
                    }
                    ready
                } else {
                    now
                };
                compute_done = compute_done.max(ready + hc);
            }
            // CSD steps: stage locally (ISP path), then compute.
            for csd in &mut self.csds {
                let done = if cfg.stage_io {
                    let count = (cfg.bs_csd * pages_per_image) as u32;
                    flash_reads += count as u64;
                    csd.isp_train_step_range(
                        data_cursor,
                        count,
                        64,
                        csd_compute,
                        sync_bytes as u64,
                        cfg.image_bytes as u64 * 4, // activations ≈ 4x input
                        cfg.bs_csd,
                        now,
                    )?
                } else {
                    now + csd_compute
                };
                compute_done = compute_done.max(done);
            }
            data_cursor = data_cursor.wrapping_add(37);

            // Ring allreduce barrier.
            let sync_done = if ranks.len() > 1 {
                ring_time(&mut self.tunnel, &ranks, sync_bytes, compute_done)
            } else {
                compute_done
            };
            sync_total += sync_done - compute_done;
            now = sync_done;
        }

        Ok(self.summarize(cfg, now, sync_total, flash_reads))
    }

    /// Shared report tail of the per-step and fast-forward paths.
    fn summarize(
        &self,
        cfg: &ScheduleConfig,
        elapsed: SimTime,
        sync_total: SimTime,
        flash_reads: u64,
    ) -> EpochReport {
        let images_per_step = cfg.num_csds * cfg.bs_csd
            + if cfg.include_host { cfg.bs_host } else { 0 };
        let images_per_sec =
            (images_per_step * cfg.steps) as f64 / elapsed.as_secs_f64().max(1e-12);
        let step_time = elapsed.as_secs_f64() / cfg.steps as f64;
        let mut per_worker_ips = Vec::new();
        if cfg.include_host {
            per_worker_ips.push(cfg.bs_host as f64 / step_time);
        }
        per_worker_ips.extend((0..cfg.num_csds).map(|_| cfg.bs_csd as f64 / step_time));

        EpochReport {
            steps: cfg.steps,
            elapsed,
            images_per_sec,
            per_worker_ips,
            sync_fraction: sync_total.as_secs_f64() / elapsed.as_secs_f64().max(1e-12),
            flash_reads,
            link_bytes: self.tunnel.stats().bytes,
        }
    }
}

/// Convenience: modeled throughput for (network, #CSDs) with tuned
/// batches — the Fig. 6 datapoint generator.
pub fn modeled_throughput(
    network: &str,
    num_csds: usize,
    include_host: bool,
    bs_csd: usize,
    bs_host: usize,
    steps: usize,
) -> Result<EpochReport> {
    let mut sched = Scheduler::new(
        PerfModel::default(),
        num_csds,
        TunnelConfig::default(),
        CsdConfig::default(),
    );
    sched.run(&ScheduleConfig {
        network: network.to_string(),
        num_csds,
        include_host,
        bs_csd,
        bs_host,
        steps,
        image_bytes: 12 * 1024,
        stage_io: false,
        per_step: false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_alone_matches_calibration() {
        let r = modeled_throughput("mobilenet_v2", 0, true, 25, 315, 5).unwrap();
        assert!((r.images_per_sec - 31.05).abs() < 1.0, "{}", r.images_per_sec);
        assert_eq!(r.sync_fraction, 0.0);
    }

    #[test]
    fn adding_csds_increases_aggregate_throughput() {
        let r0 = modeled_throughput("mobilenet_v2", 0, true, 25, 315, 4).unwrap();
        let r8 = modeled_throughput("mobilenet_v2", 8, true, 25, 315, 4).unwrap();
        let r24 = modeled_throughput("mobilenet_v2", 24, true, 25, 315, 4).unwrap();
        assert!(r8.images_per_sec > r0.images_per_sec);
        assert!(r24.images_per_sec > r8.images_per_sec);
    }

    #[test]
    fn per_node_throughput_declines_then_converges() {
        // Fig. 6's shape: individual node speed drops as nodes join,
        // then flattens beyond ~5-6 devices.
        let ips = |n| {
            modeled_throughput("mobilenet_v2", n, true, 25, 315, 4)
                .unwrap()
                .per_worker_ips[0]
        };
        let (a, b, c, d) = (ips(1), ips(4), ips(12), ips(24));
        assert!(b < a, "slowdown must appear: {a} -> {b}");
        let early_drop = (a - b) / a;
        let late_drop = (c - d) / c;
        assert!(late_drop < early_drop, "slowdown must fade: {early_drop} vs {late_drop}");
    }

    #[test]
    fn bigger_models_pay_more_sync() {
        let mv = modeled_throughput("mobilenet_v2", 16, true, 25, 315, 4).unwrap();
        let inc = modeled_throughput("inception_v3", 16, true, 16, 370, 4).unwrap();
        assert!(
            inc.sync_fraction > mv.sync_fraction,
            "inception (23.8M params) must sync longer than mobilenet: {} vs {}",
            inc.sync_fraction,
            mv.sync_fraction
        );
    }

    #[test]
    fn staged_io_accounts_flash_traffic() {
        let mut sched = Scheduler::new(
            PerfModel::default(),
            2,
            TunnelConfig::default(),
            CsdConfig::default(),
        );
        sched.preload_data(64).unwrap();
        let r = sched
            .run(&ScheduleConfig {
                network: "mobilenet_v2".into(),
                num_csds: 2,
                include_host: true,
                bs_csd: 4,
                bs_host: 16,
                steps: 2,
                image_bytes: 12 * 1024,
                stage_io: true,
                per_step: false,
            })
            .unwrap();
        assert!(r.flash_reads > 0);
        assert!(r.link_bytes > 0);
    }

    #[test]
    fn fast_forward_is_bit_identical_to_per_step() {
        // Property: across randomized shapes, the closed-form run and
        // the per-step reference produce the same report, bit for bit.
        crate::util::prop::check("scheduler fast-forward equivalence", |rng| {
            let nets = ["mobilenet_v2", "nasnet", "inception_v3", "squeezenet"];
            let num_csds = rng.usize_below(7);
            let include_host = num_csds == 0 || rng.bool(0.5);
            let cfg = ScheduleConfig {
                network: nets[rng.usize_below(nets.len())].into(),
                num_csds,
                include_host,
                bs_csd: 1 + rng.usize_below(64),
                bs_host: 1 + rng.usize_below(512),
                steps: 1 + rng.usize_below(40),
                image_bytes: 12 * 1024,
                stage_io: false,
                per_step: false,
            };
            let run = |per_step: bool| {
                let mut sched = Scheduler::new(
                    PerfModel::default(),
                    cfg.num_csds,
                    TunnelConfig::default(),
                    CsdConfig::default(),
                );
                sched.run(&ScheduleConfig { per_step, ..cfg.clone() }).unwrap()
            };
            let ff = run(false);
            let ps = run(true);
            assert_eq!(ff.elapsed, ps.elapsed, "elapsed must be bit-identical");
            assert_eq!(ff.steps, ps.steps);
            assert_eq!(ff.link_bytes, ps.link_bytes);
            assert_eq!(ff.flash_reads, ps.flash_reads);
            assert_eq!(ff.images_per_sec.to_bits(), ps.images_per_sec.to_bits());
            assert_eq!(ff.sync_fraction.to_bits(), ps.sync_fraction.to_bits());
            assert_eq!(ff.per_worker_ips.len(), ps.per_worker_ips.len());
            for (a, b) in ff.per_worker_ips.iter().zip(&ps.per_worker_ips) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        });
    }
}
