//! Algorithm 1 — Stannis's batch-size tuning for heterogeneous workers.
//!
//! Paper §IV: benchmark the slow engine (Newport) across a batch-size
//! ladder and pick its best batch; then grow the host's batch by
//! `Δt/C`-scaled increments until the host's time-per-batch reaches the
//! Newport time *plus* a synchronization margin (`E` tuned so the
//! margin is a fixed 20%). The numbers in Table I pin the semantics:
//! 25/3.08 img/s on Newport (8.12 s/batch) against 315/31.05 on the
//! host (10.15 s/batch) — i.e. host time ≈ newport_time / (1 - 0.2).

use anyhow::{ensure, Result};

use crate::perfmodel::Device;

/// Anything that can time one training batch on a device — the
/// modeled perf model in the paper-scale experiments, the real PJRT
/// engine (wallclock) in the integration tests.
pub trait StepBench {
    /// Seconds to complete one batch of `bs` on `device`.
    fn time_per_batch(&mut self, device: Device, network: &str, bs: usize) -> Result<f64>;
}

impl StepBench for crate::perfmodel::PerfModel {
    fn time_per_batch(&mut self, device: Device, network: &str, bs: usize) -> Result<f64> {
        // Memoized: the sweep revisits the same probes (and callers
        // like fig6/fig7 re-tune every network repeatedly).
        let net = crate::perfmodel::NetId::resolve(network)?;
        Ok(self.step_time_cached(device, net, bs)?.as_secs_f64())
    }
}

/// Tuner knobs (paper: `C` step scale, `E`-derived margin).
#[derive(Debug, Clone)]
pub struct TuneConfig {
    /// Batch candidates probed on the slow engine.
    pub newport_candidates: Vec<usize>,
    /// Stop growing the Newport batch when the next candidate improves
    /// throughput by less than this fraction (§V: speed converges; a
    /// bigger batch only costs DRAM).
    pub saturation_eps: f64,
    /// The paper's C: larger C = finer host batch updates.
    pub c: f64,
    /// Synchronization margin (paper's E gives 0.20).
    pub margin: f64,
    /// Convergence tolerance on the host-time target.
    pub tol: f64,
    /// Safety cap on host batch growth.
    pub max_host_bs: usize,
}

impl Default for TuneConfig {
    fn default() -> Self {
        Self {
            newport_candidates: vec![5, 10, 15, 20, 25, 30, 40, 50],
            saturation_eps: 0.009,
            c: 2.0,
            margin: 0.20,
            tol: 0.005,
            max_host_bs: 4096,
        }
    }
}

/// Tuning outcome for one network.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TuneResult {
    pub newport_bs: usize,
    pub host_bs: usize,
    /// img/s at the tuned batch sizes
    pub newport_ips: f64,
    pub host_ips: f64,
    /// s per batch at the tuned batch sizes
    pub newport_time: f64,
    pub host_time: f64,
    pub host_iters: usize,
}

/// Run Algorithm 1.
pub fn tune(bench: &mut dyn StepBench, network: &str, cfg: &TuneConfig) -> Result<TuneResult> {
    ensure!(!cfg.newport_candidates.is_empty(), "empty candidate ladder");
    ensure!(cfg.margin < 1.0, "margin must be < 1");

    // --- Newport: walk the ladder until throughput saturates. --------
    let mut newport_bs = cfg.newport_candidates[0];
    let mut newport_time = bench.time_per_batch(Device::NewportIsp, network, newport_bs)?;
    let mut newport_ips = newport_bs as f64 / newport_time;
    for &bs in &cfg.newport_candidates[1..] {
        let t = bench.time_per_batch(Device::NewportIsp, network, bs)?;
        let ips = bs as f64 / t;
        if ips <= newport_ips * (1.0 + cfg.saturation_eps) {
            break; // diminishing returns: keep the smaller batch
        }
        newport_bs = bs;
        newport_time = t;
        newport_ips = ips;
    }

    // --- Host: grow the batch toward the margin-adjusted target. -----
    // Target: host time-per-batch = newport_time / (1 - margin), the
    // slack that absorbs ring-sync stalls (see module docs).
    let target = newport_time / (1.0 - cfg.margin);
    let mut host_bs = newport_bs.max(1);
    let mut host_time = bench.time_per_batch(Device::HostXeon, network, host_bs)?;
    let mut iters = 0;
    while (host_time - target).abs() > cfg.tol * target && iters < 64 {
        // Paper's update: BS += BS * Δt / C (Δt normalized by target).
        let delta = (target - host_time) / target;
        let step = (host_bs as f64 * delta / cfg.c).round() as i64;
        let step = if step == 0 { delta.signum() as i64 } else { step };
        let next = (host_bs as i64 + step).clamp(1, cfg.max_host_bs as i64) as usize;
        if next == host_bs {
            break;
        }
        host_bs = next;
        host_time = bench.time_per_batch(Device::HostXeon, network, host_bs)?;
        iters += 1;
    }
    let host_ips = host_bs as f64 / host_time;

    Ok(TuneResult {
        newport_bs,
        host_bs,
        newport_ips,
        host_ips,
        newport_time,
        host_time,
        host_iters: iters,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::PerfModel;

    #[test]
    fn mobilenet_matches_table1() {
        let mut m = PerfModel::default();
        let r = tune(&mut m, "mobilenet_v2", &TuneConfig::default()).unwrap();
        assert_eq!(r.newport_bs, 25, "paper Table I: Newport bs 25");
        assert!(
            (r.host_bs as i64 - 315).unsigned_abs() <= 16,
            "paper Table I: host bs 315, got {}",
            r.host_bs
        );
        assert!((r.newport_ips - 3.08).abs() < 0.1, "{}", r.newport_ips);
        assert!((r.host_ips - 31.05).abs() < 1.5, "{}", r.host_ips);
    }

    #[test]
    fn equalization_holds_margin() {
        let mut m = PerfModel::default();
        let cfg = TuneConfig::default();
        for net in ["mobilenet_v2", "nasnet", "inception_v3", "squeezenet"] {
            let r = tune(&mut m, net, &cfg).unwrap();
            let ratio = r.host_time / r.newport_time;
            assert!(
                (ratio - 1.25).abs() < 0.05,
                "{net}: host/newport time ratio {ratio:.3} != 1/(1-0.2)"
            );
        }
    }

    #[test]
    fn all_nets_saturate_newport_in_paper_range() {
        let mut m = PerfModel::default();
        for (net, paper_bs) in
            [("mobilenet_v2", 25), ("nasnet", 15), ("inception_v3", 16), ("squeezenet", 50)]
        {
            let r = tune(&mut m, net, &TuneConfig::default()).unwrap();
            assert!(
                (r.newport_bs as i64 - paper_bs).abs() <= 10,
                "{net}: newport bs {} vs paper {paper_bs}",
                r.newport_bs
            );
        }
    }

    #[test]
    fn finer_c_converges_tighter() {
        let mut m = PerfModel::default();
        let coarse = tune(
            &mut m,
            "mobilenet_v2",
            &TuneConfig { c: 1.0, tol: 0.05, ..Default::default() },
        )
        .unwrap();
        let fine = tune(
            &mut m,
            "mobilenet_v2",
            &TuneConfig { c: 4.0, tol: 0.001, ..Default::default() },
        )
        .unwrap();
        let target = fine.newport_time / 0.8;
        assert!((fine.host_time - target).abs() <= (coarse.host_time - target).abs() + 1e-9);
    }

    #[test]
    fn degenerate_configs_rejected() {
        let mut m = PerfModel::default();
        assert!(tune(
            &mut m,
            "mobilenet_v2",
            &TuneConfig { newport_candidates: vec![], ..Default::default() }
        )
        .is_err());
        assert!(tune(
            &mut m,
            "mobilenet_v2",
            &TuneConfig { margin: 1.5, ..Default::default() }
        )
        .is_err());
    }

    #[test]
    fn slower_host_gets_smaller_batch() {
        let mut slow = PerfModel::with_scales(0.5, 1.0);
        let mut fast = PerfModel::default();
        let cfg = TuneConfig::default();
        let rs = tune(&mut slow, "mobilenet_v2", &cfg).unwrap();
        let rf = tune(&mut fast, "mobilenet_v2", &cfg).unwrap();
        assert!(rs.host_bs < rf.host_bs);
    }
}
