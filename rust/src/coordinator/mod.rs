//! The Stannis coordinator — the paper's software contribution.
//!
//! * [`tuning`] — Algorithm 1: heterogeneous batch-size equalization
//! * [`balance`] — Eq. 1 dataset sizing + privacy-aware placement
//! * [`scheduler`] — modeled synchronous-step timeline (Fig. 6/7)
//! * [`stannis`] — the real-execution trainer (PJRT + ring allreduce)

pub mod balance;
pub mod scheduler;
pub mod stannis;
pub mod tuning;

pub use balance::{balance, balance_weighted, Placement};
pub use scheduler::{modeled_throughput, EpochReport, ScheduleConfig, Scheduler};
pub use stannis::{StannisTrainer, TrainConfig, TrainReport};
pub use tuning::{tune, StepBench, TuneConfig, TuneResult};
