//! OCFS2-style distributed lock manager + metadata journal.
//!
//! Paper §III: host and ISP engines mount the same flash filesystem
//! concurrently; two OCFS2 agents synchronize metadata over the TCP/IP
//! tunnel. We model the DLM the way OCFS2 uses it for the Stannis
//! workload: per-resource locks in PR (protected read, shared) or EX
//! (exclusive) mode, a FIFO grant queue (no starvation), and a
//! monotone metadata version bumped on every EX release (the journal
//! replay the readers pick up).
//!
//! The lock master lives on the host (OCFS2's designated node); every
//! request/grant crosses the tunnel, so lock traffic has a real cost
//! that shows up in epoch timings when public-data shards are
//! rebalanced mid-run.
//!
//! Resource names are interned into [`ResourceId`]s (mirroring
//! `perfmodel::NetId`): hot-path requests/releases are array-indexed,
//! the string entry points remain as shims for cold callers and tests.

use std::collections::{BTreeMap, VecDeque};

use anyhow::{bail, Result};

use crate::sim::SimTime;
use crate::tunnel::{NodeId, Tunnel};

/// OCFS2 lock modes used by the Stannis data plane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LockMode {
    /// Protected read: any number of concurrent holders.
    Pr,
    /// Exclusive: sole holder.
    Ex,
}

impl LockMode {
    fn compatible(self, other: LockMode) -> bool {
        matches!((self, other), (LockMode::Pr, LockMode::Pr))
    }
}

#[derive(Debug)]
struct LockState {
    holders: Vec<(NodeId, LockMode)>,
    queue: VecDeque<(NodeId, LockMode)>,
    version: u64,
}

impl LockState {
    fn new() -> Self {
        Self { holders: Vec::new(), queue: VecDeque::new(), version: 0 }
    }

    fn can_grant(&self, mode: LockMode) -> bool {
        // FIFO fairness: nothing may overtake a queued request.
        self.queue.is_empty() && self.holders.iter().all(|(_, m)| m.compatible(mode))
    }
}

/// Result of a lock request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LockReply {
    /// Granted; holding may begin at the given time.
    Granted { at: SimTime, version: u64 },
    /// Queued behind incompatible holders/requests.
    Queued,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct DlmStats {
    pub requests: u64,
    pub grants: u64,
    pub queued: u64,
    pub releases: u64,
    /// Deadline-bounded requests that gave up (DESIGN.md
    /// §Crash-Recovery: a waiter refusing to block on a dead holder).
    pub timeouts: u64,
    /// Holds stripped by [`Dlm::force_release`] during crash recovery.
    pub force_releases: u64,
}

/// Typed failure of the deadline-bounded acquisition path. The
/// unbounded [`Dlm::request`] can wait forever behind a dead holder;
/// callers that cannot afford that use [`Dlm::request_by`] and match
/// on this instead of a stringly-typed `anyhow` error.
#[derive(Debug, Clone, PartialEq)]
pub enum DlmError {
    /// The lock could not be granted by `deadline` — an incompatible
    /// holder (possibly a dead node) pins the resource, or the grant
    /// message would land too late. Nothing was enqueued: a timed-out
    /// request leaves no FIFO residue to strand later grants on.
    Timeout { resource: String, node: NodeId, deadline: SimTime },
}

impl std::fmt::Display for DlmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DlmError::Timeout { resource, node, deadline } => write!(
                f,
                "dlm: {node} timed out acquiring {resource:?} (deadline {deadline:?})"
            ),
        }
    }
}

impl std::error::Error for DlmError {}

/// Interned DLM resource name: an index into the master's name table.
/// Resolved once (at job admission, mirroring `perfmodel::NetId`), so
/// the lock hot path — every request, grant and release of a fleet
/// rebalance window — is an array index instead of a string hash and
/// compare. The string entry points below remain as shims.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ResourceId(u32);

/// The lock master (host-resident).
pub struct Dlm {
    /// Interned resource names; `ResourceId` indexes both tables.
    names: Vec<String>,
    by_name: BTreeMap<String, u32>,
    states: Vec<LockState>,
    stats: DlmStats,
    /// Message size of one DLM request/grant on the tunnel.
    msg_bytes: usize,
}

impl Default for Dlm {
    fn default() -> Self {
        Self::new()
    }
}

impl Dlm {
    pub fn new() -> Self {
        Self {
            names: Vec::new(),
            by_name: BTreeMap::new(),
            states: Vec::new(),
            stats: DlmStats::default(),
            msg_bytes: 256,
        }
    }

    pub fn stats(&self) -> DlmStats {
        self.stats
    }

    /// Intern `name`, creating the resource on first sight. The
    /// returned id is stable for the lifetime of the master.
    pub fn resource_id(&mut self, name: &str) -> ResourceId {
        if let Some(&i) = self.by_name.get(name) {
            return ResourceId(i);
        }
        let i = self.names.len() as u32;
        self.names.push(name.to_string());
        self.by_name.insert(name.to_string(), i);
        self.states.push(LockState::new());
        ResourceId(i)
    }

    /// The id of an already-interned resource, if any.
    pub fn lookup(&self, name: &str) -> Option<ResourceId> {
        self.by_name.get(name).copied().map(ResourceId)
    }

    /// The interned name of a resource id.
    pub fn name(&self, res: ResourceId) -> &str {
        &self.names[res.0 as usize]
    }

    /// Current metadata version of a resource (journal sequence).
    /// String shim over [`Self::version_id`].
    pub fn version(&self, resource: &str) -> u64 {
        self.lookup(resource).map_or(0, |id| self.version_id(id))
    }

    pub fn version_id(&self, res: ResourceId) -> u64 {
        self.states[res.0 as usize].version
    }

    pub fn holders(&self, resource: &str) -> Vec<(NodeId, LockMode)> {
        self.lookup(resource)
            .map_or_else(Vec::new, |id| self.states[id.0 as usize].holders.clone())
    }

    /// Requests currently queued behind incompatible holders.
    pub fn queue_len(&self, resource: &str) -> usize {
        self.lookup(resource).map_or(0, |id| self.states[id.0 as usize].queue.len())
    }

    /// Request `mode` on `resource` from `node` at `now` — string shim
    /// over [`Self::request_id`] (interning on first sight, as the old
    /// map entry did).
    pub fn request(
        &mut self,
        tunnel: &mut Tunnel,
        node: NodeId,
        resource: &str,
        mode: LockMode,
        now: SimTime,
    ) -> LockReply {
        let id = self.resource_id(resource);
        self.request_id(tunnel, node, id, mode, now)
    }

    /// Request `mode` on an interned resource, paying the tunnel
    /// round-trip when the requester is not the master (host).
    pub fn request_id(
        &mut self,
        tunnel: &mut Tunnel,
        node: NodeId,
        res: ResourceId,
        mode: LockMode,
        now: SimTime,
    ) -> LockReply {
        self.stats.requests += 1;
        let req_arrive = match node {
            NodeId::Host => now,
            csd => tunnel.send(csd, NodeId::Host, self.msg_bytes, now),
        };
        let state = &mut self.states[res.0 as usize];
        if state.can_grant(mode) {
            state.holders.push((node, mode));
            self.stats.grants += 1;
            let version = state.version;
            let granted_at = match node {
                NodeId::Host => req_arrive,
                csd => tunnel.send(NodeId::Host, csd, self.msg_bytes, req_arrive),
            };
            LockReply::Granted { at: granted_at, version }
        } else {
            state.queue.push_back((node, mode));
            self.stats.queued += 1;
            LockReply::Queued
        }
    }

    /// Deadline-bounded request — string shim over
    /// [`Self::request_id_by`].
    pub fn request_by(
        &mut self,
        tunnel: &mut Tunnel,
        node: NodeId,
        resource: &str,
        mode: LockMode,
        now: SimTime,
        deadline: SimTime,
    ) -> std::result::Result<LockReply, DlmError> {
        let id = self.resource_id(resource);
        self.request_id_by(tunnel, node, id, mode, now, deadline)
    }

    /// Request `mode` with a grant deadline: if the resource cannot be
    /// granted, or the grant message would arrive after `deadline`,
    /// the request fails with [`DlmError::Timeout`] instead of queueing
    /// — the caller never blocks behind a dead holder. The request
    /// message still pays its tunnel hop (it crossed the wire before
    /// the master could say no).
    pub fn request_id_by(
        &mut self,
        tunnel: &mut Tunnel,
        node: NodeId,
        res: ResourceId,
        mode: LockMode,
        now: SimTime,
        deadline: SimTime,
    ) -> std::result::Result<LockReply, DlmError> {
        self.stats.requests += 1;
        let req_arrive = match node {
            NodeId::Host => now,
            csd => tunnel.send(csd, NodeId::Host, self.msg_bytes, now),
        };
        if self.states[res.0 as usize].can_grant(mode) {
            let version = self.states[res.0 as usize].version;
            let granted_at = match node {
                NodeId::Host => req_arrive,
                csd => tunnel.send(NodeId::Host, csd, self.msg_bytes, req_arrive),
            };
            if granted_at <= deadline {
                self.states[res.0 as usize].holders.push((node, mode));
                self.stats.grants += 1;
                return Ok(LockReply::Granted { at: granted_at, version });
            }
        }
        self.stats.timeouts += 1;
        Err(DlmError::Timeout {
            resource: self.names[res.0 as usize].clone(),
            node,
            deadline,
        })
    }

    /// Crash recovery: strip every hold and queued request of a dead
    /// `node` across all resources. Each stripped EX hold bumps the
    /// metadata version (the master replays the dead node's journal
    /// before anyone else touches the resource), and freed resources
    /// grant their FIFO-compatible waiters exactly as a voluntary
    /// release would — including waiters that were stuck behind a dead
    /// *queued* EX request. Returns (resource, waiter, grant time,
    /// version) for every grant made.
    pub fn force_release(
        &mut self,
        tunnel: &mut Tunnel,
        node: NodeId,
        now: SimTime,
    ) -> Vec<(String, NodeId, SimTime, u64)> {
        let mut out = Vec::new();
        for i in 0..self.states.len() {
            let queued_before = self.states[i].queue.len();
            self.states[i].queue.retain(|(n, _)| *n != node);
            let stripped_queue = self.states[i].queue.len() != queued_before;
            let held = self.states[i].holders.iter().position(|(n, _)| *n == node);
            if let Some(idx) = held {
                let (_, mode) = self.states[i].holders.remove(idx);
                if mode == LockMode::Ex {
                    self.states[i].version += 1; // journal replay commit
                }
                self.stats.force_releases += 1;
            }
            if held.is_none() && !stripped_queue {
                continue;
            }
            // FIFO grant loop, the shape of `release_id` but driven by
            // the host-resident master at `now`: the dead node sends
            // nothing, grants still pay the master->waiter hop.
            loop {
                let Some(&(waiter, wmode)) = self.states[i].queue.front() else { break };
                if !self.states[i].holders.iter().all(|(_, m)| m.compatible(wmode)) {
                    break;
                }
                self.states[i].queue.pop_front();
                self.states[i].holders.push((waiter, wmode));
                self.stats.grants += 1;
                let at = match waiter {
                    NodeId::Host => now,
                    csd => tunnel.send(NodeId::Host, csd, self.msg_bytes, now),
                };
                out.push((self.names[i].clone(), waiter, at, self.states[i].version));
                if wmode == LockMode::Ex {
                    break; // EX admits exactly one
                }
            }
        }
        out
    }

    /// Release a held lock — string shim over [`Self::release_id`].
    pub fn release(
        &mut self,
        tunnel: &mut Tunnel,
        node: NodeId,
        resource: &str,
        now: SimTime,
    ) -> Result<Vec<(NodeId, SimTime, u64)>> {
        let Some(id) = self.lookup(resource) else {
            bail!("release of unknown resource {resource:?}");
        };
        self.release_id(tunnel, node, id, now)
    }

    /// Release a held lock; EX release bumps the metadata version
    /// (journal commit). Returns newly granted (node, time, version)
    /// tuples from the FIFO queue.
    pub fn release_id(
        &mut self,
        tunnel: &mut Tunnel,
        node: NodeId,
        res: ResourceId,
        now: SimTime,
    ) -> Result<Vec<(NodeId, SimTime, u64)>> {
        let pos = self.states[res.0 as usize]
            .holders
            .iter()
            .position(|(n, _)| *n == node);
        let Some(idx) = pos else {
            bail!("{node} does not hold {:?}", self.names[res.0 as usize]);
        };
        let state = &mut self.states[res.0 as usize];
        let (_, mode) = state.holders.remove(idx);
        if mode == LockMode::Ex {
            state.version += 1; // journal commit visible to next holders
        }
        self.stats.releases += 1;

        // Notify master (if remote releaser), then grant FIFO-compatible waiters.
        let release_arrive = match node {
            NodeId::Host => now,
            csd => tunnel.send(csd, NodeId::Host, self.msg_bytes, now),
        };
        let mut granted = Vec::new();
        while let Some(&(waiter, wmode)) = state.queue.front() {
            let compat = state.holders.iter().all(|(_, m)| m.compatible(wmode));
            if !compat {
                break;
            }
            state.queue.pop_front();
            state.holders.push((waiter, wmode));
            self.stats.grants += 1;
            let at = match waiter {
                NodeId::Host => release_arrive,
                csd => tunnel.send(NodeId::Host, csd, self.msg_bytes, release_arrive),
            };
            granted.push((waiter, at, state.version));
            if wmode == LockMode::Ex {
                break; // EX admits exactly one
            }
        }
        Ok(granted)
    }

    /// Invariant: the intern tables agree (`names`, `by_name` and
    /// `states` describe the same resources), at most one EX holder,
    /// EX never coexists with PR, and no node holds the same resource
    /// twice.
    pub fn check_invariants(&self) -> Result<()> {
        anyhow::ensure!(
            self.names.len() == self.states.len(),
            "names {} != states {}",
            self.names.len(),
            self.states.len()
        );
        anyhow::ensure!(
            self.by_name.len() == self.names.len(),
            "by_name {} != names {}",
            self.by_name.len(),
            self.names.len()
        );
        for (name, &idx) in &self.by_name {
            anyhow::ensure!(
                self.names.get(idx as usize) == Some(name),
                "by_name[{name:?}] = {idx} does not round-trip"
            );
        }
        for (i, state) in self.states.iter().enumerate() {
            let res = &self.names[i];
            let ex = state.holders.iter().filter(|(_, m)| *m == LockMode::Ex).count();
            anyhow::ensure!(ex <= 1, "{res}: {ex} EX holders");
            if ex == 1 {
                anyhow::ensure!(
                    state.holders.len() == 1,
                    "{res}: EX coexists with other holders: {:?}",
                    state.holders
                );
            }
            for (i, (node, _)) in state.holders.iter().enumerate() {
                anyhow::ensure!(
                    !state.holders[i + 1..].iter().any(|(n, _)| n == node),
                    "{res}: {node} holds the resource twice: {:?}",
                    state.holders
                );
            }
        }
        Ok(())
    }
}

fn hash_party(h: &mut crate::analysis::audit::Fnv64, node: NodeId, mode: LockMode) {
    match node {
        NodeId::Host => h.write_u64(0),
        NodeId::Csd(i) => {
            h.write_u64(1);
            h.write_usize(i);
        }
    }
    h.write_u64(match mode {
        LockMode::Pr => 0,
        LockMode::Ex => 1,
    });
}

impl crate::analysis::audit::Auditable for Dlm {
    fn component(&self) -> &'static str {
        "dlm"
    }

    fn audit(&self) -> crate::Result<()> {
        self.check_invariants()
    }

    fn fingerprint(&self, h: &mut crate::analysis::audit::Fnv64) {
        h.write_usize(self.names.len());
        for (name, state) in self.names.iter().zip(&self.states) {
            h.write_str(name);
            h.write_u64(state.version);
            h.write_usize(state.holders.len());
            for &(node, mode) in &state.holders {
                hash_party(h, node, mode);
            }
            h.write_usize(state.queue.len());
            for &(node, mode) in &state.queue {
                hash_party(h, node, mode);
            }
        }
        h.write_u64(self.stats.requests);
        h.write_u64(self.stats.grants);
        h.write_u64(self.stats.queued);
        h.write_u64(self.stats.releases);
        h.write_u64(self.stats.timeouts);
        h.write_u64(self.stats.force_releases);
        h.write_usize(self.msg_bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tunnel::TunnelConfig;
    use crate::util::prop;

    fn setup() -> (Dlm, Tunnel) {
        (Dlm::new(), Tunnel::new(4, TunnelConfig::default()))
    }

    #[test]
    fn pr_locks_share() {
        let (mut dlm, mut tun) = setup();
        let a = dlm.request(&mut tun, NodeId::Csd(0), "meta:/public", LockMode::Pr, SimTime::ZERO);
        let b = dlm.request(&mut tun, NodeId::Host, "meta:/public", LockMode::Pr, SimTime::ZERO);
        assert!(matches!(a, LockReply::Granted { .. }));
        assert!(matches!(b, LockReply::Granted { .. }));
        dlm.check_invariants().unwrap();
    }

    #[test]
    fn ex_excludes_and_queues() {
        let (mut dlm, mut tun) = setup();
        let a = dlm.request(&mut tun, NodeId::Host, "meta:/f", LockMode::Ex, SimTime::ZERO);
        assert!(matches!(a, LockReply::Granted { .. }));
        let b = dlm.request(&mut tun, NodeId::Csd(1), "meta:/f", LockMode::Pr, SimTime::ZERO);
        assert_eq!(b, LockReply::Queued);
        let granted = dlm.release(&mut tun, NodeId::Host, "meta:/f", SimTime::ms(1)).unwrap();
        assert_eq!(granted.len(), 1);
        assert_eq!(granted[0].0, NodeId::Csd(1));
        // EX release bumped the journal version the waiter observes.
        assert_eq!(granted[0].2, 1);
        dlm.check_invariants().unwrap();
    }

    #[test]
    fn fifo_no_overtaking() {
        let (mut dlm, mut tun) = setup();
        dlm.request(&mut tun, NodeId::Host, "r", LockMode::Ex, SimTime::ZERO);
        // EX waiter queues first, then a PR request arrives.
        dlm.request(&mut tun, NodeId::Csd(0), "r", LockMode::Ex, SimTime::ZERO);
        let pr = dlm.request(&mut tun, NodeId::Csd(1), "r", LockMode::Pr, SimTime::ZERO);
        assert_eq!(pr, LockReply::Queued, "PR must not overtake queued EX");
        assert_eq!(dlm.queue_len("r"), 2);
        assert_eq!(dlm.queue_len("unknown"), 0);
        let g1 = dlm.release(&mut tun, NodeId::Host, "r", SimTime::ms(1)).unwrap();
        assert_eq!(g1[0].0, NodeId::Csd(0), "FIFO: EX waiter first");
        assert_eq!(g1.len(), 1);
        let g2 = dlm.release(&mut tun, NodeId::Csd(0), "r", SimTime::ms(2)).unwrap();
        assert_eq!(g2[0].0, NodeId::Csd(1));
    }

    #[test]
    fn remote_requests_pay_tunnel_latency() {
        let (mut dlm, mut tun) = setup();
        match dlm.request(&mut tun, NodeId::Csd(2), "r", LockMode::Pr, SimTime::ZERO) {
            LockReply::Granted { at, .. } => assert!(at > SimTime::ZERO),
            other => panic!("{other:?}"),
        }
        match dlm.request(&mut tun, NodeId::Host, "r2", LockMode::Pr, SimTime::ZERO) {
            LockReply::Granted { at, .. } => assert_eq!(at, SimTime::ZERO),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn resource_interning_is_stable_and_matches_string_path() {
        let (mut dlm, mut tun) = setup();
        let a = dlm.resource_id("shardmap:job0");
        let b = dlm.resource_id("shardmap:job1");
        assert_ne!(a, b);
        assert_eq!(dlm.resource_id("shardmap:job0"), a);
        assert_eq!(dlm.lookup("shardmap:job0"), Some(a));
        assert_eq!(dlm.name(a), "shardmap:job0");
        assert_eq!(dlm.lookup("never"), None);
        assert_eq!(dlm.version("never"), 0);

        // The id path and the string shim drive the same state machine.
        let (mut sdlm, mut stun) = setup();
        let g1 = dlm.request_id(&mut tun, NodeId::Csd(0), a, LockMode::Ex, SimTime::ZERO);
        let g2 =
            sdlm.request(&mut stun, NodeId::Csd(0), "shardmap:job0", LockMode::Ex, SimTime::ZERO);
        assert_eq!(g1, g2);
        assert_eq!(
            dlm.request_id(&mut tun, NodeId::Csd(1), a, LockMode::Pr, SimTime::ZERO),
            LockReply::Queued
        );
        assert_eq!(
            sdlm.request(&mut stun, NodeId::Csd(1), "shardmap:job0", LockMode::Pr, SimTime::ZERO),
            LockReply::Queued
        );
        let r1 = dlm.release_id(&mut tun, NodeId::Csd(0), a, SimTime::ms(1)).unwrap();
        let r2 = sdlm.release(&mut stun, NodeId::Csd(0), "shardmap:job0", SimTime::ms(1)).unwrap();
        assert_eq!(r1, r2);
        assert_eq!(dlm.version_id(a), 1);
        assert_eq!(sdlm.version("shardmap:job0"), 1);
        dlm.check_invariants().unwrap();
        sdlm.check_invariants().unwrap();
    }

    #[test]
    fn release_errors() {
        let (mut dlm, mut tun) = setup();
        assert!(dlm.release(&mut tun, NodeId::Host, "never", SimTime::ZERO).is_err());
        dlm.request(&mut tun, NodeId::Host, "r", LockMode::Pr, SimTime::ZERO);
        assert!(dlm.release(&mut tun, NodeId::Csd(0), "r", SimTime::ZERO).is_err());
    }

    #[test]
    fn deadline_bounded_request_times_out_typed() {
        let (mut dlm, mut tun) = setup();
        dlm.request(&mut tun, NodeId::Csd(0), "r", LockMode::Ex, SimTime::ZERO);
        // The holder never releases: the bounded path refuses to queue
        // and surfaces a typed, matchable error.
        let err = dlm
            .request_by(&mut tun, NodeId::Csd(1), "r", LockMode::Pr, SimTime::ms(1), SimTime::ms(5))
            .unwrap_err();
        let DlmError::Timeout { resource, node, deadline } = &err;
        assert_eq!(resource, "r");
        assert_eq!(*node, NodeId::Csd(1));
        assert_eq!(*deadline, SimTime::ms(5));
        assert!(err.to_string().contains("timed out"), "{err}");
        assert_eq!(dlm.queue_len("r"), 0, "a timed-out request leaves no FIFO residue");
        assert_eq!(dlm.stats().timeouts, 1);
        // An uncontended bounded request grants like the plain path.
        match dlm
            .request_by(&mut tun, NodeId::Csd(1), "free", LockMode::Ex, SimTime::ms(1), SimTime::secs(1))
            .unwrap()
        {
            LockReply::Granted { at, .. } => assert!(at >= SimTime::ms(1)),
            other => panic!("{other:?}"),
        }
        // A deadline in the past times out even on a free resource: the
        // grant message cannot land before it.
        assert!(dlm
            .request_by(&mut tun, NodeId::Csd(2), "free2", LockMode::Pr, SimTime::ms(1), SimTime::ZERO)
            .is_err());
        dlm.check_invariants().unwrap();
    }

    #[test]
    fn force_release_strips_dead_node_and_regrants() {
        let (mut dlm, mut tun) = setup();
        dlm.request(&mut tun, NodeId::Csd(0), "a", LockMode::Ex, SimTime::ZERO);
        dlm.request(&mut tun, NodeId::Csd(0), "b", LockMode::Pr, SimTime::ZERO);
        assert_eq!(
            dlm.request(&mut tun, NodeId::Csd(1), "a", LockMode::Pr, SimTime::ZERO),
            LockReply::Queued
        );
        let granted = dlm.force_release(&mut tun, NodeId::Csd(0), SimTime::ms(3));
        assert_eq!(granted.len(), 1, "the stranded waiter must be granted");
        assert_eq!(granted[0].0, "a");
        assert_eq!(granted[0].1, NodeId::Csd(1));
        assert_eq!(dlm.version("a"), 1, "stripping an EX hold commits the journal");
        assert_eq!(granted[0].3, 1, "and the waiter observes the bumped version");
        assert_eq!(dlm.version("b"), 0, "stripping a PR hold does not");
        assert!(dlm.holders("b").is_empty());
        assert_eq!(dlm.stats().force_releases, 2);
        dlm.check_invariants().unwrap();
        // Idempotent: a second strip of the same node finds nothing.
        assert!(dlm.force_release(&mut tun, NodeId::Csd(0), SimTime::ms(4)).is_empty());
        assert_eq!(dlm.stats().force_releases, 2);
    }

    #[test]
    fn force_release_unblocks_waiters_behind_dead_queued_ex() {
        let (mut dlm, mut tun) = setup();
        // Live PR holder; a dead node's EX queues; a live PR queues
        // behind it (FIFO forbids overtaking the dead EX).
        dlm.request(&mut tun, NodeId::Host, "r", LockMode::Pr, SimTime::ZERO);
        dlm.request(&mut tun, NodeId::Csd(0), "r", LockMode::Ex, SimTime::ZERO);
        assert_eq!(
            dlm.request(&mut tun, NodeId::Csd(1), "r", LockMode::Pr, SimTime::ZERO),
            LockReply::Queued
        );
        let granted = dlm.force_release(&mut tun, NodeId::Csd(0), SimTime::ms(1));
        assert_eq!(granted.len(), 1, "removing the dead EX frees the compatible PR");
        assert_eq!(granted[0].1, NodeId::Csd(1));
        assert_eq!(dlm.queue_len("r"), 0);
        assert_eq!(dlm.version("r"), 0, "no EX hold was stripped, no journal bump");
        dlm.check_invariants().unwrap();
    }

    #[test]
    fn property_never_conflicting_grants() {
        prop::check("DLM never grants conflicting locks", |rng| {
            let (mut dlm, mut tun) = setup();
            let nodes = [NodeId::Host, NodeId::Csd(0), NodeId::Csd(1), NodeId::Csd(2)];
            let mut held: Vec<(NodeId, LockMode)> = Vec::new();
            for step in 0..200u64 {
                let now = SimTime::us(step * 50);
                if !held.is_empty() && rng.bool(0.4) {
                    let idx = rng.usize_below(held.len());
                    let (node, _) = held.remove(idx);
                    let granted = dlm.release(&mut tun, node, "res", now).unwrap();
                    for (n, _, _) in granted {
                        let m = dlm
                            .holders("res")
                            .iter()
                            .find(|(h, _)| *h == n)
                            .map(|(_, m)| *m)
                            .unwrap();
                        held.push((n, m));
                    }
                } else {
                    let node = nodes[rng.usize_below(nodes.len())];
                    if held.iter().any(|(n, _)| *n == node) {
                        continue; // one lock per node in this property
                    }
                    let mode = if rng.bool(0.3) { LockMode::Ex } else { LockMode::Pr };
                    if let LockReply::Granted { .. } =
                        dlm.request(&mut tun, node, "res", mode, now)
                    {
                        held.push((node, mode));
                    }
                }
                dlm.check_invariants().unwrap();
            }
        });
    }
}
