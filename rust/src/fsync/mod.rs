//! OCFS2-style distributed lock manager + metadata journal.
//!
//! Paper §III: host and ISP engines mount the same flash filesystem
//! concurrently; two OCFS2 agents synchronize metadata over the TCP/IP
//! tunnel. We model the DLM the way OCFS2 uses it for the Stannis
//! workload: per-resource locks in PR (protected read, shared) or EX
//! (exclusive) mode, a FIFO grant queue (no starvation), and a
//! monotone metadata version bumped on every EX release (the journal
//! replay the readers pick up).
//!
//! The lock master lives on the host (OCFS2's designated node); every
//! request/grant crosses the tunnel, so lock traffic has a real cost
//! that shows up in epoch timings when public-data shards are
//! rebalanced mid-run.

use std::collections::{BTreeMap, VecDeque};

use anyhow::{bail, Result};

use crate::sim::SimTime;
use crate::tunnel::{NodeId, Tunnel};

/// OCFS2 lock modes used by the Stannis data plane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LockMode {
    /// Protected read: any number of concurrent holders.
    Pr,
    /// Exclusive: sole holder.
    Ex,
}

impl LockMode {
    fn compatible(self, other: LockMode) -> bool {
        matches!((self, other), (LockMode::Pr, LockMode::Pr))
    }
}

#[derive(Debug)]
struct LockState {
    holders: Vec<(NodeId, LockMode)>,
    queue: VecDeque<(NodeId, LockMode)>,
    version: u64,
}

impl LockState {
    fn new() -> Self {
        Self { holders: Vec::new(), queue: VecDeque::new(), version: 0 }
    }

    fn can_grant(&self, mode: LockMode) -> bool {
        // FIFO fairness: nothing may overtake a queued request.
        self.queue.is_empty() && self.holders.iter().all(|(_, m)| m.compatible(mode))
    }
}

/// Result of a lock request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LockReply {
    /// Granted; holding may begin at the given time.
    Granted { at: SimTime, version: u64 },
    /// Queued behind incompatible holders/requests.
    Queued,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct DlmStats {
    pub requests: u64,
    pub grants: u64,
    pub queued: u64,
    pub releases: u64,
}

/// The lock master (host-resident).
pub struct Dlm {
    resources: BTreeMap<String, LockState>,
    stats: DlmStats,
    /// Message size of one DLM request/grant on the tunnel.
    msg_bytes: usize,
}

impl Default for Dlm {
    fn default() -> Self {
        Self::new()
    }
}

impl Dlm {
    pub fn new() -> Self {
        Self { resources: BTreeMap::new(), stats: DlmStats::default(), msg_bytes: 256 }
    }

    pub fn stats(&self) -> DlmStats {
        self.stats
    }

    /// Current metadata version of a resource (journal sequence).
    pub fn version(&self, resource: &str) -> u64 {
        self.resources.get(resource).map_or(0, |s| s.version)
    }

    pub fn holders(&self, resource: &str) -> Vec<(NodeId, LockMode)> {
        self.resources.get(resource).map_or_else(Vec::new, |s| s.holders.clone())
    }

    /// Requests currently queued behind incompatible holders.
    pub fn queue_len(&self, resource: &str) -> usize {
        self.resources.get(resource).map_or(0, |s| s.queue.len())
    }

    /// Request `mode` on `resource` from `node` at `now`, paying the
    /// tunnel round-trip when the requester is not the master (host).
    pub fn request(
        &mut self,
        tunnel: &mut Tunnel,
        node: NodeId,
        resource: &str,
        mode: LockMode,
        now: SimTime,
    ) -> LockReply {
        self.stats.requests += 1;
        let req_arrive = match node {
            NodeId::Host => now,
            csd => tunnel.send(csd, NodeId::Host, self.msg_bytes, now),
        };
        let state = self.resources.entry(resource.to_string()).or_insert_with(LockState::new);
        if state.can_grant(mode) {
            state.holders.push((node, mode));
            self.stats.grants += 1;
            let granted_at = match node {
                NodeId::Host => req_arrive,
                csd => tunnel.send(NodeId::Host, csd, self.msg_bytes, req_arrive),
            };
            LockReply::Granted { at: granted_at, version: state.version }
        } else {
            state.queue.push_back((node, mode));
            self.stats.queued += 1;
            LockReply::Queued
        }
    }

    /// Release a held lock; EX release bumps the metadata version
    /// (journal commit). Returns newly granted (node, time, version)
    /// tuples from the FIFO queue.
    pub fn release(
        &mut self,
        tunnel: &mut Tunnel,
        node: NodeId,
        resource: &str,
        now: SimTime,
    ) -> Result<Vec<(NodeId, SimTime, u64)>> {
        let state = match self.resources.get_mut(resource) {
            Some(s) => s,
            None => bail!("release of unknown resource {resource:?}"),
        };
        let idx = state
            .holders
            .iter()
            .position(|(n, _)| *n == node)
            .ok_or_else(|| anyhow::anyhow!("{node} does not hold {resource:?}"))?;
        let (_, mode) = state.holders.remove(idx);
        if mode == LockMode::Ex {
            state.version += 1; // journal commit visible to next holders
        }
        self.stats.releases += 1;

        // Notify master (if remote releaser), then grant FIFO-compatible waiters.
        let release_arrive = match node {
            NodeId::Host => now,
            csd => tunnel.send(csd, NodeId::Host, self.msg_bytes, now),
        };
        let mut granted = Vec::new();
        while let Some(&(waiter, wmode)) = state.queue.front() {
            let compat = state.holders.iter().all(|(_, m)| m.compatible(wmode));
            if !compat {
                break;
            }
            state.queue.pop_front();
            state.holders.push((waiter, wmode));
            self.stats.grants += 1;
            let at = match waiter {
                NodeId::Host => release_arrive,
                csd => tunnel.send(NodeId::Host, csd, self.msg_bytes, release_arrive),
            };
            granted.push((waiter, at, state.version));
            if wmode == LockMode::Ex {
                break; // EX admits exactly one
            }
        }
        Ok(granted)
    }

    /// Invariant: at most one EX holder, EX never coexists with PR,
    /// and no node holds the same resource twice.
    pub fn check_invariants(&self) -> Result<()> {
        for (res, state) in &self.resources {
            let ex = state.holders.iter().filter(|(_, m)| *m == LockMode::Ex).count();
            anyhow::ensure!(ex <= 1, "{res}: {ex} EX holders");
            if ex == 1 {
                anyhow::ensure!(
                    state.holders.len() == 1,
                    "{res}: EX coexists with other holders: {:?}",
                    state.holders
                );
            }
            for (i, (node, _)) in state.holders.iter().enumerate() {
                anyhow::ensure!(
                    !state.holders[i + 1..].iter().any(|(n, _)| n == node),
                    "{res}: {node} holds the resource twice: {:?}",
                    state.holders
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tunnel::TunnelConfig;
    use crate::util::prop;

    fn setup() -> (Dlm, Tunnel) {
        (Dlm::new(), Tunnel::new(4, TunnelConfig::default()))
    }

    #[test]
    fn pr_locks_share() {
        let (mut dlm, mut tun) = setup();
        let a = dlm.request(&mut tun, NodeId::Csd(0), "meta:/public", LockMode::Pr, SimTime::ZERO);
        let b = dlm.request(&mut tun, NodeId::Host, "meta:/public", LockMode::Pr, SimTime::ZERO);
        assert!(matches!(a, LockReply::Granted { .. }));
        assert!(matches!(b, LockReply::Granted { .. }));
        dlm.check_invariants().unwrap();
    }

    #[test]
    fn ex_excludes_and_queues() {
        let (mut dlm, mut tun) = setup();
        let a = dlm.request(&mut tun, NodeId::Host, "meta:/f", LockMode::Ex, SimTime::ZERO);
        assert!(matches!(a, LockReply::Granted { .. }));
        let b = dlm.request(&mut tun, NodeId::Csd(1), "meta:/f", LockMode::Pr, SimTime::ZERO);
        assert_eq!(b, LockReply::Queued);
        let granted = dlm.release(&mut tun, NodeId::Host, "meta:/f", SimTime::ms(1)).unwrap();
        assert_eq!(granted.len(), 1);
        assert_eq!(granted[0].0, NodeId::Csd(1));
        // EX release bumped the journal version the waiter observes.
        assert_eq!(granted[0].2, 1);
        dlm.check_invariants().unwrap();
    }

    #[test]
    fn fifo_no_overtaking() {
        let (mut dlm, mut tun) = setup();
        dlm.request(&mut tun, NodeId::Host, "r", LockMode::Ex, SimTime::ZERO);
        // EX waiter queues first, then a PR request arrives.
        dlm.request(&mut tun, NodeId::Csd(0), "r", LockMode::Ex, SimTime::ZERO);
        let pr = dlm.request(&mut tun, NodeId::Csd(1), "r", LockMode::Pr, SimTime::ZERO);
        assert_eq!(pr, LockReply::Queued, "PR must not overtake queued EX");
        assert_eq!(dlm.queue_len("r"), 2);
        assert_eq!(dlm.queue_len("unknown"), 0);
        let g1 = dlm.release(&mut tun, NodeId::Host, "r", SimTime::ms(1)).unwrap();
        assert_eq!(g1[0].0, NodeId::Csd(0), "FIFO: EX waiter first");
        assert_eq!(g1.len(), 1);
        let g2 = dlm.release(&mut tun, NodeId::Csd(0), "r", SimTime::ms(2)).unwrap();
        assert_eq!(g2[0].0, NodeId::Csd(1));
    }

    #[test]
    fn remote_requests_pay_tunnel_latency() {
        let (mut dlm, mut tun) = setup();
        match dlm.request(&mut tun, NodeId::Csd(2), "r", LockMode::Pr, SimTime::ZERO) {
            LockReply::Granted { at, .. } => assert!(at > SimTime::ZERO),
            other => panic!("{other:?}"),
        }
        match dlm.request(&mut tun, NodeId::Host, "r2", LockMode::Pr, SimTime::ZERO) {
            LockReply::Granted { at, .. } => assert_eq!(at, SimTime::ZERO),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn release_errors() {
        let (mut dlm, mut tun) = setup();
        assert!(dlm.release(&mut tun, NodeId::Host, "never", SimTime::ZERO).is_err());
        dlm.request(&mut tun, NodeId::Host, "r", LockMode::Pr, SimTime::ZERO);
        assert!(dlm.release(&mut tun, NodeId::Csd(0), "r", SimTime::ZERO).is_err());
    }

    #[test]
    fn property_never_conflicting_grants() {
        prop::check("DLM never grants conflicting locks", |rng| {
            let (mut dlm, mut tun) = setup();
            let nodes = [NodeId::Host, NodeId::Csd(0), NodeId::Csd(1), NodeId::Csd(2)];
            let mut held: Vec<(NodeId, LockMode)> = Vec::new();
            for step in 0..200u64 {
                let now = SimTime::us(step * 50);
                if !held.is_empty() && rng.bool(0.4) {
                    let idx = rng.usize_below(held.len());
                    let (node, _) = held.remove(idx);
                    let granted = dlm.release(&mut tun, node, "res", now).unwrap();
                    for (n, _, _) in granted {
                        let m = dlm
                            .holders("res")
                            .iter()
                            .find(|(h, _)| *h == n)
                            .map(|(_, m)| *m)
                            .unwrap();
                        held.push((n, m));
                    }
                } else {
                    let node = nodes[rng.usize_below(nodes.len())];
                    if held.iter().any(|(n, _)| *n == node) {
                        continue; // one lock per node in this property
                    }
                    let mode = if rng.bool(0.3) { LockMode::Ex } else { LockMode::Pr };
                    if let LockReply::Granted { .. } =
                        dlm.request(&mut tun, node, "res", mode, now)
                    {
                        held.push((node, mode));
                    }
                }
                dlm.check_invariants().unwrap();
            }
        });
    }
}
