//! PJRT execution engine: load HLO-text artifacts, compile once, run many.
//!
//! Follows the /opt/xla-example/load_hlo pattern: HLO *text* (never a
//! serialized proto — xla_extension 0.5.1 rejects jax≥0.5's 64-bit ids)
//! → `HloModuleProto::from_text_file` → `XlaComputation` → compile on a
//! shared `PjRtClient::cpu()` → `execute` with `Literal` args.

#[allow(clippy::disallowed_types)]
// lint: allow(hash-iter) — compile cache is keyed lookup only, never iterated
use std::collections::HashMap;
use std::sync::Mutex;

use crate::model::{ParamStore, Tensor};
use crate::xla;
use crate::Result;

use super::manifest::{Manifest, NetworkManifest};

/// Output of one training step execution.
#[derive(Debug, Clone)]
pub struct StepOutput {
    pub loss: f32,
    pub grads: ParamStore,
}

/// Output of one eval step execution.
#[derive(Debug, Clone)]
pub struct EvalOutput {
    pub loss: f32,
    pub correct: i32,
}

/// A compiled artifact cache keyed by artifact-relative path.
///
/// One engine (and one PJRT client) is shared by every simulated worker:
/// the paper's workers are physically distinct A53s/Xeon, but numerics
/// are identical, so all replicas execute on one CPU client while the
/// DES accounts each worker's *modeled* time separately.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    #[allow(clippy::disallowed_types)]
    // lint: allow(hash-iter) — keyed lookup only, never iterated
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create a CPU engine over an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        #[allow(clippy::disallowed_types)]
        // lint: allow(hash-iter) — keyed lookup only, never iterated
        let cache = Mutex::new(HashMap::new());
        Ok(Self { client, manifest, cache })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn network(&self, name: &str) -> Result<&NetworkManifest> {
        self.manifest.network(name)
    }

    /// Load+compile an artifact (memoized).
    fn executable(&self, rel: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(rel) {
            return Ok(exe.clone());
        }
        let path = self.manifest.artifact_path(rel);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        self.cache
            .lock()
            .unwrap()
            .insert(rel.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile every artifact a training run will need.
    pub fn warmup(&self, network: &str, batch_sizes: &[usize]) -> Result<()> {
        let net = self.network(network)?;
        self.executable(&net.init.clone())?;
        for &bs in batch_sizes {
            let rel = net
                .train_artifact(bs)
                .ok_or_else(|| anyhow::anyhow!("{network}: no train artifact for bs={bs}"))?
                .to_string();
            self.executable(&rel)?;
        }
        Ok(())
    }

    /// Run the init artifact: seed -> fresh parameter replica.
    pub fn init_params(&self, network: &str, seed: i32) -> Result<ParamStore> {
        let net = self.network(network)?;
        let exe = self.executable(&net.init.clone())?;
        let seed_lit = xla::Literal::scalar(seed);
        let result = exe.execute::<xla::Literal>(&[seed_lit])?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        anyhow::ensure!(
            parts.len() == net.params.len(),
            "init returned {} tensors, manifest has {}",
            parts.len(),
            net.params.len()
        );
        let tensors = parts
            .iter()
            .map(Tensor::from_literal)
            .collect::<Result<Vec<_>>>()?;
        let store = ParamStore::new(tensors);
        store.check_specs(&net.params)?;
        Ok(store)
    }

    /// Execute one training step: (params, batch) -> (loss, grads).
    pub fn train_step(
        &self,
        network: &str,
        batch_size: usize,
        params: &ParamStore,
        images: &Tensor,
        labels: &[i32],
    ) -> Result<StepOutput> {
        let net = self.network(network)?;
        let rel = net
            .train_artifact(batch_size)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "{network}: no train artifact for bs={batch_size} (have {:?})",
                    net.train_batch_sizes
                )
            })?
            .to_string();
        let exe = self.executable(&rel)?;

        let hw = net.input_hw;
        anyhow::ensure!(
            images.shape() == [batch_size, hw, hw, 3],
            "image batch shape {:?} != [{batch_size}, {hw}, {hw}, 3]",
            images.shape()
        );
        anyhow::ensure!(labels.len() == batch_size, "label count mismatch");

        let mut args: Vec<xla::Literal> = Vec::with_capacity(params.len() + 2);
        for t in params.tensors() {
            args.push(t.to_literal()?);
        }
        args.push(images.to_literal()?);
        args.push(xla::Literal::vec1(labels));

        let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let mut parts = result.to_tuple()?;
        anyhow::ensure!(
            parts.len() == net.params.len() + 1,
            "train_step returned {} outputs, expected {}",
            parts.len(),
            net.params.len() + 1
        );
        let loss = parts.remove(0).to_vec::<f32>()?[0];
        let tensors = parts
            .iter()
            .map(Tensor::from_literal)
            .collect::<Result<Vec<_>>>()?;
        Ok(StepOutput { loss, grads: ParamStore::new(tensors) })
    }

    /// Execute one eval step: (params, batch) -> (loss, #correct).
    pub fn eval_step(
        &self,
        network: &str,
        params: &ParamStore,
        images: &Tensor,
        labels: &[i32],
    ) -> Result<EvalOutput> {
        let net = self.network(network)?;
        let bs = net.eval_batch_size;
        let rel = net
            .eval_artifact(bs)
            .ok_or_else(|| anyhow::anyhow!("{network}: no eval artifact"))?
            .to_string();
        let exe = self.executable(&rel)?;
        anyhow::ensure!(labels.len() == bs, "eval expects batch of {bs}");

        let mut args: Vec<xla::Literal> = Vec::with_capacity(params.len() + 2);
        for t in params.tensors() {
            args.push(t.to_literal()?);
        }
        args.push(images.to_literal()?);
        args.push(xla::Literal::vec1(labels));

        let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        anyhow::ensure!(parts.len() == 2, "eval_step returned {} outputs", parts.len());
        let loss = parts[0].to_vec::<f32>()?[0];
        let correct = parts[1].to_vec::<i32>()?[0];
        Ok(EvalOutput { loss, correct })
    }
}
