//! `artifacts/manifest.json` — the AOT interchange contract with L2.
//!
//! Parsed with the in-tree JSON substrate (`util::json`); field layout
//! mirrors what `python/compile/aot.py` emits.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::util::Json;
use crate::Result;

/// One parameter tensor's spec (order inside `NetworkManifest::params`
/// is the PJRT argument order).
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
    pub init: String,
}

impl ParamSpec {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            name: j.field("name")?.as_str()?.to_string(),
            shape: j
                .field("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?,
            dtype: j.field("dtype")?.as_str()?.to_string(),
            init: j
                .get("init")
                .map(|v| v.as_str().map(str::to_string))
                .transpose()?
                .unwrap_or_default(),
        })
    }
}

/// Everything AOT-compiled for one network.
#[derive(Debug, Clone)]
pub struct NetworkManifest {
    pub params: Vec<ParamSpec>,
    pub param_count: usize,
    pub macs_per_image: u64,
    pub flops_per_image: u64,
    pub input_hw: usize,
    pub num_classes: usize,
    pub train_batch_sizes: Vec<usize>,
    pub eval_batch_size: usize,
    pub init: String,
    /// batch size -> artifact relative path
    pub train: BTreeMap<usize, String>,
    pub eval: BTreeMap<usize, String>,
}

fn bs_map(j: &Json) -> Result<BTreeMap<usize, String>> {
    let mut out = BTreeMap::new();
    for (k, v) in j.as_obj()? {
        let bs: usize = k.parse().with_context(|| format!("batch-size key {k:?}"))?;
        out.insert(bs, v.as_str()?.to_string());
    }
    Ok(out)
}

impl NetworkManifest {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            params: j
                .field("params")?
                .as_arr()?
                .iter()
                .map(ParamSpec::from_json)
                .collect::<Result<_>>()?,
            param_count: j.field("param_count")?.as_usize()?,
            macs_per_image: j.field("macs_per_image")?.as_u64()?,
            flops_per_image: j.field("flops_per_image")?.as_u64()?,
            input_hw: j.field("input_hw")?.as_usize()?,
            num_classes: j.field("num_classes")?.as_usize()?,
            train_batch_sizes: j
                .field("train_batch_sizes")?
                .as_arr()?
                .iter()
                .map(|b| b.as_usize())
                .collect::<Result<_>>()?,
            eval_batch_size: j.field("eval_batch_size")?.as_usize()?,
            init: j.field("init")?.as_str()?.to_string(),
            train: bs_map(j.field("train")?)?,
            eval: bs_map(j.field("eval")?)?,
        })
    }

    pub fn train_artifact(&self, batch_size: usize) -> Option<&str> {
        self.train.get(&batch_size).map(String::as_str)
    }

    pub fn eval_artifact(&self, batch_size: usize) -> Option<&str> {
        self.eval.get(&batch_size).map(String::as_str)
    }

    /// Total scalar parameter count (recomputed from specs).
    pub fn num_scalars(&self) -> usize {
        self.params.iter().map(ParamSpec::num_elements).sum()
    }
}

/// Parsed manifest + its root directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u64,
    pub primary: String,
    pub networks: BTreeMap<String, NetworkManifest>,
    pub root: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json` and sanity-check the contents.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!("reading {}: {e}; run `make artifacts` first", path.display())
        })?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;

        let mut networks = BTreeMap::new();
        for (name, nj) in j.field("networks")?.as_obj()? {
            let net = NetworkManifest::from_json(nj)
                .with_context(|| format!("network {name:?}"))?;
            networks.insert(name.clone(), net);
        }
        let m = Manifest {
            version: j.field("version")?.as_u64()?,
            primary: j.field("primary")?.as_str()?.to_string(),
            networks,
            root: dir.to_path_buf(),
        };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.version == 1, "unsupported manifest version {}", self.version);
        anyhow::ensure!(
            self.networks.contains_key(&self.primary),
            "primary network {:?} missing from manifest",
            self.primary
        );
        for (name, net) in &self.networks {
            anyhow::ensure!(!net.params.is_empty(), "{name}: empty param list");
            anyhow::ensure!(
                net.param_count == net.num_scalars(),
                "{name}: param_count {} != sum of spec sizes {}",
                net.param_count,
                net.num_scalars()
            );
            for bs in &net.train_batch_sizes {
                anyhow::ensure!(
                    net.train_artifact(*bs).is_some(),
                    "{name}: train batch size {bs} has no artifact"
                );
            }
        }
        Ok(())
    }

    pub fn network(&self, name: &str) -> Result<&NetworkManifest> {
        self.networks.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "network {name:?} not in manifest (have {:?})",
                self.networks.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Absolute path of an artifact-relative path.
    pub fn artifact_path(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal_json() -> String {
        r#"{
          "version": 1,
          "primary": "net",
          "networks": {
            "net": {
              "params": [{"name": "w", "shape": [2, 3], "dtype": "f32", "init": "he"}],
              "param_count": 6,
              "macs_per_image": 10,
              "flops_per_image": 20,
              "input_hw": 8,
              "num_classes": 4,
              "train_batch_sizes": [2],
              "eval_batch_size": 2,
              "init": "net/init.hlo.txt",
              "train": {"2": "net/train_bs2.hlo.txt"},
              "eval": {"2": "net/eval_bs2.hlo.txt"}
            }
          }
        }"#
        .to_string()
    }

    #[test]
    fn parse_and_lookup() {
        let dir = std::env::temp_dir().join(format!("stannis_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), minimal_json()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let net = m.network("net").unwrap();
        assert_eq!(net.train_artifact(2), Some("net/train_bs2.hlo.txt"));
        assert_eq!(net.train_artifact(4), None);
        assert_eq!(net.num_scalars(), 6);
        assert!(m.network("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_param_count_rejected() {
        let dir = std::env::temp_dir().join(format!("stannis_manifest_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            minimal_json().replace("\"param_count\": 6", "\"param_count\": 7"),
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_artifact_for_declared_bs_rejected() {
        let dir = std::env::temp_dir().join(format!("stannis_manifest_bs_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            minimal_json().replace("\"train_batch_sizes\": [2]", "\"train_batch_sizes\": [2, 4]"),
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
