//! PJRT runtime: the only place the crate touches XLA.
//!
//! Loads the AOT artifacts produced by `python/compile/aot.py` (HLO
//! text + `manifest.json`) and exposes typed `init` / `train_step` /
//! `eval_step` execution to the rest of the coordinator.

mod engine;
mod manifest;

pub use engine::{Engine, EvalOutput, StepOutput};
pub use manifest::{Manifest, NetworkManifest, ParamSpec};

use std::path::PathBuf;

/// Resolve the artifacts directory: `$STANNIS_ARTIFACTS` or
/// `<repo>/artifacts` relative to the current dir (walking up, so tests
/// and benches work from any workspace subdirectory).
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("STANNIS_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}
