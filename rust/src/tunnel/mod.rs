//! TCP/IP-over-PCIe tunnel: the cluster's only interconnect.
//!
//! Paper §III: three cooperating processes (host-side, FE-side,
//! ISP-side) packetize TCP/IP inside PCIe transactions, giving every
//! CSD and the host a network. Two properties matter for Stannis:
//!
//! 1. **Topology** — each CSD talks to the host over its own PCIe
//!    link; CSD↔CSD traffic relays through the host (two hops), which
//!    is exactly what a ring allreduce across 24 CSDs stresses.
//! 2. **Software throughput** — packetization runs on the FE M7 / host
//!    CPU, so the *effective* tunnel bandwidth is far below raw PCIe;
//!    this software ceiling (default ~80 MB/s per endpoint, calibrated
//!    against Fig. 6/7's observed sync slowdown) is what makes gradient sync
//!    expensive for big models (Fig. 7's InceptionV3 collapse).

use crate::sim::{SimTime, Timeline};

/// A participant in the tunnel network.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum NodeId {
    Host,
    Csd(usize),
}

impl std::fmt::Display for NodeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NodeId::Host => write!(f, "host"),
            NodeId::Csd(i) => write!(f, "csd{i}"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct TunnelConfig {
    /// Raw PCIe wire bandwidth per CSD link (bytes/s).
    pub pcie_bw: f64,
    /// Software packetization throughput per endpoint (bytes/s) — the
    /// FE M7 on a CSD, one core's worth on the host.
    pub sw_bw_csd: f64,
    /// Host-side tunnel processing is DMA/memcpy-bound (the paper's
    /// host process rides PCIe BAR mappings), so it is far faster than
    /// the embedded FE stack.
    pub sw_bw_host: f64,
    /// Tunnel MTU (payload bytes per PCIe-encapsulated packet).
    pub mtu: usize,
    /// Fixed per-packet processing overhead at each endpoint.
    pub per_packet: SimTime,
    /// Base propagation latency per hop.
    pub hop_latency: SimTime,
}

impl Default for TunnelConfig {
    fn default() -> Self {
        Self {
            pcie_bw: 3.2e9,
            sw_bw_csd: 80.0e6,
            sw_bw_host: 6.0e9,
            mtu: 64 * 1024,
            per_packet: SimTime::us(20),
            hop_latency: SimTime::us(15),
        }
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct TunnelStats {
    pub messages: u64,
    pub bytes: u64,
    pub relayed: u64,
}

/// The tunnel fabric for one host + N CSDs.
#[derive(Debug)]
pub struct Tunnel {
    cfg: TunnelConfig,
    /// Per-CSD PCIe wire occupancy.
    links: Vec<Timeline>,
    /// Per-CSD FE packetization.
    csd_sw: Vec<Timeline>,
    /// Host-side packetization (shared by all flows).
    host_sw: Timeline,
    stats: TunnelStats,
}

impl Tunnel {
    pub fn new(num_csds: usize, cfg: TunnelConfig) -> Self {
        Self {
            links: (0..num_csds).map(|_| Timeline::new()).collect(),
            csd_sw: (0..num_csds).map(|_| Timeline::new()).collect(),
            host_sw: Timeline::new(),
            cfg,
            stats: TunnelStats::default(),
        }
    }

    pub fn num_csds(&self) -> usize {
        self.links.len()
    }

    pub fn config(&self) -> &TunnelConfig {
        &self.cfg
    }

    /// Record traffic accounted by an aggregate (fluid) model rather
    /// than per-message `send` calls — keeps the stats ledger whole.
    pub fn note_aggregate(&mut self, messages: u64, bytes: u64) {
        self.stats.messages += messages;
        self.stats.bytes += bytes;
    }

    pub fn stats(&self) -> TunnelStats {
        self.stats
    }

    /// Total wire bytes that crossed PCIe (relays count twice).
    pub fn link_busy_total(&self) -> SimTime {
        self.links.iter().map(Timeline::busy_time).sum()
    }

    fn packets(&self, bytes: usize) -> u64 {
        (bytes.div_ceil(self.cfg.mtu)) as u64
    }

    fn sw_time(&self, bytes: usize, host: bool) -> SimTime {
        let bw = if host { self.cfg.sw_bw_host } else { self.cfg.sw_bw_csd };
        SimTime::from_secs_f64(bytes as f64 / bw) + self.cfg.per_packet * self.packets(bytes)
    }

    fn wire_time(&self, bytes: usize) -> SimTime {
        SimTime::from_secs_f64(bytes as f64 / self.cfg.pcie_bw)
    }

    /// One hop host<->csd over the CSD's PCIe link.
    fn hop(&mut self, csd: usize, bytes: usize, ready: SimTime, to_host: bool) -> SimTime {
        let sw_csd = self.sw_time(bytes, false);
        let sw_host = self.sw_time(bytes, true);
        let wire = self.wire_time(bytes);
        // Source-side packetization …
        let (_, src_done) = if to_host {
            self.csd_sw[csd].schedule(ready, sw_csd)
        } else {
            self.host_sw.schedule(ready, sw_host)
        };
        // … wire …
        let (_, wire_done) = self.links[csd].schedule(src_done, wire);
        let arrived = wire_done + self.cfg.hop_latency;
        // … destination-side depacketization.
        let (_, dst_done) = if to_host {
            self.host_sw.schedule(arrived, sw_host)
        } else {
            self.csd_sw[csd].schedule(arrived, sw_csd)
        };
        dst_done
    }

    /// Send `bytes` from `from` to `to`; returns delivery time.
    pub fn send(&mut self, from: NodeId, to: NodeId, bytes: usize, now: SimTime) -> SimTime {
        assert_ne!(from, to, "self-send");
        self.stats.messages += 1;
        self.stats.bytes += bytes as u64;
        match (from, to) {
            (NodeId::Csd(a), NodeId::Host) => self.hop(a, bytes, now, true),
            (NodeId::Host, NodeId::Csd(b)) => self.hop(b, bytes, now, false),
            (NodeId::Csd(a), NodeId::Csd(b)) => {
                // Relay through the host switch: two hops.
                self.stats.relayed += 1;
                let at_host = self.hop(a, bytes, now, true);
                self.hop(b, bytes, at_host, false)
            }
            (NodeId::Host, NodeId::Host) => unreachable!(),
        }
    }

    /// Effective point-to-point goodput measured over one message.
    pub fn effective_bw(&mut self, from: NodeId, to: NodeId, bytes: usize) -> f64 {
        let t0 = self.links.iter().map(Timeline::next_free).max().unwrap_or(SimTime::ZERO);
        let done = self.send(from, to, bytes, t0);
        bytes as f64 / (done - t0).as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csd_to_csd_relays_through_host() {
        let mut t = Tunnel::new(4, TunnelConfig::default());
        let direct = t.send(NodeId::Csd(0), NodeId::Host, 1 << 20, SimTime::ZERO);
        let mut t2 = Tunnel::new(4, TunnelConfig::default());
        let relayed = t2.send(NodeId::Csd(0), NodeId::Csd(1), 1 << 20, SimTime::ZERO);
        assert!(relayed > direct, "relay must cost more than one hop");
        assert_eq!(t2.stats().relayed, 1);
    }

    #[test]
    fn sw_packetization_dominates_wire() {
        // 1 MiB at 80 MB/s sw vs 3.2 GB/s wire: the FE is the choke point.
        let mut t = Tunnel::new(1, TunnelConfig::default());
        let bw = t.effective_bw(NodeId::Csd(0), NodeId::Host, 1 << 20);
        assert!(bw < 80.0e6, "effective bw {bw} must sit below the sw ceiling");
        assert!(bw > 20.0e6, "but not absurdly below it: {bw}");
    }

    #[test]
    fn concurrent_flows_share_host_sw() {
        let mut t = Tunnel::new(2, TunnelConfig::default());
        let a = t.send(NodeId::Csd(0), NodeId::Host, 1 << 20, SimTime::ZERO);
        let b = t.send(NodeId::Csd(1), NodeId::Host, 1 << 20, SimTime::ZERO);
        // Both used distinct PCIe links but the same host de-packetizer:
        // the second flow finishes later.
        assert!(b > a);
    }

    #[test]
    fn per_link_isolation() {
        let mut t = Tunnel::new(2, TunnelConfig::default());
        t.send(NodeId::Host, NodeId::Csd(0), 8 << 20, SimTime::ZERO);
        // Wire time on csd1's link is untouched.
        assert_eq!(t.links[1].busy_time(), SimTime::ZERO);
    }

    #[test]
    #[should_panic(expected = "self-send")]
    fn self_send_panics() {
        let mut t = Tunnel::new(1, TunnelConfig::default());
        t.send(NodeId::Host, NodeId::Host, 10, SimTime::ZERO);
    }
}
