//! TCP/IP-over-PCIe tunnel: the cluster's only interconnect.
//!
//! Paper §III: three cooperating processes (host-side, FE-side,
//! ISP-side) packetize TCP/IP inside PCIe transactions, giving every
//! CSD and the host a network. Two properties matter for Stannis:
//!
//! 1. **Topology** — each CSD talks to the host over its own PCIe
//!    link; CSD↔CSD traffic relays through the host (two hops), which
//!    is exactly what a ring allreduce across 24 CSDs stresses.
//! 2. **Software throughput** — packetization runs on the FE M7 / host
//!    CPU, so the *effective* tunnel bandwidth is far below raw PCIe;
//!    this software ceiling (default ~80 MB/s per endpoint, calibrated
//!    against Fig. 6/7's observed sync slowdown) is what makes gradient sync
//!    expensive for big models (Fig. 7's InceptionV3 collapse).

use std::collections::VecDeque;

use crate::config::LinkFaultSpec;
use crate::sim::{SimTime, Timeline};
use crate::util::Rng;

/// A participant in the tunnel network.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum NodeId {
    Host,
    Csd(usize),
}

impl std::fmt::Display for NodeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NodeId::Host => write!(f, "host"),
            NodeId::Csd(i) => write!(f, "csd{i}"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct TunnelConfig {
    /// Raw PCIe wire bandwidth per CSD link (bytes/s).
    pub pcie_bw: f64,
    /// Software packetization throughput per endpoint (bytes/s) — the
    /// FE M7 on a CSD, one core's worth on the host.
    pub sw_bw_csd: f64,
    /// Host-side tunnel processing is DMA/memcpy-bound (the paper's
    /// host process rides PCIe BAR mappings), so it is far faster than
    /// the embedded FE stack.
    pub sw_bw_host: f64,
    /// Tunnel MTU (payload bytes per PCIe-encapsulated packet).
    pub mtu: usize,
    /// Fixed per-packet processing overhead at each endpoint.
    pub per_packet: SimTime,
    /// Base propagation latency per hop.
    pub hop_latency: SimTime,
}

impl Default for TunnelConfig {
    fn default() -> Self {
        Self {
            pcie_bw: 3.2e9,
            sw_bw_csd: 80.0e6,
            sw_bw_host: 6.0e9,
            mtu: 64 * 1024,
            per_packet: SimTime::us(20),
            hop_latency: SimTime::us(15),
        }
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct TunnelStats {
    pub messages: u64,
    pub bytes: u64,
    pub relayed: u64,
    /// Hops re-attempted by the link-fault retry ladder (0 unless
    /// link faults are armed; DESIGN.md §Crash-Recovery).
    pub retries: u64,
}

/// Armed transient-failure state: one private RNG per link, so the
/// draw sequence on link `i` is a pure function of (spec seed, i,
/// number of hops link `i` has carried) — deterministic regardless of
/// what the other links do.
#[derive(Debug)]
struct LinkFaultState {
    spec: LinkFaultSpec,
    rngs: Vec<Rng>,
    /// Links whose ladder ran out of rungs, in escalation order,
    /// awaiting the coordinator's poll.
    exhausted: VecDeque<usize>,
}

/// The tunnel fabric for one host + N CSDs.
#[derive(Debug)]
pub struct Tunnel {
    cfg: TunnelConfig,
    /// Per-CSD PCIe wire occupancy.
    links: Vec<Timeline>,
    /// Per-CSD FE packetization.
    csd_sw: Vec<Timeline>,
    /// Host-side packetization (shared by all flows).
    host_sw: Timeline,
    stats: TunnelStats,
    /// `None` unless [`Tunnel::arm_link_faults`] armed a nonzero
    /// failure probability — the off path never touches this.
    faults: Option<LinkFaultState>,
}

impl Tunnel {
    pub fn new(num_csds: usize, cfg: TunnelConfig) -> Self {
        Self {
            links: (0..num_csds).map(|_| Timeline::new()).collect(),
            csd_sw: (0..num_csds).map(|_| Timeline::new()).collect(),
            host_sw: Timeline::new(),
            cfg,
            stats: TunnelStats::default(),
            faults: None,
        }
    }

    /// Arm seeded transient link failures. A spec with
    /// `fail_prob == 0.0` disarms: no RNG is seeded and every send is
    /// bit-identical to the fault-free tunnel.
    pub fn arm_link_faults(&mut self, spec: LinkFaultSpec) {
        if !spec.armed() {
            self.faults = None;
            return;
        }
        let mut root = Rng::new(spec.seed ^ 0x7E57_11BB);
        let rngs = (0..self.links.len()).map(|i| root.fork(i as u64)).collect();
        self.faults = Some(LinkFaultState { spec, rngs, exhausted: VecDeque::new() });
    }

    pub fn link_faults_armed(&self) -> bool {
        self.faults.is_some()
    }

    /// Next link whose retry ladder was exhausted since the last poll
    /// (escalation order). The coordinator drains this after every
    /// pumped event and turns each entry into a bay crash.
    pub fn take_exhausted_link(&mut self) -> Option<usize> {
        self.faults.as_mut().and_then(|f| f.exhausted.pop_front())
    }

    pub fn num_csds(&self) -> usize {
        self.links.len()
    }

    pub fn config(&self) -> &TunnelConfig {
        &self.cfg
    }

    /// Record traffic accounted by an aggregate (fluid) model rather
    /// than per-message `send` calls — keeps the stats ledger whole.
    pub fn note_aggregate(&mut self, messages: u64, bytes: u64) {
        self.stats.messages += messages;
        self.stats.bytes += bytes;
    }

    pub fn stats(&self) -> TunnelStats {
        self.stats
    }

    /// Total wire bytes that crossed PCIe (relays count twice).
    pub fn link_busy_total(&self) -> SimTime {
        self.links.iter().map(Timeline::busy_time).sum()
    }

    fn packets(&self, bytes: usize) -> u64 {
        (bytes.div_ceil(self.cfg.mtu)) as u64
    }

    fn sw_time(&self, bytes: usize, host: bool) -> SimTime {
        let bw = if host { self.cfg.sw_bw_host } else { self.cfg.sw_bw_csd };
        SimTime::from_secs_f64(bytes as f64 / bw) + self.cfg.per_packet * self.packets(bytes)
    }

    fn wire_time(&self, bytes: usize) -> SimTime {
        SimTime::from_secs_f64(bytes as f64 / self.cfg.pcie_bw)
    }

    /// Deterministic bounded retry ladder (the PR 7 ECC idiom applied
    /// to the wire): each failed draw on the link's private RNG delays
    /// the hop by `backoff_base_us * 2^rung` before the next attempt;
    /// running out of rungs queues the link for crash escalation and
    /// lets the final attempt through so the ladder itself never
    /// deadlocks the simulation.
    fn retry_delay(&mut self, csd: usize, ready: SimTime) -> SimTime {
        let Some(f) = self.faults.as_mut() else { return ready };
        let mut at = ready;
        let mut rung = 0u32;
        let mut retries = 0u64;
        while f.rngs[csd].f64() < f.spec.fail_prob {
            if rung >= f.spec.max_retries {
                if !f.exhausted.contains(&csd) {
                    f.exhausted.push_back(csd);
                }
                break;
            }
            let backoff_us = f.spec.backoff_base_us * (1u64 << rung.min(20)) as f64;
            at = at + SimTime::from_secs_f64(backoff_us * 1e-6);
            retries += 1;
            rung += 1;
        }
        self.stats.retries += retries;
        at
    }

    /// One hop host<->csd over the CSD's PCIe link.
    fn hop(&mut self, csd: usize, bytes: usize, ready: SimTime, to_host: bool) -> SimTime {
        let ready = self.retry_delay(csd, ready);
        let sw_csd = self.sw_time(bytes, false);
        let sw_host = self.sw_time(bytes, true);
        let wire = self.wire_time(bytes);
        // Source-side packetization …
        let (_, src_done) = if to_host {
            self.csd_sw[csd].schedule(ready, sw_csd)
        } else {
            self.host_sw.schedule(ready, sw_host)
        };
        // … wire …
        let (_, wire_done) = self.links[csd].schedule(src_done, wire);
        let arrived = wire_done + self.cfg.hop_latency;
        // … destination-side depacketization.
        let (_, dst_done) = if to_host {
            self.host_sw.schedule(arrived, sw_host)
        } else {
            self.csd_sw[csd].schedule(arrived, sw_csd)
        };
        dst_done
    }

    /// Send `bytes` from `from` to `to`; returns delivery time.
    pub fn send(&mut self, from: NodeId, to: NodeId, bytes: usize, now: SimTime) -> SimTime {
        assert_ne!(from, to, "self-send");
        self.stats.messages += 1;
        self.stats.bytes += bytes as u64;
        match (from, to) {
            (NodeId::Csd(a), NodeId::Host) => self.hop(a, bytes, now, true),
            (NodeId::Host, NodeId::Csd(b)) => self.hop(b, bytes, now, false),
            (NodeId::Csd(a), NodeId::Csd(b)) => {
                // Relay through the host switch: two hops.
                self.stats.relayed += 1;
                let at_host = self.hop(a, bytes, now, true);
                self.hop(b, bytes, at_host, false)
            }
            (NodeId::Host, NodeId::Host) => unreachable!(),
        }
    }

    /// Effective point-to-point goodput of one uncontended message —
    /// a pure computation: nothing is scheduled on the timelines and
    /// no stats are booked.
    pub fn effective_bw(&self, from: NodeId, to: NodeId, bytes: usize) -> f64 {
        assert_ne!(from, to, "self-send");
        let per_hop = self.sw_time(bytes, false)
            + self.sw_time(bytes, true)
            + self.wire_time(bytes)
            + self.cfg.hop_latency;
        let hops: u64 = match (from, to) {
            (NodeId::Csd(_), NodeId::Csd(_)) => 2, // relay through the host
            _ => 1,
        };
        bytes as f64 / (per_hop * hops).as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csd_to_csd_relays_through_host() {
        let mut t = Tunnel::new(4, TunnelConfig::default());
        let direct = t.send(NodeId::Csd(0), NodeId::Host, 1 << 20, SimTime::ZERO);
        let mut t2 = Tunnel::new(4, TunnelConfig::default());
        let relayed = t2.send(NodeId::Csd(0), NodeId::Csd(1), 1 << 20, SimTime::ZERO);
        assert!(relayed > direct, "relay must cost more than one hop");
        assert_eq!(t2.stats().relayed, 1);
    }

    #[test]
    fn sw_packetization_dominates_wire() {
        // 1 MiB at 80 MB/s sw vs 3.2 GB/s wire: the FE is the choke point.
        let t = Tunnel::new(1, TunnelConfig::default());
        let bw = t.effective_bw(NodeId::Csd(0), NodeId::Host, 1 << 20);
        assert!(bw < 80.0e6, "effective bw {bw} must sit below the sw ceiling");
        assert!(bw > 20.0e6, "but not absurdly below it: {bw}");
        // Pure computation: probing leaves no trace on the fabric.
        assert_eq!(t.stats().messages, 0);
        assert_eq!(t.link_busy_total(), SimTime::ZERO);
        // The host relay costs a second hop.
        let t2 = Tunnel::new(2, TunnelConfig::default());
        let relayed = t2.effective_bw(NodeId::Csd(0), NodeId::Csd(1), 1 << 20);
        assert!((relayed - bw / 2.0).abs() / bw < 1e-12);
    }

    #[test]
    fn concurrent_flows_share_host_sw() {
        let mut t = Tunnel::new(2, TunnelConfig::default());
        let a = t.send(NodeId::Csd(0), NodeId::Host, 1 << 20, SimTime::ZERO);
        let b = t.send(NodeId::Csd(1), NodeId::Host, 1 << 20, SimTime::ZERO);
        // Both used distinct PCIe links but the same host de-packetizer:
        // the second flow finishes later.
        assert!(b > a);
    }

    #[test]
    fn per_link_isolation() {
        let mut t = Tunnel::new(2, TunnelConfig::default());
        t.send(NodeId::Host, NodeId::Csd(0), 8 << 20, SimTime::ZERO);
        // Wire time on csd1's link is untouched.
        assert_eq!(t.links[1].busy_time(), SimTime::ZERO);
    }

    #[test]
    #[should_panic(expected = "self-send")]
    fn self_send_panics() {
        let mut t = Tunnel::new(1, TunnelConfig::default());
        t.send(NodeId::Host, NodeId::Host, 10, SimTime::ZERO);
    }

    #[test]
    fn unarmed_and_zero_prob_ladders_are_bit_identical_to_faultless() {
        let mut base = Tunnel::new(2, TunnelConfig::default());
        let mut off = Tunnel::new(2, TunnelConfig::default());
        off.arm_link_faults(LinkFaultSpec { fail_prob: 0.0, ..Default::default() });
        assert!(!off.link_faults_armed(), "fail_prob 0 must disarm entirely");
        for k in 0..8usize {
            let a = base.send(NodeId::Csd(k % 2), NodeId::Host, 1 << 16, SimTime::ZERO);
            let b = off.send(NodeId::Csd(k % 2), NodeId::Host, 1 << 16, SimTime::ZERO);
            assert_eq!(a, b);
        }
        assert_eq!(base.stats().retries, 0);
        assert_eq!(off.stats().retries, 0);
    }

    #[test]
    fn retry_ladder_is_deterministic_and_backs_off() {
        let spec = LinkFaultSpec { fail_prob: 0.6, max_retries: 8, ..Default::default() };
        let run = || {
            let mut t = Tunnel::new(2, TunnelConfig::default());
            t.arm_link_faults(spec);
            let ends: Vec<SimTime> = (0..32)
                .map(|k| t.send(NodeId::Csd(k % 2), NodeId::Host, 1 << 14, SimTime::ZERO))
                .collect();
            (ends, t.stats().retries)
        };
        let (ends_a, retries_a) = run();
        let (ends_b, retries_b) = run();
        assert_eq!(ends_a, ends_b, "same seed, same ladder, same delivery times");
        assert_eq!(retries_a, retries_b);
        assert!(retries_a > 0, "p=0.6 over 32 sends must hit the ladder");
        // A clean tunnel delivers strictly earlier than a retried one.
        let mut clean = Tunnel::new(2, TunnelConfig::default());
        let clean_end = clean.send(NodeId::Csd(0), NodeId::Host, 1 << 14, SimTime::ZERO);
        assert!(ends_a.iter().any(|&e| e > clean_end), "backoff must show up in latency");
    }

    #[test]
    fn exhausted_ladder_escalates_once_per_link() {
        // p = 1.0 is unreachable from config (validate rejects it) but
        // fine for a hand-built spec: every attempt fails, so the very
        // first message on the link runs out of rungs.
        let spec = LinkFaultSpec { fail_prob: 1.0, max_retries: 2, ..Default::default() };
        let mut t = Tunnel::new(2, TunnelConfig::default());
        t.arm_link_faults(spec);
        t.send(NodeId::Csd(0), NodeId::Host, 1 << 12, SimTime::ZERO);
        t.send(NodeId::Csd(0), NodeId::Host, 1 << 12, SimTime::ZERO);
        assert_eq!(t.take_exhausted_link(), Some(0), "link 0 must escalate");
        assert_eq!(t.take_exhausted_link(), None, "and only once until re-exhausted");
        assert_eq!(t.stats().retries, 4, "two messages, two rungs each");
    }
}
