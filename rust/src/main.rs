//! Stannis CLI — tune, train and regenerate the paper's tables/figures.
//!
//! ```text
//! stannis tune     [--network mobilenet_v2]           Algorithm 1 (modeled)
//! stannis train    [--steps N --num-csds K ...]       real-exec training
//! stannis fleet    [--jobs K --total-csds N ...]      batch multi-job coordinator
//! stannis workload [--jobs K --mean-arrival S ...]    online arrival trace (submit/cancel/repair)
//! stannis sweep    [--seeds N --workers W ...]        sharded multi-seed workload sweep
//! stannis query    DIR [--where EXPR --limit N ...]   filter/paginate a job-history ledger
//! stannis lint     [--src DIR --design FILE]          determinism source lint (CI gate)
//! stannis report table1|fig6|fig7|table2              paper artifacts
//! ```
//!
//! Every subcommand rejects unknown options up front
//! ([`Args::check_known`]), so a typo'd flag (`--per-setp`) errors
//! instead of being silently ignored.

use anyhow::{bail, Result};

use stannis::analysis::lint;
use stannis::config::{CrashSpec, ExperimentConfig, FaultSpec, FleetExperimentConfig, WorkloadSpec};
use stannis::coordinator::{modeled_throughput, tune, TuneConfig};
use stannis::fleet::{
    run_sweep, run_trace_with, Fleet, FleetConfig, FleetReport, JobReport, RuntimeEvent,
};
use stannis::ledger;
use stannis::metrics::{f, print_table};
use stannis::perfmodel::PerfModel;
use stannis::power::PowerConfig;
use stannis::sim::SimTime;
use stannis::util::cli::{usage, Args, OptSpec};

const NETS: [(&str, usize, usize); 4] = [
    // (calibration name, paper newport bs, paper host bs) for reports
    ("mobilenet_v2", 25, 315),
    ("nasnet", 15, 325),
    ("inception_v3", 16, 370),
    ("squeezenet", 50, 850),
];

/// Options every experiment-shaped command accepts via
/// [`ExperimentConfig::apply_args`].
const EXPERIMENT_OPTS: [&str; 10] = [
    "network",
    "num-csds",
    "no-host",
    "bs-csd",
    "bs-host",
    "steps",
    "seed",
    "lr",
    "public-images",
    "private-per-csd",
];

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    dispatch(&Args::from_env()?)
}

/// Every dispatchable subcommand, in help order. The usage header is
/// built from this list and the drift-guard test walks it, so a new
/// `dispatch` arm cannot land without its help entry (sweep and lint
/// once did exactly that).
const SUBCOMMANDS: [&str; 8] =
    ["tune", "train", "fleet", "workload", "sweep", "query", "lint", "report"];

fn dispatch(args: &Args) -> Result<()> {
    let cmd = args.positional().first().map(String::as_str).unwrap_or("help");
    match cmd {
        "tune" => cmd_tune(args),
        "train" => cmd_train(args),
        "fleet" => cmd_fleet(args),
        "workload" => cmd_workload(args),
        "sweep" => cmd_sweep(args),
        "query" => cmd_query(args),
        "lint" => cmd_lint(args),
        "report" => {
            args.check_known(&[])?;
            match args.positional().get(1).map(String::as_str) {
                Some("table1") => report_table1(),
                Some("fig6") => report_fig6(),
                Some("fig7") => report_fig7(),
                Some("table2") => report_table2(),
                Some("all") | None => {
                    report_table1()?;
                    report_fig6()?;
                    report_fig7()?;
                    report_table2()
                }
                Some(other) => bail!("unknown report {other:?} (table1|fig6|fig7|table2|all)"),
            }
        }
        "help" | "--help" => {
            // A bare `stannis --help` parses as the flag "help" (no
            // positional), which must keep printing usage.
            args.check_known(&["help"])?;
            print!("{}", help_text());
            Ok(())
        }
        other => bail!(
            "unknown command {other:?}; try `stannis help` ({})",
            SUBCOMMANDS.join("|")
        ),
    }
}

/// The full `stannis help` output — a function (rather than inline in
/// `dispatch`) so the drift-guard test can assert it names every
/// dispatchable subcommand.
fn help_text() -> String {
    usage(
        &format!("stannis <{}> [options]", SUBCOMMANDS.join("|")),
        "STANNIS reproduction: in-storage distributed DNN training",
        &[
            OptSpec { name: "network", help: "network name", default: Some("mobilenet_v2_s") },
            OptSpec { name: "num-csds", help: "number of CSDs", default: Some("3") },
            OptSpec { name: "bs-csd", help: "CSD batch size", default: Some("4") },
            OptSpec { name: "bs-host", help: "host batch size", default: Some("16") },
            OptSpec { name: "steps", help: "training steps", default: Some("50") },
            OptSpec { name: "config", help: "JSON experiment config", default: None },
            OptSpec { name: "no-host", help: "CSD-only cluster", default: None },
            OptSpec { name: "total-csds", help: "fleet/workload: pool size", default: Some("12") },
            OptSpec { name: "jobs", help: "fleet/workload: job count", default: Some("3") },
            OptSpec { name: "degrade", help: "fault dev:secs:factor (repeatable; factor > 1 repairs)", default: None },
            OptSpec { name: "cancel", help: "workload: cancel job:secs (repeatable)", default: None },
            OptSpec { name: "mean-arrival", help: "workload: mean inter-arrival secs", default: Some("30") },
            OptSpec { name: "seed", help: "workload: arrival-process seed", default: Some("7") },
            OptSpec { name: "csds-per-job", help: "workload: devices per default-mix job", default: Some("3") },
            OptSpec { name: "no-stage-io", help: "fleet: skip legacy flash staging", default: None },
            OptSpec { name: "no-data-plane", help: "fleet: skip the modeled data plane (shard maps, DLM-locked rebalance movement)", default: None },
            OptSpec { name: "per-step", help: "fleet: disable steady-state fast-forward (reference path)", default: None },
            OptSpec { name: "retain-jobs", help: "workload/sweep: keep terminal jobs in the table (retained oracle; default streams them out as retired records)", default: None },
            OptSpec { name: "pe-limit", help: "workload/sweep: block P/E endurance limit (0 = unlimited; worn devices drain and roll replacements)", default: Some("0") },
            OptSpec { name: "read-retries", help: "workload/sweep: read-retry ladder depth on uncorrectable reads", default: Some("0") },
            OptSpec { name: "crash", help: "abrupt bay crash device:secs (repeatable; tenant resumes from its checkpoint)", default: None },
            OptSpec { name: "checkpoint-steps", help: "steps between model-state checkpoints (0 = off)", default: Some("0") },
            OptSpec { name: "checkpoint-host-copy", help: "also copy each checkpoint to the host over the tunnel", default: None },
            OptSpec { name: "link-fail-prob", help: "per-hop transient tunnel failure probability (0 = off)", default: Some("0") },
            OptSpec { name: "link-retries", help: "retry-ladder rungs before a flaky link escalates to a crash", default: Some("4") },
            OptSpec { name: "link-backoff-us", help: "base backoff of the link retry ladder (doubles per rung)", default: Some("50") },
            OptSpec { name: "seeds", help: "sweep: number of seeded traces (seed, seed+1, ...)", default: Some("4") },
            OptSpec { name: "workers", help: "sweep: worker threads (results are identical at any count)", default: Some("4") },
            OptSpec { name: "audit", help: "fleet/workload/sweep: run the full structural audit after every event", default: None },
            OptSpec { name: "ledger", help: "fleet/workload/sweep: persist retired jobs to this ledger directory", default: None },
            OptSpec { name: "where", help: "query: filter expression, e.g. 'state = done and energy_j > 100'", default: None },
            OptSpec { name: "limit", help: "query: records per page", default: Some("20") },
            OptSpec { name: "cursor", help: "query: resume from an opaque page cursor", default: None },
            OptSpec { name: "agg", help: "query: aggregate instead of listing — count, sum:F, p50:F, p99:F (repeatable)", default: None },
            OptSpec { name: "json", help: "query: emit records as JSON lines instead of a table", default: None },
            OptSpec { name: "src", help: "lint: scan this source dir instead of the repo's rust/src", default: None },
            OptSpec { name: "design", help: "lint: DESIGN.md to resolve section references against", default: None },
        ],
    )
}

fn experiment_config(args: &Args) -> Result<ExperimentConfig> {
    let base = match args.get("config") {
        Some(path) => ExperimentConfig::from_file(path)?,
        None => ExperimentConfig::default(),
    };
    base.apply_args(args)
}

fn cmd_tune(args: &Args) -> Result<()> {
    args.check_known(&["network"])?;
    let net = args.get_or("network", "mobilenet_v2");
    let mut model = PerfModel::default();
    let r = tune(&mut model, net, &TuneConfig::default())?;
    print_table(
        &format!("Algorithm 1 tuning — {net}"),
        &["device", "batch", "img/s", "s/batch"],
        &[
            vec!["newport".into(), r.newport_bs.to_string(), f(r.newport_ips, 2), f(r.newport_time, 2)],
            vec!["host".into(), r.host_bs.to_string(), f(r.host_ips, 2), f(r.host_time, 2)],
        ],
    );
    println!(
        "host/newport time ratio {:.3} (target 1/(1-margin) = 1.25)",
        r.host_time / r.newport_time
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut known = vec!["config"];
    known.extend(EXPERIMENT_OPTS);
    args.check_known(&known)?;
    let cfg = experiment_config(args)?;
    println!(
        "bringing up cluster: {} host + {} CSDs, net {}, bs {}/{}",
        if cfg.include_host { "1" } else { "0" },
        cfg.num_csds,
        cfg.network,
        cfg.bs_host,
        cfg.bs_csd
    );
    let cluster = stannis::cluster::Cluster::bring_up(cfg.clone())?;
    println!(
        "placement: {} steps/epoch, host {} imgs, {} imgs/CSD",
        cluster.placement.steps_per_epoch,
        cluster.placement.host_ids.len(),
        cluster.placement.csd_ids.first().map_or(0, Vec::len),
    );
    let mut trainer = cluster.trainer()?;
    // Real-exec wall-clock is reporting only; it never feeds the sim.
    // lint: allow(wallclock)
    #[allow(clippy::disallowed_methods)]
    let t0 = std::time::Instant::now();
    let report = trainer.train(cfg.steps)?;
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "trained {} steps ({} images) in {:.1}s wall: loss {:.4} -> {:.4}, replica divergence {:.2e}",
        cfg.steps,
        report.images_processed,
        wall,
        report.first_loss(),
        report.last_loss(),
        report.max_replica_divergence,
    );
    let (eval_loss, acc) = trainer.evaluate(4)?;
    println!("eval: loss {eval_loss:.4}, accuracy {acc:.3}");
    Ok(())
}

/// Render the shared per-job fleet table. `online` adds the workload
/// columns (lifecycle state, arrival, queue wait, completion). Takes
/// the reports directly: the batch fleet passes its retained
/// [`FleetReport::jobs`], the streaming workload passes the retired
/// records it collected off the log.
fn print_job_table(jobs: &[JobReport], online: bool) {
    let mut headers = vec![
        "job", "network", "devices", "bs csd/host", "steps", "imgs", "img/s", "sync", "J/img",
        "retunes", "moved", "lockw", "wait", "span",
    ];
    if online {
        headers.extend(["state", "arrival", "done"]);
    }
    let rows: Vec<Vec<String>> = jobs
        .iter()
        .map(|j| {
            let mut row = vec![
                j.id.to_string(),
                j.network.clone(),
                format!("{}{}", j.devices.len(), if j.held_host { "+host" } else { "" }),
                format!("{}/{}", j.bs_csd, if j.held_host { j.bs_host.to_string() } else { "-".into() }),
                j.steps_done.to_string(),
                j.images.to_string(),
                f(j.images_per_sec, 2),
                format!("{}%", f(100.0 * j.sync_fraction, 0)),
                f(j.j_per_image, 2),
                j.retunes.to_string(),
                format!("{:.1}M", j.bytes_moved as f64 / 1e6),
                j.lock_wait.to_string(),
                j.queue_wait.to_string(),
                j.elapsed.to_string(),
            ];
            if online {
                row.push(j.state.to_string());
                row.push(j.submitted_at.to_string());
                row.push(j.finished_at.to_string());
            }
            row
        })
        .collect();
    print_table(
        if online {
            "Workload — per-job schedule and outcome"
        } else {
            "Fleet — per-job schedule and outcome"
        },
        &headers,
        &rows,
    );
}

fn print_fleet_summary(r: &FleetReport) {
    println!(
        "\nfleet: makespan {}, {} images ({} img/s aggregate), energy {:.0} J jobs + {:.0} J shared chassis, {} retune(s), {} cancelled, mean queue wait {:.1}s",
        r.makespan,
        r.total_images,
        f(r.aggregate_ips, 2),
        r.jobs_energy_j,
        r.overhead_energy_j,
        r.retunes,
        r.cancelled,
        r.queue_wait.mean(),
    );
    println!(
        "flash: {} page decode(s) ({} corrected, {} uncorrectable, {} retry rung(s)), {} erase(s), {} block(s) retired ({} suspect), WAF {:.2}; {} job(s) drained, {} device(s) replaced",
        r.ecc.pages,
        r.ecc.corrected_pages,
        r.ecc.uncorrectable,
        r.ecc.retries,
        r.wear.erases,
        r.wear.retired_blocks,
        r.wear.suspect_blocks,
        r.wear.waf,
        r.drained,
        r.devices_replaced,
    );
    println!(
        "faults: {} crash(es), {} step(s) lost, {:.1} MB checkpointed, {} link retry(ies)",
        r.crashed,
        r.lost_steps,
        r.checkpoint_bytes as f64 / 1e6,
        r.link_retries,
    );
}

fn cmd_fleet(args: &Args) -> Result<()> {
    args.check_known(&[
        "config",
        "total-csds",
        "jobs",
        "degrade",
        "crash",
        "checkpoint-steps",
        "checkpoint-host-copy",
        "link-fail-prob",
        "link-retries",
        "link-backoff-us",
        "no-stage-io",
        "no-data-plane",
        "per-step",
        "audit",
        "ledger",
    ])?;
    let mut spec = match args.get("config") {
        Some(path) => FleetExperimentConfig::from_file(path)?,
        None => FleetExperimentConfig::default(),
    };
    spec.total_csds = args.parse_or("total-csds", spec.total_csds)?;
    if spec.jobs.is_empty() {
        let n_jobs = args.parse_or("jobs", 3)?;
        spec.jobs = FleetExperimentConfig::default_mix(n_jobs, spec.total_csds).jobs;
    } else if args.get("jobs").is_some() {
        bail!("--jobs conflicts with a config file that already defines jobs");
    }
    if args.flag("no-stage-io") {
        spec.stage_io = false;
    }
    if args.flag("no-data-plane") {
        spec.data_plane = false;
    }
    if args.flag("per-step") {
        spec.fast_forward = false;
    }
    // Repeatable: every --degrade occurrence is a fault (they used to
    // collapse to the last one).
    for d in args.get_all("degrade") {
        spec.faults.push(FaultSpec::parse_cli(d)?);
    }
    for c in args.get_all("crash") {
        spec.crashes.push(CrashSpec::parse_cli(c)?);
    }
    spec.checkpoint.interval_steps =
        args.parse_or("checkpoint-steps", spec.checkpoint.interval_steps)?;
    if args.flag("checkpoint-host-copy") {
        spec.checkpoint.host_copy = true;
    }
    spec.link_fault.fail_prob = args.parse_or("link-fail-prob", spec.link_fault.fail_prob)?;
    spec.link_fault.max_retries = args.parse_or("link-retries", spec.link_fault.max_retries)?;
    spec.link_fault.backoff_base_us =
        args.parse_or("link-backoff-us", spec.link_fault.backoff_base_us)?;

    println!(
        "fleet: {} CSDs, {} jobs, {} fault(s), {} crash(es), stage_io={}, data_plane={}, fast_forward={}",
        spec.total_csds,
        spec.jobs.len(),
        spec.faults.len(),
        spec.crashes.len(),
        spec.stage_io,
        spec.data_plane,
        spec.fast_forward
    );
    let mut fleet = Fleet::new(FleetConfig {
        total_csds: spec.total_csds,
        stage_io: spec.stage_io,
        data_plane: spec.data_plane,
        fast_forward: spec.fast_forward,
        audit: args.flag("audit"),
        checkpoint: spec.checkpoint,
        link_fault: spec.link_fault,
        ledger_path: args.get("ledger").map(std::path::PathBuf::from),
        ..Default::default()
    });
    for job in &spec.jobs {
        fleet.submit(job.clone());
    }
    for fault in &spec.faults {
        fleet.inject_degradation(SimTime::from_secs_f64(fault.at_secs), fault.device, fault.factor);
    }
    for crash in &spec.crashes {
        fleet.inject_crash(SimTime::from_secs_f64(crash.at_secs), crash.device);
    }
    let r = fleet.run()?;

    print_job_table(&r.jobs, false);
    print_fleet_summary(&r);
    println!(
        "data plane: {:.1} MB moved across {} rebalance window(s), mean shard-map lock wait {:.2}ms, {} host push(es)",
        r.bytes_moved as f64 / 1e6,
        fleet.data_plane().stats().rebalances,
        1e3 * r.lock_wait.mean(),
        fleet.data_plane().stats().host_pushes,
    );
    Ok(())
}

/// Workload flags shared by `workload` and `sweep` (both drive the
/// streaming trace runner over a [`WorkloadSpec`]).
const WORKLOAD_OPTS: [&str; 22] = [
    "config",
    "audit",
    "ledger",
    "total-csds",
    "jobs",
    "mean-arrival",
    "seed",
    "csds-per-job",
    "cancel",
    "degrade",
    "crash",
    "checkpoint-steps",
    "checkpoint-host-copy",
    "link-fail-prob",
    "link-retries",
    "link-backoff-us",
    "no-stage-io",
    "no-data-plane",
    "per-step",
    "retain-jobs",
    "pe-limit",
    "read-retries",
];

fn workload_spec(args: &Args) -> Result<WorkloadSpec> {
    // `apply_args` folds in every override, including the repeatable
    // --cancel / --degrade schedules.
    match args.get("config") {
        Some(path) => WorkloadSpec::from_file(path)?,
        None => WorkloadSpec::default(),
    }
    .apply_args(args)
}

/// Online session: draw the seeded arrival trace, replay cancels and
/// health events, and stream every structural event as the clock
/// advances through the chunked trace driver. Terminal jobs retire
/// into the event stream; the per-job table is rebuilt from those
/// retired records (suppressed for huge traces unless `--retain-jobs`).
fn cmd_workload(args: &Args) -> Result<()> {
    args.check_known(&WORKLOAD_OPTS)?;
    let spec = workload_spec(args)?;

    println!(
        "workload: {} CSDs, {} arrival(s) (mean gap {}s, seed {}), {} cancel(s), {} fault(s), {} crash(es), data_plane={}, fast_forward={}, retain_jobs={}",
        spec.total_csds,
        spec.jobs,
        f(spec.mean_interarrival_secs, 1),
        spec.seed,
        spec.cancels.len(),
        spec.faults.len(),
        spec.crashes.len(),
        spec.data_plane,
        spec.fast_forward,
        spec.retain_jobs,
    );
    // Per-job tables stop being readable (and affordable) at fleet
    // scale; keep collecting retired reports only for small traces or
    // on explicit request.
    let collect_jobs = spec.retain_jobs || spec.jobs <= 64;
    let mut finished: Vec<JobReport> = Vec::new();
    let (summary, rt) = run_trace_with(&spec, |e| {
        println!("{e}");
        if collect_jobs {
            if let RuntimeEvent::Retired { record } = &e.event {
                finished.push(record.report.clone());
            }
        }
    })?;

    let r = rt.report();
    println!();
    if collect_jobs {
        // Retirement order is finish order; present in submission order.
        finished.sort_by_key(|j| j.id);
        print_job_table(&finished, true);
    } else {
        println!(
            "(per-job table suppressed for {} jobs; rerun with --retain-jobs to force)",
            spec.jobs
        );
    }
    print_fleet_summary(&r);
    println!(
        "runtime: {} job(s) retired, peak {} live, {} job-table slot(s), {} log event(s)",
        r.retired, summary.peak_live_jobs, summary.job_slots, summary.log_events,
    );
    let stats = rt.data_plane().stats();
    println!(
        "data plane: {:.1} MB moved across {} rebalance window(s), {} cancel teardown(s) freeing {} page(s), {} host push(es)",
        r.bytes_moved as f64 / 1e6,
        stats.rebalances,
        stats.cancels,
        stats.freed_pages,
        stats.host_pushes,
    );
    Ok(())
}

/// Sharded multi-seed sweep: run the base workload once per seed
/// (`seed, seed+1, ...`) across worker threads and merge the per-trace
/// aggregates. The merged numbers are bit-identical at any
/// `--workers` value — parallelism is free to vary by machine.
fn cmd_sweep(args: &Args) -> Result<()> {
    let mut known = vec!["seeds", "workers"];
    known.extend(WORKLOAD_OPTS);
    args.check_known(&known)?;
    let base = workload_spec(args)?;
    let n_seeds: u64 = args.parse_or("seeds", 4u64)?;
    anyhow::ensure!(n_seeds > 0, "--seeds must be at least 1");
    let workers: usize = args.parse_or("workers", 4usize)?;
    let seeds: Vec<u64> = (0..n_seeds).map(|i| base.seed.wrapping_add(i)).collect();

    println!(
        "sweep: {} trace(s) x {} arrival(s) (base seed {}, mean gap {}s) over {} worker(s), {} CSDs",
        seeds.len(),
        base.jobs,
        base.seed,
        f(base.mean_interarrival_secs, 1),
        workers.clamp(1, seeds.len()),
        base.total_csds,
    );
    let rep = run_sweep(&base, &seeds, workers)?;

    let rows: Vec<Vec<String>> = rep
        .traces
        .iter()
        .map(|t| {
            let hours = t.makespan.as_secs_f64() / 3600.0;
            vec![
                t.seed.to_string(),
                t.jobs.to_string(),
                t.completed.to_string(),
                t.cancelled.to_string(),
                t.total_images.to_string(),
                f(t.aggregate_ips, 2),
                f(if hours > 0.0 { t.completed as f64 / hours } else { 0.0 }, 1),
                t.drained.to_string(),
                t.crashed.to_string(),
                t.lost_steps.to_string(),
                format!("{:.1}M", t.checkpoint_bytes as f64 / 1e6),
                t.link_retries.to_string(),
                t.devices_replaced.to_string(),
                f(t.waf, 2),
                t.peak_live_jobs.to_string(),
                t.job_slots.to_string(),
                t.makespan.to_string(),
            ]
        })
        .collect();
    print_table(
        "Sweep — per-seed traces",
        &[
            "seed", "jobs", "done", "cancelled", "imgs", "img/s", "jobs/h", "drained",
            "crashed", "lost", "ckpt", "retries", "replaced", "waf", "peak live", "slots",
            "makespan",
        ],
        &rows,
    );
    println!(
        "\nsweep: {} job(s) ({} cancelled, {} drained, {} crashed) across {} trace(s), {} images; mean {:.1} jobs/h, mean {:.2} img/s; queue wait mean {:.1}s max {:.1}s; peak {} live job(s); {} device(s) replaced; {} step(s) lost, {:.1} MB checkpointed, {} link retry(ies)",
        rep.total_jobs,
        rep.cancelled,
        rep.drained,
        rep.crashed,
        rep.traces.len(),
        rep.total_images,
        rep.jobs_per_hour.mean(),
        rep.aggregate_ips.mean(),
        rep.queue_wait.mean(),
        rep.queue_wait.max(),
        rep.peak_live_jobs,
        rep.devices_replaced,
        rep.lost_steps,
        rep.checkpoint_bytes as f64 / 1e6,
        rep.link_retries,
    );
    Ok(())
}

/// Inspect a job-history ledger written by `--ledger` (DESIGN.md
/// §Ledger): validated `--where` filters, keyset pagination with
/// opaque `--cursor` tokens, and `--agg` projections. Any malformed
/// expression, cursor, or aggregate spec exits non-zero before a
/// single frame is decoded.
fn cmd_query(args: &Args) -> Result<()> {
    // Option gate first: a typo'd flag must error as such even when
    // the directory argument is also missing or wrong.
    args.check_known(&["where", "limit", "cursor", "agg", "json"])?;
    let dir = args
        .positional()
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("usage: stannis query <ledger-dir> [--where EXPR --limit N --cursor C --agg A --json]"))?;
    let filter = args.get("where").map(ledger::compile).transpose()?;
    let aggs = args
        .get_all("agg")
        .iter()
        .map(|a| ledger::parse_agg(a))
        .collect::<Result<Vec<_>>>()?;
    let cursor = args.get("cursor").map(ledger::decode_cursor).transpose()?;
    let limit: usize = args.parse_or("limit", 20usize)?;

    let store = ledger::LedgerStore::open(std::path::Path::new(dir))?;
    if !aggs.is_empty() {
        anyhow::ensure!(
            args.get("cursor").is_none(),
            "--agg scans the full match set; it does not paginate (--cursor)"
        );
        let rows: Vec<Vec<String>> = ledger::aggregate(&store, filter.as_ref(), &aggs)?
            .into_iter()
            .map(|(label, value)| vec![label, f(value, 3)])
            .collect();
        print_table("Ledger — aggregates", &["aggregate", "value"], &rows);
        return Ok(());
    }

    let page = ledger::page(&store, filter.as_ref(), cursor, limit)?;
    if args.flag("json") {
        for (_, rec) in &page.records {
            println!("{}", ledger::record_json(rec));
        }
    } else {
        let reports: Vec<JobReport> =
            page.records.iter().map(|(_, r)| r.report.clone()).collect();
        print_job_table(&reports, true);
        println!(
            "\nquery: {} of {} record(s) in {} ({} segment(s))",
            page.records.len(),
            store.records_total(),
            dir,
            store.segments().len(),
        );
    }
    if let Some(next) = &page.next {
        println!("next page: --cursor {next}");
    }
    Ok(())
}

/// Determinism lint over the crate sources (DESIGN.md
/// §Static-Analysis): default-hasher collections, wall-clock reads,
/// float accumulation in the report ledgers, dangling DESIGN.md
/// section references and untested invariant checkers all exit
/// non-zero. CI runs `cargo run -- lint` as a merge gate.
fn cmd_lint(args: &Args) -> Result<()> {
    args.check_known(&["src", "design"])?;
    let diags = match args.get("src") {
        Some(src) => {
            // Explicit tree (e.g. the lint fixtures). DESIGN.md still
            // resolves against the enclosing repo unless overridden,
            // so fixture §-references exercise the real headings.
            let design = match args.get("design") {
                Some(d) => Some(std::path::PathBuf::from(d)),
                None => lint::find_repo_root(&std::env::current_dir()?)
                    .map(|root| root.join("DESIGN.md")),
            };
            let tree = lint::SourceTree::load(
                std::path::Path::new(src),
                design.as_deref(),
                &[],
            )?;
            lint::lint_tree(&tree)
        }
        None => {
            let cwd = std::env::current_dir()?;
            let root = lint::find_repo_root(&cwd).ok_or_else(|| {
                anyhow::anyhow!(
                    "no repo root (rust/src + DESIGN.md) at or above {}",
                    cwd.display()
                )
            })?;
            lint::run(&root)?
        }
    };
    if diags.is_empty() {
        println!("stannis lint: clean");
        return Ok(());
    }
    for d in &diags {
        println!("{d}");
    }
    bail!("stannis lint: {} diagnostic(s)", diags.len());
}

fn report_table1() -> Result<()> {
    let mut model = PerfModel::default();
    let mut rows = Vec::new();
    for (net, paper_nbs, paper_hbs) in NETS {
        let r = tune(&mut model, net, &TuneConfig::default())?;
        rows.push(vec![
            net.to_string(),
            format!("{} / {}", r.host_bs, r.newport_bs),
            format!("{paper_hbs} / {paper_nbs}"),
            format!("{} / {}", f(r.host_ips, 2), f(r.newport_ips, 2)),
        ]);
    }
    print_table(
        "Table I — parameter tuning (ours vs paper)",
        &["network", "batch host/newport", "paper batch", "speed host/newport (img/s)"],
        &rows,
    );
    Ok(())
}

fn tuned(net: &str) -> Result<(usize, usize)> {
    let mut model = PerfModel::default();
    let r = tune(&mut model, net, &TuneConfig::default())?;
    Ok((r.newport_bs, r.host_bs))
}

fn report_fig6() -> Result<()> {
    let counts = [0usize, 1, 2, 4, 6, 8, 12, 16, 20, 24];
    let mut rows = Vec::new();
    for (net, _, _) in NETS {
        let (nbs, hbs) = tuned(net)?;
        let mut cells = vec![net.to_string()];
        for &n in &counts {
            let r = modeled_throughput(net, n, true, nbs, hbs, 3)?;
            cells.push(f(r.images_per_sec, 1));
        }
        rows.push(cells);
    }
    let labels: Vec<String> = counts.iter().map(|n| format!("{n} CSDs")).collect();
    let mut headers = vec!["network"];
    headers.extend(labels.iter().map(String::as_str));
    print_table("Fig. 6 — aggregate img/s vs #CSDs (host included)", &headers, &rows);
    Ok(())
}

fn report_fig7() -> Result<()> {
    let counts = [0usize, 1, 2, 4, 6, 8, 12, 16, 20, 24];
    let mut rows = Vec::new();
    for (net, _, _) in NETS {
        let (nbs, hbs) = tuned(net)?;
        let base = modeled_throughput(net, 0, true, nbs, hbs, 3)?.images_per_sec;
        let mut cells = vec![net.to_string()];
        for &n in &counts {
            let r = modeled_throughput(net, n, true, nbs, hbs, 3)?;
            cells.push(f(r.images_per_sec / base, 2));
        }
        rows.push(cells);
    }
    let labels: Vec<String> = counts.iter().map(|n| n.to_string()).collect();
    let mut headers = vec!["network"];
    headers.extend(labels.iter().map(String::as_str));
    print_table("Fig. 7 — speedup vs host-alone (columns = #CSDs)", &headers, &rows);
    Ok(())
}

fn report_table2() -> Result<()> {
    let power = PowerConfig::default();
    let (nbs, hbs) = tuned("mobilenet_v2")?;
    let paper =
        [(0usize, 13.10, 0.0), (4, 8.30, 37.0), (8, 6.84, 48.0), (16, 5.05, 62.0), (24, 4.02, 69.0)];
    let base_j_img = {
        let r = modeled_throughput("mobilenet_v2", 0, true, nbs, hbs, 3)?;
        power.system_power_w(0, 24, true) / r.images_per_sec
    };
    let mut rows = Vec::new();
    for (n, paper_j, paper_saving) in paper {
        let r = modeled_throughput("mobilenet_v2", n, true, nbs, hbs, 3)?;
        let p = power.system_power_w(n, 24, true);
        let j_img = p / r.images_per_sec;
        let saving = 100.0 * (1.0 - j_img / base_j_img);
        let flops_w = r.images_per_sec * 7.16e6 * 2.0 / p; // paper-scale FLOPs
        rows.push(vec![
            n.to_string(),
            f(j_img, 2),
            f(paper_j, 2),
            format!("{}%", f(saving, 0)),
            format!("{}%", f(paper_saving, 0)),
            format!("{:.1}M", flops_w / 1e6),
        ]);
    }
    print_table(
        "Table II — energy (MobileNetV2)",
        &["CSDs", "J/img", "paper J/img", "saving", "paper saving", "FLOP/W (model)"],
        &rows,
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    fn assert_unknown_option(cmd_line: &str) {
        let e = dispatch(&args(cmd_line)).unwrap_err();
        assert!(
            e.to_string().contains("unknown option"),
            "{cmd_line:?} must reject the typo'd flag, got: {e:#}"
        );
    }

    /// Every subcommand runs `Args::check_known` before doing any work,
    /// so a typo'd flag errors instead of being silently ignored.
    #[test]
    fn every_subcommand_rejects_unknown_options() {
        assert_unknown_option("tune --netwrok mobilenet_v2");
        assert_unknown_option("train --per-setp x");
        assert_unknown_option("fleet --per-setp x");
        assert_unknown_option("workload --cancle 0:10");
        assert_unknown_option("sweep --workrs 2");
        assert_unknown_option("query /tmp --wehre x");
        assert_unknown_option("lint --srcc x");
        assert_unknown_option("report --whoops 1");
        assert_unknown_option("help --whoops 1");
    }

    /// `stannis help` / `dispatch` drift guard: every dispatchable
    /// subcommand must appear in the help output, and everything the
    /// guard walks must actually dispatch (sweep and lint once landed
    /// in the table without a usage line).
    #[test]
    fn help_names_every_dispatchable_subcommand() {
        let text = help_text();
        for cmd in SUBCOMMANDS {
            assert!(text.contains(cmd), "help output must mention the {cmd:?} subcommand");
            // The subcommand really dispatches: probing it with a bogus
            // flag reaches its own option gate, not the unknown-command
            // arm.
            let e = dispatch(&args(&format!("{cmd} --bogus-flag-for-drift-guard x")))
                .unwrap_err()
                .to_string();
            assert!(
                !e.contains("unknown command"),
                "{cmd:?} is listed in SUBCOMMANDS but dispatch does not know it: {e}"
            );
        }
        // And the arm the guard protects against still fires.
        let e = dispatch(&args("no-such-command")).unwrap_err().to_string();
        assert!(e.contains("unknown command"), "got: {e}");
    }

    #[test]
    fn unknown_flags_are_rejected_too() {
        // A bare trailing flag (no value) goes down the flags path;
        // check_known must cover it as well.
        let e = dispatch(&args("fleet --no-stagio")).unwrap_err();
        assert!(e.to_string().contains("unknown option"), "got: {e:#}");
    }

    #[test]
    fn known_options_pass_the_gate() {
        // Small end-to-end smoke runs through dispatch (fast shapes).
        dispatch(&args("--help")).unwrap();
        dispatch(&args("tune --network squeezenet")).unwrap();
        dispatch(&args("fleet --jobs 1 --total-csds 2 --no-stage-io --degrade 0:5:0.8"))
            .unwrap();
        dispatch(&args(
            "workload --jobs 2 --total-csds 2 --csds-per-job 1 --mean-arrival 5 \
             --seed 3 --cancel 1:40 --degrade 0:10:0.7 --degrade 0:20:2 --no-stage-io \
             --read-retries 2",
        ))
        .unwrap();
        dispatch(&args(
            "sweep --seeds 2 --workers 2 --jobs 2 --total-csds 2 --csds-per-job 1 \
             --mean-arrival 5 --seed 3 --no-stage-io --retain-jobs --pe-limit 100000",
        ))
        .unwrap();
        // --audit runs the full structural audit after every pumped
        // event and must not change the outcome (bit-identity is the
        // property test's job; here we just smoke the gated path).
        dispatch(&args(
            "workload --jobs 2 --total-csds 2 --csds-per-job 1 --mean-arrival 5 \
             --seed 3 --no-stage-io --audit",
        ))
        .unwrap();
        // Crash/checkpoint/link-fault knobs parse and run end to end
        // (the bit-identity and conservation properties live in the
        // integration suites; this smokes the CLI wiring).
        dispatch(&args(
            "workload --jobs 2 --total-csds 2 --csds-per-job 1 --mean-arrival 5 \
             --seed 3 --no-stage-io --checkpoint-steps 2 --checkpoint-host-copy \
             --crash 0:40 --audit",
        ))
        .unwrap();
        dispatch(&args(
            "fleet --jobs 1 --total-csds 2 --no-stage-io --checkpoint-steps 3 --crash 1:30",
        ))
        .unwrap();
    }

    /// End-to-end ledger wiring: a workload run with `--ledger` leaves
    /// a queryable directory; `stannis query` lists, filters,
    /// paginates and aggregates it. (The test harness splits on
    /// whitespace, so filters here are written space-free — the lexer
    /// does not require spaces around operators.)
    #[test]
    fn ledger_flag_and_query_subcommand_work_end_to_end() {
        let dir = std::env::temp_dir()
            .join(format!("stannis_cli_ledger_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let d = dir.display();
        dispatch(&args(&format!(
            "workload --jobs 3 --total-csds 2 --csds-per-job 1 --mean-arrival 5 \
             --seed 3 --no-stage-io --ledger {d}"
        )))
        .unwrap();
        dispatch(&args(&format!("query {d}"))).unwrap();
        dispatch(&args(&format!("query {d} --limit 2"))).unwrap();
        dispatch(&args(&format!("query {d} --where crashed=false --json"))).unwrap();
        dispatch(&args(&format!("query {d} --agg count --agg sum:energy_j"))).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Malformed query inputs are errors before any record is decoded:
    /// bad filter, bad cursor, bad aggregate, zero limit, missing dir.
    #[test]
    fn query_rejects_malformed_inputs() {
        let dir = std::env::temp_dir()
            .join(format!("stannis_cli_query_bad_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let d = dir.display();
        dispatch(&args(&format!(
            "workload --jobs 2 --total-csds 2 --csds-per-job 1 --mean-arrival 5 \
             --seed 3 --no-stage-io --ledger {d}"
        )))
        .unwrap();
        for bad in [
            format!("query {d} --where bogus_field=1"),
            format!("query {d} --where state=flying"),
            format!("query {d} --where energy_j>"),
            format!("query {d} --cursor !!!"),
            format!("query {d} --limit 0"),
            format!("query {d} --agg max:energy_j"),
            format!("query {d} --agg count --cursor AAAA"),
            "query".to_string(),
            "query /no/such/ledger/dir".to_string(),
        ] {
            assert!(dispatch(&args(&bad)).is_err(), "{bad:?} must fail");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The shipped tree lints clean through the CLI, and the seeded
    /// fixture violations all fire — the same invocations CI runs.
    #[test]
    fn lint_subcommand_is_clean_on_the_tree_and_fires_on_fixtures() {
        // cargo sets the test cwd to the manifest dir (rust/), which
        // sits under the repo root find_repo_root discovers.
        dispatch(&args("lint")).unwrap();

        let fixtures = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/lint_fixtures");
        let e = dispatch(&args(&format!("lint --src {fixtures}"))).unwrap_err();
        assert!(e.to_string().contains("diagnostic"), "got: {e:#}");
    }
}
