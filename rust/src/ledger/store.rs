//! Append-only segmented record log on disk (DESIGN.md §Ledger).
//!
//! A ledger directory holds numbered segment files (`seg-00000000.seg`,
//! `seg-00000001.seg`, ...), each a header, a run of
//! [`codec`](super::codec) frames, and a fixed-size sealed footer:
//!
//! ```text
//! [8B magic "STNLEDG1"] [u32 version] [frame]* [52B footer]
//! footer = [u32 sentinel] [u64 records] [u64 min_job] [u64 max_job]
//!          [u64 min_retired_ns] [u64 max_retired_ns] [u64 FNV-1a]
//! ```
//!
//! The footer carries exactly what query planning needs to *skip* a
//! segment without reading its frames: the record count and the
//! min/max job-id and retire-time of everything inside. A segment
//! rotates once its frames pass [`SEGMENT_PAYLOAD_BYTES`]; the final
//! (possibly short) segment is sealed by [`LedgerWriter::finish`],
//! which the trace drivers and the batch façade call when a session
//! drains — an unsealed tail fails [`LedgerStore::open`] loudly.
//!
//! Determinism contract: the file bytes are a pure function of the
//! record stream (no wallclock, no pids, no map iteration order), so
//! two bit-identical runs produce byte-identical ledgers — the
//! property the integration suite asserts across executors and sweep
//! worker counts.

use std::fs;
use std::io::{Read as _, Seek as _, SeekFrom, Write as _};
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context};

use crate::analysis::audit::{Auditable, Fnv64};
use crate::fleet::RetiredRecord;
use crate::Result;

use super::codec::{self, DecodeError};

/// Leading bytes of every segment file.
pub const MAGIC: [u8; 8] = *b"STNLEDG1";

/// Segment header length: magic + schema version.
const HEADER_LEN: u64 = 8 + 4;

/// Footer length: sentinel + 5 summary words + checksum.
const FOOTER_LEN: u64 = 4 + 5 * 8 + 8;

/// Marks a sealed footer (a value no frame length prefix can take,
/// since it is far above [`codec::MAX_PAYLOAD`]).
const FOOTER_SENTINEL: u32 = 0xF007_F007;

/// Frame bytes after which the open segment rotates. Small enough that
/// footer pruning has real resolution over a big ledger, large enough
/// that a million-record ledger stays in the hundreds of files.
pub const SEGMENT_PAYLOAD_BYTES: u64 = 256 * 1024;

/// Sealed-segment summary — the footer's content, in memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SegmentSummary {
    /// Records sealed into the segment (always ≥ 1: a segment file is
    /// only created by the first record that lands in it).
    pub records: u64,
    pub min_job: u64,
    pub max_job: u64,
    pub min_retired_ns: u64,
    pub max_retired_ns: u64,
}

impl SegmentSummary {
    fn fold(&mut self, rec: &RetiredRecord) {
        let job = rec.report.id.0;
        let ret = rec.retired_at.as_ns();
        if self.records == 0 {
            *self = SegmentSummary {
                records: 0,
                min_job: job,
                max_job: job,
                min_retired_ns: ret,
                max_retired_ns: ret,
            };
        }
        self.records += 1;
        self.min_job = self.min_job.min(job);
        self.max_job = self.max_job.max(job);
        self.min_retired_ns = self.min_retired_ns.min(ret);
        self.max_retired_ns = self.max_retired_ns.max(ret);
    }

    fn empty() -> Self {
        SegmentSummary { records: 0, min_job: 0, max_job: 0, min_retired_ns: 0, max_retired_ns: 0 }
    }

    fn checksum(&self) -> u64 {
        let mut h = Fnv64::new();
        h.write_u32(FOOTER_SENTINEL);
        h.write_u64(self.records);
        h.write_u64(self.min_job);
        h.write_u64(self.max_job);
        h.write_u64(self.min_retired_ns);
        h.write_u64(self.max_retired_ns);
        h.finish()
    }

    fn encode(&self) -> [u8; FOOTER_LEN as usize] {
        let mut out = [0u8; FOOTER_LEN as usize];
        out[0..4].copy_from_slice(&FOOTER_SENTINEL.to_le_bytes());
        out[4..12].copy_from_slice(&self.records.to_le_bytes());
        out[12..20].copy_from_slice(&self.min_job.to_le_bytes());
        out[20..28].copy_from_slice(&self.max_job.to_le_bytes());
        out[28..36].copy_from_slice(&self.min_retired_ns.to_le_bytes());
        out[36..44].copy_from_slice(&self.max_retired_ns.to_le_bytes());
        out[44..52].copy_from_slice(&self.checksum().to_le_bytes());
        out
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        ensure!(bytes.len() == FOOTER_LEN as usize, "footer must be {FOOTER_LEN} bytes");
        let word = |at: usize| u64::from_le_bytes(bytes[at..at + 8].try_into().expect("8 bytes"));
        let sentinel = u32::from_le_bytes(bytes[0..4].try_into().expect("4 bytes"));
        ensure!(
            sentinel == FOOTER_SENTINEL,
            "segment footer sentinel {sentinel:#010x} (unsealed tail segment? the \
             writer seals on `finish`)"
        );
        let s = SegmentSummary {
            records: word(4),
            min_job: word(12),
            max_job: word(20),
            min_retired_ns: word(28),
            max_retired_ns: word(36),
        };
        let want = word(44);
        ensure!(
            want == s.checksum(),
            "segment footer checksum mismatch: stored {want:#018x}, computed {:#018x}",
            s.checksum()
        );
        ensure!(s.records > 0, "sealed segment claims zero records");
        ensure!(s.min_job <= s.max_job, "footer job range inverted");
        ensure!(s.min_retired_ns <= s.max_retired_ns, "footer retire-time range inverted");
        Ok(s)
    }
}

fn segment_file_name(index: u64) -> String {
    format!("seg-{index:08}.seg")
}

// ---- write path --------------------------------------------------------

/// Bookkeeping for one segment this writer already sealed, so
/// [`LedgerWriter::audit`] can re-verify the on-disk chain cheaply
/// (footers only, not every frame).
#[derive(Debug, Clone)]
struct SealedSegment {
    path: PathBuf,
    bytes: u64,
    summary: SegmentSummary,
}

/// The append side of the ledger. Construction does no I/O — the
/// directory and first segment appear when the first record does, so a
/// ledger-armed run that never retires a job still ends with a valid
/// (empty) ledger directory after [`LedgerWriter::finish`].
///
/// [`LedgerWriter::append`] is deliberately infallible: retirement
/// control flow must be bit-identical with the ledger on or off, so an
/// I/O failure is buffered here and surfaced at the next deterministic
/// checkpoint (`FleetRuntime::pump` / [`LedgerWriter::finish`])
/// instead of rerouting the event loop.
#[derive(Debug)]
pub struct LedgerWriter {
    dir: PathBuf,
    file: Option<fs::File>,
    /// Index of the open (or next) segment.
    seg_index: u64,
    /// Frame bytes written to the open segment (header excluded).
    seg_frame_bytes: u64,
    open_summary: SegmentSummary,
    sealed: Vec<SealedSegment>,
    records_total: u64,
    bytes_total: u64,
    /// First buffered I/O error; once set the writer goes inert.
    err: Option<String>,
    scratch: Vec<u8>,
    frame: Vec<u8>,
}

impl LedgerWriter {
    pub fn new(dir: PathBuf) -> Self {
        LedgerWriter {
            dir,
            file: None,
            seg_index: 0,
            seg_frame_bytes: 0,
            open_summary: SegmentSummary::empty(),
            sealed: Vec::new(),
            records_total: 0,
            bytes_total: 0,
            err: None,
            scratch: Vec::new(),
            frame: Vec::new(),
        }
    }

    /// Directory this writer appends into.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Records appended so far (across all segments).
    pub fn records_written(&self) -> u64 {
        self.records_total
    }

    /// Frame bytes appended so far (headers and footers excluded).
    pub fn bytes_written(&self) -> u64 {
        self.bytes_total
    }

    /// Append one record. Never fails; a write error is buffered and
    /// reported by [`LedgerWriter::check`] / [`LedgerWriter::finish`].
    pub fn append(&mut self, rec: &RetiredRecord) {
        if self.err.is_some() {
            return;
        }
        if let Err(e) = self.try_append(rec) {
            self.err = Some(format!("{e:#}"));
        }
    }

    fn try_append(&mut self, rec: &RetiredRecord) -> Result<()> {
        if self.file.is_none() {
            self.open_segment()?;
        }
        self.frame.clear();
        codec::encode_frame(rec, &mut self.scratch, &mut self.frame);
        let file = self.file.as_mut().expect("segment opened above");
        file.write_all(&self.frame).with_context(|| {
            format!("ledger: appending to {}", self.dir.join(segment_file_name(self.seg_index)).display())
        })?;
        self.open_summary.fold(rec);
        self.seg_frame_bytes += self.frame.len() as u64;
        self.bytes_total += self.frame.len() as u64;
        self.records_total += 1;
        if self.seg_frame_bytes >= SEGMENT_PAYLOAD_BYTES {
            self.seal_segment()?;
        }
        Ok(())
    }

    fn open_segment(&mut self) -> Result<()> {
        fs::create_dir_all(&self.dir)
            .with_context(|| format!("ledger: creating {}", self.dir.display()))?;
        let path = self.dir.join(segment_file_name(self.seg_index));
        // `create_new` refuses to clobber: pointing --ledger at a
        // directory that already holds a ledger is an error, not a
        // silent mix of two runs' histories.
        let mut file = fs::OpenOptions::new()
            .write(true)
            .create_new(true)
            .open(&path)
            .with_context(|| {
                format!(
                    "ledger: creating segment {} (directory already holds a ledger?)",
                    path.display()
                )
            })?;
        file.write_all(&MAGIC)?;
        file.write_all(&codec::SCHEMA_VERSION.to_le_bytes())?;
        self.file = Some(file);
        self.seg_frame_bytes = 0;
        self.open_summary = SegmentSummary::empty();
        Ok(())
    }

    fn seal_segment(&mut self) -> Result<()> {
        let mut file = self.file.take().expect("sealing requires an open segment");
        debug_assert!(self.open_summary.records > 0, "segments are created lazily");
        file.write_all(&self.open_summary.encode())?;
        file.sync_all().with_context(|| {
            format!("ledger: sealing {}", self.dir.join(segment_file_name(self.seg_index)).display())
        })?;
        self.sealed.push(SealedSegment {
            path: self.dir.join(segment_file_name(self.seg_index)),
            bytes: HEADER_LEN + self.seg_frame_bytes + FOOTER_LEN,
            summary: self.open_summary,
        });
        self.seg_index += 1;
        self.seg_frame_bytes = 0;
        self.open_summary = SegmentSummary::empty();
        Ok(())
    }

    /// Surface any buffered I/O error. Cheap (no syscalls); the
    /// runtime calls it once per pumped event.
    pub fn check(&self) -> Result<()> {
        match &self.err {
            Some(e) => bail!("ledger write failed: {e}"),
            None => Ok(()),
        }
    }

    /// Seal the open tail segment (and create the directory even when
    /// nothing was appended). After this the directory is a complete,
    /// openable ledger. Appending again after `finish` starts a new
    /// segment — sealing is a safe point, not a terminal state.
    pub fn finish(&mut self) -> Result<()> {
        self.check()?;
        if self.file.is_some() {
            if let Err(e) = self.seal_segment() {
                self.err = Some(format!("{e:#}"));
                return Err(e);
            }
        } else if self.sealed.is_empty() {
            fs::create_dir_all(&self.dir)
                .with_context(|| format!("ledger: creating {}", self.dir.display()))?;
        }
        Ok(())
    }
}

impl Auditable for LedgerWriter {
    fn component(&self) -> &'static str {
        "ledger"
    }

    /// Re-verify the sealed chain on disk: contiguous indices, file
    /// sizes, and footers that still decode to what was written.
    /// Footer-deep only (frame checksums are verified by every read
    /// path); with `--audit` this runs after every event, so it must
    /// stay O(segments), not O(records).
    fn audit(&self) -> Result<()> {
        self.check()?;
        for (i, seg) in self.sealed.iter().enumerate() {
            ensure!(
                seg.path.file_name().map(|n| n.to_string_lossy().into_owned())
                    == Some(segment_file_name(i as u64)),
                "sealed segment {i} is {}, breaking the chain",
                seg.path.display()
            );
            let meta = fs::metadata(&seg.path)
                .with_context(|| format!("ledger audit: {}", seg.path.display()))?;
            ensure!(
                meta.len() == seg.bytes,
                "{} is {} byte(s) on disk but {} were sealed",
                seg.path.display(),
                meta.len(),
                seg.bytes
            );
            let on_disk = read_footer(&seg.path)
                .with_context(|| format!("ledger audit: {}", seg.path.display()))?;
            ensure!(
                on_disk == seg.summary,
                "{} footer drifted from the sealed summary",
                seg.path.display()
            );
        }
        Ok(())
    }

    /// The writer is deliberately NOT registered with
    /// `FleetRuntime::auditables()`: runtime fingerprints must stay
    /// bit-identical with the ledger on or off. This impl hashes only
    /// the writer's own counters for standalone use.
    fn fingerprint(&self, h: &mut Fnv64) {
        h.write_u64(self.records_total);
        h.write_u64(self.bytes_total);
        h.write_u64(self.seg_index);
    }
}

fn read_footer(path: &Path) -> Result<SegmentSummary> {
    let mut file = fs::File::open(path)?;
    let len = file.metadata()?.len();
    ensure!(
        len >= HEADER_LEN + FOOTER_LEN,
        "segment is {len} byte(s), shorter than header + footer"
    );
    file.seek(SeekFrom::End(-(FOOTER_LEN as i64)))?;
    let mut buf = [0u8; FOOTER_LEN as usize];
    file.read_exact(&mut buf)?;
    SegmentSummary::decode(&buf)
}

// ---- read path ---------------------------------------------------------

/// One sealed segment as seen by the reader.
#[derive(Debug, Clone)]
pub struct SegmentMeta {
    pub path: PathBuf,
    /// Index parsed from the file name (contiguous per directory).
    pub index: u64,
    pub summary: SegmentSummary,
    /// Global ordinal of the segment's first record: segments are
    /// discovered in sorted path order and ordinals accumulate across
    /// them, giving every record a total-order tiebreaker that is
    /// stable for a given directory tree (sweep seed subdirectories
    /// sort in seed order by construction).
    pub first_ordinal: u64,
}

/// Read side: opens a ledger directory (recursively — a sweep writes
/// one subdirectory per seed), validates every footer, and serves
/// whole decoded segments to the query layer.
#[derive(Debug)]
pub struct LedgerStore {
    dir: PathBuf,
    segments: Vec<SegmentMeta>,
    records_total: u64,
}

impl LedgerStore {
    pub fn open(dir: &Path) -> Result<Self> {
        let mut files = Vec::new();
        collect_segment_files(dir, &mut files)
            .with_context(|| format!("opening ledger {}", dir.display()))?;
        let mut segments = Vec::with_capacity(files.len());
        let mut ordinal = 0u64;
        for path in files {
            let summary =
                read_footer(&path).with_context(|| format!("ledger segment {}", path.display()))?;
            let index = parse_segment_index(&path)?;
            segments.push(SegmentMeta { path, index, summary, first_ordinal: ordinal });
            ordinal += summary.records;
        }
        Ok(LedgerStore { dir: dir.to_path_buf(), segments, records_total: ordinal })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Sealed segments in path order (== ordinal order).
    pub fn segments(&self) -> &[SegmentMeta] {
        &self.segments
    }

    /// Total records across every segment (from footers; no frame I/O).
    pub fn records_total(&self) -> u64 {
        self.records_total
    }

    /// Decode one whole segment: every frame checksum-verified, the
    /// count and min/max ranges cross-checked against the footer.
    /// Returns `(global ordinal, record)` pairs in write order.
    pub fn read_segment(&self, seg: &SegmentMeta) -> Result<Vec<(u64, RetiredRecord)>> {
        let bytes =
            fs::read(&seg.path).with_context(|| format!("reading {}", seg.path.display()))?;
        ensure!(
            bytes.len() as u64 >= HEADER_LEN + FOOTER_LEN,
            "{} is shorter than header + footer",
            seg.path.display()
        );
        ensure!(bytes[..8] == MAGIC, "{} has a foreign magic header", seg.path.display());
        let version = u32::from_le_bytes(bytes[8..12].try_into().expect("4 bytes"));
        if version != codec::SCHEMA_VERSION {
            return Err(DecodeError::UnknownVersion { found: version })
                .with_context(|| format!("reading {}", seg.path.display()));
        }
        let frames = &bytes[HEADER_LEN as usize..bytes.len() - FOOTER_LEN as usize];
        let mut out = Vec::with_capacity(seg.summary.records as usize);
        let mut pos = 0usize;
        let mut check = SegmentSummary::empty();
        while pos < frames.len() {
            let (rec, used) = codec::decode_frame(&frames[pos..]).with_context(|| {
                format!("{} at frame offset {pos}", seg.path.display())
            })?;
            check.fold(&rec);
            out.push((seg.first_ordinal + (check.records - 1), rec));
            pos += used;
        }
        ensure!(
            check == seg.summary,
            "{}: decoded frames disagree with the sealed footer \
             ({} record(s) decoded, footer claims {})",
            seg.path.display(),
            check.records,
            seg.summary.records
        );
        Ok(out)
    }

    /// Every record in the ledger, in ordinal (write/path) order.
    pub fn read_all(&self) -> Result<Vec<(u64, RetiredRecord)>> {
        let mut out = Vec::with_capacity(self.records_total as usize);
        for seg in &self.segments {
            out.extend(self.read_segment(seg)?);
        }
        Ok(out)
    }
}

impl Auditable for LedgerStore {
    fn component(&self) -> &'static str {
        "ledger"
    }

    /// Deep verification: segment-chain continuity per directory,
    /// footer/record agreement, and every frame checksum (via
    /// [`LedgerStore::read_segment`]). O(ledger bytes) — the offline
    /// counterpart of the writer's O(segments) audit.
    fn audit(&self) -> Result<()> {
        let mut prev_dir: Option<&Path> = None;
        let mut expect = 0u64;
        for seg in &self.segments {
            let parent = seg.path.parent().unwrap_or(Path::new(""));
            if prev_dir != Some(parent) {
                prev_dir = Some(parent);
                expect = 0;
            }
            ensure!(
                seg.index == expect,
                "{}: expected chain index {expect}, found {} (missing segment?)",
                seg.path.display(),
                seg.index
            );
            expect += 1;
            self.read_segment(seg)?;
        }
        Ok(())
    }

    /// Content digest: every record's frame-level identity, in ordinal
    /// order. Two ledgers fingerprint equal iff their decoded record
    /// streams are bit-identical.
    fn fingerprint(&self, h: &mut Fnv64) {
        h.write_u64(self.records_total);
        for seg in &self.segments {
            h.write_u64(seg.summary.records);
            h.write_u64(seg.summary.min_job);
            h.write_u64(seg.summary.max_job);
            h.write_u64(seg.summary.min_retired_ns);
            h.write_u64(seg.summary.max_retired_ns);
        }
    }
}

/// Recursive sorted walk collecting `*.seg` files. Sorting is by file
/// name at each level (directories and files interleaved), so a sweep
/// ledger's zero-padded `seed-...` subdirectories enumerate in seed
/// order at any worker count.
fn collect_segment_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)
        .with_context(|| format!("reading dir {}", dir.display()))?
        .map(|e| e.map(|e| e.path()))
        .collect::<std::io::Result<_>>()?;
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_segment_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "seg") {
            out.push(path);
        }
    }
    Ok(())
}

fn parse_segment_index(path: &Path) -> Result<u64> {
    let name = path.file_name().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default();
    let digits = name
        .strip_prefix("seg-")
        .and_then(|s| s.strip_suffix(".seg"))
        .with_context(|| format!("{name:?} is not a seg-NNNNNNNN.seg segment file"))?;
    digits.parse::<u64>().with_context(|| format!("{name:?} has a non-numeric segment index"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::{JobId, JobReport, JobState};
    use crate::sim::SimTime;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("stannis_ledger_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn record(i: u64) -> RetiredRecord {
        RetiredRecord {
            retired_at: SimTime(1_000_000 * (i + 1)),
            report: JobReport {
                id: JobId(i),
                state: if i % 3 == 0 { JobState::Cancelled } else { JobState::Completed },
                network: "squeezenet".into(),
                devices: vec![i as usize % 4, 7],
                held_host: false,
                bs_csd: 50,
                bs_host: 0,
                steps_done: 10,
                steps_per_epoch: 5,
                images: 500,
                submitted_at: SimTime(i),
                admitted_at: SimTime(i * 2),
                finished_at: SimTime(1_000_000 * (i + 1)),
                queue_wait: SimTime(i),
                elapsed: SimTime(999_999),
                images_per_sec: 10.5 + i as f64,
                sync_fraction: 0.25,
                energy_j: 3.75 * (i + 1) as f64,
                j_per_image: 0.007_5,
                link_bytes: 1 << 20,
                bytes_moved: 0,
                images_moved: 0,
                lock_wait: SimTime(0),
                retunes: 0,
                drained: false,
                crashed: i % 5 == 0,
                lost_steps: 0,
                checkpoint_bytes: 0,
            },
        }
    }

    #[test]
    fn writes_rotate_seal_and_read_back() {
        let dir = tmp_dir("roundtrip");
        let mut w = LedgerWriter::new(dir.clone());
        // ~200 B/frame: 3000 records ≈ 600 KB spans ≥ 2 segments.
        let n = 3000u64;
        for i in 0..n {
            w.append(&record(i));
        }
        w.check().expect("no buffered error");
        w.finish().expect("seals");
        w.audit().expect("sealed chain audits clean");
        assert_eq!(w.records_written(), n);

        let store = LedgerStore::open(&dir).expect("opens");
        assert!(store.segments().len() >= 2, "rotation must have produced segments");
        assert_eq!(store.records_total(), n);
        store.audit().expect("deep audit passes");
        let all = store.read_all().expect("reads");
        assert_eq!(all.len(), n as usize);
        for (i, (ordinal, rec)) in all.iter().enumerate() {
            assert_eq!(*ordinal, i as u64, "ordinals are the write order");
            assert_eq!(*rec, record(i as u64), "decode is bit-exact");
        }
        // Footer ranges really bound their segment's contents.
        for seg in store.segments() {
            let recs = store.read_segment(seg).unwrap();
            assert_eq!(recs.len() as u64, seg.summary.records);
            assert!(recs
                .iter()
                .all(|(_, r)| (seg.summary.min_retired_ns..=seg.summary.max_retired_ns)
                    .contains(&r.retired_at.as_ns())));
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_finish_leaves_an_openable_ledger() {
        let dir = tmp_dir("empty");
        let mut w = LedgerWriter::new(dir.clone());
        w.finish().expect("finishing an empty writer still creates the dir");
        let store = LedgerStore::open(&dir).expect("empty ledger opens");
        assert_eq!(store.records_total(), 0);
        assert!(store.read_all().unwrap().is_empty());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn unsealed_tail_and_corruption_fail_open() {
        let dir = tmp_dir("corrupt");
        let mut w = LedgerWriter::new(dir.clone());
        for i in 0..5 {
            w.append(&record(i));
        }
        // No finish(): the tail segment has no footer.
        drop(w);
        let err = format!("{:#}", LedgerStore::open(&dir).unwrap_err());
        assert!(err.contains("sentinel"), "got: {err}");

        // Seal properly, then flip a frame byte: open() still succeeds
        // (footers are fine) but reading the segment fails on checksum.
        let mut w = LedgerWriter::new(tmp_dir("corrupt2"));
        for i in 0..5 {
            w.append(&record(i));
        }
        w.finish().unwrap();
        let dir2 = w.dir().to_path_buf();
        let seg = dir2.join(segment_file_name(0));
        let mut bytes = fs::read(&seg).unwrap();
        bytes[HEADER_LEN as usize + 9] ^= 0x01;
        fs::write(&seg, bytes).unwrap();
        let store = LedgerStore::open(&dir2).expect("footers still valid");
        let err = format!("{:#}", store.read_segment(&store.segments()[0]).unwrap_err());
        assert!(err.contains("checksum"), "got: {err}");
        assert!(store.audit().is_err(), "deep audit must catch the flipped byte");
        let _ = fs::remove_dir_all(&dir);
        let _ = fs::remove_dir_all(&dir2);
    }

    #[test]
    fn refuses_to_clobber_an_existing_ledger() {
        let dir = tmp_dir("clobber");
        let mut w = LedgerWriter::new(dir.clone());
        w.append(&record(0));
        w.finish().unwrap();
        let mut w2 = LedgerWriter::new(dir.clone());
        w2.append(&record(1));
        let err = format!("{:#}", w2.check().unwrap_err());
        assert!(err.contains("already holds a ledger"), "got: {err}");
        let _ = fs::remove_dir_all(&dir);
    }
}
