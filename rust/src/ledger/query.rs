//! Validated filter language + keyset pagination over a ledger
//! (DESIGN.md §Ledger).
//!
//! The pipeline is split lex → parse → validate → plan: the lexer and
//! parser know nothing about the schema (they produce a raw tree of
//! `IDENT op value` comparisons under `and`/`or`), validation binds
//! identifiers to typed [`Field`]s and rejects nonsense (`state > 3`,
//! `crashed = banana`) with byte-positioned errors, and planning
//! extracts `retired_at` bounds so footer metadata can prune whole
//! segments before any frame is decoded.
//!
//! Results are totally ordered by `(retire_time, job_id, ordinal)` —
//! the ordinal (global write position) breaks ties between identical
//! `(time, job)` keys that a merged multi-seed sweep ledger can
//! legally contain. Cursors encode that full key, checksummed, in a
//! URL-safe base64 alphabet: any page size walks the same ordering
//! with no duplicates or gaps, and a truncated or doctored cursor is
//! a typed error rather than a silent reposition.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{bail, ensure, Context};

use crate::analysis::audit::Fnv64;
use crate::fleet::{JobState, RetiredRecord};
use crate::metrics::percentile;
use crate::util::json::Json;
use crate::Result;

use super::store::{LedgerStore, SegmentMeta};

// ---- schema ------------------------------------------------------------

/// Typed fields the filter language can reference.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Field {
    /// Terminal job state: `queued | running | done | cancelled`.
    State,
    /// Whether the job's chain ever crashed.
    Crashed,
    /// Whether the job was drained off a retiring device.
    Drained,
    /// Total energy in joules.
    EnergyJ,
    /// Queue wait in seconds.
    QueueWaitS,
    /// CSD index: matches if the job held that device.
    Device,
    /// Retirement time in seconds. Comparisons on this field prune
    /// segments via footer min/max before any frame is read.
    RetiredAt,
}

impl Field {
    fn parse(name: &str) -> Option<Field> {
        match name {
            "state" => Some(Field::State),
            "crashed" => Some(Field::Crashed),
            "drained" => Some(Field::Drained),
            "energy_j" => Some(Field::EnergyJ),
            "queue_wait_s" => Some(Field::QueueWaitS),
            "device" => Some(Field::Device),
            "retired_at" => Some(Field::RetiredAt),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Field::State => "state",
            Field::Crashed => "crashed",
            Field::Drained => "drained",
            Field::EnergyJ => "energy_j",
            Field::QueueWaitS => "queue_wait_s",
            Field::Device => "device",
            Field::RetiredAt => "retired_at",
        }
    }

    fn is_numeric(&self) -> bool {
        matches!(self, Field::EnergyJ | Field::QueueWaitS | Field::RetiredAt)
    }

    /// Numeric projection used by comparisons and aggregates.
    fn numeric(&self, rec: &RetiredRecord) -> f64 {
        match self {
            Field::EnergyJ => rec.report.energy_j,
            Field::QueueWaitS => rec.report.queue_wait.as_secs_f64(),
            Field::RetiredAt => rec.retired_at.as_secs_f64(),
            _ => unreachable!("validation admits only numeric fields here"),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpOp {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

impl CmpOp {
    fn holds_f64(&self, lhs: f64, rhs: f64) -> bool {
        match self {
            CmpOp::Eq => lhs == rhs,
            CmpOp::Ne => lhs != rhs,
            CmpOp::Lt => lhs < rhs,
            CmpOp::Le => lhs <= rhs,
            CmpOp::Gt => lhs > rhs,
            CmpOp::Ge => lhs >= rhs,
        }
    }

    fn text(&self) -> &'static str {
        match self {
            CmpOp::Eq => "=",
            CmpOp::Ne => "!=",
            CmpOp::Lt => "<",
            CmpOp::Le => "<=",
            CmpOp::Gt => ">",
            CmpOp::Ge => ">=",
        }
    }
}

// ---- lexer -------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Ident(String),
    Num(f64),
    Op(CmpOp),
    LParen,
    RParen,
    And,
    Or,
}

struct Lexed {
    tok: Tok,
    /// Byte offset in the source expression, for error messages.
    at: usize,
}

fn lex(src: &str) -> Result<Vec<Lexed>> {
    let bytes = src.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < bytes.len() {
        let c = bytes[i];
        match c {
            b' ' | b'\t' | b'\n' | b'\r' => i += 1,
            b'(' => {
                out.push(Lexed { tok: Tok::LParen, at: i });
                i += 1;
            }
            b')' => {
                out.push(Lexed { tok: Tok::RParen, at: i });
                i += 1;
            }
            b'=' => {
                // Accept both `=` and `==`.
                let len = if bytes.get(i + 1) == Some(&b'=') { 2 } else { 1 };
                out.push(Lexed { tok: Tok::Op(CmpOp::Eq), at: i });
                i += len;
            }
            b'!' => {
                ensure!(
                    bytes.get(i + 1) == Some(&b'='),
                    "byte {i}: lone `!` (use `!=`)"
                );
                out.push(Lexed { tok: Tok::Op(CmpOp::Ne), at: i });
                i += 2;
            }
            b'<' => {
                let (op, len) =
                    if bytes.get(i + 1) == Some(&b'=') { (CmpOp::Le, 2) } else { (CmpOp::Lt, 1) };
                out.push(Lexed { tok: Tok::Op(op), at: i });
                i += len;
            }
            b'>' => {
                let (op, len) =
                    if bytes.get(i + 1) == Some(&b'=') { (CmpOp::Ge, 2) } else { (CmpOp::Gt, 1) };
                out.push(Lexed { tok: Tok::Op(op), at: i });
                i += len;
            }
            b'-' | b'0'..=b'9' | b'.' => {
                let start = i;
                i += 1;
                while i < bytes.len()
                    && matches!(bytes[i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
                {
                    // `+`/`-` only continue a number right after an exponent marker.
                    if matches!(bytes[i], b'+' | b'-')
                        && !matches!(bytes[i - 1], b'e' | b'E')
                    {
                        break;
                    }
                    i += 1;
                }
                let text = &src[start..i];
                let n: f64 = text
                    .parse()
                    .with_context(|| format!("byte {start}: bad number {text:?}"))?;
                ensure!(n.is_finite(), "byte {start}: number {text:?} is not finite");
                out.push(Lexed { tok: Tok::Num(n), at: start });
            }
            b'a'..=b'z' | b'A'..=b'Z' | b'_' => {
                let start = i;
                while i < bytes.len()
                    && matches!(bytes[i], b'a'..=b'z' | b'A'..=b'Z' | b'0'..=b'9' | b'_')
                {
                    i += 1;
                }
                let word = &src[start..i];
                let tok = match word {
                    "and" | "AND" => Tok::And,
                    "or" | "OR" => Tok::Or,
                    _ => Tok::Ident(word.to_string()),
                };
                out.push(Lexed { tok, at: start });
            }
            _ => bail!("byte {i}: unexpected character {:?}", src[i..].chars().next().unwrap()),
        }
    }
    Ok(out)
}

// ---- raw parse ---------------------------------------------------------

/// Untyped comparison as parsed: identifier, operator, and either a
/// numeric or bareword right-hand side. Validation types it.
#[derive(Debug)]
enum RawValue {
    Num(f64),
    Word(String),
}

#[derive(Debug)]
enum RawExpr {
    Cmp { ident: String, at: usize, op: CmpOp, value: RawValue },
    And(Box<RawExpr>, Box<RawExpr>),
    Or(Box<RawExpr>, Box<RawExpr>),
}

struct Parser {
    toks: Vec<Lexed>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos).map(|l| &l.tok)
    }

    fn at(&self) -> usize {
        self.toks.get(self.pos).map(|l| l.at).unwrap_or(usize::MAX)
    }

    fn next(&mut self) -> Option<Lexed> {
        let l = self.toks.get(self.pos).map(|l| Lexed { tok: l.tok.clone(), at: l.at });
        self.pos += 1;
        l
    }

    // Grammar: expr := and_chain ('or' and_chain)*
    //          and_chain := atom ('and' atom)*
    //          atom := '(' expr ')' | IDENT OP (NUM | IDENT)
    fn expr(&mut self) -> Result<RawExpr> {
        let mut lhs = self.and_chain()?;
        while self.peek() == Some(&Tok::Or) {
            self.pos += 1;
            let rhs = self.and_chain()?;
            lhs = RawExpr::Or(Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn and_chain(&mut self) -> Result<RawExpr> {
        let mut lhs = self.atom()?;
        while self.peek() == Some(&Tok::And) {
            self.pos += 1;
            let rhs = self.atom()?;
            lhs = RawExpr::And(Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn atom(&mut self) -> Result<RawExpr> {
        match self.next() {
            Some(Lexed { tok: Tok::LParen, at }) => {
                let inner = self.expr()?;
                match self.next() {
                    Some(Lexed { tok: Tok::RParen, .. }) => Ok(inner),
                    _ => bail!("byte {at}: unclosed `(`"),
                }
            }
            Some(Lexed { tok: Tok::Ident(ident), at }) => {
                let op = match self.next() {
                    Some(Lexed { tok: Tok::Op(op), .. }) => op,
                    _ => bail!("byte {at}: expected a comparison after {ident:?}"),
                };
                let value = match self.next() {
                    Some(Lexed { tok: Tok::Num(n), .. }) => RawValue::Num(n),
                    Some(Lexed { tok: Tok::Ident(w), .. }) => RawValue::Word(w),
                    _ => bail!("byte {at}: expected a value after {ident:?} {}", op.text()),
                };
                Ok(RawExpr::Cmp { ident, at, op, value })
            }
            Some(Lexed { tok, at }) => bail!("byte {at}: expected a predicate, found {tok:?}"),
            None => bail!("unexpected end of expression"),
        }
    }

    fn finish(mut self) -> Result<RawExpr> {
        ensure!(self.pos < self.toks.len() || !self.toks.is_empty(), "empty expression");
        let e = self.expr()?;
        ensure!(
            self.pos == self.toks.len(),
            "byte {}: trailing input after a complete expression",
            self.at()
        );
        Ok(e)
    }
}

// ---- validation --------------------------------------------------------

/// Typed predicate after validation.
#[derive(Debug, Clone, PartialEq)]
pub enum Pred {
    /// `state =/!= <queued|running|done|cancelled>`
    State { eq: bool, value: JobState },
    /// `crashed|drained =/!= true|false`
    Bool { field: Field, eq: bool, value: bool },
    /// `energy_j|queue_wait_s|retired_at <op> NUM`
    Num { field: Field, op: CmpOp, value: f64 },
    /// `device =/!= N` — membership over the job's device set.
    Device { eq: bool, value: usize },
}

/// Validated, evaluable filter expression.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    Pred(Pred),
    And(Box<Expr>, Box<Expr>),
    Or(Box<Expr>, Box<Expr>),
}

fn state_value(word: &str) -> Option<JobState> {
    match word {
        "queued" => Some(JobState::Queued),
        "running" => Some(JobState::Running),
        "done" => Some(JobState::Completed),
        "cancelled" => Some(JobState::Cancelled),
        _ => None,
    }
}

fn validate(raw: RawExpr) -> Result<Expr> {
    Ok(match raw {
        RawExpr::And(a, b) => Expr::And(Box::new(validate(*a)?), Box::new(validate(*b)?)),
        RawExpr::Or(a, b) => Expr::Or(Box::new(validate(*a)?), Box::new(validate(*b)?)),
        RawExpr::Cmp { ident, at, op, value } => {
            let field = Field::parse(&ident).with_context(|| {
                format!(
                    "byte {at}: unknown field {ident:?} (expected one of state, crashed, \
                     drained, energy_j, queue_wait_s, device, retired_at)"
                )
            })?;
            let eq = match (field.is_numeric(), op) {
                (true, _) => true, // numeric fields accept every operator
                (false, CmpOp::Eq) => true,
                (false, CmpOp::Ne) => false,
                (false, op) => bail!(
                    "byte {at}: {} does not support `{}` (only `=`/`!=`)",
                    field.name(),
                    op.text()
                ),
            };
            let pred = match field {
                Field::State => match value {
                    RawValue::Word(w) => Pred::State {
                        eq,
                        value: state_value(&w).with_context(|| {
                            format!(
                                "byte {at}: bad state {w:?} (expected queued, running, \
                                 done, or cancelled)"
                            )
                        })?,
                    },
                    RawValue::Num(n) => bail!("byte {at}: state compares to a name, not {n}"),
                },
                Field::Crashed | Field::Drained => match value {
                    RawValue::Word(w) => Pred::Bool {
                        field,
                        eq,
                        value: match w.as_str() {
                            "true" => true,
                            "false" => false,
                            _ => bail!("byte {at}: {} compares to true/false, not {w:?}", field.name()),
                        },
                    },
                    RawValue::Num(n) => {
                        bail!("byte {at}: {} compares to true/false, not {n}", field.name())
                    }
                },
                Field::Device => match value {
                    RawValue::Num(n) => {
                        ensure!(
                            n >= 0.0 && n.fract() == 0.0,
                            "byte {at}: device index must be a non-negative integer, got {n}"
                        );
                        Pred::Device { eq, value: n as usize }
                    }
                    RawValue::Word(w) => bail!("byte {at}: device compares to an index, not {w:?}"),
                },
                Field::EnergyJ | Field::QueueWaitS | Field::RetiredAt => match value {
                    RawValue::Num(n) => Pred::Num { field, op, value: n },
                    RawValue::Word(w) => {
                        bail!("byte {at}: {} compares to a number, not {w:?}", field.name())
                    }
                },
            };
            Expr::Pred(pred)
        }
    })
}

/// Lex, parse, and validate a filter expression.
pub fn compile(src: &str) -> Result<Expr> {
    let toks = lex(src).with_context(|| format!("in filter {src:?}"))?;
    ensure!(!toks.is_empty(), "empty filter expression");
    let raw = Parser { toks, pos: 0 }.finish().with_context(|| format!("in filter {src:?}"))?;
    validate(raw).with_context(|| format!("in filter {src:?}"))
}

/// Evaluate a compiled filter against one record.
pub fn eval(expr: &Expr, rec: &RetiredRecord) -> bool {
    match expr {
        Expr::And(a, b) => eval(a, rec) && eval(b, rec),
        Expr::Or(a, b) => eval(a, rec) || eval(b, rec),
        Expr::Pred(p) => match p {
            Pred::State { eq, value } => (rec.report.state == *value) == *eq,
            Pred::Bool { field, eq, value } => {
                let got = match field {
                    Field::Crashed => rec.report.crashed,
                    Field::Drained => rec.report.drained,
                    _ => unreachable!("validation admits only crashed/drained here"),
                };
                (got == *value) == *eq
            }
            Pred::Num { field, op, value } => op.holds_f64(field.numeric(rec), *value),
            Pred::Device { eq, value } => rec.report.devices.contains(value) == *eq,
        },
    }
}

// ---- planning: segment pruning -----------------------------------------

/// `retired_at` bounds (seconds) implied by a filter: a record can
/// only match if its retire time lies in `[lo, hi]`. `and` intersects,
/// `or` unions, and any predicate not on `retired_at` contributes
/// `(-inf, +inf)` — conservative, never wrong.
pub fn retired_at_bounds(expr: &Expr) -> (f64, f64) {
    match expr {
        Expr::And(a, b) => {
            let (alo, ahi) = retired_at_bounds(a);
            let (blo, bhi) = retired_at_bounds(b);
            (alo.max(blo), ahi.min(bhi))
        }
        Expr::Or(a, b) => {
            let (alo, ahi) = retired_at_bounds(a);
            let (blo, bhi) = retired_at_bounds(b);
            (alo.min(blo), ahi.max(bhi))
        }
        Expr::Pred(Pred::Num { field: Field::RetiredAt, op, value }) => match op {
            CmpOp::Eq => (*value, *value),
            CmpOp::Lt | CmpOp::Le => (f64::NEG_INFINITY, *value),
            CmpOp::Gt | CmpOp::Ge => (*value, f64::INFINITY),
            CmpOp::Ne => (f64::NEG_INFINITY, f64::INFINITY),
        },
        Expr::Pred(_) => (f64::NEG_INFINITY, f64::INFINITY),
    }
}

/// Whether footer metadata alone rules the segment out for this
/// filter/cursor combination. u64 ns → f64 s conversion is monotone,
/// so comparing converted bounds needs no epsilon slop: a pruned
/// segment provably contains no matching record.
fn segment_pruned(seg: &SegmentMeta, bounds: (f64, f64), after: Option<&Key>) -> bool {
    let seg_lo = crate::sim::SimTime(seg.summary.min_retired_ns).as_secs_f64();
    let seg_hi = crate::sim::SimTime(seg.summary.max_retired_ns).as_secs_f64();
    if seg_lo > bounds.1 || seg_hi < bounds.0 {
        return true;
    }
    // Keyset resume: a segment whose newest record is older than the
    // cursor position cannot contribute.
    if let Some(k) = after {
        if seg.summary.max_retired_ns < k.retired_ns {
            return true;
        }
    }
    false
}

// ---- keyset cursors ----------------------------------------------------

/// Total-order key for pagination: `(retire_time, job_id, ordinal)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Key {
    pub retired_ns: u64,
    pub job: u64,
    /// Global write position — breaks ties when a merged sweep ledger
    /// holds the same `(time, job)` pair under several seeds.
    pub ordinal: u64,
}

impl Key {
    pub fn of(ordinal: u64, rec: &RetiredRecord) -> Key {
        Key { retired_ns: rec.retired_at.as_ns(), job: rec.report.id.0, ordinal }
    }
}

const CURSOR_ALPHABET: &[u8; 64] =
    b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789-_";

fn b64_encode(bytes: &[u8]) -> String {
    let mut out = String::with_capacity(bytes.len().div_ceil(3) * 4);
    for chunk in bytes.chunks(3) {
        let b = [chunk[0], *chunk.get(1).unwrap_or(&0), *chunk.get(2).unwrap_or(&0)];
        let n = (u32::from(b[0]) << 16) | (u32::from(b[1]) << 8) | u32::from(b[2]);
        let chars = [(n >> 18) & 63, (n >> 12) & 63, (n >> 6) & 63, n & 63];
        for (i, c) in chars.iter().enumerate() {
            if i <= chunk.len() {
                out.push(CURSOR_ALPHABET[*c as usize] as char);
            }
        }
    }
    out
}

fn b64_decode(src: &str) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(src.len() * 3 / 4);
    let mut acc = 0u32;
    let mut bits = 0u32;
    for ch in src.bytes() {
        let v = CURSOR_ALPHABET
            .iter()
            .position(|&a| a == ch)
            .with_context(|| format!("cursor contains invalid character {:?}", ch as char))?;
        acc = (acc << 6) | v as u32;
        bits += 6;
        if bits >= 8 {
            bits -= 8;
            out.push((acc >> bits) as u8);
        }
    }
    Ok(out)
}

/// Serialize a pagination key: 24 LE payload bytes + 8-byte FNV-1a
/// checksum, base64 over the URL-safe alphabet.
pub fn encode_cursor(key: &Key) -> String {
    let mut bytes = Vec::with_capacity(32);
    bytes.extend_from_slice(&key.retired_ns.to_le_bytes());
    bytes.extend_from_slice(&key.job.to_le_bytes());
    bytes.extend_from_slice(&key.ordinal.to_le_bytes());
    let mut h = Fnv64::new();
    h.write_bytes(&bytes);
    let sum = h.finish();
    bytes.extend_from_slice(&sum.to_le_bytes());
    b64_encode(&bytes)
}

pub fn decode_cursor(src: &str) -> Result<Key> {
    let bytes = b64_decode(src.trim())?;
    ensure!(bytes.len() == 32, "cursor decodes to {} byte(s), expected 32", bytes.len());
    let word = |at: usize| u64::from_le_bytes(bytes[at..at + 8].try_into().expect("8 bytes"));
    let mut h = Fnv64::new();
    h.write_bytes(&bytes[..24]);
    let want = word(24);
    ensure!(h.finish() == want, "cursor checksum mismatch (truncated or edited cursor)");
    Ok(Key { retired_ns: word(0), job: word(8), ordinal: word(16) })
}

// ---- paging ------------------------------------------------------------

/// One page of query results in `(retire_time, job_id, ordinal)` order.
#[derive(Debug)]
pub struct QueryPage {
    pub records: Vec<(Key, RetiredRecord)>,
    /// Cursor for the page after this one; `None` at the end.
    pub next: Option<String>,
}

/// Scan the ledger for records matching `filter` (all records when
/// `None`), skip anything at or before `after`, and return the first
/// `limit` in key order plus a resume cursor.
///
/// Implementation: a capacity-limited [`BTreeMap`] selection. Segments
/// are visited in write order and each is pruned by footer when the
/// filter bounds or the cursor allow; matching records enter the map
/// and the largest key is evicted once it holds `limit + 1` entries —
/// memory stays O(limit) regardless of ledger size, and keeping one
/// extra entry tells us whether a next page exists without a second
/// scan. No global sort order across segments is assumed (a merged
/// sweep ledger interleaves seed streams).
pub fn page(
    store: &LedgerStore,
    filter: Option<&Expr>,
    after: Option<Key>,
    limit: usize,
) -> Result<QueryPage> {
    ensure!(limit > 0, "page limit must be at least 1");
    let bounds =
        filter.map(retired_at_bounds).unwrap_or((f64::NEG_INFINITY, f64::INFINITY));
    let mut best: BTreeMap<Key, RetiredRecord> = BTreeMap::new();
    let mut overflow = false;
    for seg in store.segments() {
        if segment_pruned(seg, bounds, after.as_ref()) {
            continue;
        }
        for (ordinal, rec) in store.read_segment(seg)? {
            let key = Key::of(ordinal, &rec);
            if let Some(a) = &after {
                if key <= *a {
                    continue;
                }
            }
            if let Some(f) = filter {
                if !eval(f, &rec) {
                    continue;
                }
            }
            if best.len() == limit + 1 {
                let worst = *best.last_key_value().expect("non-empty").0;
                if key >= worst {
                    continue;
                }
                best.pop_last();
                overflow = true;
            }
            best.insert(key, rec);
            if best.len() > limit + 1 {
                best.pop_last();
                overflow = true;
            }
        }
    }
    if best.len() > limit {
        best.pop_last();
        overflow = true;
    }
    let records: Vec<(Key, RetiredRecord)> = best.into_iter().collect();
    let next = if overflow {
        records.last().map(|(k, _)| encode_cursor(k))
    } else {
        None
    };
    Ok(QueryPage { records, next })
}

// ---- aggregates --------------------------------------------------------

/// Aggregate projections over the matching record set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Agg {
    Count,
    Sum(Field),
    P50(Field),
    P99(Field),
}

/// Parse an `--agg` spec: `count`, `sum:FIELD`, `p50:FIELD`,
/// `p99:FIELD` — FIELD must be numeric.
pub fn parse_agg(src: &str) -> Result<Agg> {
    if src == "count" {
        return Ok(Agg::Count);
    }
    let (kind, field) = src
        .split_once(':')
        .with_context(|| format!("bad aggregate {src:?} (expected count, sum:F, p50:F, p99:F)"))?;
    let f = Field::parse(field).with_context(|| format!("unknown aggregate field {field:?}"))?;
    ensure!(
        f.is_numeric(),
        "aggregate field {} is not numeric (use energy_j, queue_wait_s, or retired_at)",
        f.name()
    );
    match kind {
        "sum" => Ok(Agg::Sum(f)),
        "p50" => Ok(Agg::P50(f)),
        "p99" => Ok(Agg::P99(f)),
        _ => bail!("bad aggregate kind {kind:?} (expected sum, p50, or p99)"),
    }
}

fn agg_label(agg: &Agg) -> String {
    match agg {
        Agg::Count => "count".into(),
        Agg::Sum(f) => format!("sum:{}", f.name()),
        Agg::P50(f) => format!("p50:{}", f.name()),
        Agg::P99(f) => format!("p99:{}", f.name()),
    }
}

/// Single pruned scan computing every requested aggregate over the
/// records matching `filter`. Sums accumulate in scan (ordinal) order,
/// so a sweep ledger's `sum:energy_j` is bitwise-equal to the ordered
/// `FleetTotals::absorb` accumulation for the same records.
pub fn aggregate(
    store: &LedgerStore,
    filter: Option<&Expr>,
    aggs: &[Agg],
) -> Result<Vec<(String, f64)>> {
    ensure!(!aggs.is_empty(), "no aggregates requested");
    let bounds =
        filter.map(retired_at_bounds).unwrap_or((f64::NEG_INFINITY, f64::INFINITY));
    let mut count = 0u64;
    let mut sums: BTreeMap<usize, f64> = BTreeMap::new();
    let mut samples: BTreeMap<usize, Vec<f64>> = BTreeMap::new();
    for (i, agg) in aggs.iter().enumerate() {
        match agg {
            Agg::Count => {}
            Agg::Sum(_) => {
                sums.insert(i, 0.0);
            }
            Agg::P50(_) | Agg::P99(_) => {
                samples.insert(i, Vec::new());
            }
        }
    }
    for seg in store.segments() {
        if segment_pruned(seg, bounds, None) {
            continue;
        }
        for (_, rec) in store.read_segment(seg)? {
            if let Some(f) = filter {
                if !eval(f, &rec) {
                    continue;
                }
            }
            count += 1;
            for (i, agg) in aggs.iter().enumerate() {
                match agg {
                    Agg::Count => {}
                    Agg::Sum(f) => *sums.get_mut(&i).expect("seeded above") += f.numeric(&rec),
                    Agg::P50(f) | Agg::P99(f) => {
                        samples.get_mut(&i).expect("seeded above").push(f.numeric(&rec))
                    }
                }
            }
        }
    }
    let mut out = Vec::with_capacity(aggs.len());
    for (i, agg) in aggs.iter().enumerate() {
        let value = match agg {
            Agg::Count => count as f64,
            Agg::Sum(_) => sums[&i],
            Agg::P50(_) | Agg::P99(_) => {
                let mut v = samples[&i].clone();
                v.sort_by(f64::total_cmp);
                let p = if matches!(agg, Agg::P50(_)) { 0.50 } else { 0.99 };
                percentile(&v, p)
            }
        };
        out.push((agg_label(agg), value));
    }
    Ok(out)
}

// ---- JSON projection ---------------------------------------------------

/// Project a record to a [`Json`] object for `stannis query --json`
/// line output. Field names match the filter language where the two
/// overlap.
pub fn record_json(rec: &RetiredRecord) -> Json {
    let mut o: BTreeMap<String, Json> = BTreeMap::new();
    let r = &rec.report;
    o.insert("job".into(), Json::Num(r.id.0 as f64));
    o.insert("state".into(), Json::Str(r.state.to_string()));
    o.insert("network".into(), Json::Str(r.network.clone()));
    o.insert(
        "devices".into(),
        Json::Arr(r.devices.iter().map(|d| Json::Num(*d as f64)).collect()),
    );
    o.insert("retired_at".into(), Json::Num(rec.retired_at.as_secs_f64()));
    o.insert("queue_wait_s".into(), Json::Num(r.queue_wait.as_secs_f64()));
    o.insert("elapsed_s".into(), Json::Num(r.elapsed.as_secs_f64()));
    o.insert("images".into(), Json::Num(r.images as f64));
    o.insert("images_per_sec".into(), Json::Num(r.images_per_sec));
    o.insert("energy_j".into(), Json::Num(r.energy_j));
    o.insert("j_per_image".into(), Json::Num(r.j_per_image));
    o.insert("crashed".into(), Json::Bool(r.crashed));
    o.insert("drained".into(), Json::Bool(r.drained));
    o.insert("lost_steps".into(), Json::Num(r.lost_steps as f64));
    o.insert("retunes".into(), Json::Num(r.retunes as f64));
    Json::Obj(o)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::{JobId, JobReport};
    use crate::sim::SimTime;

    fn rec(job: u64, retired_s: f64, energy: f64, crashed: bool) -> RetiredRecord {
        RetiredRecord {
            retired_at: SimTime::from_secs_f64(retired_s),
            report: JobReport {
                id: JobId(job),
                state: if crashed { JobState::Cancelled } else { JobState::Completed },
                network: "n".into(),
                devices: vec![job as usize % 3],
                held_host: false,
                bs_csd: 1,
                bs_host: 0,
                steps_done: 1,
                steps_per_epoch: 1,
                images: 1,
                submitted_at: SimTime(0),
                admitted_at: SimTime(0),
                finished_at: SimTime::from_secs_f64(retired_s),
                queue_wait: SimTime::from_secs_f64(retired_s / 10.0),
                elapsed: SimTime(1),
                images_per_sec: 1.0,
                sync_fraction: 0.0,
                energy_j: energy,
                j_per_image: energy,
                link_bytes: 0,
                bytes_moved: 0,
                images_moved: 0,
                lock_wait: SimTime(0),
                retunes: 0,
                drained: false,
                crashed,
                lost_steps: 0,
                checkpoint_bytes: 0,
            },
        }
    }

    #[test]
    fn filters_compile_and_evaluate() {
        let e = compile("state = done and energy_j > 5").unwrap();
        assert!(eval(&e, &rec(1, 10.0, 6.0, false)));
        assert!(!eval(&e, &rec(1, 10.0, 4.0, false)));
        assert!(!eval(&e, &rec(1, 10.0, 6.0, true)));

        let e = compile("crashed == true or queue_wait_s >= 2").unwrap();
        assert!(eval(&e, &rec(1, 10.0, 0.0, true)));
        assert!(eval(&e, &rec(1, 30.0, 0.0, false))); // queue_wait = 3s
        assert!(!eval(&e, &rec(1, 10.0, 0.0, false)));

        let e = compile("device = 2").unwrap();
        assert!(eval(&e, &rec(2, 1.0, 0.0, false)));
        assert!(!eval(&e, &rec(1, 1.0, 0.0, false)));

        let e = compile("(state != cancelled) and (retired_at < 100 or retired_at >= 200)").unwrap();
        assert!(eval(&e, &rec(1, 50.0, 0.0, false)));
        assert!(eval(&e, &rec(1, 250.0, 0.0, false)));
        assert!(!eval(&e, &rec(1, 150.0, 0.0, false)));
    }

    #[test]
    fn malformed_filters_are_typed_errors() {
        for bad in [
            "",
            "state",
            "state =",
            "state = 3",
            "state = flying",
            "state > done",
            "crashed = 1",
            "crashed = maybe",
            "device = banana",
            "device = -1",
            "device = 1.5",
            "energy_j = soup",
            "bogus_field = 1",
            "energy_j > 1 and",
            "(energy_j > 1",
            "energy_j > 1 extra",
            "energy_j ! 1",
            "energy_j > 1e309",
        ] {
            assert!(compile(bad).is_err(), "{bad:?} must not compile");
        }
    }

    #[test]
    fn bounds_drive_pruning_conservatively() {
        let e = compile("retired_at >= 10 and retired_at < 20").unwrap();
        assert_eq!(retired_at_bounds(&e), (10.0, 20.0));
        let e = compile("retired_at < 10 or retired_at >= 20").unwrap();
        assert_eq!(retired_at_bounds(&e), (f64::NEG_INFINITY, f64::INFINITY));
        let e = compile("energy_j > 3").unwrap();
        assert_eq!(retired_at_bounds(&e), (f64::NEG_INFINITY, f64::INFINITY));
        let e = compile("retired_at = 5 and energy_j > 3").unwrap();
        assert_eq!(retired_at_bounds(&e), (5.0, 5.0));
    }

    #[test]
    fn cursors_roundtrip_and_reject_tampering() {
        let k = Key { retired_ns: 123_456_789, job: 42, ordinal: 7 };
        let c = encode_cursor(&k);
        assert_eq!(decode_cursor(&c).unwrap(), k);
        assert!(c.bytes().all(|b| CURSOR_ALPHABET.contains(&b)), "URL-safe alphabet only");

        assert!(decode_cursor("!!!").is_err());
        assert!(decode_cursor(&c[..c.len() - 2]).is_err());
        let mut doctored = c.clone().into_bytes();
        doctored[0] = if doctored[0] == b'A' { b'B' } else { b'A' };
        assert!(decode_cursor(std::str::from_utf8(&doctored).unwrap()).is_err());
    }

    #[test]
    fn agg_specs_parse_and_validate() {
        assert_eq!(parse_agg("count").unwrap(), Agg::Count);
        assert_eq!(parse_agg("sum:energy_j").unwrap(), Agg::Sum(Field::EnergyJ));
        assert_eq!(parse_agg("p50:queue_wait_s").unwrap(), Agg::P50(Field::QueueWaitS));
        assert_eq!(parse_agg("p99:retired_at").unwrap(), Agg::P99(Field::RetiredAt));
        for bad in ["", "sum", "sum:", "sum:state", "sum:crashed", "max:energy_j", "p42:energy_j"] {
            assert!(parse_agg(bad).is_err(), "{bad:?} must not parse");
        }
    }
}

impl fmt::Display for Field {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}
