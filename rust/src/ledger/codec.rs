//! Canonical versioned serialization of retired-job records
//! (DESIGN.md §Ledger).
//!
//! One *frame* per record, all little-endian:
//!
//! ```text
//! [u32 payload_len] [payload bytes] [u64 FNV-1a(payload)]
//! ```
//!
//! The payload starts with the schema version
//! ([`JobReport::SCHEMA_VERSION`]) followed by every field of
//! [`RetiredRecord`] in declaration order. Floats are written as raw
//! IEEE-754 bits ([`f64::to_bits`]) and times as integer nanoseconds,
//! so a decode reproduces the source record *bit-identically* — the
//! replay property the ledger integration suite pins. Strings are
//! length-prefixed UTF-8; collections are count-prefixed.
//!
//! Decoding errors are the typed [`DecodeError`] rather than bare
//! `anyhow` strings, so callers (and tests) can distinguish an unknown
//! schema version from plain corruption. The error still converts into
//! the crate-wide `anyhow` result at the store boundary.

use std::fmt;

use crate::analysis::audit::Fnv64;
use crate::fleet::{JobId, JobReport, JobState, RetiredRecord};
use crate::sim::SimTime;

/// Version written into every payload; bump on any change to the field
/// set, field order or field encoding below. Kept equal to
/// [`JobReport::SCHEMA_VERSION`] — the record is exactly a report plus
/// its retirement instant.
pub const SCHEMA_VERSION: u32 = JobReport::SCHEMA_VERSION;

/// Hard sanity cap on one frame's payload. A real record is a few
/// hundred bytes; a corrupted length header must not trigger a
/// gigabyte read.
pub const MAX_PAYLOAD: u32 = 1 << 20;

/// Bytes of frame overhead around the payload (length prefix +
/// checksum suffix).
pub const FRAME_OVERHEAD: usize = 4 + 8;

/// Typed decode failure. `UnknownVersion` is the forward-compatibility
/// contract: a newer writer's records fail loudly and specifically
/// instead of mis-parsing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeError {
    /// The payload declares a schema version this build cannot read.
    UnknownVersion { found: u32 },
    /// The buffer ends before the bytes it promises.
    Truncated { need: usize, have: usize },
    /// The declared payload length exceeds [`MAX_PAYLOAD`].
    Oversized { len: u32 },
    /// The stored FNV-1a checksum does not match the payload bytes.
    Checksum { want: u64, got: u64 },
    /// Lifecycle-state byte outside the encoded `0..=3` range.
    BadState(u8),
    /// Boolean byte other than 0 or 1.
    BadBool(u8),
    /// A length-prefixed string is not valid UTF-8.
    BadUtf8,
    /// Payload bytes remain after the last field — the frame length
    /// and the field set disagree.
    Trailing { extra: usize },
}

impl fmt::Display for DecodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DecodeError::UnknownVersion { found } => write!(
                f,
                "unknown ledger schema version {found} (this build reads version {SCHEMA_VERSION})"
            ),
            DecodeError::Truncated { need, have } => {
                write!(f, "truncated record: need {need} byte(s), have {have}")
            }
            DecodeError::Oversized { len } => {
                write!(f, "record payload length {len} exceeds the {MAX_PAYLOAD}-byte cap")
            }
            DecodeError::Checksum { want, got } => {
                write!(f, "record checksum mismatch: stored {want:#018x}, computed {got:#018x}")
            }
            DecodeError::BadState(b) => write!(f, "invalid job-state byte {b}"),
            DecodeError::BadBool(b) => write!(f, "invalid boolean byte {b}"),
            DecodeError::BadUtf8 => write!(f, "record string is not valid UTF-8"),
            DecodeError::Trailing { extra } => {
                write!(f, "{extra} trailing byte(s) after the last record field")
            }
        }
    }
}

impl std::error::Error for DecodeError {}

// ---- encode ------------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64_bits(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

fn put_bool(out: &mut Vec<u8>, v: bool) {
    out.push(u8::from(v));
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn state_code(s: JobState) -> u8 {
    match s {
        JobState::Queued => 0,
        JobState::Running => 1,
        JobState::Completed => 2,
        JobState::Cancelled => 3,
    }
}

fn state_from_code(b: u8) -> Result<JobState, DecodeError> {
    match b {
        0 => Ok(JobState::Queued),
        1 => Ok(JobState::Running),
        2 => Ok(JobState::Completed),
        3 => Ok(JobState::Cancelled),
        other => Err(DecodeError::BadState(other)),
    }
}

/// Serialize the record's payload (version + fields, no framing).
pub fn encode_payload(rec: &RetiredRecord, out: &mut Vec<u8>) {
    put_u32(out, SCHEMA_VERSION);
    put_u64(out, rec.retired_at.as_ns());
    let r = &rec.report;
    put_u64(out, r.id.0);
    out.push(state_code(r.state));
    put_str(out, &r.network);
    put_u32(out, r.devices.len() as u32);
    for &d in &r.devices {
        put_u64(out, d as u64);
    }
    put_bool(out, r.held_host);
    put_u64(out, r.bs_csd as u64);
    put_u64(out, r.bs_host as u64);
    put_u64(out, r.steps_done as u64);
    put_u64(out, r.steps_per_epoch as u64);
    put_u64(out, r.images as u64);
    put_u64(out, r.submitted_at.as_ns());
    put_u64(out, r.admitted_at.as_ns());
    put_u64(out, r.finished_at.as_ns());
    put_u64(out, r.queue_wait.as_ns());
    put_u64(out, r.elapsed.as_ns());
    put_f64_bits(out, r.images_per_sec);
    put_f64_bits(out, r.sync_fraction);
    put_f64_bits(out, r.energy_j);
    put_f64_bits(out, r.j_per_image);
    put_u64(out, r.link_bytes);
    put_u64(out, r.bytes_moved);
    put_u64(out, r.images_moved);
    put_u64(out, r.lock_wait.as_ns());
    put_u64(out, r.retunes as u64);
    put_bool(out, r.drained);
    put_bool(out, r.crashed);
    put_u64(out, r.lost_steps as u64);
    put_u64(out, r.checkpoint_bytes);
}

/// Frame one record into `out`: length prefix, payload, FNV-1a
/// checksum. `scratch` is a reusable payload buffer (cleared here) so
/// the writer's hot loop allocates nothing after warm-up.
pub fn encode_frame(rec: &RetiredRecord, scratch: &mut Vec<u8>, out: &mut Vec<u8>) {
    scratch.clear();
    encode_payload(rec, scratch);
    debug_assert!(scratch.len() <= MAX_PAYLOAD as usize, "record payload over the frame cap");
    put_u32(out, scratch.len() as u32);
    out.extend_from_slice(scratch);
    let mut h = Fnv64::new();
    h.write_bytes(scratch);
    put_u64(out, h.finish());
}

// ---- decode ------------------------------------------------------------

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], DecodeError> {
        let have = self.buf.len() - self.pos;
        if have < n {
            return Err(DecodeError::Truncated { need: n, have });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, DecodeError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, DecodeError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64, DecodeError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn f64_bits(&mut self) -> Result<f64, DecodeError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn boolean(&mut self) -> Result<bool, DecodeError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(DecodeError::BadBool(other)),
        }
    }

    fn time(&mut self) -> Result<SimTime, DecodeError> {
        Ok(SimTime(self.u64()?))
    }

    fn string(&mut self) -> Result<String, DecodeError> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| DecodeError::BadUtf8)
    }
}

/// Decode one payload (the bytes between a frame's length prefix and
/// its checksum). Rejects unknown versions, malformed fields and
/// trailing bytes.
pub fn decode_payload(payload: &[u8]) -> Result<RetiredRecord, DecodeError> {
    let mut r = Reader { buf: payload, pos: 0 };
    let version = r.u32()?;
    if version != SCHEMA_VERSION {
        return Err(DecodeError::UnknownVersion { found: version });
    }
    let retired_at = r.time()?;
    let id = JobId(r.u64()?);
    let state = state_from_code(r.u8()?)?;
    let network = r.string()?;
    let n_devices = r.u32()? as usize;
    let mut devices = Vec::with_capacity(n_devices.min(4096));
    for _ in 0..n_devices {
        devices.push(r.u64()? as usize);
    }
    let report = JobReport {
        id,
        state,
        network,
        devices,
        held_host: r.boolean()?,
        bs_csd: r.u64()? as usize,
        bs_host: r.u64()? as usize,
        steps_done: r.u64()? as usize,
        steps_per_epoch: r.u64()? as usize,
        images: r.u64()? as usize,
        submitted_at: r.time()?,
        admitted_at: r.time()?,
        finished_at: r.time()?,
        queue_wait: r.time()?,
        elapsed: r.time()?,
        images_per_sec: r.f64_bits()?,
        sync_fraction: r.f64_bits()?,
        energy_j: r.f64_bits()?,
        j_per_image: r.f64_bits()?,
        link_bytes: r.u64()?,
        bytes_moved: r.u64()?,
        images_moved: r.u64()?,
        lock_wait: r.time()?,
        retunes: r.u64()? as usize,
        drained: r.boolean()?,
        crashed: r.boolean()?,
        lost_steps: r.u64()? as usize,
        checkpoint_bytes: r.u64()?,
    };
    if r.pos != payload.len() {
        return Err(DecodeError::Trailing { extra: payload.len() - r.pos });
    }
    Ok(RetiredRecord { retired_at, report })
}

/// Decode one frame from the front of `buf`; returns the record and
/// the bytes consumed. Verifies the length prefix, the checksum and
/// every field.
pub fn decode_frame(buf: &[u8]) -> Result<(RetiredRecord, usize), DecodeError> {
    let mut r = Reader { buf, pos: 0 };
    let len = r.u32()?;
    if len > MAX_PAYLOAD {
        return Err(DecodeError::Oversized { len });
    }
    let payload = r.take(len as usize)?;
    let want = r.u64()?;
    let mut h = Fnv64::new();
    h.write_bytes(payload);
    let got = h.finish();
    if want != got {
        return Err(DecodeError::Checksum { want, got });
    }
    let rec = decode_payload(payload)?;
    Ok((rec, r.pos))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A record exercising every field with non-default, asymmetric
    /// values (including float bit patterns exact equality must keep).
    fn sample_record(salt: u64) -> RetiredRecord {
        RetiredRecord {
            retired_at: SimTime(1_234_567_890 + salt),
            report: JobReport {
                id: JobId(42 + salt),
                state: if salt % 2 == 0 { JobState::Completed } else { JobState::Cancelled },
                network: format!("mobilenet_v2_{salt}"),
                devices: vec![3, 1, 4, 1 + salt as usize % 7],
                held_host: salt % 3 == 0,
                bs_csd: 25,
                bs_host: 315,
                steps_done: 20 + salt as usize,
                steps_per_epoch: 17,
                images: 4321,
                submitted_at: SimTime(7 + salt),
                admitted_at: SimTime(1000 + salt),
                finished_at: SimTime(1_234_567_890 + salt),
                queue_wait: SimTime(993),
                elapsed: SimTime(1_234_566_890),
                images_per_sec: 123.456_789 + salt as f64 * 0.1,
                sync_fraction: 0.062_5,
                energy_j: -0.0, // bit pattern distinct from +0.0
                j_per_image: f64::MIN_POSITIVE,
                link_bytes: 9_876_543_210,
                bytes_moved: 1 << 33,
                images_moved: 77,
                lock_wait: SimTime(55_000),
                retunes: 2,
                drained: salt % 5 == 0,
                crashed: salt % 4 == 0,
                lost_steps: 3,
                checkpoint_bytes: 65_536,
            },
        }
    }

    #[test]
    fn frame_roundtrip_is_bit_exact() {
        let mut scratch = Vec::new();
        let mut out = Vec::new();
        for salt in 0..12 {
            let rec = sample_record(salt);
            let start = out.len();
            encode_frame(&rec, &mut scratch, &mut out);
            let (back, used) = decode_frame(&out[start..]).expect("frame decodes");
            assert_eq!(used, out.len() - start, "frame is self-delimiting");
            assert_eq!(back, rec, "decode must reproduce the record exactly");
            // PartialEq on f64 treats -0.0 == 0.0; pin the actual bits too.
            assert_eq!(back.report.energy_j.to_bits(), rec.report.energy_j.to_bits());
        }
        // Frames concatenate: decode them all back in order.
        let mut pos = 0;
        for salt in 0..12 {
            let (back, used) = decode_frame(&out[pos..]).expect("stream decodes");
            assert_eq!(back, sample_record(salt));
            pos += used;
        }
        assert_eq!(pos, out.len());
    }

    #[test]
    fn unknown_version_is_a_typed_error() {
        let mut scratch = Vec::new();
        let mut out = Vec::new();
        encode_frame(&sample_record(0), &mut scratch, &mut out);
        // The version is the first payload field, right after the u32
        // length prefix; forge it and re-stamp the checksum so only the
        // version check can fire.
        out[4..8].copy_from_slice(&99u32.to_le_bytes());
        let len = u32::from_le_bytes(out[0..4].try_into().unwrap()) as usize;
        let mut h = Fnv64::new();
        h.write_bytes(&out[4..4 + len]);
        let total = out.len();
        out[total - 8..].copy_from_slice(&h.finish().to_le_bytes());
        assert_eq!(
            decode_frame(&out).unwrap_err(),
            DecodeError::UnknownVersion { found: 99 },
        );
    }

    #[test]
    fn corruption_is_detected() {
        let mut scratch = Vec::new();
        let mut frame = Vec::new();
        encode_frame(&sample_record(1), &mut scratch, &mut frame);

        // Any flipped payload byte fails the checksum.
        let mut bad = frame.clone();
        bad[10] ^= 0x40;
        assert!(matches!(decode_frame(&bad).unwrap_err(), DecodeError::Checksum { .. }));

        // A short buffer is a typed truncation, not a panic.
        assert!(matches!(
            decode_frame(&frame[..frame.len() - 3]).unwrap_err(),
            DecodeError::Truncated { .. }
        ));
        assert!(matches!(decode_frame(&[1, 0]).unwrap_err(), DecodeError::Truncated { .. }));

        // An absurd length prefix is rejected before any allocation.
        let mut huge = frame.clone();
        huge[0..4].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert_eq!(
            decode_frame(&huge).unwrap_err(),
            DecodeError::Oversized { len: MAX_PAYLOAD + 1 },
        );

        // Bad enum/bool bytes are typed (re-stamp the checksum so the
        // field check itself is what fires). The state byte sits right
        // after version + retired_at + id = 4 + 20 payload bytes.
        let mut bad_state = frame.clone();
        bad_state[4 + 20] = 9;
        let len = u32::from_le_bytes(bad_state[0..4].try_into().unwrap()) as usize;
        let mut h = Fnv64::new();
        h.write_bytes(&bad_state[4..4 + len]);
        let total = bad_state.len();
        bad_state[total - 8..].copy_from_slice(&h.finish().to_le_bytes());
        assert_eq!(decode_frame(&bad_state).unwrap_err(), DecodeError::BadState(9));
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let mut payload = Vec::new();
        encode_payload(&sample_record(2), &mut payload);
        payload.push(0);
        assert_eq!(decode_payload(&payload).unwrap_err(), DecodeError::Trailing { extra: 1 });
    }

    #[test]
    fn schema_version_consts_agree() {
        assert_eq!(SCHEMA_VERSION, JobReport::SCHEMA_VERSION);
        assert_eq!(SCHEMA_VERSION, RetiredRecord::SCHEMA_VERSION);
    }
}
