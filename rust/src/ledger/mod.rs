//! Persistent job-history ledger: the fleet control plane's
//! storage-resident record of every retired job (DESIGN.md §Ledger).
//!
//! STANNIS keeps training data resident in storage and moves only
//! what the host explicitly shares; this module applies the same
//! posture to the simulator's own telemetry. With
//! `FleetConfig::ledger_path` set (CLI `--ledger DIR`, workload JSON
//! `"ledger"`), every [`RetiredRecord`](crate::fleet::RetiredRecord)
//! that enters the runtime log is also appended — canonically encoded
//! and checksummed — to an on-disk segment log that `stannis query`
//! can filter, paginate, and aggregate long after the run exits.
//!
//! Layering (zero external deps, like `util/json`):
//!
//! - [`codec`] — canonical versioned record serialization; floats via
//!   `to_bits`, FNV-1a checksum per frame, typed [`DecodeError`].
//! - [`store`] — segmented append-only log: [`LedgerWriter`] (write
//!   path, infallible `append` + deferred error surfacing) and
//!   [`LedgerStore`] (read path, footer-validated open).
//! - [`query`] — validated filter language (lex → parse → validate →
//!   plan), footer-driven segment pruning, keyset cursor pagination,
//!   and aggregate projections.
//!
//! Determinism contract: ledger-off runs are bit-identical to a build
//! without this module (the writer never enters the runtime's
//! auditable set or fingerprint), and ledger-on runs produce
//! byte-identical directories across executors, `run_until` slicings,
//! and sweep worker counts.

pub mod codec;
pub mod query;
pub mod store;

pub use codec::{decode_frame, decode_payload, encode_frame, encode_payload, DecodeError,
    SCHEMA_VERSION};
pub use query::{aggregate, compile, decode_cursor, encode_cursor, eval, page, parse_agg,
    record_json, retired_at_bounds, Agg, CmpOp, Expr, Field, Key, Pred, QueryPage};
pub use store::{LedgerStore, LedgerWriter, SegmentMeta, SegmentSummary, MAGIC,
    SEGMENT_PAYLOAD_BYTES};
