//! Synthetic labeled image dataset with public/private tagging.
//!
//! Stands in for the paper's expanded TinyImageNet (72k public + 12k
//! private images spread over the CSDs). Images are generated on
//! demand and deterministically: every class has a fixed random
//! "prototype" pattern; an image is its class prototype plus per-image
//! noise, so the CNNs can genuinely learn the classes (the §V.C
//! accuracy-parity experiment trains on these).
//!
//! Each image also has a *location*: which CSD's flash holds it and
//! whether it is private (pinned to that CSD's ISP engine) or public
//! (shareable with the host over NVMe). The privacy invariant — a
//! private image is only ever materialized on its home CSD — is
//! enforced by [`Shard::batch`] and tested here and in the placement
//! integration tests.

use anyhow::{ensure, Result};

use crate::model::Tensor;
use crate::util::Rng;

/// Visibility of one image.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Visibility {
    Public,
    /// Private to the given CSD.
    Private { csd: usize },
}

/// Dataset-wide parameters.
#[derive(Debug, Clone)]
pub struct DatasetConfig {
    /// Total distinct public images.
    pub public_images: usize,
    /// Private images *per CSD*.
    pub private_per_csd: Vec<usize>,
    pub hw: usize,
    pub classes: usize,
    pub seed: u64,
    /// Noise-to-prototype ratio (higher = harder problem).
    pub noise: f32,
}

impl Default for DatasetConfig {
    fn default() -> Self {
        Self {
            public_images: 7200,
            private_per_csd: vec![],
            hw: 32,
            classes: 64,
            seed: 0xDA7A,
            noise: 0.55,
        }
    }
}

/// Stable identifier: public ids are `[0, public_images)`; private ids
/// follow, grouped by CSD.
pub type ImageId = usize;

/// The dataset generator.
#[derive(Debug, Clone)]
pub struct Dataset {
    cfg: DatasetConfig,
    /// Per-CSD offset of its private id range.
    private_offsets: Vec<usize>,
    total: usize,
}

impl Dataset {
    pub fn new(cfg: DatasetConfig) -> Result<Self> {
        ensure!(cfg.classes > 0 && cfg.hw > 0, "degenerate dataset config");
        ensure!(cfg.public_images > 0, "need at least some public data");
        let mut private_offsets = Vec::with_capacity(cfg.private_per_csd.len());
        let mut off = cfg.public_images;
        for n in &cfg.private_per_csd {
            private_offsets.push(off);
            off += n;
        }
        Ok(Self { private_offsets, total: off, cfg })
    }

    pub fn config(&self) -> &DatasetConfig {
        &self.cfg
    }

    pub fn len(&self) -> usize {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    pub fn num_public(&self) -> usize {
        self.cfg.public_images
    }

    /// Visibility of an image id.
    ///
    /// Private ids are a contiguous ascending range partitioned by
    /// `private_offsets`, so the owner is found by binary search — this
    /// sits on the batch hot path (`batch_from_ids` callers validate
    /// per id) where the old per-CSD linear scan was O(num_csds).
    pub fn visibility(&self, id: ImageId) -> Result<Visibility> {
        if id < self.cfg.public_images {
            return Ok(Visibility::Public);
        }
        ensure!(id < self.total, "image id {id} out of range (total {})", self.total);
        // Owner = last CSD whose offset is <= id. A zero-length shard
        // shares its successor's offset and loses the tie (the
        // partition point lands past it), so it can never claim an id.
        let csd = self.private_offsets.partition_point(|&off| off <= id) - 1;
        debug_assert!(
            id >= self.private_offsets[csd]
                && id < self.private_offsets[csd] + self.cfg.private_per_csd[csd]
        );
        Ok(Visibility::Private { csd })
    }

    /// Ids of one CSD's private shard.
    pub fn private_ids(&self, csd: usize) -> Result<std::ops::Range<ImageId>> {
        ensure!(csd < self.private_offsets.len(), "csd {csd} has no private shard");
        let off = self.private_offsets[csd];
        Ok(off..off + self.cfg.private_per_csd[csd])
    }

    /// Deterministic label for an image (balanced round-robin).
    pub fn label(&self, id: ImageId) -> i32 {
        (id % self.cfg.classes) as i32
    }

    /// Class prototype pattern (cached by callers if hot).
    fn prototype(&self, class: usize) -> Vec<f32> {
        let n = self.cfg.hw * self.cfg.hw * 3;
        let mut rng = Rng::new(self.cfg.seed ^ (class as u64).wrapping_mul(0xC1A5_5E5E));
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    /// Materialize one image as (pixels, label).
    pub fn image(&self, id: ImageId) -> Result<(Vec<f32>, i32)> {
        ensure!(id < self.total, "image id {id} out of range");
        let class = self.label(id) as usize;
        let proto = self.prototype(class);
        let mut rng = Rng::new(self.cfg.seed ^ (id as u64).wrapping_mul(0x1337_BEEF) ^ 0xF00D);
        let noise = self.cfg.noise;
        let pixels = proto
            .iter()
            .map(|p| p * (1.0 - noise) + (rng.normal() as f32) * noise)
            .collect();
        Ok((pixels, self.label(id)))
    }

    /// Assemble a batch tensor (NHWC) + labels from explicit ids.
    pub fn batch_from_ids(&self, ids: &[ImageId]) -> Result<(Tensor, Vec<i32>)> {
        let hw = self.cfg.hw;
        let mut data = Vec::with_capacity(ids.len() * hw * hw * 3);
        let mut labels = Vec::with_capacity(ids.len());
        for &id in ids {
            let (pixels, label) = self.image(id)?;
            data.extend_from_slice(&pixels);
            labels.push(label);
        }
        Ok((Tensor::new(vec![ids.len(), hw, hw, 3], data)?, labels))
    }

    /// Bytes of one encoded image (f32 pixels) — drives flash page
    /// placement in the modeled I/O path.
    pub fn image_bytes(&self) -> usize {
        self.cfg.hw * self.cfg.hw * 3 * 4
    }
}

/// One worker's assigned slice of the dataset.
#[derive(Debug, Clone)]
pub struct Shard {
    /// The worker this shard belongs to (None = host).
    pub csd: Option<usize>,
    /// Image ids, already privacy-checked at construction.
    ids: Vec<ImageId>,
    cursor: usize,
    rng: Rng,
}

impl Shard {
    /// Build a shard, enforcing the privacy invariant: a shard may only
    /// contain private images belonging to its own CSD; the host shard
    /// must be entirely public.
    pub fn new(dataset: &Dataset, csd: Option<usize>, mut ids: Vec<ImageId>, seed: u64) -> Result<Self> {
        for &id in &ids {
            match dataset.visibility(id)? {
                Visibility::Public => {}
                Visibility::Private { csd: owner } => {
                    ensure!(
                        csd == Some(owner),
                        "privacy violation: image {id} is private to csd{owner} \
                         but was placed on {:?}",
                        csd.map_or("host".to_string(), |c| format!("csd{c}")),
                    );
                }
            }
        }
        let mut rng = Rng::new(seed);
        rng.shuffle(&mut ids);
        Ok(Self { csd, ids, cursor: 0, rng })
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    pub fn ids(&self) -> &[ImageId] {
        &self.ids
    }

    /// Next `bs` ids, reshuffling at epoch boundaries.
    ///
    /// An empty shard is an error, not a panic: a degraded CSD whose
    /// re-balance emptied its shard must be skipped by the caller, and
    /// the old `self.ids[0]` on a zero-length vec index-panicked here.
    pub fn next_ids(&mut self, bs: usize) -> Result<Vec<ImageId>> {
        ensure!(
            !self.ids.is_empty(),
            "cannot draw a batch of {bs}: the {} shard is empty (skip this worker)",
            self.csd.map_or("host".to_string(), |c| format!("csd{c}")),
        );
        let mut out = Vec::with_capacity(bs);
        for _ in 0..bs {
            if self.cursor >= self.ids.len() {
                self.rng.shuffle(&mut self.ids);
                self.cursor = 0;
            }
            out.push(self.ids[self.cursor]);
            self.cursor += 1;
        }
        Ok(out)
    }

    /// Draw the next batch as tensors.
    pub fn batch(&mut self, dataset: &Dataset, bs: usize) -> Result<(Tensor, Vec<i32>)> {
        let ids = self.next_ids(bs)?;
        dataset.batch_from_ids(&ids)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dataset() -> Dataset {
        Dataset::new(DatasetConfig {
            public_images: 100,
            private_per_csd: vec![10, 20],
            hw: 8,
            classes: 10,
            seed: 1,
            noise: 0.5,
        })
        .unwrap()
    }

    #[test]
    fn id_space_layout() {
        let d = dataset();
        assert_eq!(d.len(), 130);
        assert_eq!(d.visibility(5).unwrap(), Visibility::Public);
        assert_eq!(d.visibility(105).unwrap(), Visibility::Private { csd: 0 });
        assert_eq!(d.visibility(115).unwrap(), Visibility::Private { csd: 1 });
        assert!(d.visibility(130).is_err());
        assert_eq!(d.private_ids(1).unwrap(), 110..130);
    }

    #[test]
    fn images_deterministic_and_class_correlated() {
        let d = dataset();
        let (a, la) = d.image(7).unwrap();
        let (b, _) = d.image(7).unwrap();
        assert_eq!(a, b, "same id must regenerate identically");
        // Same class (7 and 17): prototypes align better than across
        // classes (7 and 8).
        let (c, lc) = d.image(17).unwrap();
        let (e, _) = d.image(8).unwrap();
        assert_eq!(la, lc);
        let dot = |x: &[f32], y: &[f32]| x.iter().zip(y).map(|(a, b)| a * b).sum::<f32>();
        assert!(dot(&a, &c) > dot(&a, &e), "class structure must exist");
    }

    #[test]
    fn labels_balanced() {
        let d = dataset();
        let mut counts = vec![0; 10];
        for id in 0..100 {
            counts[d.label(id) as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10));
    }

    #[test]
    fn privacy_enforced_at_shard_construction() {
        let d = dataset();
        // Host shard with a private image: rejected.
        assert!(Shard::new(&d, None, vec![1, 2, 105], 0).is_err());
        // CSD 1 shard with CSD 0's private image: rejected.
        assert!(Shard::new(&d, Some(1), vec![105], 0).is_err());
        // CSD 0 with its own private + public: fine.
        assert!(Shard::new(&d, Some(0), vec![105, 3], 0).is_ok());
    }

    #[test]
    fn shard_cycles_through_all_ids() {
        let d = dataset();
        let mut s = Shard::new(&d, None, (0..10).collect(), 3).unwrap();
        let mut seen = std::collections::BTreeSet::new();
        for id in s.next_ids(10).unwrap() {
            seen.insert(id);
        }
        assert_eq!(seen.len(), 10, "first epoch covers every id exactly once");
        // Crossing the boundary reshuffles and keeps serving.
        assert_eq!(s.next_ids(15).unwrap().len(), 15);
    }

    #[test]
    fn empty_shard_batch_errors_instead_of_panicking() {
        // Regression: cursor 0 >= len 0 used to shuffle and then index
        // `self.ids[0]` — the fate of a degraded CSD whose re-balance
        // emptied its shard.
        let d = dataset();
        let mut s = Shard::new(&d, Some(0), Vec::new(), 9).unwrap();
        assert!(s.is_empty());
        let err = s.next_ids(4).unwrap_err().to_string();
        assert!(err.contains("csd0") && err.contains("empty"), "got: {err}");
        assert!(s.batch(&d, 4).is_err());
        // A host shard reports itself as such.
        let mut h = Shard::new(&d, None, Vec::new(), 9).unwrap();
        assert!(h.next_ids(1).unwrap_err().to_string().contains("host"));
    }

    #[test]
    fn visibility_binary_search_handles_zero_length_shards() {
        // csd1 holds no private data: its offset collides with csd2's
        // and must never claim an id.
        let d = Dataset::new(DatasetConfig {
            public_images: 100,
            private_per_csd: vec![10, 0, 20],
            hw: 8,
            classes: 10,
            seed: 1,
            noise: 0.5,
        })
        .unwrap();
        assert_eq!(d.visibility(99).unwrap(), Visibility::Public);
        assert_eq!(d.visibility(100).unwrap(), Visibility::Private { csd: 0 });
        assert_eq!(d.visibility(109).unwrap(), Visibility::Private { csd: 0 });
        assert_eq!(d.visibility(110).unwrap(), Visibility::Private { csd: 2 });
        assert_eq!(d.visibility(129).unwrap(), Visibility::Private { csd: 2 });
        let err = d.visibility(130).unwrap_err().to_string();
        assert!(err.contains("out of range (total 130)"), "got: {err}");
        assert_eq!(d.private_ids(1).unwrap(), 110..110);
    }

    #[test]
    fn batch_shapes() {
        let d = dataset();
        let mut s = Shard::new(&d, None, (0..20).collect(), 4).unwrap();
        let (x, y) = s.batch(&d, 6).unwrap();
        assert_eq!(x.shape(), &[6, 8, 8, 3]);
        assert_eq!(y.len(), 6);
        assert!(y.iter().all(|&l| l >= 0 && l < 10));
    }
}
