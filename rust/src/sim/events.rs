//! Time-ordered event queue with deterministic tie-breaking.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::SimTime;

/// An event scheduled for a point in simulated time, carrying a typed
/// payload `E` chosen by the embedding model (GC trigger, DLM timeout,
/// fault injection, ...).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScheduledEvent<E> {
    pub at: SimTime,
    pub seq: u64,
    pub payload: E,
}

/// Min-heap of events ordered by (time, insertion sequence).
///
/// The sequence tie-break makes simulation runs deterministic even when
/// many events share a timestamp — a requirement for byte-reproducible
/// experiment logs.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<(SimTime, u64)>>,
    payloads: std::collections::HashMap<u64, (SimTime, E)>,
    next_seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            payloads: std::collections::HashMap::new(),
            next_seq: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `payload` at absolute time `at`; returns the event id.
    pub fn schedule(&mut self, at: SimTime, payload: E) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Reverse((at, seq)));
        self.payloads.insert(seq, (at, payload));
        seq
    }

    /// Cancel a scheduled event by id. Returns true if it was pending.
    pub fn cancel(&mut self, id: u64) -> bool {
        self.payloads.remove(&id).is_some()
    }

    /// Time of the next (non-cancelled) event.
    pub fn peek_time(&mut self) -> Option<SimTime> {
        self.skip_cancelled();
        self.heap.peek().map(|Reverse((t, _))| *t)
    }

    /// Pop the next event (earliest time, FIFO among ties).
    pub fn pop(&mut self) -> Option<ScheduledEvent<E>> {
        self.skip_cancelled();
        let Reverse((at, seq)) = self.heap.pop()?;
        let (_, payload) = self.payloads.remove(&seq).expect("payload present");
        Some(ScheduledEvent { at, seq, payload })
    }

    /// Pop every event with time <= `until`, in order.
    pub fn pop_until(&mut self, until: SimTime) -> Vec<ScheduledEvent<E>> {
        let mut out = Vec::new();
        while let Some(t) = self.peek_time() {
            if t > until {
                break;
            }
            out.push(self.pop().unwrap());
        }
        out
    }

    fn skip_cancelled(&mut self) {
        while let Some(Reverse((_, seq))) = self.heap.peek() {
            if self.payloads.contains_key(seq) {
                return;
            }
            self.heap.pop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time_then_fifo() {
        let mut q = EventQueue::new();
        q.schedule(SimTime::ms(5), "b");
        q.schedule(SimTime::ms(1), "a");
        q.schedule(SimTime::ms(5), "c"); // same time as "b": FIFO
        assert_eq!(q.pop().unwrap().payload, "a");
        assert_eq!(q.pop().unwrap().payload, "b");
        assert_eq!(q.pop().unwrap().payload, "c");
        assert!(q.pop().is_none());
    }

    #[test]
    fn cancel_removes() {
        let mut q = EventQueue::new();
        let id = q.schedule(SimTime::ms(1), 1);
        q.schedule(SimTime::ms(2), 2);
        assert!(q.cancel(id));
        assert!(!q.cancel(id));
        assert_eq!(q.peek_time(), Some(SimTime::ms(2)));
        assert_eq!(q.pop().unwrap().payload, 2);
    }

    #[test]
    fn pop_until_boundary_inclusive() {
        let mut q = EventQueue::new();
        for i in 1..=5u64 {
            q.schedule(SimTime::ms(i), i);
        }
        let drained = q.pop_until(SimTime::ms(3));
        assert_eq!(drained.iter().map(|e| e.payload).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn property_pop_order_is_sorted() {
        crate::util::prop::check("event queue pops in time order", |rng| {
            let mut q = EventQueue::new();
            let n = 1 + rng.usize_below(100);
            for _ in 0..n {
                q.schedule(SimTime::ns(rng.below(1000)), ());
            }
            let mut last = (SimTime::ZERO, 0u64);
            while let Some(e) = q.pop() {
                assert!(
                    (e.at, e.seq) >= last,
                    "out of order: {:?} after {:?}",
                    (e.at, e.seq),
                    last
                );
                last = (e.at, e.seq);
            }
        });
    }
}
