//! Time-ordered event queue with deterministic tie-breaking.
//!
//! Slab-backed: payloads live inline in a generational slab and the
//! binary heap holds only `(time, seq, slot, stamp)` keys, so the hot
//! schedule/pop cycle never touches a hash map and `peek_time` needs no
//! exclusive access. Cancellation is O(1) (bump the slot stamp, making
//! the heap entry a *tombstone*); tombstones are popped over lazily and
//! the heap is compacted whenever they exceed half of it, so cancelled
//! events can never dominate memory or pop cost (DESIGN.md §Perf).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use anyhow::ensure;

use crate::analysis::audit::{Auditable, Fnv64};

use super::SimTime;

/// An event scheduled for a point in simulated time, carrying a typed
/// payload `E` chosen by the embedding model (GC trigger, DLM timeout,
/// fault injection, ...).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScheduledEvent<E> {
    pub at: SimTime,
    pub seq: u64,
    pub payload: E,
}

/// Heap key: `(time, insertion sequence, slot, stamp)`. The sequence is
/// unique, so `slot`/`stamp` never influence ordering — they only route
/// the popped key back to its slab payload and expose staleness.
type HeapKey = Reverse<(SimTime, u64, u32, u32)>;

/// One slab cell. `stamp` is bumped every time the cell is freed, so a
/// heap entry (or an external event id) carrying an older stamp is
/// recognisably stale even after the cell is reused.
#[derive(Debug)]
struct Slot<E> {
    stamp: u32,
    seq: u64,
    payload: Option<E>,
}

/// Don't bother compacting tiny heaps: below this many tombstones the
/// lazy pop-over path is cheaper than a rebuild.
const COMPACT_FLOOR: usize = 64;

/// Min-heap of events ordered by (time, insertion sequence).
///
/// The sequence tie-break makes simulation runs deterministic even when
/// many events share a timestamp — a requirement for byte-reproducible
/// experiment logs.
///
/// Invariant: the heap top is never a tombstone (every mutating method
/// restores this), which is what lets [`EventQueue::peek_time`] take
/// `&self`.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<HeapKey>,
    slots: Vec<Slot<E>>,
    free: Vec<u32>,
    next_seq: u64,
    /// Scheduled-and-not-yet-popped events (excludes tombstones).
    live: usize,
    /// Stale heap entries awaiting lazy removal or compaction.
    tombstones: usize,
}

// Manual (not derived) so `E` needs no `Default` bound.
#[allow(clippy::derivable_impls)]
impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

fn event_id(slot: u32, stamp: u32) -> u64 {
    (u64::from(stamp) << 32) | u64::from(slot)
}

fn split_id(id: u64) -> (u32, u32) {
    (id as u32, (id >> 32) as u32)
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            slots: Vec::new(),
            free: Vec::new(),
            next_seq: 0,
            live: 0,
            tombstones: 0,
        }
    }

    /// Number of pending (non-cancelled) events.
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Schedule `payload` at absolute time `at`; returns the event id.
    pub fn schedule(&mut self, at: SimTime, payload: E) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        let slot = match self.free.pop() {
            Some(slot) => {
                let s = &mut self.slots[slot as usize];
                s.seq = seq;
                s.payload = Some(payload);
                slot
            }
            None => {
                let slot = u32::try_from(self.slots.len()).expect("slab capacity");
                self.slots.push(Slot { stamp: 0, seq, payload: Some(payload) });
                slot
            }
        };
        let stamp = self.slots[slot as usize].stamp;
        self.heap.push(Reverse((at, seq, slot, stamp)));
        self.live += 1;
        event_id(slot, stamp)
    }

    /// Cancel a scheduled event by id. Returns true if it was pending.
    pub fn cancel(&mut self, id: u64) -> bool {
        let (slot, stamp) = split_id(id);
        let Some(s) = self.slots.get_mut(slot as usize) else { return false };
        if s.stamp != stamp || s.payload.is_none() {
            return false;
        }
        s.payload = None;
        s.stamp = s.stamp.wrapping_add(1);
        self.free.push(slot);
        self.live -= 1;
        self.tombstones += 1;
        self.fix_top();
        self.maybe_compact();
        true
    }

    /// Insertion sequence number of a pending event (None if the id is
    /// stale). Sequence order is the deterministic tie-break among
    /// same-time events — the fleet's fast-forward uses it to replay
    /// scheduling order exactly.
    pub fn seq_of(&self, id: u64) -> Option<u64> {
        let (slot, stamp) = split_id(id);
        let s = self.slots.get(slot as usize)?;
        (s.stamp == stamp && s.payload.is_some()).then_some(s.seq)
    }

    /// Time of the next (non-cancelled) event. `&self`: the top of the
    /// heap is live by invariant, so no lazy cleanup is needed here.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|Reverse((t, ..))| *t)
    }

    /// Pop the next event (earliest time, FIFO among ties).
    pub fn pop(&mut self) -> Option<ScheduledEvent<E>> {
        let Reverse((at, seq, slot, _stamp)) = self.heap.pop()?;
        let s = &mut self.slots[slot as usize];
        let payload = s.payload.take().expect("heap top is live by invariant");
        s.stamp = s.stamp.wrapping_add(1);
        self.free.push(slot);
        self.live -= 1;
        self.fix_top();
        Some(ScheduledEvent { at, seq, payload })
    }

    /// Drain every event with time <= `until`, in order, without
    /// allocating. The iterator is lazy: events stay queued until
    /// consumed, so dropping it early leaves the remainder pending.
    pub fn drain_until(&mut self, until: SimTime) -> DrainUntil<'_, E> {
        DrainUntil { queue: self, until }
    }

    /// Pop every event with time <= `until`, in order. Compatibility
    /// wrapper over [`EventQueue::drain_until`] for callers that want
    /// an owned batch.
    pub fn pop_until(&mut self, until: SimTime) -> Vec<ScheduledEvent<E>> {
        self.drain_until(until).collect()
    }

    /// Restore the "heap top is live" invariant after a mutation.
    fn fix_top(&mut self) {
        while let Some(Reverse((_, _, slot, stamp))) = self.heap.peek() {
            let s = &self.slots[*slot as usize];
            if s.stamp == *stamp && s.payload.is_some() {
                return;
            }
            self.heap.pop();
            self.tombstones -= 1;
        }
    }

    /// Rebuild the heap without tombstones once they outnumber live
    /// entries — keeps heap size O(live) no matter how many events are
    /// cancelled (the former design leaked them until popped over).
    fn maybe_compact(&mut self) {
        if self.tombstones <= COMPACT_FLOOR || self.tombstones * 2 <= self.heap.len() {
            return;
        }
        let entries = std::mem::take(&mut self.heap).into_vec();
        let slots = &self.slots;
        self.heap = entries
            .into_iter()
            .filter(|Reverse((_, _, slot, stamp))| {
                let s = &slots[*slot as usize];
                s.stamp == *stamp && s.payload.is_some()
            })
            .collect();
        self.tombstones = 0;
    }

    /// Verify the slab/heap bookkeeping wholesale (the audit path):
    /// counters match the slab, the free list is exact, every live heap
    /// key routes to a matching occupied slot, the heap top is live,
    /// and tombstones respect the compaction bound.
    pub fn check_invariants(&self) -> crate::Result<()> {
        let occupied = self.slots.iter().filter(|s| s.payload.is_some()).count();
        ensure!(occupied == self.live, "live {} != occupied slots {}", self.live, occupied);
        ensure!(
            self.heap.len() == self.live + self.tombstones,
            "heap len {} != live {} + tombstones {}",
            self.heap.len(),
            self.live,
            self.tombstones
        );
        ensure!(
            self.free.len() + self.live == self.slots.len(),
            "free {} + live {} != slots {}",
            self.free.len(),
            self.live,
            self.slots.len()
        );
        let mut on_free = vec![false; self.slots.len()];
        for &slot in &self.free {
            let s = self
                .slots
                .get(slot as usize)
                .ok_or_else(|| anyhow::anyhow!("free-list slot {slot} out of range"))?;
            ensure!(s.payload.is_none(), "free-list slot {slot} still holds a payload");
            ensure!(!on_free[slot as usize], "slot {slot} on the free list twice");
            on_free[slot as usize] = true;
        }
        let mut heap_live = 0usize;
        for Reverse((_, seq, slot, stamp)) in self.heap.iter() {
            let s = self
                .slots
                .get(*slot as usize)
                .ok_or_else(|| anyhow::anyhow!("heap slot {slot} out of range"))?;
            if s.stamp == *stamp && s.payload.is_some() {
                ensure!(s.seq == *seq, "heap seq {seq} != slot seq {} (slot {slot})", s.seq);
                heap_live += 1;
            }
        }
        ensure!(heap_live == self.live, "live heap keys {} != live {}", heap_live, self.live);
        if let Some(Reverse((_, _, slot, stamp))) = self.heap.peek() {
            let s = &self.slots[*slot as usize];
            ensure!(s.stamp == *stamp && s.payload.is_some(), "heap top is a tombstone");
        }
        for s in &self.slots {
            if s.payload.is_some() {
                ensure!(s.seq < self.next_seq, "slot seq {} >= next_seq {}", s.seq, self.next_seq);
            }
        }
        ensure!(
            self.tombstones <= COMPACT_FLOOR || self.tombstones * 2 <= self.heap.len(),
            "tombstones {} exceed the compaction bound (heap {})",
            self.tombstones,
            self.heap.len()
        );
        Ok(())
    }
}

impl<E> Auditable for EventQueue<E> {
    fn component(&self) -> &'static str {
        "event-queue"
    }

    fn audit(&self) -> crate::Result<()> {
        self.check_invariants()
    }

    /// Hash the *live schedule* — the sorted `(time, seq)` set plus the
    /// sequence counter. The heap's internal arrangement and tombstones
    /// are history artifacts, not observable state, so they are
    /// deliberately excluded; payloads are opaque (`E` is unbounded)
    /// but `(time, seq)` uniquely identifies each pending event.
    fn fingerprint(&self, h: &mut Fnv64) {
        let mut live: Vec<(u64, u64)> = self
            .heap
            .iter()
            .filter_map(|Reverse((at, seq, slot, stamp))| {
                let s = &self.slots[*slot as usize];
                (s.stamp == *stamp && s.payload.is_some()).then_some((at.as_ns(), *seq))
            })
            .collect();
        live.sort_unstable();
        h.write_usize(live.len());
        for (at, seq) in live {
            h.write_u64(at);
            h.write_u64(seq);
        }
        h.write_u64(self.next_seq);
    }
}

/// Borrowing iterator over events up to (and including) a deadline —
/// see [`EventQueue::drain_until`].
#[derive(Debug)]
pub struct DrainUntil<'a, E> {
    queue: &'a mut EventQueue<E>,
    until: SimTime,
}

impl<E> Iterator for DrainUntil<'_, E> {
    type Item = ScheduledEvent<E>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.queue.peek_time()? > self.until {
            return None;
        }
        self.queue.pop()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time_then_fifo() {
        let mut q = EventQueue::new();
        q.schedule(SimTime::ms(5), "b");
        q.schedule(SimTime::ms(1), "a");
        q.schedule(SimTime::ms(5), "c"); // same time as "b": FIFO
        assert_eq!(q.pop().unwrap().payload, "a");
        assert_eq!(q.pop().unwrap().payload, "b");
        assert_eq!(q.pop().unwrap().payload, "c");
        assert!(q.pop().is_none());
    }

    #[test]
    fn cancel_removes() {
        let mut q = EventQueue::new();
        let id = q.schedule(SimTime::ms(1), 1);
        q.schedule(SimTime::ms(2), 2);
        assert!(q.cancel(id));
        assert!(!q.cancel(id));
        assert_eq!(q.peek_time(), Some(SimTime::ms(2)));
        assert_eq!(q.pop().unwrap().payload, 2);
    }

    #[test]
    fn peek_time_needs_no_exclusive_access() {
        let mut q = EventQueue::new();
        q.schedule(SimTime::ms(3), ());
        let shared: &EventQueue<()> = &q;
        assert_eq!(shared.peek_time(), Some(SimTime::ms(3)));
    }

    #[test]
    fn stale_id_cannot_touch_reused_slot() {
        let mut q = EventQueue::new();
        let a = q.schedule(SimTime::ms(1), "a");
        assert!(q.cancel(a));
        // The freed slot is reused; the stale id must not hit it.
        let b = q.schedule(SimTime::ms(2), "b");
        assert_ne!(a, b, "reuse must be stamped");
        assert!(!q.cancel(a));
        assert_eq!(q.seq_of(a), None);
        assert_eq!(q.seq_of(b), Some(1));
        assert_eq!(q.pop().unwrap().payload, "b");
    }

    #[test]
    fn pop_until_boundary_inclusive() {
        let mut q = EventQueue::new();
        for i in 1..=5u64 {
            q.schedule(SimTime::ms(i), i);
        }
        let drained = q.pop_until(SimTime::ms(3));
        assert_eq!(drained.iter().map(|e| e.payload).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn drain_until_matches_pop_until_at_the_boundary() {
        // Regression: the allocation-free iterator must keep the old
        // Vec-returning semantics exactly — inclusive deadline, events
        // beyond it untouched, early drop leaves the rest pending.
        let build = || {
            let mut q = EventQueue::new();
            for i in [4u64, 1, 3, 3, 2, 5] {
                q.schedule(SimTime::ms(i), i);
            }
            q
        };
        let mut a = build();
        let mut b = build();
        let via_vec = a.pop_until(SimTime::ms(3));
        let via_iter: Vec<_> = b.drain_until(SimTime::ms(3)).collect();
        assert_eq!(via_vec, via_iter);
        assert_eq!(a.len(), b.len());
        assert_eq!(a.peek_time(), b.peek_time());

        // Early drop: one event consumed, the rest still queued.
        let mut c = build();
        let first = c.drain_until(SimTime::ms(3)).next().unwrap();
        assert_eq!(first.payload, 1);
        assert_eq!(c.len(), 5);
    }

    #[test]
    fn tombstones_stay_bounded() {
        let mut q = EventQueue::new();
        let ids: Vec<u64> = (0..4096u64).map(|i| q.schedule(SimTime::ns(i), i)).collect();
        for id in &ids[1..] {
            assert!(q.cancel(*id));
            // The compaction bound: tombstones never exceed half the
            // heap beyond the small-rebuild floor.
            assert!(
                q.tombstones <= COMPACT_FLOOR || q.tombstones * 2 <= q.heap.len(),
                "tombstones {} vs heap {}",
                q.tombstones,
                q.heap.len()
            );
        }
        assert_eq!(q.len(), 1);
        assert!(
            q.heap.len() <= 1 + COMPACT_FLOOR,
            "cancelled events must not linger in the heap: {}",
            q.heap.len()
        );
        assert_eq!(q.pop().unwrap().payload, 0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn property_pop_order_is_sorted() {
        crate::util::prop::check("event queue pops in time order", |rng| {
            let mut q = EventQueue::new();
            let n = 1 + rng.usize_below(100);
            for _ in 0..n {
                q.schedule(SimTime::ns(rng.below(1000)), ());
            }
            let mut last = (SimTime::ZERO, 0u64);
            while let Some(e) = q.pop() {
                assert!(
                    (e.at, e.seq) >= last,
                    "out of order: {:?} after {:?}",
                    (e.at, e.seq),
                    last
                );
                last = (e.at, e.seq);
            }
        });
    }

    #[test]
    fn property_audit_holds_under_random_interleavings() {
        // EventQueue::check_invariants must hold after every mutation,
        // and the fingerprint must be a pure function of the live set.
        crate::util::prop::check("EventQueue audit under schedule/cancel/pop", |rng| {
            let mut q: EventQueue<u64> = EventQueue::new();
            let mut twin: EventQueue<u64> = EventQueue::new();
            let mut ids = Vec::new();
            for step in 0..200u64 {
                match rng.below(4) {
                    0 | 1 => {
                        let at = SimTime::ns(rng.below(50));
                        ids.push(q.schedule(at, step));
                        twin.schedule(at, step);
                    }
                    2 => {
                        if !ids.is_empty() {
                            let i = rng.usize_below(ids.len());
                            let id = ids.swap_remove(i);
                            let a = q.cancel(id);
                            let b = twin.cancel(id);
                            assert_eq!(a, b);
                        }
                    }
                    _ => {
                        let a = q.pop().map(|e| (e.at, e.seq, e.payload));
                        let b = twin.pop().map(|e| (e.at, e.seq, e.payload));
                        assert_eq!(a, b);
                    }
                }
                q.check_invariants().unwrap();
                q.audit().unwrap();
                assert_eq!(
                    crate::analysis::audit::fingerprint_of(&q),
                    crate::analysis::audit::fingerprint_of(&twin),
                    "same op history must fingerprint identically"
                );
            }
        });
    }

    #[test]
    fn fingerprint_tracks_the_live_set_only() {
        let mut a: EventQueue<&str> = EventQueue::new();
        let mut b: EventQueue<&str> = EventQueue::new();
        a.schedule(SimTime::ms(1), "x");
        let dead = a.schedule(SimTime::ms(2), "y");
        assert!(a.cancel(dead));
        b.schedule(SimTime::ms(1), "x");
        b.schedule(SimTime::ms(2), "y");
        // Different live sets -> different fingerprints.
        assert_ne!(
            crate::analysis::audit::fingerprint_of(&a),
            crate::analysis::audit::fingerprint_of(&b)
        );
        b.pop();
        // Still different: b holds ("2ms", seq 1), a holds ("1ms", seq 0).
        assert_ne!(
            crate::analysis::audit::fingerprint_of(&a),
            crate::analysis::audit::fingerprint_of(&b)
        );
        assert_eq!(a.component(), "event-queue");
    }

    #[test]
    fn property_matches_reference_model() {
        // The slab queue must behave exactly like a naive sorted-Vec
        // model under random schedule/cancel/pop interleavings.
        crate::util::prop::check("slab queue == reference model", |rng| {
            let mut q = EventQueue::new();
            // (id, at, seq, payload) of still-pending events.
            let mut model: Vec<(u64, SimTime, u64, u64)> = Vec::new();
            let mut seq = 0u64;
            for step in 0..200u64 {
                match rng.below(4) {
                    0 | 1 => {
                        let at = SimTime::ns(rng.below(50));
                        let id = q.schedule(at, step);
                        model.push((id, at, seq, step));
                        seq += 1;
                    }
                    2 => {
                        if !model.is_empty() {
                            let i = rng.usize_below(model.len());
                            let (id, ..) = model.swap_remove(i);
                            assert!(q.cancel(id));
                            assert!(!q.cancel(id));
                        }
                    }
                    _ => {
                        let want = model
                            .iter()
                            .enumerate()
                            .min_by_key(|(_, &(_, at, s, _))| (at, s))
                            .map(|(i, _)| i);
                        match want {
                            Some(i) => {
                                let (_, at, s, payload) = model.remove(i);
                                let got = q.pop().unwrap();
                                assert_eq!((got.at, got.seq, got.payload), (at, s, payload));
                            }
                            None => assert!(q.pop().is_none()),
                        }
                    }
                }
                let next = model.iter().map(|&(_, at, s, _)| (at, s)).min();
                assert_eq!(q.peek_time(), next.map(|(at, _)| at));
                assert_eq!(q.len(), model.len());
            }
        });
    }
}
