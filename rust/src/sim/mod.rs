//! Discrete-event simulation core.
//!
//! Two complementary primitives drive every hardware model in the CSD
//! substrate:
//!
//! * [`Timeline`] / [`MultiTimeline`] — *resource timelines*: FIFO
//!   service resources whose next-free timestamp advances as work is
//!   scheduled on them. Flash channels, the PCIe link, the ISP cores
//!   and the host CPU are all timelines; queueing delay falls out of
//!   `max(now, next_free)`.
//! * [`EventQueue`] — a slab-backed, time-ordered event queue
//!   (deterministic FIFO tie-break, O(1) cancellation with bounded
//!   tombstones) for background processes that are not simple FIFO
//!   service: garbage collection, DLM heartbeats, fault injection.
//!
//! Simulated time is [`SimTime`] nanoseconds. All models are
//! deterministic: same seed + same schedule → identical timelines.

mod events;
mod resource;
mod time;

pub use events::{DrainUntil, EventQueue, ScheduledEvent};
pub use resource::{MultiTimeline, Timeline};
pub use time::SimTime;
