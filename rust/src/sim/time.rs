//! Simulated time: nanosecond ticks with ergonomic constructors.

use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, Mul, Sub};

/// A point in (or span of) simulated time, in nanoseconds.
///
/// One type serves both instants and durations — the arithmetic the
/// models do (max-with-next-free, accumulate-busy) never benefits from
/// the instant/duration split, and a single u64 keeps the timelines
/// allocation-free.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn ns(n: u64) -> Self {
        SimTime(n)
    }

    pub fn us(n: u64) -> Self {
        SimTime(n * 1_000)
    }

    pub fn ms(n: u64) -> Self {
        SimTime(n * 1_000_000)
    }

    pub fn secs(n: u64) -> Self {
        SimTime(n * 1_000_000_000)
    }

    /// From fractional seconds (cost-model outputs).
    pub fn from_secs_f64(s: f64) -> Self {
        assert!(s >= 0.0 && s.is_finite(), "invalid duration {s}");
        SimTime((s * 1e9).round() as u64)
    }

    pub fn as_ns(self) -> u64 {
        self.0
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }

    pub fn saturating_sub(self, other: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(other.0))
    }
}

impl Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

impl Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        assert!(self.0 >= rhs.0, "SimTime underflow: {} - {}", self.0, rhs.0);
        SimTime(self.0 - rhs.0)
    }
}

impl Mul<u64> for SimTime {
    type Output = SimTime;
    fn mul(self, rhs: u64) -> SimTime {
        SimTime(self.0 * rhs)
    }
}

impl Div<u64> for SimTime {
    type Output = SimTime;
    fn div(self, rhs: u64) -> SimTime {
        SimTime(self.0 / rhs)
    }
}

impl Sum for SimTime {
    fn sum<I: Iterator<Item = SimTime>>(iter: I) -> SimTime {
        SimTime(iter.map(|t| t.0).sum())
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let ns = self.0;
        if ns >= 1_000_000_000 {
            write!(f, "{:.3}s", ns as f64 / 1e9)
        } else if ns >= 1_000_000 {
            write!(f, "{:.3}ms", ns as f64 / 1e6)
        } else if ns >= 1_000 {
            write!(f, "{:.3}us", ns as f64 / 1e3)
        } else {
            write!(f, "{ns}ns")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_scale() {
        assert_eq!(SimTime::us(1), SimTime::ns(1000));
        assert_eq!(SimTime::ms(1), SimTime::us(1000));
        assert_eq!(SimTime::secs(1), SimTime::ms(1000));
        assert_eq!(SimTime::from_secs_f64(0.5), SimTime::ms(500));
    }

    #[test]
    fn arithmetic() {
        let t = SimTime::ms(2) + SimTime::ms(3);
        assert_eq!(t, SimTime::ms(5));
        assert_eq!(t - SimTime::ms(1), SimTime::ms(4));
        assert_eq!(t * 2, SimTime::ms(10));
        assert_eq!(t / 5, SimTime::ms(1));
        assert_eq!(SimTime::ms(1).saturating_sub(SimTime::ms(2)), SimTime::ZERO);
    }

    #[test]
    #[should_panic(expected = "underflow")]
    fn sub_underflow_panics() {
        let _ = SimTime::ms(1) - SimTime::ms(2);
    }

    #[test]
    fn display_units() {
        assert_eq!(SimTime::ns(5).to_string(), "5ns");
        assert_eq!(SimTime::us(2).to_string(), "2.000us");
        assert_eq!(SimTime::secs(3).to_string(), "3.000s");
    }
}
