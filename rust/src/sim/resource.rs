//! FIFO service resources as timelines.

use super::SimTime;

/// A single-server FIFO resource (one flash channel, one PCIe lane
/// group, one CPU hard-slot).
///
/// `schedule(now, service)` books the next service slot: the operation
/// starts at `max(now, next_free)`, occupies the server for `service`,
/// and the call returns (start, completion). Busy time and operation
/// counts are accumulated for utilization reporting.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    next_free: SimTime,
    busy: SimTime,
    ops: u64,
    /// Start of the current contiguous busy run ending at `next_free`.
    run_start: SimTime,
    /// Busy time completed before `run_start` (earlier runs).
    busy_before_run: SimTime,
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    /// Book `service` time beginning no earlier than `now`.
    pub fn schedule(&mut self, now: SimTime, service: SimTime) -> (SimTime, SimTime) {
        let start = self.next_free.max(now);
        if start > self.next_free {
            // Idle gap: a new contiguous busy run begins here.
            self.busy_before_run = self.busy;
            self.run_start = start;
        }
        let done = start + service;
        self.next_free = done;
        self.busy += service;
        self.ops += 1;
        (start, done)
    }

    /// When the resource next becomes idle.
    pub fn next_free(&self) -> SimTime {
        self.next_free
    }

    /// Total busy time booked so far.
    pub fn busy_time(&self) -> SimTime {
        self.busy
    }

    pub fn ops(&self) -> u64 {
        self.ops
    }

    /// Utilization over [0, horizon]: busy time *completed within* the
    /// horizon, divided by the horizon.
    ///
    /// A backlogged resource has service booked beyond the horizon
    /// (`next_free > horizon`); that tail has not executed yet at the
    /// horizon, so it is excluded — a saturated server reports exactly
    /// 1.0, never more. The timeline tracks the final contiguous busy
    /// run (`run_start..next_free`), so the result is exact for any
    /// horizon at or after that run's start; for a horizon inside an
    /// earlier idle gap only the coarse bound `min(earlier busy,
    /// horizon)` is available (full interval history is not kept), and
    /// the result is capped at 1.0 either way.
    pub fn utilization(&self, horizon: SimTime) -> f64 {
        if horizon == SimTime::ZERO {
            return 0.0;
        }
        let within = if horizon >= self.next_free {
            self.busy
        } else if horizon >= self.run_start {
            self.busy_before_run + (horizon - self.run_start)
        } else {
            self.busy_before_run.min(horizon)
        };
        within.min(horizon).as_ns() as f64 / horizon.as_ns() as f64
    }
}

/// `k` identical parallel servers (flash channels, ISP cores): each
/// operation is dispatched to the earliest-free server.
#[derive(Debug, Clone)]
pub struct MultiTimeline {
    servers: Vec<Timeline>,
}

impl MultiTimeline {
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "MultiTimeline needs at least one server");
        Self { servers: vec![Timeline::new(); k] }
    }

    pub fn num_servers(&self) -> usize {
        self.servers.len()
    }

    /// Schedule on the earliest-free server; returns (server, start, done).
    pub fn schedule(&mut self, now: SimTime, service: SimTime) -> (usize, SimTime, SimTime) {
        let (idx, _) = self
            .servers
            .iter()
            .enumerate()
            .min_by_key(|(i, s)| (s.next_free(), *i))
            .expect("non-empty");
        let (start, done) = self.servers[idx].schedule(now, service);
        (idx, start, done)
    }

    /// Schedule on a *specific* server (addressed resources, e.g. the
    /// flash channel a physical page lives on).
    pub fn schedule_on(
        &mut self,
        server: usize,
        now: SimTime,
        service: SimTime,
    ) -> (SimTime, SimTime) {
        self.servers[server].schedule(now, service)
    }

    pub fn server(&self, idx: usize) -> &Timeline {
        &self.servers[idx]
    }

    pub fn total_busy(&self) -> SimTime {
        self.servers.iter().map(Timeline::busy_time).sum()
    }

    pub fn total_ops(&self) -> u64 {
        self.servers.iter().map(Timeline::ops).sum()
    }

    /// Aggregate utilization over [0, horizon] (mean across servers,
    /// each clamped to work completed within the horizon — see
    /// [`Timeline::utilization`]).
    pub fn utilization(&self, horizon: SimTime) -> f64 {
        if horizon == SimTime::ZERO {
            return 0.0;
        }
        self.servers.iter().map(|s| s.utilization(horizon)).sum::<f64>()
            / self.servers.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_queueing_delay() {
        let mut t = Timeline::new();
        let (s1, d1) = t.schedule(SimTime::ZERO, SimTime::ms(10));
        assert_eq!((s1, d1), (SimTime::ZERO, SimTime::ms(10)));
        // Arrives at 2ms but the server is busy until 10ms.
        let (s2, d2) = t.schedule(SimTime::ms(2), SimTime::ms(5));
        assert_eq!((s2, d2), (SimTime::ms(10), SimTime::ms(15)));
        // Arrives after idle gap: starts immediately.
        let (s3, _) = t.schedule(SimTime::ms(100), SimTime::ms(1));
        assert_eq!(s3, SimTime::ms(100));
        assert_eq!(t.busy_time(), SimTime::ms(16));
        assert_eq!(t.ops(), 3);
    }

    #[test]
    fn utilization_accounts_idle() {
        let mut t = Timeline::new();
        t.schedule(SimTime::ZERO, SimTime::ms(25));
        assert!((t.utilization(SimTime::ms(100)) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn utilization_clamps_backlog_to_one() {
        // Three 10ms ops booked at t=0 back up to 30ms of busy time;
        // over a 10ms horizon only 10ms has actually executed, so a
        // saturated server reports exactly 1.0 — never 3.0.
        let mut t = Timeline::new();
        for _ in 0..3 {
            t.schedule(SimTime::ZERO, SimTime::ms(10));
        }
        assert_eq!(t.busy_time(), SimTime::ms(30));
        assert!((t.utilization(SimTime::ms(10)) - 1.0).abs() < 1e-12);
        // Mid-backlog horizon: 15ms of a 15ms window was busy.
        assert!((t.utilization(SimTime::ms(15)) - 1.0).abs() < 1e-12);
        // Horizon past the backlog: plain busy/horizon again.
        assert!((t.utilization(SimTime::ms(60)) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn utilization_handles_idle_gap_before_final_run() {
        // 10ms op at t=0, then (after a long gap) a 10ms op at t=100.
        let mut t = Timeline::new();
        t.schedule(SimTime::ZERO, SimTime::ms(10));
        t.schedule(SimTime::ms(100), SimTime::ms(10));
        // Horizon inside the gap: only the first op's 10ms was busy.
        assert!((t.utilization(SimTime::ms(50)) - 0.2).abs() < 1e-12);
        // Horizon inside the final run: exact (10 + 5 of 105).
        assert!((t.utilization(SimTime::ms(105)) - 15.0 / 105.0).abs() < 1e-12);
        // Horizon past everything: total busy over horizon.
        assert!((t.utilization(SimTime::ms(200)) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn multi_utilization_clamps_per_server() {
        let mut m = MultiTimeline::new(2);
        // Server 0 backlogged 4x past the horizon, server 1 idle.
        for _ in 0..4 {
            m.schedule_on(0, SimTime::ZERO, SimTime::ms(10));
        }
        let u = m.utilization(SimTime::ms(10));
        assert!((u - 0.5).abs() < 1e-12, "mean of clamped 1.0 and 0.0, got {u}");
    }

    #[test]
    fn multi_balances_to_earliest_free() {
        let mut m = MultiTimeline::new(2);
        let (a, _, _) = m.schedule(SimTime::ZERO, SimTime::ms(10));
        let (b, s, _) = m.schedule(SimTime::ZERO, SimTime::ms(10));
        assert_ne!(a, b, "second op must go to the idle server");
        assert_eq!(s, SimTime::ZERO);
        // Both busy; third op queues on whichever frees first.
        let (_, s3, _) = m.schedule(SimTime::ZERO, SimTime::ms(1));
        assert_eq!(s3, SimTime::ms(10));
    }

    #[test]
    fn addressed_scheduling_pins_server() {
        let mut m = MultiTimeline::new(4);
        m.schedule_on(3, SimTime::ZERO, SimTime::ms(7));
        assert_eq!(m.server(3).busy_time(), SimTime::ms(7));
        assert_eq!(m.server(0).busy_time(), SimTime::ZERO);
        assert_eq!(m.total_ops(), 1);
    }
}
