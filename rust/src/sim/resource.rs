//! FIFO service resources as timelines.

use super::SimTime;

/// A single-server FIFO resource (one flash channel, one PCIe lane
/// group, one CPU hard-slot).
///
/// `schedule(now, service)` books the next service slot: the operation
/// starts at `max(now, next_free)`, occupies the server for `service`,
/// and the call returns (start, completion). Busy time and operation
/// counts are accumulated for utilization reporting.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    next_free: SimTime,
    busy: SimTime,
    ops: u64,
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    /// Book `service` time beginning no earlier than `now`.
    pub fn schedule(&mut self, now: SimTime, service: SimTime) -> (SimTime, SimTime) {
        let start = self.next_free.max(now);
        let done = start + service;
        self.next_free = done;
        self.busy += service;
        self.ops += 1;
        (start, done)
    }

    /// When the resource next becomes idle.
    pub fn next_free(&self) -> SimTime {
        self.next_free
    }

    /// Total busy time booked so far.
    pub fn busy_time(&self) -> SimTime {
        self.busy
    }

    pub fn ops(&self) -> u64 {
        self.ops
    }

    /// Utilization over [0, horizon].
    pub fn utilization(&self, horizon: SimTime) -> f64 {
        if horizon == SimTime::ZERO {
            return 0.0;
        }
        self.busy.as_ns() as f64 / horizon.as_ns() as f64
    }
}

/// `k` identical parallel servers (flash channels, ISP cores): each
/// operation is dispatched to the earliest-free server.
#[derive(Debug, Clone)]
pub struct MultiTimeline {
    servers: Vec<Timeline>,
}

impl MultiTimeline {
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "MultiTimeline needs at least one server");
        Self { servers: vec![Timeline::new(); k] }
    }

    pub fn num_servers(&self) -> usize {
        self.servers.len()
    }

    /// Schedule on the earliest-free server; returns (server, start, done).
    pub fn schedule(&mut self, now: SimTime, service: SimTime) -> (usize, SimTime, SimTime) {
        let (idx, _) = self
            .servers
            .iter()
            .enumerate()
            .min_by_key(|(i, s)| (s.next_free(), *i))
            .expect("non-empty");
        let (start, done) = self.servers[idx].schedule(now, service);
        (idx, start, done)
    }

    /// Schedule on a *specific* server (addressed resources, e.g. the
    /// flash channel a physical page lives on).
    pub fn schedule_on(
        &mut self,
        server: usize,
        now: SimTime,
        service: SimTime,
    ) -> (SimTime, SimTime) {
        self.servers[server].schedule(now, service)
    }

    pub fn server(&self, idx: usize) -> &Timeline {
        &self.servers[idx]
    }

    pub fn total_busy(&self) -> SimTime {
        self.servers.iter().map(Timeline::busy_time).sum()
    }

    pub fn total_ops(&self) -> u64 {
        self.servers.iter().map(Timeline::ops).sum()
    }

    /// Aggregate utilization over [0, horizon] (mean across servers).
    pub fn utilization(&self, horizon: SimTime) -> f64 {
        if horizon == SimTime::ZERO {
            return 0.0;
        }
        self.total_busy().as_ns() as f64
            / (horizon.as_ns() as f64 * self.servers.len() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_queueing_delay() {
        let mut t = Timeline::new();
        let (s1, d1) = t.schedule(SimTime::ZERO, SimTime::ms(10));
        assert_eq!((s1, d1), (SimTime::ZERO, SimTime::ms(10)));
        // Arrives at 2ms but the server is busy until 10ms.
        let (s2, d2) = t.schedule(SimTime::ms(2), SimTime::ms(5));
        assert_eq!((s2, d2), (SimTime::ms(10), SimTime::ms(15)));
        // Arrives after idle gap: starts immediately.
        let (s3, _) = t.schedule(SimTime::ms(100), SimTime::ms(1));
        assert_eq!(s3, SimTime::ms(100));
        assert_eq!(t.busy_time(), SimTime::ms(16));
        assert_eq!(t.ops(), 3);
    }

    #[test]
    fn utilization_accounts_idle() {
        let mut t = Timeline::new();
        t.schedule(SimTime::ZERO, SimTime::ms(25));
        assert!((t.utilization(SimTime::ms(100)) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn multi_balances_to_earliest_free() {
        let mut m = MultiTimeline::new(2);
        let (a, _, _) = m.schedule(SimTime::ZERO, SimTime::ms(10));
        let (b, s, _) = m.schedule(SimTime::ZERO, SimTime::ms(10));
        assert_ne!(a, b, "second op must go to the idle server");
        assert_eq!(s, SimTime::ZERO);
        // Both busy; third op queues on whichever frees first.
        let (_, s3, _) = m.schedule(SimTime::ZERO, SimTime::ms(1));
        assert_eq!(s3, SimTime::ms(10));
    }

    #[test]
    fn addressed_scheduling_pins_server() {
        let mut m = MultiTimeline::new(4);
        m.schedule_on(3, SimTime::ZERO, SimTime::ms(7));
        assert_eq!(m.server(3).busy_time(), SimTime::ms(7));
        assert_eq!(m.server(0).busy_time(), SimTime::ZERO);
        assert_eq!(m.total_ops(), 1);
    }
}
