//! Flash translation layer: page-level mapping, garbage collection and
//! wear leveling — the BE firmware functions the paper lists (§III).
//!
//! The FTL owns the [`FlashArray`] (timing) and the [`Ecc`] decoder
//! (reliability): a logical read/write is translated, scheduled on the
//! array, decoded, and accounted. Data *content* is modeled as a u64
//! tag per logical page — enough to prove end-to-end integrity without
//! simulating 16 KiB payloads.
//!
//! The data path is **extent-based** (DESIGN.md §Perf, "Extent I/O"):
//! [`Ftl::write_run`] / [`Ftl::read_run`] move whole logical runs with
//! one bounds check, batched stats and (where pages are physically
//! consecutive) coalesced flash bookings, while `write`/`read` remain
//! as thin len-1 wrappers. Results are bit-identical to the per-page
//! loops, which stay in-tree as the property-test oracle. Block
//! allocation pops per-channel free lists in O(1), and GC victim
//! selection reads an incrementally-maintained cost-benefit index
//! instead of scanning every block per reclaimed victim.

use std::cmp::Reverse;
use std::collections::{BTreeSet, VecDeque};

use anyhow::{bail, Result};

use crate::sim::SimTime;

use super::ecc::{Ecc, EccConfig, EccOutcome, EccStats};
use super::flash::{FlashArray, FlashConfig, PhysAddr};

#[derive(Debug, Clone)]
pub struct FtlConfig {
    pub flash: FlashConfig,
    pub ecc: EccConfig,
    /// Fraction of physical blocks held back as over-provisioning.
    pub overprovision: f64,
    /// GC starts when the free-block pool drops below this count.
    pub gc_low_water: usize,
    /// GC stops once the pool recovers to this count.
    pub gc_high_water: usize,
    /// Per-block P/E endurance budget: a block at this cycle count
    /// fails its next erase and retires into the bad-block list.
    /// `0` = unlimited (endurance modeling off, the default).
    pub pe_limit: u32,
    /// Read-retry ladder depth on an uncorrectable page (`0` = off:
    /// the first failed decode is final, exactly the legacy behavior).
    pub read_retries: u32,
    /// Added latency per retry rung; rung `r` costs `r * retry_step`
    /// on top of its decode latency (voltage-shift sweeps get slower
    /// as they go deeper).
    pub retry_step: SimTime,
}

impl Default for FtlConfig {
    fn default() -> Self {
        Self {
            flash: FlashConfig::default(),
            ecc: EccConfig::default(),
            overprovision: 0.125,
            gc_low_water: 8,
            gc_high_water: 16,
            pe_limit: 0,
            read_retries: 0,
            retry_step: SimTime::us(100),
        }
    }
}

/// Typed read-path failure: the recovery code above the FTL matches on
/// these variants instead of message strings. `Display` reproduces the
/// legacy messages byte-for-byte, so the bulk-vs-per-page string
/// equality property and existing `.contains(...)` assertions hold
/// unchanged.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadError {
    /// Single-page lpn outside the logical space.
    LpnOutOfRange { lpn: u32 },
    /// Run bounds outside the logical space.
    RunOutOfRange { lpn0: u32, end: u64, logical_pages: usize },
    /// Never written, or trimmed since.
    Unwritten { lpn: u32 },
    /// ECC gave up after the first decode plus every configured retry
    /// rung; `block`/`pe`/`retries` carry the context the endurance
    /// pipeline escalates with.
    Uncorrectable { lpn: u32, block: u32, pe: u32, retries: u32 },
}

impl std::fmt::Display for ReadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            ReadError::LpnOutOfRange { lpn } => write!(f, "lpn {lpn} out of range"),
            ReadError::RunOutOfRange { lpn0, end, logical_pages } => write!(
                f,
                "lpn run {lpn0}..{end} out of range (logical pages {logical_pages})"
            ),
            ReadError::Unwritten { lpn } => write!(f, "lpn {lpn} never written"),
            ReadError::Uncorrectable { lpn, pe, .. } => {
                write!(f, "uncorrectable ECC error reading lpn {lpn} (pe={pe})")
            }
        }
    }
}

impl std::error::Error for ReadError {}

/// Typed end-of-life condition: block retirement has shrunk the free
/// pool below what GC needs to keep allocating. The fleet layer
/// downcasts to this to trigger drain → replace → re-carve instead of
/// treating the device error as fatal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeviceWornOut {
    pub free_blocks: usize,
    pub retired_blocks: usize,
    pub gc_low_water: usize,
}

impl std::fmt::Display for DeviceWornOut {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "device worn out: {} free blocks left (GC headroom {}) after {} retired",
            self.free_blocks, self.gc_low_water, self.retired_blocks
        )
    }
}

impl std::error::Error for DeviceWornOut {}

/// Endurance & wear counters surfaced to the fleet reports.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WearReport {
    /// Block erases performed by GC.
    pub erases: u64,
    /// Blocks retired into the bad-block list (capacity lost).
    pub retired_blocks: u64,
    /// Blocks that needed at least one read-retry recovery.
    pub suspect_blocks: u64,
    /// Pages recovered (and relocated) by the read-retry ladder.
    pub retry_recoveries: u64,
    /// Write amplification factor.
    pub waf: f64,
}

impl WearReport {
    /// Element-wise merge (waf is re-derived by callers that need the
    /// fleet-level ratio; here it keeps the max as a worst-device
    /// indicator).
    pub fn merge(&mut self, other: WearReport) {
        self.erases += other.erases;
        self.retired_blocks += other.retired_blocks;
        self.suspect_blocks += other.suspect_blocks;
        self.retry_recoveries += other.retry_recoveries;
        self.waf = self.waf.max(other.waf);
    }
}

#[derive(Debug, Clone)]
struct BlockInfo {
    /// validity bitmap per page
    valid: Vec<bool>,
    valid_count: u32,
    /// next page index to program (append-only within a block)
    write_ptr: u32,
    pe_cycles: u32,
    /// Read-retry recoveries charged to this block (bad-block
    /// management watches repeat offenders).
    suspect: u32,
}

impl BlockInfo {
    fn new(pages: usize) -> Self {
        Self { valid: vec![false; pages], valid_count: 0, write_ptr: 0, pe_cycles: 0, suspect: 0 }
    }

    fn is_full(&self, pages: usize) -> bool {
        self.write_ptr as usize >= pages
    }
}

/// Free-block pool: one FIFO queue per channel, so a channel-local
/// refill is O(1) instead of the old single-queue `iter().position` +
/// mid-queue `VecDeque::remove` scan (O(free) with an element shift).
/// A monotone sequence number per insertion preserves the old global
/// FIFO order, and a membership bitmap gives O(1) `contains` for the
/// GC victim scan.
#[derive(Debug, Clone)]
struct FreeBlocks {
    /// `(insertion seq, block id)` per channel, FIFO.
    per_channel: Vec<VecDeque<(u64, u32)>>,
    /// O(1) membership, mirrors the queues.
    member: Vec<bool>,
    len: usize,
    next_seq: u64,
}

impl FreeBlocks {
    fn new(channels: usize, total_blocks: usize) -> Self {
        Self {
            per_channel: vec![VecDeque::new(); channels],
            member: vec![false; total_blocks],
            len: 0,
            next_seq: 0,
        }
    }

    fn len(&self) -> usize {
        self.len
    }

    fn contains(&self, block: u32) -> bool {
        self.member[block as usize]
    }

    fn push(&mut self, channel: usize, block: u32) {
        debug_assert!(!self.member[block as usize], "block {block} freed twice");
        self.per_channel[channel].push_back((self.next_seq, block));
        self.next_seq += 1;
        self.member[block as usize] = true;
        self.len += 1;
    }

    /// Oldest free block on `channel` (the block the old global-queue
    /// scan would have found first).
    fn pop_channel(&mut self, channel: usize) -> Option<u32> {
        let (_, block) = self.per_channel[channel].pop_front()?;
        self.member[block as usize] = false;
        self.len -= 1;
        Some(block)
    }

    /// Globally oldest free block across all channels (the old
    /// `pop_front`) — O(channels), only reached when every channel's
    /// local pool is empty.
    fn pop_oldest(&mut self) -> Option<u32> {
        let ch = self
            .per_channel
            .iter()
            .enumerate()
            .filter_map(|(ch, q)| q.front().map(|&(seq, _)| (seq, ch)))
            .min()
            .map(|(_, ch)| ch)?;
        self.pop_channel(ch)
    }

    /// Structural coherence, reached via `Ftl::check_invariants` (the
    /// audit path — this promotes the double-free `debug_assert!` in
    /// [`FreeBlocks::push`] into release-mode `--audit` runs): the
    /// queues, the membership bitmap and the length counter must all
    /// describe the same duplicate-free set, with every insertion seq
    /// already issued.
    fn check_invariants(&self) -> Result<()> {
        let queued: usize = self.per_channel.iter().map(|q| q.len()).sum();
        anyhow::ensure!(queued == self.len, "free len {} != queued {queued}", self.len);
        let mut seen = vec![false; self.member.len()];
        for q in &self.per_channel {
            for &(seq, block) in q {
                anyhow::ensure!(
                    seq < self.next_seq,
                    "free-list seq {seq} >= next_seq {}",
                    self.next_seq
                );
                let b = block as usize;
                anyhow::ensure!(b < self.member.len(), "free block {block} out of range");
                anyhow::ensure!(!seen[b], "block {block} on the free list twice");
                seen[b] = true;
                anyhow::ensure!(self.member[b], "queued block {block} not in the bitmap");
            }
        }
        let members = self.member.iter().filter(|&&m| m).count();
        anyhow::ensure!(members == self.len, "membership bitmap {members} != len {}", self.len);
        Ok(())
    }
}

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FtlStats {
    pub host_writes: u64,
    pub gc_writes: u64,
    pub gc_runs: u64,
    pub reads: u64,
    /// Logical pages unmapped via [`Ftl::trim`]/[`Ftl::trim_run`] (a
    /// cancelled job's shard teardown shows up here — the per-device
    /// side of the data plane's freed-page ledger).
    pub trims: u64,
}

impl FtlStats {
    /// Write amplification factor: (host + GC relocations) / host.
    pub fn waf(&self) -> f64 {
        if self.host_writes == 0 {
            return 1.0;
        }
        (self.host_writes + self.gc_writes) as f64 / self.host_writes as f64
    }
}

/// Outcome of a logical read.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReadResult {
    pub tag: u64,
    pub done: SimTime,
    pub ecc: EccOutcome,
}

/// Page-mapped FTL over a flash array.
pub struct Ftl {
    cfg: FtlConfig,
    flash: FlashArray,
    ecc: Ecc,
    /// logical page -> physical address
    l2p: Vec<Option<PhysAddr>>,
    /// physical page -> logical page (for GC relocation)
    p2l: Vec<Option<u32>>,
    /// content tags, indexed by logical page
    tags: Vec<u64>,
    blocks: Vec<BlockInfo>,
    free: FreeBlocks,
    /// per-channel active write block (stripes programs across channels)
    active: Vec<Option<u32>>,
    next_channel: usize,
    /// GC victim index: `(score key, Reverse(block id))` for every
    /// block with something to reclaim, kept in sync on every
    /// valid-count / write-pointer / erase change. `last()` (skipping
    /// active frontiers) is exactly the block the full cost-benefit
    /// scan picks, same tie-break — O(log blocks) instead of a scan
    /// per GC-loop iteration.
    victim_index: BTreeSet<(u64, Reverse<u32>)>,
    /// Each block's current key in `victim_index` (for O(log) removal).
    in_index: Vec<Option<u64>>,
    /// Blocks retired after an endurance-limit erase failure: out of
    /// the free pool and the victim index forever; capacity shrinks.
    bad_blocks: BTreeSet<u32>,
    /// Pages the read-retry ladder recovered (and relocated).
    retry_recoveries: u64,
    stats: FtlStats,
}

/// Run the read-retry ladder after a failed first decode: up to
/// `read_retries` re-decodes from the same RNG stream, rung `r`
/// costing `r * retry_step` plus its decode latency. Free function so
/// the bulk-read closure (which destructures `Ftl`) can call it too.
fn retry_ladder(ecc: &mut Ecc, cfg: &FtlConfig, pe: u32) -> (EccOutcome, SimTime, u32) {
    let mut extra = SimTime::ZERO;
    for rung in 1..=cfg.read_retries {
        let (out, lat) = ecc.retry_page(cfg.flash.page_bytes, pe);
        extra += cfg.retry_step * rung as u64 + lat;
        if out != EccOutcome::Uncorrectable {
            return (out, extra, rung);
        }
    }
    (EccOutcome::Uncorrectable, extra, cfg.read_retries)
}

/// Cost-benefit score with wear bias — the single expression both the
/// victim index and the reference full scan evaluate, so their floats
/// are bit-identical.
fn victim_score(pages: f64, b: &BlockInfo) -> f64 {
    let invalid = b.write_ptr as f64 - b.valid_count as f64;
    invalid / pages - 0.01 * b.pe_cycles as f64
}

/// Order-preserving u64 key for a finite f64 score (sign-flip trick):
/// `a < b  ⇔  key(a) < key(b)`. Scores are finite by construction and
/// `-0.0` cannot arise (`x - y` with `x == y` rounds to `+0.0`), so
/// key equality coincides with float equality — ties break exactly as
/// the scan's `partial_cmp` does.
fn score_key(score: f64) -> u64 {
    let bits = score.to_bits();
    if bits & (1 << 63) == 0 {
        bits | (1 << 63)
    } else {
        !bits
    }
}

impl Ftl {
    pub fn new(cfg: FtlConfig, seed: u64) -> Self {
        let total_blocks = cfg.flash.total_blocks();
        let pages = cfg.flash.pages_per_block;
        let logical_pages =
            ((cfg.flash.total_pages() as f64) * (1.0 - cfg.overprovision)) as usize;
        let flash = FlashArray::new(cfg.flash.clone());
        let ecc = Ecc::new(cfg.ecc.clone(), seed);
        let blocks = (0..total_blocks).map(|_| BlockInfo::new(pages)).collect();
        let channels = cfg.flash.channels;
        // Blocks enter the free pool in id order (the old global FIFO);
        // a block's channel is fixed by its id, so per-channel queues
        // filtered from that order are the same FIFO the old scan saw.
        let per_channel_blocks = cfg.flash.dies_per_channel * cfg.flash.blocks_per_die;
        let mut free = FreeBlocks::new(channels, total_blocks);
        for b in 0..total_blocks as u32 {
            free.push(b as usize / per_channel_blocks, b);
        }
        Self {
            l2p: vec![None; logical_pages],
            p2l: vec![None; cfg.flash.total_pages()],
            tags: vec![0; logical_pages],
            blocks,
            free,
            active: vec![None; channels],
            next_channel: 0,
            victim_index: BTreeSet::new(),
            in_index: vec![None; total_blocks],
            bad_blocks: BTreeSet::new(),
            retry_recoveries: 0,
            stats: FtlStats::default(),
            cfg,
            flash,
            ecc,
        }
    }

    pub fn logical_pages(&self) -> usize {
        self.l2p.len()
    }

    pub fn page_bytes(&self) -> usize {
        self.cfg.flash.page_bytes
    }

    pub fn stats(&self) -> FtlStats {
        self.stats
    }

    pub fn flash_stats(&self) -> super::flash::FlashStats {
        self.flash.stats()
    }

    pub fn free_block_count(&self) -> usize {
        self.free.len()
    }

    pub fn max_pe_cycles(&self) -> u32 {
        self.blocks.iter().map(|b| b.pe_cycles).max().unwrap_or(0)
    }

    pub fn min_pe_cycles(&self) -> u32 {
        self.blocks.iter().map(|b| b.pe_cycles).min().unwrap_or(0)
    }

    /// Decoder counters (corrected pages/bits, uncorrectables, retries).
    pub fn ecc_stats(&self) -> EccStats {
        self.ecc.stats()
    }

    /// Endurance & wear counters for the fleet reports.
    pub fn wear(&self) -> WearReport {
        WearReport {
            erases: self.flash.stats().erases,
            retired_blocks: self.bad_blocks.len() as u64,
            suspect_blocks: self.blocks.iter().filter(|b| b.suspect > 0).count() as u64,
            retry_recoveries: self.retry_recoveries,
            waf: self.stats.waf(),
        }
    }

    pub fn retired_block_count(&self) -> usize {
        self.bad_blocks.len()
    }

    /// True once block retirement has eaten into GC headroom: the
    /// device still serves reads but can no longer sustain writes —
    /// the fleet's cue to drain and replace. Checked *between*
    /// operations (every write path ends with `maybe_gc`, so a healthy
    /// device always rests at or above the low-water mark).
    pub fn worn_out(&self) -> bool {
        self.cfg.pe_limit > 0
            && !self.bad_blocks.is_empty()
            && self.free.len() < self.cfg.gc_low_water
    }

    // ---- address helpers ---------------------------------------------

    fn block_addr(&self, block_id: u32, page: u32) -> PhysAddr {
        let f = &self.cfg.flash;
        let per_die = f.blocks_per_die as u32;
        let per_channel = (f.dies_per_channel as u32) * per_die;
        PhysAddr {
            channel: (block_id / per_channel) as u16,
            die: ((block_id % per_channel) / per_die) as u16,
            block: block_id % per_die,
            page,
        }
    }

    fn phys_index(&self, addr: PhysAddr) -> usize {
        let f = &self.cfg.flash;
        (((addr.channel as usize * f.dies_per_channel + addr.die as usize)
            * f.blocks_per_die
            + addr.block as usize)
            * f.pages_per_block)
            + addr.page as usize
    }

    fn block_id_of(&self, addr: PhysAddr) -> u32 {
        let f = &self.cfg.flash;
        ((addr.channel as usize * f.dies_per_channel + addr.die as usize) * f.blocks_per_die
            + addr.block as usize) as u32
    }

    // ---- write path ---------------------------------------------------

    /// Allocate the next physical page on some channel's active block.
    ///
    /// A channel refill pops its own free queue in O(1); the old code
    /// scanned one global queue (`iter().position` + mid-queue
    /// `remove`) per refill, O(free blocks) with an element shift. The
    /// order is unchanged: each channel still receives its blocks in
    /// global free-FIFO order (erased blocks re-enter oldest-first, so
    /// wear keeps spreading).
    fn alloc_page(&mut self, now: SimTime) -> Result<PhysAddr> {
        let channels = self.active.len();
        for _ in 0..channels {
            let ch = self.next_channel;
            self.next_channel = (self.next_channel + 1) % channels;
            // Refill this channel's active block if missing/full.
            let need_new = match self.active[ch] {
                None => true,
                Some(b) => self.blocks[b as usize].is_full(self.cfg.flash.pages_per_block),
            };
            if need_new {
                match self.free.pop_channel(ch) {
                    Some(b) => self.active[ch] = Some(b),
                    None => continue, // this channel exhausted; try next
                }
            }
            let b = self.active[ch].unwrap();
            let info = &mut self.blocks[b as usize];
            let page = info.write_ptr;
            info.write_ptr += 1;
            return Ok(self.block_addr(b, page));
        }
        // No channel-local free block anywhere: take the globally
        // oldest free block (only reachable once every queue is empty,
        // kept for faithfulness to the old fallback).
        if let Some(b) = self.free.pop_oldest() {
            let ch = self.block_addr(b, 0).channel as usize;
            self.active[ch] = Some(b);
            let info = &mut self.blocks[b as usize];
            let page = info.write_ptr;
            info.write_ptr += 1;
            return Ok(self.block_addr(b, page));
        }
        let _ = now;
        // Distinguish "the workload genuinely outran over-provisioning"
        // (legacy message, unchanged) from "retirement shrank capacity
        // under the workload" (typed: the fleet drains and replaces).
        if !self.bad_blocks.is_empty() {
            return Err(DeviceWornOut {
                free_blocks: self.free.len(),
                retired_blocks: self.bad_blocks.len(),
                gc_low_water: self.cfg.gc_low_water,
            }
            .into());
        }
        bail!("flash out of space: no free blocks (GC failed to reclaim)")
    }

    /// Write `tag` to logical page `lpn`. Returns completion time.
    /// Thin len-1 wrapper over the run path.
    pub fn write(&mut self, lpn: u32, tag: u64, now: SimTime) -> Result<SimTime> {
        self.write_fill(lpn, 1, tag, now)
    }

    /// Bulk write: `tags[i]` lands on logical page `lpn0 + i`. One
    /// bounds check for the whole run; GC is checked at the same
    /// per-page points as the page-at-a-time path, so physical layout,
    /// timing and stats are bit-identical to a `write` loop. Returns
    /// the completion time of the last-finishing page.
    pub fn write_run(&mut self, lpn0: u32, tags: &[u64], now: SimTime) -> Result<SimTime> {
        self.write_run_with(lpn0, tags.len() as u32, |i| tags[i as usize], now)
    }

    /// Bulk write of `len` pages all tagged `tag` — the image-layout
    /// shape (every flash page of an image carries the image id),
    /// allocation-free at the call site.
    pub fn write_fill(&mut self, lpn0: u32, len: u32, tag: u64, now: SimTime) -> Result<SimTime> {
        self.write_run_with(lpn0, len, |_| tag, now)
    }

    fn write_run_with(
        &mut self,
        lpn0: u32,
        len: u32,
        tag_at: impl Fn(u32) -> u64,
        now: SimTime,
    ) -> Result<SimTime> {
        let end = lpn0 as u64 + len as u64;
        anyhow::ensure!(
            end <= self.l2p.len() as u64,
            "lpn run {lpn0}..{end} out of range (logical pages {})",
            self.l2p.len()
        );
        let mut done = now;
        for i in 0..len {
            done = done.max(self.write_inner(lpn0 + i, tag_at(i), now, false)?);
            self.maybe_gc(now)?;
        }
        Ok(done)
    }

    fn write_inner(&mut self, lpn: u32, tag: u64, now: SimTime, is_gc: bool) -> Result<SimTime> {
        // Invalidate the old location.
        if let Some(old) = self.l2p[lpn as usize] {
            let bid = self.block_id_of(old) as usize;
            let pidx = self.phys_index(old);
            let info = &mut self.blocks[bid];
            if info.valid[old.page as usize] {
                info.valid[old.page as usize] = false;
                info.valid_count -= 1;
            }
            self.p2l[pidx] = None;
            self.reindex(bid as u32);
        }
        let addr = self.alloc_page(now)?;
        let done = self.flash.program_page(addr, now);
        let bid = self.block_id_of(addr) as usize;
        let pidx = self.phys_index(addr);
        let info = &mut self.blocks[bid];
        info.valid[addr.page as usize] = true;
        info.valid_count += 1;
        self.l2p[lpn as usize] = Some(addr);
        self.p2l[pidx] = Some(lpn);
        self.tags[lpn as usize] = tag;
        self.reindex(bid as u32);
        if is_gc {
            self.stats.gc_writes += 1;
        } else {
            self.stats.host_writes += 1;
        }
        Ok(done)
    }

    // ---- trim path ------------------------------------------------------

    /// Unmap logical page `lpn` (NVMe Deallocate): the physical page is
    /// invalidated so GC can reclaim it, the mapping is dropped, and a
    /// subsequent read of the lpn errors like a never-written page.
    /// A pure metadata operation — no flash timing is booked. Returns
    /// `true` if the page was mapped. Thin len-1 wrapper over the run
    /// path.
    pub fn trim(&mut self, lpn: u32) -> Result<bool> {
        Ok(self.trim_run(lpn, 1)? == 1)
    }

    /// Trim `len` consecutive logical pages starting at `lpn0` (one
    /// bounds check for the run; the GC victim index is re-synced once
    /// per touched block, not per page — the extent discipline of
    /// DESIGN.md §Perf). Returns how many pages were actually mapped —
    /// the freed-page count the data-plane ledger records.
    pub fn trim_run(&mut self, lpn0: u32, len: u32) -> Result<u64> {
        let end = lpn0 as u64 + len as u64;
        anyhow::ensure!(
            end <= self.l2p.len() as u64,
            "lpn run {lpn0}..{end} out of range (logical pages {})",
            self.l2p.len()
        );
        let mut freed = 0u64;
        // A run touches few distinct blocks; a tiny linear-probed list
        // beats any set. Deferring reindex is safe: nothing allocates
        // or collects between the unmaps.
        let mut touched: Vec<u32> = Vec::new();
        for i in 0..len {
            let lpn = lpn0 + i;
            let Some(addr) = self.l2p[lpn as usize].take() else { continue };
            let bid = self.block_id_of(addr);
            let pidx = self.phys_index(addr);
            let info = &mut self.blocks[bid as usize];
            if info.valid[addr.page as usize] {
                info.valid[addr.page as usize] = false;
                info.valid_count -= 1;
            }
            self.p2l[pidx] = None;
            self.tags[lpn as usize] = 0;
            if !touched.contains(&bid) {
                touched.push(bid);
            }
            freed += 1;
        }
        self.stats.trims += freed;
        for bid in touched {
            self.reindex(bid);
        }
        Ok(freed)
    }

    // ---- read path ------------------------------------------------------

    /// Read logical page `lpn`: translate, schedule flash read, decode.
    /// A failed decode runs the read-retry ladder (if configured); a
    /// recovered page is relocated off its suspect block before the
    /// result returns.
    pub fn read(&mut self, lpn: u32, now: SimTime) -> Result<ReadResult> {
        if lpn as usize >= self.l2p.len() {
            return Err(ReadError::LpnOutOfRange { lpn }.into());
        }
        let addr = self.l2p[lpn as usize].ok_or(ReadError::Unwritten { lpn })?;
        let flash_done = self.flash.read_page(addr, now);
        let bid = self.block_id_of(addr);
        let pe = self.blocks[bid as usize].pe_cycles;
        let (mut ecc, mut ecc_lat) = self.ecc.decode_page(self.cfg.flash.page_bytes, pe);
        self.stats.reads += 1;
        if ecc == EccOutcome::Uncorrectable && self.cfg.read_retries > 0 {
            let (out, extra, _) = retry_ladder(&mut self.ecc, &self.cfg, pe);
            ecc = out;
            ecc_lat += extra;
            if out != EccOutcome::Uncorrectable {
                self.recover_page(lpn, now)?;
            }
        }
        if ecc == EccOutcome::Uncorrectable {
            return Err(ReadError::Uncorrectable {
                lpn,
                block: bid,
                pe,
                retries: self.cfg.read_retries,
            }
            .into());
        }
        Ok(ReadResult { tag: self.tags[lpn as usize], done: flash_done + ecc_lat, ecc })
    }

    /// A page the retry ladder pulled back from the brink: bump the
    /// block's suspect count and relocate the page to a fresh block
    /// (counted as background write amplification, like a GC move).
    fn recover_page(&mut self, lpn: u32, now: SimTime) -> Result<()> {
        let addr = self.l2p[lpn as usize].expect("recovered page is mapped");
        let bid = self.block_id_of(addr) as usize;
        self.blocks[bid].suspect += 1;
        self.retry_recoveries += 1;
        let tag = self.tags[lpn as usize];
        self.write_inner(lpn, tag, now, true)?;
        self.maybe_gc(now)
    }

    /// Bulk read of `len` consecutive logical pages starting at `lpn0`.
    /// One bounds check for the run; per-page ECC decodes run in the
    /// same order as a `read` loop (the decoder is a seeded RNG, so
    /// order is part of the equivalence contract). Physically
    /// consecutive pages of one block coalesce their flash bookings
    /// ([`FlashArray::read_run_with`]) with identical completion
    /// times. Returns the completion time of the last-finishing page.
    pub fn read_run(&mut self, lpn0: u32, len: u32, now: SimTime) -> Result<SimTime> {
        self.read_run_with(lpn0, len, now, |_, _| ())
    }

    /// [`Self::read_run`] with a per-page completion callback
    /// `(offset in run, page done)`, invoked in run order — for
    /// callers that pipeline each page into another resource (e.g. the
    /// NVMe host path).
    ///
    /// Error paths match the per-page loop: an unwritten page or an
    /// uncorrectable ECC error aborts the run with the same message
    /// after booking the same pages (modulo the remainder of a
    /// coalesced stretch on the abandoned timeline — the run is dead
    /// either way).
    pub fn read_run_with(
        &mut self,
        lpn0: u32,
        len: u32,
        now: SimTime,
        mut per_page: impl FnMut(u32, SimTime),
    ) -> Result<SimTime> {
        let end = lpn0 as u64 + len as u64;
        if end > self.l2p.len() as u64 {
            return Err(ReadError::RunOutOfRange { lpn0, end, logical_pages: self.l2p.len() }
                .into());
        }
        let mut done = now;
        let mut i = 0u32;
        let mut recovered: Vec<u32> = Vec::new();
        while i < len {
            let lpn = lpn0 + i;
            let addr = self.l2p[lpn as usize].ok_or(ReadError::Unwritten { lpn })?;
            // Extend over physically consecutive pages of the same
            // block: exactly these coalesce into one die booking (plus
            // stretch-segmented bus bookings) without reordering any
            // timeline relative to the per-page loop.
            let mut k = 1u32;
            while i + k < len {
                match self.l2p[(lpn0 + i + k) as usize] {
                    Some(a)
                        if a.channel == addr.channel
                            && a.die == addr.die
                            && a.block == addr.block
                            && a.page == addr.page + k =>
                    {
                        k += 1;
                    }
                    _ => break,
                }
            }
            let bid = self.block_id_of(addr);
            let pe = self.blocks[bid as usize].pe_cycles;
            let page_bytes = self.cfg.flash.page_bytes;
            let Ftl { flash, ecc, stats, cfg, .. } = &mut *self;
            let mut bad = None;
            flash.read_run_with(addr, k, now, |j, flash_done| {
                if bad.is_some() {
                    return; // fatal ECC error: the run aborts below
                }
                let (mut out, mut ecc_lat) = ecc.decode_page(page_bytes, pe);
                stats.reads += 1;
                if out == EccOutcome::Uncorrectable && cfg.read_retries > 0 {
                    let (o2, extra, _) = retry_ladder(ecc, cfg, pe);
                    out = o2;
                    ecc_lat += extra;
                    if o2 != EccOutcome::Uncorrectable {
                        recovered.push(lpn + j);
                    }
                }
                if out == EccOutcome::Uncorrectable {
                    bad = Some(lpn + j);
                    return;
                }
                let page_done = flash_done + ecc_lat;
                done = done.max(page_done);
                per_page(i + j, page_done);
            });
            // Relocate recovered pages at stretch granularity — safe
            // here (nothing else holds flash state), and the page keeps
            // serving its old location until this point.
            for l in recovered.drain(..) {
                self.recover_page(l, now)?;
            }
            if let Some(l) = bad {
                return Err(ReadError::Uncorrectable {
                    lpn: l,
                    block: bid,
                    pe,
                    retries: self.cfg.read_retries,
                }
                .into());
            }
            i += k;
        }
        Ok(done)
    }

    // ---- garbage collection ----------------------------------------------

    fn maybe_gc(&mut self, now: SimTime) -> Result<()> {
        if self.free.len() >= self.cfg.gc_low_water {
            return Ok(());
        }
        self.stats.gc_runs += 1;
        while self.free.len() < self.cfg.gc_high_water {
            let Some(victim) = self.select_victim() else { break };
            self.collect_block(victim, now)?;
        }
        Ok(())
    }

    /// Re-sync one block's entry in the victim index after any change
    /// to its valid count, write pointer or P/E count. A block is
    /// indexed iff it has something to reclaim (`0 < valid < written`
    /// or fully invalid); write frontiers stay indexed and are skipped
    /// at selection time, because `active` membership changes without
    /// touching the block itself.
    fn reindex(&mut self, bid: u32) {
        if let Some(key) = self.in_index[bid as usize].take() {
            self.victim_index.remove(&(key, Reverse(bid)));
        }
        let b = &self.blocks[bid as usize];
        if b.write_ptr > 0 && b.valid_count < b.write_ptr {
            let key = score_key(victim_score(self.cfg.flash.pages_per_block as f64, b));
            self.victim_index.insert((key, Reverse(bid)));
            self.in_index[bid as usize] = Some(key);
        }
    }

    /// Cost-benefit victim selection with wear bias: prefer blocks with
    /// many invalid pages; among similar benefit prefer low wear so
    /// erases spread out (wear leveling). Served from the incremental
    /// index: walk down from the best score, skipping write frontiers
    /// (at most `channels` entries). Returns exactly the block
    /// [`Self::select_victim_scan`] picks.
    fn select_victim(&self) -> Option<u32> {
        self.victim_index
            .iter()
            .rev()
            .map(|&(_, Reverse(id))| id)
            .find(|id| !self.active.iter().any(|a| *a == Some(*id)))
    }

    /// Reference full-scan selection — the oracle the index is
    /// property-tested against (and the pre-index implementation).
    fn select_victim_scan(&self) -> Option<u32> {
        let pages = self.cfg.flash.pages_per_block as f64;
        let active: Vec<u32> = self.active.iter().flatten().copied().collect();
        self.blocks
            .iter()
            .enumerate()
            .filter(|(i, b)| {
                let id = *i as u32;
                b.write_ptr > 0                       // has been written
                    && !active.contains(&id)          // not a write frontier
                    && !self.free.contains(id)
                    && (b.valid_count as usize) < b.write_ptr as usize // something to reclaim
            })
            .map(|(i, b)| (i as u32, victim_score(pages, b)))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(b.0.cmp(&a.0)))
            .map(|(i, _)| i)
    }

    /// Current GC victim by the incremental index (bench/test hook).
    #[doc(hidden)]
    pub fn gc_victim(&self) -> Option<u32> {
        self.select_victim()
    }

    /// Current GC victim by the reference full scan (bench/test hook).
    #[doc(hidden)]
    pub fn gc_victim_scan(&self) -> Option<u32> {
        self.select_victim_scan()
    }

    fn collect_block(&mut self, victim: u32, now: SimTime) -> Result<()> {
        // Relocate valid pages.
        let pages = self.cfg.flash.pages_per_block;
        for p in 0..pages as u32 {
            let addr = self.block_addr(victim, p);
            if self.blocks[victim as usize].valid[p as usize] {
                let lpn = self.p2l[self.phys_index(addr)]
                    .ok_or_else(|| anyhow::anyhow!("valid page without p2l entry"))?;
                self.flash.read_page(addr, now);
                let tag = self.tags[lpn as usize];
                self.write_inner(lpn, tag, now, true)?;
            }
        }
        // Erase and return to the pool — unless the block has consumed
        // its endurance budget: then the erase fails and the block
        // retires into the bad-block list instead (valid pages were
        // already relocated above, so no data is stranded). Capacity
        // shrinks; the block never re-enters the free pool or the
        // victim index.
        let addr = self.block_addr(victim, 0);
        if self.cfg.pe_limit > 0 && self.blocks[victim as usize].pe_cycles >= self.cfg.pe_limit {
            let info = &mut self.blocks[victim as usize];
            info.valid.iter_mut().for_each(|v| *v = false);
            info.valid_count = 0;
            info.write_ptr = 0;
            self.bad_blocks.insert(victim);
            self.reindex(victim); // write_ptr == 0: drops out for good
            return Ok(());
        }
        self.flash.erase_block(addr, now);
        let info = &mut self.blocks[victim as usize];
        info.valid.iter_mut().for_each(|v| *v = false);
        info.valid_count = 0;
        info.write_ptr = 0;
        info.pe_cycles += 1;
        let ch = addr.channel as usize;
        self.free.push(ch, victim);
        self.reindex(victim); // reclaimed: drops out of the index
        Ok(())
    }

    /// Invariant checker used by the property tests: every l2p entry's
    /// target is marked valid and maps back via p2l; valid counts match.
    pub fn check_invariants(&self) -> Result<()> {
        for (lpn, entry) in self.l2p.iter().enumerate() {
            if let Some(addr) = entry {
                let bid = self.block_id_of(*addr) as usize;
                anyhow::ensure!(
                    self.blocks[bid].valid[addr.page as usize],
                    "lpn {lpn} maps to invalid page {addr:?}"
                );
                anyhow::ensure!(
                    self.p2l[self.phys_index(*addr)] == Some(lpn as u32),
                    "p2l mismatch at {addr:?}"
                );
            }
        }
        for (bid, info) in self.blocks.iter().enumerate() {
            let count = info.valid.iter().filter(|&&v| v).count() as u32;
            anyhow::ensure!(
                count == info.valid_count,
                "block {bid} valid_count {} != bitmap {count}",
                info.valid_count
            );
        }
        // Victim index mirrors block state: every block with something
        // to reclaim is indexed under its current score; nothing else.
        for (bid, info) in self.blocks.iter().enumerate() {
            let eligible = info.write_ptr > 0 && info.valid_count < info.write_ptr;
            match self.in_index[bid] {
                Some(key) => {
                    anyhow::ensure!(eligible, "block {bid} indexed but not eligible");
                    let want =
                        score_key(victim_score(self.cfg.flash.pages_per_block as f64, info));
                    anyhow::ensure!(key == want, "block {bid} indexed under a stale score");
                    anyhow::ensure!(
                        self.victim_index.contains(&(key, Reverse(bid as u32))),
                        "block {bid} missing from the victim index"
                    );
                }
                None => anyhow::ensure!(!eligible, "eligible block {bid} not indexed"),
            }
        }
        anyhow::ensure!(
            self.victim_index.len() == self.in_index.iter().flatten().count(),
            "victim index has orphan entries"
        );
        // Bad-block retirement invariants: a retired block is out of
        // every allocation structure forever, holds no data, and really
        // did exhaust its endurance budget. Capacity accounting is
        // conserved: free, retired and in-use blocks partition the
        // array.
        let mut in_use = 0usize;
        for (bid, info) in self.blocks.iter().enumerate() {
            let bid = bid as u32;
            let retired = self.bad_blocks.contains(&bid);
            let free = self.free.contains(bid);
            anyhow::ensure!(
                !(retired && free),
                "retired block {bid} re-entered the free pool"
            );
            if retired {
                anyhow::ensure!(
                    self.in_index[bid as usize].is_none(),
                    "retired block {bid} still indexed for GC"
                );
                anyhow::ensure!(
                    !self.active.iter().any(|a| *a == Some(bid)),
                    "retired block {bid} is an active write frontier"
                );
                anyhow::ensure!(
                    info.valid_count == 0 && info.write_ptr == 0,
                    "retired block {bid} still holds data"
                );
                if self.cfg.pe_limit > 0 {
                    anyhow::ensure!(
                        info.pe_cycles >= self.cfg.pe_limit,
                        "block {bid} retired below the P/E limit ({} < {})",
                        info.pe_cycles,
                        self.cfg.pe_limit
                    );
                }
            } else if !free {
                in_use += 1;
            }
        }
        anyhow::ensure!(
            self.free.len() + self.bad_blocks.len() + in_use == self.blocks.len(),
            "block accounting leak: {} free + {} retired + {} in use != {} total",
            self.free.len(),
            self.bad_blocks.len(),
            in_use,
            self.blocks.len()
        );
        // Free-list structural coherence (the release-mode promotion of
        // the double-free debug assertion), channel locality of every
        // queued block, and the flash array's byte ledger.
        self.free.check_invariants()?;
        for (ch, q) in self.free.per_channel.iter().enumerate() {
            for &(_, block) in q {
                anyhow::ensure!(
                    self.block_addr(block, 0).channel as usize == ch,
                    "block {block} queued on channel {ch} but lives on channel {}",
                    self.block_addr(block, 0).channel
                );
            }
        }
        self.flash.check_invariants()?;
        Ok(())
    }
}

impl crate::analysis::audit::Auditable for Ftl {
    fn component(&self) -> &'static str {
        "ftl"
    }

    fn audit(&self) -> crate::Result<()> {
        self.check_invariants()
    }

    /// Hash the device's observable translation state: the live
    /// mapping (with tags), per-block bookkeeping, the free/bad pools,
    /// the write frontiers and every counter ledger. Iteration orders
    /// are all structural (vec index, BTreeSet, per-channel FIFO), so
    /// the fingerprint is replay-deterministic.
    fn fingerprint(&self, h: &mut crate::analysis::audit::Fnv64) {
        let mapped = self.l2p.iter().filter(|e| e.is_some()).count();
        h.write_usize(mapped);
        for (lpn, entry) in self.l2p.iter().enumerate() {
            if let Some(addr) = entry {
                h.write_usize(lpn);
                h.write_u64(addr.channel as u64);
                h.write_u64(addr.die as u64);
                h.write_u32(addr.block);
                h.write_u32(addr.page);
                h.write_u64(self.tags[lpn]);
            }
        }
        for b in &self.blocks {
            h.write_u32(b.write_ptr);
            h.write_u32(b.valid_count);
            h.write_u32(b.pe_cycles);
            h.write_u32(b.suspect);
        }
        h.write_usize(self.bad_blocks.len());
        for &b in &self.bad_blocks {
            h.write_u32(b);
        }
        for q in &self.free.per_channel {
            h.write_usize(q.len());
            for &(seq, block) in q {
                h.write_u64(seq);
                h.write_u32(block);
            }
        }
        for a in &self.active {
            match a {
                None => h.write_u64(0),
                Some(b) => h.write_u64(u64::from(*b) + 1),
            }
        }
        h.write_usize(self.next_channel);
        h.write_u64(self.stats.host_writes);
        h.write_u64(self.stats.gc_writes);
        h.write_u64(self.stats.gc_runs);
        h.write_u64(self.stats.reads);
        h.write_u64(self.stats.trims);
        h.write_u64(self.retry_recoveries);
        let e = self.ecc.stats();
        h.write_u64(e.pages);
        h.write_u64(e.corrected_pages);
        h.write_u64(e.corrected_bits);
        h.write_u64(e.uncorrectable);
        h.write_u64(e.retries);
        let f = self.flash.stats();
        h.write_u64(f.reads);
        h.write_u64(f.programs);
        h.write_u64(f.erases);
        h.write_u64(f.bytes_read);
        h.write_u64(f.bytes_written);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn small_ftl() -> Ftl {
        let cfg = FtlConfig {
            flash: FlashConfig {
                channels: 2,
                dies_per_channel: 2,
                blocks_per_die: 8,
                pages_per_block: 8,
                page_bytes: 4096,
                ..Default::default()
            },
            gc_low_water: 3,
            gc_high_water: 5,
            overprovision: 0.25,
            ..Default::default()
        };
        Ftl::new(cfg, 42)
    }

    /// Regression pin for the per-channel free-list refill: allocation
    /// order (channel striping, lowest-id-first block refill within a
    /// channel, append-only pages) must be exactly what the old
    /// global-queue scan produced.
    #[test]
    fn allocation_order_is_pinned() {
        // small_ftl geometry: 2 channels x 2 dies x 8 blocks x 8 pages.
        // Block ids 0..16 live on channel 0, 16..32 on channel 1; the
        // first 8 blocks of each channel are on die 0.
        let mut ftl = small_ftl();
        for lpn in 0..36u32 {
            ftl.write(lpn, lpn as u64, SimTime::ZERO).unwrap();
        }
        for lpn in 0..36u32 {
            let addr = ftl.l2p[lpn as usize].expect("written");
            let seq = lpn / 2; // per-channel program sequence
            assert_eq!(addr.channel, (lpn % 2) as u16, "lpn {lpn}");
            assert_eq!(addr.die, (seq / 8 / 8) as u16, "lpn {lpn}");
            assert_eq!(addr.block, (seq / 8) % 8, "lpn {lpn}");
            assert_eq!(addr.page, seq % 8, "lpn {lpn}");
        }
        ftl.check_invariants().unwrap();
    }

    #[test]
    fn write_read_roundtrip() {
        let mut ftl = small_ftl();
        ftl.write(3, 0xDEAD, SimTime::ZERO).unwrap();
        ftl.write(7, 0xBEEF, SimTime::ZERO).unwrap();
        assert_eq!(ftl.read(3, SimTime::ZERO).unwrap().tag, 0xDEAD);
        assert_eq!(ftl.read(7, SimTime::ZERO).unwrap().tag, 0xBEEF);
        assert!(ftl.read(9, SimTime::ZERO).is_err(), "unwritten lpn errors");
    }

    #[test]
    fn overwrite_returns_latest() {
        let mut ftl = small_ftl();
        for i in 0..10u64 {
            ftl.write(5, i, SimTime::ZERO).unwrap();
        }
        assert_eq!(ftl.read(5, SimTime::ZERO).unwrap().tag, 9);
        ftl.check_invariants().unwrap();
    }

    #[test]
    fn gc_reclaims_and_preserves_data() {
        let mut ftl = small_ftl();
        let n = ftl.logical_pages() as u32;
        // Fill, then overwrite everything several times to force GC.
        for round in 0..4u64 {
            for lpn in 0..n {
                ftl.write(lpn, (round << 32) | lpn as u64, SimTime::ZERO).unwrap();
            }
        }
        assert!(ftl.stats().gc_runs > 0, "GC must have triggered");
        for lpn in 0..n {
            assert_eq!(ftl.read(lpn, SimTime::ZERO).unwrap().tag, (3 << 32) | lpn as u64);
        }
        ftl.check_invariants().unwrap();
        // Sequential full-device overwrites leave victims fully invalid,
        // so WAF stays 1.0 — the ideal. Skewed overwrites (below) must
        // instead relocate the cold half and raise WAF.
        assert!((ftl.stats().waf() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn skewed_overwrites_cause_relocation() {
        let mut ftl = small_ftl();
        let n = ftl.logical_pages() as u32;
        // Cold data: every lpn once.
        for lpn in 0..n {
            ftl.write(lpn, lpn as u64, SimTime::ZERO).unwrap();
        }
        // Hot third rewritten many times (lpn % 3 == 0 hits both
        // channel stripes): GC victims now mix hot (invalid) and cold
        // (valid) pages -> relocations -> WAF > 1.
        for round in 0..15u64 {
            for lpn in (0..n).step_by(3) {
                ftl.write(lpn, round, SimTime::ZERO).unwrap();
            }
        }
        assert!(ftl.stats().gc_runs > 0);
        assert!(ftl.stats().waf() > 1.0, "waf={}", ftl.stats().waf());
        // Cold data survived relocation.
        for lpn in 0..n {
            if lpn % 3 != 0 {
                assert_eq!(ftl.read(lpn, SimTime::ZERO).unwrap().tag, lpn as u64);
            }
        }
        ftl.check_invariants().unwrap();
    }

    #[test]
    fn wear_spreads_across_blocks() {
        let mut ftl = small_ftl();
        let n = ftl.logical_pages() as u32;
        for round in 0..20u64 {
            for lpn in 0..n {
                ftl.write(lpn, round, SimTime::ZERO).unwrap();
            }
        }
        let (min_pe, max_pe) = (ftl.min_pe_cycles(), ftl.max_pe_cycles());
        assert!(max_pe > 0);
        assert!(
            max_pe - min_pe <= max_pe.max(4),
            "wear imbalance too high: {min_pe}..{max_pe}"
        );
    }

    #[test]
    fn property_random_workload_integrity() {
        prop::check("FTL preserves latest write under random workload", |rng| {
            let mut ftl = small_ftl();
            let n = ftl.logical_pages() as u32;
            let mut shadow = std::collections::BTreeMap::new();
            for i in 0..600u64 {
                let lpn = rng.below(n as u64) as u32;
                ftl.write(lpn, i, SimTime::ZERO).unwrap();
                shadow.insert(lpn, i);
            }
            ftl.check_invariants().unwrap();
            for (lpn, want) in shadow {
                assert_eq!(ftl.read(lpn, SimTime::ZERO).unwrap().tag, want);
            }
        });
    }

    #[test]
    fn trim_unmaps_and_frees_for_gc() {
        let mut ftl = small_ftl();
        ftl.write_fill(4, 3, 0xAB, SimTime::ZERO).unwrap();
        // Mapped pages trim; never-written ones report false.
        assert_eq!(ftl.trim_run(4, 3).unwrap(), 3);
        assert_eq!(ftl.stats().trims, 3);
        for lpn in 4..7 {
            let e = ftl.read(lpn, SimTime::ZERO).unwrap_err();
            assert!(e.to_string().contains("never written"), "got: {e}");
        }
        // Idempotent: a second trim frees nothing.
        assert_eq!(ftl.trim_run(4, 3).unwrap(), 0);
        assert_eq!(ftl.stats().trims, 3);
        assert!(!ftl.trim(9).unwrap());
        // Out-of-range runs fail up front.
        let n = ftl.logical_pages() as u32;
        assert!(ftl.trim_run(n - 1, 2).is_err());
        ftl.check_invariants().unwrap();
        // The invalidated pages really are reclaimable: fill the device
        // and keep overwriting — GC must run without out-of-space.
        for round in 0..3u64 {
            for lpn in 0..n {
                ftl.write(lpn, round, SimTime::ZERO).unwrap();
            }
        }
        ftl.check_invariants().unwrap();
    }

    #[test]
    fn trim_then_rewrite_roundtrips() {
        let mut ftl = small_ftl();
        ftl.write(5, 0xA, SimTime::ZERO).unwrap();
        assert!(ftl.trim(5).unwrap());
        ftl.write(5, 0xB, SimTime::ZERO).unwrap();
        assert_eq!(ftl.read(5, SimTime::ZERO).unwrap().tag, 0xB);
        ftl.check_invariants().unwrap();
    }

    #[test]
    fn timing_advances_with_load() {
        let mut ftl = small_ftl();
        let t1 = ftl.write(0, 1, SimTime::ZERO).unwrap();
        // Saturate the same channels: later completion times grow.
        let mut last = SimTime::ZERO;
        for lpn in 0..16u32 {
            last = ftl.write(lpn, 2, SimTime::ZERO).unwrap();
        }
        assert!(last > t1);
    }

    // ---- extent-path equivalence oracle -----------------------------

    /// The pre-extent per-page reference: a plain `write` loop.
    fn write_per_page(ftl: &mut Ftl, lpn0: u32, tags: &[u64], now: SimTime) -> Result<SimTime> {
        let mut done = now;
        for (i, &t) in tags.iter().enumerate() {
            done = done.max(ftl.write(lpn0 + i as u32, t, now)?);
        }
        Ok(done)
    }

    /// The pre-extent per-page reference: a plain `read` loop.
    fn read_per_page(ftl: &mut Ftl, lpn0: u32, len: u32, now: SimTime) -> Result<SimTime> {
        let mut done = now;
        for i in 0..len {
            done = done.max(ftl.read(lpn0 + i, now)?.done);
        }
        Ok(done)
    }

    /// Full observable mapping state (l2p, tags, per-block counters).
    fn fingerprint(f: &Ftl) -> (Vec<Option<PhysAddr>>, Vec<u64>, Vec<(u32, u32, u32)>) {
        (
            f.l2p.clone(),
            f.tags.clone(),
            f.blocks
                .iter()
                .map(|b| (b.write_ptr, b.valid_count, b.pe_cycles))
                .collect(),
        )
    }

    #[test]
    fn run_wrappers_roundtrip() {
        let mut ftl = small_ftl();
        let done = ftl.write_fill(4, 3, 0xAB, SimTime::ZERO).unwrap();
        assert!(done > SimTime::ZERO);
        for lpn in 4..7 {
            assert_eq!(ftl.read(lpn, SimTime::ZERO).unwrap().tag, 0xAB);
        }
        assert!(ftl.read_run(4, 3, SimTime::ZERO).unwrap() > SimTime::ZERO);
        // Zero-length runs are no-ops.
        assert_eq!(ftl.write_run(0, &[], SimTime::ms(7)).unwrap(), SimTime::ms(7));
        assert_eq!(ftl.read_run(0, 0, SimTime::ms(7)).unwrap(), SimTime::ms(7));
        // Out-of-range runs fail up front (one bounds check per run).
        let n = ftl.logical_pages() as u32;
        assert!(ftl.write_fill(n - 1, 2, 1, SimTime::ZERO).is_err());
        assert!(ftl.read_run(n - 1, 2, SimTime::ZERO).is_err());
        assert!(ftl.read_run(0, 2, SimTime::ZERO).is_err(), "unwritten page errors");
        ftl.check_invariants().unwrap();
    }

    /// Property: bulk runs are bit-identical to the per-page reference
    /// over randomized mixed workloads — returned completion times,
    /// `FtlStats`, flash stats, free-pool size, full l2p/tags/block
    /// state and `check_invariants` — including GC pressure and
    /// out-of-space edges.
    #[test]
    fn property_bulk_ops_match_per_page_reference() {
        prop::check("bulk FTL ops match the per-page reference", |rng| {
            let cfg = FtlConfig {
                flash: FlashConfig {
                    channels: 1 + rng.usize_below(2),
                    dies_per_channel: 1 + rng.usize_below(2),
                    blocks_per_die: 8,
                    pages_per_block: 8,
                    page_bytes: 4096,
                    ..Default::default()
                },
                gc_low_water: 3,
                gc_high_water: 5,
                // Occasionally under-provision so GC cannot keep up and
                // the out-of-space error path is exercised too.
                overprovision: if rng.bool(0.2) { 0.05 } else { 0.25 },
                ..Default::default()
            };
            let mut bulk = Ftl::new(cfg.clone(), 42);
            let mut refr = Ftl::new(cfg, 42);
            let n = bulk.logical_pages() as u32;
            let mut tick = 0u64;
            for _ in 0..60 {
                let len = 1 + rng.below(6) as u32;
                let lpn0 = rng.below(n as u64) as u32;
                let len = len.min(n - lpn0);
                let now = SimTime::us(tick);
                tick += 50;
                if rng.bool(0.6) {
                    let tags: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
                    let a = bulk.write_run(lpn0, &tags, now);
                    let b = write_per_page(&mut refr, lpn0, &tags, now);
                    match (a, b) {
                        (Ok(x), Ok(y)) => assert_eq!(x, y, "write-run completion"),
                        (Err(x), Err(y)) => assert_eq!(x.to_string(), y.to_string()),
                        (a, b) => panic!("bulk {a:?} vs per-page {b:?}"),
                    }
                } else {
                    let a = bulk.read_run(lpn0, len, now);
                    let b = read_per_page(&mut refr, lpn0, len, now);
                    match (a, b) {
                        (Ok(x), Ok(y)) => assert_eq!(x, y, "read-run completion"),
                        (Err(x), Err(y)) => assert_eq!(x.to_string(), y.to_string()),
                        (a, b) => panic!("bulk {a:?} vs per-page {b:?}"),
                    }
                }
                assert_eq!(bulk.stats(), refr.stats());
                assert_eq!(bulk.free_block_count(), refr.free_block_count());
            }
            bulk.check_invariants().unwrap();
            refr.check_invariants().unwrap();
            assert_eq!(fingerprint(&bulk), fingerprint(&refr));
            assert_eq!(bulk.flash_stats(), refr.flash_stats());
        });
    }

    // ---- endurance & failure pipeline --------------------------------

    #[test]
    fn typed_read_errors_carry_context() {
        let mut ftl = small_ftl();
        let n = ftl.logical_pages() as u32;
        let e = ftl.read(n, SimTime::ZERO).unwrap_err();
        assert_eq!(e.downcast_ref::<ReadError>(), Some(&ReadError::LpnOutOfRange { lpn: n }));
        assert_eq!(e.to_string(), format!("lpn {n} out of range"));
        let e = ftl.read(3, SimTime::ZERO).unwrap_err();
        assert_eq!(e.downcast_ref::<ReadError>(), Some(&ReadError::Unwritten { lpn: 3 }));
        assert_eq!(e.to_string(), "lpn 3 never written");
        let e = ftl.read_run(n - 1, 2, SimTime::ZERO).unwrap_err();
        assert!(matches!(e.downcast_ref::<ReadError>(), Some(ReadError::RunOutOfRange { .. })));
        assert_eq!(
            e.to_string(),
            format!("lpn run {}..{} out of range (logical pages {n})", n - 1, n as u64 + 1)
        );
    }

    /// A brutal ECC config (t=1) makes most first decodes fail; the
    /// retry ladder must recover a good fraction, relocating each
    /// recovered page off its (now suspect) block, and surface the
    /// rest as typed `Uncorrectable` errors carrying block/pe context.
    #[test]
    fn retry_ladder_recovers_and_relocates() {
        let cfg = FtlConfig {
            flash: FlashConfig {
                channels: 2,
                dies_per_channel: 2,
                blocks_per_die: 8,
                pages_per_block: 8,
                page_bytes: 4096,
                ..Default::default()
            },
            ecc: EccConfig { t: 1, rber_fresh: 2e-4, ..Default::default() },
            gc_low_water: 3,
            gc_high_water: 5,
            overprovision: 0.25,
            read_retries: 4,
            ..Default::default()
        };
        let mut ftl = Ftl::new(cfg, 42);
        for lpn in 0..16u32 {
            ftl.write(lpn, lpn as u64, SimTime::ZERO).unwrap();
        }
        let (mut recovered_reads, mut failed_reads) = (0u32, 0u32);
        for round in 0..20 {
            for lpn in 0..16u32 {
                let before = ftl.wear().retry_recoveries;
                match ftl.read(lpn, SimTime::us(round)) {
                    Ok(r) => {
                        assert_eq!(r.tag, lpn as u64, "recovery must preserve data");
                        if ftl.wear().retry_recoveries > before {
                            recovered_reads += 1;
                        }
                    }
                    Err(e) => {
                        let re = e.downcast_ref::<ReadError>().expect("typed read error");
                        match re {
                            ReadError::Uncorrectable { lpn: l, retries, .. } => {
                                assert_eq!(*l, lpn);
                                assert_eq!(*retries, 4);
                            }
                            other => panic!("unexpected read error {other:?}"),
                        }
                        failed_reads += 1;
                    }
                }
            }
            ftl.check_invariants().unwrap();
        }
        assert!(recovered_reads > 0, "ladder never recovered a page");
        assert!(failed_reads > 0, "ladder never exhausted (test too easy)");
        let w = ftl.wear();
        assert_eq!(w.retry_recoveries as u32, recovered_reads);
        assert!(w.suspect_blocks > 0, "recoveries must mark blocks suspect");
        assert!(ftl.ecc_stats().retries > 0, "retries must be counted");
        // Bulk reads run the same ladder: totals keep moving.
        let before = ftl.ecc_stats().retries;
        for _ in 0..10 {
            let _ = ftl.read_run(0, 16, SimTime::ZERO);
        }
        assert!(ftl.ecc_stats().retries > before);
        ftl.check_invariants().unwrap();
    }

    /// With a finite P/E budget, GC erases start failing: blocks retire
    /// into the bad-block list (never re-entering the free pool — the
    /// extended `check_invariants` audits that every round), capacity
    /// shrinks, and the device finally reports a typed `DeviceWornOut`
    /// instead of the generic out-of-space error. Reads keep working.
    #[test]
    fn endurance_limit_retires_blocks_until_worn_out() {
        let cfg = FtlConfig { pe_limit: 2, ..Default::default() };
        let mut ftl = Ftl::new(
            FtlConfig {
                flash: FlashConfig {
                    channels: 2,
                    dies_per_channel: 2,
                    blocks_per_die: 8,
                    pages_per_block: 8,
                    page_bytes: 4096,
                    ..Default::default()
                },
                gc_low_water: 3,
                gc_high_water: 5,
                overprovision: 0.25,
                ..cfg
            },
            42,
        );
        let n = ftl.logical_pages() as u32;
        let mut worn = None;
        'outer: for round in 0..10_000u64 {
            for lpn in 0..n {
                match ftl.write(lpn, round, SimTime::ZERO) {
                    Ok(_) => {}
                    Err(e) => {
                        worn = Some(e);
                        break 'outer;
                    }
                }
            }
            ftl.check_invariants().unwrap();
        }
        let e = worn.expect("a 2-cycle P/E budget must wear the device out");
        let w = e.downcast_ref::<DeviceWornOut>().expect("typed DeviceWornOut");
        assert!(w.retired_blocks > 0);
        assert!(ftl.worn_out(), "worn_out() must agree with the error");
        let wear = ftl.wear();
        assert_eq!(wear.retired_blocks as usize, ftl.retired_block_count());
        assert!(wear.retired_blocks > 0 && wear.erases > 0);
        ftl.check_invariants().unwrap();
        // The device still serves reads for everything that stayed
        // mapped — EOL is a write-path condition.
        let mapped: Vec<u32> =
            (0..n).filter(|&l| ftl.l2p[l as usize].is_some()).take(8).collect();
        assert!(!mapped.is_empty());
        for lpn in mapped {
            ftl.read(lpn, SimTime::ZERO).unwrap();
        }
        // A default (pe_limit = 0) FTL never wears out.
        assert!(!small_ftl().worn_out());
    }

    /// Property: across skewed overwrite workloads, the incremental
    /// victim index picks exactly the block the full scan picks (same
    /// tie-break), at every GC decision point.
    #[test]
    fn property_victim_index_matches_full_scan() {
        prop::check("victim index tracks the full cost-benefit scan", |rng| {
            let mut ftl = small_ftl();
            let n = ftl.logical_pages() as u32;
            let hot = 1 + rng.usize_below(8) as u32;
            for round in 0..1 + rng.below(20) {
                for lpn in 0..n {
                    if round == 0 || lpn % hot == 0 {
                        ftl.write(lpn, round, SimTime::ZERO).unwrap();
                    }
                }
                assert_eq!(ftl.gc_victim(), ftl.gc_victim_scan());
            }
            ftl.check_invariants().unwrap();
        });
    }
}
