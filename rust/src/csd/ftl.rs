//! Flash translation layer: page-level mapping, garbage collection and
//! wear leveling — the BE firmware functions the paper lists (§III).
//!
//! The FTL owns the [`FlashArray`] (timing) and the [`Ecc`] decoder
//! (reliability): a logical read/write is translated, scheduled on the
//! array, decoded, and accounted. Data *content* is modeled as a u64
//! tag per logical page — enough to prove end-to-end integrity without
//! simulating 16 KiB payloads.

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::sim::SimTime;

use super::ecc::{Ecc, EccConfig, EccOutcome};
use super::flash::{FlashArray, FlashConfig, PhysAddr};

#[derive(Debug, Clone)]
pub struct FtlConfig {
    pub flash: FlashConfig,
    pub ecc: EccConfig,
    /// Fraction of physical blocks held back as over-provisioning.
    pub overprovision: f64,
    /// GC starts when the free-block pool drops below this count.
    pub gc_low_water: usize,
    /// GC stops once the pool recovers to this count.
    pub gc_high_water: usize,
}

impl Default for FtlConfig {
    fn default() -> Self {
        Self {
            flash: FlashConfig::default(),
            ecc: EccConfig::default(),
            overprovision: 0.125,
            gc_low_water: 8,
            gc_high_water: 16,
        }
    }
}

#[derive(Debug, Clone)]
struct BlockInfo {
    /// validity bitmap per page
    valid: Vec<bool>,
    valid_count: u32,
    /// next page index to program (append-only within a block)
    write_ptr: u32,
    pe_cycles: u32,
}

impl BlockInfo {
    fn new(pages: usize) -> Self {
        Self { valid: vec![false; pages], valid_count: 0, write_ptr: 0, pe_cycles: 0 }
    }

    fn is_full(&self, pages: usize) -> bool {
        self.write_ptr as usize >= pages
    }
}

/// Free-block pool: one FIFO queue per channel, so a channel-local
/// refill is O(1) instead of the old single-queue `iter().position` +
/// mid-queue `VecDeque::remove` scan (O(free) with an element shift).
/// A monotone sequence number per insertion preserves the old global
/// FIFO order, and a membership bitmap gives O(1) `contains` for the
/// GC victim scan.
#[derive(Debug, Clone)]
struct FreeBlocks {
    /// `(insertion seq, block id)` per channel, FIFO.
    per_channel: Vec<VecDeque<(u64, u32)>>,
    /// O(1) membership, mirrors the queues.
    member: Vec<bool>,
    len: usize,
    next_seq: u64,
}

impl FreeBlocks {
    fn new(channels: usize, total_blocks: usize) -> Self {
        Self {
            per_channel: vec![VecDeque::new(); channels],
            member: vec![false; total_blocks],
            len: 0,
            next_seq: 0,
        }
    }

    fn len(&self) -> usize {
        self.len
    }

    fn contains(&self, block: u32) -> bool {
        self.member[block as usize]
    }

    fn push(&mut self, channel: usize, block: u32) {
        debug_assert!(!self.member[block as usize], "block {block} freed twice");
        self.per_channel[channel].push_back((self.next_seq, block));
        self.next_seq += 1;
        self.member[block as usize] = true;
        self.len += 1;
    }

    /// Oldest free block on `channel` (the block the old global-queue
    /// scan would have found first).
    fn pop_channel(&mut self, channel: usize) -> Option<u32> {
        let (_, block) = self.per_channel[channel].pop_front()?;
        self.member[block as usize] = false;
        self.len -= 1;
        Some(block)
    }

    /// Globally oldest free block across all channels (the old
    /// `pop_front`) — O(channels), only reached when every channel's
    /// local pool is empty.
    fn pop_oldest(&mut self) -> Option<u32> {
        let ch = self
            .per_channel
            .iter()
            .enumerate()
            .filter_map(|(ch, q)| q.front().map(|&(seq, _)| (seq, ch)))
            .min()
            .map(|(_, ch)| ch)?;
        self.pop_channel(ch)
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct FtlStats {
    pub host_writes: u64,
    pub gc_writes: u64,
    pub gc_runs: u64,
    pub reads: u64,
}

impl FtlStats {
    /// Write amplification factor: (host + GC relocations) / host.
    pub fn waf(&self) -> f64 {
        if self.host_writes == 0 {
            return 1.0;
        }
        (self.host_writes + self.gc_writes) as f64 / self.host_writes as f64
    }
}

/// Outcome of a logical read.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReadResult {
    pub tag: u64,
    pub done: SimTime,
    pub ecc: EccOutcome,
}

/// Page-mapped FTL over a flash array.
pub struct Ftl {
    cfg: FtlConfig,
    flash: FlashArray,
    ecc: Ecc,
    /// logical page -> physical address
    l2p: Vec<Option<PhysAddr>>,
    /// physical page -> logical page (for GC relocation)
    p2l: Vec<Option<u32>>,
    /// content tags, indexed by logical page
    tags: Vec<u64>,
    blocks: Vec<BlockInfo>,
    free: FreeBlocks,
    /// per-channel active write block (stripes programs across channels)
    active: Vec<Option<u32>>,
    next_channel: usize,
    stats: FtlStats,
}

impl Ftl {
    pub fn new(cfg: FtlConfig, seed: u64) -> Self {
        let total_blocks = cfg.flash.total_blocks();
        let pages = cfg.flash.pages_per_block;
        let logical_pages =
            ((cfg.flash.total_pages() as f64) * (1.0 - cfg.overprovision)) as usize;
        let flash = FlashArray::new(cfg.flash.clone());
        let ecc = Ecc::new(cfg.ecc.clone(), seed);
        let blocks = (0..total_blocks).map(|_| BlockInfo::new(pages)).collect();
        let channels = cfg.flash.channels;
        // Blocks enter the free pool in id order (the old global FIFO);
        // a block's channel is fixed by its id, so per-channel queues
        // filtered from that order are the same FIFO the old scan saw.
        let per_channel_blocks = cfg.flash.dies_per_channel * cfg.flash.blocks_per_die;
        let mut free = FreeBlocks::new(channels, total_blocks);
        for b in 0..total_blocks as u32 {
            free.push(b as usize / per_channel_blocks, b);
        }
        Self {
            l2p: vec![None; logical_pages],
            p2l: vec![None; cfg.flash.total_pages()],
            tags: vec![0; logical_pages],
            blocks,
            free,
            active: vec![None; channels],
            next_channel: 0,
            stats: FtlStats::default(),
            cfg,
            flash,
            ecc,
        }
    }

    pub fn logical_pages(&self) -> usize {
        self.l2p.len()
    }

    pub fn page_bytes(&self) -> usize {
        self.cfg.flash.page_bytes
    }

    pub fn stats(&self) -> FtlStats {
        self.stats
    }

    pub fn flash_stats(&self) -> super::flash::FlashStats {
        self.flash.stats()
    }

    pub fn free_block_count(&self) -> usize {
        self.free.len()
    }

    pub fn max_pe_cycles(&self) -> u32 {
        self.blocks.iter().map(|b| b.pe_cycles).max().unwrap_or(0)
    }

    pub fn min_pe_cycles(&self) -> u32 {
        self.blocks.iter().map(|b| b.pe_cycles).min().unwrap_or(0)
    }

    // ---- address helpers ---------------------------------------------

    fn block_addr(&self, block_id: u32, page: u32) -> PhysAddr {
        let f = &self.cfg.flash;
        let per_die = f.blocks_per_die as u32;
        let per_channel = (f.dies_per_channel as u32) * per_die;
        PhysAddr {
            channel: (block_id / per_channel) as u16,
            die: ((block_id % per_channel) / per_die) as u16,
            block: block_id % per_die,
            page,
        }
    }

    fn phys_index(&self, addr: PhysAddr) -> usize {
        let f = &self.cfg.flash;
        (((addr.channel as usize * f.dies_per_channel + addr.die as usize)
            * f.blocks_per_die
            + addr.block as usize)
            * f.pages_per_block)
            + addr.page as usize
    }

    fn block_id_of(&self, addr: PhysAddr) -> u32 {
        let f = &self.cfg.flash;
        ((addr.channel as usize * f.dies_per_channel + addr.die as usize) * f.blocks_per_die
            + addr.block as usize) as u32
    }

    // ---- write path ---------------------------------------------------

    /// Allocate the next physical page on some channel's active block.
    ///
    /// A channel refill pops its own free queue in O(1); the old code
    /// scanned one global queue (`iter().position` + mid-queue
    /// `remove`) per refill, O(free blocks) with an element shift. The
    /// order is unchanged: each channel still receives its blocks in
    /// global free-FIFO order (erased blocks re-enter oldest-first, so
    /// wear keeps spreading).
    fn alloc_page(&mut self, now: SimTime) -> Result<PhysAddr> {
        let channels = self.active.len();
        for _ in 0..channels {
            let ch = self.next_channel;
            self.next_channel = (self.next_channel + 1) % channels;
            // Refill this channel's active block if missing/full.
            let need_new = match self.active[ch] {
                None => true,
                Some(b) => self.blocks[b as usize].is_full(self.cfg.flash.pages_per_block),
            };
            if need_new {
                match self.free.pop_channel(ch) {
                    Some(b) => self.active[ch] = Some(b),
                    None => continue, // this channel exhausted; try next
                }
            }
            let b = self.active[ch].unwrap();
            let info = &mut self.blocks[b as usize];
            let page = info.write_ptr;
            info.write_ptr += 1;
            return Ok(self.block_addr(b, page));
        }
        // No channel-local free block anywhere: take the globally
        // oldest free block (only reachable once every queue is empty,
        // kept for faithfulness to the old fallback).
        if let Some(b) = self.free.pop_oldest() {
            let ch = self.block_addr(b, 0).channel as usize;
            self.active[ch] = Some(b);
            let info = &mut self.blocks[b as usize];
            let page = info.write_ptr;
            info.write_ptr += 1;
            return Ok(self.block_addr(b, page));
        }
        let _ = now;
        bail!("flash out of space: no free blocks (GC failed to reclaim)")
    }

    /// Write `tag` to logical page `lpn`. Returns completion time.
    pub fn write(&mut self, lpn: u32, tag: u64, now: SimTime) -> Result<SimTime> {
        anyhow::ensure!((lpn as usize) < self.l2p.len(), "lpn {lpn} out of range");
        let done = self.write_inner(lpn, tag, now, false)?;
        self.maybe_gc(now)?;
        Ok(done)
    }

    fn write_inner(&mut self, lpn: u32, tag: u64, now: SimTime, is_gc: bool) -> Result<SimTime> {
        // Invalidate the old location.
        if let Some(old) = self.l2p[lpn as usize] {
            let bid = self.block_id_of(old) as usize;
            let pidx = self.phys_index(old);
            let info = &mut self.blocks[bid];
            if info.valid[old.page as usize] {
                info.valid[old.page as usize] = false;
                info.valid_count -= 1;
            }
            self.p2l[pidx] = None;
        }
        let addr = self.alloc_page(now)?;
        let done = self.flash.program_page(addr, now);
        let bid = self.block_id_of(addr) as usize;
        let pidx = self.phys_index(addr);
        let info = &mut self.blocks[bid];
        info.valid[addr.page as usize] = true;
        info.valid_count += 1;
        self.l2p[lpn as usize] = Some(addr);
        self.p2l[pidx] = Some(lpn);
        self.tags[lpn as usize] = tag;
        if is_gc {
            self.stats.gc_writes += 1;
        } else {
            self.stats.host_writes += 1;
        }
        Ok(done)
    }

    // ---- read path ------------------------------------------------------

    /// Read logical page `lpn`: translate, schedule flash read, decode.
    pub fn read(&mut self, lpn: u32, now: SimTime) -> Result<ReadResult> {
        anyhow::ensure!((lpn as usize) < self.l2p.len(), "lpn {lpn} out of range");
        let addr = self.l2p[lpn as usize]
            .ok_or_else(|| anyhow::anyhow!("lpn {lpn} never written"))?;
        let flash_done = self.flash.read_page(addr, now);
        let pe = self.blocks[self.block_id_of(addr) as usize].pe_cycles;
        let (ecc, ecc_lat) = self.ecc.decode_page(self.cfg.flash.page_bytes, pe);
        self.stats.reads += 1;
        if ecc == EccOutcome::Uncorrectable {
            bail!("uncorrectable ECC error reading lpn {lpn} (pe={pe})");
        }
        Ok(ReadResult { tag: self.tags[lpn as usize], done: flash_done + ecc_lat, ecc })
    }

    // ---- garbage collection ----------------------------------------------

    fn maybe_gc(&mut self, now: SimTime) -> Result<()> {
        if self.free.len() >= self.cfg.gc_low_water {
            return Ok(());
        }
        self.stats.gc_runs += 1;
        while self.free.len() < self.cfg.gc_high_water {
            let Some(victim) = self.select_victim() else { break };
            self.collect_block(victim, now)?;
        }
        Ok(())
    }

    /// Cost-benefit victim selection with wear bias: prefer blocks with
    /// many invalid pages; among similar benefit prefer low wear so
    /// erases spread out (wear leveling).
    fn select_victim(&self) -> Option<u32> {
        let pages = self.cfg.flash.pages_per_block as f64;
        let active: Vec<u32> = self.active.iter().flatten().copied().collect();
        self.blocks
            .iter()
            .enumerate()
            .filter(|(i, b)| {
                let id = *i as u32;
                b.write_ptr > 0                       // has been written
                    && !active.contains(&id)          // not a write frontier
                    && !self.free.contains(id)
                    && (b.valid_count as usize) < b.write_ptr as usize // something to reclaim
            })
            .map(|(i, b)| {
                let invalid = b.write_ptr as f64 - b.valid_count as f64;
                let score = invalid / pages - 0.01 * b.pe_cycles as f64;
                (i as u32, score)
            })
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(b.0.cmp(&a.0)))
            .map(|(i, _)| i)
    }

    fn collect_block(&mut self, victim: u32, now: SimTime) -> Result<()> {
        // Relocate valid pages.
        let pages = self.cfg.flash.pages_per_block;
        for p in 0..pages as u32 {
            let addr = self.block_addr(victim, p);
            if self.blocks[victim as usize].valid[p as usize] {
                let lpn = self.p2l[self.phys_index(addr)]
                    .ok_or_else(|| anyhow::anyhow!("valid page without p2l entry"))?;
                self.flash.read_page(addr, now);
                let tag = self.tags[lpn as usize];
                self.write_inner(lpn, tag, now, true)?;
            }
        }
        // Erase and return to the pool.
        let addr = self.block_addr(victim, 0);
        self.flash.erase_block(addr, now);
        let info = &mut self.blocks[victim as usize];
        info.valid.iter_mut().for_each(|v| *v = false);
        info.valid_count = 0;
        info.write_ptr = 0;
        info.pe_cycles += 1;
        let ch = addr.channel as usize;
        self.free.push(ch, victim);
        Ok(())
    }

    /// Invariant checker used by the property tests: every l2p entry's
    /// target is marked valid and maps back via p2l; valid counts match.
    pub fn check_invariants(&self) -> Result<()> {
        for (lpn, entry) in self.l2p.iter().enumerate() {
            if let Some(addr) = entry {
                let bid = self.block_id_of(*addr) as usize;
                anyhow::ensure!(
                    self.blocks[bid].valid[addr.page as usize],
                    "lpn {lpn} maps to invalid page {addr:?}"
                );
                anyhow::ensure!(
                    self.p2l[self.phys_index(*addr)] == Some(lpn as u32),
                    "p2l mismatch at {addr:?}"
                );
            }
        }
        for (bid, info) in self.blocks.iter().enumerate() {
            let count = info.valid.iter().filter(|&&v| v).count() as u32;
            anyhow::ensure!(
                count == info.valid_count,
                "block {bid} valid_count {} != bitmap {count}",
                info.valid_count
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn small_ftl() -> Ftl {
        let cfg = FtlConfig {
            flash: FlashConfig {
                channels: 2,
                dies_per_channel: 2,
                blocks_per_die: 8,
                pages_per_block: 8,
                page_bytes: 4096,
                ..Default::default()
            },
            gc_low_water: 3,
            gc_high_water: 5,
            overprovision: 0.25,
            ..Default::default()
        };
        Ftl::new(cfg, 42)
    }

    /// Regression pin for the per-channel free-list refill: allocation
    /// order (channel striping, lowest-id-first block refill within a
    /// channel, append-only pages) must be exactly what the old
    /// global-queue scan produced.
    #[test]
    fn allocation_order_is_pinned() {
        // small_ftl geometry: 2 channels x 2 dies x 8 blocks x 8 pages.
        // Block ids 0..16 live on channel 0, 16..32 on channel 1; the
        // first 8 blocks of each channel are on die 0.
        let mut ftl = small_ftl();
        for lpn in 0..36u32 {
            ftl.write(lpn, lpn as u64, SimTime::ZERO).unwrap();
        }
        for lpn in 0..36u32 {
            let addr = ftl.l2p[lpn as usize].expect("written");
            let seq = lpn / 2; // per-channel program sequence
            assert_eq!(addr.channel, (lpn % 2) as u16, "lpn {lpn}");
            assert_eq!(addr.die, (seq / 8 / 8) as u16, "lpn {lpn}");
            assert_eq!(addr.block, (seq / 8) % 8, "lpn {lpn}");
            assert_eq!(addr.page, seq % 8, "lpn {lpn}");
        }
        ftl.check_invariants().unwrap();
    }

    #[test]
    fn write_read_roundtrip() {
        let mut ftl = small_ftl();
        ftl.write(3, 0xDEAD, SimTime::ZERO).unwrap();
        ftl.write(7, 0xBEEF, SimTime::ZERO).unwrap();
        assert_eq!(ftl.read(3, SimTime::ZERO).unwrap().tag, 0xDEAD);
        assert_eq!(ftl.read(7, SimTime::ZERO).unwrap().tag, 0xBEEF);
        assert!(ftl.read(9, SimTime::ZERO).is_err(), "unwritten lpn errors");
    }

    #[test]
    fn overwrite_returns_latest() {
        let mut ftl = small_ftl();
        for i in 0..10u64 {
            ftl.write(5, i, SimTime::ZERO).unwrap();
        }
        assert_eq!(ftl.read(5, SimTime::ZERO).unwrap().tag, 9);
        ftl.check_invariants().unwrap();
    }

    #[test]
    fn gc_reclaims_and_preserves_data() {
        let mut ftl = small_ftl();
        let n = ftl.logical_pages() as u32;
        // Fill, then overwrite everything several times to force GC.
        for round in 0..4u64 {
            for lpn in 0..n {
                ftl.write(lpn, (round << 32) | lpn as u64, SimTime::ZERO).unwrap();
            }
        }
        assert!(ftl.stats().gc_runs > 0, "GC must have triggered");
        for lpn in 0..n {
            assert_eq!(ftl.read(lpn, SimTime::ZERO).unwrap().tag, (3 << 32) | lpn as u64);
        }
        ftl.check_invariants().unwrap();
        // Sequential full-device overwrites leave victims fully invalid,
        // so WAF stays 1.0 — the ideal. Skewed overwrites (below) must
        // instead relocate the cold half and raise WAF.
        assert!((ftl.stats().waf() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn skewed_overwrites_cause_relocation() {
        let mut ftl = small_ftl();
        let n = ftl.logical_pages() as u32;
        // Cold data: every lpn once.
        for lpn in 0..n {
            ftl.write(lpn, lpn as u64, SimTime::ZERO).unwrap();
        }
        // Hot third rewritten many times (lpn % 3 == 0 hits both
        // channel stripes): GC victims now mix hot (invalid) and cold
        // (valid) pages -> relocations -> WAF > 1.
        for round in 0..15u64 {
            for lpn in (0..n).step_by(3) {
                ftl.write(lpn, round, SimTime::ZERO).unwrap();
            }
        }
        assert!(ftl.stats().gc_runs > 0);
        assert!(ftl.stats().waf() > 1.0, "waf={}", ftl.stats().waf());
        // Cold data survived relocation.
        for lpn in 0..n {
            if lpn % 3 != 0 {
                assert_eq!(ftl.read(lpn, SimTime::ZERO).unwrap().tag, lpn as u64);
            }
        }
        ftl.check_invariants().unwrap();
    }

    #[test]
    fn wear_spreads_across_blocks() {
        let mut ftl = small_ftl();
        let n = ftl.logical_pages() as u32;
        for round in 0..20u64 {
            for lpn in 0..n {
                ftl.write(lpn, round, SimTime::ZERO).unwrap();
            }
        }
        let (min_pe, max_pe) = (ftl.min_pe_cycles(), ftl.max_pe_cycles());
        assert!(max_pe > 0);
        assert!(
            max_pe - min_pe <= max_pe.max(4),
            "wear imbalance too high: {min_pe}..{max_pe}"
        );
    }

    #[test]
    fn property_random_workload_integrity() {
        prop::check("FTL preserves latest write under random workload", |rng| {
            let mut ftl = small_ftl();
            let n = ftl.logical_pages() as u32;
            let mut shadow = std::collections::HashMap::new();
            for i in 0..600u64 {
                let lpn = rng.below(n as u64) as u32;
                ftl.write(lpn, i, SimTime::ZERO).unwrap();
                shadow.insert(lpn, i);
            }
            ftl.check_invariants().unwrap();
            for (lpn, want) in shadow {
                assert_eq!(ftl.read(lpn, SimTime::ZERO).unwrap().tag, want);
            }
        });
    }

    #[test]
    fn timing_advances_with_load() {
        let mut ftl = small_ftl();
        let t1 = ftl.write(0, 1, SimTime::ZERO).unwrap();
        // Saturate the same channels: later completion times grow.
        let mut last = SimTime::ZERO;
        for lpn in 0..16u32 {
            last = ftl.write(lpn, 2, SimTime::ZERO).unwrap();
        }
        assert!(last > t1);
    }
}
