//! Newport CSD substrate: every hardware block of paper Fig. 1 as a
//! deterministic discrete-event model.
//!
//! * [`flash`] — NAND array geometry + page/block timing
//! * [`ecc`] — BCH-style correction with wear-dependent RBER
//! * [`ftl`] — page-mapped L2P, garbage collection, wear leveling
//! * [`nvme`] — FE + NVMe-over-PCIe host path (shared PCIe timeline)
//! * [`isp`] — quad-A53 in-storage compute engine + DRAM admission
//! * [`device`] — the composed Newport device and its two data paths

pub mod device;
pub mod ecc;
pub mod flash;
pub mod ftl;
pub mod isp;
pub mod nvme;

pub use device::{CsdConfig, CsdIoStats, NewportCsd};
pub use ecc::{Ecc, EccConfig, EccOutcome, EccStats};
pub use flash::{FlashArray, FlashConfig, FlashStats, PhysAddr};
pub use ftl::{DeviceWornOut, Ftl, FtlConfig, FtlStats, ReadError, WearReport};
pub use isp::{IspConfig, IspEngine};
pub use nvme::{NvmeConfig, NvmeLink};
