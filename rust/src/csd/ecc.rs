//! ECC model: BCH-style correction with wear-dependent raw bit errors.
//!
//! Newport's BE carries an ECC unit that restores data on flash bit
//! errors (paper §III). We model a BCH code correcting up to `t` bits
//! per 1 KiB codeword; the raw bit error rate (RBER) grows with a
//! block's program/erase count. Outcomes per page read:
//!   * clean          — no errors
//!   * corrected      — ≤ t errors in every codeword (decode latency)
//!   * uncorrectable  — some codeword exceeded t (fault-injection path)

use crate::sim::SimTime;
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct EccConfig {
    /// Correctable bits per codeword.
    pub t: u32,
    /// Codeword payload size in bytes.
    pub codeword_bytes: usize,
    /// RBER when a block is fresh.
    pub rber_fresh: f64,
    /// RBER added per P/E cycle (linear wear model).
    pub rber_per_pe: f64,
    /// Extra decode latency when correction kicks in.
    pub correction_latency: SimTime,
}

impl Default for EccConfig {
    fn default() -> Self {
        Self {
            t: 72,
            codeword_bytes: 1024,
            rber_fresh: 1e-6,
            // RBER climbs ~linearly with wear; at ~3k P/E this reaches
            // the 1e-3 regime where 72-bit BCH starts to sweat.
            rber_per_pe: 3.3e-7,
            correction_latency: SimTime::us(8),
        }
    }
}

/// Result of decoding one page.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EccOutcome {
    Clean,
    Corrected { bits: u32 },
    Uncorrectable,
}

#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EccStats {
    pub pages: u64,
    pub corrected_pages: u64,
    pub corrected_bits: u64,
    pub uncorrectable: u64,
    /// Read-retry ladder rungs taken after a failed first decode (the
    /// FTL's recovery path; zero whenever retries are configured off).
    pub retries: u64,
}

impl EccStats {
    /// Element-wise sum — fleet reports aggregate device decoders.
    pub fn merge(&mut self, other: EccStats) {
        self.pages += other.pages;
        self.corrected_pages += other.corrected_pages;
        self.corrected_bits += other.corrected_bits;
        self.uncorrectable += other.uncorrectable;
        self.retries += other.retries;
    }
}

/// The decoder. Deterministic given its RNG seed.
#[derive(Debug)]
pub struct Ecc {
    cfg: EccConfig,
    rng: Rng,
    stats: EccStats,
}

impl Ecc {
    pub fn new(cfg: EccConfig, seed: u64) -> Self {
        Self { cfg, rng: Rng::new(seed), stats: EccStats::default() }
    }

    pub fn stats(&self) -> EccStats {
        self.stats
    }

    pub fn rber(&self, pe_cycles: u32) -> f64 {
        self.cfg.rber_fresh + self.cfg.rber_per_pe * pe_cycles as f64
    }

    /// Sample the number of bit errors in one codeword: Poisson with
    /// mean RBER * bits (inversion sampling; mean is tiny).
    fn sample_errors(&mut self, rber: f64) -> u32 {
        let mean = rber * (self.cfg.codeword_bytes * 8) as f64;
        // Knuth's algorithm is fine for mean << 100.
        let l = (-mean).exp();
        let mut k = 0u32;
        let mut p = 1.0;
        loop {
            p *= self.rng.f64();
            if p <= l {
                return k;
            }
            k += 1;
            if k > 10_000 {
                return k; // pathological RBER, treat as destroyed
            }
        }
    }

    /// Decode a page read from a block with `pe_cycles` wear.
    /// Returns the outcome and the added decode latency.
    pub fn decode_page(&mut self, page_bytes: usize, pe_cycles: u32) -> (EccOutcome, SimTime) {
        let rber = self.rber(pe_cycles);
        let codewords = page_bytes.div_ceil(self.cfg.codeword_bytes);
        let mut total_bits = 0u32;
        let mut worst = 0u32;
        for _ in 0..codewords {
            let e = self.sample_errors(rber);
            total_bits += e;
            worst = worst.max(e);
        }
        self.stats.pages += 1;
        if worst > self.cfg.t {
            self.stats.uncorrectable += 1;
            (EccOutcome::Uncorrectable, self.cfg.correction_latency)
        } else if total_bits > 0 {
            self.stats.corrected_pages += 1;
            self.stats.corrected_bits += total_bits as u64;
            (EccOutcome::Corrected { bits: total_bits }, self.cfg.correction_latency)
        } else {
            (EccOutcome::Clean, SimTime::ZERO)
        }
    }

    /// One rung of the FTL's read-retry ladder: a retry shifts the read
    /// voltage, so the decode is a fresh experiment drawn from the
    /// *same* seeded stream as first decodes — with the ladder
    /// configured off this is never called and the draw sequence is
    /// untouched (the endurance-off bit-identity contract).
    pub fn retry_page(&mut self, page_bytes: usize, pe_cycles: u32) -> (EccOutcome, SimTime) {
        self.stats.retries += 1;
        self.decode_page(page_bytes, pe_cycles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_blocks_mostly_clean() {
        let mut ecc = Ecc::new(EccConfig::default(), 1);
        let mut clean = 0;
        for _ in 0..1000 {
            if matches!(ecc.decode_page(16384, 0).0, EccOutcome::Clean) {
                clean += 1;
            }
        }
        // RBER 1e-6 * 131072 bits ≈ 0.13 errors/page -> ~88% clean
        assert!(clean > 800, "clean={clean}");
        assert_eq!(ecc.stats().uncorrectable, 0);
    }

    #[test]
    fn wear_increases_corrections() {
        let mut fresh = Ecc::new(EccConfig::default(), 2);
        let mut worn = Ecc::new(EccConfig::default(), 2);
        let (mut cf, mut cw) = (0u64, 0u64);
        for _ in 0..500 {
            if !matches!(fresh.decode_page(16384, 0).0, EccOutcome::Clean) {
                cf += 1;
            }
            if !matches!(worn.decode_page(16384, 3000).0, EccOutcome::Clean) {
                cw += 1;
            }
        }
        assert!(cw > cf * 2, "worn={cw} fresh={cf}");
    }

    #[test]
    fn extreme_wear_goes_uncorrectable() {
        let cfg = EccConfig { rber_per_pe: 1e-4, ..Default::default() };
        let mut ecc = Ecc::new(cfg, 3);
        let mut bad = 0;
        for _ in 0..50 {
            if matches!(ecc.decode_page(16384, 50_000).0, EccOutcome::Uncorrectable) {
                bad += 1;
            }
        }
        assert!(bad > 0, "expected uncorrectable pages at absurd wear");
    }

    #[test]
    fn corrected_reads_pay_latency() {
        let cfg = EccConfig { rber_fresh: 1e-3, ..Default::default() };
        let mut ecc = Ecc::new(cfg, 4);
        let (outcome, lat) = ecc.decode_page(16384, 0);
        assert!(!matches!(outcome, EccOutcome::Clean));
        assert!(lat > SimTime::ZERO);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Ecc::new(EccConfig::default(), 9);
        let mut b = Ecc::new(EccConfig::default(), 9);
        for _ in 0..100 {
            assert_eq!(a.decode_page(16384, 100).0, b.decode_page(16384, 100).0);
        }
    }

    #[test]
    fn retries_draw_from_the_same_stream_and_are_counted() {
        // A retry consumes exactly the draws a first decode would, so
        // decode-retry-decode on one decoder equals three straight
        // decodes on a twin — the ladder inserts rungs, never forks the
        // stream.
        let mut a = Ecc::new(EccConfig::default(), 11);
        let mut b = Ecc::new(EccConfig::default(), 11);
        let r1 = a.decode_page(16384, 500).0;
        let r2 = a.retry_page(16384, 500).0;
        let r3 = a.decode_page(16384, 500).0;
        assert_eq!(r1, b.decode_page(16384, 500).0);
        assert_eq!(r2, b.decode_page(16384, 500).0);
        assert_eq!(r3, b.decode_page(16384, 500).0);
        assert_eq!(a.stats().retries, 1);
        assert_eq!(b.stats().retries, 0);
        let mut sum = EccStats::default();
        sum.merge(a.stats());
        sum.merge(b.stats());
        assert_eq!(sum.pages, 6);
        assert_eq!(sum.retries, 1);
    }
}
