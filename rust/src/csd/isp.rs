//! ISP engine model: the quad-core ARM Cortex-A53 + shared DRAM that
//! runs training *inside* the Newport CSD (paper §III).
//!
//! Compute throughput (images/sec per network/batch) comes from the
//! calibrated [`perfmodel`](crate::perfmodel); this module adds the
//! engine's *constraints*: DRAM capacity (the paper's §V concern —
//! "large batch size on big networks can saturate the DRAM and stall
//! training") and core occupancy.

use anyhow::{bail, Result};

use crate::sim::{SimTime, Timeline};

#[derive(Debug, Clone)]
pub struct IspConfig {
    /// DRAM available to the ISP engine. The paper quotes 8 GB shared,
    /// ~6 GB usable for the training workload.
    pub dram_bytes: u64,
    /// Cores in the ISP cluster (quad A53). Training occupies all of
    /// them; the core timeline serializes co-resident jobs.
    pub cores: usize,
    /// Resident model/framework footprint independent of batch.
    pub framework_bytes: u64,
}

impl Default for IspConfig {
    fn default() -> Self {
        Self {
            dram_bytes: 6 * 1024 * 1024 * 1024,
            cores: 4,
            framework_bytes: 512 * 1024 * 1024,
        }
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct IspStats {
    pub steps: u64,
    pub images: u64,
}

/// One CSD's in-storage compute engine.
#[derive(Debug)]
pub struct IspEngine {
    cfg: IspConfig,
    /// The whole quad-core cluster as one service timeline (training
    /// steps are data-parallel across cores internally).
    cluster: Timeline,
    stats: IspStats,
}

impl IspEngine {
    pub fn new(cfg: IspConfig) -> Self {
        Self { cfg, cluster: Timeline::new(), stats: IspStats::default() }
    }

    pub fn stats(&self) -> IspStats {
        self.stats
    }

    pub fn busy_time(&self) -> SimTime {
        self.cluster.busy_time()
    }

    /// DRAM footprint of a training step: activations scale with batch.
    pub fn step_dram_bytes(
        &self,
        param_bytes: u64,
        activation_bytes_per_image: u64,
        batch: usize,
    ) -> u64 {
        // params + gradients + momentum + per-image activations
        self.cfg.framework_bytes
            + 3 * param_bytes
            + activation_bytes_per_image * batch as u64
    }

    /// Check a batch fits in DRAM (the paper's stall condition).
    pub fn admit(
        &self,
        param_bytes: u64,
        activation_bytes_per_image: u64,
        batch: usize,
    ) -> Result<()> {
        let need = self.step_dram_bytes(param_bytes, activation_bytes_per_image, batch);
        if need > self.cfg.dram_bytes {
            bail!(
                "DRAM saturated: step needs {:.2} GiB of {:.2} GiB (batch {batch})",
                need as f64 / (1u64 << 30) as f64,
                self.cfg.dram_bytes as f64 / (1u64 << 30) as f64,
            );
        }
        Ok(())
    }

    /// Book one training step of `compute` duration on the cluster,
    /// beginning once `inputs_ready`. Returns completion time.
    pub fn run_step(
        &mut self,
        compute: SimTime,
        inputs_ready: SimTime,
        batch: usize,
    ) -> SimTime {
        let (_, done) = self.cluster.schedule(inputs_ready, compute);
        self.stats.steps += 1;
        self.stats.images += batch as u64;
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_small_batches_reject_huge() {
        let isp = IspEngine::new(IspConfig::default());
        // MobileNetV2-class: 14 MB params, ~40 MB activations per image
        assert!(isp.admit(14_000_000, 40_000_000, 16).is_ok());
        assert!(isp.admit(14_000_000, 40_000_000, 10_000).is_err());
    }

    #[test]
    fn steps_serialize_on_the_cluster() {
        let mut isp = IspEngine::new(IspConfig::default());
        let d1 = isp.run_step(SimTime::secs(8), SimTime::ZERO, 25);
        let d2 = isp.run_step(SimTime::secs(8), SimTime::ZERO, 25);
        assert_eq!(d1, SimTime::secs(8));
        assert_eq!(d2, SimTime::secs(16));
        assert_eq!(isp.stats().images, 50);
    }

    #[test]
    fn waits_for_inputs() {
        let mut isp = IspEngine::new(IspConfig::default());
        let done = isp.run_step(SimTime::secs(1), SimTime::secs(5), 8);
        assert_eq!(done, SimTime::secs(6));
    }
}
