//! NVMe-over-PCIe front end: the *host* data path the ISP engine
//! bypasses.
//!
//! Paper §III: data headed to the host traverses the FE subsystem and
//! the "complex, power-consuming" NVMe-over-PCIe link; the ISP engine
//! reads flash directly over the internal bus. This module models the
//! host path: submission/completion queue overheads + PCIe transfer
//! time on a shared link timeline (the same link the TCP/IP tunnel
//! rides, so NVMe traffic and tunnel traffic contend realistically).

use crate::sim::{SimTime, Timeline};

#[derive(Debug, Clone)]
pub struct NvmeConfig {
    /// Effective PCIe bandwidth (bytes/s). Gen3 x4 ≈ 3.2 GB/s effective.
    pub pcie_bw: f64,
    /// Fixed per-command firmware/doorbell/interrupt overhead.
    pub cmd_overhead: SimTime,
    /// Max commands the FE can have in flight (queue depth).
    pub queue_depth: usize,
}

impl Default for NvmeConfig {
    fn default() -> Self {
        Self { pcie_bw: 3.2e9, cmd_overhead: SimTime::us(10), queue_depth: 256 }
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct NvmeStats {
    pub commands: u64,
    pub bytes: u64,
}

/// The FE + PCIe link pair.
#[derive(Debug)]
pub struct NvmeLink {
    cfg: NvmeConfig,
    /// Shared PCIe link occupancy (NVMe data + tunnel packets).
    link: Timeline,
    /// FE command processing (one ARM M7 in the paper).
    fe: Timeline,
    stats: NvmeStats,
}

impl NvmeLink {
    pub fn new(cfg: NvmeConfig) -> Self {
        Self { cfg, link: Timeline::new(), fe: Timeline::new(), stats: NvmeStats::default() }
    }

    pub fn stats(&self) -> NvmeStats {
        self.stats
    }

    pub fn link_busy(&self) -> SimTime {
        self.link.busy_time()
    }

    fn xfer_time(&self, bytes: usize) -> SimTime {
        SimTime::from_secs_f64(bytes as f64 / self.cfg.pcie_bw)
    }

    /// Issue one host-side transfer of `bytes` whose backend (flash)
    /// data is ready at `backend_done`. Returns completion at the host.
    pub fn transfer(&mut self, bytes: usize, now: SimTime, backend_done: SimTime) -> SimTime {
        // FE parses/validates the command first …
        let (_, fe_done) = self.fe.schedule(now, self.cfg.cmd_overhead);
        // … then the payload crosses PCIe once flash data is available.
        let ready = fe_done.max(backend_done);
        let (_, done) = self.link.schedule(ready, self.xfer_time(bytes));
        self.stats.commands += 1;
        self.stats.bytes += bytes as u64;
        done
    }

    /// Book raw link time for non-NVMe traffic (the TCP/IP tunnel).
    /// Returns completion of the wire transfer.
    pub fn occupy_link(&mut self, bytes: usize, now: SimTime) -> SimTime {
        let (_, done) = self.link.schedule(now, self.xfer_time(bytes));
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_includes_overhead_and_wire_time() {
        let mut n = NvmeLink::new(NvmeConfig::default());
        let done = n.transfer(3_200_000, SimTime::ZERO, SimTime::ZERO);
        // 10us overhead + 1ms wire time
        assert_eq!(done, SimTime::us(10) + SimTime::ms(1));
    }

    #[test]
    fn waits_for_backend() {
        let mut n = NvmeLink::new(NvmeConfig::default());
        let done = n.transfer(3200, SimTime::ZERO, SimTime::ms(5));
        assert!(done >= SimTime::ms(5));
    }

    #[test]
    fn tunnel_and_nvme_contend_for_link() {
        let mut n = NvmeLink::new(NvmeConfig::default());
        // Tunnel hogs the link for ~1ms.
        n.occupy_link(3_200_000, SimTime::ZERO);
        let done = n.transfer(3200, SimTime::ZERO, SimTime::ZERO);
        assert!(done > SimTime::ms(1), "NVMe transfer must queue behind tunnel burst");
    }

    #[test]
    fn stats_accumulate() {
        let mut n = NvmeLink::new(NvmeConfig::default());
        n.transfer(100, SimTime::ZERO, SimTime::ZERO);
        n.transfer(200, SimTime::ZERO, SimTime::ZERO);
        assert_eq!(n.stats().commands, 2);
        assert_eq!(n.stats().bytes, 300);
    }
}
