//! NAND flash array timing model.
//!
//! Newport's back end (paper §III): 16 flash channels operated in
//! parallel, each with multiple dies; page reads/programs occupy the
//! die, then the channel bus for the data transfer. Geometry and
//! timings default to a 3D-TLC part consistent with the paper's 32 TB
//! per-device capacity.

use crate::sim::{MultiTimeline, SimTime};

/// Physical page address.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PhysAddr {
    pub channel: u16,
    pub die: u16,
    pub block: u32,
    pub page: u32,
}

/// Array geometry + timing parameters.
#[derive(Debug, Clone)]
pub struct FlashConfig {
    pub channels: usize,
    pub dies_per_channel: usize,
    pub blocks_per_die: usize,
    pub pages_per_block: usize,
    pub page_bytes: usize,
    /// tR: page read (cell array -> page register)
    pub t_read: SimTime,
    /// tPROG: page program
    pub t_prog: SimTime,
    /// tBERS: block erase
    pub t_erase: SimTime,
    /// Channel bus bandwidth (bytes/sec) for register <-> controller.
    pub channel_bw: f64,
}

impl Default for FlashConfig {
    fn default() -> Self {
        Self {
            channels: 16,
            dies_per_channel: 4,
            // Scaled-down block count keeps FTL tables small in tests;
            // capacity-sensitive experiments override this.
            blocks_per_die: 256,
            pages_per_block: 64,
            page_bytes: 16 * 1024,
            t_read: SimTime::us(60),
            t_prog: SimTime::us(660),
            t_erase: SimTime::ms(3),
            channel_bw: 400.0e6,
        }
    }
}

impl FlashConfig {
    pub fn total_pages(&self) -> usize {
        self.channels * self.dies_per_channel * self.blocks_per_die * self.pages_per_block
    }

    pub fn total_blocks(&self) -> usize {
        self.channels * self.dies_per_channel * self.blocks_per_die
    }

    pub fn capacity_bytes(&self) -> usize {
        self.total_pages() * self.page_bytes
    }

    fn xfer_time(&self, bytes: usize) -> SimTime {
        SimTime::from_secs_f64(bytes as f64 / self.channel_bw)
    }
}

/// Cumulative operation counters (drives the power model).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FlashStats {
    pub reads: u64,
    pub programs: u64,
    pub erases: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
}

/// The array: per-die service timelines + per-channel bus timelines.
#[derive(Debug)]
pub struct FlashArray {
    cfg: FlashConfig,
    /// dies indexed channel-major: channel * dies_per_channel + die
    dies: MultiTimeline,
    /// channel buses
    buses: MultiTimeline,
    stats: FlashStats,
}

impl FlashArray {
    pub fn new(cfg: FlashConfig) -> Self {
        let dies = MultiTimeline::new(cfg.channels * cfg.dies_per_channel);
        let buses = MultiTimeline::new(cfg.channels);
        Self { cfg, dies, buses, stats: FlashStats::default() }
    }

    pub fn config(&self) -> &FlashConfig {
        &self.cfg
    }

    pub fn stats(&self) -> FlashStats {
        self.stats
    }

    fn die_index(&self, addr: PhysAddr) -> usize {
        addr.channel as usize * self.cfg.dies_per_channel + addr.die as usize
    }

    pub fn check_addr(&self, addr: PhysAddr) -> bool {
        (addr.channel as usize) < self.cfg.channels
            && (addr.die as usize) < self.cfg.dies_per_channel
            && (addr.block as usize) < self.cfg.blocks_per_die
            && (addr.page as usize) < self.cfg.pages_per_block
    }

    /// Read one page: die busy for tR, then channel bus for the
    /// transfer. Returns completion time.
    pub fn read_page(&mut self, addr: PhysAddr, now: SimTime) -> SimTime {
        assert!(self.check_addr(addr), "bad address {addr:?}");
        let die = self.die_index(addr);
        let (_, cell_done) = self.dies.schedule_on(die, now, self.cfg.t_read);
        let xfer = self.cfg.xfer_time(self.cfg.page_bytes);
        let (_, done) = self.buses.schedule_on(addr.channel as usize, cell_done, xfer);
        self.stats.reads += 1;
        self.stats.bytes_read += self.cfg.page_bytes as u64;
        done
    }

    /// Program one page: channel bus transfer in, then die busy for tPROG.
    pub fn program_page(&mut self, addr: PhysAddr, now: SimTime) -> SimTime {
        assert!(self.check_addr(addr), "bad address {addr:?}");
        let xfer = self.cfg.xfer_time(self.cfg.page_bytes);
        let (_, in_done) = self.buses.schedule_on(addr.channel as usize, now, xfer);
        let die = self.die_index(addr);
        let (_, done) = self.dies.schedule_on(die, in_done, self.cfg.t_prog);
        self.stats.programs += 1;
        self.stats.bytes_written += self.cfg.page_bytes as u64;
        done
    }

    /// Read a run of `count` physically consecutive pages of one block
    /// (starting at `addr0`) with coalesced timeline bookings:
    ///
    /// * the die cell reads all arrive at `now`, so their `count`
    ///   back-to-back tR bookings collapse into one `count * tR`
    ///   booking landing on exactly the same timeline state;
    /// * the channel-bus transfers arrive tR apart and serialize FIFO;
    ///   maximal contiguous stretches (each next arrival no later than
    ///   the rolling completion) collapse into one booking per
    ///   stretch — a stretch boundary is precisely where the per-page
    ///   loop would have left the bus idle.
    ///
    /// Per-page completion times are reconstructed in closed form and
    /// reported through `per_page(offset, done)` in run order; they,
    /// the final timeline state and the stats are bit-identical to a
    /// [`Self::read_page`] loop (property-tested below). Returns the
    /// last page's completion.
    pub fn read_run_with(
        &mut self,
        addr0: PhysAddr,
        count: u32,
        now: SimTime,
        mut per_page: impl FnMut(u32, SimTime),
    ) -> SimTime {
        if count == 0 {
            return now;
        }
        assert!(self.check_addr(addr0), "bad address {addr0:?}");
        assert!(
            addr0.page as usize + count as usize <= self.cfg.pages_per_block,
            "run of {count} pages overflows the block at {addr0:?}"
        );
        let die = self.die_index(addr0);
        let t_read = self.cfg.t_read;
        let (cell_start, _) = self.dies.schedule_on(die, now, t_read * count as u64);
        let xfer = self.cfg.xfer_time(self.cfg.page_bytes);
        let bus = addr0.channel as usize;
        let mut done = now;
        let mut i = 0u32;
        while i < count {
            let arrive = cell_start + t_read * (i as u64 + 1);
            let bus_free = self.buses.server(bus).next_free();
            // Offsets j = 1.. behind page i stay contiguous while
            // j * (tR - xfer) <= start - arrive (for tR <= xfer, every
            // later arrival lands on a busy bus: one stretch).
            let gap = arrive.max(bus_free) - arrive;
            let drain = t_read.as_ns().saturating_sub(xfer.as_ns());
            let stretch = if drain == 0 {
                count - i
            } else {
                (count - i).min(1 + (gap.as_ns() / drain) as u32)
            };
            // Promoted from debug-only: the coalescing math is only
            // bit-identical to the per-page loop if every stretch lands
            // exactly where FIFO would put it. Cheap u64 compare.
            let (start, _) = self.buses.schedule_on(bus, arrive, xfer * stretch as u64);
            assert_eq!(start, arrive.max(bus_free), "read stretch broke FIFO booking");
            for j in 0..stretch {
                done = start + xfer * (j as u64 + 1);
                per_page(i + j, done);
            }
            i += stretch;
        }
        self.stats.reads += count as u64;
        self.stats.bytes_read += count as u64 * self.cfg.page_bytes as u64;
        done
    }

    /// [`Self::read_run_with`] without the per-page callback.
    pub fn read_run(&mut self, addr0: PhysAddr, count: u32, now: SimTime) -> SimTime {
        self.read_run_with(addr0, count, now, |_, _| ())
    }

    /// Program a run of `count` physically consecutive pages of one
    /// block with coalesced bookings — the mirror of
    /// [`Self::read_run_with`]: the bus transfers in all arrive at
    /// `now` (one booking), the die programs arrive one transfer apart
    /// and coalesce per contiguous stretch. Bit-identical to a
    /// [`Self::program_page`] loop; returns the last page's completion
    /// and reports each page's through `per_page`.
    pub fn program_run_with(
        &mut self,
        addr0: PhysAddr,
        count: u32,
        now: SimTime,
        mut per_page: impl FnMut(u32, SimTime),
    ) -> SimTime {
        if count == 0 {
            return now;
        }
        assert!(self.check_addr(addr0), "bad address {addr0:?}");
        assert!(
            addr0.page as usize + count as usize <= self.cfg.pages_per_block,
            "run of {count} pages overflows the block at {addr0:?}"
        );
        let xfer = self.cfg.xfer_time(self.cfg.page_bytes);
        let bus = addr0.channel as usize;
        let (in_start, _) = self.buses.schedule_on(bus, now, xfer * count as u64);
        let die = self.die_index(addr0);
        let t_prog = self.cfg.t_prog;
        let mut done = now;
        let mut i = 0u32;
        while i < count {
            let arrive = in_start + xfer * (i as u64 + 1);
            let die_free = self.dies.server(die).next_free();
            let gap = arrive.max(die_free) - arrive;
            let drain = xfer.as_ns().saturating_sub(t_prog.as_ns());
            let stretch = if drain == 0 {
                count - i
            } else {
                (count - i).min(1 + (gap.as_ns() / drain) as u32)
            };
            // Promoted from debug-only, mirroring read_run_with.
            let (start, _) = self.dies.schedule_on(die, arrive, t_prog * stretch as u64);
            assert_eq!(start, arrive.max(die_free), "program stretch broke FIFO booking");
            for j in 0..stretch {
                done = start + t_prog * (j as u64 + 1);
                per_page(i + j, done);
            }
            i += stretch;
        }
        self.stats.programs += count as u64;
        self.stats.bytes_written += count as u64 * self.cfg.page_bytes as u64;
        done
    }

    /// [`Self::program_run_with`] without the per-page callback.
    pub fn program_run(&mut self, addr0: PhysAddr, count: u32, now: SimTime) -> SimTime {
        self.program_run_with(addr0, count, now, |_, _| ())
    }

    /// Erase a whole block (die busy for tBERS).
    pub fn erase_block(&mut self, addr: PhysAddr, now: SimTime) -> SimTime {
        assert!(self.check_addr(addr), "bad address {addr:?}");
        let die = self.die_index(addr);
        let (_, done) = self.dies.schedule_on(die, now, self.cfg.t_erase);
        self.stats.erases += 1;
        done
    }

    /// Mean die utilization over [0, horizon].
    pub fn die_utilization(&self, horizon: SimTime) -> f64 {
        self.dies.utilization(horizon)
    }

    /// Verify the booking ledger: every page op accounts exactly one
    /// page of traffic on every path (single-page, run-coalesced, and
    /// retry reads all increment pages and bytes together), so the
    /// byte counters are always page-count multiples.
    pub fn check_invariants(&self) -> crate::Result<()> {
        let page = self.cfg.page_bytes as u64;
        anyhow::ensure!(
            self.stats.bytes_read == self.stats.reads * page,
            "flash bytes_read {} != reads {} * page_bytes {page}",
            self.stats.bytes_read,
            self.stats.reads
        );
        anyhow::ensure!(
            self.stats.bytes_written == self.stats.programs * page,
            "flash bytes_written {} != programs {} * page_bytes {page}",
            self.stats.bytes_written,
            self.stats.programs
        );
        Ok(())
    }

    /// Aggregate sequential-read bandwidth estimate: time to stream
    /// `bytes` across all channels from `now`, returned as completion.
    pub fn stream_read(&mut self, bytes: usize, now: SimTime) -> SimTime {
        let pages = bytes.div_ceil(self.cfg.page_bytes);
        let mut done = now;
        for p in 0..pages {
            // stripe pages round-robin across channels and dies
            let addr = PhysAddr {
                channel: (p % self.cfg.channels) as u16,
                die: ((p / self.cfg.channels) % self.cfg.dies_per_channel) as u16,
                block: 0,
                page: (p % self.cfg.pages_per_block) as u32,
            };
            done = done.max(self.read_page(addr, now));
        }
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addr(c: u16, d: u16, b: u32, p: u32) -> PhysAddr {
        PhysAddr { channel: c, die: d, block: b, page: p }
    }

    #[test]
    fn read_latency_is_tr_plus_transfer() {
        let cfg = FlashConfig::default();
        let xfer = SimTime::from_secs_f64(cfg.page_bytes as f64 / cfg.channel_bw);
        let mut arr = FlashArray::new(cfg);
        let done = arr.read_page(addr(0, 0, 0, 0), SimTime::ZERO);
        assert_eq!(done, SimTime::us(60) + xfer);
    }

    #[test]
    fn same_die_serializes_different_dies_overlap() {
        let mut arr = FlashArray::new(FlashConfig::default());
        let d1 = arr.read_page(addr(0, 0, 0, 0), SimTime::ZERO);
        let d2 = arr.read_page(addr(0, 0, 0, 1), SimTime::ZERO); // same die
        assert!(d2 > d1, "same-die reads must serialize");
        let mut arr2 = FlashArray::new(FlashConfig::default());
        let e1 = arr2.read_page(addr(0, 0, 0, 0), SimTime::ZERO);
        let e2 = arr2.read_page(addr(1, 0, 0, 0), SimTime::ZERO); // other channel
        assert_eq!(e1, e2, "independent channels overlap fully");
    }

    #[test]
    fn program_slower_than_read() {
        let mut arr = FlashArray::new(FlashConfig::default());
        let r = arr.read_page(addr(0, 0, 0, 0), SimTime::ZERO);
        let mut arr2 = FlashArray::new(FlashConfig::default());
        let w = arr2.program_page(addr(0, 0, 0, 0), SimTime::ZERO);
        assert!(w > r);
    }

    #[test]
    fn stream_read_uses_all_channels() {
        let cfg = FlashConfig::default();
        let channels = cfg.channels;
        let page = cfg.page_bytes;
        let mut arr = FlashArray::new(cfg);
        // One page per channel: all complete in ~one page read time.
        let t_parallel = arr.stream_read(page * channels, SimTime::ZERO);
        let mut arr2 = FlashArray::new(FlashConfig::default());
        let t_single = arr2.read_page(addr(0, 0, 0, 0), SimTime::ZERO);
        assert_eq!(t_parallel, t_single);
        assert_eq!(arr.stats().reads, channels as u64);
    }

    #[test]
    fn audit_byte_conservation_on_every_op_path() {
        // FlashArray::check_invariants ties the byte counters to the
        // page counters on single-page, coalesced-run and erase paths.
        let mut arr = FlashArray::new(FlashConfig::default());
        arr.check_invariants().unwrap();
        arr.read_page(addr(0, 0, 0, 0), SimTime::ZERO);
        arr.program_page(addr(0, 0, 0, 1), SimTime::ZERO);
        arr.check_invariants().unwrap();
        arr.read_run(addr(1, 0, 0, 0), 8, SimTime::ZERO);
        arr.program_run(addr(2, 0, 0, 0), 8, SimTime::ZERO);
        arr.erase_block(addr(0, 0, 0, 0), SimTime::ZERO);
        arr.check_invariants().unwrap();
        let s = arr.stats();
        assert_eq!(s.reads, 9);
        assert_eq!(s.programs, 9);
        assert_eq!(s.erases, 1);
    }

    /// Property: run bookings are bit-identical to the per-page loop —
    /// per-page completion times, final timeline state (observed via
    /// probe bookings on every die and bus) and stats — across both
    /// stretch regimes (cell-bound tR > xfer with bus idle gaps, and
    /// bus-bound tR <= xfer with one contiguous stretch).
    #[test]
    fn property_run_bookings_match_per_page() {
        crate::util::prop::check("flash run ops match per-page bookings", |rng| {
            let cfg = FlashConfig {
                channels: 2,
                dies_per_channel: 2,
                blocks_per_die: 4,
                pages_per_block: 16,
                page_bytes: 4096,
                t_read: [SimTime::us(5), SimTime::us(60), SimTime::us(200)]
                    [rng.usize_below(3)],
                t_prog: [SimTime::us(20), SimTime::us(660)][rng.usize_below(2)],
                channel_bw: [50.0e6, 400.0e6][rng.usize_below(2)],
                ..Default::default()
            };
            let mut a = FlashArray::new(cfg.clone());
            let mut b = FlashArray::new(cfg);
            for _ in 0..40 {
                let page = rng.usize_below(16) as u32;
                let base = PhysAddr {
                    channel: rng.usize_below(2) as u16,
                    die: rng.usize_below(2) as u16,
                    block: rng.usize_below(4) as u32,
                    page,
                };
                let now = SimTime::us(rng.below(500));
                let count = 1 + rng.usize_below((16 - page as usize).min(8)) as u32;
                match rng.usize_below(4) {
                    // Interleave plain ops so runs start from varied
                    // (and sometimes backlogged) timeline states.
                    0 => {
                        assert_eq!(a.read_page(base, now), b.read_page(base, now));
                    }
                    1 => {
                        assert_eq!(a.program_page(base, now), b.program_page(base, now));
                    }
                    2 => {
                        let mut runs = Vec::new();
                        let last = a.read_run_with(base, count, now, |i, d| runs.push((i, d)));
                        let mut pages = Vec::new();
                        for i in 0..count {
                            let d = b.read_page(PhysAddr { page: base.page + i, ..base }, now);
                            pages.push((i, d));
                        }
                        assert_eq!(runs, pages, "read-run per-page completions");
                        assert_eq!(last, pages.last().unwrap().1);
                    }
                    _ => {
                        let mut runs = Vec::new();
                        let last =
                            a.program_run_with(base, count, now, |i, d| runs.push((i, d)));
                        let mut pages = Vec::new();
                        for i in 0..count {
                            let d =
                                b.program_page(PhysAddr { page: base.page + i, ..base }, now);
                            pages.push((i, d));
                        }
                        assert_eq!(runs, pages, "program-run per-page completions");
                        assert_eq!(last, pages.last().unwrap().1);
                    }
                }
            }
            assert_eq!(a.stats(), b.stats());
            // Probe every die: identical next-free state on both sides.
            for c in 0..2u16 {
                for d in 0..2u16 {
                    let probe = PhysAddr { channel: c, die: d, block: 0, page: 0 };
                    assert_eq!(
                        a.read_page(probe, SimTime::ZERO),
                        b.read_page(probe, SimTime::ZERO)
                    );
                }
            }
        });
    }

    #[test]
    fn empty_run_is_a_no_op() {
        let mut arr = FlashArray::new(FlashConfig::default());
        let t = SimTime::ms(3);
        assert_eq!(arr.read_run(addr(0, 0, 0, 0), 0, t), t);
        assert_eq!(arr.program_run(addr(0, 0, 0, 0), 0, t), t);
        assert_eq!(arr.stats(), FlashStats::default());
    }

    #[test]
    #[should_panic(expected = "overflows the block")]
    fn overlong_run_panics() {
        let mut arr = FlashArray::new(FlashConfig::default());
        let pages = arr.config().pages_per_block as u32;
        arr.read_run(addr(0, 0, 0, 1), pages, SimTime::ZERO);
    }

    #[test]
    #[should_panic(expected = "bad address")]
    fn bad_address_panics() {
        let mut arr = FlashArray::new(FlashConfig::default());
        arr.read_page(addr(99, 0, 0, 0), SimTime::ZERO);
    }

    #[test]
    fn stats_accumulate() {
        let mut arr = FlashArray::new(FlashConfig::default());
        arr.read_page(addr(0, 0, 0, 0), SimTime::ZERO);
        arr.program_page(addr(0, 0, 0, 1), SimTime::ZERO);
        arr.erase_block(addr(0, 0, 0, 0), SimTime::ZERO);
        let s = arr.stats();
        assert_eq!((s.reads, s.programs, s.erases), (1, 1, 1));
        assert_eq!(s.bytes_read as usize, arr.config().page_bytes);
    }
}
