//! The Newport CSD: FE (NVMe) + BE (FTL/flash/ECC) + ISP engine.
//!
//! Exposes the two data paths the paper contrasts:
//!   * **host path** — flash → BE → FE → NVMe-over-PCIe → host DRAM
//!   * **ISP path**  — flash → BE → internal bus → ISP DRAM
//! The ISP path skips the FE and the PCIe serialization entirely; the
//! asymmetry in both latency and energy between these two calls is the
//! paper's core hardware claim.

use anyhow::Result;

use crate::sim::SimTime;

use super::ftl::{Ftl, FtlConfig};
use super::isp::{IspConfig, IspEngine, IspStats};
use super::nvme::{NvmeConfig, NvmeLink, NvmeStats};

#[derive(Debug, Clone, Default)]
pub struct CsdConfig {
    pub ftl: FtlConfig,
    pub nvme: NvmeConfig,
    pub isp: IspConfig,
    /// Internal bus bandwidth for the ISP path (bytes/s); the shared
    /// data bus of Fig. 1 is much faster than the external PCIe hop.
    pub internal_bus_bw: Option<f64>,
}

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CsdIoStats {
    pub host_path_reads: u64,
    pub host_path_bytes: u64,
    pub isp_path_reads: u64,
    pub isp_path_bytes: u64,
}

/// One Newport device.
pub struct NewportCsd {
    pub id: usize,
    ftl: Ftl,
    nvme: NvmeLink,
    isp: IspEngine,
    internal_bus_bw: f64,
    io: CsdIoStats,
}

impl NewportCsd {
    pub fn new(id: usize, cfg: CsdConfig, seed: u64) -> Self {
        Self {
            id,
            ftl: Ftl::new(cfg.ftl, seed ^ (id as u64).wrapping_mul(0x9E37)),
            nvme: NvmeLink::new(cfg.nvme),
            isp: IspEngine::new(cfg.isp),
            internal_bus_bw: cfg.internal_bus_bw.unwrap_or(6.4e9),
            io: CsdIoStats::default(),
        }
    }

    pub fn ftl(&mut self) -> &mut Ftl {
        &mut self.ftl
    }

    pub fn ftl_ref(&self) -> &Ftl {
        &self.ftl
    }

    pub fn isp(&self) -> &IspEngine {
        &self.isp
    }

    pub fn io_stats(&self) -> CsdIoStats {
        self.io
    }

    pub fn nvme_stats(&self) -> NvmeStats {
        self.nvme.stats()
    }

    pub fn isp_stats(&self) -> IspStats {
        self.isp.stats()
    }

    pub fn page_bytes(&self) -> usize {
        self.ftl.page_bytes()
    }

    /// Write a logical page (either path lands in the same FTL).
    pub fn write_page(&mut self, lpn: u32, tag: u64, now: SimTime) -> Result<SimTime> {
        self.ftl.write(lpn, tag, now)
    }

    /// Write an extent: `len` logical pages from `lpn0`, all tagged
    /// `tag` (an image's pages carry its image id). Bit-identical to a
    /// [`Self::write_page`] loop, without the per-page call overhead.
    pub fn write_run(&mut self, lpn0: u32, len: u32, tag: u64, now: SimTime) -> Result<SimTime> {
        self.ftl.write_fill(lpn0, len, tag, now)
    }

    /// Trim an extent (NVMe Deallocate): unmap `len` logical pages from
    /// `lpn0` so GC can reclaim them. Metadata-only — no timing booked.
    /// Returns how many pages were actually mapped (freed).
    pub fn trim_run(&mut self, lpn0: u32, len: u32) -> Result<u64> {
        self.ftl.trim_run(lpn0, len)
    }

    /// Host path: read `lpns` and ship them over NVMe. Returns arrival
    /// time of the last byte at the host.
    pub fn read_for_host(&mut self, lpns: &[u32], now: SimTime) -> Result<SimTime> {
        let page = self.ftl.page_bytes();
        let mut done = now;
        for &lpn in lpns {
            let r = self.ftl.read(lpn, now)?;
            let host_done = self.nvme.transfer(page, now, r.done);
            done = done.max(host_done);
        }
        self.io.host_path_reads += lpns.len() as u64;
        self.io.host_path_bytes += (lpns.len() * page) as u64;
        Ok(done)
    }

    /// ISP path: read `lpns` into ISP DRAM over the internal bus — no
    /// FE, no PCIe. Returns availability time in ISP DRAM.
    pub fn read_for_isp(&mut self, lpns: &[u32], now: SimTime) -> Result<SimTime> {
        let page = self.ftl.page_bytes();
        let bus_time = SimTime::from_secs_f64(page as f64 / self.internal_bus_bw);
        let mut done = now;
        for &lpn in lpns {
            let r = self.ftl.read(lpn, now)?;
            done = done.max(r.done + bus_time);
        }
        self.io.isp_path_reads += lpns.len() as u64;
        self.io.isp_path_bytes += (lpns.len() * page) as u64;
        Ok(done)
    }

    /// [`Self::read_for_host`] over one contiguous LPN extent: each
    /// page is read and shipped over NVMe exactly as the slice path
    /// would book it — bit-identical, with no LPN scratch list.
    pub fn read_for_host_run(&mut self, lpn0: u32, len: u32, now: SimTime) -> Result<SimTime> {
        let page = self.ftl.page_bytes();
        let NewportCsd { ftl, nvme, io, .. } = self;
        let mut done = now;
        // Flash and NVMe occupy disjoint timelines, so pipelining each
        // page's transfer from the run callback books the same times.
        ftl.read_run_with(lpn0, len, now, |_, page_done| {
            done = done.max(nvme.transfer(page, now, page_done));
        })?;
        io.host_path_reads += len as u64;
        io.host_path_bytes += len as u64 * page as u64;
        Ok(done)
    }

    /// [`Self::read_for_isp`] over one contiguous LPN extent.
    pub fn read_for_isp_run(&mut self, lpn0: u32, len: u32, now: SimTime) -> Result<SimTime> {
        if len == 0 {
            return Ok(now);
        }
        let page = self.ftl.page_bytes();
        let bus_time = SimTime::from_secs_f64(page as f64 / self.internal_bus_bw);
        let done = self.ftl.read_run(lpn0, len, now)?;
        self.io.isp_path_reads += len as u64;
        self.io.isp_path_bytes += len as u64 * page as u64;
        Ok(done + bus_time)
    }

    /// ISP path over a wrapping LPN range: pages `(start + i) % wrap`
    /// for `i in 0..count` — the cyclic preloaded-staging shape of the
    /// legacy `stage_io` executors, without building the LPN list.
    fn read_for_isp_wrapped(
        &mut self,
        start: u32,
        count: u32,
        wrap: u32,
        now: SimTime,
    ) -> Result<SimTime> {
        anyhow::ensure!(wrap > 0, "wrapping LPN range needs a nonzero modulus");
        let page = self.ftl.page_bytes();
        let bus_time = SimTime::from_secs_f64(page as f64 / self.internal_bus_bw);
        let mut done = now;
        for i in 0..count {
            let r = self.ftl.read(start.wrapping_add(i) % wrap, now)?;
            done = done.max(r.done + bus_time);
        }
        self.io.isp_path_reads += count as u64;
        self.io.isp_path_bytes += count as u64 * page as u64;
        Ok(done)
    }

    /// Host path over a wrapping LPN range (see
    /// [`Self::read_for_isp_wrapped`]); mirrors a
    /// [`Self::read_for_host`] call on the expanded list.
    pub fn read_for_host_wrapped(
        &mut self,
        start: u32,
        count: u32,
        wrap: u32,
        now: SimTime,
    ) -> Result<SimTime> {
        anyhow::ensure!(wrap > 0, "wrapping LPN range needs a nonzero modulus");
        let page = self.ftl.page_bytes();
        let mut done = now;
        for i in 0..count {
            let r = self.ftl.read(start.wrapping_add(i) % wrap, now)?;
            done = done.max(self.nvme.transfer(page, now, r.done));
        }
        self.io.host_path_reads += count as u64;
        self.io.host_path_bytes += count as u64 * page as u64;
        Ok(done)
    }

    /// Run one in-storage training step: stage `data_lpns` via the ISP
    /// path, then occupy the ISP cluster for `compute`. DRAM admission
    /// is checked against the batch footprint.
    pub fn isp_train_step(
        &mut self,
        data_lpns: &[u32],
        compute: SimTime,
        param_bytes: u64,
        activation_bytes_per_image: u64,
        batch: usize,
        now: SimTime,
    ) -> Result<SimTime> {
        self.isp.admit(param_bytes, activation_bytes_per_image, batch)?;
        let inputs_ready = self.read_for_isp(data_lpns, now)?;
        Ok(self.isp.run_step(compute, inputs_ready, batch))
    }

    /// [`Self::isp_train_step`] over a wrapping LPN range: stages
    /// `count` pages starting at `start` modulo `wrap` — the
    /// scratch-free variant for cyclic preloaded staging.
    #[allow(clippy::too_many_arguments)]
    pub fn isp_train_step_range(
        &mut self,
        start: u32,
        count: u32,
        wrap: u32,
        compute: SimTime,
        param_bytes: u64,
        activation_bytes_per_image: u64,
        batch: usize,
        now: SimTime,
    ) -> Result<SimTime> {
        self.isp.admit(param_bytes, activation_bytes_per_image, batch)?;
        let inputs_ready = self.read_for_isp_wrapped(start, count, wrap, now)?;
        Ok(self.isp.run_step(compute, inputs_ready, batch))
    }

    /// Book tunnel traffic on the shared PCIe link (allreduce bytes).
    pub fn tunnel_transfer(&mut self, bytes: usize, now: SimTime) -> SimTime {
        self.nvme.occupy_link(bytes, now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::csd::flash::FlashConfig;

    fn small_csd() -> NewportCsd {
        let cfg = CsdConfig {
            ftl: FtlConfig {
                flash: FlashConfig {
                    channels: 4,
                    dies_per_channel: 2,
                    blocks_per_die: 16,
                    pages_per_block: 16,
                    page_bytes: 4096,
                    ..Default::default()
                },
                overprovision: 0.2,
                gc_low_water: 3,
                gc_high_water: 5,
                ..Default::default()
            },
            ..Default::default()
        };
        NewportCsd::new(0, cfg, 7)
    }

    fn write_pages(csd: &mut NewportCsd, n: u32) {
        for lpn in 0..n {
            csd.write_page(lpn, lpn as u64, SimTime::ZERO).unwrap();
        }
    }

    #[test]
    fn isp_path_faster_than_host_path() {
        let mut a = small_csd();
        write_pages(&mut a, 64);
        let lpns: Vec<u32> = (0..64).collect();
        let host = a.read_for_host(&lpns, SimTime::ms(10)).unwrap();

        let mut b = small_csd();
        write_pages(&mut b, 64);
        let isp = b.read_for_isp(&lpns, SimTime::ms(10)).unwrap();
        assert!(
            isp < host,
            "ISP path must beat flash->NVMe->host: isp={isp}, host={host}"
        );
    }

    #[test]
    fn train_step_stages_then_computes() {
        let mut csd = small_csd();
        write_pages(&mut csd, 8);
        let done = csd
            .isp_train_step(&[0, 1, 2, 3], SimTime::secs(8), 14_000_000, 1_000_000, 4, SimTime::ZERO)
            .unwrap();
        assert!(done >= SimTime::secs(8));
        assert_eq!(csd.isp_stats().steps, 1);
        assert_eq!(csd.io_stats().isp_path_reads, 4);
    }

    #[test]
    fn dram_saturation_rejected() {
        let mut csd = small_csd();
        write_pages(&mut csd, 4);
        let r = csd.isp_train_step(
            &[0],
            SimTime::secs(1),
            14_000_000,
            50_000_000, // 50 MB activations per image
            1000,       // * 1000 images >> 6 GB
            SimTime::ZERO,
        );
        assert!(r.is_err());
    }

    /// Extent entry points are bit-identical to the slice/per-page
    /// paths: completion times, FTL/flash state and io stats — on twin
    /// devices fed the same workload.
    #[test]
    fn extent_paths_match_slice_paths() {
        let mut a = small_csd();
        let mut b = small_csd();
        for img in 0..8u32 {
            let ea = a.write_run(img * 4, 4, img as u64, SimTime::ZERO).unwrap();
            let mut eb = SimTime::ZERO;
            for k in 0..4 {
                eb = eb.max(b.write_page(img * 4 + k, img as u64, SimTime::ZERO).unwrap());
            }
            assert_eq!(ea, eb, "image {img} extent layout");
        }
        let lpns: Vec<u32> = (8..20).collect();
        let ia = a.read_for_isp_run(8, 12, SimTime::ms(1)).unwrap();
        let ib = b.read_for_isp(&lpns, SimTime::ms(1)).unwrap();
        assert_eq!(ia, ib, "ISP staging");
        let ha = a.read_for_host_run(8, 12, SimTime::ms(2)).unwrap();
        let hb = b.read_for_host(&lpns, SimTime::ms(2)).unwrap();
        assert_eq!(ha, hb, "host staging");
        assert_eq!(a.io_stats(), b.io_stats());
        // Wrapping ranges == the expanded LPN list.
        let wrapped: Vec<u32> = (0..10).map(|i| (30 + i) % 32).collect();
        let wa = a.read_for_host_wrapped(30, 10, 32, SimTime::ms(3)).unwrap();
        let wb = b.read_for_host(&wrapped, SimTime::ms(3)).unwrap();
        assert_eq!(wa, wb, "wrapped host staging");
        let ta = a
            .isp_train_step_range(30, 10, 32, SimTime::secs(1), 1 << 20, 1 << 16, 4, SimTime::ms(4))
            .unwrap();
        let tb = b
            .isp_train_step(&wrapped, SimTime::secs(1), 1 << 20, 1 << 16, 4, SimTime::ms(4))
            .unwrap();
        assert_eq!(ta, tb, "wrapped train step");
        assert_eq!(a.io_stats(), b.io_stats());
        assert_eq!(a.isp_stats().steps, b.isp_stats().steps);
        assert_eq!(a.ftl_ref().stats(), b.ftl_ref().stats());
        assert_eq!(a.ftl_ref().flash_stats(), b.ftl_ref().flash_stats());
    }

    #[test]
    fn tunnel_traffic_contends_with_host_reads() {
        let mut csd = small_csd();
        write_pages(&mut csd, 4);
        csd.tunnel_transfer(32_000_000, SimTime::ZERO); // ~10ms link burst
        let done = csd.read_for_host(&[0], SimTime::ZERO).unwrap();
        assert!(done > SimTime::ms(9), "host read must queue behind tunnel burst");
    }
}
