//! Inert XLA/PJRT binding surface.
//!
//! The real-execution path ([`crate::runtime::Engine`]) is written
//! against the `xla_extension`-style API (clients, loaded executables,
//! literals). This container builds without that native runtime, so
//! this module provides the same surface with every entry point that
//! would touch PJRT returning a typed "built without XLA" error. The
//! modeled experiments — tuning, the paper figures, the fleet and
//! workload runtimes — never reach this module; the artifact-dependent
//! integration suites skip themselves when no artifacts directory is
//! present.
//!
//! Swapping in a real binding is a matter of replacing this module
//! (the `xla::` paths in `runtime/engine.rs` and `model/tensor.rs`
//! resolve here via `use crate::xla;`).

use std::path::Path;

use crate::Result;

fn unavailable() -> anyhow::Error {
    anyhow::anyhow!("stannis was built without the XLA/PJRT runtime")
}

/// A host-side literal value (tensor of bits + shape).
#[derive(Debug, Clone, Default)]
pub struct Literal {}

impl Literal {
    /// A rank-0 i32 literal.
    pub fn scalar(_v: i32) -> Literal {
        Literal {}
    }

    /// A rank-1 literal from a slice.
    pub fn vec1<T>(_vals: &[T]) -> Literal {
        Literal {}
    }

    /// Reshape to `dims` (element count must match).
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    /// The array shape of a non-tuple literal.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(unavailable())
    }

    /// Copy the elements out as a typed vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    /// Destructure a tuple literal into its parts.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }
}

/// Dimensions of an array-shaped literal.
#[derive(Debug, Clone, Default)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// An HLO module parsed from text.
#[derive(Debug, Clone, Default)]
pub struct HloModuleProto {}

impl HloModuleProto {
    /// Parse an HLO-text artifact file.
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// A computation ready to compile.
#[derive(Debug, Clone, Default)]
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// A PJRT client (one per process, CPU platform).
#[derive(Debug)]
pub struct PjRtClient {}

impl PjRtClient {
    /// Bring up the CPU client. Always errors in this build.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    /// Compile a computation for this client.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// A compiled executable resident on the client.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    /// Execute with literal arguments; returns per-device, per-output
    /// buffers.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// A device-resident buffer.
#[derive(Debug)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_pjrt_entry_point_reports_the_missing_runtime() {
        let msg = "built without the XLA/PJRT runtime";
        assert!(PjRtClient::cpu().unwrap_err().to_string().contains(msg));
        assert!(HloModuleProto::from_text_file("x.hlo").unwrap_err().to_string().contains(msg));
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).unwrap_err().to_string().contains(msg));
        assert!(lit.to_vec::<f32>().unwrap_err().to_string().contains(msg));
        assert!(lit.to_tuple().unwrap_err().to_string().contains(msg));
        assert!(lit.array_shape().unwrap_err().to_string().contains(msg));
        let _ = Literal::scalar(3);
        let comp = XlaComputation::from_proto(&HloModuleProto::default());
        let _ = format!("{comp:?}");
    }
}
