//! Calibrated device throughput model — the paper's testbed in numbers.
//!
//! We cannot run a Xeon Silver 4108 and 24 quad-A53 ISP engines, so the
//! *modeled* experiments (Table I, Fig. 6/7, Table II) drive the real
//! Stannis coordinator with this device model instead of wallclock. The
//! anchors are Table I itself: peak images/sec per (device, network)
//! and the batch-saturation behaviour described in §V ("speed converges
//! after a certain batch size" — ~16 for MobileNetV2 on Newport,
//! ~300 on the host).
//!
//! Throughput follows a saturating curve
//!     ips(bs) = peak * bs / (bs + bs_half)
//! which matches both quoted saturation points and gives Algorithm 1 a
//! realistic landscape to search. Sync costs are *not* modeled here —
//! they come from the tunnel + allreduce modules.
//!
//! Network names are interned into [`NetId`]s at config-load /
//! admission time; the `*_id` methods are the allocation-free hot path
//! and the string-keyed methods are compatibility shims over
//! [`NetId::resolve`] (DESIGN.md §Perf).

use std::cell::RefCell;
// The memo is a keyed cache, never iterated, so hasher order cannot
// leak into any result. lint: allow(hash-iter)
#[allow(clippy::disallowed_types)]
use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::sim::SimTime;

/// Which physical engine executes a worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Device {
    /// Xeon Silver 4108 (8C/16T) — the host.
    HostXeon,
    /// Newport ISP engine (quad Cortex-A53).
    NewportIsp,
}

/// Per-(network, device) calibration anchors.
#[derive(Debug, Clone, Copy)]
pub struct NetCalib {
    /// Paper network name.
    pub name: &'static str,
    /// Asymptotic peak images/sec on the host / Newport.
    pub host_peak: f64,
    pub newport_peak: f64,
    /// Half-saturation batch sizes (curve knee).
    pub host_bs_half: f64,
    pub newport_bs_half: f64,
    /// Paper-scale model size (for sync-byte accounting) and MACs.
    pub params: u64,
    pub macs_per_image: u64,
}

/// Calibration table derived from paper Table I (tuned batch + speed)
/// plus the §V saturation notes.
pub const CALIBRATION: &[NetCalib] = &[
    NetCalib {
        name: "mobilenet_v2",
        // Table I: host 31.05 img/s @ bs 315; text: 32.3 peak.
        host_peak: 34.0,
        host_bs_half: 30.0,
        // Table I: newport 3.08 @ bs 25; ≈3 for every bs ≥ 16.
        newport_peak: 3.2,
        newport_bs_half: 1.0,
        params: 3_470_000,
        macs_per_image: 56_000_000,
    },
    NetCalib {
        name: "nasnet",
        // Table I: host 47.31 @ 325; newport 2.80 @ 15.
        host_peak: 51.5,
        host_bs_half: 29.0,
        newport_peak: 3.0,
        newport_bs_half: 1.1,
        params: 5_300_000,
        macs_per_image: 564_000_000,
    },
    NetCalib {
        name: "inception_v3",
        // Table I: host 30.80 @ 370; newport 1.85 @ 16.
        host_peak: 33.2,
        host_bs_half: 29.0,
        newport_peak: 1.95,
        newport_bs_half: 0.5,
        params: 23_830_000,
        macs_per_image: 5_720_000_000,
    },
    NetCalib {
        name: "squeezenet",
        // Table I: host 219.0 @ 850; newport 16.3 @ 50.
        host_peak: 227.0,
        host_bs_half: 31.0,
        newport_peak: 16.9,
        newport_bs_half: 1.8,
        params: 1_250_000,
        macs_per_image: 861_000_000,
    },
];

/// Interned network identity: an index into [`CALIBRATION`].
///
/// Resolved once (config load / job admission) so the per-step hot
/// path — `ips_id` / `step_time_id` / `sync_bytes` — is plain array
/// indexing instead of a string-compare chain (DESIGN.md §Perf). The
/// string-keyed entry points remain as thin shims over
/// [`NetId::resolve`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NetId(u16);

impl NetId {
    /// Map a repo network name (including scaled-model aliases) to its
    /// calibration row.
    pub fn resolve(name: &str) -> Result<NetId> {
        let key = match name {
            "mobilenet_v2" | "mobilenet_v2_s" | "mobilenetv2" => "mobilenet_v2",
            "nasnet" | "nasnet_s" => "nasnet",
            "inception_v3" | "inception_v3_s" | "inceptionv3" => "inception_v3",
            "squeezenet" | "squeezenet_s" => "squeezenet",
            other => other,
        };
        CALIBRATION
            .iter()
            .position(|c| c.name == key)
            .map(|i| NetId(i as u16))
            .ok_or_else(|| anyhow::anyhow!("no calibration for network {name:?}"))
    }

    /// The calibration row — a direct array index.
    #[inline]
    pub fn calib(self) -> &'static NetCalib {
        &CALIBRATION[self.0 as usize]
    }

    /// Canonical (calibration-table) name.
    pub fn name(self) -> &'static str {
        self.calib().name
    }

    /// Gradient bytes synchronized per step (paper-scale params, f32).
    #[inline]
    pub fn sync_bytes(self) -> usize {
        self.calib().params as usize * 4
    }

    /// Every interned network, in calibration order.
    pub fn all() -> impl Iterator<Item = NetId> {
        (0..CALIBRATION.len()).map(|i| NetId(i as u16))
    }
}

/// Map repo network names (scaled models) to calibration rows — the
/// historical string-keyed entry point, now a shim over [`NetId`].
pub fn calib_for(name: &str) -> Result<&'static NetCalib> {
    Ok(NetId::resolve(name)?.calib())
}

/// Memo key for [`PerfModel::step_time_cached`]. The scale factors are
/// keyed by bit pattern so mutating `host_scale`/`newport_scale` after
/// populating the cache can never serve a stale entry.
type StepTimeKey = (Device, NetId, usize, u64, u64);

/// The device model used by tuning/scheduling in modeled mode.
#[derive(Debug, Clone)]
pub struct PerfModel {
    /// Relative speed multiplier per device (fault/ablation hook;
    /// 1.0 = calibrated speed).
    pub host_scale: f64,
    pub newport_scale: f64,
    /// Memoized step times for the Algorithm-1 tuning sweep, which
    /// revisits the same (device, net, batch) probes many times.
    /// Lookup-only (never iterated). lint: allow(hash-iter)
    #[allow(clippy::disallowed_types)]
    memo: RefCell<HashMap<StepTimeKey, SimTime>>,
}

impl Default for PerfModel {
    fn default() -> Self {
        Self::with_scales(1.0, 1.0)
    }
}

impl PerfModel {
    /// A model with per-device speed multipliers (1.0 = calibrated).
    pub fn with_scales(host_scale: f64, newport_scale: f64) -> Self {
        // lint: allow(hash-iter)
        Self { host_scale, newport_scale, memo: RefCell::new(HashMap::new()) }
    }

    /// Images/sec for (device, network) at a given batch size — the
    /// string-keyed shim over [`PerfModel::ips_id`].
    pub fn ips(&self, device: Device, network: &str, batch: usize) -> Result<f64> {
        self.ips_id(device, NetId::resolve(network)?, batch)
    }

    /// Images/sec for an interned network: branch-free table lookup,
    /// no allocation — the per-step hot path.
    #[inline]
    pub fn ips_id(&self, device: Device, net: NetId, batch: usize) -> Result<f64> {
        bail_on_zero_batch(batch)?;
        let c = net.calib();
        let (peak, half, scale) = match device {
            Device::HostXeon => (c.host_peak, c.host_bs_half, self.host_scale),
            Device::NewportIsp => (c.newport_peak, c.newport_bs_half, self.newport_scale),
        };
        let bs = batch as f64;
        Ok(scale * peak * bs / (bs + half))
    }

    /// Wall time for one training step (one batch) on the device — the
    /// string-keyed shim over [`PerfModel::step_time_id`].
    pub fn step_time(&self, device: Device, network: &str, batch: usize) -> Result<SimTime> {
        self.step_time_id(device, NetId::resolve(network)?, batch)
    }

    /// Step time for an interned network (pure computation, no cache —
    /// callers on the simulation hot path construct throwaway models).
    #[inline]
    pub fn step_time_id(&self, device: Device, net: NetId, batch: usize) -> Result<SimTime> {
        let ips = self.ips_id(device, net, batch)?;
        Ok(SimTime::from_secs_f64(batch as f64 / ips))
    }

    /// Memoized [`PerfModel::step_time_id`] for the tuning sweep:
    /// Algorithm 1 probes the same batch ladder repeatedly, and
    /// hypertuning-style searches multiply the probe count further.
    pub fn step_time_cached(&self, device: Device, net: NetId, batch: usize) -> Result<SimTime> {
        let key =
            (device, net, batch, self.host_scale.to_bits(), self.newport_scale.to_bits());
        if let Some(&t) = self.memo.borrow().get(&key) {
            return Ok(t);
        }
        let t = self.step_time_id(device, net, batch)?;
        self.memo.borrow_mut().insert(key, t);
        Ok(t)
    }

    /// Gradient bytes synchronized per step (paper-scale params, f32)
    /// — string-keyed shim over [`NetId::sync_bytes`].
    pub fn sync_bytes(&self, network: &str) -> Result<usize> {
        Ok(NetId::resolve(network)?.sync_bytes())
    }
}

fn bail_on_zero_batch(batch: usize) -> Result<()> {
    if batch == 0 {
        bail!("batch size 0");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_match_table1() {
        let m = PerfModel::default();
        // Table I row checks within 3%.
        let cases = [
            (Device::HostXeon, "mobilenet_v2", 315, 31.05),
            (Device::NewportIsp, "mobilenet_v2", 25, 3.08),
            (Device::HostXeon, "nasnet", 325, 47.31),
            (Device::NewportIsp, "nasnet", 15, 2.80),
            (Device::HostXeon, "inception_v3", 370, 30.80),
            (Device::NewportIsp, "inception_v3", 16, 1.85),
            (Device::HostXeon, "squeezenet", 850, 219.0),
            (Device::NewportIsp, "squeezenet", 50, 16.3),
        ];
        for (dev, net, bs, want) in cases {
            let got = m.ips(dev, net, bs).unwrap();
            assert!(
                (got - want).abs() / want < 0.03,
                "{net:?} on {dev:?} @ {bs}: {got:.2} vs paper {want:.2}"
            );
        }
    }

    #[test]
    fn newport_saturates_by_bs16() {
        // §V: "about 3 images per second for all batch sizes greater
        // than 16" (MobileNetV2 on Newport).
        let m = PerfModel::default();
        let at16 = m.ips(Device::NewportIsp, "mobilenet_v2", 16).unwrap();
        let at64 = m.ips(Device::NewportIsp, "mobilenet_v2", 64).unwrap();
        assert!((at64 - at16) / at16 < 0.06, "{at16} -> {at64}");
    }

    #[test]
    fn ips_monotone_in_batch() {
        let m = PerfModel::default();
        let mut last = 0.0;
        for bs in [1, 2, 4, 8, 16, 32, 64, 128, 256, 512] {
            let v = m.ips(Device::HostXeon, "mobilenet_v2", bs).unwrap();
            assert!(v > last);
            last = v;
        }
    }

    #[test]
    fn step_time_scales_with_batch() {
        let m = PerfModel::default();
        let t25 = m.step_time(Device::NewportIsp, "mobilenet_v2", 25).unwrap();
        // 25 images at ~3.08 img/s ≈ 8.1s (the §V-A quoted step time).
        assert!((t25.as_secs_f64() - 8.1).abs() < 0.3, "{t25}");
    }

    #[test]
    fn scaled_model_names_resolve() {
        let m = PerfModel::default();
        assert!(m.ips(Device::HostXeon, "mobilenet_v2_s", 32).is_ok());
        assert!(m.ips(Device::HostXeon, "nonexistent_net", 32).is_err());
        assert!(m.ips(Device::HostXeon, "mobilenet_v2", 0).is_err());
    }

    #[test]
    fn sync_bytes_paper_scale() {
        let m = PerfModel::default();
        assert_eq!(m.sync_bytes("mobilenet_v2").unwrap(), 13_880_000);
    }

    #[test]
    fn interned_ids_agree_with_string_shims() {
        let m = PerfModel::with_scales(1.0, 0.7);
        for name in ["mobilenet_v2_s", "nasnet", "inception_v3", "squeezenet_s"] {
            let id = NetId::resolve(name).unwrap();
            for bs in [1usize, 16, 64] {
                assert_eq!(
                    m.ips(Device::NewportIsp, name, bs).unwrap(),
                    m.ips_id(Device::NewportIsp, id, bs).unwrap()
                );
                assert_eq!(
                    m.step_time(Device::HostXeon, name, bs).unwrap(),
                    m.step_time_id(Device::HostXeon, id, bs).unwrap()
                );
            }
            assert_eq!(m.sync_bytes(name).unwrap(), id.sync_bytes());
            assert_eq!(calib_for(name).unwrap().name, id.name());
        }
        assert!(NetId::resolve("nonexistent_net").is_err());
        assert_eq!(NetId::all().count(), CALIBRATION.len());
    }

    #[test]
    fn memo_is_coherent_under_scale_mutation() {
        let mut m = PerfModel::default();
        let id = NetId::resolve("mobilenet_v2").unwrap();
        let t1 = m.step_time_cached(Device::HostXeon, id, 32).unwrap();
        assert_eq!(t1, m.step_time_cached(Device::HostXeon, id, 32).unwrap());
        // Mutating a pub scale field must not serve the stale entry.
        m.host_scale = 0.5;
        let t2 = m.step_time_cached(Device::HostXeon, id, 32).unwrap();
        assert!(t2 > t1, "half-speed host must take longer: {t1} -> {t2}");
        assert_eq!(t2, m.step_time_id(Device::HostXeon, id, 32).unwrap());
    }
}
