//! The fleet's shared device pool: every Newport CSD in the chassis,
//! with per-device health and job assignment (DESIGN.md §5).
//!
//! Health is a multiplicative throughput scale (1.0 = calibrated
//! speed); a thermal throttle or flash wear event degrades it via
//! [`DevicePool::degrade`], which is the same fault axis
//! `PerfModel::newport_scale` models for a whole cluster — here it is
//! tracked per device so one sick drive only slows its own job.

use anyhow::{ensure, Result};

use crate::csd::{CsdConfig, NewportCsd};
use crate::sim::SimTime;

use super::job::JobId;

/// Floor on degraded health: a device never models as fully dead here
/// (worker dropout is a different fault path, see `integration_faults`).
const MIN_HEALTH: f64 = 0.01;

/// One bay of the pool.
pub struct FleetDevice {
    pub csd: NewportCsd,
    /// Relative throughput (1.0 = calibrated Newport speed).
    pub health: f64,
    /// The job currently holding this device, if any.
    pub assigned: Option<JobId>,
    preloaded: bool,
}

/// All CSDs of the chassis, carved into per-job groups.
pub struct DevicePool {
    devices: Vec<FleetDevice>,
}

impl DevicePool {
    pub fn new(total: usize, cfg: &CsdConfig) -> Self {
        let devices = (0..total)
            .map(|i| FleetDevice {
                csd: NewportCsd::new(i, cfg.clone(), 0xF1EE7 + i as u64),
                health: 1.0,
                assigned: None,
                preloaded: false,
            })
            .collect();
        Self { devices }
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn free_count(&self) -> usize {
        self.devices.iter().filter(|d| d.assigned.is_none()).count()
    }

    /// Carve `n` free devices for `job`, healthiest first (ties break
    /// to the lowest index, so an all-healthy pool carves exactly the
    /// lowest indices and admission stays deterministic). A repaired
    /// bay therefore goes back to the front of the line for the next
    /// admission. Returns `None` — without mutating anything — if fewer
    /// than `n` are free. The returned indices are sorted ascending
    /// (group identity is a set; ring order comes from the indices).
    pub fn carve(&mut self, n: usize, job: JobId) -> Option<Vec<usize>> {
        let mut free: Vec<usize> = self
            .devices
            .iter()
            .enumerate()
            .filter(|(_, d)| d.assigned.is_none())
            .map(|(i, _)| i)
            .collect();
        if free.len() < n {
            return None;
        }
        // Health is finite and positive (degrade/repair enforce it), so
        // the bit ordering of the comparison is total.
        free.sort_by(|&a, &b| {
            self.devices[b]
                .health
                .partial_cmp(&self.devices[a].health)
                .expect("health is finite")
                .then(a.cmp(&b))
        });
        free.truncate(n);
        free.sort_unstable();
        for &i in &free {
            self.devices[i].assigned = Some(job);
        }
        Some(free)
    }

    /// Release every device held by `job`.
    pub fn release(&mut self, job: JobId) {
        for d in &mut self.devices {
            if d.assigned == Some(job) {
                d.assigned = None;
            }
        }
    }

    pub fn health(&self, device: usize) -> f64 {
        self.devices[device].health
    }

    /// Multiply a device's health by `factor`. `factor < 1` is a fault
    /// (thermal throttle, wear); `factor > 1` is a *repair* (throttle
    /// lifted, module swapped) — health is clamped to 1.0, a bay never
    /// models faster than its calibrated Newport speed. Returns the new
    /// health.
    pub fn degrade(&mut self, device: usize, factor: f64) -> Result<f64> {
        ensure!(device < self.devices.len(), "no device {device} in the pool");
        ensure!(factor > 0.0 && factor.is_finite(), "bad degradation factor {factor}");
        let d = &mut self.devices[device];
        d.health = (d.health * factor).clamp(MIN_HEALTH, 1.0);
        Ok(d.health)
    }

    pub fn assigned_job(&self, device: usize) -> Option<JobId> {
        self.devices.get(device).and_then(|d| d.assigned)
    }

    /// The slowest health in a group — the scale the whole group's
    /// synchronous step is gated by.
    pub fn group_health(&self, devices: &[usize]) -> f64 {
        devices
            .iter()
            .map(|&d| self.devices[d].health)
            .fold(1.0, f64::min)
    }

    pub fn device(&self, device: usize) -> &NewportCsd {
        &self.devices[device].csd
    }

    pub fn device_mut(&mut self, device: usize) -> &mut NewportCsd {
        &mut self.devices[device].csd
    }

    /// Stage `pages` logical pages on a device once, so training reads
    /// hit mapped flash (mirrors `Scheduler::preload_data`).
    pub fn preload(&mut self, device: usize, pages: u32, now: SimTime) -> Result<()> {
        let d = &mut self.devices[device];
        if d.preloaded {
            return Ok(());
        }
        for lpn in 0..pages {
            d.csd.write_page(lpn, lpn as u64, now)?;
        }
        d.preloaded = true;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn carve_is_deterministic_and_atomic() {
        let mut p = DevicePool::new(4, &CsdConfig::default());
        let a = p.carve(3, JobId(0)).unwrap();
        assert_eq!(a, vec![0, 1, 2]);
        assert_eq!(p.free_count(), 1);
        // Not enough left: must fail without grabbing the last device.
        assert!(p.carve(2, JobId(1)).is_none());
        assert_eq!(p.free_count(), 1);
        let b = p.carve(1, JobId(1)).unwrap();
        assert_eq!(b, vec![3]);
        p.release(JobId(0));
        assert_eq!(p.free_count(), 3);
        assert_eq!(p.assigned_job(3), Some(JobId(1)));
        assert_eq!(p.assigned_job(0), None);
    }

    #[test]
    fn degrade_compounds_and_floors() {
        let mut p = DevicePool::new(2, &CsdConfig::default());
        p.degrade(0, 0.5).unwrap();
        p.degrade(0, 0.5).unwrap();
        assert!((p.health(0) - 0.25).abs() < 1e-12);
        assert_eq!(p.health(1), 1.0);
        p.degrade(0, 1e-9).unwrap();
        assert!(p.health(0) >= MIN_HEALTH);
        assert!(p.degrade(5, 0.5).is_err());
        assert!(p.degrade(1, 0.0).is_err());
    }

    #[test]
    fn repair_restores_health_clamped_at_one() {
        let mut p = DevicePool::new(2, &CsdConfig::default());
        p.degrade(0, 0.5).unwrap();
        // Partial repair compounds multiplicatively, like faults.
        assert!((p.degrade(0, 1.5).unwrap() - 0.75).abs() < 1e-12);
        // Over-repair clamps at calibrated speed.
        assert_eq!(p.degrade(0, 10.0).unwrap(), 1.0);
        // Repairing a healthy bay is a no-op at the clamp.
        assert_eq!(p.degrade(1, 2.0).unwrap(), 1.0);
    }

    #[test]
    fn carve_prefers_healthiest_devices() {
        let mut p = DevicePool::new(4, &CsdConfig::default());
        p.degrade(0, 0.5).unwrap();
        p.degrade(2, 0.8).unwrap();
        // Healthiest-first: 1 and 3 (1.0) beat 2 (0.8) beats 0 (0.5);
        // the result is reported in ascending index order.
        assert_eq!(p.carve(3, JobId(0)).unwrap(), vec![1, 2, 3]);
        assert_eq!(p.carve(1, JobId(1)).unwrap(), vec![0]);
        p.release(JobId(0));
        // A repaired bay jumps back ahead of a degraded one.
        p.degrade(3, 0.7).unwrap();
        p.degrade(2, 2.0).unwrap(); // 0.8 -> 1.0 (clamped repair)
        assert_eq!(p.carve(2, JobId(2)).unwrap(), vec![1, 2]);
    }

    #[test]
    fn group_health_is_min() {
        let mut p = DevicePool::new(3, &CsdConfig::default());
        p.degrade(1, 0.6).unwrap();
        assert!((p.group_health(&[0, 1, 2]) - 0.6).abs() < 1e-12);
        assert_eq!(p.group_health(&[0, 2]), 1.0);
        assert_eq!(p.group_health(&[]), 1.0);
    }
}
