//! The fleet's shared device pool: every Newport CSD in the chassis,
//! with per-device health and job assignment (DESIGN.md §5).
//!
//! Health is a multiplicative throughput scale (1.0 = calibrated
//! speed); a thermal throttle or flash wear event degrades it via
//! [`DevicePool::degrade`], which is the same fault axis
//! `PerfModel::newport_scale` models for a whole cluster — here it is
//! tracked per device so one sick drive only slows its own job.

use anyhow::{ensure, Context, Result};

use crate::analysis::audit::{Auditable, Fnv64};
use crate::csd::{CsdConfig, EccStats, NewportCsd, WearReport};
use crate::sim::SimTime;

use super::job::JobId;

/// Floor on degraded health: a device never models as fully dead here
/// (worker dropout is a different fault path, see `integration_faults`).
const MIN_HEALTH: f64 = 0.01;

/// One bay of the pool.
pub struct FleetDevice {
    pub csd: NewportCsd,
    /// Relative throughput (1.0 = calibrated Newport speed).
    pub health: f64,
    /// The job currently holding this device, if any.
    pub assigned: Option<JobId>,
    /// How many times this bay's module has been swapped for a fresh
    /// one (device end-of-life replacements; seeds each incarnation).
    pub generation: u32,
    preloaded: bool,
}

/// All CSDs of the chassis, carved into per-job groups.
pub struct DevicePool {
    devices: Vec<FleetDevice>,
}

impl DevicePool {
    pub fn new(total: usize, cfg: &CsdConfig) -> Self {
        let devices = (0..total)
            .map(|i| FleetDevice {
                csd: NewportCsd::new(i, cfg.clone(), 0xF1EE7 + i as u64),
                health: 1.0,
                assigned: None,
                generation: 0,
                preloaded: false,
            })
            .collect();
        Self { devices }
    }

    /// Swap a worn-out bay for a factory-fresh module (the rolling
    /// replacement of the endurance pipeline): new deterministic seed
    /// per incarnation, full health, nothing preloaded. The bay must be
    /// idle — the runtime drains its job first. Returns the retired
    /// module's wear and decoder counters so fleet ledgers stay
    /// conserved across the swap.
    pub fn replace(&mut self, device: usize, cfg: &CsdConfig) -> Result<(WearReport, EccStats)> {
        ensure!(device < self.devices.len(), "no device {device} in the pool");
        if let Some(job) = self.devices[device].assigned {
            anyhow::bail!("cannot replace device {device}: {job} still holds it");
        }
        let generation = self.devices[device].generation + 1;
        // Distinct from every first-incarnation seed (0xF1EE7 + i) and
        // from every other (bay, generation) pair.
        let seed =
            0xF1EE7 + device as u64 + 0x9E37_79B9u64.wrapping_mul(generation as u64);
        let old = std::mem::replace(
            &mut self.devices[device],
            FleetDevice {
                csd: NewportCsd::new(device, cfg.clone(), seed),
                health: 1.0,
                assigned: None,
                generation,
                preloaded: false,
            },
        );
        Ok((old.csd.ftl_ref().wear(), old.csd.ftl_ref().ecc_stats()))
    }

    /// Bays whose FTL reports end-of-life (ascending index) — the
    /// runtime's cue to drain and replace.
    pub fn worn_devices(&self) -> Vec<usize> {
        self.devices
            .iter()
            .enumerate()
            .filter(|(_, d)| d.csd.ftl_ref().worn_out())
            .map(|(i, _)| i)
            .collect()
    }

    /// Aggregate wear + decoder counters across the *live* devices
    /// (history of replaced modules is accumulated by the runtime at
    /// swap time, from [`DevicePool::replace`]'s return value).
    pub fn wear_totals(&self) -> (WearReport, EccStats) {
        let mut w = WearReport::default();
        let mut e = EccStats::default();
        for d in &self.devices {
            w.merge(d.csd.ftl_ref().wear());
            e.merge(d.csd.ftl_ref().ecc_stats());
        }
        (w, e)
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn free_count(&self) -> usize {
        self.devices.iter().filter(|d| d.assigned.is_none()).count()
    }

    /// Carve `n` free devices for `job`, healthiest first; at equal
    /// health the least-worn bay (fewest retired blocks) wins, and ties
    /// still break to the lowest index — so an all-fresh pool carves
    /// exactly the lowest indices and admission stays bit-identical to
    /// the pre-endurance behavior. A repaired bay therefore goes back
    /// to the front of the line for the next admission. Returns `None`
    /// — without mutating anything — if fewer than `n` are free. The
    /// returned indices are sorted ascending (group identity is a set;
    /// ring order comes from the indices).
    pub fn carve(&mut self, n: usize, job: JobId) -> Option<Vec<usize>> {
        let mut free: Vec<usize> = self
            .devices
            .iter()
            .enumerate()
            .filter(|(_, d)| d.assigned.is_none())
            .map(|(i, _)| i)
            .collect();
        if free.len() < n {
            return None;
        }
        // Health is finite and positive (degrade/repair enforce it), so
        // the bit ordering of the comparison is total. Retired-block
        // counts stay zero with endurance off, keeping the legacy order.
        free.sort_by(|&a, &b| {
            self.devices[b]
                .health
                .partial_cmp(&self.devices[a].health)
                .expect("health is finite")
                .then_with(|| {
                    let wa = self.devices[a].csd.ftl_ref().retired_block_count();
                    let wb = self.devices[b].csd.ftl_ref().retired_block_count();
                    wa.cmp(&wb)
                })
                .then(a.cmp(&b))
        });
        free.truncate(n);
        free.sort_unstable();
        for &i in &free {
            self.devices[i].assigned = Some(job);
        }
        Some(free)
    }

    /// Release every device held by `job`.
    pub fn release(&mut self, job: JobId) {
        for d in &mut self.devices {
            if d.assigned == Some(job) {
                d.assigned = None;
            }
        }
    }

    pub fn health(&self, device: usize) -> f64 {
        self.devices[device].health
    }

    /// How many times this bay's module has been swapped at end-of-life
    /// (0 = the original module).
    pub fn generation(&self, device: usize) -> u32 {
        self.devices[device].generation
    }

    /// Multiply a device's health by `factor`. `factor < 1` is a fault
    /// (thermal throttle, wear); `factor > 1` is a *repair* (throttle
    /// lifted, module swapped) — health is clamped to 1.0, a bay never
    /// models faster than its calibrated Newport speed. Returns the new
    /// health.
    pub fn degrade(&mut self, device: usize, factor: f64) -> Result<f64> {
        ensure!(device < self.devices.len(), "no device {device} in the pool");
        ensure!(factor > 0.0 && factor.is_finite(), "bad degradation factor {factor}");
        let d = &mut self.devices[device];
        d.health = (d.health * factor).clamp(MIN_HEALTH, 1.0);
        Ok(d.health)
    }

    pub fn assigned_job(&self, device: usize) -> Option<JobId> {
        self.devices.get(device).and_then(|d| d.assigned)
    }

    /// The slowest health in a group — the scale the whole group's
    /// synchronous step is gated by.
    pub fn group_health(&self, devices: &[usize]) -> f64 {
        devices
            .iter()
            .map(|&d| self.devices[d].health)
            .fold(1.0, f64::min)
    }

    pub fn device(&self, device: usize) -> &NewportCsd {
        &self.devices[device].csd
    }

    pub fn device_mut(&mut self, device: usize) -> &mut NewportCsd {
        &mut self.devices[device].csd
    }

    /// Stage `pages` logical pages on a device once, so training reads
    /// hit mapped flash (mirrors `Scheduler::preload_data`).
    pub fn preload(&mut self, device: usize, pages: u32, now: SimTime) -> Result<()> {
        let d = &mut self.devices[device];
        if d.preloaded {
            return Ok(());
        }
        for lpn in 0..pages {
            d.csd.write_page(lpn, lpn as u64, now)?;
        }
        d.preloaded = true;
        Ok(())
    }

    /// Verify every bay: health inside the modeled band, and each
    /// module's FTL internally coherent (the audit path).
    pub fn check_invariants(&self) -> Result<()> {
        for (i, d) in self.devices.iter().enumerate() {
            ensure!(
                d.health.is_finite() && (MIN_HEALTH..=1.0).contains(&d.health),
                "device {i}: health {} outside [{MIN_HEALTH}, 1.0]",
                d.health
            );
            d.csd
                .ftl_ref()
                .check_invariants()
                .with_context(|| format!("device {i} (generation {}) ftl", d.generation))?;
        }
        Ok(())
    }
}

impl Auditable for DevicePool {
    fn component(&self) -> &'static str {
        "device-pool"
    }

    fn audit(&self) -> crate::Result<()> {
        self.check_invariants()
    }

    fn fingerprint(&self, h: &mut Fnv64) {
        h.write_usize(self.devices.len());
        for (i, d) in self.devices.iter().enumerate() {
            h.write_usize(i);
            h.write_u32(d.generation);
            h.write_f64_bits(d.health);
            h.write_bool(d.preloaded);
            match d.assigned {
                None => h.write_u64(0),
                Some(j) => h.write_u64(j.0.wrapping_add(1)),
            }
            d.csd.ftl_ref().fingerprint(h);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn carve_is_deterministic_and_atomic() {
        let mut p = DevicePool::new(4, &CsdConfig::default());
        let a = p.carve(3, JobId(0)).unwrap();
        assert_eq!(a, vec![0, 1, 2]);
        assert_eq!(p.free_count(), 1);
        // Not enough left: must fail without grabbing the last device.
        assert!(p.carve(2, JobId(1)).is_none());
        assert_eq!(p.free_count(), 1);
        let b = p.carve(1, JobId(1)).unwrap();
        assert_eq!(b, vec![3]);
        p.release(JobId(0));
        assert_eq!(p.free_count(), 3);
        assert_eq!(p.assigned_job(3), Some(JobId(1)));
        assert_eq!(p.assigned_job(0), None);
    }

    #[test]
    fn degrade_compounds_and_floors() {
        let mut p = DevicePool::new(2, &CsdConfig::default());
        p.degrade(0, 0.5).unwrap();
        p.degrade(0, 0.5).unwrap();
        assert!((p.health(0) - 0.25).abs() < 1e-12);
        assert_eq!(p.health(1), 1.0);
        p.degrade(0, 1e-9).unwrap();
        assert!(p.health(0) >= MIN_HEALTH);
        assert!(p.degrade(5, 0.5).is_err());
        assert!(p.degrade(1, 0.0).is_err());
    }

    #[test]
    fn repair_restores_health_clamped_at_one() {
        let mut p = DevicePool::new(2, &CsdConfig::default());
        p.degrade(0, 0.5).unwrap();
        // Partial repair compounds multiplicatively, like faults.
        assert!((p.degrade(0, 1.5).unwrap() - 0.75).abs() < 1e-12);
        // Over-repair clamps at calibrated speed.
        assert_eq!(p.degrade(0, 10.0).unwrap(), 1.0);
        // Repairing a healthy bay is a no-op at the clamp.
        assert_eq!(p.degrade(1, 2.0).unwrap(), 1.0);
    }

    #[test]
    fn carve_prefers_healthiest_devices() {
        let mut p = DevicePool::new(4, &CsdConfig::default());
        p.degrade(0, 0.5).unwrap();
        p.degrade(2, 0.8).unwrap();
        // Healthiest-first: 1 and 3 (1.0) beat 2 (0.8) beats 0 (0.5);
        // the result is reported in ascending index order.
        assert_eq!(p.carve(3, JobId(0)).unwrap(), vec![1, 2, 3]);
        assert_eq!(p.carve(1, JobId(1)).unwrap(), vec![0]);
        p.release(JobId(0));
        // A repaired bay jumps back ahead of a degraded one.
        p.degrade(3, 0.7).unwrap();
        p.degrade(2, 2.0).unwrap(); // 0.8 -> 1.0 (clamped repair)
        assert_eq!(p.carve(2, JobId(2)).unwrap(), vec![1, 2]);
    }

    /// Tiny geometry with a one-cycle P/E limit so a few overwrite
    /// rounds retire blocks (fast wear for the placement tests).
    fn endurance_cfg() -> CsdConfig {
        use crate::csd::flash::FlashConfig;
        use crate::csd::ftl::FtlConfig;
        CsdConfig {
            ftl: FtlConfig {
                flash: FlashConfig {
                    channels: 1,
                    dies_per_channel: 1,
                    blocks_per_die: 8,
                    pages_per_block: 8,
                    page_bytes: 4096,
                    ..Default::default()
                },
                overprovision: 0.5,
                gc_low_water: 2,
                gc_high_water: 3,
                pe_limit: 1,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    /// Overwrite a bay's first pages until GC retires at least one
    /// block (or the device goes fully worn-out, which implies it).
    fn wear_bay(p: &mut DevicePool, device: usize) {
        'rounds: for _ in 0..1000 {
            for lpn in 0..8u32 {
                if p.device_mut(device).write_page(lpn, lpn as u64, SimTime::ZERO).is_err() {
                    break 'rounds;
                }
            }
            if p.device(device).ftl_ref().retired_block_count() > 0 {
                break;
            }
        }
        assert!(p.device(device).ftl_ref().retired_block_count() > 0, "bay {device} never retired a block");
    }

    #[test]
    fn carve_breaks_health_ties_toward_least_worn() {
        let mut p = DevicePool::new(3, &endurance_cfg());
        wear_bay(&mut p, 0);
        // Equal health everywhere: the worn bay loses the tie-break.
        assert_eq!(p.carve(2, JobId(0)).unwrap(), vec![1, 2]);
        p.release(JobId(0));
        // Health still dominates wear: a degraded fresh bay ranks below
        // a worn healthy one.
        p.degrade(1, 0.5).unwrap();
        assert_eq!(p.carve(2, JobId(1)).unwrap(), vec![0, 2]);
    }

    #[test]
    fn replace_swaps_in_a_fresh_module_and_returns_its_history() {
        let mut p = DevicePool::new(2, &endurance_cfg());
        wear_bay(&mut p, 0);
        p.degrade(0, 0.3).unwrap();
        let carved = p.carve(1, JobId(3)).unwrap();
        assert_eq!(carved, vec![1], "healthiest bay first");
        // An assigned bay cannot be swapped out from under its job.
        assert!(p.replace(1, &endurance_cfg()).is_err());
        assert!(p.replace(9, &endurance_cfg()).is_err());
        let (wear, ecc) = p.replace(0, &endurance_cfg()).unwrap();
        assert!(wear.retired_blocks > 0, "history must carry the old module's wear");
        assert!(ecc.pages > 0);
        // Fresh module: full health, no wear, next generation seed.
        assert_eq!(p.health(0), 1.0);
        assert_eq!(p.device(0).ftl_ref().retired_block_count(), 0);
        assert_eq!(p.devices[0].generation, 1);
        assert!(!p.devices[0].preloaded);
        let (live, _) = p.wear_totals();
        assert_eq!(live.retired_blocks, 0, "live totals reset; history returned to caller");
    }

    #[test]
    fn audit_and_fingerprint_track_pool_state() {
        use crate::analysis::audit::fingerprint_of;
        let mut p = DevicePool::new(3, &CsdConfig::default());
        // DevicePool::check_invariants holds on a fresh pool and after
        // every mutation below; the fingerprint moves with the state.
        p.check_invariants().unwrap();
        let fresh = fingerprint_of(&p);
        assert_eq!(fresh, fingerprint_of(&p), "fingerprint is a pure function");
        p.degrade(1, 0.5).unwrap();
        p.check_invariants().unwrap();
        let degraded = fingerprint_of(&p);
        assert_ne!(fresh, degraded, "health change must move the fingerprint");
        p.carve(1, JobId(7)).unwrap();
        p.check_invariants().unwrap();
        assert_ne!(degraded, fingerprint_of(&p), "assignment must move the fingerprint");
        p.preload(0, 4, SimTime::ZERO).unwrap();
        p.check_invariants().unwrap();
    }

    #[test]
    fn group_health_and_carve_order_recover_after_a_mid_trace_swap() {
        let mut p = DevicePool::new(3, &endurance_cfg());
        wear_bay(&mut p, 0);
        p.degrade(0, 0.4).unwrap();
        // The sick bay still carves when the group needs every device,
        // and it gates the group's synchronous step.
        let g = p.carve(3, JobId(0)).unwrap();
        assert_eq!(g, vec![0, 1, 2]);
        assert!((p.group_health(&g) - 0.4).abs() < 1e-12);
        p.release(JobId(0));
        // Mid-trace swap (crash or end-of-life): the fresh module wipes
        // both the health penalty and the wear tie-break penalty, so
        // the bay goes back to the front of the carve order.
        p.replace(0, &endurance_cfg()).unwrap();
        assert_eq!(p.group_health(&g), 1.0, "a fresh module restores the group gate");
        assert_eq!(
            p.carve(2, JobId(1)).unwrap(),
            vec![0, 1],
            "all-fresh ties must break to the lowest index again"
        );
    }

    #[test]
    fn wear_ledger_is_conserved_across_double_replacement() {
        let mut p = DevicePool::new(2, &endurance_cfg());
        // Two full wear-and-swap cycles on the same bay: each retired
        // module's history leaves with the replace() return value, and
        // nothing is double-counted or lost.
        wear_bay(&mut p, 0);
        let (before_first, _) = p.wear_totals();
        let (w1, e1) = p.replace(0, &endurance_cfg()).unwrap();
        wear_bay(&mut p, 0);
        let (before_second, _) = p.wear_totals();
        let (w2, e2) = p.replace(0, &endurance_cfg()).unwrap();
        assert_eq!(p.generation(0), 2);
        assert_eq!(p.generation(1), 0, "the untouched bay keeps its module");
        // Conservation per swap: what the pool reported live just
        // before the swap is exactly what the swap handed back (bay 1
        // is never written, so the live totals are bay 0's).
        assert_eq!(w1.retired_blocks, before_first.retired_blocks);
        assert_eq!(w1.erases, before_first.erases);
        assert_eq!(w2.retired_blocks, before_second.retired_blocks);
        assert_eq!(w2.erases, before_second.erases);
        // Both incarnations really wore out independently (the second
        // module starts fresh and re-earns its retirement).
        assert!(w1.retired_blocks > 0 && w2.retired_blocks > 0);
        assert!(e1.pages > 0 && e2.pages > 0);
        let (live, live_ecc) = p.wear_totals();
        assert_eq!(live.retired_blocks, 0, "history leaves with the caller, twice");
        assert_eq!(live.erases, 0);
        assert_eq!(live_ecc.pages, 0);
    }

    #[test]
    fn group_health_is_min() {
        let mut p = DevicePool::new(3, &CsdConfig::default());
        p.degrade(1, 0.6).unwrap();
        assert!((p.group_health(&[0, 1, 2]) - 0.6).abs() < 1e-12);
        assert_eq!(p.group_health(&[0, 2]), 1.0);
        assert_eq!(p.group_health(&[]), 1.0);
    }
}
