//! Million-arrival trace driving and multi-seed sweeps
//! (DESIGN.md §Runtime, "Sweep harness").
//!
//! [`FleetRuntime::load_workload`] materializes a whole trace up
//! front — every arrival submitted, every external event resident —
//! which is fine for hundreds of jobs and hopeless for millions.
//! [`run_trace_with`] is the streaming alternative: it walks
//! [`WorkloadSpec::arrival_iter`] in chunks, keeps only a few thousand
//! not-yet-due externals inside the runtime, and drains
//! [`FleetRuntime::take_log`] between chunks, so a million-arrival
//! Poisson trace runs in O(live jobs + chunk) memory end to end.
//!
//! [`run_sweep`] shards *independent* seeded traces over plain
//! `std::thread` workers (zero new dependencies). Each trace is
//! single-threaded and deterministic in its seed; shards are assigned
//! round-robin by seed index and folded back in seed order, so the
//! merged [`SweepReport`] is bit-identical at any worker count — the
//! property the sweep determinism test pins down.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::config::WorkloadSpec;
use crate::metrics::RunningStat;
use crate::sim::SimTime;

use super::coordinator::{FleetConfig, FleetRuntime, LogEntry};

/// Arrivals submitted per driver chunk. Bounds how many pending
/// externals the runtime holds at once; large enough that chunk
/// bookkeeping is noise against step simulation.
const CHUNK: usize = 4096;

/// Build the runtime a [`WorkloadSpec`] asks for: the spec's pool
/// size, staging/data-plane/executor toggles, retention mode and
/// endurance knobs over otherwise-default fleet knobs. Single mapping
/// shared by the CLI, the benches and the trace drivers.
pub fn runtime_for(spec: &WorkloadSpec) -> FleetRuntime {
    let mut cfg = FleetConfig {
        total_csds: spec.total_csds,
        stage_io: spec.stage_io,
        data_plane: spec.data_plane,
        fast_forward: spec.fast_forward,
        retain_jobs: spec.retain_jobs,
        audit: spec.audit,
        ..FleetConfig::default()
    };
    cfg.csd.ftl.pe_limit = spec.endurance.pe_limit;
    cfg.csd.ftl.read_retries = spec.endurance.read_retries;
    cfg.csd.ftl.retry_step = SimTime::from_secs_f64(spec.endurance.retry_step_us * 1e-6);
    cfg.checkpoint = spec.checkpoint;
    cfg.link_fault = spec.link_fault;
    cfg.ledger_path = spec.ledger.clone();
    FleetRuntime::new(cfg)
}

/// Per-trace summary: the fleet totals that survive a streaming run
/// (no per-job list — that streamed out as retired records).
///
/// `PartialEq` is exact — f64 fields compare bitwise-equal values —
/// because the sweep determinism property asserts summaries are
/// *identical* across worker counts, not merely close.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSummary {
    /// Seed the trace was drawn from.
    pub seed: u64,
    /// Arrivals the spec submitted.
    pub jobs: usize,
    /// Jobs that ran to natural completion.
    pub completed: usize,
    /// Jobs torn down by the cancel schedule.
    pub cancelled: usize,
    pub total_images: usize,
    pub makespan: SimTime,
    pub aggregate_ips: f64,
    pub jobs_energy_j: f64,
    pub total_energy_j: f64,
    /// Queue-wait statistics across the trace's jobs (seconds).
    pub queue_wait: RunningStat,
    /// Shard-map DLM wait statistics across the trace's jobs (seconds).
    pub lock_wait: RunningStat,
    /// High-water mark of concurrently running jobs — the bound the
    /// streaming job table's slot count stays under.
    pub peak_live_jobs: usize,
    /// Slots the job table actually grew (streaming: ≤ concurrency
    /// high-water; retained oracle: every job ever materialized).
    pub job_slots: usize,
    /// Structural log entries the run streamed.
    pub log_events: usize,
    /// Jobs drained off worn-out devices (each resubmitted a successor
    /// that is counted on top of `jobs`). Zero with endurance off.
    pub drained: usize,
    /// Jobs killed by bay crashes (each resumed from its checkpoint as
    /// a successor). Zero with no crash schedule and no link faults.
    pub crashed: usize,
    /// Completed-but-uncheckpointed steps lost to crashes.
    pub lost_steps: usize,
    /// Bytes written by checkpoint windows (flash + host copies).
    pub checkpoint_bytes: u64,
    /// Tunnel hops re-attempted by the link-fault retry ladder.
    pub link_retries: u64,
    /// Device modules swapped at end-of-life across the trace.
    pub devices_replaced: usize,
    /// Fleet-wide write amplification at trace end (live devices plus
    /// replaced-module history; 0 when nothing was written).
    pub waf: f64,
    /// [`FleetRuntime::fingerprint`] of the drained session — the
    /// one-u64 identity of the trace's end state. Part of the summary
    /// so the sweep worker-count invariance property pins state
    /// identity, not just the reported totals.
    pub fingerprint: u64,
}

/// Drive one seeded trace in chunks, handing every structural
/// [`LogEntry`] to `on_log` as it streams out. Returns the summary
/// plus the drained runtime (for callers that want post-run state —
/// the pool, the data plane, a final `report()`).
///
/// Semantics match [`FleetRuntime::load_workload`] + run-to-idle: the
/// same arrivals (identical RNG draw order via `arrival_iter`), the
/// same cancel and fault schedules, the same event outcomes. The only
/// caveat is exact event-*time* ties between externals scheduled in
/// different chunks and already-pending internal events, which can pop
/// in a different order than the all-upfront replay; the seeded traces
/// draw continuous times, where such ties do not occur.
pub fn run_trace_with(
    spec: &WorkloadSpec,
    mut on_log: impl FnMut(&LogEntry),
) -> Result<(TraceSummary, FleetRuntime)> {
    spec.validate()?;
    let mut rt = runtime_for(spec);
    let mut log_events = 0usize;

    // Health events are operator-scheduled and few: schedule up front.
    for f in &spec.faults {
        rt.inject_degradation(SimTime::from_secs_f64(f.at_secs), f.device, f.factor);
    }
    // Crash faults likewise (DESIGN.md §Crash-Recovery).
    for c in &spec.crashes {
        rt.inject_crash(SimTime::from_secs_f64(c.at_secs), c.device);
    }
    // Cancels keyed by submission index, scheduled the moment their job
    // is submitted. `validate` pinned every index below `spec.jobs`.
    let mut cancels: BTreeMap<usize, Vec<f64>> = BTreeMap::new();
    for c in &spec.cancels {
        cancels.entry(c.job).or_default().push(c.at_secs);
    }

    let mut arrivals = spec.arrival_iter();
    let mut next = arrivals.next();
    let mut next_i = 0usize; // submission index of `next`
    while next.is_some() {
        for _ in 0..CHUNK {
            let Some((at_secs, job)) = next.take() else { break };
            let id = rt.submit_at(SimTime::from_secs_f64(at_secs), job)?;
            if let Some(times) = cancels.get(&next_i) {
                for &c in times {
                    rt.cancel(id, SimTime::from_secs_f64(c))?;
                }
            }
            next_i += 1;
            next = arrivals.next();
        }
        // Drain up to the earliest instant a not-yet-submitted external
        // could land: the next arrival, or the earliest cancel aimed at
        // an unsubmitted index (cancel times are not monotone in
        // submission index). The inclusive horizon is safe — `submit_at`
        // and `cancel` both accept `at == now`. No horizon left means
        // every external is in; drain to idle.
        let mut horizon = next.as_ref().map(|(t, _)| *t);
        for times in cancels.range(next_i..).map(|(_, v)| v) {
            for &t in times {
                horizon = Some(horizon.map_or(t, |h: f64| h.min(t)));
            }
        }
        match horizon {
            Some(h) => rt.run_until(SimTime::from_secs_f64(h))?,
            None => rt.run_until_idle()?,
        }
        for e in rt.take_log() {
            log_events += 1;
            on_log(&e);
        }
    }

    // The trace is drained: seal the ledger (no-op with none armed) so
    // the directory is a complete, queryable set of segments.
    rt.seal_ledger()?;

    let r = rt.report();
    // Endurance drains resubmit successors, so retirements can exceed
    // the spec's arrival count — never fall short of it.
    debug_assert!(r.retired >= spec.jobs, "trace drained with unretired jobs");
    let summary = TraceSummary {
        seed: spec.seed,
        jobs: spec.jobs,
        completed: r.retired - r.cancelled,
        cancelled: r.cancelled,
        total_images: r.total_images,
        makespan: r.makespan,
        aggregate_ips: r.aggregate_ips,
        jobs_energy_j: r.jobs_energy_j,
        total_energy_j: r.total_energy_j,
        queue_wait: r.queue_wait,
        lock_wait: r.lock_wait,
        peak_live_jobs: r.peak_live_jobs,
        job_slots: rt.job_slots(),
        log_events,
        drained: r.drained,
        crashed: r.crashed,
        lost_steps: r.lost_steps,
        checkpoint_bytes: r.checkpoint_bytes,
        link_retries: r.link_retries,
        devices_replaced: r.devices_replaced,
        waf: r.wear.waf,
        fingerprint: rt.fingerprint(),
    };
    Ok((summary, rt))
}

/// [`run_trace_with`] with the log discarded — the sweep workers'
/// inner loop.
pub fn run_trace(spec: &WorkloadSpec) -> Result<TraceSummary> {
    run_trace_with(spec, |_| {}).map(|(summary, _)| summary)
}

/// Merged result of a multi-seed sweep: per-trace summaries in seed
/// order plus cross-trace aggregates folded with
/// [`RunningStat::merge`]. `PartialEq` is exact, like
/// [`TraceSummary`]'s — the worker-count invariance property compares
/// whole reports.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepReport {
    /// One summary per requested seed, in the seeds' given order
    /// regardless of which worker ran which trace.
    pub traces: Vec<TraceSummary>,
    /// Per-job queue waits merged across every trace (seconds).
    pub queue_wait: RunningStat,
    /// Per-job DLM lock waits merged across every trace (seconds).
    pub lock_wait: RunningStat,
    /// Per-trace completed-jobs-per-hour samples.
    pub jobs_per_hour: RunningStat,
    /// Per-trace aggregate throughput samples (img/s).
    pub aggregate_ips: RunningStat,
    pub total_images: usize,
    pub total_jobs: usize,
    pub cancelled: usize,
    /// Jobs drained off worn-out devices, summed across traces.
    pub drained: usize,
    /// Jobs killed by bay crashes, summed across traces.
    pub crashed: usize,
    /// Steps lost to crashes, summed across traces.
    pub lost_steps: usize,
    /// Checkpoint bytes written, summed across traces.
    pub checkpoint_bytes: u64,
    /// Link-fault retries, summed across traces.
    pub link_retries: u64,
    /// Device modules swapped at end-of-life, summed across traces.
    pub devices_replaced: usize,
    /// Max concurrently running jobs over any single trace.
    pub peak_live_jobs: usize,
}

/// Run `base` once per seed, sharded over `workers` OS threads
/// (clamped to `1..=seeds.len()`), and fold the results.
///
/// Worker-count invariance by construction: each trace is
/// single-threaded and deterministic in its seed; worker `w` takes
/// seed indices `w, w + workers, ...` and posts results tagged with
/// their index; the fold consumes the slots in index order. Nothing
/// about scheduling, completion order or thread count can reach the
/// folded numbers.
pub fn run_sweep(base: &WorkloadSpec, seeds: &[u64], workers: usize) -> Result<SweepReport> {
    anyhow::ensure!(!seeds.is_empty(), "a sweep needs at least one seed");
    base.validate()?;
    let workers = workers.clamp(1, seeds.len());
    let mut slots: Vec<Option<Result<TraceSummary>>> = Vec::new();
    slots.resize_with(seeds.len(), || None);
    std::thread::scope(|scope| {
        let (tx, rx) = std::sync::mpsc::channel();
        for w in 0..workers {
            let tx = tx.clone();
            scope.spawn(move || {
                for i in (w..seeds.len()).step_by(workers) {
                    let mut spec = base.clone();
                    spec.seed = seeds[i];
                    // One ledger subdirectory per seed, zero-padded so
                    // a sorted directory walk enumerates seeds in seed
                    // order — the merged ledger is identical at any
                    // worker count (DESIGN.md §Ledger).
                    if let Some(dir) = &base.ledger {
                        spec.ledger = Some(dir.join(format!("seed-{:020}", seeds[i])));
                    }
                    if tx.send((i, run_trace(&spec))).is_err() {
                        return; // collector gone; nothing left to report to
                    }
                }
            });
        }
        drop(tx);
        for (i, res) in rx {
            slots[i] = Some(res);
        }
    });

    let mut traces = Vec::with_capacity(seeds.len());
    for (i, slot) in slots.into_iter().enumerate() {
        let summary = slot
            .expect("every shard index was posted exactly once")
            .with_context(|| format!("sweep trace for seed {}", seeds[i]))?;
        traces.push(summary);
    }

    let mut queue_wait = RunningStat::new();
    let mut lock_wait = RunningStat::new();
    let mut jobs_per_hour = RunningStat::new();
    let mut aggregate_ips = RunningStat::new();
    let mut total_images = 0usize;
    let mut total_jobs = 0usize;
    let mut cancelled = 0usize;
    let mut drained = 0usize;
    let mut crashed = 0usize;
    let mut lost_steps = 0usize;
    let mut checkpoint_bytes = 0u64;
    let mut link_retries = 0u64;
    let mut devices_replaced = 0usize;
    let mut peak_live_jobs = 0usize;
    for t in &traces {
        queue_wait.merge(&t.queue_wait);
        lock_wait.merge(&t.lock_wait);
        let hours = t.makespan.as_secs_f64() / 3600.0;
        jobs_per_hour.add(if hours > 0.0 { t.completed as f64 / hours } else { 0.0 });
        aggregate_ips.add(t.aggregate_ips);
        total_images += t.total_images;
        total_jobs += t.jobs;
        cancelled += t.cancelled;
        drained += t.drained;
        crashed += t.crashed;
        lost_steps += t.lost_steps;
        checkpoint_bytes += t.checkpoint_bytes;
        link_retries += t.link_retries;
        devices_replaced += t.devices_replaced;
        peak_live_jobs = peak_live_jobs.max(t.peak_live_jobs);
    }
    Ok(SweepReport {
        traces,
        queue_wait,
        lock_wait,
        jobs_per_hour,
        aggregate_ips,
        total_images,
        total_jobs,
        cancelled,
        drained,
        crashed,
        lost_steps,
        checkpoint_bytes,
        link_retries,
        devices_replaced,
        peak_live_jobs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CancelSpec, ExperimentConfig, WeightedJob};

    fn small_spec() -> WorkloadSpec {
        WorkloadSpec {
            total_csds: 6,
            stage_io: false,
            data_plane: true,
            fast_forward: true,
            retain_jobs: false,
            seed: 11,
            jobs: 10,
            mean_interarrival_secs: 8.0,
            mix: vec![WeightedJob {
                weight: 1.0,
                job: ExperimentConfig {
                    num_csds: 2,
                    include_host: false,
                    steps: 6,
                    public_images: 256,
                    private_per_csd: 64,
                    ..Default::default()
                },
            }],
            csds_per_job: 2,
            cancels: vec![CancelSpec { job: 3, at_secs: 2.5 }],
            faults: vec![],
            endurance: Default::default(),
            crashes: vec![],
            checkpoint: Default::default(),
            link_fault: Default::default(),
            audit: false,
            ledger: None,
        }
    }

    #[test]
    fn chunked_trace_matches_the_upfront_replay() {
        let spec = small_spec();
        let (summary, rt) = run_trace_with(&spec, |_| {}).expect("trace runs");

        let mut oracle = runtime_for(&spec);
        oracle.load_workload(&spec).expect("replay loads");
        oracle.run_until_idle().expect("replay drains");
        let want = oracle.report();
        let got = rt.report();

        assert_eq!(summary.jobs, 10);
        assert_eq!(summary.completed + summary.cancelled, 10);
        assert_eq!(summary.cancelled, want.cancelled);
        assert_eq!(summary.total_images, want.total_images);
        assert_eq!(summary.makespan, want.makespan);
        // Exact f64 equality: same events in the same order.
        assert_eq!(summary.jobs_energy_j, want.jobs_energy_j);
        assert_eq!(summary.total_energy_j, want.total_energy_j);
        assert_eq!(summary.queue_wait, want.queue_wait);
        assert_eq!(got.link_bytes, want.link_bytes);
        assert_eq!(summary.log_events, oracle.take_log().len());
    }

    #[test]
    fn sweep_is_invariant_to_worker_count() {
        let base = small_spec();
        let seeds = [3u64, 7, 19, 23, 41];
        let one = run_sweep(&base, &seeds, 1).expect("1 worker");
        let two = run_sweep(&base, &seeds, 2).expect("2 workers");
        let many = run_sweep(&base, &seeds, 64).expect("clamped workers");
        assert_eq!(one, two);
        assert_eq!(one, many);
        assert_eq!(one.traces.len(), seeds.len());
        assert_eq!(one.total_jobs, seeds.len() * base.jobs);
        assert_eq!(one.queue_wait.count(), one.total_jobs);
    }

    #[test]
    fn trace_fingerprint_is_invariant_to_audit_and_matches_the_replay() {
        // The end-state fingerprint is one u64 — the cheapest possible
        // cross-run identity check. It must agree between the chunked
        // driver and the all-upfront replay, and between audited and
        // unaudited runs of the same spec.
        let spec = small_spec();
        let (_, rt) = run_trace_with(&spec, |_| {}).expect("trace runs");

        let mut audited = spec.clone();
        audited.audit = true;
        let (_, rt_audited) = run_trace_with(&audited, |_| {}).expect("audited trace runs");

        let mut oracle = runtime_for(&spec);
        oracle.load_workload(&spec).expect("replay loads");
        oracle.run_until_idle().expect("replay drains");
        oracle.take_log();
        oracle.full_audit().expect("the drained replay audits clean");

        assert_eq!(rt.fingerprint(), oracle.fingerprint());
        assert_eq!(rt.fingerprint(), rt_audited.fingerprint());
    }

    #[test]
    fn sweep_rejects_an_empty_seed_list() {
        let err = run_sweep(&small_spec(), &[], 4).unwrap_err();
        assert!(err.to_string().contains("at least one seed"), "{err}");
    }
}
