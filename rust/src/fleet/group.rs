//! One provisioned fleet group: the per-job wiring every job needs —
//! dataset generation and Eq. 1 balancing, plus (for real execution)
//! artifact validation and the PJRT trainer.
//!
//! [`Cluster`](crate::cluster::Cluster) is the single-job special case:
//! it wraps exactly one [`JobGroup`]. The modeled [`Fleet`](super::Fleet)
//! provisions many groups over the shared pool through the same
//! [`provision_placement`] path, so both execution modes share one
//! Eq. 1 implementation (DESIGN.md §5).

use std::sync::Arc;

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::{balance_weighted, Placement, StannisTrainer, TrainConfig};
use crate::data::Dataset;
use crate::runtime::Engine;

/// Dataset + Eq. 1 placement for one job, at explicit batch sizes
/// (which Algorithm 1 may have overridden relative to the config).
pub fn provision_placement(
    cfg: &ExperimentConfig,
    bs_csd: usize,
    bs_host: usize,
) -> Result<(Dataset, Placement)> {
    provision_placement_weighted(cfg, bs_csd, bs_host, &[])
}

/// [`provision_placement`] with per-device health weights: the fleet
/// passes its group's current healths so the public top-up lands on
/// the healthiest devices first (`balance_weighted`), which is what
/// makes a degradation-driven re-balance *move* public shards — the
/// movement the data plane then charges (DESIGN.md §Data-Plane).
pub fn provision_placement_weighted(
    cfg: &ExperimentConfig,
    bs_csd: usize,
    bs_host: usize,
    health: &[f64],
) -> Result<(Dataset, Placement)> {
    let dataset = Dataset::new(cfg.dataset())?;
    let placement =
        balance_weighted(&dataset, cfg.num_csds, bs_csd, bs_host, cfg.include_host, health)?;
    Ok((dataset, placement))
}

/// A fully wired real-execution group (engine + dataset + placement).
pub struct JobGroup {
    pub engine: Arc<Engine>,
    pub dataset: Dataset,
    pub placement: Placement,
    pub cfg: ExperimentConfig,
}

impl JobGroup {
    /// Provision from config: validate the network + batch artifacts,
    /// generate the dataset, balance the shards (Eq. 1).
    pub fn provision(cfg: ExperimentConfig, engine: Arc<Engine>) -> Result<Self> {
        let net = engine.network(&cfg.network)?;
        anyhow::ensure!(
            net.train_artifact(cfg.bs_csd).is_some(),
            "network {} has no train artifact for bs_csd={} (have {:?})",
            cfg.network,
            cfg.bs_csd,
            net.train_batch_sizes
        );
        let (dataset, placement) = provision_placement(&cfg, cfg.bs_csd, cfg.bs_host)?;
        Ok(Self { engine, dataset, placement, cfg })
    }

    /// Construct the real-execution trainer for this group.
    pub fn trainer(&self) -> Result<StannisTrainer> {
        StannisTrainer::new(
            self.engine.clone(),
            self.dataset.clone(),
            &self.placement,
            TrainConfig {
                network: self.cfg.network.clone(),
                num_csds: self.cfg.num_csds,
                include_host: self.cfg.include_host,
                bs_csd: self.cfg.bs_csd,
                bs_host: self.cfg.bs_host,
                steps: self.cfg.steps,
                sgd: self.cfg.sgd(),
                seed: self.cfg.seed as i32,
                consistency_every: 10,
                weighted_grads: true,
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn provision_placement_respects_eq1() {
        let cfg = ExperimentConfig {
            num_csds: 2,
            public_images: 10_000,
            private_per_csd: 500,
            ..Default::default()
        };
        let (_, p) = provision_placement(&cfg, 25, 315).unwrap();
        // dataset_card = 500, bs_card = 25 -> 20 steps; host = 20*315.
        assert_eq!(p.steps_per_epoch, 20);
        assert_eq!(p.host_ids.len(), 20 * 315);
    }

    #[test]
    fn provision_placement_rejects_zero_batch() {
        let cfg = ExperimentConfig::default();
        assert!(provision_placement(&cfg, 0, 16).is_err());
    }
}
