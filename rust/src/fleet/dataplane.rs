//! The modeled data plane for fleet runs (DESIGN.md §Data-Plane).
//!
//! STANNIS's headline invariant (paper §III, §V.C) is that *private
//! data never leaves its CSD* while *public data is shared under full
//! control*. The fleet coordinator models time and energy; this module
//! gives its jobs the physical substrate those claims live on:
//!
//! * **Shard map** — at admission, each job's Eq. 1 [`Placement`]
//!   becomes a physical layout: every image of every CSD shard is
//!   written as one contiguous `ppi`-page flash extent through that
//!   device's FTL (private images pinned to their home CSD, public
//!   images slot-allocated), and the host's public shard is staged
//!   round-robin across the group so the host path has real pages to
//!   read. Layout, movement and staged-read measurement all use the
//!   extent APIs (`write_run`/`read_run` — DESIGN.md §Perf, "Extent
//!   I/O"); results are bit-identical to the per-page loops they
//!   replaced.
//! * **Staged reads** — every (re)balance window measures one batch's
//!   staging cost per device through the real flash / NVMe timelines;
//!   the coordinator charges that window-constant cost on every step,
//!   which keeps steps exact repeats inside a window — the legality
//!   condition of the steady-state fast-forward (DESIGN.md §Perf).
//! * **Rebalance movement** — a degradation re-runs Eq. 1 with health
//!   weights; the public-shard delta then physically moves: source-CSD
//!   flash read → TCP-over-PCIe tunnel relay through the host →
//!   destination flash write, each destination holding the shard-map
//!   resource in EX through its phase (OCFS2-style: the [`Dlm`] master
//!   is host-resident, every request/grant crosses the tunnel, and an
//!   EX release commits a journal version the group's readers then
//!   observe under PR). Lock wait and journal traffic land in the
//!   job's epoch timings exactly as §III describes.
//! * **Privacy guard** — the transfer layer is the enforcement point:
//!   a `Visibility::Private` id appearing in any cross-node transfer
//!   is a hard error (`integration_fleet` property-tests this over
//!   randomized degraded fleets).
//!
//! Everything here is driven at *structural* events only (admission,
//! degradation, completion); the per-step hot path reads the
//! precomputed [`StepStaging`] plan and touches no hardware state, so
//! the per-step and fast-forward executors stay bit-identical.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use anyhow::{bail, ensure, Result};

use crate::analysis::audit::{Auditable, Fnv64};
use crate::coordinator::Placement;
use crate::csd::NewportCsd;
use crate::data::{Dataset, ImageId, Visibility};
use crate::fsync::{Dlm, DlmStats, LockMode, LockReply, ResourceId};
use crate::sim::SimTime;
use crate::tunnel::{NodeId, Tunnel};

use super::job::JobId;
use super::pool::DevicePool;

/// One cross-node movement of staged image data (page-granular).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransferRecord {
    pub job: JobId,
    pub image: ImageId,
    pub from: NodeId,
    pub to: NodeId,
    pub bytes: u64,
}

/// Fleet-wide data-plane totals (per-job numbers live in the job
/// reports; these survive job completion).
#[derive(Debug, Clone, Copy, Default)]
pub struct DataPlaneStats {
    /// Flash pages programmed by admission layouts.
    pub layout_pages: u64,
    /// Rebalance windows executed (including empty-delta ones).
    pub rebalances: u64,
    /// Images relocated CSD→CSD by rebalances.
    pub moved_images: u64,
    /// Bytes those relocations carried (plus host pushes).
    pub moved_bytes: u64,
    /// Public images newly pushed host→CSD (grown host/CSD shards).
    pub host_pushes: u64,
    /// Jobs torn down mid-run by [`DataPlane::cancel`].
    pub cancels: u64,
    /// Flash pages trimmed by cancel teardowns — must equal the
    /// cancelled jobs' resident page counts (the per-device side of the
    /// same ledger is `FtlStats::trims`).
    pub freed_pages: u64,
    /// Flash pages programmed by checkpoint windows
    /// (DESIGN.md §Crash-Recovery). Always 0 with checkpointing off.
    pub ckpt_pages: u64,
}

/// Per-step staged-I/O charge for a job's current window. Measured
/// once per (re)balance; pure data on the per-step hot path.
#[derive(Debug, Clone, Default)]
pub struct StepStaging {
    /// Per group-device latency of staging one batch via the ISP path.
    pub stage: Vec<SimTime>,
    /// Latency of staging the host batch via flash → NVMe.
    pub host_stage: SimTime,
    /// Flash pages read per step (ISP-path + host-path).
    pub flash_reads: u64,
    /// Bytes the host batch crosses NVMe per step.
    pub host_bytes: u64,
}

/// Cost summary of one data-plane window (admission layout or
/// rebalance movement), for the coordinator's ledgers.
#[derive(Debug, Clone, Copy)]
pub struct WindowCost {
    /// When the job's next step may start (layout / movement done and
    /// journal version observed by the group).
    pub ready: SimTime,
    pub pages_read: u64,
    pub pages_written: u64,
    pub bytes_moved: u64,
    pub images_moved: u64,
    /// Total DLM request-to-grant wait across the window.
    pub lock_wait: SimTime,
}

/// Page-slot allocator of one device's staging area: an image holds
/// `ppi` consecutive logical pages at `slot * ppi`. Slots are reused
/// lowest-first so layouts are deterministic.
#[derive(Debug, Default)]
struct DeviceSlots {
    of: BTreeMap<ImageId, u32>,
    free: BTreeSet<u32>,
    next: u32,
}

impl DeviceSlots {
    fn alloc(&mut self, id: ImageId) -> u32 {
        let slot = match self.free.pop_first() {
            Some(s) => s,
            None => {
                let s = self.next;
                self.next += 1;
                s
            }
        };
        self.of.insert(id, slot);
        slot
    }

    fn release(&mut self, id: ImageId) {
        if let Some(slot) = self.of.remove(&id) {
            self.free.insert(slot);
        }
    }
}

/// One job's physical shard map + current staging plan.
struct JobPlane {
    /// Global pool indices of the job's device group.
    devices: Vec<usize>,
    dataset: Dataset,
    /// Flash pages per image.
    ppi: u32,
    /// Per group-device slot allocation (image → slot).
    slots: Vec<DeviceSlots>,
    /// Which group device holds the staged copy of each public image.
    public_home: BTreeMap<ImageId, usize>,
    /// Current Eq. 1 shards (per group device, in shard order).
    shards: Vec<Vec<ImageId>>,
    host_shard: Vec<ImageId>,
    staging: StepStaging,
    /// Journal version of the shard-map resource the group last
    /// observed (monotone across rebalances).
    version: u64,
    /// Interned `shardmap:jobN` resource — resolved once at admission
    /// so every lock op of every window is an array lookup, not a
    /// `format!` + string hash.
    res: ResourceId,
}

/// Where a missing image comes from during a rebalance.
#[derive(Debug, Clone, Copy)]
enum MoveSrc {
    /// Staged copy lives on another group device: flash read there,
    /// tunnel relay (two hops through the host), flash write here.
    Csd(usize),
    /// Never staged in this group: the host pushes it from the public
    /// pool (one host→CSD hop + flash write).
    HostPush,
}

/// The fleet's data plane: shard maps, the host-resident DLM, and the
/// movement/transfer ledger.
pub struct DataPlane {
    dlm: Dlm,
    image_bytes: usize,
    jobs: BTreeMap<JobId, JobPlane>,
    transfers: Vec<TransferRecord>,
    stats: DataPlaneStats,
}

/// Privacy enforcement point: every cross-node movement of staged data
/// funnels through here. A transfer always has distinct endpoints, so
/// a private image on one *necessarily* leaves (or never came from)
/// its home CSD — any private id here is a hard error, which is
/// exactly the §III invariant "a transfer whose source or destination
/// is not the image's home CSD must not carry it".
fn record_transfer(
    transfers: &mut Vec<TransferRecord>,
    dataset: &Dataset,
    rec: TransferRecord,
) -> Result<()> {
    ensure!(rec.from != rec.to, "degenerate self-transfer of image {}", rec.image);
    if let Visibility::Private { csd } = dataset.visibility(rec.image)? {
        bail!(
            "privacy violation: image {} is private to group csd{csd} and must never \
             cross nodes, but was put on a {} -> {} transfer",
            rec.image,
            rec.from,
            rec.to,
        );
    }
    transfers.push(rec);
    Ok(())
}

/// Write one image's pages onto a device (no-op if already resident).
/// Returns (completion, pages written).
///
/// Slots are allocated contiguously per image, so the image is one
/// `ppi`-page extent: a single [`NewportCsd::write_run`] replaces the
/// old per-page `write_page` loop (bit-identical layout and timing —
/// the FTL property tests are the contract).
fn lay_out(
    plane: &mut JobPlane,
    group_idx: usize,
    id: ImageId,
    dev: &mut NewportCsd,
    at: SimTime,
) -> Result<(SimTime, u64)> {
    if plane.slots[group_idx].of.contains_key(&id) {
        return Ok((at, 0));
    }
    let slot = plane.slots[group_idx].alloc(id);
    let end = dev.write_run(slot * plane.ppi, plane.ppi, id as u64, at)?;
    Ok((end.max(at), plane.ppi as u64))
}

impl DataPlane {
    pub fn new(image_bytes: usize) -> Self {
        Self {
            dlm: Dlm::new(),
            image_bytes,
            jobs: BTreeMap::new(),
            transfers: Vec::new(),
            stats: DataPlaneStats::default(),
        }
    }

    pub fn stats(&self) -> DataPlaneStats {
        self.stats
    }

    pub fn dlm_stats(&self) -> DlmStats {
        self.dlm.stats()
    }

    /// Every cross-node transfer the plane executed, in order — the
    /// privacy property test's evidence ledger.
    pub fn transfers(&self) -> &[TransferRecord] {
        &self.transfers
    }

    /// Journal version of a job's shard-map resource.
    pub fn version(&self, job: JobId) -> u64 {
        self.dlm.version(&Self::resource(job))
    }

    /// The current window's per-step staging plan for a job.
    pub fn staging(&self, job: JobId) -> &StepStaging {
        &self.jobs.get(&job).expect("job admitted to the data plane").staging
    }

    /// Drop a completed job's map (ledgers and stats persist).
    pub fn complete(&mut self, job: JobId) {
        self.jobs.remove(&job);
    }

    /// Flash pages currently staged for a job across its group — what a
    /// cancel teardown must free. Zero for unknown/torn-down jobs.
    pub fn resident_pages(&self, job: JobId) -> u64 {
        self.jobs.get(&job).map_or(0, |p| {
            p.slots.iter().map(|s| s.of.len() as u64).sum::<u64>() * p.ppi as u64
        })
    }

    /// Cancel teardown: under the host's EX lock on the job's shard-map
    /// resource, trim every staged image extent on every group device
    /// (freeing the pages for GC), commit the empty map as a journal
    /// version, and drop the job's plane. Trims are metadata-only, so
    /// the window costs lock traffic but no flash time. Returns the
    /// window cost; `pages_written` counts the *freed* pages (also
    /// accumulated in [`DataPlaneStats::freed_pages`]).
    pub fn cancel(
        &mut self,
        job: JobId,
        pool: &mut DevicePool,
        tunnel: &mut Tunnel,
        now: SimTime,
    ) -> Result<WindowCost> {
        let Some(mut plane) = self.jobs.remove(&job) else {
            bail!("{job} was never admitted to the data plane")
        };
        let res = plane.res;
        let granted_at = match self.dlm.request_id(tunnel, NodeId::Host, res, LockMode::Ex, now) {
            LockReply::Granted { at, .. } => at,
            LockReply::Queued => bail!(
                "internal: shard-map resource {:?} contended at cancel",
                self.dlm.name(res)
            ),
        };
        self.dlm.check_invariants()?;
        let ppi = plane.ppi;
        let mut freed = 0u64;
        for i in 0..plane.devices.len() {
            let d = plane.devices[i];
            let slots = std::mem::take(&mut plane.slots[i]);
            for (_, slot) in slots.of {
                freed += pool.device_mut(d).trim_run(slot * ppi, ppi)?;
            }
        }
        self.dlm.release_id(tunnel, NodeId::Host, res, granted_at)?;
        self.dlm.check_invariants()?;
        self.stats.cancels += 1;
        self.stats.freed_pages += freed;
        Ok(WindowCost {
            ready: granted_at,
            pages_read: 0,
            pages_written: freed,
            bytes_moved: 0,
            images_moved: 0,
            lock_wait: granted_at.saturating_sub(now),
        })
    }

    /// Canonical shard-map resource name — interned into a
    /// [`ResourceId`] once at admission; only cold paths (external
    /// `version` queries) go through the string form.
    fn resource(job: JobId) -> String {
        format!("shardmap:{job}")
    }

    /// Checkpoint window (DESIGN.md §Crash-Recovery): every group
    /// device programs the job's model state (`param_bytes`, padded up
    /// to whole image-sized extents) through its FTL, into slots carved
    /// from the same per-device allocator as staged images but keyed by
    /// pseudo-image ids from the top of the id space — disjoint from
    /// any dataset id by construction. The first checkpoint allocates
    /// the slots; later ones overwrite the same extents in place, so
    /// steady-state checkpointing costs no new capacity, and the
    /// cancel/crash teardown trims them with everything else. Returns
    /// (completion instant, pages programmed, bytes written). No
    /// transfer records: nothing crosses nodes here — the optional host
    /// copy rides the tunnel in the coordinator and is booked there.
    pub fn checkpoint(
        &mut self,
        job: JobId,
        param_bytes: u64,
        pool: &mut DevicePool,
        now: SimTime,
    ) -> Result<(SimTime, u64, u64)> {
        let Some(plane) = self.jobs.get_mut(&job) else {
            bail!("{job} was never admitted to the data plane")
        };
        if plane.devices.is_empty() {
            return Ok((now, 0, 0)); // host-only group: nothing to program
        }
        let ppi = plane.ppi;
        let (mut done, mut pages, mut bytes) = (now, 0u64, 0u64);
        for i in 0..plane.devices.len() {
            let d = plane.devices[i];
            let page = pool.device(d).page_bytes() as u64;
            let extents = param_bytes.div_ceil(page).max(1).div_ceil(ppi as u64) as u32;
            for k in 0..extents {
                let pid: ImageId = ImageId::MAX - k as ImageId;
                let slot = match plane.slots[i].of.get(&pid) {
                    Some(&s) => s,
                    None => plane.slots[i].alloc(pid),
                };
                let end = pool.device_mut(d).write_run(slot * ppi, ppi, pid as u64, now)?;
                done = done.max(end);
            }
            let dev_pages = extents as u64 * ppi as u64;
            pages += dev_pages;
            bytes += dev_pages * page;
        }
        self.stats.ckpt_pages += pages;
        Ok((done, pages, bytes))
    }

    /// Strip every DLM hold and queued request of a dead node (crash
    /// path; DESIGN.md §Crash-Recovery). Each stripped EX hold bumps
    /// its resource's journal version, so survivors re-observe before
    /// trusting their shard maps. Returns how many entries (holds +
    /// queued requests) were stripped.
    pub fn force_release(&mut self, tunnel: &mut Tunnel, node: NodeId, now: SimTime) -> usize {
        self.dlm.force_release(tunnel, node, now).len()
    }

    /// Admission: install the physical shard map under the
    /// coordinator's (host-side) EX lock and measure the first window's
    /// staging plan. Returns the window cost; `ready` is when the first
    /// step may begin.
    #[allow(clippy::too_many_arguments)]
    pub fn admit(
        &mut self,
        job: JobId,
        dataset: Dataset,
        placement: &Placement,
        devices: &[usize],
        holds_host: bool,
        bs_csd: usize,
        bs_host: usize,
        param_bytes: u64,
        activation_bytes_per_image: u64,
        pool: &mut DevicePool,
        tunnel: &mut Tunnel,
        now: SimTime,
    ) -> Result<WindowCost> {
        ensure!(!self.jobs.contains_key(&job), "{job} already admitted to the data plane");
        let page = if devices.is_empty() {
            self.image_bytes.max(1)
        } else {
            pool.device(devices[0]).page_bytes()
        };
        let ppi = self.image_bytes.div_ceil(page).max(1) as u32;
        // Intern the shard-map resource once; every later lock op on
        // this job's map is id-keyed.
        let res = self.dlm.resource_id(&Self::resource(job));
        let mut plane = JobPlane {
            devices: devices.to_vec(),
            dataset,
            ppi,
            slots: devices.iter().map(|_| DeviceSlots::default()).collect(),
            public_home: BTreeMap::new(),
            shards: placement.csd_ids.clone(),
            host_shard: placement.host_ids.clone(),
            staging: StepStaging::default(),
            version: 0,
            res,
        };

        // The lock master (host) installs the map under EX; no tunnel
        // round-trip since the requester is the master itself.
        let granted_at = match self.dlm.request_id(tunnel, NodeId::Host, res, LockMode::Ex, now) {
            LockReply::Granted { at, .. } => at,
            LockReply::Queued => bail!(
                "internal: fresh shard-map resource {:?} contended",
                self.dlm.name(res)
            ),
        };
        self.dlm.check_invariants()?;

        let mut pages_written = 0u64;
        let mut done = granted_at;
        // CSD shards: private images pinned home, public images
        // slot-allocated on their assigned device.
        for i in 0..plane.devices.len() {
            let d = plane.devices[i];
            let shard = plane.shards[i].clone();
            for &id in &shard {
                if let Visibility::Private { csd } = plane.dataset.visibility(id)? {
                    ensure!(
                        csd == i,
                        "privacy violation: {job} placed private image {id} of csd{csd} \
                         on group device {i}"
                    );
                }
                let (end, w) = lay_out(&mut plane, i, id, pool.device_mut(d), granted_at)?;
                if w > 0 && matches!(plane.dataset.visibility(id)?, Visibility::Public) {
                    plane.public_home.insert(id, i);
                }
                pages_written += w;
                done = done.max(end);
            }
        }
        // Host shard: public-only, staged round-robin across the group
        // (reusing any copy a CSD shard already placed).
        if holds_host && !plane.devices.is_empty() {
            let host_shard = plane.host_shard.clone();
            for (k, &id) in host_shard.iter().enumerate() {
                ensure!(
                    matches!(plane.dataset.visibility(id)?, Visibility::Public),
                    "privacy violation: private image {id} in {job}'s host shard"
                );
                if plane.public_home.contains_key(&id) {
                    continue;
                }
                let i = k % plane.devices.len();
                let d = plane.devices[i];
                let (end, w) = lay_out(&mut plane, i, id, pool.device_mut(d), granted_at)?;
                plane.public_home.insert(id, i);
                pages_written += w;
                done = done.max(end);
            }
        }
        self.dlm.release_id(tunnel, NodeId::Host, res, done)?;
        self.dlm.check_invariants()?;
        plane.version = self.dlm.version_id(res);

        Self::remeasure(
            &mut plane,
            pool,
            bs_csd,
            bs_host,
            holds_host,
            param_bytes,
            activation_bytes_per_image,
            done,
        )?;
        self.stats.layout_pages += pages_written;
        self.jobs.insert(job, plane);
        Ok(WindowCost {
            ready: done,
            pages_read: 0,
            pages_written,
            bytes_moved: 0,
            images_moved: 0,
            lock_wait: granted_at.saturating_sub(now),
        })
    }

    /// Rebalance after a re-tune: install the new Eq. 1 shards and
    /// physically move the public-shard delta. Each destination device
    /// acquires the shard-map resource in EX (FIFO through the DLM, so
    /// lock wait is real), receives its images, and releases —
    /// committing a journal version. The whole group then takes PR to
    /// observe the commit before the next step.
    #[allow(clippy::too_many_arguments)]
    pub fn rebalance(
        &mut self,
        job: JobId,
        placement: &Placement,
        holds_host: bool,
        bs_csd: usize,
        bs_host: usize,
        param_bytes: u64,
        activation_bytes_per_image: u64,
        pool: &mut DevicePool,
        tunnel: &mut Tunnel,
        now: SimTime,
    ) -> Result<WindowCost> {
        let plane = match self.jobs.get_mut(&job) {
            Some(p) => p,
            None => bail!("{job} was never admitted to the data plane"),
        };
        plane.shards = placement.csd_ids.clone();
        plane.host_shard = placement.host_ids.clone();
        self.stats.rebalances += 1;
        let ndev = plane.devices.len();
        let ppi = plane.ppi;
        let page = if ndev == 0 { 0 } else { pool.device(plane.devices[0]).page_bytes() };
        let res = plane.res;

        // Plan the delta: per destination device, which images it is
        // missing and where each comes from. A retained image keeps its
        // slot; private images are laid out at admission and never
        // appear here (they cannot miss their home).
        let mut planned: BTreeSet<ImageId> = BTreeSet::new();
        let mut incoming: Vec<Vec<(ImageId, MoveSrc)>> = vec![Vec::new(); ndev];
        for (i, shard) in plane.shards.iter().enumerate() {
            for &id in shard {
                if plane.slots[i].of.contains_key(&id) {
                    continue;
                }
                match plane.dataset.visibility(id)? {
                    Visibility::Private { csd } => bail!(
                        "internal: private image {id} of csd{csd} missing from its home \
                         shard map in {job}"
                    ),
                    Visibility::Public => {
                        let src = match plane.public_home.get(&id) {
                            Some(&j) => MoveSrc::Csd(j),
                            None => MoveSrc::HostPush,
                        };
                        if planned.insert(id) {
                            incoming[i].push((id, src));
                        }
                    }
                }
            }
        }
        // Host-shard growth: stage any never-seen public image
        // round-robin (the host pushes from the public pool).
        if holds_host && ndev > 0 {
            for (k, &id) in plane.host_shard.iter().enumerate() {
                ensure!(
                    matches!(plane.dataset.visibility(id)?, Visibility::Public),
                    "privacy violation: private image {id} in {job}'s host shard"
                );
                if plane.public_home.contains_key(&id) || planned.contains(&id) {
                    continue;
                }
                planned.insert(id);
                incoming[k % ndev].push((id, MoveSrc::HostPush));
            }
        }

        let dests: Vec<usize> = (0..ndev).filter(|&i| !incoming[i].is_empty()).collect();
        let mut lock_wait = SimTime::ZERO;
        let mut pages_read = 0u64;
        let mut pages_written = 0u64;
        let mut bytes_moved = 0u64;
        let mut images_moved = 0u64;
        let mut movement_done = now;

        if dests.is_empty() {
            // Empty delta (e.g. only the host batch was re-tuned): the
            // coordinator still commits the new map under a host EX so
            // the journal version advances monotonically per window.
            match self.dlm.request_id(tunnel, NodeId::Host, res, LockMode::Ex, now) {
                LockReply::Granted { at, .. } => {
                    self.dlm.check_invariants()?;
                    self.dlm.release_id(tunnel, NodeId::Host, res, at)?;
                    movement_done = movement_done.max(at);
                }
                LockReply::Queued => bail!(
                    "internal: shard-map resource {:?} contended",
                    self.dlm.name(res)
                ),
            }
        } else {
            // All destinations request EX up front: the first is
            // granted, the rest queue FIFO behind it and are granted by
            // the previous holder's release — their wait is the modeled
            // lock contention.
            let mut grant: VecDeque<(usize, SimTime)> = VecDeque::new();
            for &i in &dests {
                let node = NodeId::Csd(plane.devices[i]);
                if let LockReply::Granted { at, .. } =
                    self.dlm.request_id(tunnel, node, res, LockMode::Ex, now)
                {
                    grant.push_back((i, at));
                }
                self.dlm.check_invariants()?;
            }
            ensure!(
                grant.len() == 1,
                "internal: {} EX grants on {:?}",
                grant.len(),
                self.dlm.name(res)
            );
            while let Some((i, at)) = grant.pop_front() {
                lock_wait += at.saturating_sub(now);
                let gi = plane.devices[i];
                let mut phase_done = at;
                let moves = incoming[i].clone();
                for &(id, src) in &moves {
                    let bytes = ppi as u64 * page as u64;
                    let arrived = match src {
                        MoveSrc::Csd(j) => {
                            let gj = plane.devices[j];
                            let sslot = match plane.slots[j].of.get(&id) {
                                Some(&s) => s,
                                None => bail!(
                                    "internal: image {id} homed on group device {j} \
                                     without a slot"
                                ),
                            };
                            // One extent read: the staged image is a
                            // contiguous `ppi`-page run on the source.
                            let read_done =
                                pool.device_mut(gj).ftl().read_run(sslot * ppi, ppi, at)?;
                            pages_read += ppi as u64;
                            record_transfer(
                                &mut self.transfers,
                                &plane.dataset,
                                TransferRecord {
                                    job,
                                    image: id,
                                    from: NodeId::Csd(gj),
                                    to: NodeId::Csd(gi),
                                    bytes,
                                },
                            )?;
                            // The delta *moves*: the source copy is
                            // trimmed and its slot freed.
                            plane.slots[j].release(id);
                            self.stats.moved_images += 1;
                            tunnel.send(NodeId::Csd(gj), NodeId::Csd(gi), bytes as usize, read_done)
                        }
                        MoveSrc::HostPush => {
                            record_transfer(
                                &mut self.transfers,
                                &plane.dataset,
                                TransferRecord {
                                    job,
                                    image: id,
                                    from: NodeId::Host,
                                    to: NodeId::Csd(gi),
                                    bytes,
                                },
                            )?;
                            self.stats.host_pushes += 1;
                            tunnel.send(NodeId::Host, NodeId::Csd(gi), bytes as usize, at)
                        }
                    };
                    let (end, w) = lay_out(plane, i, id, pool.device_mut(gi), arrived)?;
                    plane.public_home.insert(id, i);
                    pages_written += w;
                    bytes_moved += bytes;
                    images_moved += 1;
                    phase_done = phase_done.max(end);
                }
                // EX release = journal commit; it hands the lock to the
                // next queued destination (FIFO, exactly one EX).
                let granted =
                    self.dlm.release_id(tunnel, NodeId::Csd(gi), res, phase_done)?;
                self.dlm.check_invariants()?;
                movement_done = movement_done.max(phase_done);
                for (node, g_at, _version) in granted {
                    let idx = dests
                        .iter()
                        .copied()
                        .find(|&x| NodeId::Csd(plane.devices[x]) == node)
                        .ok_or_else(|| {
                            anyhow::anyhow!(
                                "internal: {node} granted {:?} unexpectedly",
                                self.dlm.name(res)
                            )
                        })?;
                    grant.push_back((idx, g_at));
                }
            }
        }

        // Journal read-back: every group device takes PR to observe the
        // committed version before the next step (OCFS2 readers replay
        // the journal the EX releases committed).
        let new_version = self.dlm.version_id(res);
        ensure!(
            new_version > plane.version,
            "journal version must advance across a rebalance window \
             ({} -> {new_version})",
            plane.version
        );
        let mut ready = movement_done;
        for &d in &plane.devices {
            match self.dlm.request_id(tunnel, NodeId::Csd(d), res, LockMode::Pr, movement_done)
            {
                LockReply::Granted { at, version } => {
                    ensure!(
                        version == new_version,
                        "reader on csd{d} observed stale journal version {version} \
                         (committed {new_version})"
                    );
                    ready = ready.max(at);
                }
                LockReply::Queued => {
                    bail!("internal: PR on {:?} queued with no EX holder", self.dlm.name(res))
                }
            }
            self.dlm.check_invariants()?;
        }
        for &d in &plane.devices {
            self.dlm.release_id(tunnel, NodeId::Csd(d), res, ready)?;
        }
        self.dlm.check_invariants()?;
        plane.version = new_version;

        self.stats.moved_bytes += bytes_moved;
        Self::remeasure(
            plane,
            pool,
            bs_csd,
            bs_host,
            holds_host,
            param_bytes,
            activation_bytes_per_image,
            ready,
        )?;
        Ok(WindowCost {
            ready,
            pages_read,
            pages_written,
            bytes_moved,
            images_moved,
            lock_wait,
        })
    }

    /// Measure the window's per-step staging plan: one batch per device
    /// through the ISP path, the host batch through flash → NVMe. Pages
    /// are channel-striped by the slot layout, so every batch of the
    /// epoch costs the same — which is what lets one measurement stand
    /// for the whole window (and keeps fast-forward exact).
    #[allow(clippy::too_many_arguments)]
    fn remeasure(
        plane: &mut JobPlane,
        pool: &mut DevicePool,
        bs_csd: usize,
        bs_host: usize,
        holds_host: bool,
        param_bytes: u64,
        activation_bytes_per_image: u64,
        t0: SimTime,
    ) -> Result<()> {
        let ndev = plane.devices.len();
        let ppi = plane.ppi;
        let mut staging = StepStaging { stage: vec![SimTime::ZERO; ndev], ..Default::default() };
        for i in 0..ndev {
            if plane.shards[i].is_empty() {
                continue; // empty shard: skip the worker (see data::Shard)
            }
            // Each batch image is one contiguous `ppi`-page extent at
            // its slot — run reads replace the flattened LPN list (the
            // per-page bookings and io stats are identical).
            let dev = pool.device_mut(plane.devices[i]);
            dev.isp().admit(param_bytes, activation_bytes_per_image, bs_csd)?;
            let mut done = t0;
            let mut pages = 0u64;
            for id in plane.shards[i].iter().take(bs_csd) {
                let slot = plane.slots[i].of[id];
                done = done.max(dev.read_for_isp_run(slot * ppi, ppi, t0)?);
                pages += ppi as u64;
            }
            staging.stage[i] = done.saturating_sub(t0);
            staging.flash_reads += pages;
        }
        if holds_host && ndev > 0 && !plane.host_shard.is_empty() {
            let page = pool.device(plane.devices[0]).page_bytes();
            // Plan the host batch as per-device extent runs, not pages.
            let mut per_dev: BTreeMap<usize, Vec<(u32, u32)>> = BTreeMap::new();
            for id in plane.host_shard.iter().take(bs_host) {
                let &home = plane
                    .public_home
                    .get(id)
                    .ok_or_else(|| anyhow::anyhow!("host image {id} was never staged"))?;
                let slot = plane.slots[home].of[id];
                per_dev.entry(home).or_default().push((slot * ppi, ppi));
            }
            let mut done = t0;
            for (i, runs) in &per_dev {
                let dev = pool.device_mut(plane.devices[*i]);
                let mut pages = 0u64;
                for &(lpn0, len) in runs {
                    done = done.max(dev.read_for_host_run(lpn0, len, t0)?);
                    pages += len as u64;
                }
                staging.flash_reads += pages;
                staging.host_bytes += pages * page as u64;
            }
            staging.host_stage = done.saturating_sub(t0);
        }
        plane.staging = staging;
        Ok(())
    }

    /// Audit the plane: per-job slot-allocator and shard-map
    /// consistency, the privacy guarantee over the whole transfer
    /// ledger, transfer/stat conservation, and the DLM's own
    /// invariants (DESIGN.md §Static-Analysis).
    pub fn check_invariants(&self) -> Result<()> {
        for (job, p) in &self.jobs {
            ensure!(
                p.slots.len() == p.devices.len() && p.shards.len() == p.devices.len(),
                "{job}: {} slot allocator(s) / {} shard(s) for {} device(s)",
                p.slots.len(),
                p.shards.len(),
                p.devices.len()
            );
            for (i, s) in p.slots.iter().enumerate() {
                let mut used = BTreeSet::new();
                for (&id, &slot) in &s.of {
                    ensure!(
                        slot < s.next,
                        "{job}: image {id} on device {i} holds slot {slot} >= cursor {}",
                        s.next
                    );
                    ensure!(used.insert(slot), "{job}: slot {slot} double-booked on device {i}");
                    ensure!(
                        !s.free.contains(&slot),
                        "{job}: slot {slot} on device {i} both allocated and free"
                    );
                }
                for &slot in &s.free {
                    ensure!(
                        slot < s.next,
                        "{job}: free slot {slot} on device {i} >= cursor {}",
                        s.next
                    );
                }
                ensure!(
                    s.of.len() + s.free.len() == s.next as usize,
                    "{job}: device {i} slot leak ({} allocated + {} free != {} carved)",
                    s.of.len(),
                    s.free.len(),
                    s.next
                );
            }
            for (&id, &home) in &p.public_home {
                ensure!(home < p.slots.len(), "{job}: image {id} homed on group index {home}");
                ensure!(
                    p.slots[home].of.contains_key(&id),
                    "{job}: public_home says image {id} is staged on device {home}, \
                     but it holds no slot there"
                );
                ensure!(
                    matches!(p.dataset.visibility(id)?, Visibility::Public),
                    "{job}: private image {id} in the public home map"
                );
            }
            for (i, shard) in p.shards.iter().enumerate() {
                for &id in shard {
                    ensure!(
                        p.slots[i].of.contains_key(&id),
                        "{job}: shard image {id} not resident on its device {i}"
                    );
                    if let Visibility::Private { csd } = p.dataset.visibility(id)? {
                        ensure!(
                            csd == i,
                            "{job}: private image {id} of csd{csd} sharded on device {i}"
                        );
                    }
                }
            }
            let committed = self.dlm.version_id(p.res);
            ensure!(
                p.version == committed,
                "{job}: group observed journal version {} but the DLM committed {committed}",
                p.version
            );
        }
        // The §III privacy invariant re-proved over the whole ledger
        // (for jobs whose dataset is still installed), plus transfer /
        // stat conservation — every movement funnels through
        // `record_transfer`, so these totals must tie out exactly.
        let mut ledger_bytes = 0u64;
        for rec in &self.transfers {
            ensure!(rec.from != rec.to, "self-transfer of image {} in the ledger", rec.image);
            if let Some(p) = self.jobs.get(&rec.job) {
                ensure!(
                    matches!(p.dataset.visibility(rec.image)?, Visibility::Public),
                    "privacy violation in the ledger: private image {} crossed {} -> {}",
                    rec.image,
                    rec.from,
                    rec.to
                );
            }
            ledger_bytes += rec.bytes;
        }
        ensure!(
            ledger_bytes == self.stats.moved_bytes,
            "transfer ledger carries {ledger_bytes} B but stats book {} B moved",
            self.stats.moved_bytes
        );
        ensure!(
            self.transfers.len() as u64 == self.stats.moved_images + self.stats.host_pushes,
            "{} transfer record(s) vs {} relocation(s) + {} host push(es)",
            self.transfers.len(),
            self.stats.moved_images,
            self.stats.host_pushes
        );
        self.dlm.check_invariants()
    }
}

fn hash_node(h: &mut Fnv64, n: NodeId) {
    match n {
        NodeId::Host => h.write_u32(0),
        NodeId::Csd(i) => {
            h.write_u32(1);
            h.write_usize(i);
        }
    }
}

impl Auditable for DataPlane {
    fn component(&self) -> &'static str {
        "data-plane"
    }

    fn audit(&self) -> Result<()> {
        self.check_invariants()
    }

    /// Digest of every installed shard map (slots, homes, shards,
    /// staging plan, journal version), the transfer ledger, the stats
    /// block and the DLM.
    fn fingerprint(&self, h: &mut Fnv64) {
        h.write_usize(self.image_bytes);
        h.write_usize(self.jobs.len());
        for (job, p) in &self.jobs {
            h.write_u64(job.0);
            h.write_usize(p.devices.len());
            for &d in &p.devices {
                h.write_usize(d);
            }
            h.write_u32(p.ppi);
            h.write_u64(p.version);
            for s in &p.slots {
                h.write_usize(s.of.len());
                for (&id, &slot) in &s.of {
                    h.write_usize(id);
                    h.write_u32(slot);
                }
                h.write_usize(s.free.len());
                for &f in &s.free {
                    h.write_u32(f);
                }
                h.write_u32(s.next);
            }
            h.write_usize(p.public_home.len());
            for (&id, &home) in &p.public_home {
                h.write_usize(id);
                h.write_usize(home);
            }
            for shard in &p.shards {
                h.write_usize(shard.len());
                for &id in shard {
                    h.write_usize(id);
                }
            }
            h.write_usize(p.host_shard.len());
            for &id in &p.host_shard {
                h.write_usize(id);
            }
            h.write_usize(p.staging.stage.len());
            for &t in &p.staging.stage {
                h.write_u64(t.as_ns());
            }
            h.write_u64(p.staging.host_stage.as_ns());
            h.write_u64(p.staging.flash_reads);
            h.write_u64(p.staging.host_bytes);
        }
        h.write_usize(self.transfers.len());
        for r in &self.transfers {
            h.write_u64(r.job.0);
            h.write_usize(r.image);
            hash_node(h, r.from);
            hash_node(h, r.to);
            h.write_u64(r.bytes);
        }
        let s = &self.stats;
        h.write_u64(s.layout_pages);
        h.write_u64(s.rebalances);
        h.write_u64(s.moved_images);
        h.write_u64(s.moved_bytes);
        h.write_u64(s.host_pushes);
        h.write_u64(s.cancels);
        h.write_u64(s.freed_pages);
        h.write_u64(s.ckpt_pages);
        self.dlm.fingerprint(h);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::csd::CsdConfig;
    use crate::data::DatasetConfig;
    use crate::tunnel::TunnelConfig;

    fn dataset(public: usize, private: Vec<usize>) -> Dataset {
        Dataset::new(DatasetConfig {
            public_images: public,
            private_per_csd: private,
            hw: 8,
            classes: 4,
            seed: 7,
            noise: 0.5,
        })
        .unwrap()
    }

    fn setup(n: usize) -> (DataPlane, DevicePool, Tunnel) {
        (
            DataPlane::new(8 * 1024),
            DevicePool::new(n, &CsdConfig::default()),
            Tunnel::new(n, TunnelConfig::default()),
        )
    }

    fn placement(d: &Dataset, csds: usize, bs_csd: usize, bs_host: usize, host: bool) -> Placement {
        crate::coordinator::balance(d, csds, bs_csd, bs_host, host).unwrap()
    }

    #[test]
    fn admission_lays_out_and_measures() {
        let (mut plane, mut pool, mut tun) = setup(2);
        let d = dataset(200, vec![16, 16]);
        let p = placement(&d, 2, 8, 16, true);
        let cost = plane
            .admit(
                JobId(0),
                d,
                &p,
                &[0, 1],
                true,
                8,
                16,
                1 << 20,
                32 * 1024,
                &mut pool,
                &mut tun,
                SimTime::ZERO,
            )
            .unwrap();
        assert!(cost.pages_written > 0, "layout must program pages");
        assert!(cost.ready > SimTime::ZERO, "layout takes simulated time");
        assert_eq!(cost.bytes_moved, 0);
        let st = plane.staging(JobId(0)).clone();
        assert_eq!(st.stage.len(), 2);
        assert!(st.stage.iter().all(|&s| s > SimTime::ZERO), "staging must cost time");
        assert!(st.host_stage > SimTime::ZERO);
        assert!(st.flash_reads > 0 && st.host_bytes > 0);
        // Version 1 after the admission commit; no tunnel traffic (the
        // host is the lock master).
        assert_eq!(plane.version(JobId(0)), 1);
        assert_eq!(tun.stats().bytes, 0);
    }

    #[test]
    fn audit_holds_and_fingerprint_moves_across_every_window_kind() {
        use crate::analysis::audit::fingerprint_of;
        let (mut plane, mut pool, mut tun) = setup(2);
        let d = dataset(400, vec![4, 4]);
        plane.check_invariants().unwrap();
        let fp_empty = fingerprint_of(&plane);

        let before = placement(&d, 2, 8, 16, false);
        plane
            .admit(
                JobId(0),
                d.clone(),
                &before,
                &[0, 1],
                false,
                8,
                16,
                1 << 20,
                32 * 1024,
                &mut pool,
                &mut tun,
                SimTime::ZERO,
            )
            .unwrap();
        plane.check_invariants().unwrap();
        let fp_admitted = fingerprint_of(&plane);
        assert_ne!(fp_empty, fp_admitted, "an installed shard map must move the digest");

        let after = crate::coordinator::balance_weighted(&d, 2, 8, 16, false, &[0.5, 1.0]).unwrap();
        plane
            .rebalance(
                JobId(0),
                &after,
                false,
                8,
                16,
                1 << 20,
                32 * 1024,
                &mut pool,
                &mut tun,
                SimTime::secs(10),
            )
            .unwrap();
        plane.check_invariants().unwrap();
        let fp_rebalanced = fingerprint_of(&plane);
        assert_ne!(fp_admitted, fp_rebalanced, "moved shards must move the digest");

        plane.cancel(JobId(0), &mut pool, &mut tun, SimTime::secs(20)).unwrap();
        plane.check_invariants().unwrap();
        // The ledger and stats survive the teardown, so the digest does
        // not return to the empty-plane value.
        assert_ne!(fingerprint_of(&plane), fp_empty);
        assert_eq!(plane.component(), "data-plane");
    }

    #[test]
    fn rebalance_moves_delta_under_locks_and_rejects_private_leaks() {
        let (mut plane, mut pool, mut tun) = setup(2);
        // Small private shards force a public top-up (4 images per
        // device) whose blocks swap when the health order flips.
        let d = dataset(400, vec![4, 4]);
        let before = placement(&d, 2, 8, 16, false);
        plane
            .admit(
                JobId(0),
                d.clone(),
                &before,
                &[0, 1],
                false,
                8,
                16,
                1 << 20,
                32 * 1024,
                &mut pool,
                &mut tun,
                SimTime::ZERO,
            )
            .unwrap();
        // Health-weighted re-balance: device 0 degraded, so the public
        // top-up blocks swap between the two devices.
        let after = crate::coordinator::balance_weighted(&d, 2, 8, 16, false, &[0.5, 1.0]).unwrap();
        let t = SimTime::secs(10);
        let cost = plane
            .rebalance(
                JobId(0),
                &after,
                false,
                8,
                16,
                1 << 20,
                32 * 1024,
                &mut pool,
                &mut tun,
                t,
            )
            .unwrap();
        assert!(cost.images_moved > 0, "delta must physically move");
        assert!(cost.bytes_moved > 0);
        assert!(cost.ready > t, "movement takes simulated time");
        assert!(tun.stats().bytes > 0, "movement + lock traffic crosses the tunnel");
        assert!(tun.stats().relayed > 0, "csd->csd moves relay through the host");
        assert!(plane.version(JobId(0)) > 1, "EX releases commit journal versions");
        // Every transfer is public; no private id ever crossed nodes.
        assert!(!plane.transfers().is_empty());
        for rec in plane.transfers() {
            assert!(matches!(d.visibility(rec.image).unwrap(), Visibility::Public));
        }
        // The guard itself hard-errors on a private cross-CSD transfer.
        let priv_id = d.private_ids(0).unwrap().start;
        let mut log = Vec::new();
        let err = record_transfer(
            &mut log,
            &d,
            TransferRecord {
                job: JobId(0),
                image: priv_id,
                from: NodeId::Csd(0),
                to: NodeId::Csd(1),
                bytes: 1,
            },
        )
        .unwrap_err();
        assert!(err.to_string().contains("privacy violation"), "got: {err}");
        assert!(log.is_empty());
    }

    #[test]
    fn cancel_frees_every_resident_page_under_the_lock() {
        let (mut plane, mut pool, mut tun) = setup(2);
        let d = dataset(200, vec![16, 16]);
        let p = placement(&d, 2, 8, 16, true);
        plane
            .admit(
                JobId(0),
                d,
                &p,
                &[0, 1],
                true,
                8,
                16,
                1 << 20,
                32 * 1024,
                &mut pool,
                &mut tun,
                SimTime::ZERO,
            )
            .unwrap();
        let resident = plane.resident_pages(JobId(0));
        assert!(resident > 0, "admission must stage pages");
        let v1 = plane.version(JobId(0));
        let cost = plane.cancel(JobId(0), &mut pool, &mut tun, SimTime::secs(3)).unwrap();
        // Every staged page is freed, and the two sides of the ledger
        // agree: the plane's freed_pages equals the devices' FTL trims.
        assert_eq!(cost.pages_written, resident);
        assert_eq!(plane.stats().freed_pages, resident);
        assert_eq!(plane.stats().cancels, 1);
        let trims: u64 = (0..2).map(|i| pool.device(i).ftl_ref().stats().trims).sum();
        assert_eq!(trims, resident);
        assert_eq!(plane.resident_pages(JobId(0)), 0);
        // The teardown committed a journal version under EX.
        assert!(plane.version(JobId(0)) > v1);
        // Double-cancel (or cancelling an unknown job) is an error.
        assert!(plane.cancel(JobId(0), &mut pool, &mut tun, SimTime::secs(4)).is_err());
    }

    #[test]
    fn empty_delta_rebalance_still_commits_a_version() {
        let (mut plane, mut pool, mut tun) = setup(1);
        let d = dataset(100, vec![16]);
        let p = placement(&d, 1, 8, 16, false);
        plane
            .admit(
                JobId(3),
                d,
                &p,
                &[0],
                false,
                8,
                16,
                1 << 20,
                32 * 1024,
                &mut pool,
                &mut tun,
                SimTime::ZERO,
            )
            .unwrap();
        let v1 = plane.version(JobId(3));
        // Same placement again: nothing moves, version still advances.
        let cost = plane
            .rebalance(
                JobId(3),
                &p,
                false,
                8,
                16,
                1 << 20,
                32 * 1024,
                &mut pool,
                &mut tun,
                SimTime::secs(5),
            )
            .unwrap();
        assert_eq!(cost.images_moved, 0);
        assert_eq!(cost.bytes_moved, 0);
        assert!(plane.version(JobId(3)) > v1);
    }
}
