//! Fleet-scale multi-job coordination (DESIGN.md §5).
//!
//! STANNIS (DAC'20) schedules *one* training job across a host and a
//! pool of Newport CSDs. The deployment target its follow-up line of
//! work describes is a shared chassis serving many concurrent
//! workloads — different networks, batch ladders and privacy
//! placements time-sharing one device fleet. This module turns the
//! single-experiment pipeline into that system:
//!
//! * [`pool`] — the shared [`DevicePool`]: every Newport in the
//!   chassis, with per-device health and job assignment.
//! * [`group`] — per-job provisioning ([`JobGroup`], Eq. 1 balancing);
//!   [`crate::cluster::Cluster`] is the single-job special case.
//! * [`job`] — job identity, lifecycle and per-job reports.
//! * [`dataplane`] — the physical data plane: flash-page shard maps,
//!   DLM-guarded rebalance movement, per-window staged-read costing,
//!   and the privacy guard on every cross-node transfer
//!   (DESIGN.md §Data-Plane).
//! * [`coordinator`] — the [`FleetRuntime`] itself: an online session
//!   (submit/cancel/run_until over arrival, cancellation and
//!   degradation/repair events) with FIFO-with-backfill admission,
//!   per-group Algorithm 1 tuning, concurrent synchronous steps on the
//!   shared discrete-event loop with per-job ring-allreduce domains,
//!   and degradation-driven re-tuning that never disturbs co-tenants.
//!   [`Fleet`] is the legacy batch façade (submit-all-at-t0 +
//!   run-until-idle). By default the runtime is *streaming*: terminal
//!   jobs retire into compact [`RetiredRecord`]s on the `take_log`
//!   stream and their slab slots are reused, so memory is O(live
//!   jobs); `FleetConfig::retain_jobs` restores the keep-everything
//!   oracle (DESIGN.md §Runtime, "Retirement & streaming").
//! * [`sweep`] — the chunked million-arrival trace driver
//!   ([`run_trace`]) and the sharded multi-seed sweep harness
//!   ([`run_sweep`]): independent seeded traces across `std::thread`
//!   workers, merged deterministically — per-seed results are
//!   bit-identical at any worker count (DESIGN.md §Runtime, "Sweep
//!   harness").

pub mod coordinator;
pub mod dataplane;
pub mod group;
pub mod job;
pub mod pool;
pub mod sweep;

pub use coordinator::{Fleet, FleetConfig, FleetReport, FleetRuntime, LogEntry, RuntimeEvent};
pub use dataplane::{DataPlane, DataPlaneStats, StepStaging, TransferRecord};
pub use group::{provision_placement, provision_placement_weighted, JobGroup};
pub use job::{JobId, JobReport, JobState, RetiredRecord};
pub use pool::{DevicePool, FleetDevice};
pub use sweep::{run_sweep, run_trace, run_trace_with, runtime_for, SweepReport, TraceSummary};
