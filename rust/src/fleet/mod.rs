//! Fleet-scale multi-job coordination (DESIGN.md §5).
//!
//! STANNIS (DAC'20) schedules *one* training job across a host and a
//! pool of Newport CSDs. The deployment target its follow-up line of
//! work describes is a shared chassis serving many concurrent
//! workloads — different networks, batch ladders and privacy
//! placements time-sharing one device fleet. This module turns the
//! single-experiment pipeline into that system:
//!
//! * [`pool`] — the shared [`DevicePool`]: every Newport in the
//!   chassis, with per-device health and job assignment.
//! * [`group`] — per-job provisioning ([`JobGroup`], Eq. 1 balancing);
//!   [`crate::cluster::Cluster`] is the single-job special case.
//! * [`job`] — job identity, lifecycle and per-job reports.
//! * [`coordinator`] — the [`Fleet`] itself: FIFO-with-backfill
//!   admission, per-group Algorithm 1 tuning, concurrent synchronous
//!   steps on the shared discrete-event loop with per-job
//!   ring-allreduce domains, and degradation-driven re-tuning that
//!   never disturbs co-tenants.

pub mod coordinator;
pub mod group;
pub mod job;
pub mod pool;

pub use coordinator::{Fleet, FleetConfig, FleetReport};
pub use group::{provision_placement, JobGroup};
pub use job::{JobId, JobReport, JobState};
pub use pool::{DevicePool, FleetDevice};
