//! The fleet coordinator: an event-driven multi-job scheduler over the
//! shared CSD pool (DESIGN.md §5).
//!
//! A [`Fleet`] owns every Newport in the chassis plus the host. Jobs
//! ([`ExperimentConfig`]s) enter a FIFO admission queue with backfill:
//! the head waits for its device group (and the host, if requested —
//! the host is granted to at most one job at a time), while smaller
//! jobs behind it may start on leftover devices. Admission runs the
//! full single-job pipeline per group:
//!
//! 1. carve a device group from the pool,
//! 2. Algorithm 1 tuning at the group's slowest health
//!    ([`crate::coordinator::tune`]),
//! 3. health-weighted Eq. 1 balancing
//!    ([`super::group::provision_placement_weighted`]),
//! 4. data-plane installation ([`super::dataplane::DataPlane`]): the
//!    placement becomes a physical flash-page shard map and the
//!    window's staged-read plan is measured (DESIGN.md §Data-Plane),
//! 5. per-job synchronous steps on the shared [`EventQueue`], each
//!    step's ring allreduce confined to the job's own domain
//!    ([`ring_time_shared`] — co-tenant rings share the host root's
//!    packetization budget).
//!
//! **Dynamic rebalancing:** a `Degrade` event multiplies one device's
//! health. The owning job abandons its in-flight step, re-runs
//! Algorithm 1 at the new slowest health and re-balances its placement
//! — co-tenant jobs are never re-tuned or rescheduled. Their contention
//! price is sampled per step from the set of active ring domains, so a
//! co-tenant's metrics are bit-identical with or without the fault as
//! long as that set is unchanged at its own step boundaries (the
//! degraded job slowing down but staying active — the scenario
//! `integration_fleet` asserts); a fault that shifts a completion
//! across a co-tenant's step boundary legitimately reprices that step.
//!
//! Everything is deterministic: same submissions + same fault schedule
//! → identical reports.
//!
//! **Steady-state fast-forward:** between structural events (an
//! admission, a completion, a degradation), every running job repeats
//! bit-identical steps — the compute model is pure and the fluid ring
//! model is shift-invariant. When staging is off, the coordinator
//! therefore advances whole windows in closed form (`Fleet::fast_forward`):
//! it computes the number of steps each job completes strictly before
//! the window's end, credits their time/images/energy/link totals with
//! integer arithmetic (exactly what per-step accumulation would have
//! summed), and re-schedules each job's one in-flight step at its
//! post-window position. `FleetConfig::fast_forward = false` forces the
//! per-step reference path; the two are bit-identical (asserted by the
//! `integration_fleet` equivalence property; legality conditions in
//! DESIGN.md §Perf).

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};

use anyhow::{ensure, Result};

use crate::allreduce::ring_time_shared;
use crate::config::ExperimentConfig;
use crate::coordinator::{tune, TuneConfig};
use crate::csd::CsdConfig;
use crate::metrics::RunningStat;
use crate::perfmodel::{Device, NetId, PerfModel};
use crate::power::{EnergyMeter, PowerConfig};
use crate::sim::{EventQueue, SimTime};
use crate::tunnel::{NodeId, Tunnel, TunnelConfig};

use super::dataplane::DataPlane;
use super::group::provision_placement_weighted;
use super::job::{Job, JobId, JobReport, JobState, PendingStep};
use super::pool::DevicePool;

/// Logical pages preloaded per device; training reads cycle over them
/// (mirrors the single-job scheduler's staging model).
const PRELOADED_PAGES: u32 = 64;

/// Fleet-level knobs (per-job shape comes from each job's
/// [`ExperimentConfig`]).
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Devices in the shared pool (chassis bays holding Newports).
    pub total_csds: usize,
    /// Legacy per-step staging toggle: push every batch through the
    /// CSD flash substrate inside `schedule_step` (stateful, so it
    /// forces the per-step executor). Superseded by `data_plane` when
    /// that is on.
    pub stage_io: bool,
    /// Model the physical data plane (DESIGN.md §Data-Plane): Eq. 1
    /// placements become flash-page shard maps at admission, staged
    /// reads are charged from per-window flash/NVMe measurements fed
    /// into each step, and a degradation's re-balance physically moves
    /// the public-shard delta under `fsync::Dlm` EX locks. Default on;
    /// per-step costs stay window-constant, so the steady-state
    /// fast-forward remains exact.
    pub data_plane: bool,
    /// Bytes of one staged image on flash.
    pub image_bytes: usize,
    /// Advance steady-state windows analytically instead of scheduling
    /// every step (bit-identical results; inert only under the legacy
    /// per-step `stage_io` staging, whose FTL state makes steps
    /// non-repeating — the data plane's window-constant staging is
    /// fast-forward-safe). `false` is the per-step reference path for
    /// equivalence checks and benches.
    pub fast_forward: bool,
    pub tune: TuneConfig,
    pub power: PowerConfig,
    pub tunnel: TunnelConfig,
    pub csd: CsdConfig,
}

impl FleetConfig {
    /// ISP DRAM footprint heuristic: activations ≈ 4× the input image.
    /// Single source for every DRAM-admission check (admission window,
    /// rebalance window, legacy per-step staging) so the three can
    /// never disagree.
    pub fn activation_bytes_per_image(&self) -> u64 {
        self.image_bytes as u64 * 4
    }
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            total_csds: 24,
            stage_io: true,
            data_plane: true,
            image_bytes: 12 * 1024,
            fast_forward: true,
            tune: TuneConfig::default(),
            power: PowerConfig::default(),
            tunnel: TunnelConfig::default(),
            csd: CsdConfig::default(),
        }
    }
}

/// Events driving the fleet's discrete-event loop.
#[derive(Debug, Clone, Copy)]
enum FleetEvent {
    /// One synchronous step of `job` (compute + ring sync) completed.
    StepDone { job: JobId },
    /// Device fault: multiply `device`'s health by `factor`.
    Degrade { device: usize, factor: f64 },
}

/// A submitted-but-not-yet-admitted job.
struct QueuedJob {
    id: JobId,
    spec: ExperimentConfig,
    submitted_at: SimTime,
}

/// Fleet-wide summary across all jobs.
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Per-job reports, in submission (id) order.
    pub jobs: Vec<JobReport>,
    /// Time the last job finished.
    pub makespan: SimTime,
    pub total_images: usize,
    /// Aggregate fleet throughput over the makespan, img/s.
    pub aggregate_ips: f64,
    /// Sum of per-job energy (devices + host-active + link + flash).
    pub jobs_energy_j: f64,
    /// Shared-chassis energy not attributable to any job (base, idle
    /// bays, idle host).
    pub overhead_energy_j: f64,
    pub total_energy_j: f64,
    /// Total tunnel traffic across all ring domains (plus data-plane
    /// movement and DLM lock traffic, each attributed to its job).
    pub link_bytes: u64,
    /// Bytes of public-shard data physically moved by rebalances.
    pub bytes_moved: u64,
    /// Shard-map DLM request-to-grant wait per job (seconds).
    pub lock_wait: RunningStat,
    /// Queue-wait statistics across jobs (seconds).
    pub queue_wait: RunningStat,
    /// Total degradation-driven re-tunes across the fleet.
    pub retunes: usize,
}

/// The multi-job coordinator.
pub struct Fleet {
    cfg: FleetConfig,
    pool: DevicePool,
    tunnel: Tunnel,
    plane: DataPlane,
    queue: VecDeque<QueuedJob>,
    jobs: BTreeMap<JobId, Job>,
    events: EventQueue<FleetEvent>,
    now: SimTime,
    host_held_by: Option<JobId>,
    next_id: u64,
    overhead: EnergyMeter,
    /// Times of injected-but-not-yet-fired degradations — the
    /// fast-forward horizon (a fault must never be jumped over).
    degrades: BinaryHeap<Reverse<SimTime>>,
}

impl Fleet {
    pub fn new(cfg: FleetConfig) -> Self {
        Self {
            pool: DevicePool::new(cfg.total_csds, &cfg.csd),
            tunnel: Tunnel::new(cfg.total_csds, cfg.tunnel.clone()),
            plane: DataPlane::new(cfg.image_bytes),
            queue: VecDeque::new(),
            jobs: BTreeMap::new(),
            events: EventQueue::new(),
            now: SimTime::ZERO,
            host_held_by: None,
            next_id: 0,
            overhead: EnergyMeter::new(),
            degrades: BinaryHeap::new(),
            cfg,
        }
    }

    /// Enqueue a job. Demands come from the spec: `num_csds` devices,
    /// plus the host iff `include_host`.
    pub fn submit(&mut self, spec: ExperimentConfig) -> JobId {
        let id = JobId(self.next_id);
        self.next_id += 1;
        self.queue.push_back(QueuedJob { id, spec, submitted_at: self.now });
        id
    }

    /// The data plane's ledgers (transfer log, movement totals, DLM
    /// stats) — populated only when `FleetConfig::data_plane` is on.
    pub fn data_plane(&self) -> &DataPlane {
        &self.plane
    }

    /// Schedule a device fault: at simulated time `at`, multiply
    /// `device`'s health by `factor` (0.6 = thermal throttle to 60%).
    pub fn inject_degradation(&mut self, at: SimTime, device: usize, factor: f64) {
        self.events.schedule(at, FleetEvent::Degrade { device, factor });
        self.degrades.push(Reverse(at));
    }

    /// Run every submitted job to completion; returns the fleet report.
    pub fn run(&mut self) -> Result<FleetReport> {
        for q in &self.queue {
            ensure!(
                q.spec.num_csds <= self.pool.len(),
                "{} demands {} CSDs but the pool has {}",
                q.id,
                q.spec.num_csds,
                self.pool.len()
            );
        }
        self.try_admit()?;
        loop {
            if self.cfg.fast_forward {
                self.fast_forward()?;
            }
            let Some(ev) = self.events.pop() else { break };
            if let FleetEvent::Degrade { device, factor } = ev.payload {
                self.degrades.pop();
                // A fault landing after the last job finished changes
                // pool health but must not stretch the fleet timeline
                // (makespan/overhead end with the last job).
                let idle = self.queue.is_empty()
                    && self.jobs.values().all(|j| j.state == JobState::Completed);
                if idle {
                    self.pool.degrade(device, factor)?;
                    continue;
                }
            }
            self.advance_overhead(ev.at);
            self.now = ev.at;
            match ev.payload {
                FleetEvent::StepDone { job } => self.on_step_done(job)?,
                FleetEvent::Degrade { device, factor } => self.on_degrade(device, factor)?,
            }
        }
        ensure!(
            self.queue.is_empty(),
            "{} job(s) were never admitted (pool too small for their combined demands)",
            self.queue.len()
        );
        ensure!(
            self.jobs.values().all(|j| j.state == JobState::Completed),
            "internal: event queue drained with jobs still running"
        );
        Ok(self.report())
    }

    fn report(&self) -> FleetReport {
        let jobs: Vec<JobReport> =
            self.jobs.values().map(|j| j.report(&self.cfg.power)).collect();
        let total_images: usize = jobs.iter().map(|j| j.images).sum();
        let jobs_energy_j: f64 = jobs.iter().map(|j| j.energy_j).sum();
        let overhead_energy_j = self.overhead.total_joules();
        let mut queue_wait = RunningStat::new();
        let mut lock_wait = RunningStat::new();
        for j in &jobs {
            queue_wait.add(j.queue_wait.as_secs_f64());
            lock_wait.add(j.lock_wait.as_secs_f64());
        }
        let secs = self.now.as_secs_f64();
        FleetReport {
            makespan: self.now,
            total_images,
            aggregate_ips: if secs > 0.0 { total_images as f64 / secs } else { 0.0 },
            jobs_energy_j,
            overhead_energy_j,
            total_energy_j: jobs_energy_j + overhead_energy_j,
            link_bytes: self.tunnel.stats().bytes,
            bytes_moved: jobs.iter().map(|j| j.bytes_moved).sum(),
            lock_wait,
            queue_wait,
            retunes: jobs.iter().map(|j| j.retunes).sum(),
            jobs,
        }
    }

    /// Integrate shared-chassis power (base, idle bays, idle host) over
    /// the interval between events — the piece of Table II's meter no
    /// single job owns.
    fn advance_overhead(&mut self, to: SimTime) {
        if to <= self.now {
            return;
        }
        let dt = to - self.now;
        let pw = &self.cfg.power;
        self.overhead.add_power("base", pw.base_w, dt);
        self.overhead
            .add_power("idle_storage", self.pool.free_count() as f64 * pw.storage_idle_w, dt);
        if self.host_held_by.is_none() {
            self.overhead.add_power("host_idle", pw.host_idle_w, dt);
        }
    }

    /// FIFO admission with backfill: admit every queued job whose
    /// device-group (and host) demand fits the currently free pool.
    /// First steps are scheduled only after the whole admission pass,
    /// so jobs admitted at the same instant see the same co-tenant
    /// count (symmetric contention pricing).
    fn try_admit(&mut self) -> Result<()> {
        let mut admitted = Vec::new();
        let mut i = 0;
        while i < self.queue.len() {
            let fits = {
                let q = &self.queue[i];
                (!q.spec.include_host || self.host_held_by.is_none())
                    && self.pool.free_count() >= q.spec.num_csds
            };
            if !fits {
                i += 1;
                continue;
            }
            let q = self.queue.remove(i).expect("index in bounds");
            admitted.push(self.admit(q)?);
        }
        for id in admitted {
            self.schedule_step(id)?;
        }
        Ok(())
    }

    /// Algorithm 1 at the group's slowest health. Host-only jobs keep
    /// their configured batch (the paper's 0-CSD baseline has nothing
    /// to equalize against).
    fn tune_group(
        &self,
        spec: &ExperimentConfig,
        group_health: f64,
    ) -> Result<(usize, usize)> {
        if spec.num_csds == 0 {
            return Ok((spec.bs_csd.max(1), spec.bs_host.max(1)));
        }
        let mut model = PerfModel::with_scales(1.0, group_health);
        let r = tune(&mut model, &spec.network, &self.cfg.tune)?;
        let bs_host = if spec.include_host { r.host_bs } else { spec.bs_host.max(1) };
        Ok((r.newport_bs, bs_host))
    }

    fn admit(&mut self, q: QueuedJob) -> Result<JobId> {
        let net = NetId::resolve(&q.spec.network)?;
        let devices = self
            .pool
            .carve(q.spec.num_csds, q.id)
            .expect("try_admit checked the free count");
        let holds_host = q.spec.include_host;
        if holds_host {
            self.host_held_by = Some(q.id);
        }
        let group_health = self.pool.group_health(&devices);
        let (bs_csd, bs_host) = self.tune_group(&q.spec, group_health)?;
        // Health-weighted Eq. 1: the public top-up lands on the
        // healthiest devices first, which is what a later degradation
        // re-deals — producing the physical shard delta the data plane
        // then moves.
        let health: Vec<f64> = devices.iter().map(|&d| self.pool.health(d)).collect();
        let (dataset, placement) =
            provision_placement_weighted(&q.spec, bs_csd, bs_host, &health)?;
        if self.cfg.stage_io && !self.cfg.data_plane {
            for &d in &devices {
                self.pool.preload(d, PRELOADED_PAGES, self.now)?;
            }
        }
        let mut job = Job {
            id: q.id,
            net,
            state: JobState::Running,
            devices,
            holds_host,
            bs_csd,
            bs_host,
            steps_per_epoch: placement.steps_per_epoch,
            images_target: 0,
            images_done: 0,
            steps_done: 0,
            retunes: 0,
            submitted_at: q.submitted_at,
            admitted_at: self.now,
            finished_at: SimTime::ZERO,
            sync_time: SimTime::ZERO,
            link_bytes: 0,
            flash_reads: 0,
            flash_progs: 0,
            staged_host_bytes: 0,
            moved_bytes: 0,
            moved_images: 0,
            lock_wait: SimTime::ZERO,
            stage_ready: self.now,
            staging: Default::default(),
            meter: EnergyMeter::new(),
            pending: None,
            data_cursor: 0,
            spec: q.spec,
        };
        job.images_target = job.spec.steps.max(1) * job.images_per_step();
        let id = job.id;
        if self.cfg.data_plane {
            // Install the physical shard map (flash-page layout under
            // the host's EX lock) and measure the first window's
            // staging plan; the first step starts once layout is done.
            let before = self.tunnel.stats();
            let cost = self.plane.admit(
                id,
                dataset,
                &placement,
                &job.devices,
                holds_host,
                bs_csd,
                bs_host,
                net.sync_bytes() as u64,
                self.cfg.activation_bytes_per_image(),
                &mut self.pool,
                &mut self.tunnel,
                self.now,
            )?;
            let after = self.tunnel.stats();
            job.flash_progs += cost.pages_written;
            job.link_bytes += after.bytes - before.bytes;
            job.lock_wait += cost.lock_wait;
            job.stage_ready = cost.ready;
            job.staging = self.plane.staging(id).clone();
        }
        self.jobs.insert(id, job);
        Ok(id)
    }

    /// Ring domains currently active (incl. the caller's) — co-tenants
    /// sharing the host root's packetization budget.
    fn running_ring_jobs(&self) -> usize {
        self.jobs
            .values()
            .filter(|j| {
                j.state == JobState::Running
                    && j.devices.len() + usize::from(j.holds_host) > 1
            })
            .count()
            .max(1)
    }

    /// Book one synchronous step for `id` starting at `self.now` (or
    /// the job's data-plane `stage_ready`, if later): per-device
    /// staging + compute (health-scaled), host staging + compute if
    /// held, then the job's own ring-allreduce domain.
    ///
    /// With the data plane on, staging is charged from the job's
    /// window-constant [`StepStaging`](super::dataplane::StepStaging)
    /// plan — pure data, no hardware state — so steps inside a window
    /// are exact repeats and the fast-forward stays bit-identical.
    fn schedule_step(&mut self, id: JobId) -> Result<()> {
        let (devices, holds_host, bs_csd, bs_host, net, data_cursor, images, stage_ready) = {
            let j = &self.jobs[&id];
            (
                j.devices.clone(),
                j.holds_host,
                j.bs_csd,
                j.bs_host,
                j.net,
                j.data_cursor,
                j.images_per_step(),
                j.stage_ready,
            )
        };
        // Take the window plan out of the job for the booking (no
        // per-step clone; restored below with the pending step).
        let staging = if self.cfg.data_plane {
            let j = self.jobs.get_mut(&id).expect("job exists");
            Some(std::mem::take(&mut j.staging))
        } else {
            None
        };
        let sharers = self.running_ring_jobs();
        let sync_bytes = net.sync_bytes();
        let now = self.now.max(stage_ready);
        let mut compute_done = now;
        let mut flash_reads = 0u64;
        let mut host_bytes = 0u64;
        if let Some(st) = &staging {
            flash_reads = st.flash_reads;
            host_bytes = st.host_bytes;
        }
        for (i, &d) in devices.iter().enumerate() {
            let health = self.pool.health(d);
            let compute = PerfModel::with_scales(1.0, health)
                .step_time_id(Device::NewportIsp, net, bs_csd)?;
            let done = if let Some(st) = &staging {
                now + st.stage[i] + compute
            } else if self.cfg.stage_io {
                // Scratch-free: a wrapping LPN range over the preloaded
                // pages replaces the old per-step `Vec<u32>` build.
                let ppi = self
                    .cfg
                    .image_bytes
                    .div_ceil(self.pool.device(d).page_bytes())
                    .max(1);
                let count = (bs_csd * ppi) as u32;
                flash_reads += count as u64;
                self.pool.device_mut(d).isp_train_step_range(
                    data_cursor,
                    count,
                    PRELOADED_PAGES,
                    compute,
                    sync_bytes as u64,
                    self.cfg.activation_bytes_per_image(),
                    bs_csd,
                    now,
                )?
            } else {
                now + compute
            };
            compute_done = compute_done.max(done);
        }
        if holds_host {
            let host_compute =
                PerfModel::default().step_time_id(Device::HostXeon, net, bs_host)?;
            let host_stage = staging.as_ref().map_or(SimTime::ZERO, |st| st.host_stage);
            compute_done = compute_done.max(now + host_stage + host_compute);
        }
        let ranks: Vec<NodeId> = holds_host
            .then_some(NodeId::Host)
            .into_iter()
            .chain(devices.iter().map(|&d| NodeId::Csd(d)))
            .collect();
        let stats_before = self.tunnel.stats();
        let sync_end = if ranks.len() > 1 {
            ring_time_shared(&mut self.tunnel, &ranks, sync_bytes, compute_done, sharers)
        } else {
            compute_done
        };
        let stats_after = self.tunnel.stats();
        let event = self.events.schedule(sync_end, FleetEvent::StepDone { job: id });
        let j = self.jobs.get_mut(&id).expect("job exists");
        if let Some(st) = staging {
            j.staging = st;
        }
        j.data_cursor = j.data_cursor.wrapping_add(37);
        j.pending = Some(PendingStep {
            event,
            start: now,
            end: sync_end,
            sync: sync_end - compute_done,
            link_bytes: stats_after.bytes - stats_before.bytes,
            link_msgs: stats_after.messages - stats_before.messages,
            flash_reads,
            host_bytes,
            images,
        });
        Ok(())
    }

    fn on_step_done(&mut self, id: JobId) -> Result<()> {
        let finished = {
            let pw = &self.cfg.power;
            let now = self.now;
            let j = self.jobs.get_mut(&id).expect("StepDone for unknown job");
            let p = j.pending.take().expect("StepDone without a pending step");
            commit_steps(j, pw, &p, 1);
            if j.images_done >= j.images_target {
                j.state = JobState::Completed;
                j.finished_at = now;
                true
            } else {
                false
            }
        };
        if finished {
            self.pool.release(id);
            self.plane.complete(id);
            if self.host_held_by == Some(id) {
                self.host_held_by = None;
            }
            self.try_admit()
        } else {
            self.schedule_step(id)
        }
    }

    /// Advance every running job to just before the next *structural*
    /// event — the earliest completion or injected degradation — in one
    /// closed-form jump, instead of scheduling each intermediate step.
    ///
    /// Legal because, inside such a window, a job's steps are exact
    /// repeats: compute times are pure functions of (health, net,
    /// batch), the fluid ring model is shift-invariant and stateless
    /// (beyond its byte ledger), and the co-tenant count is frozen.
    /// Each job's last pre-window-end step stays a real event, so
    /// completions, admissions and degradations still run through the
    /// ordinary per-step machinery. No-op (exact fallback to per-step)
    /// when the *legacy* per-step flash staging is on — its FTL/
    /// timeline state makes steps non-repeating. The data plane is
    /// fast-forward-safe: its staged-read charge is a window constant
    /// and every stateful booking (layout, movement, locks) happens at
    /// structural events, which both executors run identically.
    fn fast_forward(&mut self) -> Result<()> {
        if self.cfg.stage_io && !self.cfg.data_plane {
            return Ok(());
        }
        // Scan phase: per running job, the in-flight step's period and
        // the projected completion time at one step per period.
        struct Window {
            id: JobId,
            period: SimTime,
            end: SimTime,
            skip: u64,
        }
        let mut windows: Vec<Window> = Vec::new();
        let mut horizon = self.degrades.peek().map(|Reverse(t)| *t);
        for j in self.jobs.values() {
            if j.state != JobState::Running {
                continue;
            }
            let Some(p) = &j.pending else { return Ok(()) };
            let period = p.end - p.start;
            if period == SimTime::ZERO || p.images == 0 {
                return Ok(()); // degenerate config: keep the reference path
            }
            let remaining = (j.images_target - j.images_done).div_ceil(p.images) as u64;
            let finish = p.end + period * (remaining - 1);
            horizon = Some(horizon.map_or(finish, |h| h.min(finish)));
            windows.push(Window { id: j.id, period, end: p.end, skip: 0 });
        }
        let Some(w_end) = horizon else { return Ok(()) };
        // Steps that END strictly before the window end are skippable;
        // the step ending at (or beyond) it remains in-flight.
        for w in &mut windows {
            if w.end < w_end {
                // Ends at end, end+period, ...: how many land before
                // w_end — i.e. ceil(span / period).
                let span = w_end - w.end;
                w.skip = span.as_ns().div_ceil(w.period.as_ns());
            }
        }
        windows.retain(|w| w.skip > 0);
        if windows.is_empty() {
            return Ok(());
        }
        // Re-schedule in the order the per-step path would have
        // scheduled the surviving steps: by their (virtual) start time;
        // at equal starts the longer period was scheduled earlier (its
        // predecessor fired first); full ties keep the existing seq
        // order. This reproduces the deterministic FIFO tie-break of
        // the reference path.
        windows.sort_by_key(|w| {
            let start = w.end + w.period * w.skip - w.period;
            let pending = self.jobs[&w.id].pending.as_ref().expect("scanned above");
            (start, Reverse(w.period), self.events.seq_of(pending.event))
        });
        let pw = &self.cfg.power;
        for w in &windows {
            let j = self.jobs.get_mut(&w.id).expect("job exists");
            let p = j.pending.take().expect("scanned above");
            commit_steps(j, pw, &p, w.skip);
            // Mirror the data-cursor advance of the skipped
            // `schedule_step` calls (unobservable with staging off, but
            // keeps the cursor phase identical if configs evolve).
            j.data_cursor = j.data_cursor.wrapping_add(37u32.wrapping_mul(w.skip as u32));
            let shift = w.period * w.skip;
            // The skipped rings' traffic, credited on the fabric ledger
            // exactly as `ring_time_shared` would have.
            self.tunnel.note_aggregate(w.skip * p.link_msgs, w.skip * p.link_bytes);
            self.events.cancel(p.event);
            let event = self
                .events
                .schedule(p.end + shift, FleetEvent::StepDone { job: w.id });
            j.pending = Some(PendingStep {
                event,
                start: p.start + shift,
                end: p.end + shift,
                ..p
            });
        }
        Ok(())
    }

    /// Device fault: degrade health; if a job holds the device, abandon
    /// its in-flight step (its compute is lost — no images/steps are
    /// credited), re-tune at the new slowest health and re-balance.
    /// Co-tenant jobs are not touched. The abandoned step's staged
    /// flash pages and ring traffic were already booked on the device
    /// and fabric ledgers, so their bytes and energy stay attributed to
    /// the job — keeping fleet totals equal to the per-job sums even
    /// across faults.
    fn on_degrade(&mut self, device: usize, factor: f64) -> Result<()> {
        self.pool.degrade(device, factor)?;
        let Some(id) = self.pool.assigned_job(device) else {
            return Ok(()); // unassigned bay: health change only
        };
        let cancelled = {
            let pw = &self.cfg.power;
            let now = self.now;
            let j = self.jobs.get_mut(&id).expect("assigned job exists");
            j.retunes += 1;
            j.pending.take().map(|p| {
                let dt = now.saturating_sub(p.start);
                j.meter.add_power(
                    "newport",
                    j.devices.len() as f64 * (pw.newport_idle_w + pw.newport_isp_active_w),
                    dt,
                );
                if j.holds_host {
                    j.meter.add_power("host", pw.host_active_w, dt);
                }
                j.link_bytes += p.link_bytes;
                j.flash_reads += p.flash_reads;
                j.staged_host_bytes += p.host_bytes;
                p.event
            })
        };
        if let Some(ev) = cancelled {
            self.events.cancel(ev);
        }
        let (devices, spec, holds_host, net) = {
            let j = &self.jobs[&id];
            (j.devices.clone(), j.spec.clone(), j.holds_host, j.net)
        };
        let group_health = self.pool.group_health(&devices);
        let (bs_csd, bs_host) = self.tune_group(&spec, group_health)?;
        let health: Vec<f64> = devices.iter().map(|&d| self.pool.health(d)).collect();
        let (_dataset, placement) =
            provision_placement_weighted(&spec, bs_csd, bs_host, &health)?;
        {
            let j = self.jobs.get_mut(&id).expect("assigned job exists");
            j.bs_csd = bs_csd;
            if j.holds_host {
                j.bs_host = bs_host;
            }
            j.steps_per_epoch = placement.steps_per_epoch;
        }
        if self.cfg.data_plane {
            // The public-shard delta of the health-weighted re-balance
            // physically moves (flash read → tunnel relay → flash
            // write) under DLM EX locks; the next step starts once the
            // movement completes and the group has observed the new
            // journal version. All traffic inside the window is
            // attributed to the affected job, so fleet ledgers stay
            // conservative across faults.
            let before = self.tunnel.stats();
            let cost = self.plane.rebalance(
                id,
                &placement,
                holds_host,
                bs_csd,
                bs_host,
                net.sync_bytes() as u64,
                self.cfg.activation_bytes_per_image(),
                &mut self.pool,
                &mut self.tunnel,
                self.now,
            )?;
            let after = self.tunnel.stats();
            let staging = self.plane.staging(id).clone();
            let j = self.jobs.get_mut(&id).expect("assigned job exists");
            j.link_bytes += after.bytes - before.bytes;
            j.flash_reads += cost.pages_read;
            j.flash_progs += cost.pages_written;
            j.moved_bytes += cost.bytes_moved;
            j.moved_images += cost.images_moved;
            j.lock_wait += cost.lock_wait;
            j.stage_ready = cost.ready;
            j.staging = staging;
        }
        self.schedule_step(id)
    }
}

/// Credit `k` completed repeats of the in-flight step `p` to `j` — the
/// single commit path shared by the per-step executor (`k = 1`) and the
/// fast-forward executor (`k = steps skipped`). All accumulators are
/// integers (`SimTime`, byte/step counts) or chop-invariant power
/// integrals, so `k` calls with 1 and 1 call with `k` book bit-identical
/// totals (DESIGN.md §Perf).
fn commit_steps(j: &mut Job, pw: &PowerConfig, p: &PendingStep, k: u64) {
    let dt = (p.end - p.start) * k;
    j.steps_done += k as usize;
    j.images_done += p.images * k as usize;
    j.sync_time += p.sync * k;
    j.link_bytes += p.link_bytes * k;
    j.flash_reads += p.flash_reads * k;
    j.staged_host_bytes += p.host_bytes * k;
    j.meter.add_power(
        "newport",
        j.devices.len() as f64 * (pw.newport_idle_w + pw.newport_isp_active_w),
        dt,
    );
    if j.holds_host {
        j.meter.add_power("host", pw.host_active_w, dt);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(network: &str, num_csds: usize, include_host: bool, steps: usize) -> ExperimentConfig {
        ExperimentConfig {
            network: network.into(),
            num_csds,
            include_host,
            steps,
            ..Default::default()
        }
    }

    #[test]
    fn single_job_fleet_completes_with_tuned_batches() {
        let mut fleet = Fleet::new(FleetConfig {
            total_csds: 3,
            stage_io: false,
            ..Default::default()
        });
        let id = fleet.submit(job("mobilenet_v2", 3, true, 4));
        let r = fleet.run().unwrap();
        assert_eq!(r.jobs.len(), 1);
        let j = &r.jobs[0];
        assert_eq!(j.id, id);
        // Algorithm 1 ran at admission: paper Table I batches.
        assert_eq!(j.bs_csd, 25);
        assert!((j.bs_host as i64 - 315).unsigned_abs() <= 16, "host bs {}", j.bs_host);
        assert_eq!(j.steps_done, 4);
        assert_eq!(j.images, r.total_images);
        assert!(j.images_per_sec > 0.0);
        assert!(j.sync_fraction > 0.0 && j.sync_fraction < 1.0);
        assert_eq!(r.retunes, 0);
    }

    #[test]
    fn host_only_job_runs_without_a_ring() {
        let mut fleet = Fleet::new(FleetConfig {
            total_csds: 2,
            stage_io: false,
            ..Default::default()
        });
        fleet.submit(job("mobilenet_v2", 0, true, 3));
        let r = fleet.run().unwrap();
        assert_eq!(r.jobs[0].sync_fraction, 0.0);
        assert_eq!(r.link_bytes, 0);
        assert_eq!(r.jobs[0].images, 3 * ExperimentConfig::default().bs_host);
    }

    #[test]
    fn oversized_job_is_rejected() {
        let mut fleet = Fleet::new(FleetConfig {
            total_csds: 2,
            stage_io: false,
            ..Default::default()
        });
        fleet.submit(job("mobilenet_v2", 5, false, 2));
        assert!(fleet.run().is_err());
    }

    #[test]
    fn fast_forward_matches_per_step_reference() {
        let run = |ff: bool| {
            let mut fleet = Fleet::new(FleetConfig {
                total_csds: 6,
                stage_io: false,
                fast_forward: ff,
                ..Default::default()
            });
            fleet.submit(job("mobilenet_v2", 3, true, 40));
            fleet.submit(job("squeezenet", 3, false, 25));
            // Mid-run fault on job 0's group: the window must stop at
            // the fault, re-tune, then fast-forward again.
            fleet.inject_degradation(SimTime::secs(100), 0, 0.7);
            fleet.run().unwrap()
        };
        let a = run(true);
        let b = run(false);
        assert_eq!(a.makespan, b.makespan, "makespan must be bit-identical");
        assert_eq!(a.total_images, b.total_images);
        assert_eq!(a.link_bytes, b.link_bytes);
        assert_eq!(a.retunes, b.retunes);
        assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.finished_at, y.finished_at);
            assert_eq!(x.steps_done, y.steps_done);
            assert_eq!(x.images, y.images);
            assert_eq!(x.link_bytes, y.link_bytes);
            assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits());
        }
    }

    #[test]
    fn identical_lockstep_jobs_stay_in_admission_order() {
        // Two bit-identical jobs tie at every step boundary — the
        // fast-forward must preserve the per-step FIFO tie-break, so
        // both complete at the same instant and in submission order.
        // (Data plane off: physical staging on *different* device
        // groups differs by per-device ECC draws, which would
        // legitimately break the exact tie this test exists to probe.)
        let run = |ff: bool| {
            let mut fleet = Fleet::new(FleetConfig {
                total_csds: 4,
                stage_io: false,
                data_plane: false,
                fast_forward: ff,
                ..Default::default()
            });
            fleet.submit(job("squeezenet", 2, false, 30));
            fleet.submit(job("squeezenet", 2, false, 30));
            fleet.run().unwrap()
        };
        let (a, b) = (run(true), run(false));
        assert_eq!(a.jobs[0].finished_at, a.jobs[1].finished_at);
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.finished_at, y.finished_at);
            assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits());
        }
        assert_eq!(a.makespan, b.makespan);
    }

    #[test]
    fn data_plane_charges_staging_and_moves_shards_on_degradation() {
        let run = |data_plane: bool| {
            let mut fleet = Fleet::new(FleetConfig {
                total_csds: 3,
                stage_io: false,
                data_plane,
                ..Default::default()
            });
            fleet.submit(job("mobilenet_v2", 3, true, 8));
            fleet.inject_degradation(SimTime::secs(30), 0, 0.6);
            fleet.run().unwrap()
        };
        let on = run(true);
        let off = run(false);
        let j = &on.jobs[0];
        assert_eq!(j.retunes, 1);
        assert!(j.bytes_moved > 0, "public-shard delta must physically move");
        assert!(j.images_moved > 0);
        assert!(j.lock_wait > SimTime::ZERO, "DLM grants cross the tunnel");
        assert_eq!(on.bytes_moved, j.bytes_moved);
        assert_eq!(off.jobs[0].bytes_moved, 0, "no data plane, no movement");
        assert!(
            on.makespan > off.makespan,
            "staged reads + movement must cost simulated time: {} !> {}",
            on.makespan,
            off.makespan
        );
        assert!(j.energy_j > off.jobs[0].energy_j, "flash + link energy is charged");
        // Movement and lock traffic crossed the tunnel and stayed
        // attributed to the job (ledger conservation).
        assert_eq!(on.link_bytes, on.jobs.iter().map(|x| x.link_bytes).sum::<u64>());
        assert!(on.link_bytes > off.link_bytes);
    }

    #[test]
    fn data_plane_host_pushes_grown_host_shard() {
        // Degradation re-tunes the host batch upward; with a public
        // pool bigger than the initial host shard, the growth is
        // staged by host→CSD pushes rather than CSD→CSD moves alone.
        let mut fleet = Fleet::new(FleetConfig {
            total_csds: 2,
            stage_io: false,
            ..Default::default()
        });
        fleet.submit(ExperimentConfig {
            network: "mobilenet_v2".into(),
            num_csds: 2,
            include_host: true,
            steps: 8,
            public_images: 20_000,
            ..Default::default()
        });
        fleet.inject_degradation(SimTime::secs(30), 0, 0.5);
        let r = fleet.run().unwrap();
        assert_eq!(r.jobs[0].retunes, 1);
        assert!(fleet.data_plane().stats().host_pushes > 0, "grown host shard is pushed");
        assert!(fleet
            .data_plane()
            .transfers()
            .iter()
            .any(|t| t.from == crate::tunnel::NodeId::Host));
    }

    #[test]
    fn degrading_an_idle_bay_touches_no_job() {
        let mut fleet = Fleet::new(FleetConfig {
            total_csds: 4,
            stage_io: false,
            ..Default::default()
        });
        fleet.submit(job("mobilenet_v2", 2, true, 3));
        // Device 3 is never carved (job takes 0,1).
        fleet.inject_degradation(SimTime::secs(1), 3, 0.5);
        let r = fleet.run().unwrap();
        assert_eq!(r.retunes, 0);
        assert_eq!(r.jobs[0].retunes, 0);
    }
}
