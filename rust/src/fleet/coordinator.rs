//! The fleet runtime: an online, event-driven multi-job session over
//! the shared CSD pool (DESIGN.md §5, §Runtime).
//!
//! A [`FleetRuntime`] owns every Newport in the chassis plus the host
//! and exposes a *session* API — the shape STANNIS's deployment target
//! (a shared chassis continuously serving training jobs) actually has:
//!
//! * [`FleetRuntime::submit`] / [`FleetRuntime::submit_at`] enqueue a
//!   job at a simulated arrival instant,
//! * [`FleetRuntime::cancel`] tears a job down mid-run (devices
//!   released, shard pages trimmed under the DLM lock, partial report),
//! * [`FleetRuntime::inject_degradation`] /
//!   [`FleetRuntime::inject_repair`] are time-stamped health events
//!   (`factor < 1` throttles, `factor > 1` restores, clamped at 1.0),
//! * the clock is driven by [`FleetRuntime::run_until`] /
//!   [`FleetRuntime::run_until_idle`]; [`FleetRuntime::take_log`]
//!   streams the structural events a slice produced.
//!
//! The legacy batch [`Fleet`] is a thin façade: submit-all-at-t0 +
//! `run_until_idle` — kept so batch callers migrate mechanically, and
//! as the reference the online-vs-batch equivalence property pins the
//! runtime against (`integration_fleet`).
//!
//! Jobs arrive into a FIFO admission queue with backfill: the head
//! waits for its device group (and the host, if requested — the host is
//! granted to at most one job at a time), while smaller jobs behind it
//! may start on leftover devices. Admission fires on *arrival* events
//! and on every release (completion, cancellation), not just
//! completions. Each admission runs the full single-job pipeline:
//!
//! 1. carve a device group from the pool (healthiest bays first, so a
//!    repaired bay goes back to the front of the line),
//! 2. Algorithm 1 tuning at the group's slowest health
//!    ([`crate::coordinator::tune`]),
//! 3. health-weighted Eq. 1 balancing
//!    ([`super::group::provision_placement_weighted`]),
//! 4. data-plane installation ([`super::dataplane::DataPlane`]): the
//!    placement becomes a physical flash-page shard map and the
//!    window's staged-read plan is measured (DESIGN.md §Data-Plane),
//! 5. per-job synchronous steps on the shared [`EventQueue`], each
//!    step's ring allreduce confined to the job's own domain
//!    ([`ring_time_shared`] — co-tenant rings share the host root's
//!    packetization budget).
//!
//! **Dynamic rebalancing:** a `Degrade` event multiplies one device's
//! health (clamped to at most 1.0, so a repair never models a bay
//! faster than calibration). The owning job abandons its in-flight
//! step, re-runs Algorithm 1 at the new slowest health and re-balances
//! its placement — co-tenant jobs are never re-tuned or rescheduled.
//! Their contention price is sampled per step from the set of active
//! ring domains, so a co-tenant's metrics are bit-identical with or
//! without the fault as long as that set is unchanged at its own step
//! boundaries; a fault that shifts a completion across a co-tenant's
//! step boundary legitimately reprices that step.
//!
//! Everything is deterministic: same submissions + same external-event
//! schedule → identical reports, however the session is sliced into
//! `run_until` calls.
//!
//! **Steady-state fast-forward:** between structural events (an
//! arrival, an admission, a completion, a cancellation, a health
//! event), every running job repeats bit-identical steps — the compute
//! model is pure and the fluid ring model is shift-invariant. When
//! staging is off, the coordinator therefore advances whole windows in
//! closed form (`FleetRuntime::fast_forward`): it computes the number
//! of steps each job completes strictly before the window's end,
//! credits their time/images/energy/link totals with integer
//! arithmetic (exactly what per-step accumulation would have summed),
//! and re-schedules each job's one in-flight step at its post-window
//! position. A window additionally ends at the next *external* event
//! (pending arrival/cancel/fault) and at the `run_until` horizon, so
//! online sessions stay bit-exact however they are driven.
//! `FleetConfig::fast_forward = false` forces the per-step reference
//! path; the two are bit-identical (asserted by the `integration_fleet`
//! equivalence properties; legality conditions in DESIGN.md §Perf).
//!
//! **Retirement & streaming:** by default the runtime is *streaming* —
//! the moment a job turns terminal its final [`JobReport`] is folded
//! into fleet-level accumulators, a compact
//! [`RetiredRecord`](super::RetiredRecord) is emitted through
//! [`FleetRuntime::take_log`], and the `Job` leaves the live table (a
//! generational slab whose freed slots are reused), so a session's
//! memory is O(live jobs) — a million-arrival trace runs in the
//! footprint of its peak concurrency. `FleetConfig::retain_jobs = true`
//! restores the retained-everything behavior (every job stays in the
//! table and appears in [`FleetReport::jobs`]); it is the oracle the
//! streaming-vs-retained equivalence property pins the default against,
//! and what the batch [`Fleet`] façade uses. Both modes run the same
//! retirement path — same log stream, same accumulator order, so every
//! total is bit-identical across modes (DESIGN.md §Runtime).

use std::cmp::Reverse;
use std::collections::{BTreeMap, VecDeque};

use anyhow::{bail, ensure, Context, Result};

use crate::allreduce::ring_time_shared;
use crate::analysis::audit::{Auditable, Fnv64};
use crate::config::{CheckpointSpec, ExperimentConfig, LinkFaultSpec, WorkloadSpec};
use crate::coordinator::{tune, TuneConfig};
use crate::csd::{CsdConfig, EccStats, WearReport};
use crate::ledger::LedgerWriter;
use crate::metrics::RunningStat;
use crate::perfmodel::{Device, NetId, PerfModel};
use crate::power::{EnergyMeter, PowerConfig};
use crate::sim::{EventQueue, SimTime};
use crate::tunnel::{NodeId, Tunnel, TunnelConfig};

use super::dataplane::DataPlane;
use super::group::provision_placement_weighted;
use super::job::{Job, JobId, JobReport, JobState, PendingStep, RetiredRecord};
use super::pool::DevicePool;

/// Logical pages preloaded per device; training reads cycle over them
/// (mirrors the single-job scheduler's staging model).
const PRELOADED_PAGES: u32 = 64;

/// Fleet-level knobs (per-job shape comes from each job's
/// [`ExperimentConfig`]).
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Devices in the shared pool (chassis bays holding Newports).
    pub total_csds: usize,
    /// Legacy per-step staging toggle: push every batch through the
    /// CSD flash substrate inside `schedule_step` (stateful, so it
    /// forces the per-step executor). Superseded by `data_plane` when
    /// that is on.
    pub stage_io: bool,
    /// Model the physical data plane (DESIGN.md §Data-Plane): Eq. 1
    /// placements become flash-page shard maps at admission, staged
    /// reads are charged from per-window flash/NVMe measurements fed
    /// into each step, and a degradation's re-balance physically moves
    /// the public-shard delta under `fsync::Dlm` EX locks. Default on;
    /// per-step costs stay window-constant, so the steady-state
    /// fast-forward remains exact.
    pub data_plane: bool,
    /// Keep terminal jobs in the live table so [`FleetReport::jobs`]
    /// enumerates every job ever submitted (the retained-everything
    /// oracle; what the batch [`Fleet`] façade forces). Default
    /// `false`: terminal jobs are retired out of the table into
    /// [`RetiredRecord`]s on the [`FleetRuntime::take_log`] stream and
    /// their slab slots are reused — memory stays O(live jobs). Both
    /// modes emit identical logs and bit-identical totals.
    pub retain_jobs: bool,
    /// Bytes of one staged image on flash.
    pub image_bytes: usize,
    /// Advance steady-state windows analytically instead of scheduling
    /// every step (bit-identical results; inert only under the legacy
    /// per-step `stage_io` staging, whose FTL state makes steps
    /// non-repeating — the data plane's window-constant staging is
    /// fast-forward-safe). `false` is the per-step reference path for
    /// equivalence checks and benches.
    pub fast_forward: bool,
    /// Run [`FleetRuntime::full_audit`] after every processed event
    /// (DESIGN.md §Static-Analysis): every registered
    /// [`Auditable`](crate::analysis::audit::Auditable) component —
    /// event queue, device pool (FTL/flash/free-list per bay), data
    /// plane (incl. the DLM), job slab — re-checks its invariants,
    /// plus the cross-component ledgers. Purely read-only, so results
    /// are bit-identical with the audit off; it only converts a latent
    /// corruption into an error at the first event exhibiting it. Off
    /// by default (it is O(state) per event); the property harness and
    /// `--audit` turn it on.
    pub audit: bool,
    /// Periodic model-state checkpointing (DESIGN.md §Crash-Recovery):
    /// every `interval_steps` completed steps a job writes its model
    /// state as flash extents on every group device (plus an optional
    /// tunnel copy to the host), and a later crash resumes the job
    /// from the last checkpoint instead of step 0. Defaults off
    /// (`interval_steps == 0`) — bit-identical to the pre-checkpoint
    /// runtime.
    pub checkpoint: CheckpointSpec,
    /// Seeded transient tunnel-link failures with a bounded
    /// retry/backoff ladder; a link that exhausts its ladder escalates
    /// to a bay crash. Defaults off (`fail_prob == 0.0`).
    pub link_fault: LinkFaultSpec,
    pub tune: TuneConfig,
    pub power: PowerConfig,
    pub tunnel: TunnelConfig,
    pub csd: CsdConfig,
    /// Persist every retired job to an on-disk ledger at this
    /// directory (DESIGN.md §Ledger). Defaults off (`None`): the
    /// runtime is bit-identical with or without a ledger attached —
    /// the writer never enters the auditable set or the fingerprint.
    pub ledger_path: Option<std::path::PathBuf>,
}

impl FleetConfig {
    /// ISP DRAM footprint heuristic: activations ≈ 4× the input image.
    /// Single source for every DRAM-admission check (admission window,
    /// rebalance window, legacy per-step staging) so the three can
    /// never disagree.
    pub fn activation_bytes_per_image(&self) -> u64 {
        self.image_bytes as u64 * 4
    }
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            total_csds: 24,
            stage_io: true,
            data_plane: true,
            retain_jobs: false,
            image_bytes: 12 * 1024,
            fast_forward: true,
            audit: false,
            checkpoint: CheckpointSpec::default(),
            link_fault: LinkFaultSpec::default(),
            tune: TuneConfig::default(),
            power: PowerConfig::default(),
            tunnel: TunnelConfig::default(),
            csd: CsdConfig::default(),
            ledger_path: None,
        }
    }
}

/// Events driving the runtime's discrete-event loop. `StepDone` is
/// internal; the rest are *external* (operator-scheduled) events — the
/// fast-forward window boundaries.
#[derive(Debug, Clone, Copy)]
enum FleetEvent {
    /// One synchronous step of `job` (compute + ring sync) completed.
    StepDone { job: JobId },
    /// `job` arrives: it enters the admission queue.
    Arrive { job: JobId },
    /// Tear `job` down (queued or running).
    Cancel { job: JobId },
    /// Device health event: multiply `device`'s health by `factor`
    /// (`< 1` fault, `> 1` repair; clamped to at most 1.0).
    Degrade { device: usize, factor: f64 },
    /// `device` dies abruptly (operator schedule or link-fault ladder
    /// exhaustion): in-flight step lost, DLM locks force-released,
    /// module swapped, tenant resumed from its last checkpoint.
    Crash { device: usize },
}

/// A job whose arrival event has not fired yet.
struct PendingArrival {
    spec: ExperimentConfig,
    at: SimTime,
    /// Scheduled `Arrive` event id, for cancellation-before-arrival.
    event: u64,
}

/// An arrived-but-not-yet-admitted job.
struct QueuedJob {
    id: JobId,
    spec: ExperimentConfig,
    submitted_at: SimTime,
}

/// One structural event of a session, for progress streaming
/// ([`FleetRuntime::take_log`]).
#[derive(Debug, Clone)]
pub struct LogEntry {
    pub at: SimTime,
    pub event: RuntimeEvent,
}

/// What happened at a [`LogEntry`]'s instant.
#[derive(Debug, Clone)]
pub enum RuntimeEvent {
    /// The job's arrival fired: it is now in the admission queue.
    Arrived { job: JobId, network: String, num_csds: usize, include_host: bool },
    /// The job was admitted onto a device group.
    Admitted { job: JobId, devices: Vec<usize>, holds_host: bool, bs_csd: usize, bs_host: usize },
    /// The job trained its full image target and released its group.
    Completed { job: JobId, images: usize },
    /// The job was torn down (partial progress in `images`;
    /// `freed_pages` is its shard-map teardown, zero with the data
    /// plane off or for never-admitted jobs).
    Cancelled { job: JobId, images: usize, freed_pages: u64 },
    /// A device health fault landed (`health` is the new value).
    Degraded { device: usize, factor: f64, health: f64 },
    /// A device repair landed (`health` is the new, clamped value).
    Repaired { device: usize, factor: f64, health: f64 },
    /// The job turned terminal and its final report was folded into the
    /// fleet accumulators. Follows the job's `Completed`/`Cancelled`
    /// entry at the same instant. In the streaming default this record
    /// is the job's entire surviving history (its slab slot is freed
    /// for reuse); with `retain_jobs` the job also stays in the table.
    /// Boxed: a record is ~10x the size of every other variant.
    Retired { record: Box<RetiredRecord> },
    /// A device's FTL hit end-of-life (free blocks under GC headroom
    /// after block retirements). If a job held the bay it was drained
    /// (cancel-style teardown, `freed_pages` of shard map trimmed) and
    /// its remaining steps resubmitted as `successor`.
    WornOut {
        device: usize,
        job: Option<JobId>,
        successor: Option<JobId>,
        freed_pages: u64,
    },
    /// A worn-out bay was swapped for a factory-fresh module (rolling
    /// replacement); `generation` counts this bay's incarnations and
    /// the wear counters summarize the module being retired.
    Replaced { device: usize, generation: u32, retired_blocks: u64, erases: u64 },
    /// A bay died abruptly (scheduled crash or a tunnel link that
    /// exhausted its retry ladder) — the *ungraceful* sibling of
    /// `WornOut` (DESIGN.md §Crash-Recovery). If a job held the bay,
    /// its in-flight step burned, its DLM locks were force-released,
    /// and the steps past its last checkpoint (`lost_steps`) were
    /// resubmitted with the rest as `successor`.
    Crashed {
        device: usize,
        job: Option<JobId>,
        successor: Option<JobId>,
        lost_steps: usize,
        freed_pages: u64,
    },
    /// The job wrote a periodic model-state checkpoint (`bytes` of
    /// flash extents across its group, plus the optional host copy).
    Checkpointed { job: JobId, steps: usize, bytes: u64 },
}

impl std::fmt::Display for LogEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // SimTime's Display ignores width flags; pad the rendered form.
        let at = self.at.to_string();
        write!(f, "[{at:>12}] ")?;
        match &self.event {
            RuntimeEvent::Arrived { job, network, num_csds, include_host } => write!(
                f,
                "{job} arrived: {network}, wants {num_csds} CSD(s){}",
                if *include_host { " + host" } else { "" }
            ),
            RuntimeEvent::Admitted { job, devices, holds_host, bs_csd, bs_host } => write!(
                f,
                "{job} admitted on {} device(s){} (bs {bs_csd}/{bs_host})",
                devices.len(),
                if *holds_host { " + host" } else { "" }
            ),
            RuntimeEvent::Completed { job, images } => {
                write!(f, "{job} completed: {images} images")
            }
            RuntimeEvent::Cancelled { job, images, freed_pages } => write!(
                f,
                "{job} cancelled: {images} images done, {freed_pages} shard page(s) freed"
            ),
            RuntimeEvent::Degraded { device, factor, health } => {
                write!(f, "device {device} degraded x{factor:.2} -> health {health:.2}")
            }
            RuntimeEvent::Repaired { device, factor, health } => {
                write!(f, "device {device} repaired x{factor:.2} -> health {health:.2}")
            }
            RuntimeEvent::Retired { record } => {
                let r = &record.report;
                write!(
                    f,
                    "{} retired: {}, {} images, {:.2} J/img",
                    r.id, r.state, r.images, r.j_per_image
                )
            }
            RuntimeEvent::WornOut { device, job, successor, freed_pages } => {
                match (job, successor) {
                    (Some(j), Some(s)) => write!(
                        f,
                        "device {device} worn out: {j} drained ({freed_pages} shard page(s) freed), resubmitted as {s}"
                    ),
                    _ => write!(f, "device {device} worn out (idle bay)"),
                }
            }
            RuntimeEvent::Replaced { device, generation, retired_blocks, erases } => write!(
                f,
                "device {device} replaced (incarnation {generation}): retired module had {retired_blocks} bad block(s), {erases} erase(s)"
            ),
            RuntimeEvent::Crashed { device, job, successor, lost_steps, freed_pages } => {
                match (job, successor) {
                    (Some(j), Some(s)) => write!(
                        f,
                        "device {device} crashed: {j} lost {lost_steps} step(s) ({freed_pages} shard page(s) freed), resumed as {s}"
                    ),
                    _ => write!(f, "device {device} crashed (idle bay)"),
                }
            }
            RuntimeEvent::Checkpointed { job, steps, bytes } => {
                write!(f, "{job} checkpointed at step {steps}: {bytes} B")
            }
        }
    }
}

/// Generational slab holding the live job table. Ids resolve through
/// an id-ordered index (iteration order = submission order, which the
/// fast-forward scan and `report` depend on); freed slots go on a free
/// list and are reused, so in the streaming default the slot count
/// tracks *peak concurrency*, not total arrivals. Generations catch a
/// stale index entry (a bug) in debug builds.
#[derive(Default)]
struct JobSlab {
    slots: Vec<JobSlot>,
    /// Freed slot indices, LIFO (hottest slot is reused first).
    free: Vec<u32>,
    /// JobId -> occupied slot, ordered by id.
    index: BTreeMap<JobId, SlotRef>,
}

struct JobSlot {
    gen: u32,
    job: Option<Job>,
}

#[derive(Clone, Copy)]
struct SlotRef {
    slot: u32,
    gen: u32,
}

impl JobSlab {
    fn insert(&mut self, job: Job) {
        let id = job.id;
        let slot = match self.free.pop() {
            Some(s) => {
                self.slots[s as usize].job = Some(job);
                s
            }
            None => {
                self.slots.push(JobSlot { gen: 0, job: Some(job) });
                (self.slots.len() - 1) as u32
            }
        };
        let gen = self.slots[slot as usize].gen;
        let prev = self.index.insert(id, SlotRef { slot, gen });
        debug_assert!(prev.is_none(), "{id} inserted twice");
    }

    fn get(&self, id: &JobId) -> Option<&Job> {
        let r = self.index.get(id)?;
        let s = &self.slots[r.slot as usize];
        debug_assert_eq!(s.gen, r.gen, "stale slot ref for {id}");
        s.job.as_ref()
    }

    fn get_mut(&mut self, id: &JobId) -> Option<&mut Job> {
        let r = self.index.get(id)?;
        let s = &mut self.slots[r.slot as usize];
        debug_assert_eq!(s.gen, r.gen, "stale slot ref for {id}");
        s.job.as_mut()
    }

    /// Remove `id`, bumping the slot generation and freeing it for
    /// reuse.
    fn remove(&mut self, id: &JobId) -> Option<Job> {
        let r = self.index.remove(id)?;
        let s = &mut self.slots[r.slot as usize];
        debug_assert_eq!(s.gen, r.gen, "stale slot ref for {id}");
        let job = s.job.take();
        debug_assert!(job.is_some(), "index pointed at an empty slot");
        s.gen = s.gen.wrapping_add(1);
        self.free.push(r.slot);
        job
    }

    /// Jobs in id (submission) order.
    fn values(&self) -> impl Iterator<Item = &Job> {
        self.index.values().map(|r| {
            self.slots[r.slot as usize].job.as_ref().expect("indexed slot is occupied")
        })
    }

    /// Slots ever allocated — the table's memory high-water mark. In
    /// the streaming default this stays at peak concurrency; with
    /// `retain_jobs` it grows to the total job count.
    fn slot_high_water(&self) -> usize {
        self.slots.len()
    }

    /// Release-mode promotion of the slab's `debug_assert!`s: every
    /// slot is either indexed (occupied, matching generation and id)
    /// or on the free list (vacant), exactly once.
    pub fn check_invariants(&self) -> Result<()> {
        let mut owner = vec![None::<JobId>; self.slots.len()];
        for (id, r) in &self.index {
            let slot = r.slot as usize;
            ensure!(slot < self.slots.len(), "{id} indexed to slot {slot} out of range");
            ensure!(
                self.slots[slot].gen == r.gen,
                "{id} holds a stale ref to slot {slot}: gen {} vs slot gen {}",
                r.gen,
                self.slots[slot].gen
            );
            let job = self.slots[slot]
                .job
                .as_ref()
                .with_context(|| format!("{id} indexed to vacant slot {slot}"))?;
            ensure!(job.id == *id, "slot {slot} holds {} but is indexed as {id}", job.id);
            ensure!(
                owner[slot].replace(*id).is_none(),
                "slot {slot} indexed twice (second owner {id})"
            );
        }
        let mut freed = vec![false; self.slots.len()];
        for &s in &self.free {
            let slot = s as usize;
            ensure!(slot < self.slots.len(), "free list names slot {slot} out of range");
            ensure!(
                self.slots[slot].job.is_none(),
                "free list names occupied slot {slot}"
            );
            ensure!(!freed[slot], "slot {slot} on the free list twice");
            ensure!(owner[slot].is_none(), "slot {slot} both indexed and free");
            freed[slot] = true;
        }
        ensure!(
            self.index.len() + self.free.len() == self.slots.len(),
            "slab leak: {} indexed + {} free != {} slots",
            self.index.len(),
            self.free.len(),
            self.slots.len()
        );
        Ok(())
    }
}

impl Auditable for JobSlab {
    fn component(&self) -> &'static str {
        "job-slab"
    }

    fn audit(&self) -> Result<()> {
        self.check_invariants()
    }

    /// Digest of the live table: slab shape plus each job's observable
    /// progress ledgers, in id (submission) order.
    fn fingerprint(&self, h: &mut Fnv64) {
        h.write_usize(self.slots.len());
        h.write_usize(self.free.len());
        h.write_usize(self.index.len());
        for (id, r) in &self.index {
            h.write_u64(id.0);
            h.write_u32(r.slot);
            h.write_u32(r.gen);
            let j = self.slots[r.slot as usize].job.as_ref().expect("indexed slot occupied");
            h.write_u32(match j.state {
                JobState::Queued => 0,
                JobState::Running => 1,
                JobState::Completed => 2,
                JobState::Cancelled => 3,
            });
            h.write_usize(j.devices.len());
            for &d in &j.devices {
                h.write_usize(d);
            }
            h.write_bool(j.holds_host);
            h.write_usize(j.bs_csd);
            h.write_usize(j.bs_host);
            h.write_usize(j.steps_per_epoch);
            h.write_usize(j.images_target);
            h.write_usize(j.images_done);
            h.write_usize(j.steps_done);
            h.write_usize(j.retunes);
            h.write_u64(j.submitted_at.as_ns());
            h.write_u64(j.admitted_at.as_ns());
            h.write_u64(j.finished_at.as_ns());
            h.write_u64(j.sync_time.as_ns());
            h.write_u64(j.link_bytes);
            h.write_u64(j.flash_reads);
            h.write_u64(j.flash_progs);
            h.write_u64(j.staged_host_bytes);
            h.write_u64(j.moved_bytes);
            h.write_u64(j.moved_images);
            h.write_u64(j.lock_wait.as_ns());
            h.write_u64(j.stage_ready.as_ns());
            h.write_bool(j.drained);
            h.write_bool(j.crashed);
            h.write_usize(j.ckpt_steps);
            h.write_u64(j.ckpt_bytes);
            h.write_usize(j.lost_steps);
            h.write_bool(j.pending.is_some());
            h.write_u32(j.data_cursor);
        }
    }
}

/// Fleet-level accumulators of retired (terminal) jobs, folded in at
/// retirement — finish order, identical in both modes, so `report`
/// totals are bit-identical whether or not the jobs are retained.
#[derive(Default, Clone)]
struct FleetTotals {
    images: usize,
    energy_j: f64,
    bytes_moved: u64,
    retunes: usize,
    completed: usize,
    cancelled: usize,
    /// Jobs torn down by a device end-of-life drain (a subset of
    /// `cancelled`; their remaining steps were resubmitted).
    drained: usize,
    /// Jobs killed by an abrupt bay crash (also a subset of
    /// `cancelled`; each resumed as a successor from its checkpoint).
    crashed: usize,
    /// Completed-but-uncheckpointed steps those crashes lost (redone
    /// by the successors).
    lost_steps: usize,
    /// Bytes of model-state checkpoints written across all jobs.
    checkpoint_bytes: u64,
    queue_wait: RunningStat,
    lock_wait: RunningStat,
}

impl FleetTotals {
    fn absorb(&mut self, r: &JobReport) {
        self.images += r.images;
        // lint: allow(float-ledger) — the fleet energy total is an f64
        // by contract; bit-identity holds because retirement order is
        // identical across modes (module docs), not because the sum is
        // integer.
        self.energy_j += r.energy_j;
        self.bytes_moved += r.bytes_moved;
        self.retunes += r.retunes;
        if r.drained {
            self.drained += 1;
        }
        if r.crashed {
            self.crashed += 1;
        }
        self.lost_steps += r.lost_steps;
        self.checkpoint_bytes += r.checkpoint_bytes;
        match r.state {
            JobState::Completed => self.completed += 1,
            JobState::Cancelled => self.cancelled += 1,
            JobState::Queued | JobState::Running => {
                unreachable!("absorbed a non-terminal report")
            }
        }
        // lint: allow(float-ledger) — wait *statistics* are seconds by
        // design; the underlying SimTime ledgers stay integer ns.
        self.queue_wait.add(r.queue_wait.as_secs_f64());
        // lint: allow(float-ledger) — same contract as queue_wait.
        self.lock_wait.add(r.lock_wait.as_secs_f64());
    }

    fn retired(&self) -> usize {
        self.completed + self.cancelled
    }

    /// Fold the accumulators into a session fingerprint. Float totals
    /// enter as raw IEEE bits — any accumulation-order divergence
    /// between two runs shows up here verbatim.
    fn fingerprint(&self, h: &mut Fnv64) {
        h.write_usize(self.images);
        h.write_f64_bits(self.energy_j);
        h.write_u64(self.bytes_moved);
        h.write_usize(self.retunes);
        h.write_usize(self.completed);
        h.write_usize(self.cancelled);
        h.write_usize(self.drained);
        h.write_usize(self.crashed);
        h.write_usize(self.lost_steps);
        h.write_u64(self.checkpoint_bytes);
        for stat in [&self.queue_wait, &self.lock_wait] {
            h.write_usize(stat.count());
            h.write_f64_bits(stat.sum());
            h.write_f64_bits(stat.min());
            h.write_f64_bits(stat.max());
        }
    }
}

/// Fleet-wide summary across all jobs.
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Per-job reports of the jobs still in the live table, in
    /// submission (id) order. With `FleetConfig::retain_jobs` that is
    /// every job ever materialized; in the streaming default it is only
    /// the still-running ones — terminal jobs' reports streamed out as
    /// [`RetiredRecord`]s via [`FleetRuntime::take_log`] (queued jobs
    /// appear once admitted or cancelled, in both modes).
    pub jobs: Vec<JobReport>,
    /// Time the last structural event landed (last completion, for a
    /// drained session).
    pub makespan: SimTime,
    pub total_images: usize,
    /// Aggregate fleet throughput over the makespan, img/s.
    pub aggregate_ips: f64,
    /// Sum of per-job energy (devices + host-active + link + flash).
    pub jobs_energy_j: f64,
    /// Shared-chassis energy not attributable to any job (base, idle
    /// bays, idle host).
    pub overhead_energy_j: f64,
    pub total_energy_j: f64,
    /// Total tunnel traffic across all ring domains (plus data-plane
    /// movement and DLM lock traffic, each attributed to its job).
    pub link_bytes: u64,
    /// Bytes of public-shard data physically moved by rebalances.
    pub bytes_moved: u64,
    /// Shard-map DLM request-to-grant wait per job (seconds).
    pub lock_wait: RunningStat,
    /// Queue-wait statistics across jobs (seconds).
    pub queue_wait: RunningStat,
    /// Total degradation-driven re-tunes across the fleet.
    pub retunes: usize,
    /// Jobs that ended in [`JobState::Cancelled`].
    pub cancelled: usize,
    /// Jobs that reached a terminal state (completed + cancelled) —
    /// counted at retirement, so it is exact in both modes even though
    /// the streaming default no longer holds the jobs themselves.
    pub retired: usize,
    /// High-water mark of concurrently *running* (admitted,
    /// non-terminal) jobs — identical across streaming/retained modes,
    /// and the bound the streaming table's slot count stays under.
    pub peak_live_jobs: usize,
    /// Jobs torn down by a device end-of-life drain (a subset of
    /// `cancelled`; their remaining steps resubmitted as successors).
    /// Zero whenever endurance is off.
    pub drained: usize,
    /// Jobs killed by an abrupt bay crash (also a subset of
    /// `cancelled`; each resumed from its last checkpoint as a
    /// successor). Zero with the crash pipeline off.
    pub crashed: usize,
    /// Completed-but-uncheckpointed steps those crashes lost — work
    /// the successors redid.
    pub lost_steps: usize,
    /// Bytes of periodic model-state checkpoints written (flash
    /// extents plus optional tunnel host copies).
    pub checkpoint_bytes: u64,
    /// Tunnel sends that hit the transient-fault retry ladder (each
    /// retry backed off and retransmitted; zero with link faults off).
    pub link_retries: u64,
    /// Device modules swapped at end-of-life (rolling replacement).
    pub devices_replaced: usize,
    /// Fleet-wide flash wear: the live devices plus the accumulated
    /// history of every replaced module, so erase/retirement/WAF
    /// ledgers stay conserved across swaps.
    pub wear: WearReport,
    /// Fleet-wide ECC decoder counters, same scope as `wear`.
    pub ecc: EccStats,
}

/// The online multi-job session (see the module docs for the API
/// shape; [`Fleet`] is the batch façade).
pub struct FleetRuntime {
    cfg: FleetConfig,
    pool: DevicePool,
    tunnel: Tunnel,
    plane: DataPlane,
    /// Submitted jobs whose arrival event has not fired (keyed by
    /// `JobId.0`).
    arrivals: BTreeMap<u64, PendingArrival>,
    /// Arrived jobs waiting for admission, FIFO.
    queue: VecDeque<QueuedJob>,
    /// The live job table. Streaming default: running jobs only
    /// (terminal jobs retire out and their slots are reused); with
    /// `retain_jobs`: every job ever materialized.
    jobs: JobSlab,
    /// Accumulated totals of retired jobs (see [`FleetTotals`]).
    totals: FleetTotals,
    /// Currently-running (admitted, non-terminal) jobs and the session
    /// high-water mark of that count.
    live_jobs: usize,
    peak_live_jobs: usize,
    events: EventQueue<FleetEvent>,
    now: SimTime,
    host_held_by: Option<JobId>,
    next_id: u64,
    overhead: EnergyMeter,
    /// Pending *external* events per instant (arrivals, cancels,
    /// degradations/repairs) — the fast-forward horizon: a window must
    /// never jump over one.
    externals: BTreeMap<SimTime, u32>,
    /// Structural-event log since the last [`FleetRuntime::take_log`].
    log: Vec<LogEntry>,
    /// Wear history of modules retired by end-of-life replacement
    /// (folded in at swap time; live wear is read off the pool).
    retired_wear: WearReport,
    /// Decoder history of those modules, same scope.
    retired_ecc: EccStats,
    /// Modules swapped at end-of-life.
    devices_replaced: usize,
    /// On-disk job-history ledger (DESIGN.md §Ledger), armed by
    /// `FleetConfig::ledger_path`. Deliberately NOT part of
    /// `FleetRuntime::auditables` or the fingerprint: ledger-on and
    /// ledger-off runs must stay bit-identical.
    ledger: Option<LedgerWriter>,
}

impl FleetRuntime {
    pub fn new(cfg: FleetConfig) -> Self {
        let mut tunnel = Tunnel::new(cfg.total_csds, cfg.tunnel.clone());
        tunnel.arm_link_faults(cfg.link_fault);
        Self {
            pool: DevicePool::new(cfg.total_csds, &cfg.csd),
            tunnel,
            plane: DataPlane::new(cfg.image_bytes),
            arrivals: BTreeMap::new(),
            queue: VecDeque::new(),
            jobs: JobSlab::default(),
            totals: FleetTotals::default(),
            live_jobs: 0,
            peak_live_jobs: 0,
            events: EventQueue::new(),
            now: SimTime::ZERO,
            host_held_by: None,
            next_id: 0,
            overhead: EnergyMeter::new(),
            externals: BTreeMap::new(),
            log: Vec::new(),
            retired_wear: WearReport::default(),
            retired_ecc: EccStats::default(),
            devices_replaced: 0,
            ledger: cfg.ledger_path.clone().map(LedgerWriter::new),
            cfg,
        }
    }

    /// The session clock: the instant of the last processed event. The
    /// clock only moves on events — idle gaps are metered when the next
    /// event lands, and a `run_until` horizon beyond the last event
    /// does not stretch the timeline.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// True when no event is pending (the session has drained; more
    /// submissions may re-start it).
    pub fn is_idle(&self) -> bool {
        self.events.is_empty()
    }

    /// Time of the next pending event, if any — the natural `run_until`
    /// target for a streaming driver.
    pub fn next_event_time(&self) -> Option<SimTime> {
        self.events.peek_time()
    }

    /// Enqueue a job arriving now. Demands come from the spec:
    /// `num_csds` devices, plus the host iff `include_host`.
    pub fn submit(&mut self, spec: ExperimentConfig) -> JobId {
        self.submit_at(self.now, spec)
            .expect("an arrival at the current instant is never in the past")
    }

    /// Enqueue a job arriving at simulated time `at` (an external
    /// event). Errors if `at` is already in the past.
    pub fn submit_at(&mut self, at: SimTime, spec: ExperimentConfig) -> Result<JobId> {
        ensure!(
            at >= self.now,
            "cannot submit a job arriving at {at}: the session clock is already at {}",
            self.now
        );
        let id = JobId(self.next_id);
        self.next_id += 1;
        let event = self.events.schedule(at, FleetEvent::Arrive { job: id });
        self.external_scheduled(at);
        self.arrivals.insert(id.0, PendingArrival { spec, at, event });
        Ok(id)
    }

    /// Schedule a teardown of `job` at simulated time `at`: a queued
    /// job is dequeued, a running job abandons its in-flight step,
    /// releases its device carve (and the host), and its data-plane
    /// shard pages are trimmed under the DLM lock; either way the job
    /// ends as [`JobState::Cancelled`] with a partial report. A cancel
    /// landing after the job already finished is a no-op — whether the
    /// job is still in the table or was already retired out of it.
    /// Errors if the job id was never submitted or `at` is in the past.
    pub fn cancel(&mut self, job: JobId, at: SimTime) -> Result<()> {
        ensure!(
            at >= self.now,
            "cannot cancel {job} at {at}: the session clock is already at {}",
            self.now
        );
        // Ids are assigned sequentially, so anything below the cursor
        // was submitted — even if the job has since retired out of the
        // table (streaming default).
        ensure!(job.0 < self.next_id, "cancel for unknown {job} (never submitted)");
        if self.job_settled(job) {
            return Ok(()); // already finished (possibly retired): nothing to schedule
        }
        self.events.schedule(at, FleetEvent::Cancel { job });
        self.external_scheduled(at);
        Ok(())
    }

    /// True once a *submitted* job has reached a terminal state —
    /// whether its record is still in the table (`retain_jobs`) or was
    /// already retired out of it (streaming default). Callers must have
    /// checked `job.0 < self.next_id`.
    fn job_settled(&self, job: JobId) -> bool {
        debug_assert!(job.0 < self.next_id, "settled-check for a never-submitted id");
        match self.jobs.get(&job) {
            Some(j) => j.state.is_terminal(),
            // Not in the table: either retired (settled) or still on
            // its way in (pending arrival / admission queue).
            None => {
                !self.arrivals.contains_key(&job.0) && !self.queue.iter().any(|q| q.id == job)
            }
        }
    }

    /// Schedule a device fault: at simulated time `at`, multiply
    /// `device`'s health by `factor` (0.6 = thermal throttle to 60%).
    /// `factor > 1` expresses a repair (see
    /// [`FleetRuntime::inject_repair`]); health is clamped to 1.0.
    pub fn inject_degradation(&mut self, at: SimTime, device: usize, factor: f64) {
        let at = at.max(self.now);
        self.events.schedule(at, FleetEvent::Degrade { device, factor });
        self.external_scheduled(at);
    }

    /// Schedule a device repair: at `at`, multiply `device`'s health by
    /// `factor >= 1` (clamped at 1.0 — a bay never models faster than
    /// calibration). The owning job re-tunes to the restored speed and
    /// re-balances, exactly like a degradation in the other direction.
    pub fn inject_repair(&mut self, at: SimTime, device: usize, factor: f64) {
        self.inject_degradation(at, device, factor.max(1.0));
    }

    /// Schedule an abrupt bay crash at simulated time `at` (DESIGN.md
    /// §Crash-Recovery): the tenant job's in-flight step is lost, the
    /// dead node's DLM locks are force-released, the module is swapped
    /// for a fresh one, and the job resumes from its last checkpoint
    /// (no checkpoint ⇒ from step 0) with the lost steps ledgered.
    pub fn inject_crash(&mut self, at: SimTime, device: usize) {
        let at = at.max(self.now);
        self.events.schedule(at, FleetEvent::Crash { device });
        self.external_scheduled(at);
    }

    /// The data plane's ledgers (transfer log, movement totals, DLM
    /// stats) — populated only when `FleetConfig::data_plane` is on.
    pub fn data_plane(&self) -> &DataPlane {
        &self.plane
    }

    /// The shared device pool (read-only: per-device health, FTL/flash
    /// stats — e.g. to audit a cancel teardown's trims).
    pub fn pool(&self) -> &DevicePool {
        &self.pool
    }

    /// Lifecycle state of a submitted job still tracked by the session:
    /// `None` for unknown ids — and, in the streaming default, for jobs
    /// already retired out of the table (their terminal state lives in
    /// the [`RetiredRecord`] the log streamed; with
    /// `FleetConfig::retain_jobs` terminal jobs keep answering here).
    pub fn job_state(&self, job: JobId) -> Option<JobState> {
        if let Some(j) = self.jobs.get(&job) {
            return Some(j.state);
        }
        let queued = self.arrivals.contains_key(&job.0)
            || self.queue.iter().any(|q| q.id == job);
        queued.then_some(JobState::Queued)
    }

    /// Currently-running (admitted, non-terminal) jobs.
    pub fn live_jobs(&self) -> usize {
        self.live_jobs
    }

    /// Session high-water mark of [`FleetRuntime::live_jobs`] —
    /// identical across streaming/retained modes.
    pub fn peak_live_jobs(&self) -> usize {
        self.peak_live_jobs
    }

    /// Job-table slots ever allocated (the table's memory high-water
    /// mark). Streaming default: bounded by peak concurrency — retired
    /// slots are reused. With `retain_jobs`: grows to the total number
    /// of jobs materialized. The live-set regression test pins the
    /// contrast.
    pub fn job_slots(&self) -> usize {
        self.jobs.slot_high_water()
    }

    /// Jobs that reached a terminal state (completed + cancelled),
    /// counted at retirement.
    pub fn retired_jobs(&self) -> usize {
        self.totals.retired()
    }

    /// Drain the structural-event log accumulated since the last call —
    /// the per-event progress stream a driver prints between
    /// `run_until` slices.
    pub fn take_log(&mut self) -> Vec<LogEntry> {
        std::mem::take(&mut self.log)
    }

    /// Replay a [`WorkloadSpec`] into this session: submit its seeded
    /// arrival trace (job ids are assigned sequentially in submission
    /// order, so a fresh runtime sees `JobId(0..jobs)`), schedule its
    /// cancels (by submission index) and its health events. Returns
    /// the sorted, deduplicated external-event times — the natural
    /// `run_until` boundaries for a streaming driver. Single
    /// implementation shared by the CLI, the workload bench and the
    /// integration tests, so the replay semantics cannot diverge.
    pub fn load_workload(&mut self, spec: &WorkloadSpec) -> Result<Vec<SimTime>> {
        spec.validate()?;
        let mut boundaries = Vec::new();
        let mut ids = Vec::new();
        for (at_secs, job) in spec.arrivals() {
            let at = SimTime::from_secs_f64(at_secs);
            ids.push(self.submit_at(at, job)?);
            boundaries.push(at);
        }
        for c in &spec.cancels {
            let id = *ids
                .get(c.job)
                .ok_or_else(|| {
                    anyhow::anyhow!("cancel references job {} but only {} arrive", c.job, ids.len())
                })?;
            let at = SimTime::from_secs_f64(c.at_secs);
            self.cancel(id, at)?;
            boundaries.push(at);
        }
        for f in &spec.faults {
            let at = SimTime::from_secs_f64(f.at_secs);
            self.inject_degradation(at, f.device, f.factor);
            boundaries.push(at);
        }
        for c in &spec.crashes {
            let at = SimTime::from_secs_f64(c.at_secs);
            self.inject_crash(at, c.device);
            boundaries.push(at);
        }
        boundaries.sort_unstable();
        boundaries.dedup();
        Ok(boundaries)
    }

    /// Process every event up to and including simulated time `t`. The
    /// clock stops at the last event processed (never beyond the final
    /// completion), so slicing a session into `run_until` calls — at
    /// any boundaries — is bit-identical to draining it in one call.
    pub fn run_until(&mut self, t: SimTime) -> Result<()> {
        self.pump(Some(t))
    }

    /// Drive the session until no event is pending. Errors if arrived
    /// jobs can never be admitted (demand exceeds the pool).
    pub fn run_until_idle(&mut self) -> Result<()> {
        self.pump(None)?;
        if let Some(q) = self.queue.iter().find(|q| q.spec.num_csds > self.pool.len()) {
            bail!(
                "{} demands {} CSDs but the pool has {}",
                q.id,
                q.spec.num_csds,
                self.pool.len()
            );
        }
        ensure!(
            self.queue.is_empty(),
            "{} job(s) were never admitted (pool too small for their combined demands)",
            self.queue.len()
        );
        ensure!(
            self.jobs.values().all(|j| j.state.is_terminal()),
            "internal: event queue drained with jobs still running"
        );
        Ok(())
    }

    /// The core event loop, bounded by `until` (inclusive) when given.
    fn pump(&mut self, until: Option<SimTime>) -> Result<()> {
        loop {
            if self.cfg.fast_forward {
                self.fast_forward(until)?;
            }
            let Some(at) = self.events.peek_time() else { break };
            if until.is_some_and(|u| at > u) {
                break;
            }
            let ev = self.events.pop().expect("peeked a pending event");
            if !matches!(ev.payload, FleetEvent::StepDone { .. }) {
                self.external_fired(ev.at);
            }
            // External events landing on an idle chassis mutate state
            // but must not stretch the fleet timeline (makespan and
            // overhead end with the last job) — arrivals excepted, they
            // re-start it.
            let idle = self.queue.is_empty()
                && self.jobs.values().all(|j| j.state.is_terminal());
            match ev.payload {
                FleetEvent::Degrade { device, factor } if idle => {
                    ensure!(device < self.pool.len(), "no device {device} in the pool");
                    let health = self.pool.degrade(device, factor)?;
                    self.log_fault(ev.at, device, factor, health);
                    continue;
                }
                // A crash on an idle chassis swaps the module (state
                // mutation, logged at the crash instant) without
                // stretching the timeline.
                FleetEvent::Crash { device } if idle => {
                    self.crash_idle_bay(ev.at, device)?;
                    continue;
                }
                // A cancel for a job that already finished (still in
                // the table or retired out of it) is a no-op — it must
                // not stretch the timeline.
                FleetEvent::Cancel { job } if self.job_settled(job) => {
                    continue;
                }
                _ => {}
            }
            self.advance_overhead(ev.at);
            self.now = ev.at;
            match ev.payload {
                FleetEvent::StepDone { job } => self.on_step_done(job)?,
                FleetEvent::Arrive { job } => self.on_arrive(job)?,
                FleetEvent::Cancel { job } => self.on_cancel(job)?,
                FleetEvent::Degrade { device, factor } => self.on_degrade(device, factor)?,
                FleetEvent::Crash { device } => self.on_crash(device)?,
            }
            // A tunnel link that exhausted its retry ladder during this
            // event's traffic escalates to a bay crash at the same
            // instant (the final attempt went through, so nothing
            // deadlocks — the bay just doesn't survive it).
            self.process_link_faults()?;
            // Every path that wears flash (admission layout, rebalance
            // movement, legacy per-step staging, retry relocations) runs
            // inside an event handler, so end-of-life is only reachable
            // here — a safe point where no step booking is in flight.
            self.process_eol()?;
            // Surface any buffered ledger write error at a
            // deterministic point (the append itself is infallible so
            // retirement control flow is ledger-independent).
            if let Some(w) = &self.ledger {
                w.check()?;
            }
            // The guard: with `audit` on, every component re-proves its
            // invariants after every event — read-only, so the session
            // stays bit-identical to an unaudited one.
            if self.cfg.audit {
                self.full_audit()?;
            }
        }
        Ok(())
    }

    // ---- external-event bookkeeping ----------------------------------

    fn external_scheduled(&mut self, at: SimTime) {
        *self.externals.entry(at).or_insert(0) += 1;
    }

    fn external_fired(&mut self, at: SimTime) {
        if let Some(n) = self.externals.get_mut(&at) {
            if *n > 1 {
                *n -= 1;
                return;
            }
        }
        self.externals.remove(&at);
    }

    /// Earliest pending external event — the fast-forward horizon.
    fn next_external(&self) -> Option<SimTime> {
        self.externals.keys().next().copied()
    }

    fn log_fault(&mut self, at: SimTime, device: usize, factor: f64, health: f64) {
        let event = if factor > 1.0 {
            RuntimeEvent::Repaired { device, factor, health }
        } else {
            RuntimeEvent::Degraded { device, factor, health }
        };
        self.log.push(LogEntry { at, event });
    }

    /// Every [`Auditable`] component registered with the runtime, in
    /// fingerprint order. Single source for [`FleetRuntime::full_audit`]
    /// and [`FleetRuntime::fingerprint`], so the audited surface and
    /// the fingerprinted surface can never drift apart.
    fn auditables(&self) -> [&dyn Auditable; 4] {
        [&self.events, &self.pool, &self.plane, &self.jobs]
    }

    /// Re-check every registered component's invariants plus the
    /// runtime's own cross-component ledgers (DESIGN.md
    /// §Static-Analysis). Read-only: running it (or not) never changes
    /// a result bit. With [`FleetConfig::audit`] it runs after every
    /// processed event, so a latent corruption errors out at the first
    /// event that exhibits it — and a bit-identity failure bisects to
    /// the first divergent event via [`FleetRuntime::fingerprint`].
    pub fn full_audit(&self) -> Result<()> {
        for c in self.auditables() {
            c.audit().with_context(|| {
                format!("full audit: component '{}' failed at {}", c.component(), self.now)
            })?;
        }
        // The ledger writer audits here but is NOT in `auditables()`:
        // that array also feeds the fingerprint, and ledger-on/off runs
        // must fingerprint identically. Its audit is still read-only
        // (footer re-reads), so bit-identity holds either way.
        if let Some(w) = &self.ledger {
            w.audit().with_context(|| {
                format!("full audit: component '{}' failed at {}", w.component(), self.now)
            })?;
        }
        // Cross-component: the live counter matches the table.
        let live = self.jobs.values().filter(|j| !j.state.is_terminal()).count();
        ensure!(
            live == self.live_jobs,
            "live-job counter {} but the table holds {live} non-terminal job(s)",
            self.live_jobs
        );
        ensure!(
            self.peak_live_jobs >= self.live_jobs,
            "peak_live_jobs {} below live_jobs {}",
            self.peak_live_jobs,
            self.live_jobs
        );
        // The host grant names a live job that actually holds it.
        if let Some(id) = self.host_held_by {
            let j = self
                .jobs
                .get(&id)
                .with_context(|| format!("host held by {id}, which is not in the table"))?;
            ensure!(j.holds_host, "host held by {id} but the job does not record it");
            ensure!(!j.state.is_terminal(), "host held by terminal {id}");
        }
        // Id monotonicity: nothing tracked was assigned past the cursor.
        for id in self.arrivals.keys() {
            ensure!(*id < self.next_id, "pending arrival job{id} >= id cursor {}", self.next_id);
        }
        for q in &self.queue {
            ensure!(q.id.0 < self.next_id, "queued {} >= id cursor {}", q.id, self.next_id);
        }
        Ok(())
    }

    /// Deterministic FNV-1a digest of the session's observable state:
    /// the clock, the admission pipeline, the retired-job accumulators
    /// and every registered component (`FleetRuntime::auditables`).
    /// Two equivalent executions (fast-forward vs per-step, streaming
    /// vs retained at matched visibility, audit on vs off, any
    /// `run_until` slicing at the same instant) must produce the same
    /// value — compare per event to bisect a bit-identity failure to
    /// the first divergent event. The drained [`FleetRuntime::take_log`]
    /// stream is deliberately excluded: it is a consumable, not state.
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv64::new();
        h.write_u64(self.now.as_ns());
        h.write_u64(self.next_id);
        h.write_usize(self.live_jobs);
        h.write_usize(self.peak_live_jobs);
        h.write_usize(self.devices_replaced);
        h.write_bool(self.host_held_by.is_some());
        if let Some(id) = self.host_held_by {
            h.write_u64(id.0);
        }
        h.write_usize(self.arrivals.len());
        for (id, a) in &self.arrivals {
            h.write_u64(*id);
            h.write_u64(a.at.as_ns());
        }
        h.write_usize(self.queue.len());
        for q in &self.queue {
            h.write_u64(q.id.0);
            h.write_u64(q.submitted_at.as_ns());
        }
        self.totals.fingerprint(&mut h);
        h.write_u64(self.retired_wear.erases);
        h.write_u64(self.retired_wear.retired_blocks);
        h.write_u64(self.retired_ecc.pages);
        h.write_u64(self.retired_ecc.uncorrectable);
        for c in self.auditables() {
            h.write_str(c.component());
            c.fingerprint(&mut h);
        }
        h.finish()
    }

    /// Session summary (see [`FleetReport::jobs`] for what the per-job
    /// list holds in each mode). Totals are the retired-job
    /// accumulators plus the partial contributions of still-live jobs —
    /// the *same* accumulation order in both modes (terminal jobs in
    /// finish order, then live jobs in id order), so every f64 total is
    /// bit-identical whether or not terminal jobs were retained. Taking
    /// it mid-session yields a consistent partial view.
    pub fn report(&self) -> FleetReport {
        let jobs: Vec<JobReport> =
            self.jobs.values().map(|j| j.report(&self.cfg.power)).collect();
        let t = &self.totals;
        let mut total_images = t.images;
        let mut jobs_energy_j = t.energy_j;
        let mut bytes_moved = t.bytes_moved;
        let mut retunes = t.retunes;
        let mut checkpoint_bytes = t.checkpoint_bytes;
        let mut queue_wait = t.queue_wait.clone();
        let mut lock_wait = t.lock_wait.clone();
        for j in &jobs {
            if j.state.is_terminal() {
                continue; // retained mode: already absorbed at retirement
            }
            total_images += j.images;
            jobs_energy_j += j.energy_j;
            bytes_moved += j.bytes_moved;
            retunes += j.retunes;
            checkpoint_bytes += j.checkpoint_bytes;
            queue_wait.add(j.queue_wait.as_secs_f64());
            lock_wait.add(j.lock_wait.as_secs_f64());
        }
        let overhead_energy_j = self.overhead.total_joules();
        let secs = self.now.as_secs_f64();
        let (mut wear, mut ecc) = self.pool.wear_totals();
        wear.merge(self.retired_wear);
        ecc.merge(self.retired_ecc);
        FleetReport {
            makespan: self.now,
            total_images,
            aggregate_ips: if secs > 0.0 { total_images as f64 / secs } else { 0.0 },
            jobs_energy_j,
            overhead_energy_j,
            total_energy_j: jobs_energy_j + overhead_energy_j,
            link_bytes: self.tunnel.stats().bytes,
            bytes_moved,
            lock_wait,
            queue_wait,
            retunes,
            cancelled: t.cancelled,
            retired: t.retired(),
            peak_live_jobs: self.peak_live_jobs,
            drained: t.drained,
            crashed: t.crashed,
            lost_steps: t.lost_steps,
            checkpoint_bytes,
            link_retries: self.tunnel.stats().retries,
            devices_replaced: self.devices_replaced,
            wear,
            ecc,
            jobs,
        }
    }

    /// Terminal accounting, shared by every path that ends a job: fold
    /// the final report into the fleet totals, stream a
    /// [`RetiredRecord`] through the log, and — unless `retain_jobs` —
    /// drop the `Job`, freeing its slab slot for reuse. Running in both
    /// modes keeps the log sequence and every accumulator bit-identical
    /// across them; the only difference is whether the job outlives
    /// this call in the table.
    fn retire(&mut self, job: Job) {
        debug_assert!(job.state.is_terminal(), "retiring a non-terminal job");
        let report = job.report(&self.cfg.power);
        self.totals.absorb(&report);
        let record = RetiredRecord { retired_at: self.now, report };
        // Ledger append before the log push: the appended frame is a
        // pure function of the record, and `append` is infallible
        // (errors buffer until the next `pump` check), so control flow
        // from here on is identical with the ledger on or off.
        if let Some(w) = &mut self.ledger {
            w.append(&record);
        }
        self.log.push(LogEntry { at: self.now, event: RuntimeEvent::Retired { record: Box::new(record) } });
        if self.cfg.retain_jobs {
            self.jobs.insert(job);
        }
    }

    /// Seal the ledger's open tail segment so the directory is a
    /// complete, queryable ledger (DESIGN.md §Ledger). Called by the
    /// trace drivers and the batch [`Fleet`] façade when a session
    /// drains; a no-op without a ledger. Sealing is a safe point, not
    /// a terminal state — later retirements open a fresh segment.
    pub fn seal_ledger(&mut self) -> Result<()> {
        match &mut self.ledger {
            Some(w) => w.finish(),
            None => Ok(()),
        }
    }

    /// Integrate shared-chassis power (base, idle bays, idle host) over
    /// the interval between events — the piece of Table II's meter no
    /// single job owns.
    fn advance_overhead(&mut self, to: SimTime) {
        if to <= self.now {
            return;
        }
        let dt = to - self.now;
        let pw = &self.cfg.power;
        self.overhead.add_power("base", pw.base_w, dt);
        self.overhead
            .add_power("idle_storage", self.pool.free_count() as f64 * pw.storage_idle_w, dt);
        if self.host_held_by.is_none() {
            self.overhead.add_power("host_idle", pw.host_idle_w, dt);
        }
    }

    /// An arrival fired: the job joins the admission queue. Same-time
    /// arrivals are admitted in one pass (deferred to the last of the
    /// instant), so jobs arriving together see the same co-tenant count
    /// — exactly the batch coordinator's symmetric contention pricing.
    fn on_arrive(&mut self, id: JobId) -> Result<()> {
        let a = self.arrivals.remove(&id.0).expect("Arrive event for unknown job");
        self.log.push(LogEntry {
            at: self.now,
            event: RuntimeEvent::Arrived {
                job: id,
                network: a.spec.network.clone(),
                num_csds: a.spec.num_csds,
                include_host: a.spec.include_host,
            },
        });
        self.queue.push_back(QueuedJob { id, spec: a.spec, submitted_at: self.now });
        if self.arrivals.values().any(|p| p.at == self.now) {
            return Ok(()); // a sibling arrival at this instant runs the pass
        }
        self.try_admit()
    }

    /// FIFO admission with backfill: admit every queued job whose
    /// device-group (and host) demand fits the currently free pool.
    /// First steps are scheduled only after the whole admission pass,
    /// so jobs admitted at the same instant see the same co-tenant
    /// count (symmetric contention pricing).
    fn try_admit(&mut self) -> Result<()> {
        let mut admitted = Vec::new();
        let mut i = 0;
        while i < self.queue.len() {
            let fits = {
                let q = &self.queue[i];
                (!q.spec.include_host || self.host_held_by.is_none())
                    && self.pool.free_count() >= q.spec.num_csds
            };
            if !fits {
                i += 1;
                continue;
            }
            let q = self.queue.remove(i).expect("index in bounds");
            admitted.push(self.admit(q)?);
        }
        for id in admitted {
            self.schedule_step(id)?;
        }
        Ok(())
    }

    /// Algorithm 1 at the group's slowest health. Host-only jobs keep
    /// their configured batch (the paper's 0-CSD baseline has nothing
    /// to equalize against).
    fn tune_group(
        &self,
        spec: &ExperimentConfig,
        group_health: f64,
    ) -> Result<(usize, usize)> {
        if spec.num_csds == 0 {
            return Ok((spec.bs_csd.max(1), spec.bs_host.max(1)));
        }
        let mut model = PerfModel::with_scales(1.0, group_health);
        let r = tune(&mut model, &spec.network, &self.cfg.tune)?;
        let bs_host = if spec.include_host { r.host_bs } else { spec.bs_host.max(1) };
        Ok((r.newport_bs, bs_host))
    }

    fn admit(&mut self, q: QueuedJob) -> Result<JobId> {
        let net = NetId::resolve(&q.spec.network)?;
        let devices = self
            .pool
            .carve(q.spec.num_csds, q.id)
            .expect("try_admit checked the free count");
        let holds_host = q.spec.include_host;
        if holds_host {
            self.host_held_by = Some(q.id);
        }
        let group_health = self.pool.group_health(&devices);
        let (bs_csd, bs_host) = self.tune_group(&q.spec, group_health)?;
        // Health-weighted Eq. 1: the public top-up lands on the
        // healthiest devices first, which is what a later degradation
        // re-deals — producing the physical shard delta the data plane
        // then moves.
        let health: Vec<f64> = devices.iter().map(|&d| self.pool.health(d)).collect();
        let (dataset, placement) =
            provision_placement_weighted(&q.spec, bs_csd, bs_host, &health)?;
        if self.cfg.stage_io && !self.cfg.data_plane {
            for &d in &devices {
                self.pool.preload(d, PRELOADED_PAGES, self.now)?;
            }
        }
        self.log.push(LogEntry {
            at: self.now,
            event: RuntimeEvent::Admitted {
                job: q.id,
                devices: devices.clone(),
                holds_host,
                bs_csd,
                bs_host,
            },
        });
        let mut job = Job {
            id: q.id,
            net,
            state: JobState::Running,
            devices,
            holds_host,
            bs_csd,
            bs_host,
            steps_per_epoch: placement.steps_per_epoch,
            images_target: 0,
            images_done: 0,
            steps_done: 0,
            retunes: 0,
            submitted_at: q.submitted_at,
            admitted_at: self.now,
            finished_at: SimTime::ZERO,
            sync_time: SimTime::ZERO,
            link_bytes: 0,
            flash_reads: 0,
            flash_progs: 0,
            staged_host_bytes: 0,
            moved_bytes: 0,
            moved_images: 0,
            lock_wait: SimTime::ZERO,
            stage_ready: self.now,
            staging: Default::default(),
            meter: EnergyMeter::new(),
            drained: false,
            crashed: false,
            ckpt_steps: 0,
            ckpt_bytes: 0,
            lost_steps: 0,
            pending: None,
            data_cursor: 0,
            spec: q.spec,
        };
        job.images_target = job.spec.steps.max(1) * job.images_per_step();
        let id = job.id;
        if self.cfg.data_plane {
            // Install the physical shard map (flash-page layout under
            // the host's EX lock) and measure the first window's
            // staging plan; the first step starts once layout is done.
            let before = self.tunnel.stats();
            let cost = self.plane.admit(
                id,
                dataset,
                &placement,
                &job.devices,
                holds_host,
                bs_csd,
                bs_host,
                net.sync_bytes() as u64,
                self.cfg.activation_bytes_per_image(),
                &mut self.pool,
                &mut self.tunnel,
                self.now,
            )?;
            let after = self.tunnel.stats();
            job.flash_progs += cost.pages_written;
            job.link_bytes += after.bytes - before.bytes;
            job.lock_wait += cost.lock_wait;
            job.stage_ready = cost.ready;
            job.staging = self.plane.staging(id).clone();
        }
        self.jobs.insert(job);
        self.live_jobs += 1;
        self.peak_live_jobs = self.peak_live_jobs.max(self.live_jobs);
        Ok(id)
    }

    /// Ring domains currently active (incl. the caller's) — co-tenants
    /// sharing the host root's packetization budget.
    fn running_ring_jobs(&self) -> usize {
        self.jobs
            .values()
            .filter(|j| {
                j.state == JobState::Running
                    && j.devices.len() + usize::from(j.holds_host) > 1
            })
            .count()
            .max(1)
    }

    /// Book one synchronous step for `id` starting at `self.now` (or
    /// the job's data-plane `stage_ready`, if later): per-device
    /// staging + compute (health-scaled), host staging + compute if
    /// held, then the job's own ring-allreduce domain.
    ///
    /// With the data plane on, staging is charged from the job's
    /// window-constant [`StepStaging`](super::dataplane::StepStaging)
    /// plan — pure data, no hardware state — so steps inside a window
    /// are exact repeats and the fast-forward stays bit-identical.
    fn schedule_step(&mut self, id: JobId) -> Result<()> {
        let (devices, holds_host, bs_csd, bs_host, net, data_cursor, images, stage_ready) = {
            let j = self.jobs.get(&id).expect("job exists");
            (
                j.devices.clone(),
                j.holds_host,
                j.bs_csd,
                j.bs_host,
                j.net,
                j.data_cursor,
                j.images_per_step(),
                j.stage_ready,
            )
        };
        // Take the window plan out of the job for the booking (no
        // per-step clone; restored below with the pending step).
        let staging = if self.cfg.data_plane {
            let j = self.jobs.get_mut(&id).expect("job exists");
            Some(std::mem::take(&mut j.staging))
        } else {
            None
        };
        let sharers = self.running_ring_jobs();
        let sync_bytes = net.sync_bytes();
        let now = self.now.max(stage_ready);
        let mut compute_done = now;
        let mut flash_reads = 0u64;
        let mut host_bytes = 0u64;
        if let Some(st) = &staging {
            flash_reads = st.flash_reads;
            host_bytes = st.host_bytes;
        }
        for (i, &d) in devices.iter().enumerate() {
            let health = self.pool.health(d);
            let compute = PerfModel::with_scales(1.0, health)
                .step_time_id(Device::NewportIsp, net, bs_csd)?;
            let done = if let Some(st) = &staging {
                now + st.stage[i] + compute
            } else if self.cfg.stage_io {
                // Scratch-free: a wrapping LPN range over the preloaded
                // pages replaces the old per-step `Vec<u32>` build.
                let ppi = self
                    .cfg
                    .image_bytes
                    .div_ceil(self.pool.device(d).page_bytes())
                    .max(1);
                let count = (bs_csd * ppi) as u32;
                flash_reads += count as u64;
                self.pool.device_mut(d).isp_train_step_range(
                    data_cursor,
                    count,
                    PRELOADED_PAGES,
                    compute,
                    sync_bytes as u64,
                    self.cfg.activation_bytes_per_image(),
                    bs_csd,
                    now,
                )?
            } else {
                now + compute
            };
            compute_done = compute_done.max(done);
        }
        if holds_host {
            let host_compute =
                PerfModel::default().step_time_id(Device::HostXeon, net, bs_host)?;
            let host_stage = staging.as_ref().map_or(SimTime::ZERO, |st| st.host_stage);
            compute_done = compute_done.max(now + host_stage + host_compute);
        }
        let ranks: Vec<NodeId> = holds_host
            .then_some(NodeId::Host)
            .into_iter()
            .chain(devices.iter().map(|&d| NodeId::Csd(d)))
            .collect();
        let stats_before = self.tunnel.stats();
        let sync_end = if ranks.len() > 1 {
            ring_time_shared(&mut self.tunnel, &ranks, sync_bytes, compute_done, sharers)
        } else {
            compute_done
        };
        let stats_after = self.tunnel.stats();
        let event = self.events.schedule(sync_end, FleetEvent::StepDone { job: id });
        let j = self.jobs.get_mut(&id).expect("job exists");
        if let Some(st) = staging {
            j.staging = st;
        }
        j.data_cursor = j.data_cursor.wrapping_add(37);
        j.pending = Some(PendingStep {
            event,
            start: now,
            end: sync_end,
            sync: sync_end - compute_done,
            link_bytes: stats_after.bytes - stats_before.bytes,
            link_msgs: stats_after.messages - stats_before.messages,
            flash_reads,
            host_bytes,
            images,
        });
        Ok(())
    }

    fn on_step_done(&mut self, id: JobId) -> Result<()> {
        let finished = {
            let pw = &self.cfg.power;
            let now = self.now;
            let j = self.jobs.get_mut(&id).expect("StepDone for unknown job");
            let p = j.pending.take().expect("StepDone without a pending step");
            commit_steps(j, pw, &p, 1);
            if j.images_done >= j.images_target {
                j.state = JobState::Completed;
                j.finished_at = now;
                true
            } else {
                false
            }
        };
        if finished {
            self.pool.release(id);
            self.plane.complete(id);
            if self.host_held_by == Some(id) {
                self.host_held_by = None;
            }
            let job = self.jobs.remove(&id).expect("StepDone for unknown job");
            self.live_jobs -= 1;
            self.log.push(LogEntry {
                at: self.now,
                event: RuntimeEvent::Completed { job: id, images: job.images_done },
            });
            self.retire(job);
            self.try_admit()
        } else {
            self.maybe_checkpoint(id)?;
            self.schedule_step(id)
        }
    }

    /// Abandon `id`'s in-flight step (mid-step teardown or re-tune):
    /// its compute is lost — no images/steps are credited — but the
    /// power burned so far and the traffic already booked on the device
    /// and fabric ledgers stay attributed to the job, keeping fleet
    /// totals equal to the per-job sums across faults and cancels.
    fn abandon_step(&mut self, id: JobId) {
        let pw = &self.cfg.power;
        let now = self.now;
        let j = self.jobs.get_mut(&id).expect("job exists");
        let Some(p) = j.pending.take() else { return };
        let dt = now.saturating_sub(p.start);
        j.meter.add_power(
            "newport",
            j.devices.len() as f64 * (pw.newport_idle_w + pw.newport_isp_active_w),
            dt,
        );
        if j.holds_host {
            j.meter.add_power("host", pw.host_active_w, dt);
        }
        j.link_bytes += p.link_bytes;
        j.flash_reads += p.flash_reads;
        j.staged_host_bytes += p.host_bytes;
        self.events.cancel(p.event);
    }

    /// A cancel fired: tear the job down wherever it is in its
    /// lifecycle (pending arrival, queued, or running).
    fn on_cancel(&mut self, id: JobId) -> Result<()> {
        // Not yet arrived: drop the scheduled arrival and record a
        // zero-progress cancelled job. The stub retires immediately —
        // it was never admitted, so there is nothing to release.
        if let Some(a) = self.arrivals.remove(&id.0) {
            self.events.cancel(a.event);
            self.external_fired(a.at);
            let job = cancelled_stub(id, a.spec, a.at.min(self.now), self.now)?;
            self.log.push(LogEntry {
                at: self.now,
                event: RuntimeEvent::Cancelled { job: id, images: 0, freed_pages: 0 },
            });
            self.retire(job);
            return Ok(());
        }
        // Arrived but never admitted: dequeue.
        if let Some(pos) = self.queue.iter().position(|q| q.id == id) {
            let q = self.queue.remove(pos).expect("position in bounds");
            let job = cancelled_stub(id, q.spec, q.submitted_at, self.now)?;
            self.log.push(LogEntry {
                at: self.now,
                event: RuntimeEvent::Cancelled { job: id, images: 0, freed_pages: 0 },
            });
            self.retire(job);
            return Ok(());
        }
        let Some(j) = self.jobs.get(&id) else {
            // Already retired out of the table (streaming default):
            // the cancel landed after the job's natural completion —
            // a no-op, same as the terminal-in-table race below.
            // `cancel` validated the id at schedule time, so a truly
            // unknown id here is an internal error.
            ensure!(id.0 < self.next_id, "internal: Cancel event for unknown {id}");
            return Ok(());
        };
        if j.state.is_terminal() {
            return Ok(()); // raced with completion: no-op
        }
        // Running: abandon the in-flight step, tear down the shard map
        // under the DLM lock, release the carve.
        self.abandon_step(id);
        let freed = if self.cfg.data_plane {
            let before = self.tunnel.stats();
            let cost = self.plane.cancel(id, &mut self.pool, &mut self.tunnel, self.now)?;
            let after = self.tunnel.stats();
            let j = self.jobs.get_mut(&id).expect("job exists");
            j.link_bytes += after.bytes - before.bytes;
            j.lock_wait += cost.lock_wait;
            cost.pages_written
        } else {
            0
        };
        let j = self.jobs.get_mut(&id).expect("job exists");
        j.state = JobState::Cancelled;
        j.finished_at = self.now;
        self.pool.release(id);
        if self.host_held_by == Some(id) {
            self.host_held_by = None;
        }
        let job = self.jobs.remove(&id).expect("job exists");
        self.live_jobs -= 1;
        self.log.push(LogEntry {
            at: self.now,
            event: RuntimeEvent::Cancelled {
                job: id,
                images: job.images_done,
                freed_pages: freed,
            },
        });
        self.retire(job);
        // The released carve (and host) may admit queued jobs.
        self.try_admit()
    }

    /// Advance every running job to just before the next *structural*
    /// event — the earliest completion, pending external event
    /// (arrival, cancel, fault) or `until` horizon — in one closed-form
    /// jump, instead of scheduling each intermediate step.
    ///
    /// Legal because, inside such a window, a job's steps are exact
    /// repeats: compute times are pure functions of (health, net,
    /// batch), the fluid ring model is shift-invariant and stateless
    /// (beyond its byte ledger), and the co-tenant count is frozen.
    /// Each job's last pre-window-end step stays a real event, so
    /// completions, admissions, cancellations and health events still
    /// run through the ordinary per-step machinery. No-op (exact
    /// fallback to per-step) when the *legacy* per-step flash staging
    /// is on — its FTL/timeline state makes steps non-repeating. The
    /// data plane is fast-forward-safe: its staged-read charge is a
    /// window constant and every stateful booking (layout, movement,
    /// locks, teardown) happens at structural events, which both
    /// executors run identically.
    fn fast_forward(&mut self, until: Option<SimTime>) -> Result<()> {
        if self.cfg.stage_io && !self.cfg.data_plane {
            return Ok(());
        }
        // Transient link faults draw one RNG value per tunnel hop, so
        // sends are stateful and steps stop being exact repeats — the
        // closed-form jump would book a different draw sequence than
        // the per-step path. Armed faults fall back to the reference
        // executor; off, this branch never taken.
        if self.tunnel.link_faults_armed() {
            return Ok(());
        }
        // Scan phase: per running job, the in-flight step's period and
        // the projected completion time at one step per period.
        struct Window {
            id: JobId,
            period: SimTime,
            end: SimTime,
            skip: u64,
        }
        let mut windows: Vec<Window> = Vec::new();
        let mut horizon = self.next_external();
        if let Some(u) = until {
            horizon = Some(horizon.map_or(u, |h| h.min(u)));
        }
        for j in self.jobs.values() {
            if j.state != JobState::Running {
                continue;
            }
            let Some(p) = &j.pending else { return Ok(()) };
            let period = p.end - p.start;
            if period == SimTime::ZERO || p.images == 0 {
                return Ok(()); // degenerate config: keep the reference path
            }
            let remaining = (j.images_target - j.images_done).div_ceil(p.images) as u64;
            let finish = p.end + period * (remaining - 1);
            horizon = Some(horizon.map_or(finish, |h| h.min(finish)));
            windows.push(Window { id: j.id, period, end: p.end, skip: 0 });
        }
        let Some(w_end) = horizon else { return Ok(()) };
        // Steps that END strictly before the window end are skippable;
        // the step ending at (or beyond) it remains in-flight.
        let ck_interval = self.cfg.checkpoint.interval_steps;
        for w in &mut windows {
            if w.end < w_end {
                // Ends at end, end+period, ...: how many land before
                // w_end — i.e. ceil(span / period).
                let span = w_end - w.end;
                w.skip = span.as_ns().div_ceil(w.period.as_ns());
            }
            if ck_interval > 0 {
                // Checkpoint steps must stay real events — the
                // checkpoint I/O runs in `on_step_done`, which skipped
                // steps never reach. The in-flight step is number
                // `steps_done + 1`, so at most the steps up to (but not
                // including) the next checkpoint multiple may be
                // committed in closed form.
                let done = self.jobs.get(&w.id).expect("job exists").steps_done as u64;
                w.skip = w.skip.min(ck_interval - 1 - done % ck_interval);
            }
        }
        windows.retain(|w| w.skip > 0);
        if windows.is_empty() {
            return Ok(());
        }
        // Re-schedule in the order the per-step path would have
        // scheduled the surviving steps: by their (virtual) start time;
        // at equal starts the longer period was scheduled earlier (its
        // predecessor fired first); full ties keep the existing seq
        // order. This reproduces the deterministic FIFO tie-break of
        // the reference path.
        windows.sort_by_key(|w| {
            let start = w.end + w.period * w.skip - w.period;
            let j = self.jobs.get(&w.id).expect("job exists");
            let pending = j.pending.as_ref().expect("scanned above");
            (start, Reverse(w.period), self.events.seq_of(pending.event))
        });
        let pw = &self.cfg.power;
        for w in &windows {
            let j = self.jobs.get_mut(&w.id).expect("job exists");
            let p = j.pending.take().expect("scanned above");
            commit_steps(j, pw, &p, w.skip);
            // Mirror the data-cursor advance of the skipped
            // `schedule_step` calls (unobservable with staging off, but
            // keeps the cursor phase identical if configs evolve).
            j.data_cursor = j.data_cursor.wrapping_add(37u32.wrapping_mul(w.skip as u32));
            let shift = w.period * w.skip;
            // The skipped rings' traffic, credited on the fabric ledger
            // exactly as `ring_time_shared` would have.
            self.tunnel.note_aggregate(w.skip * p.link_msgs, w.skip * p.link_bytes);
            self.events.cancel(p.event);
            let event = self
                .events
                .schedule(p.end + shift, FleetEvent::StepDone { job: w.id });
            j.pending = Some(PendingStep {
                event,
                start: p.start + shift,
                end: p.end + shift,
                ..p
            });
        }
        Ok(())
    }

    /// Device health event: degrade (or repair) health; if a job holds
    /// the device and its effective speed changed, abandon its
    /// in-flight step (its compute is lost — no images/steps are
    /// credited), re-tune at the new slowest health and re-balance.
    /// Co-tenant jobs are not touched. The abandoned step's staged
    /// flash pages and ring traffic were already booked on the device
    /// and fabric ledgers, so their bytes and energy stay attributed to
    /// the job — keeping fleet totals equal to the per-job sums even
    /// across faults.
    fn on_degrade(&mut self, device: usize, factor: f64) -> Result<()> {
        ensure!(device < self.pool.len(), "no device {device} in the pool");
        let before = self.pool.health(device);
        let health = self.pool.degrade(device, factor)?;
        self.log_fault(self.now, device, factor, health);
        if health == before {
            return Ok(()); // clamped no-op (e.g. repairing a healthy bay)
        }
        let Some(id) = self.pool.assigned_job(device) else {
            return Ok(()); // unassigned bay: health change only
        };
        self.jobs.get_mut(&id).expect("assigned job exists").retunes += 1;
        self.abandon_step(id);
        let (devices, spec, holds_host, net) = {
            let j = self.jobs.get(&id).expect("assigned job exists");
            (j.devices.clone(), j.spec.clone(), j.holds_host, j.net)
        };
        let group_health = self.pool.group_health(&devices);
        let (bs_csd, bs_host) = self.tune_group(&spec, group_health)?;
        let health: Vec<f64> = devices.iter().map(|&d| self.pool.health(d)).collect();
        let (_dataset, placement) =
            provision_placement_weighted(&spec, bs_csd, bs_host, &health)?;
        {
            let j = self.jobs.get_mut(&id).expect("assigned job exists");
            j.bs_csd = bs_csd;
            if j.holds_host {
                j.bs_host = bs_host;
            }
            j.steps_per_epoch = placement.steps_per_epoch;
        }
        if self.cfg.data_plane {
            // The public-shard delta of the health-weighted re-balance
            // physically moves (flash read → tunnel relay → flash
            // write) under DLM EX locks; the next step starts once the
            // movement completes and the group has observed the new
            // journal version. All traffic inside the window is
            // attributed to the affected job, so fleet ledgers stay
            // conservative across faults.
            let before = self.tunnel.stats();
            let cost = self.plane.rebalance(
                id,
                &placement,
                holds_host,
                bs_csd,
                bs_host,
                net.sync_bytes() as u64,
                self.cfg.activation_bytes_per_image(),
                &mut self.pool,
                &mut self.tunnel,
                self.now,
            )?;
            let after = self.tunnel.stats();
            let staging = self.plane.staging(id).clone();
            let j = self.jobs.get_mut(&id).expect("assigned job exists");
            j.link_bytes += after.bytes - before.bytes;
            j.flash_reads += cost.pages_read;
            j.flash_progs += cost.pages_written;
            j.moved_bytes += cost.bytes_moved;
            j.moved_images += cost.images_moved;
            j.lock_wait += cost.lock_wait;
            j.stage_ready = cost.ready;
            j.staging = staging;
        }
        self.schedule_step(id)
    }

    /// Scan for worn-out bays and run the end-of-life pipeline on each
    /// (ascending device order, so the sequence is deterministic):
    /// drain the assigned job — cancel-style teardown, remaining steps
    /// resubmitted as a successor arriving at this instant — then swap
    /// the bay for a factory-fresh module and fold the retired module's
    /// wear/ECC history into the fleet accumulators. Runs after every
    /// event; O(1) (and unreachable) with endurance off, because
    /// `pe_limit == 0` means no block ever retires.
    fn process_eol(&mut self) -> Result<()> {
        if self.cfg.csd.ftl.pe_limit == 0 {
            return Ok(());
        }
        let worn = self.pool.worn_devices();
        if worn.is_empty() {
            return Ok(());
        }
        for device in worn {
            // A drain earlier in this pass released the whole group but
            // cannot un-wear a device, so no re-check is needed — each
            // listed bay is still worn and gets replaced exactly once.
            if let Some(id) = self.pool.assigned_job(device) {
                self.drain_job(id, device)?;
            } else {
                self.log.push(LogEntry {
                    at: self.now,
                    event: RuntimeEvent::WornOut {
                        device,
                        job: None,
                        successor: None,
                        freed_pages: 0,
                    },
                });
            }
            let (wear, ecc) = self.pool.replace(device, &self.cfg.csd)?;
            self.retired_wear.merge(wear);
            self.retired_ecc.merge(ecc);
            self.devices_replaced += 1;
            self.log.push(LogEntry {
                at: self.now,
                event: RuntimeEvent::Replaced {
                    device,
                    generation: self.pool.generation(device),
                    retired_blocks: wear.retired_blocks,
                    erases: wear.erases,
                },
            });
        }
        // The freed carve (and the fresh bay) may admit queued jobs;
        // the resubmitted successors join the queue via their Arrive
        // events at this same instant and are admitted FIFO — the
        // retry/backoff when the pool is momentarily full.
        self.try_admit()
    }

    /// Tear `id` down because `device` (one of its bays) wore out:
    /// exactly the running-cancel teardown — abandon the in-flight
    /// step, trim the shard map under the DLM lock, release the carve —
    /// but marked `drained` and followed by resubmitting the job's
    /// remaining steps as a fresh arrival at the current instant.
    /// Returns the successor's id.
    fn drain_job(&mut self, id: JobId, device: usize) -> Result<JobId> {
        self.abandon_step(id);
        let freed = if self.cfg.data_plane {
            let before = self.tunnel.stats();
            let cost = self.plane.cancel(id, &mut self.pool, &mut self.tunnel, self.now)?;
            let after = self.tunnel.stats();
            let j = self.jobs.get_mut(&id).expect("drained job exists");
            j.link_bytes += after.bytes - before.bytes;
            j.lock_wait += cost.lock_wait;
            cost.pages_written
        } else {
            0
        };
        let successor_spec = {
            let j = self.jobs.get_mut(&id).expect("drained job exists");
            j.state = JobState::Cancelled;
            j.drained = true;
            j.finished_at = self.now;
            // Whole completed steps survive in the drained job's report;
            // the successor re-runs the remainder (at least one step —
            // re-tuning at its own admission may change images/step, so
            // step count is the resumption currency, like a checkpoint
            // interval).
            let steps_left = j.spec.steps.max(1).saturating_sub(j.steps_done).max(1);
            let mut spec = j.spec.clone();
            spec.steps = steps_left;
            spec
        };
        self.pool.release(id);
        if self.host_held_by == Some(id) {
            self.host_held_by = None;
        }
        let job = self.jobs.remove(&id).expect("drained job exists");
        self.live_jobs -= 1;
        let successor = self.submit_at(self.now, successor_spec)?;
        self.log.push(LogEntry {
            at: self.now,
            event: RuntimeEvent::WornOut {
                device,
                job: Some(id),
                successor: Some(successor),
                freed_pages: freed,
            },
        });
        self.retire(job);
        Ok(successor)
    }

    /// Periodic model-state checkpoint (DESIGN.md §Crash-Recovery),
    /// run after each completed non-final step: when the step count
    /// hits a multiple of `interval_steps`, the job writes its model
    /// state as whole flash extents on every group device through the
    /// data plane (real modeled I/O, charged on the device timelines),
    /// optionally copies one replica to the host over the tunnel, and
    /// records the covered step count as its resumption point. The
    /// next step starts no earlier than the checkpoint completes.
    /// No-op with checkpointing off; with the data plane off there is
    /// no extent path to write through, so the checkpoint degrades to
    /// the host copy (if requested) plus the resumption-point marker.
    fn maybe_checkpoint(&mut self, id: JobId) -> Result<()> {
        let ck = self.cfg.checkpoint;
        if !ck.armed() {
            return Ok(());
        }
        let (steps_done, param_bytes, first_dev) = {
            let j = self.jobs.get(&id).expect("job exists");
            (j.steps_done, j.net.sync_bytes() as u64, j.devices.first().copied())
        };
        if steps_done as u64 % ck.interval_steps != 0 {
            return Ok(());
        }
        let (mut done, mut bytes, mut pages) = (self.now, 0u64, 0u64);
        if self.cfg.data_plane {
            let (flash_done, p, b) =
                self.plane.checkpoint(id, param_bytes, &mut self.pool, self.now)?;
            done = flash_done;
            pages = p;
            bytes = b;
        }
        let mut host_bytes = 0u64;
        if ck.host_copy {
            if let Some(d) = first_dev {
                done = self.tunnel.send(NodeId::Csd(d), NodeId::Host, param_bytes as usize, done);
                host_bytes = param_bytes;
                bytes += param_bytes;
            }
        }
        let j = self.jobs.get_mut(&id).expect("job exists");
        j.ckpt_steps = steps_done;
        j.ckpt_bytes += bytes;
        j.flash_progs += pages;
        j.link_bytes += host_bytes;
        j.stage_ready = j.stage_ready.max(done);
        self.log.push(LogEntry {
            at: self.now,
            event: RuntimeEvent::Checkpointed { job: id, steps: steps_done, bytes },
        });
        Ok(())
    }

    /// Drain the tunnel's exhausted-retry-ladder queue: each entry is a
    /// link whose last rung failed during the event just dispatched,
    /// and escalates to a crash of the corresponding bay at the current
    /// instant. The teardown traffic of one crash may itself exhaust
    /// further ladders; the loop drains those too (escalation order).
    /// Terminates because a freshly swapped bay carries no assigned
    /// job, so repeated crashes of the same link eventually stop
    /// generating traffic. O(1) with link faults off.
    fn process_link_faults(&mut self) -> Result<()> {
        while let Some(device) = self.tunnel.take_exhausted_link() {
            self.on_crash(device)?;
        }
        Ok(())
    }

    /// A crash landed on an idle chassis: swap the module and fold its
    /// history in (state mutation, logged at the crash instant) without
    /// advancing the clock — the fleet timeline must not stretch.
    fn crash_idle_bay(&mut self, at: SimTime, device: usize) -> Result<()> {
        ensure!(device < self.pool.len(), "no device {device} in the pool");
        self.log.push(LogEntry {
            at,
            event: RuntimeEvent::Crashed {
                device,
                job: None,
                successor: None,
                lost_steps: 0,
                freed_pages: 0,
            },
        });
        let (wear, ecc) = self.pool.replace(device, &self.cfg.csd)?;
        self.retired_wear.merge(wear);
        self.retired_ecc.merge(ecc);
        self.devices_replaced += 1;
        self.log.push(LogEntry {
            at,
            event: RuntimeEvent::Replaced {
                device,
                generation: self.pool.generation(device),
                retired_blocks: wear.retired_blocks,
                erases: wear.erases,
            },
        });
        Ok(())
    }

    /// A bay died abruptly (scheduled `--crash` fault or link-fault
    /// escalation). Unlike the graceful end-of-life drain, nothing on
    /// the module survives: the tenant's in-flight step is lost, any
    /// DLM locks the dead node held are force-released, and the tenant
    /// resumes from its last checkpoint (step 0 without one) rather
    /// than from its completed-step count. The bay itself is swapped
    /// for a factory-fresh module exactly like the EOL path.
    fn on_crash(&mut self, device: usize) -> Result<()> {
        ensure!(device < self.pool.len(), "no device {device} in the pool");
        if let Some(id) = self.pool.assigned_job(device) {
            self.crash_job(id, device)?;
        } else {
            self.log.push(LogEntry {
                at: self.now,
                event: RuntimeEvent::Crashed {
                    device,
                    job: None,
                    successor: None,
                    lost_steps: 0,
                    freed_pages: 0,
                },
            });
        }
        let (wear, ecc) = self.pool.replace(device, &self.cfg.csd)?;
        self.retired_wear.merge(wear);
        self.retired_ecc.merge(ecc);
        self.devices_replaced += 1;
        self.log.push(LogEntry {
            at: self.now,
            event: RuntimeEvent::Replaced {
                device,
                generation: self.pool.generation(device),
                retired_blocks: wear.retired_blocks,
                erases: wear.erases,
            },
        });
        self.try_admit()
    }

    /// Tear `id` down because `device` (one of its bays) crashed:
    /// cancel-style teardown — abandon the in-flight step, force-release
    /// the dead node's DLM state (journal-version bump), trim the shard
    /// map — then resubmit from the last checkpoint. Steps past the
    /// checkpoint were done but their state died with the module; they
    /// are ledgered as `lost_steps` and the successor redoes them.
    fn crash_job(&mut self, id: JobId, device: usize) -> Result<JobId> {
        self.abandon_step(id);
        let freed = if self.cfg.data_plane {
            let before = self.tunnel.stats();
            self.plane.force_release(&mut self.tunnel, NodeId::Csd(device), self.now);
            let cost = self.plane.cancel(id, &mut self.pool, &mut self.tunnel, self.now)?;
            let after = self.tunnel.stats();
            let j = self.jobs.get_mut(&id).expect("crashed job exists");
            j.link_bytes += after.bytes - before.bytes;
            j.lock_wait += cost.lock_wait;
            cost.pages_written
        } else {
            0
        };
        let (successor_spec, lost) = {
            let j = self.jobs.get_mut(&id).expect("crashed job exists");
            j.state = JobState::Cancelled;
            j.crashed = true;
            j.finished_at = self.now;
            // Resume from the checkpointed prefix: completed steps past
            // it are lost (redone by the successor), and with no
            // checkpoint the successor restarts from step 0. At least
            // one step always remains — the crash interrupted a running
            // job, so its final step had not committed.
            let ckpt = j.ckpt_steps.min(j.steps_done);
            j.lost_steps = j.steps_done - ckpt;
            let steps_left = j.spec.steps.max(1).saturating_sub(ckpt).max(1);
            let mut spec = j.spec.clone();
            spec.steps = steps_left;
            (spec, j.lost_steps)
        };
        self.pool.release(id);
        if self.host_held_by == Some(id) {
            self.host_held_by = None;
        }
        let job = self.jobs.remove(&id).expect("crashed job exists");
        self.live_jobs -= 1;
        let successor = self.submit_at(self.now, successor_spec)?;
        self.log.push(LogEntry {
            at: self.now,
            event: RuntimeEvent::Crashed {
                device,
                job: Some(id),
                successor: Some(successor),
                lost_steps: lost,
                freed_pages: freed,
            },
        });
        self.retire(job);
        Ok(successor)
    }
}

/// A zero-progress [`Job`] record for a job cancelled before it was
/// ever admitted — so the fleet report still carries one row per
/// submitted job.
fn cancelled_stub(
    id: JobId,
    spec: ExperimentConfig,
    submitted_at: SimTime,
    now: SimTime,
) -> Result<Job> {
    let net = NetId::resolve(&spec.network)?;
    Ok(Job {
        id,
        net,
        state: JobState::Cancelled,
        devices: Vec::new(),
        holds_host: false,
        bs_csd: spec.bs_csd.max(1),
        bs_host: spec.bs_host.max(1),
        steps_per_epoch: 0,
        images_target: 0,
        images_done: 0,
        steps_done: 0,
        retunes: 0,
        submitted_at,
        admitted_at: now,
        finished_at: now,
        sync_time: SimTime::ZERO,
        link_bytes: 0,
        flash_reads: 0,
        flash_progs: 0,
        staged_host_bytes: 0,
        moved_bytes: 0,
        moved_images: 0,
        lock_wait: SimTime::ZERO,
        stage_ready: now,
        staging: Default::default(),
        meter: EnergyMeter::new(),
        drained: false,
        crashed: false,
        ckpt_steps: 0,
        ckpt_bytes: 0,
        lost_steps: 0,
        pending: None,
        data_cursor: 0,
        spec,
    })
}

/// The legacy batch coordinator: a thin façade over [`FleetRuntime`]
/// that submits every job at t = 0, replays the fault schedule as
/// events and drives the session to idle in one blocking `run()`. The
/// online-vs-batch equivalence property (`integration_fleet`) pins the
/// two APIs bit-identical.
pub struct Fleet {
    rt: FleetRuntime,
    specs: Vec<ExperimentConfig>,
    faults: Vec<(SimTime, usize, f64)>,
    crashes: Vec<(SimTime, usize)>,
    /// Jobs handed to the runtime so far — keeps predicted ids aligned
    /// with the runtime's assignment even across repeated `run` calls.
    submitted: u64,
}

impl Fleet {
    pub fn new(mut cfg: FleetConfig) -> Self {
        // The batch façade's contract is a report enumerating every
        // submitted job — it IS the retained-everything oracle.
        cfg.retain_jobs = true;
        Self {
            rt: FleetRuntime::new(cfg),
            specs: Vec::new(),
            faults: Vec::new(),
            crashes: Vec::new(),
            submitted: 0,
        }
    }

    /// Enqueue a job (arrival at t = 0 when `run` starts). Demands come
    /// from the spec: `num_csds` devices, plus the host iff
    /// `include_host`.
    pub fn submit(&mut self, spec: ExperimentConfig) -> JobId {
        // Ids are assigned by the runtime in submission order at `run`.
        let id = JobId(self.submitted);
        self.submitted += 1;
        self.specs.push(spec);
        id
    }

    /// Schedule a device fault: at simulated time `at`, multiply
    /// `device`'s health by `factor` (0.6 = thermal throttle to 60%;
    /// `> 1` repairs, clamped at 1.0).
    pub fn inject_degradation(&mut self, at: SimTime, device: usize, factor: f64) {
        self.faults.push((at, device, factor));
    }

    /// Schedule an abrupt bay crash (DESIGN.md §Crash-Recovery),
    /// replayed as an event when `run` starts.
    pub fn inject_crash(&mut self, at: SimTime, device: usize) {
        self.crashes.push((at, device));
    }

    /// Run every submitted job to completion; returns the fleet report.
    pub fn run(&mut self) -> Result<FleetReport> {
        for q in &self.specs {
            ensure!(
                q.num_csds <= self.rt.pool.len(),
                "job demands {} CSDs but the pool has {}",
                q.num_csds,
                self.rt.pool.len()
            );
        }
        // First run: t = 0. Jobs submitted after a previous `run` keep
        // the old facade semantics of arriving at the current clock.
        for spec in self.specs.drain(..) {
            let now = self.rt.now();
            self.rt.submit_at(now, spec)?;
        }
        for &(at, device, factor) in &self.faults {
            self.rt.inject_degradation(at, device, factor);
        }
        self.faults.clear();
        for &(at, device) in &self.crashes {
            self.rt.inject_crash(at, device);
        }
        self.crashes.clear();
        self.rt.run_until_idle()?;
        self.rt.seal_ledger()?;
        Ok(self.rt.report())
    }

    /// The data plane's ledgers — populated only when
    /// `FleetConfig::data_plane` is on.
    pub fn data_plane(&self) -> &DataPlane {
        self.rt.data_plane()
    }

    /// The underlying session (e.g. to drain the structural-event log
    /// after a batch run).
    pub fn runtime(&mut self) -> &mut FleetRuntime {
        &mut self.rt
    }
}

/// Credit `k` completed repeats of the in-flight step `p` to `j` — the
/// single commit path shared by the per-step executor (`k = 1`) and the
/// fast-forward executor (`k = steps skipped`). All accumulators are
/// integers (`SimTime`, byte/step counts) or chop-invariant power
/// integrals, so `k` calls with 1 and 1 call with `k` book bit-identical
/// totals (DESIGN.md §Perf).
fn commit_steps(j: &mut Job, pw: &PowerConfig, p: &PendingStep, k: u64) {
    let dt = (p.end - p.start) * k;
    j.steps_done += k as usize;
    j.images_done += p.images * k as usize;
    j.sync_time += p.sync * k;
    j.link_bytes += p.link_bytes * k;
    j.flash_reads += p.flash_reads * k;
    j.staged_host_bytes += p.host_bytes * k;
    j.meter.add_power(
        "newport",
        j.devices.len() as f64 * (pw.newport_idle_w + pw.newport_isp_active_w),
        dt,
    );
    if j.holds_host {
        j.meter.add_power("host", pw.host_active_w, dt);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(network: &str, num_csds: usize, include_host: bool, steps: usize) -> ExperimentConfig {
        ExperimentConfig {
            network: network.into(),
            num_csds,
            include_host,
            steps,
            ..Default::default()
        }
    }

    #[test]
    fn single_job_fleet_completes_with_tuned_batches() {
        let mut fleet = Fleet::new(FleetConfig {
            total_csds: 3,
            stage_io: false,
            ..Default::default()
        });
        let id = fleet.submit(job("mobilenet_v2", 3, true, 4));
        let r = fleet.run().unwrap();
        assert_eq!(r.jobs.len(), 1);
        let j = &r.jobs[0];
        assert_eq!(j.id, id);
        assert_eq!(j.state, JobState::Completed);
        // Algorithm 1 ran at admission: paper Table I batches.
        assert_eq!(j.bs_csd, 25);
        assert!((j.bs_host as i64 - 315).unsigned_abs() <= 16, "host bs {}", j.bs_host);
        assert_eq!(j.steps_done, 4);
        assert_eq!(j.images, r.total_images);
        assert!(j.images_per_sec > 0.0);
        assert!(j.sync_fraction > 0.0 && j.sync_fraction < 1.0);
        assert_eq!(r.retunes, 0);
        assert_eq!(r.cancelled, 0);
    }

    #[test]
    fn host_only_job_runs_without_a_ring() {
        let mut fleet = Fleet::new(FleetConfig {
            total_csds: 2,
            stage_io: false,
            ..Default::default()
        });
        fleet.submit(job("mobilenet_v2", 0, true, 3));
        let r = fleet.run().unwrap();
        assert_eq!(r.jobs[0].sync_fraction, 0.0);
        assert_eq!(r.link_bytes, 0);
        assert_eq!(r.jobs[0].images, 3 * ExperimentConfig::default().bs_host);
    }

    #[test]
    fn oversized_job_is_rejected() {
        let mut fleet = Fleet::new(FleetConfig {
            total_csds: 2,
            stage_io: false,
            ..Default::default()
        });
        fleet.submit(job("mobilenet_v2", 5, false, 2));
        assert!(fleet.run().is_err());
    }

    #[test]
    fn fast_forward_matches_per_step_reference() {
        let run = |ff: bool| {
            let mut fleet = Fleet::new(FleetConfig {
                total_csds: 6,
                stage_io: false,
                fast_forward: ff,
                ..Default::default()
            });
            fleet.submit(job("mobilenet_v2", 3, true, 40));
            fleet.submit(job("squeezenet", 3, false, 25));
            // Mid-run fault on job 0's group: the window must stop at
            // the fault, re-tune, then fast-forward again.
            fleet.inject_degradation(SimTime::secs(100), 0, 0.7);
            fleet.run().unwrap()
        };
        let a = run(true);
        let b = run(false);
        assert_eq!(a.makespan, b.makespan, "makespan must be bit-identical");
        assert_eq!(a.total_images, b.total_images);
        assert_eq!(a.link_bytes, b.link_bytes);
        assert_eq!(a.retunes, b.retunes);
        assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.finished_at, y.finished_at);
            assert_eq!(x.steps_done, y.steps_done);
            assert_eq!(x.images, y.images);
            assert_eq!(x.link_bytes, y.link_bytes);
            assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits());
        }
    }

    #[test]
    fn identical_lockstep_jobs_stay_in_admission_order() {
        // Two bit-identical jobs tie at every step boundary — the
        // fast-forward must preserve the per-step FIFO tie-break, so
        // both complete at the same instant and in submission order.
        // (Data plane off: physical staging on *different* device
        // groups differs by per-device ECC draws, which would
        // legitimately break the exact tie this test exists to probe.)
        let run = |ff: bool| {
            let mut fleet = Fleet::new(FleetConfig {
                total_csds: 4,
                stage_io: false,
                data_plane: false,
                fast_forward: ff,
                ..Default::default()
            });
            fleet.submit(job("squeezenet", 2, false, 30));
            fleet.submit(job("squeezenet", 2, false, 30));
            fleet.run().unwrap()
        };
        let (a, b) = (run(true), run(false));
        assert_eq!(a.jobs[0].finished_at, a.jobs[1].finished_at);
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.finished_at, y.finished_at);
            assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits());
        }
        assert_eq!(a.makespan, b.makespan);
    }

    #[test]
    fn data_plane_charges_staging_and_moves_shards_on_degradation() {
        let run = |data_plane: bool| {
            let mut fleet = Fleet::new(FleetConfig {
                total_csds: 3,
                stage_io: false,
                data_plane,
                ..Default::default()
            });
            fleet.submit(job("mobilenet_v2", 3, true, 8));
            fleet.inject_degradation(SimTime::secs(30), 0, 0.6);
            fleet.run().unwrap()
        };
        let on = run(true);
        let off = run(false);
        let j = &on.jobs[0];
        assert_eq!(j.retunes, 1);
        assert!(j.bytes_moved > 0, "public-shard delta must physically move");
        assert!(j.images_moved > 0);
        assert!(j.lock_wait > SimTime::ZERO, "DLM grants cross the tunnel");
        assert_eq!(on.bytes_moved, j.bytes_moved);
        assert_eq!(off.jobs[0].bytes_moved, 0, "no data plane, no movement");
        assert!(
            on.makespan > off.makespan,
            "staged reads + movement must cost simulated time: {} !> {}",
            on.makespan,
            off.makespan
        );
        assert!(j.energy_j > off.jobs[0].energy_j, "flash + link energy is charged");
        // Movement and lock traffic crossed the tunnel and stayed
        // attributed to the job (ledger conservation).
        assert_eq!(on.link_bytes, on.jobs.iter().map(|x| x.link_bytes).sum::<u64>());
        assert!(on.link_bytes > off.link_bytes);
    }

    #[test]
    fn data_plane_host_pushes_grown_host_shard() {
        // Degradation re-tunes the host batch upward; with a public
        // pool bigger than the initial host shard, the growth is
        // staged by host→CSD pushes rather than CSD→CSD moves alone.
        let mut fleet = Fleet::new(FleetConfig {
            total_csds: 2,
            stage_io: false,
            ..Default::default()
        });
        fleet.submit(ExperimentConfig {
            network: "mobilenet_v2".into(),
            num_csds: 2,
            include_host: true,
            steps: 8,
            public_images: 20_000,
            ..Default::default()
        });
        fleet.inject_degradation(SimTime::secs(30), 0, 0.5);
        let r = fleet.run().unwrap();
        assert_eq!(r.jobs[0].retunes, 1);
        assert!(fleet.data_plane().stats().host_pushes > 0, "grown host shard is pushed");
        assert!(fleet
            .data_plane()
            .transfers()
            .iter()
            .any(|t| t.from == crate::tunnel::NodeId::Host));
    }

    #[test]
    fn degrading_an_idle_bay_touches_no_job() {
        let mut fleet = Fleet::new(FleetConfig {
            total_csds: 4,
            stage_io: false,
            ..Default::default()
        });
        fleet.submit(job("mobilenet_v2", 2, true, 3));
        // Device 3 is never carved (job takes 0,1).
        fleet.inject_degradation(SimTime::secs(1), 3, 0.5);
        let r = fleet.run().unwrap();
        assert_eq!(r.retunes, 0);
        assert_eq!(r.jobs[0].retunes, 0);
    }

    // ---- online session API ------------------------------------------

    #[test]
    fn submit_at_delays_arrival_and_admission() {
        let mut rt = FleetRuntime::new(FleetConfig {
            total_csds: 2,
            stage_io: false,
            retain_jobs: true,
            ..Default::default()
        });
        let id = rt.submit_at(SimTime::secs(50), job("squeezenet", 2, false, 3)).unwrap();
        assert_eq!(rt.job_state(id), Some(JobState::Queued));
        // Driving to just before the arrival does nothing.
        rt.run_until(SimTime::secs(49)).unwrap();
        assert_eq!(rt.now(), SimTime::ZERO, "no event processed yet");
        assert_eq!(rt.job_state(id), Some(JobState::Queued));
        rt.run_until(SimTime::secs(50)).unwrap();
        assert_eq!(rt.now(), SimTime::secs(50));
        assert_eq!(rt.job_state(id), Some(JobState::Running));
        rt.run_until_idle().unwrap();
        assert_eq!(rt.job_state(id), Some(JobState::Completed));
        let r = rt.report();
        assert_eq!(r.jobs[0].submitted_at, SimTime::secs(50));
        assert_eq!(r.jobs[0].admitted_at, SimTime::secs(50));
        assert_eq!(r.jobs[0].queue_wait, SimTime::ZERO);
        assert!(r.makespan > SimTime::secs(50));
        // Submitting into the past is rejected.
        assert!(rt.submit_at(SimTime::secs(1), job("squeezenet", 1, false, 1)).is_err());
    }

    #[test]
    fn cancel_mid_run_releases_devices_and_admits_waiter() {
        let mut rt = FleetRuntime::new(FleetConfig {
            total_csds: 2,
            stage_io: false,
            retain_jobs: true,
            ..Default::default()
        });
        // A long job hogs the whole pool; B waits behind it.
        let a = rt.submit(job("mobilenet_v2", 2, true, 10_000));
        let b = rt.submit(job("squeezenet", 2, false, 3));
        rt.cancel(a, SimTime::secs(120)).unwrap();
        rt.run_until_idle().unwrap();
        let r = rt.report();
        assert_eq!(r.cancelled, 1);
        let find = |id| r.jobs.iter().find(|j| j.id == id).unwrap();
        let (ja, jb) = (find(a), find(b));
        assert_eq!(ja.state, JobState::Cancelled);
        assert_eq!(ja.finished_at, SimTime::secs(120));
        assert!(ja.steps_done > 0, "partial progress is reported");
        assert!(ja.images > 0 && ja.images < 10_000 * 25);
        assert!(ja.energy_j > 0.0, "burned power stays attributed");
        // B admits the instant A's carve is released.
        assert_eq!(jb.state, JobState::Completed);
        assert_eq!(jb.admitted_at, SimTime::secs(120));
        assert_eq!(jb.steps_done, 3);
        // The cancelled job's shard pages were all freed (data-plane
        // ledger and per-device FTL trims agree).
        let stats = rt.data_plane().stats();
        assert_eq!(stats.cancels, 1);
        assert!(stats.freed_pages > 0);
        assert_eq!(rt.data_plane().resident_pages(a), 0);
        // A cancel for an already-finished job is a quiet no-op.
        rt.cancel(b, rt.now()).unwrap();
        rt.run_until_idle().unwrap();
        // Unknown ids are rejected.
        assert!(rt.cancel(JobId(99), rt.now()).is_err());
    }

    #[test]
    fn cancel_before_arrival_reports_a_stub() {
        let mut rt = FleetRuntime::new(FleetConfig {
            total_csds: 2,
            stage_io: false,
            retain_jobs: true,
            ..Default::default()
        });
        let a = rt.submit_at(SimTime::secs(100), job("squeezenet", 2, false, 5)).unwrap();
        rt.cancel(a, SimTime::secs(10)).unwrap();
        rt.run_until_idle().unwrap();
        let r = rt.report();
        assert_eq!(r.jobs.len(), 1);
        assert_eq!(r.jobs[0].state, JobState::Cancelled);
        assert_eq!(r.jobs[0].images, 0);
        assert_eq!(r.jobs[0].steps_done, 0);
        assert_eq!(r.makespan, SimTime::secs(10), "the cancel is the only event");
        assert_eq!(rt.job_state(a), Some(JobState::Cancelled));
    }

    #[test]
    fn repair_restores_speed_and_retunes() {
        let run = |repair: bool| {
            let mut rt = FleetRuntime::new(FleetConfig {
                total_csds: 2,
                stage_io: false,
                retain_jobs: true,
                ..Default::default()
            });
            rt.submit(job("mobilenet_v2", 2, true, 60));
            rt.inject_degradation(SimTime::secs(30), 0, 0.5);
            if repair {
                // Over-repair: clamps back to full health.
                rt.inject_repair(SimTime::secs(60), 0, 4.0);
            }
            rt.run_until_idle().unwrap();
            rt.report()
        };
        let repaired = run(true);
        let throttled = run(false);
        assert_eq!(repaired.jobs[0].retunes, 2, "fault + repair each re-tune");
        assert_eq!(throttled.jobs[0].retunes, 1);
        assert!(
            repaired.makespan < throttled.makespan,
            "a repaired group must finish sooner: {} !< {}",
            repaired.makespan,
            throttled.makespan
        );
        // Repairing an already-healthy bay is a no-op (no re-tune).
        let mut rt = FleetRuntime::new(FleetConfig {
            total_csds: 2,
            stage_io: false,
            retain_jobs: true,
            ..Default::default()
        });
        rt.submit(job("mobilenet_v2", 2, true, 5));
        rt.inject_repair(SimTime::secs(10), 0, 2.0);
        rt.run_until_idle().unwrap();
        assert_eq!(rt.report().jobs[0].retunes, 0);
    }

    #[test]
    fn run_until_slicing_is_bit_identical_and_streams_a_log() {
        let build = || {
            let mut rt = FleetRuntime::new(FleetConfig {
                total_csds: 4,
                stage_io: false,
                retain_jobs: true,
                ..Default::default()
            });
            rt.submit(job("mobilenet_v2", 2, true, 12));
            rt.submit_at(SimTime::secs(40), job("squeezenet", 2, false, 8)).unwrap();
            rt.inject_degradation(SimTime::secs(80), 0, 0.7);
            rt
        };
        // One shot.
        let mut one = build();
        one.run_until_idle().unwrap();
        let r1 = one.report();
        // Sliced at arbitrary boundaries, streaming the log as we go.
        let mut sliced = build();
        let mut log = Vec::new();
        for secs in [1u64, 40, 41, 80, 200, 1000] {
            sliced.run_until(SimTime::secs(secs)).unwrap();
            log.extend(sliced.take_log());
        }
        sliced.run_until_idle().unwrap();
        log.extend(sliced.take_log());
        let r2 = sliced.report();
        assert_eq!(r1.makespan, r2.makespan);
        assert_eq!(r1.total_energy_j.to_bits(), r2.total_energy_j.to_bits());
        assert_eq!(r1.link_bytes, r2.link_bytes);
        for (x, y) in r1.jobs.iter().zip(&r2.jobs) {
            assert_eq!(x.finished_at, y.finished_at);
            assert_eq!(x.steps_done, y.steps_done);
            assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits());
        }
        // The log carries the whole story in time order: 2 arrivals,
        // 2 admissions, 1 fault, 2 completions.
        assert!(log.windows(2).all(|w| w[0].at <= w[1].at), "log is time-ordered");
        let count = |f: fn(&RuntimeEvent) -> bool| log.iter().filter(|e| f(&e.event)).count();
        assert_eq!(count(|e| matches!(e, RuntimeEvent::Arrived { .. })), 2);
        assert_eq!(count(|e| matches!(e, RuntimeEvent::Admitted { .. })), 2);
        assert_eq!(count(|e| matches!(e, RuntimeEvent::Degraded { .. })), 1);
        assert_eq!(count(|e| matches!(e, RuntimeEvent::Completed { .. })), 2);
        // Every terminal job also streamed its compact final record —
        // in retained mode too (the log is mode-invariant).
        assert_eq!(count(|e| matches!(e, RuntimeEvent::Retired { .. })), 2);
        // Entries render as one line each for the CLI stream.
        for e in &log {
            assert!(!e.to_string().is_empty());
        }
    }

    #[test]
    fn streaming_default_retires_jobs_and_reuses_slots() {
        // Default config: terminal jobs leave the table; their final
        // reports arrive as Retired records on the log, and the second
        // job reuses the first one's slab slot.
        let mut rt = FleetRuntime::new(FleetConfig {
            total_csds: 2,
            stage_io: false,
            ..Default::default()
        });
        let a = rt.submit(job("squeezenet", 2, false, 3));
        let b = rt.submit_at(SimTime::secs(10_000), job("squeezenet", 2, false, 3)).unwrap();
        rt.run_until_idle().unwrap();
        let r = rt.report();
        assert!(r.jobs.is_empty(), "streaming mode holds no terminal jobs");
        assert_eq!(r.retired, 2);
        assert_eq!(rt.retired_jobs(), 2);
        assert_eq!(rt.live_jobs(), 0);
        assert_eq!(r.peak_live_jobs, 1, "the jobs never overlapped");
        assert_eq!(rt.job_slots(), 1, "job1 must reuse job0's freed slot");
        assert!(r.total_images > 0, "totals survive retirement");
        assert!(r.jobs_energy_j > 0.0);
        assert_eq!(r.queue_wait.count(), 2);
        // States are no longer queryable once retired...
        assert_eq!(rt.job_state(a), None);
        assert_eq!(rt.job_state(b), None);
        // ...because the history lives in the log.
        let log = rt.take_log();
        let records: Vec<_> = log
            .iter()
            .filter_map(|e| match &e.event {
                RuntimeEvent::Retired { record } => Some(record),
                _ => None,
            })
            .collect();
        assert_eq!(records.len(), 2);
        assert_eq!(records[0].report.id, a);
        assert_eq!(records[0].report.state, JobState::Completed);
        assert_eq!(records[1].report.id, b);
        assert_eq!(records[0].retired_at, records[0].report.finished_at);
        // The accumulators match the streamed records exactly.
        let sum: f64 = records.iter().map(|rec| rec.report.energy_j).sum();
        assert_eq!(sum.to_bits(), r.jobs_energy_j.to_bits());
    }

    #[test]
    fn worn_device_drains_job_and_rolls_in_a_replacement() {
        use crate::csd::flash::FlashConfig;
        use crate::csd::ftl::FtlConfig;
        // Tiny endurance-limited flash so a few overwrite rounds reach
        // end-of-life; no staging, so the job itself never touches the
        // FTL — the test wears bay 0 directly and lets the pump react.
        // Per-step execution: the drain must land at the first step
        // boundary after the wear-out, not at a fast-forwarded
        // completion (no event handler runs in between otherwise).
        let mut cfg = FleetConfig {
            total_csds: 3,
            stage_io: false,
            data_plane: false,
            fast_forward: false,
            retain_jobs: true,
            ..Default::default()
        };
        cfg.csd.ftl = FtlConfig {
            flash: FlashConfig {
                channels: 1,
                dies_per_channel: 1,
                blocks_per_die: 8,
                pages_per_block: 8,
                page_bytes: 4096,
                ..Default::default()
            },
            overprovision: 0.5,
            gc_low_water: 2,
            gc_high_water: 3,
            pe_limit: 1,
            ..Default::default()
        };
        let mut rt = FleetRuntime::new(cfg);
        let a = rt.submit(job("squeezenet", 2, false, 5000));
        rt.run_until(SimTime::secs(30)).unwrap();
        assert_eq!(rt.job_state(a), Some(JobState::Running));
        // Wear bay 0 (held by the job) to end-of-life.
        'wear: for _ in 0..1000 {
            for lpn in 0..8u32 {
                if rt.pool.device_mut(0).write_page(lpn, lpn as u64, rt.now).is_err() {
                    break 'wear;
                }
            }
            if rt.pool.device(0).ftl_ref().worn_out() {
                break;
            }
        }
        assert!(rt.pool.device(0).ftl_ref().worn_out(), "bay 0 never wore out");
        rt.run_until_idle().unwrap();
        let r = rt.report();
        // The victim was drained (cancelled + marked), its successor
        // re-ran the remaining steps to completion, and the whole
        // workload's step budget is conserved across the drain.
        assert_eq!(r.drained, 1);
        assert_eq!(r.cancelled, 1, "a drain counts as a cancel");
        assert_eq!(r.devices_replaced, 1);
        let find = |id: JobId| r.jobs.iter().find(|j| j.id == id).unwrap();
        let victim = find(a);
        assert_eq!(victim.state, JobState::Cancelled);
        assert!(victim.drained);
        assert!(victim.steps_done > 0 && victim.steps_done < 5000);
        let successor = find(JobId(1));
        assert_eq!(successor.state, JobState::Completed);
        assert!(!successor.drained);
        assert_eq!(victim.steps_done + successor.steps_done, 5000);
        // The replaced module's wear history survives in fleet totals.
        assert!(r.wear.retired_blocks > 0);
        assert_eq!(rt.pool.device(0).ftl_ref().retired_block_count(), 0, "fresh module");
        assert_eq!(rt.pool.generation(0), 1);
        // The log tells the story: worn-out (with drain + successor),
        // then the replacement.
        let log = rt.take_log();
        assert!(log.iter().any(|e| matches!(
            e.event,
            RuntimeEvent::WornOut { device: 0, job: Some(j), successor: Some(s), .. }
                if j == a && s == JobId(1)
        )));
        assert!(log.iter().any(|e| matches!(
            e.event,
            RuntimeEvent::Replaced { device: 0, generation: 1, .. }
        )));
        for e in &log {
            assert!(!e.to_string().is_empty());
        }
    }

    #[test]
    fn cancel_after_natural_completion_is_a_noop_even_when_retired() {
        // A cancel scheduled while the job runs but firing after its
        // completion must be a no-op in BOTH modes — in the streaming
        // default the job is not even in the table anymore.
        for retain in [false, true] {
            let mut rt = FleetRuntime::new(FleetConfig {
                total_csds: 2,
                stage_io: false,
                retain_jobs: retain,
                ..Default::default()
            });
            let a = rt.submit(job("squeezenet", 2, false, 2));
            // Far beyond the job's natural completion.
            rt.cancel(a, SimTime::secs(1_000_000)).unwrap();
            rt.run_until_idle().unwrap();
            let r = rt.report();
            assert_eq!(r.retired, 1, "retain={retain}");
            assert_eq!(r.cancelled, 0, "the late cancel must not re-kill the job");
            assert!(
                r.makespan < SimTime::secs(1_000_000),
                "a settled cancel must not stretch the timeline (retain={retain})"
            );
            // Scheduling ANOTHER cancel for the retired id is a quiet
            // no-op too (not an unknown-id error, not a double-release).
            rt.cancel(a, rt.now()).unwrap();
            rt.run_until_idle().unwrap();
            assert_eq!(rt.report().cancelled, 0);
            // Truly unknown ids still error.
            assert!(rt.cancel(JobId(99), rt.now()).is_err());
        }
    }

    #[test]
    fn job_slab_reuses_slots_and_audits_clean() {
        let mk = |i: u64| {
            cancelled_stub(
                JobId(i),
                job("mobilenet_v2", 0, false, 1),
                SimTime::ZERO,
                SimTime::ZERO,
            )
            .unwrap()
        };
        let mut slab = JobSlab::default();
        slab.check_invariants().unwrap();
        for i in 0..4 {
            slab.insert(mk(i));
        }
        slab.check_invariants().unwrap();
        assert!(slab.remove(&JobId(1)).is_some());
        assert!(slab.remove(&JobId(2)).is_some());
        slab.check_invariants().unwrap();
        // Freed slots are reused LIFO — the table never grows past
        // peak occupancy — and a reused slot's bumped generation keeps
        // the audit clean.
        slab.insert(mk(4));
        slab.check_invariants().unwrap();
        assert_eq!(slab.slot_high_water(), 4);
        let fp = |s: &JobSlab| {
            let mut h = Fnv64::new();
            s.fingerprint(&mut h);
            h.finish()
        };
        let before = fp(&slab);
        assert!(slab.remove(&JobId(4)).is_some());
        slab.check_invariants().unwrap();
        assert_ne!(before, fp(&slab), "the live set is part of the digest");
        assert_eq!(slab.component(), "job-slab");
    }

    #[test]
    fn full_audit_detects_a_corrupted_ledger() {
        let mut rt = FleetRuntime::new(FleetConfig {
            total_csds: 2,
            stage_io: false,
            ..Default::default()
        });
        rt.submit(job("squeezenet", 2, false, 2));
        rt.run_until_idle().unwrap();
        rt.full_audit().unwrap();
        // The audit is not a rubber stamp: corrupt one cross-component
        // ledger and the next full_audit must say which one.
        rt.live_jobs += 1;
        let err = rt.full_audit().unwrap_err().to_string();
        assert!(err.contains("live-job counter"), "unexpected audit error: {err}");
    }

    /// The determinism guard must be invisible: a session with `audit`
    /// on (every component re-proving its invariants after every
    /// event) is bit-identical — log stream, report, energy bits,
    /// state fingerprint — to the same session with it off, across
    /// both executors and randomized `run_until` slicings. The
    /// fingerprint is also slicing-invariant, so a violation bisects
    /// to the first divergent event.
    #[test]
    fn property_audit_on_is_bit_identical_to_audit_off() {
        crate::util::prop::check_n("audit on == audit off", 6, |rng| {
            let fast_forward = rng.bool(0.5);
            let mut cuts: Vec<u64> = (0..rng.usize_below(4)).map(|_| rng.below(600)).collect();
            cuts.sort_unstable();
            let run = |audit: bool, sliced: bool| {
                let mut rt = FleetRuntime::new(FleetConfig {
                    total_csds: 4,
                    stage_io: false,
                    fast_forward,
                    audit,
                    ..Default::default()
                });
                rt.submit(job("mobilenet_v2", 2, true, 6));
                rt.submit(job("squeezenet", 2, false, 4));
                let c = rt.submit(job("squeezenet", 1, false, 3));
                rt.inject_degradation(SimTime::secs(40), 0, 0.7);
                rt.cancel(c, SimTime::secs(5)).unwrap();
                let mut logs: Vec<String> = Vec::new();
                if sliced {
                    for &s in &cuts {
                        rt.run_until(SimTime::secs(s)).unwrap();
                        logs.extend(rt.take_log().iter().map(|e| e.to_string()));
                        // The harness audits even when the config does
                        // not — full_audit is read-only either way.
                        rt.full_audit().unwrap();
                    }
                }
                rt.run_until_idle().unwrap();
                logs.extend(rt.take_log().iter().map(|e| e.to_string()));
                rt.full_audit().unwrap();
                (logs, rt.report(), rt.fingerprint())
            };
            let (la, ra, fa) = run(true, true);
            let (lb, rb, fb) = run(false, true);
            let (_, _, fc) = run(false, false);
            assert_eq!(la, lb, "log streams must be identical");
            assert_eq!(fa, fb, "state fingerprints must be identical");
            assert_eq!(fb, fc, "the fingerprint must be slicing-invariant");
            assert_eq!(ra.makespan, rb.makespan);
            assert_eq!(ra.total_images, rb.total_images);
            assert_eq!(ra.total_energy_j.to_bits(), rb.total_energy_j.to_bits());
            assert_eq!(ra.jobs_energy_j.to_bits(), rb.jobs_energy_j.to_bits());
            assert_eq!(ra.link_bytes, rb.link_bytes);
            assert_eq!(ra.bytes_moved, rb.bytes_moved);
            assert_eq!(ra.retired, rb.retired);
            assert_eq!(ra.peak_live_jobs, rb.peak_live_jobs);
        });
    }

    // ---- crash faults, checkpoint/restore, link retry ----------------

    #[test]
    fn crash_resumes_from_checkpoint_and_replaces_bay() {
        let mut rt = FleetRuntime::new(FleetConfig {
            total_csds: 3,
            stage_io: false,
            retain_jobs: true,
            checkpoint: CheckpointSpec { interval_steps: 5, host_copy: false },
            ..Default::default()
        });
        let a = rt.submit(job("squeezenet", 2, false, 5000));
        // Bay 0 belongs to the job (lowest-index carve); kill it mid-run.
        rt.inject_crash(SimTime::secs(100), 0);
        rt.run_until_idle().unwrap();
        let r = rt.report();
        assert_eq!(r.crashed, 1);
        assert_eq!(r.cancelled, 1, "a crash counts as a cancel");
        assert_eq!(r.devices_replaced, 1);
        let find = |id: JobId| r.jobs.iter().find(|j| j.id == id).unwrap();
        let victim = find(a);
        assert_eq!(victim.state, JobState::Cancelled);
        assert!(victim.crashed && !victim.drained);
        assert!(
            victim.steps_done >= 5,
            "the crash must land after the first checkpoint, got {} steps",
            victim.steps_done
        );
        // The checkpoint cadence pins the loss exactly: everything past
        // the last interval boundary died with the module.
        assert_eq!(victim.lost_steps, victim.steps_done % 5);
        assert!(victim.checkpoint_bytes > 0, "periodic checkpoints must write flash");
        let successor = find(JobId(1));
        assert_eq!(successor.state, JobState::Completed);
        assert!(!successor.crashed);
        assert!(successor.checkpoint_bytes > 0, "the successor checkpoints too");
        // Conservation: checkpointed prefix + successor's rerun covers
        // the spec exactly once; the lost tail was redone.
        assert_eq!(
            (victim.steps_done - victim.lost_steps) + successor.steps_done,
            5000,
            "checkpointed steps + successor steps must cover the spec"
        );
        assert_eq!(r.lost_steps, victim.lost_steps);
        assert_eq!(rt.pool.generation(0), 1, "the crashed bay was swapped");
        let log = rt.take_log();
        assert!(log.iter().any(|e| matches!(
            e.event,
            RuntimeEvent::Crashed { device: 0, job: Some(j), successor: Some(s), .. }
                if j == a && s == JobId(1)
        )));
        assert!(log.iter().any(|e| matches!(
            e.event,
            RuntimeEvent::Checkpointed { job, .. } if job == a
        )));
        assert!(log.iter().any(|e| matches!(
            e.event,
            RuntimeEvent::Replaced { device: 0, generation: 1, .. }
        )));
        for e in &log {
            assert!(!e.to_string().is_empty());
        }
    }

    #[test]
    fn crash_without_checkpoint_restarts_from_step_zero() {
        let mut rt = FleetRuntime::new(FleetConfig {
            total_csds: 3,
            stage_io: false,
            retain_jobs: true,
            ..Default::default()
        });
        let a = rt.submit(job("squeezenet", 2, false, 5000));
        rt.inject_crash(SimTime::secs(100), 0);
        rt.run_until_idle().unwrap();
        let r = rt.report();
        assert_eq!(r.crashed, 1);
        let find = |id: JobId| r.jobs.iter().find(|j| j.id == id).unwrap();
        let victim = find(a);
        assert!(victim.crashed);
        assert!(victim.steps_done > 0, "the crash must land mid-run");
        // No checkpoint: every completed step is lost and the successor
        // redoes the whole spec.
        assert_eq!(victim.lost_steps, victim.steps_done);
        assert_eq!(victim.checkpoint_bytes, 0);
        assert_eq!(find(JobId(1)).steps_done, 5000);
        assert_eq!(r.lost_steps, victim.steps_done);
    }

    #[test]
    fn checkpointing_is_bit_identical_across_executors_and_costs_time() {
        let run = |ff: bool, interval: u64| {
            let mut fleet = Fleet::new(FleetConfig {
                total_csds: 6,
                stage_io: false,
                fast_forward: ff,
                checkpoint: CheckpointSpec { interval_steps: interval, host_copy: true },
                ..Default::default()
            });
            fleet.submit(job("squeezenet", 3, false, 40));
            fleet.submit(job("mobilenet_v2", 3, true, 25));
            fleet.inject_degradation(SimTime::secs(100), 0, 0.7);
            fleet.run().unwrap()
        };
        // The fast-forward must cap its windows at checkpoint
        // boundaries so the periodic I/O runs as real events — the
        // closed form stays exact, not approximate.
        let a = run(true, 7);
        let b = run(false, 7);
        assert_eq!(a.makespan, b.makespan, "makespan must be bit-identical");
        assert_eq!(a.total_images, b.total_images);
        assert_eq!(a.link_bytes, b.link_bytes);
        assert_eq!(a.checkpoint_bytes, b.checkpoint_bytes);
        assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.finished_at, y.finished_at);
            assert_eq!(x.steps_done, y.steps_done);
            assert_eq!(x.checkpoint_bytes, y.checkpoint_bytes);
            assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits());
        }
        assert!(a.checkpoint_bytes > 0, "both jobs checkpoint periodically");
        assert_eq!(
            a.checkpoint_bytes,
            a.jobs.iter().map(|j| j.checkpoint_bytes).sum::<u64>(),
            "the fleet total is the per-job ledger's sum"
        );
        // Checkpoints are real modeled I/O: flash extents + host copies
        // cost simulated time and energy against the off baseline.
        let off = run(true, 0);
        assert_eq!(off.checkpoint_bytes, 0);
        assert!(
            a.makespan > off.makespan,
            "checkpoint I/O must cost time: {} !> {}",
            a.makespan,
            off.makespan
        );
        assert!(a.total_energy_j > off.total_energy_j);
    }

    #[test]
    fn crashing_an_idle_bay_swaps_it_without_stretching_the_timeline() {
        let mut rt = FleetRuntime::new(FleetConfig {
            total_csds: 4,
            stage_io: false,
            ..Default::default()
        });
        rt.submit(job("squeezenet", 2, false, 3));
        // Device 3 is never carved; the crash fires long after the only
        // job completed, on an idle fleet.
        rt.inject_crash(SimTime::secs(1_000_000), 3);
        rt.run_until_idle().unwrap();
        let r = rt.report();
        assert_eq!(r.crashed, 0, "no tenant, no crashed job");
        assert_eq!(r.cancelled, 0);
        assert_eq!(r.devices_replaced, 1, "the module is still swapped");
        assert_eq!(rt.pool.generation(3), 1);
        assert!(
            r.makespan < SimTime::secs(1_000_000),
            "an idle-bay crash must not stretch the timeline, got {}",
            r.makespan
        );
        let log = rt.take_log();
        assert!(log.iter().any(|e| matches!(
            e.event,
            RuntimeEvent::Crashed { device: 3, job: None, successor: None, .. }
        )));
    }

    #[test]
    fn transient_link_faults_retry_deterministically_without_escalating() {
        // A deep ladder over a modest per-attempt failure rate: sends
        // hit the retry path constantly but the ladder never exhausts,
        // so no bay crashes — the run just stretches by the backoff.
        let run = |armed: bool, ff: bool| {
            let mut fleet = Fleet::new(FleetConfig {
                total_csds: 2,
                stage_io: false,
                fast_forward: ff,
                link_fault: if armed {
                    LinkFaultSpec { fail_prob: 0.2, max_retries: 12, ..Default::default() }
                } else {
                    LinkFaultSpec::default()
                },
                ..Default::default()
            });
            fleet.submit(job("squeezenet", 2, false, 30));
            fleet.run().unwrap()
        };
        let on = run(true, true);
        assert!(on.link_retries > 0, "a 20% loss rate must exercise the ladder");
        assert_eq!(on.crashed, 0, "a 13-rung ladder never exhausts at 20% loss");
        assert_eq!(on.devices_replaced, 0);
        assert_eq!(on.retired, 1);
        // Per-link RNG forks are seeded, so the whole run — including
        // which attempts fail and how far each backoff reaches — is
        // reproducible to the bit, and the fast-forward disarms itself
        // (per-send draws are stateful) so both executors agree.
        let again = run(true, true);
        assert_eq!(on.makespan, again.makespan);
        assert_eq!(on.link_retries, again.link_retries);
        assert_eq!(on.total_energy_j.to_bits(), again.total_energy_j.to_bits());
        let per_step = run(true, false);
        assert_eq!(on.makespan, per_step.makespan, "armed ladder must disarm fast-forward");
        assert_eq!(on.link_retries, per_step.link_retries);
        assert_eq!(on.total_energy_j.to_bits(), per_step.total_energy_j.to_bits());
        // Backoff is real simulated time against the faultless baseline.
        let off = run(false, true);
        assert_eq!(off.link_retries, 0);
        assert!(
            on.makespan > off.makespan,
            "retries must cost time: {} !> {}",
            on.makespan,
            off.makespan
        );
    }
}
