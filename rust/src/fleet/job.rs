//! Job identity, lifecycle state and the per-job report the fleet
//! emits (DESIGN.md §5).

use crate::config::ExperimentConfig;
use crate::perfmodel::NetId;
use crate::power::{EnergyMeter, PowerConfig};
use crate::sim::SimTime;

use super::dataplane::StepStaging;

/// Stable identifier of one submitted job, assigned at `submit` time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct JobId(pub u64);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job{}", self.0)
    }
}

/// Lifecycle of a job inside the fleet runtime:
/// `Queued -> Running -> Completed`, with `Cancelled` reachable from
/// both non-terminal states via [`super::FleetRuntime::cancel`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Submitted (arrival scheduled or already in the admission queue),
    /// waiting for a device group (and the host, if requested).
    Queued,
    /// Admitted: device group carved, batches tuned, placement
    /// balanced, steps in flight.
    Running,
    /// All target images processed; devices released.
    Completed,
    /// Torn down mid-run (or dequeued before admission): devices
    /// released, data-plane shard pages trimmed, report partial.
    Cancelled,
}

impl JobState {
    /// Terminal states release their resources and never run again.
    pub fn is_terminal(self) -> bool {
        matches!(self, JobState::Completed | JobState::Cancelled)
    }
}

impl std::fmt::Display for JobState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Completed => "done",
            JobState::Cancelled => "cancelled",
        })
    }
}

/// One step currently in flight for a job: everything needed to commit
/// (on completion) or abandon (on a mid-step degradation) its effects.
#[derive(Debug, Clone)]
pub(crate) struct PendingStep {
    /// Event id of the scheduled `StepDone`, for cancellation.
    pub event: u64,
    pub start: SimTime,
    pub end: SimTime,
    /// Share of the step spent in the ring allreduce barrier.
    pub sync: SimTime,
    /// Tunnel bytes this step's ring moved (attributed on completion).
    pub link_bytes: u64,
    /// Tunnel messages the ring moved (fast-forward re-credits both).
    pub link_msgs: u64,
    /// Flash pages staged on the group's devices this step.
    pub flash_reads: u64,
    /// Bytes the host's staged batch crossed NVMe this step (data
    /// plane; zero on the legacy staging paths).
    pub host_bytes: u64,
    /// Images the step trains across the whole group.
    pub images: usize,
}

/// Internal bookkeeping for one admitted job.
#[derive(Debug)]
pub(crate) struct Job {
    pub id: JobId,
    pub spec: ExperimentConfig,
    /// Interned network, resolved once at admission — the per-step hot
    /// path never re-parses the spec's network string.
    pub net: NetId,
    pub state: JobState,
    /// Global pool indices of the carved device group.
    pub devices: Vec<usize>,
    pub holds_host: bool,
    /// Batch sizes currently in force (Algorithm 1 output; re-tuned on
    /// degradation).
    pub bs_csd: usize,
    pub bs_host: usize,
    /// Eq. 1 steps-per-epoch of the current placement.
    pub steps_per_epoch: usize,
    /// Total images the job must train (fixed at admission).
    pub images_target: usize,
    pub images_done: usize,
    pub steps_done: usize,
    pub retunes: usize,
    pub submitted_at: SimTime,
    pub admitted_at: SimTime,
    pub finished_at: SimTime,
    pub sync_time: SimTime,
    pub link_bytes: u64,
    /// Total flash pages staged for this job (energy conversion happens
    /// once, in [`Job::report`], so per-step and fast-forward paths
    /// book identical integers rather than accumulated floats).
    pub flash_reads: u64,
    /// Flash pages programmed for this job (data-plane layout and
    /// rebalance movement writes).
    pub flash_progs: u64,
    /// Bytes the host's staged batches moved over NVMe (data plane).
    pub staged_host_bytes: u64,
    /// Bytes of public-shard data physically moved by rebalances
    /// (flash read -> tunnel relay -> flash write) plus host pushes.
    pub moved_bytes: u64,
    /// Images those movements relocated.
    pub moved_images: u64,
    /// Total DLM request-to-grant time across this job's shard-map
    /// lock acquisitions (admission + rebalance windows).
    pub lock_wait: SimTime,
    /// The job's next step may start no earlier than this (data-plane
    /// layout / movement completion).
    pub stage_ready: SimTime,
    /// The current window's staged-read plan (copied from the data
    /// plane once per window; empty when the data plane is off). The
    /// per-step hot path takes it by `mem::take` rather than cloning.
    pub staging: StepStaging,
    pub meter: EnergyMeter,
    /// Set when the job was torn down by a device end-of-life drain
    /// (rather than a user cancel); its remaining steps were resubmitted
    /// as a successor job. Always false with endurance off.
    pub drained: bool,
    /// Set when the job was torn down by an abrupt bay crash
    /// (DESIGN.md §Crash-Recovery); the checkpointed prefix of its
    /// steps was resubmitted as a successor. Always false with the
    /// crash pipeline off.
    pub crashed: bool,
    /// Steps covered by the job's last completed checkpoint (0 when
    /// checkpointing is off or nothing has been written yet).
    pub ckpt_steps: usize,
    /// Bytes this job's checkpoints wrote (flash pages + optional
    /// tunnel host copies).
    pub ckpt_bytes: u64,
    /// Steps that were done but not checkpointed when the job crashed
    /// — work the successor must redo. Always 0 without a crash.
    pub lost_steps: usize,
    pub pending: Option<PendingStep>,
    /// Rolling offset into the preloaded flash pages (mirrors the
    /// single-job scheduler's data cursor).
    pub data_cursor: u32,
}

impl Job {
    /// Images one synchronous step trains across the group.
    pub fn images_per_step(&self) -> usize {
        self.devices.len() * self.bs_csd + if self.holds_host { self.bs_host } else { 0 }
    }
}

/// Public per-job summary in the fleet report.
///
/// `PartialEq` is exact (f64 fields compare bitwise-equal values) —
/// the streaming-vs-retained equivalence property asserts the retired
/// record stream reproduces the oracle's reports to the bit.
#[derive(Debug, Clone, PartialEq)]
pub struct JobReport {
    pub id: JobId,
    /// Terminal lifecycle state ([`JobState::Completed`] or
    /// [`JobState::Cancelled`]; a partial report taken mid-session may
    /// also show `Queued`/`Running`).
    pub state: JobState,
    pub network: String,
    pub devices: Vec<usize>,
    pub held_host: bool,
    pub bs_csd: usize,
    pub bs_host: usize,
    pub steps_done: usize,
    pub steps_per_epoch: usize,
    pub images: usize,
    pub submitted_at: SimTime,
    pub admitted_at: SimTime,
    pub finished_at: SimTime,
    /// Time spent waiting in the admission queue.
    pub queue_wait: SimTime,
    /// Wall time from admission to completion.
    pub elapsed: SimTime,
    pub images_per_sec: f64,
    pub sync_fraction: f64,
    pub energy_j: f64,
    pub j_per_image: f64,
    pub link_bytes: u64,
    /// Public-shard bytes physically moved by data-plane rebalances
    /// (and host pushes of newly staged public images).
    pub bytes_moved: u64,
    /// Images those movements relocated.
    pub images_moved: u64,
    /// Total shard-map DLM request-to-grant wait.
    pub lock_wait: SimTime,
    /// How many times a device degradation forced a re-tune/re-balance.
    pub retunes: usize,
    /// True when this (cancelled) job was drained off a worn-out device
    /// and its remaining steps resubmitted as a successor job. Always
    /// false with endurance off.
    pub drained: bool,
    /// True when this (cancelled) job died in an abrupt bay crash; its
    /// checkpointed prefix was resubmitted as a successor. Always
    /// false with the crash pipeline off.
    pub crashed: bool,
    /// Steps lost to the crash (done but past the last checkpoint).
    pub lost_steps: usize,
    /// Bytes the job's checkpoints wrote (flash + host copies).
    pub checkpoint_bytes: u64,
}

/// Compact terminal record of a retired job: exactly the final
/// [`JobReport`] and the instant it left the live table — nothing
/// else survives retirement (the `Job`'s energy meter, staging plan,
/// spec and placement die with the slab slot). In the streaming
/// runtime (DESIGN.md §Runtime, "Retirement & streaming") the
/// `take_log` stream of these records IS the per-job history.
#[derive(Debug, Clone, PartialEq)]
pub struct RetiredRecord {
    /// Instant the job was retired (== the report's `finished_at`).
    pub retired_at: SimTime,
    /// Final per-job report, bit-identical to what the retained
    /// oracle computes for the same job at session end: `Job::report`
    /// is a pure function of the job's state, and terminal jobs are
    /// never mutated again.
    pub report: JobReport,
}

impl JobReport {
    /// Version of the report's field set as serialized by the ledger
    /// codec (`ledger/codec.rs`). Bump whenever a field is added,
    /// removed, reordered, or changes width — decoding a frame written
    /// under a different version is a typed
    /// [`DecodeError::UnknownVersion`](crate::ledger::DecodeError::UnknownVersion),
    /// never a silent misread.
    pub const SCHEMA_VERSION: u32 = 1;
}

impl RetiredRecord {
    /// A retired record serializes as its report plus the retire
    /// instant; the two version in lockstep.
    pub const SCHEMA_VERSION: u32 = JobReport::SCHEMA_VERSION;
}

impl Job {
    /// Summarize for the fleet report. Link/flash traffic converts to
    /// energy here (integer counters × per-unit cost) rather than being
    /// accumulated per step — one float multiply at the end is both
    /// cheaper and independent of how steps were batched.
    pub(crate) fn report(&self, pw: &PowerConfig) -> JobReport {
        let elapsed = self.finished_at.saturating_sub(self.admitted_at);
        let secs = elapsed.as_secs_f64();
        let energy = self.meter.total_joules()
            + (self.link_bytes + self.staged_host_bytes) as f64 * pw.link_pj_per_byte * 1e-12
            + self.flash_reads as f64 * pw.flash_read_uj * 1e-6
            + self.flash_progs as f64 * pw.flash_prog_uj * 1e-6;
        JobReport {
            id: self.id,
            state: self.state,
            network: self.spec.network.clone(),
            devices: self.devices.clone(),
            held_host: self.holds_host,
            bs_csd: self.bs_csd,
            bs_host: self.bs_host,
            steps_done: self.steps_done,
            steps_per_epoch: self.steps_per_epoch,
            images: self.images_done,
            submitted_at: self.submitted_at,
            admitted_at: self.admitted_at,
            finished_at: self.finished_at,
            queue_wait: self.admitted_at.saturating_sub(self.submitted_at),
            elapsed,
            images_per_sec: if secs > 0.0 { self.images_done as f64 / secs } else { 0.0 },
            sync_fraction: if secs > 0.0 { self.sync_time.as_secs_f64() / secs } else { 0.0 },
            energy_j: energy,
            j_per_image: if self.images_done > 0 { energy / self.images_done as f64 } else { 0.0 },
            link_bytes: self.link_bytes,
            bytes_moved: self.moved_bytes,
            images_moved: self.moved_images,
            lock_wait: self.lock_wait,
            retunes: self.retunes,
            drained: self.drained,
            crashed: self.crashed,
            lost_steps: self.lost_steps,
            checkpoint_bytes: self.ckpt_bytes,
        }
    }
}
