//! Measurement + reporting: a criterion-style micro-bench harness,
//! streaming aggregation for fleet reports, and the fixed-width table
//! printer the paper-row reports use.
//! (In-tree because the offline build has no criterion — DESIGN.md §4.)

pub mod agg;
pub mod bench;

pub use agg::{percentile, RunningStat};
pub use bench::{bench, record_bench_json, record_bench_json_to, BenchResult};

/// Print a fixed-width table (paper-style rows).
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths.get(i).copied().unwrap_or(8)));
        }
        println!("{}", s.trim_end());
    };
    line(&headers.iter().map(|h| h.to_string()).collect::<Vec<_>>());
    line(&widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>());
    for row in rows {
        line(row);
    }
}

/// Format a float with fixed precision (table cells).
pub fn f(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

#[cfg(test)]
mod tests {
    #[test]
    fn table_prints_without_panic() {
        super::print_table(
            "t",
            &["a", "bb"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
    }
}
