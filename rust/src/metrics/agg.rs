//! Streaming aggregation: a running summary statistic for fleet-level
//! reporting (queue waits, per-step times) — constant memory, no
//! sample buffer (DESIGN.md §4).

/// Running count/sum/min/max/mean over a stream of f64 samples.
///
/// `PartialEq` compares the raw accumulators (exact f64 equality) —
/// the sweep-determinism property tests assert merged stats are
/// *bit-identical* across worker counts, not merely close.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunningStat {
    n: usize,
    sum: f64,
    min: f64,
    max: f64,
}

impl RunningStat {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, v: f64) {
        if self.n == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.n += 1;
        self.sum += v;
    }

    /// Fold another stat into this one — roll-ups across runs or
    /// shards (e.g. the fleet bench's queue-wait summary over a whole
    /// multi-tenancy sweep).
    pub fn merge(&mut self, other: &RunningStat) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            self.min = other.min;
            self.max = other.max;
        } else {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
        self.n += other.n;
        self.sum += other.sum;
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Nearest-rank percentile of an ascending-sorted sample slice (the
/// bench-report convention: index = round(p * (n-1))). Empty -> 0.0.
/// Shared by the workload and sweep bench targets.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((p * (sorted.len() - 1) as f64).round() as usize).min(sorted.len() - 1);
    sorted[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_all_moments() {
        let mut s = RunningStat::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.mean(), 0.0);
        for v in [3.0, -1.0, 4.0] {
            s.add(v);
        }
        assert_eq!(s.count(), 3);
        assert!((s.sum() - 6.0).abs() < 1e-12);
        assert!((s.mean() - 2.0).abs() < 1e-12);
        assert_eq!(s.min(), -1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn single_sample_is_its_own_extremes() {
        let mut s = RunningStat::new();
        s.add(7.5);
        assert_eq!(s.min(), 7.5);
        assert_eq!(s.max(), 7.5);
        assert_eq!(s.mean(), 7.5);
    }

    #[test]
    fn merge_folds_all_moments() {
        let mut a = RunningStat::new();
        a.add(1.0);
        a.add(3.0);
        let mut b = RunningStat::new();
        b.add(-2.0);
        let mut empty = RunningStat::new();
        a.merge(&b);
        a.merge(&empty);
        empty.merge(&a);
        assert_eq!(a.count(), 3);
        assert_eq!(a.min(), -2.0);
        assert_eq!(a.max(), 3.0);
        assert!((a.mean() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(empty.count(), 3);
        assert_eq!(empty.min(), -2.0);
    }

    #[test]
    fn equality_is_exact_on_the_accumulators() {
        let mut a = RunningStat::new();
        let mut b = RunningStat::new();
        for v in [0.1, 0.2, 0.3] {
            a.add(v);
            b.add(v);
        }
        assert_eq!(a, b);
        b.add(0.0);
        assert_ne!(a, b);
    }

    #[test]
    fn percentile_nearest_rank() {
        assert_eq!(percentile(&[], 0.5), 0.0);
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 0.5), 3.0); // round(0.5*3)=2
        assert_eq!(percentile(&xs, 1.0), 4.0);
    }
}
