//! Micro-benchmark harness (criterion-lite): warmup, timed iterations,
//! robust summary statistics. Used by every target in rust/benches/.
//! Also the machine-readable bench ledger (`BENCH_<pr>.json`) that
//! tracks the perf trajectory across PRs.
//!
//! This file is the one sanctioned wall-clock consumer in the crate:
//! `stannis lint` exempts it from the `wallclock` rule wholesale, and
//! the clippy disallowed-methods gate is lifted file-wide to match.

#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use crate::util::Json;

/// Summary of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub std_ns: f64,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.mean_ns / 1e9
    }

    /// Pretty one-liner, auto-scaled units.
    pub fn summary(&self) -> String {
        fn scale(ns: f64) -> String {
            if ns >= 1e9 {
                format!("{:.3} s", ns / 1e9)
            } else if ns >= 1e6 {
                format!("{:.3} ms", ns / 1e6)
            } else if ns >= 1e3 {
                format!("{:.3} us", ns / 1e3)
            } else {
                format!("{ns:.0} ns")
            }
        }
        format!(
            "{:<40} mean {:>12}  p50 {:>12}  p95 {:>12}  (n={})",
            self.name,
            scale(self.mean_ns),
            scale(self.p50_ns),
            scale(self.p95_ns),
            self.iters
        )
    }
}

/// Run `f` for `warmup` + `iters` timed iterations.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / iters as f64;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / iters as f64;
    let pct = |p: f64| samples[((p * (iters - 1) as f64).round() as usize).min(iters - 1)];
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: pct(0.50),
        p95_ns: pct(0.95),
        min_ns: samples[0],
        max_ns: samples[iters - 1],
        std_ns: var.sqrt(),
    }
}

/// Default path of the machine-readable bench ledger, relative to the
/// working directory `cargo bench` runs targets in (the workspace
/// root). Overridable via `STANNIS_BENCH_JSON`.
pub const BENCH_JSON_PATH: &str = "BENCH_2.json";

/// Merge `values` into `section` of the bench ledger and rewrite it.
///
/// Each bench target owns one section, so running targets in any order
/// accumulates a single JSON file (`{"simcore": {...}, "fleet": {...}}`)
/// that CI prints and future PRs diff against. Failures are reported to
/// stderr but never fail the bench — the ledger is telemetry, not a
/// gate.
pub fn record_bench_json(section: &str, values: &[(&str, f64)]) {
    record_bench_json_to(BENCH_JSON_PATH, section, values);
}

/// [`record_bench_json`] with an explicit default ledger path — each
/// PR's new bench targets own a fresh `BENCH_<pr>.json` without moving
/// the older ledgers. `STANNIS_BENCH_JSON` still overrides everything
/// (all sections then land in one file).
pub fn record_bench_json_to(default_path: &str, section: &str, values: &[(&str, f64)]) {
    let path =
        std::env::var("STANNIS_BENCH_JSON").unwrap_or_else(|_| default_path.to_string());
    let existing = std::fs::read_to_string(&path).ok();
    let merged = merge_bench_json(existing.as_deref(), section, values);
    if let Err(e) = std::fs::write(&path, merged) {
        eprintln!("warning: could not write bench ledger {path}: {e}");
    } else {
        println!("[bench ledger] {path} <- section {section:?} ({} values)", values.len());
    }
}

/// Pure merge step of [`record_bench_json`] (separated for testing):
/// returns the new ledger text given the existing one.
pub fn merge_bench_json(existing: Option<&str>, section: &str, values: &[(&str, f64)]) -> String {
    let mut root = match existing.and_then(|t| Json::parse(t).ok()) {
        Some(Json::Obj(m)) => m,
        _ => std::collections::BTreeMap::new(),
    };
    let mut sec = match root.remove(section) {
        Some(Json::Obj(m)) => m,
        _ => std::collections::BTreeMap::new(),
    };
    for (k, v) in values {
        let val = if v.is_finite() { Json::Num(*v) } else { Json::Null };
        sec.insert((*k).to_string(), val);
    }
    root.insert(section.to_string(), Json::Obj(sec));
    // Stamp the ledger as measured: the checked-in file ships with a
    // placeholder `_meta.status`, which must not outlive real numbers.
    let mut meta = match root.remove("_meta") {
        Some(Json::Obj(m)) => m,
        _ => std::collections::BTreeMap::new(),
    };
    meta.entry("schema".to_string()).or_insert_with(|| Json::Str("stannis-bench-v1".into()));
    meta.insert("status".to_string(), Json::Str("measured".into()));
    meta.insert(
        "note".to_string(),
        Json::Str(
            "Written by cargo bench targets via metrics::record_bench_json; \
             each target owns one section."
                .into(),
        ),
    );
    root.insert("_meta".to_string(), Json::Obj(meta));
    let mut out = Json::Obj(root).to_string();
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let r = bench("spin", 2, 20, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.min_ns <= r.p50_ns);
        assert!(r.p50_ns <= r.p95_ns);
        assert!(r.p95_ns <= r.max_ns);
        assert!(r.mean_ns > 0.0);
        assert!(!r.summary().is_empty());
    }

    #[test]
    #[should_panic]
    fn zero_iters_rejected() {
        bench("bad", 0, 0, || {});
    }

    #[test]
    fn bench_json_merge_preserves_other_sections() {
        let first = merge_bench_json(None, "simcore", &[("events_per_sec", 1.5e6)]);
        let second = merge_bench_json(Some(&first), "fleet", &[("speedup", 12.0)]);
        // Update one key of an existing section; keep the sibling.
        let third =
            merge_bench_json(Some(&second), "simcore", &[("events_per_sec", 2.0e6)]);
        let j = Json::parse(third.trim()).unwrap();
        let sim = j.field("simcore").unwrap();
        assert_eq!(sim.field("events_per_sec").unwrap().as_f64().unwrap(), 2.0e6);
        assert_eq!(
            j.field("fleet").unwrap().field("speedup").unwrap().as_f64().unwrap(),
            12.0
        );
        // Corrupt/absent ledgers start fresh; non-finite values are null.
        let fresh = merge_bench_json(Some("not json"), "s", &[("nan", f64::NAN)]);
        assert_eq!(
            Json::parse(fresh.trim()).unwrap().field("s").unwrap().field("nan").unwrap(),
            &Json::Null
        );
    }

    #[test]
    fn bench_json_merge_replaces_placeholder_meta() {
        // A checked-in ledger carries a pending-placeholder _meta; the
        // first real recording must re-stamp it as measured.
        let placeholder = r#"{"_meta":{"schema":"stannis-bench-v1",
            "status":"pending-first-measured-run","note":"placeholders"},
            "simcore":{"events_per_sec":null}}"#;
        let out = merge_bench_json(Some(placeholder), "simcore", &[("events_per_sec", 1.0)]);
        let j = Json::parse(out.trim()).unwrap();
        let meta = j.field("_meta").unwrap();
        assert_eq!(meta.field("status").unwrap().as_str().unwrap(), "measured");
        assert_eq!(meta.field("schema").unwrap().as_str().unwrap(), "stannis-bench-v1");
        assert!(!meta.field("note").unwrap().as_str().unwrap().contains("placeholder"));
    }
}
