//! Micro-benchmark harness (criterion-lite): warmup, timed iterations,
//! robust summary statistics. Used by every target in rust/benches/.

use std::time::Instant;

/// Summary of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub std_ns: f64,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.mean_ns / 1e9
    }

    /// Pretty one-liner, auto-scaled units.
    pub fn summary(&self) -> String {
        fn scale(ns: f64) -> String {
            if ns >= 1e9 {
                format!("{:.3} s", ns / 1e9)
            } else if ns >= 1e6 {
                format!("{:.3} ms", ns / 1e6)
            } else if ns >= 1e3 {
                format!("{:.3} us", ns / 1e3)
            } else {
                format!("{ns:.0} ns")
            }
        }
        format!(
            "{:<40} mean {:>12}  p50 {:>12}  p95 {:>12}  (n={})",
            self.name,
            scale(self.mean_ns),
            scale(self.p50_ns),
            scale(self.p95_ns),
            self.iters
        )
    }
}

/// Run `f` for `warmup` + `iters` timed iterations.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / iters as f64;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / iters as f64;
    let pct = |p: f64| samples[((p * (iters - 1) as f64).round() as usize).min(iters - 1)];
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: pct(0.50),
        p95_ns: pct(0.95),
        min_ns: samples[0],
        max_ns: samples[iters - 1],
        std_ns: var.sqrt(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let r = bench("spin", 2, 20, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.min_ns <= r.p50_ns);
        assert!(r.p50_ns <= r.p95_ns);
        assert!(r.p95_ns <= r.max_ns);
        assert!(r.mean_ns > 0.0);
        assert!(!r.summary().is_empty());
    }

    #[test]
    #[should_panic]
    fn zero_iters_rejected() {
        bench("bad", 0, 0, || {});
    }
}
