//! Zero-dependency support substrates: JSON, CLI parsing, PRNG and a
//! property-testing harness (see DESIGN.md §4, zero-dependency note).

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;

pub use json::Json;
pub use rng::Rng;
