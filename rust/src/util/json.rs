//! Minimal JSON parser/serializer (RFC 8259 subset, no external deps).
//!
//! Exists because the build image has no serde in its offline registry
//! (DESIGN.md §4 zero-dependency note). Supports everything the
//! manifest/config files use: objects, arrays, strings with escapes,
//! numbers, booleans, null. Numbers are kept as f64 plus an exact-i64
//! fast path (`as_u64`/`as_i64` only succeed when the value is
//! integral and in range).

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => bail!("expected object, got {}", other.type_name()),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("expected array, got {}", other.type_name()),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {}", other.type_name()),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {}", other.type_name()),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 || n > u64::MAX as f64 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as u64)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 || n < i64::MIN as f64 || n > i64::MAX as f64 {
            bail!("expected integer, got {n}");
        }
        Ok(n as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {}", other.type_name()),
        }
    }

    /// Required object field.
    pub fn field(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .with_context(|| format!("missing field {key:?}"))
    }

    /// Optional object field.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    fn type_name(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .with_context(|| format!("unexpected end of input at byte {}", self.pos))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.peek()?;
        if got != b {
            bail!("expected {:?} at byte {}, got {:?}", b as char, self.pos, got as char);
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            other => bail!("unexpected byte {:?} at {}", other as char, self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected ',' or '}}' at byte {}, got {:?}", self.pos, other as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                other => bail!("expected ',' or ']' at byte {}, got {:?}", self.pos, other as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let esc = self.peek()?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .context("truncated \\u escape")?;
                            let code = u16::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.pos += 4;
                            // Surrogate pairs: parse low half if present.
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.bytes.get(self.pos) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .bytes
                                        .get(self.pos + 2..self.pos + 6)
                                        .context("truncated surrogate pair")?;
                                    let low =
                                        u16::from_str_radix(std::str::from_utf8(hex2)?, 16)?;
                                    self.pos += 6;
                                    let c = 0x10000
                                        + ((code as u32 - 0xD800) << 10)
                                        + (low as u32 - 0xDC00);
                                    char::from_u32(c).context("bad surrogate pair")?
                                } else {
                                    bail!("lone high surrogate");
                                }
                            } else {
                                char::from_u32(code as u32).context("bad codepoint")?
                            };
                            out.push(ch);
                        }
                        other => bail!("bad escape \\{}", other as char),
                    }
                }
                _ => {
                    // copy raw UTF-8 bytes through
                    let start = self.pos - 1;
                    while let Some(&nb) = self.bytes.get(self.pos) {
                        if nb == b'"' || nb == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek()? == b'-' {
            self.pos += 1;
        }
        while let Some(&b) = self.bytes.get(self.pos) {
            if b.is_ascii_digit() || b == b'.' || b == b'e' || b == b'E' || b == b'+' || b == b'-' {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = text
            .parse()
            .with_context(|| format!("bad number {text:?} at byte {start}"))?;
        Ok(Json::Num(n))
    }
}

// ---- serialization -------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{"version": 1, "nets": {"a": {"bs": [1, 2, 32], "macs": 1543680}},
                      "name": "mobilenet_v2_s", "neg": -2.5e3, "flag": true, "none": null}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.field("version").unwrap().as_u64().unwrap(), 1);
        let bs = j.field("nets").unwrap().field("a").unwrap().field("bs").unwrap();
        assert_eq!(bs.as_arr().unwrap().len(), 3);
        assert_eq!(bs.as_arr().unwrap()[2].as_usize().unwrap(), 32);
        assert_eq!(j.field("neg").unwrap().as_f64().unwrap(), -2500.0);
        assert!(j.field("flag").unwrap().as_bool().unwrap());
        assert_eq!(j.field("none").unwrap(), &Json::Null);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let j = Json::parse(r#""a\"b\\c\nA😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\"b\\c\nA😀");
    }

    /// Regression (PR 10): a record name carrying control characters
    /// must survive emit → parse bit-exactly. Every char below 0x20 is
    /// escaped on output (`\n`/`\r`/`\t` short forms, `\u00XX`
    /// otherwise), and the parser accepts both the escaped and the raw
    /// form — a newline in a network name can no longer corrupt an
    /// emitted JSON report.
    #[test]
    fn control_characters_roundtrip_through_display() {
        let nasty: String =
            (0u8..0x20).map(|b| b as char).chain("end\"\\".chars()).collect();
        let mut obj = std::collections::BTreeMap::new();
        obj.insert(nasty.clone(), Json::Str(nasty.clone()));
        let j = Json::Obj(obj);
        let printed = j.to_string();
        // The emitted document is printable: no raw control bytes.
        assert!(printed.bytes().all(|b| b >= 0x20), "raw control byte in {printed:?}");
        let back = Json::parse(&printed).unwrap();
        assert_eq!(back, j, "control characters must round-trip bit-exactly");
        assert_eq!(back.field(&nasty).unwrap().as_str().unwrap(), nasty);
        // Raw (unescaped) control chars in the input parse too.
        assert_eq!(Json::parse("\"a\nb\tc\"").unwrap().as_str().unwrap(), "a\nb\tc");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("01a").is_err());
    }

    #[test]
    fn integer_accessors_are_strict() {
        assert!(Json::parse("1.5").unwrap().as_u64().is_err());
        assert!(Json::parse("-1").unwrap().as_u64().is_err());
        assert_eq!(Json::parse("-1").unwrap().as_i64().unwrap(), -1);
    }

    #[test]
    fn display_roundtrips() {
        let doc = r#"{"a": [1, 2.5, "x\ny", true, null], "b": {"c": -7}}"#;
        let j = Json::parse(doc).unwrap();
        let printed = j.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), j);
    }

    #[test]
    fn missing_field_reports_key() {
        let j = Json::parse("{}").unwrap();
        let err = j.field("nope").unwrap_err().to_string();
        assert!(err.contains("nope"), "{err}");
    }
}
