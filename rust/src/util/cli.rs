//! Tiny CLI argument parser (clap-lite, zero-dependency).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed accessors and an auto-generated usage string.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Declarative option spec used for usage/help rendering.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: rest is positional
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    out.opts.insert(body.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name).with_context(|| format!("missing required --{name}"))
    }

    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name} {v:?}: {e}")),
        }
    }

    /// Comma-separated list option.
    pub fn list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Error out on unknown options (catches typos in scripts).
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.opts.keys().chain(self.flags.iter()) {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k} (known: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

/// Render a usage block from option specs.
pub fn usage(cmd: &str, summary: &str, opts: &[OptSpec]) -> String {
    let mut s = format!("{summary}\n\nUSAGE: {cmd} [options]\n\nOPTIONS:\n");
    for o in opts {
        let default = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
        s.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, default));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn key_value_styles() {
        // NOTE: a bare `--flag` followed by a positional would consume it
        // as a value (greedy rule) — subcommands therefore come first.
        let a = parse("run --net mobilenet --csds=8 --verbose");
        assert_eq!(a.get("net"), Some("mobilenet"));
        assert_eq!(a.parse_or("csds", 0usize).unwrap(), 8);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["run".to_string()]);
    }

    #[test]
    fn typed_errors() {
        let a = parse("--csds abc");
        assert!(a.parse_or("csds", 0usize).is_err());
        assert!(a.require("missing").is_err());
    }

    #[test]
    fn lists_and_defaults() {
        let a = parse("--models a,b , c");
        assert_eq!(a.list_or("models", &[]), vec!["a", "b"]);
        let b = parse("");
        assert_eq!(b.list_or("models", &["x"]), vec!["x"]);
        assert_eq!(b.get_or("net", "dflt"), "dflt");
    }

    #[test]
    fn unknown_rejected() {
        let a = parse("--whoops 3");
        assert!(a.check_known(&["net"]).is_err());
        assert!(a.check_known(&["whoops"]).is_ok());
    }

    #[test]
    fn double_dash_terminates() {
        let a = parse("--k v -- --not-an-opt");
        assert_eq!(a.positional(), &["--not-an-opt".to_string()]);
    }
}
