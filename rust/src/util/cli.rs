//! Tiny CLI argument parser (clap-lite, zero-dependency).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed accessors and an auto-generated usage string.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Declarative option spec used for usage/help rendering.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

/// Parsed arguments. A repeated `--key value` accumulates every
/// occurrence in order: [`Args::get`] returns the last one (the usual
/// override-wins CLI convention), [`Args::get_all`] returns them all
/// (repeatable options like `--degrade`).
#[derive(Debug, Clone, Default)]
pub struct Args {
    opts: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: rest is positional
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.entry(k.to_string()).or_default().push(v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    out.opts.entry(body.to_string()).or_default().push(it.next().unwrap());
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Last occurrence of `--name value` (override-wins).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).and_then(|v| v.last()).map(String::as_str)
    }

    /// Every occurrence of `--name value`, in argv order (empty slice
    /// when absent) — for repeatable options like `--degrade`, whose
    /// occurrences used to silently collapse to the last one.
    pub fn get_all(&self, name: &str) -> &[String] {
        match self.opts.get(name) {
            Some(v) => v.as_slice(),
            None => &[],
        }
    }

    /// Parse every occurrence of `--name value` into `T`, in argv order.
    pub fn parse_all<T: std::str::FromStr>(&self, name: &str) -> Result<Vec<T>>
    where
        T::Err: std::fmt::Display,
    {
        self.get_all(name)
            .iter()
            .map(|v| v.parse().map_err(|e| anyhow::anyhow!("--{name} {v:?}: {e}")))
            .collect()
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name).with_context(|| format!("missing required --{name}"))
    }

    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name} {v:?}: {e}")),
        }
    }

    /// Comma-separated list option.
    pub fn list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Error out on unknown options (catches typos in scripts).
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.opts.keys().chain(self.flags.iter()) {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k} (known: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

/// Render a usage block from option specs.
pub fn usage(cmd: &str, summary: &str, opts: &[OptSpec]) -> String {
    let mut s = format!("{summary}\n\nUSAGE: {cmd} [options]\n\nOPTIONS:\n");
    for o in opts {
        let default = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
        s.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, default));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn key_value_styles() {
        // NOTE: a bare `--flag` followed by a positional would consume it
        // as a value (greedy rule) — subcommands therefore come first.
        let a = parse("run --net mobilenet --csds=8 --verbose");
        assert_eq!(a.get("net"), Some("mobilenet"));
        assert_eq!(a.parse_or("csds", 0usize).unwrap(), 8);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["run".to_string()]);
    }

    #[test]
    fn typed_errors() {
        let a = parse("--csds abc");
        assert!(a.parse_or("csds", 0usize).is_err());
        assert!(a.require("missing").is_err());
    }

    #[test]
    fn lists_and_defaults() {
        let a = parse("--models a,b , c");
        assert_eq!(a.list_or("models", &[]), vec!["a", "b"]);
        let b = parse("");
        assert_eq!(b.list_or("models", &["x"]), vec!["x"]);
        assert_eq!(b.get_or("net", "dflt"), "dflt");
    }

    #[test]
    fn repeated_options_accumulate() {
        // Regression: repeated `--key value` used to collapse to one
        // entry in the map, silently dropping e.g. a second --degrade.
        let a = parse("--degrade 0:30:0.6 --steps 5 --degrade 1:60:0.8");
        assert_eq!(a.get_all("degrade"), &["0:30:0.6".to_string(), "1:60:0.8".to_string()]);
        assert_eq!(a.get("degrade"), Some("1:60:0.8"), "get is last-wins");
        assert_eq!(a.get_all("missing"), &[] as &[String]);
        // `--k=v` and `--k v` occurrences interleave in argv order.
        let b = parse("--seed=1 --seed 2 --seed=3");
        assert_eq!(b.get_all("seed"), &["1".to_string(), "2".into(), "3".into()]);
        assert_eq!(b.parse_all::<u64>("seed").unwrap(), vec![1, 2, 3]);
        assert!(parse("--n 1 --n x").parse_all::<u64>("n").is_err());
        assert_eq!(parse("").parse_all::<u64>("n").unwrap(), Vec::<u64>::new());
    }

    #[test]
    fn unknown_rejected() {
        let a = parse("--whoops 3");
        assert!(a.check_known(&["net"]).is_err());
        assert!(a.check_known(&["whoops"]).is_ok());
    }

    #[test]
    fn double_dash_terminates() {
        let a = parse("--k v -- --not-an-opt");
        assert_eq!(a.positional(), &["--not-an-opt".to_string()]);
    }
}
