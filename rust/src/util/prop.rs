//! Property-testing harness (proptest-lite, zero-dependency).
//!
//! Runs a property over `cases` randomized inputs drawn from a seeded
//! [`Rng`]; on failure it reports the failing case index and the seed
//! so the exact input can be replayed (`STANNIS_PROP_SEED=<n>` to
//! pin, `STANNIS_PROP_CASES=<n>` to widen a local run).

use super::rng::Rng;

/// Number of cases per property (default 64; env-overridable).
pub fn default_cases() -> u64 {
    std::env::var("STANNIS_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

fn base_seed() -> u64 {
    std::env::var("STANNIS_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xC0FF_EE00)
}

/// Run `prop` over `default_cases()` seeded RNGs. The property gets a
/// fresh deterministic RNG per case and must panic (assert) on failure.
pub fn check(name: &str, mut prop: impl FnMut(&mut Rng)) {
    let cases = default_cases();
    let seed0 = base_seed();
    for case in 0..cases {
        let seed = seed0 ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(panic) = result {
            let msg = panic
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay with STANNIS_PROP_SEED={seed0} STANNIS_PROP_CASES={n}): {msg}",
                n = case + 1,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add commutes", |rng| {
            let (a, b) = (rng.below(1000) as i64, rng.below(1000) as i64);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports() {
        check("always fails eventually", |rng| {
            assert!(rng.below(4) != 3, "hit the bad value");
        });
    }
}
