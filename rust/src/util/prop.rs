//! Property-testing harness (proptest-lite, zero-dependency).
//!
//! Runs a property over `cases` randomized inputs drawn from a seeded
//! [`Rng`]; on failure it reports the failing case index and the seed
//! so the exact input can be replayed (`STANNIS_PROP_SEED=<n>` to
//! pin, `STANNIS_PROP_CASES=<n>` to widen a local run).

use super::rng::Rng;

/// Number of cases per property (default 64; env-overridable).
pub fn default_cases() -> u64 {
    std::env::var("STANNIS_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

fn base_seed() -> u64 {
    std::env::var("STANNIS_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xC0FF_EE00)
}

/// Run `prop` over `default_cases()` seeded RNGs. The property gets a
/// fresh deterministic RNG per case and must panic (assert) on failure.
pub fn check(name: &str, prop: impl FnMut(&mut Rng)) {
    check_n(name, default_cases(), prop);
}

/// [`check`] with an explicit case count — for expensive properties
/// (e.g. whole-fleet equivalence runs) that would blow the test budget
/// at the default width. `STANNIS_PROP_CASES` only widens an explicit
/// count (a deliberate wide local run must never silently *shrink* a
/// deliberately-sized property).
pub fn check_n(name: &str, cases: u64, mut prop: impl FnMut(&mut Rng)) {
    let cases = match std::env::var("STANNIS_PROP_CASES") {
        Ok(v) => v.parse().map_or(cases, |env: u64| env.max(cases)),
        Err(_) => cases,
    };
    let seed0 = base_seed();
    for case in 0..cases {
        let seed = seed0 ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(panic) = result {
            let msg = panic
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay with STANNIS_PROP_SEED={seed0} STANNIS_PROP_CASES={n}): {msg}",
                n = case + 1,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add commutes", |rng| {
            let (a, b) = (rng.below(1000) as i64, rng.below(1000) as i64);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn check_n_runs_exactly_n_cases() {
        if std::env::var("STANNIS_PROP_CASES").is_ok() {
            return; // the env override intentionally wins
        }
        let mut ran = 0u64;
        check_n("counts cases", 7, |_| ran += 1);
        assert_eq!(ran, 7);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports() {
        check("always fails eventually", |rng| {
            assert!(rng.below(4) != 3, "hit the bad value");
        });
    }
}
