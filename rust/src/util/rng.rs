//! Deterministic xoshiro256**-style PRNG — no external rand crate.
//!
//! Used by the synthetic dataset generator, the DES jitter models, the
//! property-testing harness and the fault injectors. Determinism (seed
//! → identical stream on every platform) is load-bearing: experiments
//! in EXPERIMENTS.md are reproduced byte-for-byte from the seeds they
//! record.

/// xoshiro256** by Blackman & Vigna (public domain reference impl).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so nearby seeds give uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = move || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Self { s: [next_sm(), next_sm(), next_sm(), next_sm()] }
    }

    /// Derive an independent stream (worker-local RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, n). Unbiased via rejection sampling.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(12345);
        let mut b = Rng::new(12345);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn forked_streams_diverge() {
        let mut r = Rng::new(4);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }
}
