//! Correctness-analysis subsystem (DESIGN.md §Static-Analysis).
//!
//! Two halves guard the repo's determinism contracts:
//!
//! - [`lint`] — the `stannis lint` source pass: a zero-dependency
//!   scanner enforcing the *static* preconditions of bit-identity
//!   (no default-hasher iteration, no wall-clock reads in simulated
//!   paths, integer-exact ledgers, resolvable design references,
//!   tested invariant checkers).
//! - [`audit`] — the runtime half: the [`audit::Auditable`] trait
//!   unifies every subsystem's `check_invariants` behind
//!   `FleetRuntime::full_audit()`, and [`audit::Fnv64`] fingerprints
//!   observable state so bit-identity failures bisect to the first
//!   divergent event.

pub mod audit;
pub mod lint;
