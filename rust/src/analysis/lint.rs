//! `stannis lint` — the determinism source pass (DESIGN.md §Static-Analysis).
//!
//! Every bit-identity contract in this repo (fast-forward == per-step,
//! slicing invariance, streaming == retained, endurance-off identity,
//! audit-on == audit-off) has static preconditions: no iteration over
//! default-hasher collections, no wall-clock reads in simulated paths,
//! no float accumulation in the integer-exact ledgers. This module is a
//! zero-dependency, hand-rolled scanner over `rust/src/**.rs` that
//! enforces those preconditions as hard CI failures, in the same
//! no-external-crates style as `util::json`.
//!
//! Rules (each a [`Rule`] impl, each with a fixture under
//! `rust/lint_fixtures/`):
//!
//! - `hash-iter`: no default-hasher map/set types outside an explicit
//!   allow tag — their iteration order is per-process random.
//! - `wallclock`: no wall-clock time sources outside `metrics/bench.rs`
//!   (benches and examples are not scanned; they are the sanctioned
//!   timing layer).
//! - `float-ledger`: no float casts or float `+=` accumulation inside
//!   the ledger types (`FleetTotals`-shaped reports) without a tag.
//! - `design-ref`: every `DESIGN.md` section reference in the source
//!   must resolve to a real heading, so docs cannot rot silently.
//! - `invariant-test`: every public `check_invariants` must be
//!   exercised by at least one test that names its type.
//!
//! Allowlist grammar: `// lint: allow(rule-name)` on the offending line
//! itself, or in the contiguous run of comment/attribute lines directly
//! above it. Tags should carry a justification after the closing paren.
//!
//! The scanner needles are assembled at runtime from string fragments
//! so this file never contains the contiguous patterns it hunts — the
//! linter lints itself as part of the tree.

use std::collections::BTreeSet;
use std::fmt;
use std::fs;
use std::ops::Range;
use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::Result;

/// One finding, pointing at a 1-based source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// A source file split into lines, addressed by its path relative to
/// the scanned root.
#[derive(Debug, Clone)]
pub struct SourceFile {
    pub rel: String,
    pub lines: Vec<String>,
}

/// The unit the rules run over: the scanned files, the set of valid
/// design-doc heading tokens, and a reference corpus of test/bench
/// sources (searched by `invariant-test`, never scanned for
/// violations).
#[derive(Debug, Clone)]
pub struct SourceTree {
    pub files: Vec<SourceFile>,
    pub design_headings: BTreeSet<String>,
    pub test_corpus: Vec<SourceFile>,
}

impl SourceTree {
    /// Load every `.rs` under `src_dir` (sorted, recursive), the
    /// heading tokens of `design` (if given), and every `.rs` under
    /// each existing `corpus_dirs` entry as reference-only corpus.
    pub fn load(src_dir: &Path, design: Option<&Path>, corpus_dirs: &[PathBuf]) -> Result<SourceTree> {
        let mut files = Vec::new();
        walk_rs(src_dir, src_dir, &mut files)?;
        let design_headings = match design {
            Some(p) if p.is_file() => parse_design_headings(p)?,
            _ => BTreeSet::new(),
        };
        let mut test_corpus = Vec::new();
        for dir in corpus_dirs {
            if dir.is_dir() {
                walk_rs(dir, dir, &mut test_corpus)?;
            }
        }
        Ok(SourceTree { files, design_headings, test_corpus })
    }
}

/// A single lint rule: a stable slug plus a pass over the tree.
pub trait Rule {
    fn name(&self) -> &'static str;
    fn check(&self, tree: &SourceTree, out: &mut Vec<Diagnostic>);
}

/// The full rule set, in reporting order.
pub fn rules() -> Vec<Box<dyn Rule>> {
    vec![
        Box::new(HashIter),
        Box::new(Wallclock),
        Box::new(FloatLedger),
        Box::new(DesignRef),
        Box::new(InvariantTest),
    ]
}

/// Run every rule over an already-loaded tree; diagnostics come back
/// sorted by (file, line, rule) so output order is deterministic.
pub fn lint_tree(tree: &SourceTree) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for rule in rules() {
        rule.check(tree, &mut out);
    }
    out.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    out
}

/// Lint the shipped tree rooted at `repo_root`: scans `rust/src`,
/// resolves headings against `DESIGN.md`, and reads `rust/tests` +
/// `rust/benches` as the test corpus.
pub fn run(repo_root: &Path) -> Result<Vec<Diagnostic>> {
    let tree = SourceTree::load(
        &repo_root.join("rust/src"),
        Some(&repo_root.join("DESIGN.md")),
        &[repo_root.join("rust/tests"), repo_root.join("rust/benches")],
    )?;
    Ok(lint_tree(&tree))
}

/// Walk up from `start` to the first directory that looks like the
/// repo root (has `rust/src` and `DESIGN.md`).
pub fn find_repo_root(start: &Path) -> Option<PathBuf> {
    let mut cur = Some(start.to_path_buf());
    while let Some(dir) = cur {
        if dir.join("rust/src").is_dir() && dir.join("DESIGN.md").is_file() {
            return Some(dir);
        }
        cur = dir.parent().map(Path::to_path_buf);
    }
    None
}

// ---------------------------------------------------------------------------
// shared scanning helpers

/// Assemble a needle from fragments at runtime, so the source of this
/// module never contains the contiguous pattern it scans for.
fn needle(parts: &[&str]) -> String {
    parts.concat()
}

/// True when the diagnostic at `idx` is suppressed by an allow tag:
/// `lint: allow(<rule>)` on the line itself or inside the contiguous
/// block of comment/attribute lines directly above it.
fn allowed(f: &SourceFile, idx: usize, rule: &str) -> bool {
    let tag = format!("lint: allow({rule})");
    if f.lines[idx].contains(&tag) {
        return true;
    }
    let mut i = idx;
    while i > 0 {
        i -= 1;
        let t = f.lines[i].trim_start();
        if t.starts_with("//") {
            if t.contains(&tag) {
                return true;
            }
        } else if t.starts_with("#[") || t.starts_with("#![") {
            continue;
        } else {
            break;
        }
    }
    false
}

fn diag(rule: &'static str, f: &SourceFile, idx: usize, message: String) -> Diagnostic {
    Diagnostic { rule, file: f.rel.clone(), line: idx + 1, message }
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

fn is_ref_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-')
}

fn read_dir_sorted(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut entries = Vec::new();
    for e in fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))? {
        entries.push(e?.path());
    }
    entries.sort();
    Ok(entries)
}

fn walk_rs(dir: &Path, base: &Path, out: &mut Vec<SourceFile>) -> Result<()> {
    for path in read_dir_sorted(dir)? {
        if path.is_dir() {
            walk_rs(&path, base, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let text = fs::read_to_string(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            let rel = path
                .strip_prefix(base)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            out.push(SourceFile { rel, lines: text.lines().map(String::from).collect() });
        }
    }
    Ok(())
}

fn parse_design_headings(path: &Path) -> Result<BTreeSet<String>> {
    let text =
        fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
    let mut out = BTreeSet::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("## §") {
            let token: String = rest.chars().take_while(|&c| is_ref_char(c)).collect();
            let token = token.trim_end_matches('.');
            if !token.is_empty() {
                out.insert(token.to_string());
            }
        }
    }
    Ok(out)
}

/// Brace-tracked extent of an item starting at `start` (exclusive end
/// line index). A braceless item (`struct X;`) ends at its semicolon.
fn region_end(f: &SourceFile, start: usize) -> usize {
    let mut depth: i64 = 0;
    let mut opened = false;
    for i in start..f.lines.len() {
        for c in f.lines[i].chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if opened && depth <= 0 {
            return i + 1;
        }
        if !opened && f.lines[i].trim_end().ends_with(';') {
            return i + 1;
        }
    }
    f.lines.len()
}

/// True when `prefix`+`name` occurs in `line` followed by a non-ident
/// character (word-boundary match on the type name).
fn has_marker(line: &str, prefix: &str, name: &str) -> bool {
    let pat = format!("{prefix}{name}");
    let mut start = 0;
    while let Some(pos) = line[start..].find(&pat) {
        let end = start + pos + pat.len();
        let boundary = !matches!(line[end..].chars().next(), Some(c) if is_ident_char(c));
        if boundary {
            return true;
        }
        start = end;
    }
    false
}

// ---------------------------------------------------------------------------
// rule: hash-iter

/// Default-hasher collections randomize iteration order per process;
/// one stray iteration in a report path breaks replay stability.
struct HashIter;

impl Rule for HashIter {
    fn name(&self) -> &'static str {
        "hash-iter"
    }

    fn check(&self, tree: &SourceTree, out: &mut Vec<Diagnostic>) {
        let needles = [needle(&["Hash", "Map"]), needle(&["Hash", "Set"])];
        for f in &tree.files {
            for (i, line) in f.lines.iter().enumerate() {
                for n in &needles {
                    if line.contains(n.as_str()) {
                        if !allowed(f, i, self.name()) {
                            out.push(diag(
                                self.name(),
                                f,
                                i,
                                format!(
                                    "default-hasher `{n}` — use the BTree equivalent, or tag \
                                     `// lint: allow({})` with a keyed-lookup-only justification",
                                    self.name()
                                ),
                            ));
                        }
                        break;
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// rule: wallclock

/// Wall-clock reads make two runs of the same trace observably differ;
/// simulated paths must use `SimTime` only. `metrics/bench.rs` is the
/// one sanctioned in-crate timing helper.
struct Wallclock;

impl Rule for Wallclock {
    fn name(&self) -> &'static str {
        "wallclock"
    }

    fn check(&self, tree: &SourceTree, out: &mut Vec<Diagnostic>) {
        let needles = [
            needle(&["Instant", "::"]),
            needle(&["System", "Time::"]),
            needle(&["std::", "time"]),
        ];
        for f in &tree.files {
            if f.rel.ends_with("metrics/bench.rs") {
                continue;
            }
            for (i, line) in f.lines.iter().enumerate() {
                for n in &needles {
                    if line.contains(n.as_str()) {
                        if !allowed(f, i, self.name()) {
                            out.push(diag(
                                self.name(),
                                f,
                                i,
                                format!(
                                    "wall-clock source `{n}` outside the bench layer — \
                                     simulated paths use SimTime; tag `// lint: allow({})` \
                                     if timing the process itself is the point",
                                    self.name()
                                ),
                            ));
                        }
                        break;
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// rule: float-ledger

/// The report ledgers are integer-exact by design: merging per-job
/// results must be associative and replay-stable, so float casts and
/// float `+=` inside a ledger struct/impl need an explicit tag naming
/// why the value is display-only.
struct FloatLedger;

impl FloatLedger {
    fn ledger_names() -> Vec<String> {
        vec![
            needle(&["Fleet", "Totals"]),
            needle(&["Wear", "Report"]),
            needle(&["Ecc", "Stats"]),
        ]
    }

    /// Regions of `f` belonging to ledger types: `(range, is_struct)`.
    fn regions(f: &SourceFile, names: &[String]) -> Vec<(Range<usize>, bool)> {
        let mut out = Vec::new();
        for (i, line) in f.lines.iter().enumerate() {
            for n in names {
                let is_struct = has_marker(line, "struct ", n);
                let is_impl =
                    has_marker(line, "impl ", n) || has_marker(line, "for ", n);
                if is_struct || is_impl {
                    out.push((i..region_end(f, i), is_struct));
                    break;
                }
            }
        }
        out
    }
}

impl Rule for FloatLedger {
    fn name(&self) -> &'static str {
        "float-ledger"
    }

    fn check(&self, tree: &SourceTree, out: &mut Vec<Diagnostic>) {
        let names = Self::ledger_names();
        let cast = needle(&["as ", "f64"]);
        let secs = needle(&["as_secs_", "f64"]);
        let field_marker = needle(&[": ", "f64"]);
        for f in &tree.files {
            let regions = Self::regions(f, &names);
            // Pass 1: collect the f64 field names declared by ledger structs.
            let mut fields: Vec<String> = Vec::new();
            for (range, is_struct) in &regions {
                if !is_struct {
                    continue;
                }
                for idx in range.clone() {
                    if let Some(name) = f64_field(&f.lines[idx], &field_marker) {
                        fields.push(name);
                    }
                }
            }
            // Pass 2: flag float casts and float accumulation in any
            // ledger region.
            for (range, _) in &regions {
                for idx in range.clone() {
                    let line = &f.lines[idx];
                    let hit = line.contains(secs.as_str())
                        || line.contains(cast.as_str())
                        || (line.contains("+=")
                            && fields.iter().any(|fd| line.contains(fd.as_str())));
                    if hit && !allowed(f, idx, self.name()) {
                        out.push(diag(
                            self.name(),
                            f,
                            idx,
                            format!(
                                "float accumulation inside a ledger region — ledgers are \
                                 integer-exact; tag `// lint: allow({})` with a \
                                 display-only justification",
                                self.name()
                            ),
                        ));
                    }
                }
            }
        }
    }
}

/// If `line` declares an f64 field (`name: f64`), return the name.
fn f64_field(line: &str, marker: &str) -> Option<String> {
    let pos = line.find(marker)?;
    let left = &line[..pos];
    let rev: String =
        left.chars().rev().take_while(|&c| is_ident_char(c)).collect();
    let name: String = rev.chars().rev().collect();
    if name.is_empty() { None } else { Some(name) }
}

// ---------------------------------------------------------------------------
// rule: design-ref

/// Section references in doc comments must resolve to a real heading
/// in DESIGN.md, so the design doc and the code cannot drift apart
/// silently.
struct DesignRef;

impl Rule for DesignRef {
    fn name(&self) -> &'static str {
        "design-ref"
    }

    fn check(&self, tree: &SourceTree, out: &mut Vec<Diagnostic>) {
        let n = needle(&["DESIGN.md", " §"]);
        for f in &tree.files {
            for (i, line) in f.lines.iter().enumerate() {
                let mut start = 0;
                while let Some(pos) = line[start..].find(n.as_str()) {
                    let after = start + pos + n.len();
                    let token: String =
                        line[after..].chars().take_while(|&c| is_ref_char(c)).collect();
                    let token = token.trim_end_matches('.').to_string();
                    let resolved =
                        !token.is_empty() && tree.design_headings.contains(&token);
                    if !resolved && !allowed(f, i, self.name()) {
                        let what = if token.is_empty() {
                            "dangling design reference (no section token)".to_string()
                        } else {
                            format!("design reference §{token} matches no DESIGN.md heading")
                        };
                        out.push(diag(self.name(), f, i, what));
                    }
                    start = after;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// rule: invariant-test

/// An invariant checker nobody calls is dead armor: every public
/// `check_invariants` must be exercised by at least one test region
/// (in-file `#[cfg(test)]` tail, or the tests/benches corpus) that
/// names the implementing type.
struct InvariantTest;

impl Rule for InvariantTest {
    fn name(&self) -> &'static str {
        "invariant-test"
    }

    fn check(&self, tree: &SourceTree, out: &mut Vec<Diagnostic>) {
        let def = needle(&["pub fn ", "check_", "invariants"]);
        let call = needle(&["check_", "invariants"]);
        let mut regions: Vec<String> = Vec::new();
        for f in &tree.files {
            if let Some(pos) = f.lines.iter().position(|l| l.contains("#[cfg(test)]")) {
                regions.push(f.lines[pos..].join("\n"));
            }
        }
        for f in &tree.test_corpus {
            regions.push(f.lines.join("\n"));
        }
        for f in &tree.files {
            for (i, line) in f.lines.iter().enumerate() {
                if !line.contains(def.as_str()) || allowed(f, i, self.name()) {
                    continue;
                }
                let Some(ty) = enclosing_impl_type(f, i) else {
                    out.push(diag(
                        self.name(),
                        f,
                        i,
                        format!("`{def}` outside any impl block"),
                    ));
                    continue;
                };
                let covered = regions
                    .iter()
                    .any(|r| r.contains(call.as_str()) && r.contains(ty.as_str()));
                if !covered {
                    out.push(diag(
                        self.name(),
                        f,
                        i,
                        format!(
                            "`{def}` on {ty} is not exercised by any test that names {ty}"
                        ),
                    ));
                }
            }
        }
    }
}

/// The self type of the nearest enclosing `impl` above `def_idx`.
fn enclosing_impl_type(f: &SourceFile, def_idx: usize) -> Option<String> {
    for i in (0..def_idx).rev() {
        let t = f.lines[i].trim_start();
        if let Some(rest) = t.strip_prefix("impl") {
            if rest.starts_with('<') || rest.starts_with(' ') {
                if let Some(ty) = impl_self_type(rest) {
                    return Some(ty);
                }
            }
        }
    }
    None
}

/// Parse the self type out of the text following `impl`:
/// `" Ftl {"`, `"<E> EventQueue<E> {"`, `" Auditable for Ftl {"`.
fn impl_self_type(rest: &str) -> Option<String> {
    let mut s = rest;
    if let Some(stripped) = s.strip_prefix('<') {
        let mut depth = 1usize;
        let mut end = stripped.len();
        for (i, c) in stripped.char_indices() {
            match c {
                '<' => depth += 1,
                '>' => {
                    depth -= 1;
                    if depth == 0 {
                        end = i + 1;
                        break;
                    }
                }
                _ => {}
            }
        }
        s = &stripped[end..];
    }
    if let Some(pos) = s.find(" for ") {
        s = &s[pos + 5..];
    }
    let s = s.trim_start();
    let name: String = s.chars().take_while(|&c| is_ident_char(c)).collect();
    if name.is_empty() { None } else { Some(name) }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR"))
    }

    fn fixture_tree() -> SourceTree {
        SourceTree::load(
            &repo_root().join("rust/lint_fixtures"),
            Some(&repo_root().join("DESIGN.md")),
            &[],
        )
        .unwrap()
    }

    fn fixture_diags(rule: &str) -> Vec<Diagnostic> {
        lint_tree(&fixture_tree())
            .into_iter()
            .filter(|d| d.rule == rule)
            .collect()
    }

    #[test]
    fn hash_iter_fires_once_and_respects_the_allow_tag() {
        let d = fixture_diags("hash-iter");
        assert_eq!(d.len(), 1, "{d:#?}");
        assert_eq!(d[0].file, "hash_iter.rs");
    }

    #[test]
    fn wallclock_fires_once_and_respects_the_allow_tag() {
        let d = fixture_diags("wallclock");
        assert_eq!(d.len(), 1, "{d:#?}");
        assert_eq!(d[0].file, "wallclock.rs");
    }

    #[test]
    fn float_ledger_fires_once_and_respects_the_allow_tag() {
        let d = fixture_diags("float-ledger");
        assert_eq!(d.len(), 1, "{d:#?}");
        assert_eq!(d[0].file, "float_ledger.rs");
    }

    #[test]
    fn design_ref_fires_on_unknown_heading_only() {
        let d = fixture_diags("design-ref");
        assert_eq!(d.len(), 1, "{d:#?}");
        assert_eq!(d[0].file, "design_ref.rs");
        assert!(d[0].message.contains("No-Such-Section"), "{}", d[0].message);
    }

    #[test]
    fn invariant_test_fires_on_the_untested_type_only() {
        let d = fixture_diags("invariant-test");
        assert_eq!(d.len(), 1, "{d:#?}");
        assert_eq!(d[0].file, "invariant_test.rs");
        assert!(d[0].message.contains("Orphan"), "{}", d[0].message);
    }

    #[test]
    fn every_rule_fires_somewhere_on_the_fixture_tree() {
        let diags = lint_tree(&fixture_tree());
        for r in rules() {
            assert!(
                diags.iter().any(|d| d.rule == r.name()),
                "rule {} silent on its fixture",
                r.name()
            );
        }
    }

    #[test]
    fn shipped_tree_is_clean() {
        let diags = run(&repo_root()).unwrap();
        assert!(
            diags.is_empty(),
            "shipped tree has lint diagnostics:\n{}",
            diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
        );
    }

    #[test]
    fn diagnostics_are_sorted_and_display_cleanly() {
        let diags = lint_tree(&fixture_tree());
        let keys: Vec<_> =
            diags.iter().map(|d| (d.file.clone(), d.line, d.rule)).collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
        for d in &diags {
            let s = d.to_string();
            assert!(s.contains(&format!("[{}]", d.rule)), "{s}");
        }
    }

    #[test]
    fn impl_self_type_parses_the_shapes_we_use() {
        assert_eq!(impl_self_type(" Ftl {").as_deref(), Some("Ftl"));
        assert_eq!(impl_self_type("<E> EventQueue<E> {").as_deref(), Some("EventQueue"));
        assert_eq!(impl_self_type(" Auditable for DevicePool {").as_deref(), Some("DevicePool"));
        assert_eq!(
            impl_self_type("<E> Auditable for EventQueue<E> {").as_deref(),
            Some("EventQueue")
        );
    }

    #[test]
    fn allow_tag_reaches_through_attribute_lines() {
        let f = SourceFile {
            rel: "x.rs".into(),
            lines: vec![
                "// lint: allow(demo) — justified".into(),
                "#[allow(dead_code)]".into(),
                "let x = 1;".into(),
            ],
        };
        assert!(allowed(&f, 2, "demo"));
        assert!(!allowed(&f, 2, "other"));
    }

    #[test]
    fn find_repo_root_walks_up_from_src() {
        let start = repo_root().join("rust/src/analysis");
        assert_eq!(find_repo_root(&start), Some(repo_root()));
    }
}
