//! Runtime audit + fingerprint framework (DESIGN.md §Static-Analysis).
//!
//! Every stateful subsystem of the simulator grew its own
//! `check_invariants` over the PRs (FTL mapping coherence, DLM lock
//! exclusion, data-plane slot accounting, event-queue slab bookkeeping,
//! job-table slab indexing). [`Auditable`] unifies them behind one
//! trait so `FleetRuntime::full_audit()` can sweep the whole runtime —
//! after *every pumped event* when `FleetConfig::audit` / `--audit` is
//! armed, and always inside the property harness.
//!
//! [`Auditable::fingerprint`] folds the component's *observable* state
//! into a deterministic [`Fnv64`] hash. Bit-identity contracts (fast
//! forward == per step, slicing invariance, streaming == retained,
//! audit on == audit off) compare fingerprints per event instead of
//! final reports, so a divergence bisects to the first divergent event.
//! Implementations must hash only replay-deterministic state in a
//! deterministic order: sort anything that lives in a heap, hash floats
//! via their IEEE bit patterns, never hash addresses or capacities.

use crate::Result;

/// A component that can verify its internal invariants and fold its
/// observable state into a fingerprint. Implemented by the `Ftl`, the
/// `Dlm`, the `DevicePool`, the `DataPlane`, the `EventQueue` slab and
/// the runtime's `JobSlab`; `FleetRuntime::full_audit()` sweeps all of
/// them.
pub trait Auditable {
    /// Short stable component name, used to prefix audit failures.
    fn component(&self) -> &'static str;

    /// Check every internal invariant; `Err` means corrupted state.
    /// Must be read-only — an audited run must stay bit-identical to an
    /// unaudited one.
    fn audit(&self) -> Result<()>;

    /// Fold the component's observable state into `h`. Deterministic:
    /// the same logical state always hashes identically, regardless of
    /// how it was reached.
    fn fingerprint(&self, h: &mut Fnv64);
}

/// FNV-1a, 64-bit: the crate's one deterministic hasher. Chosen for
/// the audit path because it is trivially portable (no per-process
/// keys, unlike `DefaultHasher`), byte-order explicit, and fast enough
/// to run after every event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fnv64 {
    state: u64,
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;

    pub fn new() -> Self {
        Self { state: Self::OFFSET }
    }

    pub fn write_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= u64::from(b);
            self.state = self.state.wrapping_mul(Self::PRIME);
        }
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write_bytes(&v.to_le_bytes());
    }

    pub fn write_u32(&mut self, v: u32) {
        self.write_u64(u64::from(v));
    }

    pub fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64);
    }

    pub fn write_bool(&mut self, v: bool) {
        self.write_bytes(&[u8::from(v)]);
    }

    /// Length-prefixed, so `("ab", "c")` and `("a", "bc")` differ.
    pub fn write_str(&mut self, s: &str) {
        self.write_u64(s.len() as u64);
        self.write_bytes(s.as_bytes());
    }

    /// Hash a float by its exact IEEE-754 bit pattern — fingerprints
    /// witness *bit* identity, not approximate equality.
    pub fn write_f64_bits(&mut self, v: f64) {
        self.write_u64(v.to_bits());
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

/// Fingerprint one component in isolation (fresh hasher).
pub fn fingerprint_of(c: &dyn Auditable) -> u64 {
    let mut h = Fnv64::new();
    c.fingerprint(&mut h);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_known_vectors() {
        // Canonical FNV-1a/64 test vectors.
        assert_eq!(Fnv64::new().finish(), 0xcbf2_9ce4_8422_2325);
        let mut h = Fnv64::new();
        h.write_bytes(b"a");
        assert_eq!(h.finish(), 0xaf63_dc4c_8601_ec8c);
        let mut h = Fnv64::new();
        h.write_bytes(b"foobar");
        assert_eq!(h.finish(), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn length_prefix_disambiguates_strings() {
        let mut a = Fnv64::new();
        a.write_str("ab");
        a.write_str("c");
        let mut b = Fnv64::new();
        b.write_str("a");
        b.write_str("bc");
        assert_ne!(a.finish(), b.finish());
    }

    #[test]
    fn float_bits_distinguish_negative_zero() {
        let mut a = Fnv64::new();
        a.write_f64_bits(0.0);
        let mut b = Fnv64::new();
        b.write_f64_bits(-0.0);
        assert_ne!(a.finish(), b.finish(), "bit identity, not numeric equality");
    }

    #[test]
    fn order_matters() {
        let mut a = Fnv64::new();
        a.write_u64(1);
        a.write_u64(2);
        let mut b = Fnv64::new();
        b.write_u64(2);
        b.write_u64(1);
        assert_ne!(a.finish(), b.finish());
    }
}
