//! Component power model + energy meter (Table II's wall-power meter).
//!
//! The paper measures server input power with an off-the-shelf meter
//! while 0/4/8/16/24 Newport CSDs train MobileNetV2, against a baseline
//! server whose 24 bays hold conventional Micron 11 TB SSDs. We rebuild
//! that meter from components:
//!
//!   P_system(k) = P_base + P_host(util) + k·P_newport(training)
//!                 + (24-k)·P_idle_storage + P_link(traffic)
//!
//! Component wattages are calibrated so the 0-CSD and 24-CSD endpoints
//! land on Table II's 13.10 and ~4 J/image; the intermediate rows then
//! *fall out* of the model rather than being copied. (Note recorded in
//! EXPERIMENTS.md: the paper's own FLOPS/W row is not consistent with
//! its J/image row; we report both from our model.)

use crate::sim::SimTime;

/// Calibrated component wattages.
#[derive(Debug, Clone)]
pub struct PowerConfig {
    /// Chassis floor: fans, PSU loss, BMC, DRAM refresh.
    pub base_w: f64,
    /// Host package (Xeon 4108 + board) when training.
    pub host_active_w: f64,
    /// Host package when idle.
    pub host_idle_w: f64,
    /// One Micron-class SSD idling in a bay.
    pub storage_idle_w: f64,
    /// One Newport CSD idling (flash + controller, ISP parked).
    pub newport_idle_w: f64,
    /// Added power when a Newport ISP engine trains (quad A53 + DRAM).
    pub newport_isp_active_w: f64,
    /// NVMe/PCIe link energy per byte moved host<->device. Also prices
    /// the fleet data plane's movement relays and host staged batches
    /// (integer byte counters converted once in `fleet::Job::report`).
    pub link_pj_per_byte: f64,
    /// Flash array energy per page read (16 KiB).
    pub flash_read_uj: f64,
    /// Flash array energy per page program — layout and rebalance
    /// writes of the data plane's shard maps book against this.
    pub flash_prog_uj: f64,
}

impl Default for PowerConfig {
    fn default() -> Self {
        Self {
            base_w: 118.0,
            host_active_w: 145.0,
            host_idle_w: 45.0,
            storage_idle_w: 6.0,
            // Table II's 24-CSD endpoint implies ~3.1 W per training
            // Newport (4.02 J/img at ~2.7x the host-alone throughput) —
            // below an idle Micron, which is exactly the paper's pitch.
            newport_idle_w: 1.2,
            newport_isp_active_w: 1.9,
            link_pj_per_byte: 15.0,
            flash_read_uj: 60.0,
            flash_prog_uj: 180.0,
        }
    }
}

impl PowerConfig {
    /// Steady-state system power with `active_csds` Newports training
    /// (the remaining `total_bays - active_csds` bays hold idle
    /// conventional SSDs) and the host training iff `host_active`.
    pub fn system_power_w(&self, active_csds: usize, total_bays: usize, host_active: bool) -> f64 {
        let host = if host_active { self.host_active_w } else { self.host_idle_w };
        let idle_bays = total_bays.saturating_sub(active_csds);
        self.base_w
            + host
            + active_csds as f64 * (self.newport_idle_w + self.newport_isp_active_w)
            + idle_bays as f64 * self.storage_idle_w
    }
}

/// Energy ledger, integrated over simulated time.
///
/// Power draws are accumulated as *integer simulated time* per
/// `(component, watts)` pair and converted to joules only when read.
/// Because `SimTime` addition is exact, the total is independent of how
/// an interval was chopped into sub-intervals — integrating a window in
/// one `add_power` call is bit-identical to integrating it event by
/// event. This is what lets the steady-state fast-forward path book the
/// same energy as the per-step path down to the last bit
/// (DESIGN.md §Perf).
#[derive(Debug, Clone, Default)]
pub struct EnergyMeter {
    /// Exact time integrated per (component, watts-bit-pattern).
    power: std::collections::BTreeMap<(&'static str, u64), SimTime>,
    /// Direct energy events (page read, link transfer), joules.
    energy: std::collections::BTreeMap<&'static str, f64>,
}

impl EnergyMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Integrate `watts` over `dt`.
    pub fn add_power(&mut self, component: &'static str, watts: f64, dt: SimTime) {
        *self.power.entry((component, watts.to_bits())).or_insert(SimTime::ZERO) += dt;
    }

    /// Add a fixed energy event (page read, link transfer).
    pub fn add_energy(&mut self, component: &'static str, joules: f64) {
        *self.energy.entry(component).or_insert(0.0) += joules;
    }

    pub fn total_joules(&self) -> f64 {
        // Deterministic summation order (BTreeMap key order), so two
        // meters holding identical ledgers report identical floats.
        let p: f64 = self
            .power
            .iter()
            .map(|(&(_, w), &dt)| f64::from_bits(w) * dt.as_secs_f64())
            .sum();
        p + self.energy.values().sum::<f64>()
    }

    pub fn component_joules(&self, component: &str) -> f64 {
        let p: f64 = self
            .power
            .iter()
            .filter(|((c, _), _)| *c == component)
            .map(|(&(_, w), &dt)| f64::from_bits(w) * dt.as_secs_f64())
            .sum();
        p + self.energy.get(component).copied().unwrap_or(0.0)
    }

    pub fn breakdown(&self) -> impl Iterator<Item = (&'static str, f64)> + '_ {
        let mut by: std::collections::BTreeMap<&'static str, f64> = self.energy.clone();
        for (&(c, w), &dt) in &self.power {
            *by.entry(c).or_insert(0.0) += f64::from_bits(w) * dt.as_secs_f64();
        }
        by.into_iter()
    }
}

/// Account one training interval: steady-state power plus I/O events.
#[allow(clippy::too_many_arguments)]
pub fn account_interval(
    meter: &mut EnergyMeter,
    cfg: &PowerConfig,
    dt: SimTime,
    active_csds: usize,
    total_bays: usize,
    host_active: bool,
    link_bytes: u64,
    flash_reads: u64,
    flash_progs: u64,
) {
    let host = if host_active { cfg.host_active_w } else { cfg.host_idle_w };
    meter.add_power("base", cfg.base_w, dt);
    meter.add_power("host", host, dt);
    meter.add_power(
        "newport",
        active_csds as f64 * (cfg.newport_idle_w + cfg.newport_isp_active_w),
        dt,
    );
    meter.add_power(
        "idle_storage",
        total_bays.saturating_sub(active_csds) as f64 * cfg.storage_idle_w,
        dt,
    );
    meter.add_energy("link", link_bytes as f64 * cfg.link_pj_per_byte * 1e-12);
    meter.add_energy(
        "flash",
        flash_reads as f64 * cfg.flash_read_uj * 1e-6
            + flash_progs as f64 * cfg.flash_prog_uj * 1e-6,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_endpoint_matches_table2() {
        // 0 CSDs: host trains alone, 24 idle Micron SSDs.
        let cfg = PowerConfig::default();
        let p0 = cfg.system_power_w(0, 24, true);
        // Paper: 13.10 J/img at 31.05 img/s -> 406.8 W.
        let j_per_img = p0 / 31.05;
        assert!(
            (j_per_img - 13.10).abs() < 0.35,
            "J/img at 0 CSDs = {j_per_img:.2} (P={p0:.0} W)"
        );
    }

    #[test]
    fn full_rack_endpoint_matches_table2() {
        let cfg = PowerConfig::default();
        let p24 = cfg.system_power_w(24, 24, true);
        // Paper: 2.7x speedup -> ~83.8 img/s aggregate, 4.02 J/img.
        let j_per_img = p24 / (31.05 * 2.7);
        assert!(
            (j_per_img - 4.02).abs() < 0.4,
            "J/img at 24 CSDs = {j_per_img:.2} (P={p24:.0} W)"
        );
    }

    #[test]
    fn more_csds_less_power_per_bay_when_replacing_idle_ssds() {
        let cfg = PowerConfig::default();
        // A training Newport draws less than an idle Micron in this
        // calibration — the paper's counterintuitive headline.
        assert!(cfg.newport_idle_w + cfg.newport_isp_active_w < cfg.storage_idle_w);
        assert!(cfg.system_power_w(24, 24, true) < cfg.system_power_w(0, 24, true));
    }

    #[test]
    fn meter_integrates() {
        let mut m = EnergyMeter::new();
        m.add_power("host", 100.0, SimTime::secs(10));
        m.add_energy("flash", 0.5);
        assert!((m.total_joules() - 1000.5).abs() < 1e-9);
        assert!((m.component_joules("host") - 1000.0).abs() < 1e-9);
        assert_eq!(m.component_joules("nope"), 0.0);
    }

    #[test]
    fn integration_is_chop_invariant() {
        // The fast-forward guarantee: one big interval and many small
        // ones must produce the *bit-identical* total.
        let mut whole = EnergyMeter::new();
        whole.add_power("newport", 3.1, SimTime::ns(7 * 1_234_567));
        whole.add_power("host", 145.0, SimTime::ns(7 * 1_234_567));
        let mut chopped = EnergyMeter::new();
        for _ in 0..7 {
            chopped.add_power("newport", 3.1, SimTime::ns(1_234_567));
            chopped.add_power("host", 145.0, SimTime::ns(1_234_567));
        }
        assert_eq!(whole.total_joules().to_bits(), chopped.total_joules().to_bits());
        assert_eq!(
            whole.component_joules("newport").to_bits(),
            chopped.component_joules("newport").to_bits()
        );
        let a: Vec<_> = whole.breakdown().collect();
        let b: Vec<_> = chopped.breakdown().collect();
        assert_eq!(a.len(), b.len());
        for ((ka, va), (kb, vb)) in a.iter().zip(&b) {
            assert_eq!(ka, kb);
            assert_eq!(va.to_bits(), vb.to_bits());
        }
    }

    #[test]
    fn account_interval_sums_components() {
        let mut m = EnergyMeter::new();
        let cfg = PowerConfig::default();
        account_interval(&mut m, &cfg, SimTime::secs(1), 4, 24, true, 1 << 30, 1000, 100);
        let steady = cfg.system_power_w(4, 24, true);
        let expect_steady = steady * 1.0;
        let total = m.total_joules();
        assert!(total > expect_steady, "I/O events must add energy");
        assert!((m.component_joules("base") - cfg.base_w).abs() < 1e-9);
    }
}
