//! Cluster assembly: turn an [`ExperimentConfig`] into the live pieces
//! a run needs (engine, dataset, placement, trainer) — the glue between
//! the config system and the coordinator.

use std::sync::Arc;

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::{balance, Placement, StannisTrainer, TrainConfig};
use crate::data::Dataset;
use crate::runtime::{default_artifacts_dir, Engine};

/// A fully wired real-execution cluster.
pub struct Cluster {
    pub engine: Arc<Engine>,
    pub dataset: Dataset,
    pub placement: Placement,
    pub cfg: ExperimentConfig,
}

impl Cluster {
    /// Build from config: load artifacts, generate the dataset,
    /// balance the shards (Eq. 1).
    pub fn bring_up(cfg: ExperimentConfig) -> Result<Self> {
        let engine = Arc::new(Engine::new(default_artifacts_dir())?);
        Self::bring_up_with_engine(cfg, engine)
    }

    /// Same, reusing an existing engine (tests share one to avoid
    /// recompiling artifacts).
    pub fn bring_up_with_engine(cfg: ExperimentConfig, engine: Arc<Engine>) -> Result<Self> {
        // Validate the network + batch artifacts up front.
        let net = engine.network(&cfg.network)?;
        anyhow::ensure!(
            net.train_artifact(cfg.bs_csd).is_some(),
            "network {} has no train artifact for bs_csd={} (have {:?})",
            cfg.network,
            cfg.bs_csd,
            net.train_batch_sizes
        );
        let dataset = Dataset::new(cfg.dataset())?;
        let placement = balance(
            &dataset,
            cfg.num_csds,
            cfg.bs_csd,
            cfg.bs_host,
            cfg.include_host,
        )?;
        Ok(Self { engine, dataset, placement, cfg })
    }

    /// Construct the trainer for this cluster.
    pub fn trainer(&self) -> Result<StannisTrainer> {
        StannisTrainer::new(
            self.engine.clone(),
            self.dataset.clone(),
            &self.placement,
            TrainConfig {
                network: self.cfg.network.clone(),
                num_csds: self.cfg.num_csds,
                include_host: self.cfg.include_host,
                bs_csd: self.cfg.bs_csd,
                bs_host: self.cfg.bs_host,
                steps: self.cfg.steps,
                sgd: self.cfg.sgd(),
                seed: self.cfg.seed as i32,
                consistency_every: 10,
                weighted_grads: true,
            },
        )
    }
}
