//! Cluster assembly: turn an [`ExperimentConfig`] into the live pieces
//! a run needs (engine, dataset, placement, trainer).
//!
//! Since the fleet subsystem landed, a `Cluster` is the *single-job
//! special case* of a fleet group: all the per-job wiring (artifact
//! validation, dataset generation, Eq. 1 balancing, trainer
//! construction) lives in [`fleet::group::JobGroup`](crate::fleet::JobGroup),
//! and `Cluster` wraps exactly one group. Multi-job callers go through
//! [`crate::fleet::Fleet`] instead (DESIGN.md §5).
//!
//! `Cluster` is the *real-execution* path (PJRT engine, wallclock
//! steps). Its modeled twin — the single-job special case of the
//! simulated fleet — is [`crate::coordinator::Scheduler`], which, like
//! the fleet coordinator, collapses steady-state runs into a
//! closed-form fast-forward when flash staging is off (bit-identical
//! to the per-step loop; DESIGN.md §Perf). Real execution cannot be
//! fast-forwarded: wallclock steps are not repeats.

use std::ops::Deref;
use std::sync::Arc;

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::fleet::JobGroup;
use crate::runtime::{default_artifacts_dir, Engine};

/// A fully wired real-execution cluster — one provisioned [`JobGroup`].
///
/// Derefs to the group, so `cluster.engine`, `cluster.placement`,
/// `cluster.cfg` and `cluster.trainer()` keep their historical shape.
pub struct Cluster {
    group: JobGroup,
}

impl Deref for Cluster {
    type Target = JobGroup;

    fn deref(&self) -> &JobGroup {
        &self.group
    }
}

impl Cluster {
    /// Build from config: load artifacts, generate the dataset,
    /// balance the shards (Eq. 1).
    pub fn bring_up(cfg: ExperimentConfig) -> Result<Self> {
        let engine = Arc::new(Engine::new(default_artifacts_dir())?);
        Self::bring_up_with_engine(cfg, engine)
    }

    /// Same, reusing an existing engine (tests share one to avoid
    /// recompiling artifacts).
    pub fn bring_up_with_engine(cfg: ExperimentConfig, engine: Arc<Engine>) -> Result<Self> {
        Ok(Self { group: JobGroup::provision(cfg, engine)? })
    }

    /// Unwrap into the underlying fleet group.
    pub fn into_group(self) -> JobGroup {
        self.group
    }
}
