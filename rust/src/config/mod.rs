//! Experiment configuration: JSON files + CLI overrides.
//!
//! One [`ExperimentConfig`] fully determines a run (cluster shape,
//! network, tuning knobs, dataset, optimizer), making every number in
//! EXPERIMENTS.md reproducible from a checked-in config + seed.

use std::path::Path;

use anyhow::{Context, Result};

use crate::model::SgdConfig;
use crate::util::{cli::Args, Json};

/// Cluster + run shape.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub network: String,
    pub num_csds: usize,
    pub include_host: bool,
    pub bs_csd: usize,
    pub bs_host: usize,
    pub steps: usize,
    pub seed: i64,
    pub base_lr: f64,
    pub momentum: f64,
    pub warmup_steps: u64,
    pub public_images: usize,
    pub private_per_csd: usize,
    /// Reference total batch the base_lr was tuned for (Goyal linear
    /// scaling uses total_batch / reference_batch).
    pub reference_batch: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            network: "mobilenet_v2_s".into(),
            num_csds: 3,
            include_host: true,
            bs_csd: 4,
            bs_host: 16,
            steps: 50,
            seed: 0,
            base_lr: 0.005,
            momentum: 0.9,
            warmup_steps: 10,
            public_images: 1536,
            private_per_csd: 256,
            reference_batch: 32,
        }
    }
}

impl ExperimentConfig {
    /// Load from a JSON file; missing keys keep defaults.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        let j = Json::parse(&text)?;
        Self::from_json(&j)
    }

    /// Build from a parsed JSON object (one job entry of a fleet spec);
    /// missing keys keep defaults.
    pub fn from_json(j: &Json) -> Result<Self> {
        Self::default().merged_with(j)
    }

    fn merged_with(mut self, j: &Json) -> Result<Self> {
        if let Some(v) = j.get("network") {
            self.network = v.as_str()?.to_string();
        }
        if let Some(v) = j.get("num_csds") {
            self.num_csds = v.as_usize()?;
        }
        if let Some(v) = j.get("include_host") {
            self.include_host = v.as_bool()?;
        }
        if let Some(v) = j.get("bs_csd") {
            self.bs_csd = v.as_usize()?;
        }
        if let Some(v) = j.get("bs_host") {
            self.bs_host = v.as_usize()?;
        }
        if let Some(v) = j.get("steps") {
            self.steps = v.as_usize()?;
        }
        if let Some(v) = j.get("seed") {
            self.seed = v.as_i64()?;
        }
        if let Some(v) = j.get("base_lr") {
            self.base_lr = v.as_f64()?;
        }
        if let Some(v) = j.get("momentum") {
            self.momentum = v.as_f64()?;
        }
        if let Some(v) = j.get("warmup_steps") {
            self.warmup_steps = v.as_u64()?;
        }
        if let Some(v) = j.get("public_images") {
            self.public_images = v.as_usize()?;
        }
        if let Some(v) = j.get("private_per_csd") {
            self.private_per_csd = v.as_usize()?;
        }
        Ok(self)
    }

    /// Apply CLI overrides (flags named like the JSON keys).
    pub fn apply_args(mut self, args: &Args) -> Result<Self> {
        if let Some(v) = args.get("network") {
            self.network = v.to_string();
        }
        self.num_csds = args.parse_or("num-csds", self.num_csds)?;
        if args.flag("no-host") {
            self.include_host = false;
        }
        self.bs_csd = args.parse_or("bs-csd", self.bs_csd)?;
        self.bs_host = args.parse_or("bs-host", self.bs_host)?;
        self.steps = args.parse_or("steps", self.steps)?;
        self.seed = args.parse_or("seed", self.seed)?;
        self.base_lr = args.parse_or("lr", self.base_lr)?;
        self.public_images = args.parse_or("public-images", self.public_images)?;
        self.private_per_csd = args.parse_or("private-per-csd", self.private_per_csd)?;
        Ok(self)
    }

    pub fn sgd(&self) -> SgdConfig {
        let total_batch = self.num_csds * self.bs_csd
            + if self.include_host { self.bs_host } else { 0 };
        SgdConfig {
            base_lr: self.base_lr as f32,
            momentum: self.momentum as f32,
            lr_scale: total_batch as f32 / self.reference_batch.max(1) as f32,
            warmup_steps: self.warmup_steps,
        }
    }

    pub fn dataset(&self) -> crate::data::DatasetConfig {
        crate::data::DatasetConfig {
            public_images: self.public_images,
            private_per_csd: vec![self.private_per_csd; self.num_csds],
            seed: self.seed as u64 ^ 0xDA7A,
            ..Default::default()
        }
    }
}

/// A scheduled device health event for fleet runs (DESIGN.md §5,
/// §Runtime): at `at_secs` of simulated time, multiply `device`'s
/// health by `factor`. `factor < 1` is a fault (thermal throttle,
/// wear); `factor > 1` is a *repair* (throttle lifted, module swapped)
/// — the pool clamps health at 1.0, so a schedule can express
/// degrade-then-repair with one mechanism (`0:30:0.5` then `0:90:2`).
#[derive(Debug, Clone, Copy)]
pub struct FaultSpec {
    pub at_secs: f64,
    pub device: usize,
    pub factor: f64,
}

impl FaultSpec {
    fn from_json(j: &Json) -> Result<Self> {
        Self {
            at_secs: j.field("at_secs")?.as_f64()?,
            device: j.field("device")?.as_usize()?,
            factor: j.field("factor")?.as_f64()?,
        }
        .validated()
    }

    /// Parse the CLI form `device:at_secs:factor` (e.g. `3:30:0.6` to
    /// throttle, `3:90:2` to repair).
    pub fn parse_cli(s: &str) -> Result<Self> {
        let parts: Vec<&str> = s.split(':').collect();
        anyhow::ensure!(
            parts.len() == 3,
            "fault spec {s:?} must be device:at_secs:factor (e.g. 3:30:0.6; factor > 1 repairs)"
        );
        Self {
            device: parts[0].parse().with_context(|| format!("device in {s:?}"))?,
            at_secs: parts[1].parse().with_context(|| format!("at_secs in {s:?}"))?,
            factor: parts[2].parse().with_context(|| format!("factor in {s:?}"))?,
        }
        .validated()
    }

    /// True for health-restoring events (`factor > 1`; the pool clamps
    /// the result at full health).
    pub fn is_repair(&self) -> bool {
        self.factor > 1.0
    }

    fn validated(self) -> Result<Self> {
        anyhow::ensure!(
            self.at_secs >= 0.0 && self.at_secs.is_finite(),
            "fault at_secs must be a non-negative time, got {}",
            self.at_secs
        );
        anyhow::ensure!(
            self.factor > 0.0 && self.factor.is_finite(),
            "fault factor must be a positive scale (< 1 degrades, > 1 repairs), got {}",
            self.factor
        );
        Ok(self)
    }
}

/// A scheduled mid-run cancellation for workload runs: tear down the
/// `job`-th submitted job (submission order, 0-based) at `at_secs`.
#[derive(Debug, Clone, Copy)]
pub struct CancelSpec {
    pub job: usize,
    pub at_secs: f64,
}

impl CancelSpec {
    fn from_json(j: &Json) -> Result<Self> {
        Self { job: j.field("job")?.as_usize()?, at_secs: j.field("at_secs")?.as_f64()? }
            .validated()
    }

    /// Parse the CLI form `job:at_secs` (e.g. `2:45.5`).
    pub fn parse_cli(s: &str) -> Result<Self> {
        let parts: Vec<&str> = s.split(':').collect();
        anyhow::ensure!(
            parts.len() == 2,
            "cancel spec {s:?} must be job:at_secs (e.g. 2:45.5)"
        );
        Self {
            job: parts[0].parse().with_context(|| format!("job in {s:?}"))?,
            at_secs: parts[1].parse().with_context(|| format!("at_secs in {s:?}"))?,
        }
        .validated()
    }

    fn validated(self) -> Result<Self> {
        anyhow::ensure!(
            self.at_secs >= 0.0 && self.at_secs.is_finite(),
            "cancel at_secs must be a non-negative time, got {}",
            self.at_secs
        );
        Ok(self)
    }
}

/// A scheduled abrupt bay failure (DESIGN.md §Crash-Recovery): at
/// `at_secs` of simulated time `device` dies mid-flight. Unlike a
/// [`FaultSpec`] degrade (which throttles and re-tunes) or end-of-life
/// wear-out (which drains gracefully), a crash loses the in-flight
/// step, force-releases the bay's DLM locks, swaps the bay, and
/// resumes the tenant from its last checkpoint.
#[derive(Debug, Clone, Copy)]
pub struct CrashSpec {
    pub device: usize,
    pub at_secs: f64,
}

impl CrashSpec {
    fn from_json(j: &Json) -> Result<Self> {
        Self { device: j.field("device")?.as_usize()?, at_secs: j.field("at_secs")?.as_f64()? }
            .validated()
    }

    /// Parse the CLI form `device:at_secs` (e.g. `3:45.5`).
    pub fn parse_cli(s: &str) -> Result<Self> {
        let parts: Vec<&str> = s.split(':').collect();
        anyhow::ensure!(
            parts.len() == 2,
            "crash spec {s:?} must be device:at_secs (e.g. 3:45.5)"
        );
        Self {
            device: parts[0].parse().with_context(|| format!("device in {s:?}"))?,
            at_secs: parts[1].parse().with_context(|| format!("at_secs in {s:?}"))?,
        }
        .validated()
    }

    fn validated(self) -> Result<Self> {
        anyhow::ensure!(
            self.at_secs >= 0.0 && self.at_secs.is_finite(),
            "crash at_secs must be a non-negative time, got {}",
            self.at_secs
        );
        Ok(self)
    }
}

/// Checkpointing knobs (DESIGN.md §Crash-Recovery). Default *off*
/// (`interval_steps == 0`): no checkpoint I/O is scheduled, no
/// fast-forward window boundary is added, and the runtime is
/// bit-identical to the pre-checkpoint simulator. With a nonzero
/// interval every job writes its model state through the data plane's
/// extent path every `interval_steps` steps; a crashed tenant resumes
/// from the last completed checkpoint instead of step 0.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct CheckpointSpec {
    /// Steps between checkpoints. `0` = checkpointing off.
    pub interval_steps: u64,
    /// Also copy each checkpoint to the host over the tunnel (survives
    /// loss of the whole group, costs tunnel bandwidth).
    pub host_copy: bool,
}

impl CheckpointSpec {
    pub fn armed(&self) -> bool {
        self.interval_steps > 0
    }

    fn from_json(j: &Json) -> Result<Self> {
        let mut out = Self::default();
        if let Some(v) = j.get("interval_steps") {
            out.interval_steps = v.as_u64()?;
        }
        if let Some(v) = j.get("host_copy") {
            out.host_copy = v.as_bool()?;
        }
        Ok(out)
    }
}

/// Seeded transient tunnel-link failures (DESIGN.md §Crash-Recovery).
/// Default *off* (`fail_prob == 0.0`): the tunnel never consults the
/// ladder, no RNG is seeded, and send timings are bit-identical to the
/// fault-free simulator. Armed, each hop over a link draws from that
/// link's private RNG; a failed draw retries after exponentially
/// growing backoff, and exhausting `max_retries` rungs escalates to a
/// crash of the bay behind the link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkFaultSpec {
    /// Per-attempt failure probability in [0, 1). `0` = off.
    pub fail_prob: f64,
    /// Rungs of the retry ladder before escalating to a crash.
    pub max_retries: u32,
    /// Backoff before rung `r` retries: `backoff_base_us * 2^r`.
    pub backoff_base_us: f64,
    /// Seed of the per-link RNG forks.
    pub seed: u64,
}

impl Default for LinkFaultSpec {
    fn default() -> Self {
        Self { fail_prob: 0.0, max_retries: 4, backoff_base_us: 50.0, seed: 0x11AB }
    }
}

impl LinkFaultSpec {
    pub fn armed(&self) -> bool {
        self.fail_prob > 0.0
    }

    fn from_json(j: &Json) -> Result<Self> {
        let mut out = Self::default();
        if let Some(v) = j.get("fail_prob") {
            out.fail_prob = v.as_f64()?;
        }
        if let Some(v) = j.get("max_retries") {
            out.max_retries = v.as_u64()? as u32;
        }
        if let Some(v) = j.get("backoff_base_us") {
            out.backoff_base_us = v.as_f64()?;
        }
        if let Some(v) = j.get("seed") {
            out.seed = v.as_u64()?;
        }
        Ok(out)
    }

    fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            (0.0..1.0).contains(&self.fail_prob),
            "link fail_prob must sit in [0, 1), got {} (at 1.0 every message \
             exhausts the ladder and the whole chassis crash-loops)",
            self.fail_prob
        );
        anyhow::ensure!(
            self.backoff_base_us >= 0.0 && self.backoff_base_us.is_finite(),
            "link backoff_base_us must be a non-negative time, got {}",
            self.backoff_base_us
        );
        Ok(())
    }
}

/// Multi-job experiment spec for the fleet coordinator: a shared
/// device pool plus many per-job [`ExperimentConfig`]s and an optional
/// fault schedule.
#[derive(Debug, Clone)]
pub struct FleetExperimentConfig {
    /// Devices in the shared pool.
    pub total_csds: usize,
    /// Legacy per-step flash staging (superseded by `data_plane`).
    pub stage_io: bool,
    /// Model the physical data plane: flash-page shard maps at
    /// admission, per-window staged-read charging, DLM-locked
    /// public-shard movement on rebalance (DESIGN.md §Data-Plane).
    /// Default on — the CLI spelling to disable is `--no-data-plane`.
    pub data_plane: bool,
    /// Steady-state fast-forward (bit-identical closed-form windows;
    /// see DESIGN.md §Perf). `false` forces the per-step reference
    /// path — the CLI spelling is `--per-step`.
    pub fast_forward: bool,
    pub jobs: Vec<ExperimentConfig>,
    pub faults: Vec<FaultSpec>,
    /// Scheduled abrupt bay failures (DESIGN.md §Crash-Recovery).
    pub crashes: Vec<CrashSpec>,
    /// Checkpointing knobs; default off.
    pub checkpoint: CheckpointSpec,
    /// Transient tunnel-link failures; default off.
    pub link_fault: LinkFaultSpec,
}

impl Default for FleetExperimentConfig {
    fn default() -> Self {
        Self {
            total_csds: 12,
            stage_io: true,
            data_plane: true,
            fast_forward: true,
            jobs: Vec::new(),
            faults: Vec::new(),
            crashes: Vec::new(),
            checkpoint: CheckpointSpec::default(),
            link_fault: LinkFaultSpec::default(),
        }
    }
}

impl FleetExperimentConfig {
    /// Load from a JSON file shaped like
    /// `{"total_csds": 12, "jobs": [{...}, ...], "faults": [{...}]}`;
    /// each job object takes [`ExperimentConfig`] keys.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        let j = Json::parse(&text)?;
        let mut out = Self::default();
        if let Some(v) = j.get("total_csds") {
            out.total_csds = v.as_usize()?;
        }
        if let Some(v) = j.get("stage_io") {
            out.stage_io = v.as_bool()?;
        }
        if let Some(v) = j.get("data_plane") {
            out.data_plane = v.as_bool()?;
        }
        if let Some(v) = j.get("fast_forward") {
            out.fast_forward = v.as_bool()?;
        }
        if let Some(v) = j.get("jobs") {
            for job in v.as_arr()? {
                out.jobs.push(ExperimentConfig::from_json(job)?);
            }
        }
        if let Some(v) = j.get("faults") {
            for f in v.as_arr()? {
                out.faults.push(FaultSpec::from_json(f)?);
            }
        }
        if let Some(v) = j.get("crashes") {
            for c in v.as_arr()? {
                out.crashes.push(CrashSpec::from_json(c)?);
            }
        }
        if let Some(v) = j.get("checkpoint") {
            out.checkpoint = CheckpointSpec::from_json(v)?;
        }
        if let Some(v) = j.get("link_fault") {
            out.link_fault = LinkFaultSpec::from_json(v)?;
            out.link_fault.validate()?;
        }
        Ok(out)
    }

    /// A deterministic default workload mix: `n_jobs` jobs cycling the
    /// paper's four networks, the first one holding the host, devices
    /// spread evenly across the pool.
    pub fn default_mix(n_jobs: usize, total_csds: usize) -> Self {
        const NETS: [&str; 4] = ["mobilenet_v2", "squeezenet", "nasnet", "inception_v3"];
        let n_jobs = n_jobs.max(1);
        let base = (total_csds / n_jobs).max(1);
        let mut spare = total_csds.saturating_sub(base * n_jobs);
        let jobs = (0..n_jobs)
            .map(|i| {
                let extra = usize::from(spare > 0);
                spare = spare.saturating_sub(1);
                ExperimentConfig {
                    network: NETS[i % NETS.len()].into(),
                    num_csds: (base + extra).min(total_csds),
                    include_host: i == 0,
                    steps: 20,
                    seed: i as i64,
                    ..Default::default()
                }
            })
            .collect();
        Self { total_csds, jobs, ..Default::default() }
    }
}

/// One entry of a workload's job mix: a job template drawn with
/// probability proportional to `weight`.
#[derive(Debug, Clone)]
pub struct WeightedJob {
    pub weight: f64,
    pub job: ExperimentConfig,
}

/// Endurance and failure-pipeline knobs (DESIGN.md §Endurance),
/// applied to every device in the pool. The default is *off* in every
/// dimension — with `pe_limit == 0` and `read_retries == 0` the flash
/// model is bit-identical to the pre-endurance simulator (no retry
/// draws touch the ECC RNG stream, no block ever retires, no device
/// reaches end of life).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnduranceSpec {
    /// Program/erase cycles a block survives before its next erase
    /// fails and the block retires to the bad-block list. `0` =
    /// unlimited (endurance modeling off).
    pub pe_limit: u32,
    /// Depth of the read-retry ladder tried on an uncorrectable page
    /// read before the error surfaces. `0` = fail immediately.
    pub read_retries: u32,
    /// Extra latency per rung of the retry ladder, in microseconds.
    pub retry_step_us: f64,
}

impl Default for EnduranceSpec {
    fn default() -> Self {
        Self { pe_limit: 0, read_retries: 0, retry_step_us: 100.0 }
    }
}

impl EnduranceSpec {
    fn from_json(j: &Json) -> Result<Self> {
        let mut out = Self::default();
        if let Some(v) = j.get("pe_limit") {
            out.pe_limit = v.as_u64()? as u32;
        }
        if let Some(v) = j.get("read_retries") {
            out.read_retries = v.as_u64()? as u32;
        }
        if let Some(v) = j.get("retry_step_us") {
            out.retry_step_us = v.as_f64()?;
        }
        Ok(out)
    }
}

/// An *online* multi-job experiment for the fleet runtime
/// (DESIGN.md §Runtime): a seeded arrival process over a weighted job
/// mix, plus cancel and degrade/repair schedules — the open-loop
/// traffic shape a shared chassis actually serves, driven by
/// `stannis workload`.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Devices in the shared pool.
    pub total_csds: usize,
    /// Legacy per-step flash staging (superseded by `data_plane`).
    pub stage_io: bool,
    /// Model the physical data plane (DESIGN.md §Data-Plane).
    pub data_plane: bool,
    /// Steady-state fast-forward (`--per-step` disables).
    pub fast_forward: bool,
    /// Keep terminal jobs in the runtime's table (the
    /// retained-everything oracle; `--retain-jobs`). Default off: jobs
    /// retire into the streamed log and memory stays O(live jobs) —
    /// the only mode that survives million-arrival traces.
    pub retain_jobs: bool,
    /// Seed of the arrival process and mix draws.
    pub seed: u64,
    /// Number of job arrivals to draw.
    pub jobs: usize,
    /// Mean of the exponential inter-arrival gap (Poisson process).
    pub mean_interarrival_secs: f64,
    /// Job templates, drawn by weight per arrival. Empty = the default
    /// four-network mix (each job sized `csds_per_job`).
    pub mix: Vec<WeightedJob>,
    /// Devices per job in the default mix (ignored with an explicit
    /// `mix`).
    pub csds_per_job: usize,
    /// Mid-run cancellations (`job` is the submission index).
    pub cancels: Vec<CancelSpec>,
    /// Health events: `factor < 1` degrades, `> 1` repairs.
    pub faults: Vec<FaultSpec>,
    /// Flash endurance knobs (retry ladder, block retirement, device
    /// end-of-life). Default off in every dimension.
    pub endurance: EnduranceSpec,
    /// Scheduled abrupt bay failures (`--crash device:at_secs`,
    /// repeatable; DESIGN.md §Crash-Recovery).
    pub crashes: Vec<CrashSpec>,
    /// Checkpointing knobs (`--checkpoint-steps`,
    /// `--checkpoint-host-copy`). Default off.
    pub checkpoint: CheckpointSpec,
    /// Transient tunnel-link failures (`--link-fail-prob`,
    /// `--link-retries`, `--link-backoff-us`). Default off.
    pub link_fault: LinkFaultSpec,
    /// Run the runtime's full invariant audit after every event
    /// (`--audit`; DESIGN.md §Static-Analysis). Read-only — results
    /// are bit-identical either way — but O(state) per event, so off
    /// by default.
    pub audit: bool,
    /// Persist every retired job to an on-disk ledger at this
    /// directory (`--ledger DIR`, JSON `"ledger"`; DESIGN.md §Ledger).
    /// Default off (`None`) — runs are bit-identical either way;
    /// `run_sweep` derives one `seed-*` subdirectory per seed.
    pub ledger: Option<std::path::PathBuf>,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        Self {
            total_csds: 12,
            stage_io: true,
            data_plane: true,
            fast_forward: true,
            retain_jobs: false,
            seed: 7,
            jobs: 8,
            mean_interarrival_secs: 30.0,
            mix: Vec::new(),
            csds_per_job: 3,
            cancels: Vec::new(),
            faults: Vec::new(),
            endurance: EnduranceSpec::default(),
            crashes: Vec::new(),
            checkpoint: CheckpointSpec::default(),
            link_fault: LinkFaultSpec::default(),
            audit: false,
            ledger: None,
        }
    }
}

impl WorkloadSpec {
    /// Load from a JSON file shaped like
    /// `{"total_csds": 12, "jobs": 8, "mean_interarrival_secs": 30,
    ///   "seed": 7, "mix": [{"weight": 2, "network": "squeezenet",
    ///   ...job keys}], "cancels": [{"job": 1, "at_secs": 45}],
    ///   "faults": [{"at_secs": 30, "device": 1, "factor": 0.6}]}`;
    /// missing keys keep defaults. Each mix object takes a `weight`
    /// plus [`ExperimentConfig`] keys.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        let j = Json::parse(&text)?;
        let mut out = Self::default();
        if let Some(v) = j.get("total_csds") {
            out.total_csds = v.as_usize()?;
        }
        if let Some(v) = j.get("stage_io") {
            out.stage_io = v.as_bool()?;
        }
        if let Some(v) = j.get("data_plane") {
            out.data_plane = v.as_bool()?;
        }
        if let Some(v) = j.get("fast_forward") {
            out.fast_forward = v.as_bool()?;
        }
        if let Some(v) = j.get("retain_jobs") {
            out.retain_jobs = v.as_bool()?;
        }
        if let Some(v) = j.get("seed") {
            out.seed = v.as_u64()?;
        }
        if let Some(v) = j.get("jobs") {
            out.jobs = v.as_usize()?;
        }
        if let Some(v) = j.get("mean_interarrival_secs") {
            out.mean_interarrival_secs = v.as_f64()?;
        }
        if let Some(v) = j.get("csds_per_job") {
            out.csds_per_job = v.as_usize()?;
        }
        if let Some(v) = j.get("mix") {
            for m in v.as_arr()? {
                let weight = match m.get("weight") {
                    Some(w) => w.as_f64()?,
                    None => 1.0,
                };
                out.mix.push(WeightedJob { weight, job: ExperimentConfig::from_json(m)? });
            }
        }
        if let Some(v) = j.get("cancels") {
            for c in v.as_arr()? {
                out.cancels.push(CancelSpec::from_json(c)?);
            }
        }
        if let Some(v) = j.get("faults") {
            for f in v.as_arr()? {
                out.faults.push(FaultSpec::from_json(f)?);
            }
        }
        if let Some(v) = j.get("endurance") {
            out.endurance = EnduranceSpec::from_json(v)?;
        }
        if let Some(v) = j.get("crashes") {
            for c in v.as_arr()? {
                out.crashes.push(CrashSpec::from_json(c)?);
            }
        }
        if let Some(v) = j.get("checkpoint") {
            out.checkpoint = CheckpointSpec::from_json(v)?;
        }
        if let Some(v) = j.get("link_fault") {
            out.link_fault = LinkFaultSpec::from_json(v)?;
        }
        if let Some(v) = j.get("audit") {
            out.audit = v.as_bool()?;
        }
        if let Some(v) = j.get("ledger") {
            out.ledger = Some(std::path::PathBuf::from(v.as_str()?));
        }
        out.validated()
    }

    /// Apply CLI overrides (`--total-csds`, `--jobs`, `--mean-arrival`,
    /// `--seed`, `--csds-per-job`, `--retain-jobs`, `--pe-limit`,
    /// `--read-retries`, `--crash`, `--checkpoint-steps`,
    /// `--checkpoint-host-copy`, `--link-fail-prob`, `--link-retries`,
    /// `--link-backoff-us`, `--audit`, `--ledger`).
    pub fn apply_args(mut self, args: &Args) -> Result<Self> {
        self.total_csds = args.parse_or("total-csds", self.total_csds)?;
        self.jobs = args.parse_or("jobs", self.jobs)?;
        self.mean_interarrival_secs =
            args.parse_or("mean-arrival", self.mean_interarrival_secs)?;
        self.seed = args.parse_or("seed", self.seed)?;
        self.csds_per_job = args.parse_or("csds-per-job", self.csds_per_job)?;
        self.endurance.pe_limit = args.parse_or("pe-limit", self.endurance.pe_limit)?;
        self.endurance.read_retries =
            args.parse_or("read-retries", self.endurance.read_retries)?;
        if args.flag("no-stage-io") {
            self.stage_io = false;
        }
        if args.flag("no-data-plane") {
            self.data_plane = false;
        }
        if args.flag("per-step") {
            self.fast_forward = false;
        }
        if args.flag("retain-jobs") {
            self.retain_jobs = true;
        }
        if args.flag("audit") {
            self.audit = true;
        }
        for c in args.get_all("cancel") {
            self.cancels.push(CancelSpec::parse_cli(c)?);
        }
        for d in args.get_all("degrade") {
            self.faults.push(FaultSpec::parse_cli(d)?);
        }
        for c in args.get_all("crash") {
            self.crashes.push(CrashSpec::parse_cli(c)?);
        }
        self.checkpoint.interval_steps =
            args.parse_or("checkpoint-steps", self.checkpoint.interval_steps)?;
        if args.flag("checkpoint-host-copy") {
            self.checkpoint.host_copy = true;
        }
        self.link_fault.fail_prob =
            args.parse_or("link-fail-prob", self.link_fault.fail_prob)?;
        self.link_fault.max_retries =
            args.parse_or("link-retries", self.link_fault.max_retries)?;
        self.link_fault.backoff_base_us =
            args.parse_or("link-backoff-us", self.link_fault.backoff_base_us)?;
        if let Some(dir) = args.get("ledger") {
            self.ledger = Some(std::path::PathBuf::from(dir));
        }
        self.validated()
    }

    /// Check the spec's invariants: at least one arrival, a finite
    /// non-negative mean gap, strictly positive finite mix weights,
    /// cancel indices inside the trace, fault and crash devices inside
    /// the pool, and sane endurance/link-fault knobs. `from_file`/`apply_args` run this,
    /// and so do the trace drivers
    /// ([`crate::fleet::FleetRuntime::load_workload`],
    /// [`crate::fleet::sweep::run_trace_with`]) — a hand-built spec
    /// cannot bypass it.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.jobs > 0, "a workload needs at least one job arrival");
        anyhow::ensure!(
            self.mean_interarrival_secs >= 0.0 && self.mean_interarrival_secs.is_finite(),
            "mean_interarrival_secs must be a non-negative time, got {}",
            self.mean_interarrival_secs
        );
        for (i, m) in self.mix.iter().enumerate() {
            anyhow::ensure!(
                m.weight > 0.0 && m.weight.is_finite(),
                "mix entry {i} ({:?}) has weight {}: every mix weight must be a \
                 positive finite number (a zero-weight template can never be drawn \
                 — delete the entry instead)",
                m.job.network,
                m.weight
            );
        }
        for (i, c) in self.cancels.iter().enumerate() {
            anyhow::ensure!(
                c.job < self.jobs,
                "cancel entry {i} references job {} but only {} arrive",
                c.job,
                self.jobs
            );
        }
        for (i, f) in self.faults.iter().enumerate() {
            anyhow::ensure!(
                f.device < self.total_csds,
                "fault entry {i} (at {}s) targets device {} but the pool has only \
                 {} device(s)",
                f.at_secs,
                f.device,
                self.total_csds
            );
        }
        anyhow::ensure!(
            self.endurance.retry_step_us >= 0.0 && self.endurance.retry_step_us.is_finite(),
            "endurance retry_step_us must be a non-negative time, got {}",
            self.endurance.retry_step_us
        );
        for (i, c) in self.crashes.iter().enumerate() {
            anyhow::ensure!(
                c.device < self.total_csds,
                "crash entry {i} (at {}s) targets device {} but the pool has only \
                 {} device(s)",
                c.at_secs,
                c.device,
                self.total_csds
            );
        }
        self.link_fault.validate()?;
        Ok(())
    }

    fn validated(self) -> Result<Self> {
        self.validate()?;
        Ok(self)
    }

    /// The effective job mix: the explicit one, or the default
    /// four-network rotation at `csds_per_job` devices (first template
    /// holds the host).
    pub fn effective_mix(&self) -> Vec<WeightedJob> {
        if !self.mix.is_empty() {
            return self.mix.clone();
        }
        const NETS: [&str; 4] = ["mobilenet_v2", "squeezenet", "nasnet", "inception_v3"];
        NETS.iter()
            .enumerate()
            .map(|(i, net)| WeightedJob {
                weight: 1.0,
                job: ExperimentConfig {
                    network: (*net).into(),
                    num_csds: self.csds_per_job.min(self.total_csds).max(1),
                    include_host: i == 0,
                    steps: 20,
                    seed: i as i64,
                    ..Default::default()
                },
            })
            .collect()
    }

    /// Draw the arrival trace lazily: `jobs` arrivals of a Poisson
    /// process (exponential inter-arrival gaps of mean
    /// `mean_interarrival_secs`) over the weighted mix, one at a time.
    /// Deterministic in `seed` — the same spec always yields the same
    /// trace, byte for byte; the draw sequence (one gap draw, then one
    /// mix pick, per arrival) is identical to the eager
    /// [`WorkloadSpec::arrivals`], which is now a collecting wrapper.
    /// The streaming trace driver ([`crate::fleet::sweep`]) leans on
    /// this: a million-arrival trace never materializes a Vec.
    pub fn arrival_iter(&self) -> impl Iterator<Item = (f64, ExperimentConfig)> + '_ {
        let mix = self.effective_mix();
        let total_w: f64 = mix.iter().map(|m| m.weight).sum();
        let mut rng = crate::util::Rng::new(self.seed ^ 0x4A0B_70AD);
        let mut t = 0.0f64;
        (0..self.jobs).map(move |_| {
            // Inverse-CDF exponential draw; f64() < 1 keeps ln finite.
            t += -self.mean_interarrival_secs * (1.0 - rng.f64()).ln();
            let mut pick = rng.f64() * total_w;
            let mut job = mix.last().expect("mix is non-empty").job.clone();
            for m in &mix {
                if pick < m.weight {
                    job = m.job.clone();
                    break;
                }
                pick -= m.weight;
            }
            (t, job)
        })
    }

    /// The whole arrival trace at once — small traces and tests; see
    /// [`WorkloadSpec::arrival_iter`] for the streaming form.
    pub fn arrivals(&self) -> Vec<(f64, ExperimentConfig)> {
        self.arrival_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn file_overrides_defaults() {
        let dir = std::env::temp_dir().join(format!("stannis_cfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("exp.json");
        std::fs::write(&p, r#"{"network": "squeezenet_s", "num_csds": 7, "base_lr": 0.1}"#)
            .unwrap();
        let c = ExperimentConfig::from_file(&p).unwrap();
        assert_eq!(c.network, "squeezenet_s");
        assert_eq!(c.num_csds, 7);
        assert!((c.base_lr - 0.1).abs() < 1e-12);
        assert_eq!(c.steps, ExperimentConfig::default().steps);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cli_overrides_file() {
        let args = crate::util::cli::Args::parse(
            ["--num-csds", "9", "--no-host", "--lr", "0.2"].map(String::from),
        )
        .unwrap();
        let c = ExperimentConfig::default().apply_args(&args).unwrap();
        assert_eq!(c.num_csds, 9);
        assert!(!c.include_host);
        // 9 CSD-only workers at bs 4 = total 36 vs reference 32
        assert!((c.sgd().lr_scale - 36.0 / 32.0).abs() < 1e-6);
    }

    #[test]
    fn bad_type_errors() {
        let args = crate::util::cli::Args::parse(["--steps", "many"].map(String::from)).unwrap();
        assert!(ExperimentConfig::default().apply_args(&args).is_err());
    }

    #[test]
    fn fleet_spec_parses_jobs_and_faults() {
        let dir = std::env::temp_dir().join(format!("stannis_fleet_cfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("fleet.json");
        std::fs::write(
            &p,
            r#"{
                "total_csds": 8,
                "stage_io": false,
                "data_plane": false,
                "fast_forward": false,
                "jobs": [
                    {"network": "mobilenet_v2", "num_csds": 3, "steps": 5},
                    {"network": "squeezenet", "num_csds": 4, "include_host": false}
                ],
                "faults": [{"at_secs": 30.5, "device": 1, "factor": 0.6}]
            }"#,
        )
        .unwrap();
        let f = FleetExperimentConfig::from_file(&p).unwrap();
        assert_eq!(f.total_csds, 8);
        assert!(!f.stage_io);
        assert!(!f.data_plane);
        assert!(!f.fast_forward);
        assert!(FleetExperimentConfig::default().fast_forward, "fast path is the default");
        assert!(FleetExperimentConfig::default().data_plane, "data plane is the default");
        assert_eq!(f.jobs.len(), 2);
        assert_eq!(f.jobs[0].num_csds, 3);
        assert_eq!(f.jobs[0].steps, 5);
        assert_eq!(f.jobs[1].network, "squeezenet");
        assert!(!f.jobs[1].include_host);
        // Unset keys keep per-job defaults.
        assert_eq!(f.jobs[1].steps, ExperimentConfig::default().steps);
        assert_eq!(f.faults.len(), 1);
        assert_eq!(f.faults[0].device, 1);
        assert!((f.faults[0].at_secs - 30.5).abs() < 1e-12);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fault_cli_form_parses() {
        let f = FaultSpec::parse_cli("3:30:0.6").unwrap();
        assert_eq!(f.device, 3);
        assert!((f.at_secs - 30.0).abs() < 1e-12);
        assert!((f.factor - 0.6).abs() < 1e-12);
        assert!(!f.is_repair());
        assert!(FaultSpec::parse_cli("3:30").is_err());
        assert!(FaultSpec::parse_cli("a:b:c").is_err());
    }

    #[test]
    fn fault_spec_expresses_repairs() {
        // factor > 1 is a valid, parseable repair event — both CLI and
        // JSON forms — so degrade-then-repair needs no second mechanism.
        let r = FaultSpec::parse_cli("0:90:2.5").unwrap();
        assert!(r.is_repair());
        assert!((r.factor - 2.5).abs() < 1e-12);
        let j = Json::parse(r#"{"at_secs": 90, "device": 0, "factor": 4.0}"#).unwrap();
        let from_json = FaultSpec::from_json(&j).unwrap();
        assert!(from_json.is_repair());
        // Zero/negative/non-finite factors stay invalid in both
        // directions.
        assert!(FaultSpec::parse_cli("0:90:0").is_err());
        assert!(FaultSpec::parse_cli("0:90:-2").is_err());
        assert!(FaultSpec::parse_cli("0:90:inf").is_err());
        assert!(FaultSpec::parse_cli("0:-1:0.5").is_err());
    }

    #[test]
    fn cancel_cli_form_parses() {
        let c = CancelSpec::parse_cli("2:45.5").unwrap();
        assert_eq!(c.job, 2);
        assert!((c.at_secs - 45.5).abs() < 1e-12);
        assert!(CancelSpec::parse_cli("2").is_err());
        assert!(CancelSpec::parse_cli("2:x").is_err());
        assert!(CancelSpec::parse_cli("2:-5").is_err());
    }

    #[test]
    fn workload_spec_parses_and_validates() {
        let dir = std::env::temp_dir().join(format!("stannis_wl_cfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("workload.json");
        std::fs::write(
            &p,
            r#"{
                "total_csds": 8,
                "jobs": 5,
                "seed": 42,
                "mean_interarrival_secs": 12.5,
                "mix": [
                    {"weight": 3, "network": "squeezenet", "num_csds": 2, "steps": 6},
                    {"network": "mobilenet_v2", "num_csds": 3, "include_host": true}
                ],
                "cancels": [{"job": 1, "at_secs": 45.0}],
                "faults": [{"at_secs": 30.0, "device": 1, "factor": 0.6},
                           {"at_secs": 90.0, "device": 1, "factor": 2.0}]
            }"#,
        )
        .unwrap();
        let w = WorkloadSpec::from_file(&p).unwrap();
        assert_eq!(w.total_csds, 8);
        assert_eq!(w.jobs, 5);
        assert_eq!(w.seed, 42);
        assert!((w.mean_interarrival_secs - 12.5).abs() < 1e-12);
        assert_eq!(w.mix.len(), 2);
        assert!((w.mix[0].weight - 3.0).abs() < 1e-12);
        assert_eq!(w.mix[0].job.network, "squeezenet");
        assert!((w.mix[1].weight - 1.0).abs() < 1e-12, "weight defaults to 1");
        assert_eq!(w.cancels.len(), 1);
        assert_eq!(w.faults.len(), 2);
        assert!(!w.faults[0].is_repair() && w.faults[1].is_repair());
        // A cancel referencing a job that never arrives is rejected.
        std::fs::write(&p, r#"{"jobs": 2, "cancels": [{"job": 5, "at_secs": 1}]}"#).unwrap();
        assert!(WorkloadSpec::from_file(&p).is_err());
        // A zero-weight mix entry is rejected with the entry named —
        // the file path runs the same public `validate` as the drivers.
        std::fs::write(
            &p,
            r#"{"jobs": 2, "mix": [{"network": "squeezenet"},
                                   {"network": "nasnet", "weight": 0.0}]}"#,
        )
        .unwrap();
        let err = WorkloadSpec::from_file(&p).unwrap_err().to_string();
        assert!(err.contains("mix entry 1"), "must name the entry, got: {err}");
        assert!(err.contains("weight"), "must explain the rule, got: {err}");
        // retain_jobs parses from JSON and defaults off (streaming).
        std::fs::write(&p, r#"{"jobs": 2, "retain_jobs": true}"#).unwrap();
        assert!(WorkloadSpec::from_file(&p).unwrap().retain_jobs);
        assert!(!WorkloadSpec::default().retain_jobs, "streaming is the default");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn crash_cli_form_parses() {
        let c = CrashSpec::parse_cli("3:45.5").unwrap();
        assert_eq!(c.device, 3);
        assert!((c.at_secs - 45.5).abs() < 1e-12);
        assert!(CrashSpec::parse_cli("3").is_err());
        assert!(CrashSpec::parse_cli("3:x").is_err());
        assert!(CrashSpec::parse_cli("3:-5").is_err());
        assert!(CrashSpec::parse_cli("3:30:0.6").is_err(), "fault form is not a crash");
    }

    #[test]
    fn crash_pipeline_knobs_default_off_and_parse() {
        // Every knob of the crash pipeline defaults off: a spec that
        // never mentions them is the pre-crash-pipeline spec.
        let d = WorkloadSpec::default();
        assert!(d.crashes.is_empty());
        assert!(!d.checkpoint.armed());
        assert!(!d.link_fault.armed());
        assert_eq!(d.checkpoint, CheckpointSpec::default());
        assert_eq!(d.link_fault, LinkFaultSpec::default());

        let dir = std::env::temp_dir().join(format!("stannis_crash_cfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("workload.json");
        std::fs::write(
            &p,
            r#"{
                "total_csds": 8,
                "jobs": 4,
                "crashes": [{"device": 2, "at_secs": 60.0}],
                "checkpoint": {"interval_steps": 5, "host_copy": true},
                "link_fault": {"fail_prob": 0.01, "max_retries": 6,
                               "backoff_base_us": 25.0, "seed": 99}
            }"#,
        )
        .unwrap();
        let w = WorkloadSpec::from_file(&p).unwrap();
        assert_eq!(w.crashes.len(), 1);
        assert_eq!(w.crashes[0].device, 2);
        assert!((w.crashes[0].at_secs - 60.0).abs() < 1e-12);
        assert_eq!(w.checkpoint.interval_steps, 5);
        assert!(w.checkpoint.host_copy && w.checkpoint.armed());
        assert!(w.link_fault.armed());
        assert_eq!(w.link_fault.max_retries, 6);
        assert_eq!(w.link_fault.seed, 99);
        // A crash outside the pool is rejected with the entry named.
        std::fs::write(
            &p,
            r#"{"total_csds": 4, "jobs": 2, "crashes": [{"device": 9, "at_secs": 1}]}"#,
        )
        .unwrap();
        let err = WorkloadSpec::from_file(&p).unwrap_err().to_string();
        assert!(err.contains("crash entry 0"), "must name the entry, got: {err}");
        // fail_prob == 1.0 is rejected (every message would crash-loop).
        std::fs::write(&p, r#"{"jobs": 2, "link_fault": {"fail_prob": 1.0}}"#).unwrap();
        assert!(WorkloadSpec::from_file(&p).is_err());
        // CLI overrides: repeated --crash plus checkpoint/link knobs.
        let args = crate::util::cli::Args::parse(
            [
                "--crash", "0:10", "--crash", "1:20", "--checkpoint-steps", "8",
                "--checkpoint-host-copy", "--link-fail-prob", "0.05",
                "--link-retries", "3", "--link-backoff-us", "10",
            ]
            .map(String::from),
        )
        .unwrap();
        let w = WorkloadSpec::default().apply_args(&args).unwrap();
        assert_eq!(w.crashes.len(), 2, "repeated --crash must not collapse");
        assert_eq!(w.checkpoint.interval_steps, 8);
        assert!(w.checkpoint.host_copy);
        assert!((w.link_fault.fail_prob - 0.05).abs() < 1e-12);
        assert_eq!(w.link_fault.max_retries, 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn workload_arrivals_are_seeded_and_monotone() {
        let spec = WorkloadSpec { jobs: 20, ..Default::default() };
        let a = spec.arrivals();
        let b = spec.arrivals();
        assert_eq!(a.len(), 20);
        // Deterministic in the seed; different seeds give different
        // traces.
        assert!(a
            .iter()
            .zip(&b)
            .all(|(x, y)| x.0 == y.0 && x.1.network == y.1.network));
        let c = WorkloadSpec { jobs: 20, seed: 99, ..Default::default() }.arrivals();
        assert!(a.iter().zip(&c).any(|(x, y)| x.0 != y.0));
        // Arrival times are non-decreasing and strictly positive mean.
        assert!(a.windows(2).all(|w| w[0].0 <= w[1].0));
        assert!(a.last().unwrap().0 > 0.0);
        // The default mix rotates the paper's four networks.
        let nets: std::collections::BTreeSet<&str> =
            a.iter().map(|(_, j)| j.network.as_str()).collect();
        assert!(nets.len() > 1, "mix must actually vary: {nets:?}");
        // CLI overrides layer on top, including repeated --cancel and
        // --degrade occurrences.
        let args = crate::util::cli::Args::parse(
            [
                "--jobs", "4", "--mean-arrival", "5", "--cancel", "0:10", "--cancel", "1:20",
                "--degrade", "0:30:0.5", "--degrade", "0:60:2", "--per-step",
            ]
            .map(String::from),
        )
        .unwrap();
        let w = WorkloadSpec::default().apply_args(&args).unwrap();
        assert_eq!(w.jobs, 4);
        assert!((w.mean_interarrival_secs - 5.0).abs() < 1e-12);
        assert_eq!(w.cancels.len(), 2, "repeated --cancel must not collapse");
        assert_eq!(w.faults.len(), 2, "repeated --degrade must not collapse");
        assert!(w.faults[1].is_repair());
        assert!(!w.fast_forward);
        let args =
            crate::util::cli::Args::parse(["--retain-jobs"].map(String::from)).unwrap();
        assert!(WorkloadSpec::default().apply_args(&args).unwrap().retain_jobs);
    }

    #[test]
    fn workload_arrival_iter_is_lazy_and_identical_to_collecting() {
        let spec = WorkloadSpec { jobs: 50, seed: 31, ..Default::default() };
        let eager = spec.arrivals();
        let lazy: Vec<_> = spec.arrival_iter().collect();
        assert_eq!(eager.len(), lazy.len());
        for (e, l) in eager.iter().zip(&lazy) {
            assert_eq!(e.0.to_bits(), l.0.to_bits(), "identical RNG draw order, to the bit");
            assert_eq!(e.1.network, l.1.network);
        }
        // Taking a prefix draws only that prefix — the streaming trace
        // driver depends on never materializing the tail.
        let prefix: Vec<_> = spec.arrival_iter().take(3).collect();
        assert_eq!(prefix.len(), 3);
        assert_eq!(prefix[2].0.to_bits(), eager[2].0.to_bits());
    }

    #[test]
    fn default_mix_spreads_devices_and_grants_one_host() {
        let f = FleetExperimentConfig::default_mix(3, 8);
        assert_eq!(f.jobs.len(), 3);
        assert_eq!(f.jobs.iter().map(|j| j.num_csds).sum::<usize>(), 8);
        assert_eq!(f.jobs.iter().filter(|j| j.include_host).count(), 1);
        assert!(f.jobs[0].include_host);
    }
}
