//! # stannis — STANNIS (DAC'20) reproduction
//!
//! Distributed, in-storage training of neural networks on clusters of
//! computational storage devices (CSDs), reproduced as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the Stannis coordinator: Algorithm 1
//!   batch-size tuning, Eq. 1 load balancing, privacy-aware data
//!   placement, ring-allreduce gradient synchronization, and the full
//!   Newport CSD substrate (NAND flash, FTL, ECC, NVMe, ISP engine,
//!   TCP/IP-over-PCIe tunnel, OCFS2-style metadata sync) as a
//!   discrete-event simulation.
//! * **L2/L1 (build-time Python)** — JAX models + Pallas kernels,
//!   AOT-lowered to HLO text artifacts executed here via PJRT
//!   ([`runtime`]). Python never runs on the training path.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment
//! index mapping each paper table/figure to a module and bench.

pub mod allreduce;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod csd;
pub mod data;
pub mod fsync;
pub mod metrics;
pub mod model;
pub mod perfmodel;
pub mod power;
pub mod runtime;
pub mod sim;
pub mod tunnel;
pub mod util;

/// Crate-wide result type (PJRT, I/O and logic errors all flow as anyhow).
pub type Result<T> = anyhow::Result<T>;
