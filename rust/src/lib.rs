//! # stannis — STANNIS (DAC'20) reproduction
//!
//! Distributed, in-storage training of neural networks on clusters of
//! computational storage devices (CSDs), reproduced as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the Stannis coordinator: Algorithm 1
//!   batch-size tuning, Eq. 1 load balancing, privacy-aware data
//!   placement, ring-allreduce gradient synchronization, and the full
//!   Newport CSD substrate (NAND flash, FTL, ECC, NVMe, ISP engine,
//!   TCP/IP-over-PCIe tunnel, OCFS2-style metadata sync) as a
//!   discrete-event simulation. The [`fleet`] subsystem scales this to
//!   a shared chassis: a multi-job coordinator that admits many
//!   experiments onto one device pool, tunes and balances each job's
//!   group independently, runs them concurrently with per-job
//!   ring-allreduce domains, and re-tunes a job in place when one of
//!   its devices degrades mid-run.
//! * **L2/L1 (build-time Python)** — JAX models + Pallas kernels,
//!   AOT-lowered to HLO text artifacts executed here via PJRT
//!   ([`runtime`]). Python never runs on the training path.
//!
//! See `DESIGN.md` for the system inventory (§2), the fleet
//! architecture (§5) and the per-experiment index mapping each paper
//! table/figure to a module and bench (§7).

pub mod allreduce;
pub mod analysis;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod csd;
pub mod data;
pub mod fleet;
pub mod fsync;
pub mod ledger;
pub mod metrics;
pub mod model;
pub mod perfmodel;
pub mod power;
pub mod runtime;
pub mod sim;
pub mod tunnel;
pub mod util;
pub mod xla;

/// Crate-wide result type (PJRT, I/O and logic errors all flow as anyhow).
pub type Result<T> = anyhow::Result<T>;
