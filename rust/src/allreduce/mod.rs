//! Ring allreduce — Stannis's gradient synchronization (paper §II.B).
//!
//! Two faces of the same algorithm:
//!
//! * [`ring_allreduce_mean`] — the *numerics*: a faithful
//!   reduce-scatter + allgather over per-rank buffers, chunk by chunk,
//!   exactly as Horovod/NCCL execute it. Used on the real-execution
//!   path where each simulated worker holds a live gradient set.
//! * [`ring_time`] — the *timing*: the same 2(N-1) rounds of
//!   neighbor-to-neighbor messages booked on the TCP/IP-over-PCIe
//!   [`Tunnel`], which is where the paper's sync slowdown (Fig. 6/7)
//!   comes from. [`ring_time_shared`] is the same schedule for a ring
//!   co-tenanting the fabric with other jobs' rings (the fleet's
//!   per-job allreduce domains, DESIGN.md §5).
//!
//! A parameter-server baseline ([`param_server_time`]) reproduces the
//! TensorFlow-classic comparison the paper describes in §II.B.

use anyhow::{ensure, Result};

use crate::sim::SimTime;
use crate::tunnel::{NodeId, Tunnel};

/// In-place ring allreduce (mean) across `replicas`.
///
/// Every replica must have identical length; afterwards every replica
/// holds the elementwise mean. The chunk schedule is the textbook ring:
/// N ranks, N chunks; N-1 reduce-scatter rounds then N-1 allgather
/// rounds, rank r sending chunk (r - step) mod N rightward each round.
pub fn ring_allreduce_mean(replicas: &mut [Vec<f32>]) -> Result<()> {
    let n = replicas.len();
    ensure!(n > 0, "no replicas");
    if n == 1 {
        return Ok(());
    }
    let len = replicas[0].len();
    for (i, r) in replicas.iter().enumerate() {
        ensure!(r.len() == len, "replica {i} length {} != {len}", r.len());
    }

    // Chunk boundaries (last chunk absorbs the remainder).
    let bounds = |c: usize| -> (usize, usize) {
        let base = len / n;
        let start = c * base;
        let end = if c == n - 1 { len } else { start + base };
        (start, end)
    };

    // Split-borrow two distinct replicas (src read-only, dst mutable).
    // Safe: the ring guarantees src != dst for n >= 2.
    fn two<'a>(reps: &'a mut [Vec<f32>], src: usize, dst: usize) -> (&'a [f32], &'a mut [f32]) {
        debug_assert_ne!(src, dst);
        if src < dst {
            let (a, b) = reps.split_at_mut(dst);
            (&a[src], &mut b[0])
        } else {
            let (a, b) = reps.split_at_mut(src);
            (&b[0], &mut a[dst])
        }
    }

    // Reduce-scatter: after step s, rank (r+1) holds the running sum of
    // chunk (r - s .. r) from the senders upstream. In-round in-place
    // application is exact: within a round, chunk c is read by exactly
    // one src and written at exactly one dst, and dst's own outgoing
    // chunk is a different chunk id — no read-after-write hazard.
    for step in 0..n - 1 {
        for r in 0..n {
            let c = (r + n - step) % n;
            let (s, e) = bounds(c);
            let dst = (r + 1) % n;
            let (src_rep, dst_rep) = two(&mut replicas[..], r, dst);
            // Slice windows let LLVM autovectorize the accumulate.
            let (src_w, dst_w) = (&src_rep[s..e], &mut dst_rep[s..e]);
            for i in 0..src_w.len() {
                dst_w[i] += src_w[i];
            }
        }
    }

    // Each rank now owns the fully-reduced chunk (r + 1) mod n; scale
    // to the mean before circulating.
    let inv = 1.0 / n as f32;
    for r in 0..n {
        let c = (r + 1) % n;
        let (s, e) = bounds(c);
        for x in &mut replicas[r][s..e] {
            *x *= inv;
        }
    }

    // Allgather: circulate the owned chunks around the ring (pure
    // copies; same no-hazard argument as the reduce-scatter).
    for step in 0..n - 1 {
        for r in 0..n {
            let c = (r + 1 + n - step) % n;
            let (s, e) = bounds(c);
            let dst = (r + 1) % n;
            let (src_rep, dst_rep) = two(&mut replicas[..], r, dst);
            dst_rep[s..e].copy_from_slice(&src_rep[s..e]);
        }
    }
    Ok(())
}

/// Time the ring's 2(N-1)-step pipelined schedule over the tunnel.
///
/// Uses the standard α-β (latency-bandwidth) *fluid* model over the
/// tunnel's calibrated parameters rather than booking every chunk hop
/// on the FIFO timelines: NCCL/Horovod interleave chunk segments at
/// packet granularity, which fluid sharing captures and atomic
/// whole-chunk FIFO bookings mis-model as convoys (observed 20x
/// inflation). The message-level DES (`Tunnel::send`) remains in use
/// for control traffic (DLM, staging) where convoys are real.
///
/// Resource accounting per step (n ranks, chunk = bytes/n):
///   * each CSD packetizes 1 send + 1 receive           → 2·chunk
///   * the host crosses every csd↔csd relay twice, plus its own
///     send/receive                                      → ~(2n-2)·chunk
///   * each CSD's PCIe wire carries ≤ 2·chunk
/// Total = max resource busy time + per-step latency chain. The
/// PCIe-star topology makes the *host* the asymptotic bottleneck — a
/// physical fact of tunneling all CSD↔CSD traffic through the root
/// (see EXPERIMENTS.md notes).
pub fn ring_time(
    tunnel: &mut Tunnel,
    ranks: &[NodeId],
    bytes: usize,
    start: SimTime,
) -> SimTime {
    ring_time_fluid(tunnel, ranks, bytes, start, 1.0)
}

/// [`ring_time`] for a ring that shares the fabric with co-tenant
/// rings — the fleet's per-job allreduce domains.
///
/// Each job's CSDs (and their PCIe links and FE packetizers) are its
/// own, but every csd↔csd relay of *every* ring crosses the host root,
/// so `sharers` concurrent domains split the host-side packetization
/// budget evenly (fluid fair-share). With the default calibration the
/// FE is the bottleneck, so co-tenancy is nearly free until many rings
/// stack up — a property `integration_fleet` leans on.
pub fn ring_time_shared(
    tunnel: &mut Tunnel,
    ranks: &[NodeId],
    bytes: usize,
    start: SimTime,
    sharers: usize,
) -> SimTime {
    ring_time_fluid(tunnel, ranks, bytes, start, 1.0 / sharers.max(1) as f64)
}

/// Shared fluid-model core; `host_share` is this ring's fraction of
/// the host root's packetization bandwidth.
fn ring_time_fluid(
    tunnel: &mut Tunnel,
    ranks: &[NodeId],
    bytes: usize,
    start: SimTime,
    host_share: f64,
) -> SimTime {
    let n = ranks.len();
    if n <= 1 {
        return start;
    }
    let cfg = tunnel.config().clone();
    let chunk = (bytes.div_ceil(n)) as f64;
    let steps = 2 * (n - 1);
    let has_host = ranks.contains(&NodeId::Host);

    let pkts_per_chunk = (chunk / cfg.mtu as f64).ceil();
    let pkt = cfg.per_packet.as_secs_f64();

    // Per-step busy time on each resource class (fluid sharing).
    let t_csd_step = 2.0 * (chunk / cfg.sw_bw_csd + pkts_per_chunk * pkt);
    let host_crossings = if has_host { 2 * n - 2 } else { 2 * n } as f64;
    let t_host_step =
        host_crossings * (chunk / (cfg.sw_bw_host * host_share) + pkts_per_chunk * pkt);
    let t_wire_step = 2.0 * chunk / cfg.pcie_bw;
    // Pipeline startup: one chunk's first hop must traverse the ring
    // serially before steady state (α term).
    let hop_lat = 2.0 * cfg.hop_latency.as_secs_f64();

    let per_step = t_csd_step.max(t_host_step).max(t_wire_step) + hop_lat;
    let total = per_step * steps as f64;

    tunnel.note_aggregate((steps * n) as u64, (steps * n) as u64 * chunk as u64);
    start + SimTime::from_secs_f64(total)
}

/// Parameter-server baseline (paper §II.B, TensorFlow-classic): all
/// workers push `bytes` to the server, it averages, then broadcasts.
/// Same fluid model as [`ring_time`] for a fair comparison.
pub fn param_server_time(
    tunnel: &mut Tunnel,
    workers: &[NodeId],
    server: NodeId,
    bytes: usize,
    start: SimTime,
) -> SimTime {
    let cfg = tunnel.config().clone();
    let n_clients = workers.iter().filter(|&&w| w != server).count();
    if n_clients == 0 {
        return start;
    }
    let pkts = (bytes as f64 / cfg.mtu as f64).ceil();
    let pkt = cfg.per_packet.as_secs_f64();
    let (server_bw, client_bw) = if server == NodeId::Host {
        (cfg.sw_bw_host, cfg.sw_bw_csd)
    } else {
        (cfg.sw_bw_csd, cfg.sw_bw_csd)
    };
    // Gather: server ingests n·bytes serially; clients push in parallel.
    let t_client = bytes as f64 / client_bw + pkts * pkt;
    let t_server = n_clients as f64 * (bytes as f64 / server_bw + pkts * pkt);
    let one_way = t_client.max(t_server) + 2.0 * cfg.hop_latency.as_secs_f64();
    tunnel.note_aggregate(2 * n_clients as u64, 2 * (n_clients * bytes) as u64);
    start + SimTime::from_secs_f64(2.0 * one_way)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tunnel::TunnelConfig;
    use crate::util::prop;

    fn mean_of(replicas: &[Vec<f32>]) -> Vec<f32> {
        let n = replicas.len() as f32;
        let len = replicas[0].len();
        (0..len)
            .map(|i| replicas.iter().map(|r| r[i]).sum::<f32>() / n)
            .collect()
    }

    #[test]
    fn two_ranks_mean() {
        let mut reps = vec![vec![1.0, 2.0, 3.0, 4.0], vec![3.0, 2.0, 1.0, 0.0]];
        ring_allreduce_mean(&mut reps).unwrap();
        assert_eq!(reps[0], vec![2.0, 2.0, 2.0, 2.0]);
        assert_eq!(reps[1], reps[0]);
    }

    #[test]
    fn single_rank_noop() {
        let mut reps = vec![vec![5.0, 6.0]];
        ring_allreduce_mean(&mut reps).unwrap();
        assert_eq!(reps[0], vec![5.0, 6.0]);
    }

    #[test]
    fn length_mismatch_errors() {
        let mut reps = vec![vec![1.0], vec![1.0, 2.0]];
        assert!(ring_allreduce_mean(&mut reps).is_err());
    }

    #[test]
    fn property_equals_mean_any_n() {
        prop::check("ring allreduce == elementwise mean", |rng| {
            let n = 2 + rng.usize_below(9); // 2..10 ranks
            let len = 1 + rng.usize_below(200); // any length incl. < n
            let replicas: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..len).map(|_| (rng.f32() - 0.5) * 10.0).collect())
                .collect();
            let want = mean_of(&replicas);
            let mut got = replicas.clone();
            ring_allreduce_mean(&mut got).unwrap();
            for r in 0..n {
                for i in 0..len {
                    assert!(
                        (got[r][i] - want[i]).abs() <= 1e-4 * (1.0 + want[i].abs()),
                        "rank {r} elem {i}: {} vs {}",
                        got[r][i],
                        want[i]
                    );
                }
            }
        });
    }

    #[test]
    fn all_replicas_identical_after_reduce() {
        prop::check("replicas converge identically", |rng| {
            let n = 2 + rng.usize_below(6);
            let len = n + rng.usize_below(64);
            let mut reps: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..len).map(|_| rng.f32()).collect())
                .collect();
            ring_allreduce_mean(&mut reps).unwrap();
            for r in 1..n {
                assert_eq!(reps[r], reps[0], "rank {r} diverged");
            }
        });
    }

    #[test]
    fn ring_time_grows_sublinearly_with_ranks() {
        // Ring is bandwidth-optimal: per-worker bytes ≈ 2 * bytes * (N-1)/N,
        // so doubling N must not double the sync time.
        let bytes = 4 << 20;
        let mut t4 = Tunnel::new(4, TunnelConfig::default());
        let ranks4: Vec<NodeId> = std::iter::once(NodeId::Host)
            .chain((0..3).map(NodeId::Csd))
            .collect();
        let d4 = ring_time(&mut t4, &ranks4, bytes, SimTime::ZERO);

        let mut t8 = Tunnel::new(8, TunnelConfig::default());
        let ranks8: Vec<NodeId> = std::iter::once(NodeId::Host)
            .chain((0..7).map(NodeId::Csd))
            .collect();
        let d8 = ring_time(&mut t8, &ranks8, bytes, SimTime::ZERO);
        assert!(
            d8.as_secs_f64() < 2.0 * d4.as_secs_f64(),
            "ring not bandwidth-optimal: {d4} -> {d8}"
        );
    }

    #[test]
    fn co_tenant_rings_split_the_host_root() {
        let bytes = 13_880_000;
        let ranks: Vec<NodeId> =
            std::iter::once(NodeId::Host).chain((0..8).map(NodeId::Csd)).collect();
        let t = |sharers: usize| {
            let mut tn = Tunnel::new(8, TunnelConfig::default());
            ring_time_shared(&mut tn, &ranks, bytes, SimTime::ZERO, sharers).as_secs_f64()
        };
        let solo = t(1);
        let duo = t(2);
        let mob = t(32);
        // ring_time is exactly the exclusive case.
        let mut tn = Tunnel::new(8, TunnelConfig::default());
        assert_eq!(
            ring_time(&mut tn, &ranks, bytes, SimTime::ZERO).as_secs_f64(),
            solo
        );
        // The FE packetizer is the default bottleneck, so light
        // co-tenancy is nearly free...
        assert!(duo >= solo);
        assert!(duo < solo * 1.5, "2 sharers must not blow up sync: {solo} -> {duo}");
        // ...but enough concurrent rings choke the shared host root.
        assert!(mob > duo * 2.0, "32 sharers must choke the root: {duo} -> {mob}");
    }

    #[test]
    fn param_server_competitive_in_star_topology() {
        // Negative finding worth pinning (see EXPERIMENTS.md §Ablations):
        // the ring's bandwidth-optimality argument assumes a switched
        // mesh. Over the PCIe *star*, every csd↔csd hop relays through
        // the root, so the ring moves ~2x the volume a parameter server
        // does and loses. Stannis still implements the ring because the
        // paper (via Horovod/NCCL) does; this test documents the fabric
        // reality our DES exposes.
        let bytes = 4 << 20;
        let n = 12;
        let ranks: Vec<NodeId> = std::iter::once(NodeId::Host)
            .chain((0..n - 1).map(NodeId::Csd))
            .collect();
        let mut t1 = Tunnel::new(n - 1, TunnelConfig::default());
        let ring = ring_time(&mut t1, &ranks, bytes, SimTime::ZERO);
        let mut t2 = Tunnel::new(n - 1, TunnelConfig::default());
        let ps = param_server_time(&mut t2, &ranks, NodeId::Host, bytes, SimTime::ZERO);
        assert!(ps < ring, "PS {ps} should beat ring {ring} on a star fabric");
        assert!(
            ring.as_secs_f64() < 3.0 * ps.as_secs_f64(),
            "but not by an implausible factor: ring {ring} vs ps {ps}"
        );
    }

    #[test]
    fn ring_sync_cost_converges_with_ranks() {
        // The steady-state ring cost must approach an asymptote (the
        // per-endpoint 4·bytes·(N-1)/N law), not keep growing linearly —
        // this is what lets Fig. 6's per-node slowdown flatten.
        let bytes = 13_880_000;
        let t_at = |n: usize| {
            let ranks: Vec<NodeId> = std::iter::once(NodeId::Host)
                .chain((0..n).map(NodeId::Csd))
                .collect();
            let mut t = Tunnel::new(n, TunnelConfig::default());
            ring_time(&mut t, &ranks, bytes, SimTime::ZERO).as_secs_f64()
        };
        let (t6, t12, t24) = (t_at(6), t_at(12), t_at(24));
        let grow_early = t12 / t6;
        let grow_late = t24 / t12;
        assert!(grow_late < grow_early, "{t6} {t12} {t24}");
        assert!(t24 < 1.5 * t12, "sync must flatten: {t12} -> {t24}");
    }
}
