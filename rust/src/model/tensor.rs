//! A minimal dense f32 tensor — the host-side mirror of one PJRT buffer.

use crate::xla;
use crate::Result;

/// Dense row-major f32 tensor.
///
/// This is deliberately *not* a general ndarray: the coordinator only
/// ever moves whole parameter/gradient tensors between PJRT literals,
/// allreduce chunks and the optimizer, so shape + flat data suffice.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        anyhow::ensure!(
            n == data.len(),
            "shape {:?} implies {} elements, got {}",
            shape,
            n,
            data.len()
        );
        Ok(Self { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    /// Deterministic pseudo-random tensor (xorshift64*), for tests and
    /// synthetic data. Values are approximately N(0, std²) via CLT.
    pub fn randn(shape: Vec<usize>, std: f32, seed: u64) -> Self {
        let n: usize = shape.iter().product();
        let mut s = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).max(1);
        let mut next = move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            // map to [-0.5, 0.5)
            (s.wrapping_mul(0x2545_F491_4F6C_DD1D) >> 11) as f32 / (1u64 << 53) as f32 - 0.5
        };
        let data = (0..n)
            .map(|_| {
                // sum of 12 uniforms on [-0.5, 0.5) has variance 1
                let z: f32 = (0..12).map(|_| next()).sum();
                z * std
            })
            .collect();
        Self { shape, data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Convert into a PJRT literal with this tensor's shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }

    /// Read a PJRT literal back into a tensor (f32 only).
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Self::new(dims, data)
    }

    pub fn scale(&mut self, k: f32) {
        for v in &mut self.data {
            *v *= k;
        }
    }

    pub fn add_assign(&mut self, other: &Tensor) -> Result<()> {
        anyhow::ensure!(self.shape == other.shape, "shape mismatch in add_assign");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
        Ok(())
    }

    /// max |aᵢ - bᵢ| — used by tests and the allreduce verifier.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_size() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn randn_is_deterministic_and_roughly_normal() {
        let a = Tensor::randn(vec![1000], 1.0, 7);
        let b = Tensor::randn(vec![1000], 1.0, 7);
        assert_eq!(a, b);
        let mean: f32 = a.data().iter().sum::<f32>() / 1000.0;
        let var: f32 = a.data().iter().map(|v| (v - mean).powi(2)).sum::<f32>() / 1000.0;
        assert!(mean.abs() < 0.15, "mean {mean}");
        assert!((var - 1.0).abs() < 0.3, "var {var}");
    }

    #[test]
    fn scale_and_add() {
        let mut a = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::new(vec![3], vec![1.0, 1.0, 1.0]).unwrap();
        a.scale(2.0);
        a.add_assign(&b).unwrap();
        assert_eq!(a.data(), &[3.0, 5.0, 7.0]);
    }

    #[test]
    fn add_shape_mismatch_errors() {
        let mut a = Tensor::zeros(vec![2]);
        let b = Tensor::zeros(vec![3]);
        assert!(a.add_assign(&b).is_err());
    }
}
