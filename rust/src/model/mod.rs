//! Model-side state: dense tensors, flat parameter stores and SGD.
//!
//! The parameter *order* is the AOT interchange contract: it mirrors
//! `artifacts/manifest.json`, which in turn mirrors the declaration
//! order of the JAX model builder (python/compile/models/blocks.py).

mod optimizer;
mod params;
mod tensor;

pub use optimizer::{Sgd, SgdConfig};
pub use params::ParamStore;
pub use tensor::Tensor;
