//! Flat parameter store — one model replica as an ordered tensor list.

use crate::runtime::ParamSpec;
use crate::Result;

use super::Tensor;

/// An ordered set of parameter tensors for one worker's model replica.
///
/// Order is the manifest order (= PJRT argument order); the store never
/// reorders. Gradients use the same layout, so `ParamStore` doubles as
/// the gradient container flowing through ring-allreduce.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamStore {
    tensors: Vec<Tensor>,
}

impl ParamStore {
    pub fn new(tensors: Vec<Tensor>) -> Self {
        Self { tensors }
    }

    /// Zero-filled store matching a manifest's parameter specs.
    pub fn zeros_like_specs(specs: &[ParamSpec]) -> Self {
        Self {
            tensors: specs.iter().map(|s| Tensor::zeros(s.shape.clone())).collect(),
        }
    }

    /// Validate this store against the manifest specs (shape + count).
    pub fn check_specs(&self, specs: &[ParamSpec]) -> Result<()> {
        anyhow::ensure!(
            self.tensors.len() == specs.len(),
            "param count {} != manifest {}",
            self.tensors.len(),
            specs.len()
        );
        for (t, s) in self.tensors.iter().zip(specs) {
            anyhow::ensure!(
                t.shape() == s.shape.as_slice(),
                "param {:?}: shape {:?} != manifest {:?}",
                s.name,
                t.shape(),
                s.shape
            );
        }
        Ok(())
    }

    pub fn tensors(&self) -> &[Tensor] {
        &self.tensors
    }

    pub fn tensors_mut(&mut self) -> &mut [Tensor] {
        &mut self.tensors
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Total number of scalar parameters.
    pub fn num_scalars(&self) -> usize {
        self.tensors.iter().map(Tensor::len).sum()
    }

    /// Serialize all tensors into one contiguous f32 vector
    /// (manifest order) — the allreduce wire format.
    pub fn to_flat(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.num_scalars());
        for t in &self.tensors {
            out.extend_from_slice(t.data());
        }
        out
    }

    /// Overwrite tensor contents from a flat vector (inverse of
    /// [`Self::to_flat`]). Length must match exactly.
    pub fn load_flat(&mut self, flat: &[f32]) -> Result<()> {
        anyhow::ensure!(
            flat.len() == self.num_scalars(),
            "flat length {} != store scalars {}",
            flat.len(),
            self.num_scalars()
        );
        let mut off = 0;
        for t in &mut self.tensors {
            let n = t.len();
            t.data_mut().copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        Ok(())
    }

    pub fn is_finite(&self) -> bool {
        self.tensors.iter().all(Tensor::is_finite)
    }

    /// Largest elementwise divergence from another replica — the
    /// consistency metric the accuracy experiment (§V.C) reports.
    pub fn max_abs_diff(&self, other: &ParamStore) -> f32 {
        self.tensors
            .iter()
            .zip(&other.tensors)
            .map(|(a, b)| a.max_abs_diff(b))
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> ParamStore {
        ParamStore::new(vec![
            Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap(),
            Tensor::new(vec![3], vec![5.0, 6.0, 7.0]).unwrap(),
        ])
    }

    #[test]
    fn flat_roundtrip() {
        let s = store();
        let flat = s.to_flat();
        assert_eq!(flat, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        let mut z = ParamStore::new(vec![Tensor::zeros(vec![2, 2]), Tensor::zeros(vec![3])]);
        z.load_flat(&flat).unwrap();
        assert_eq!(z, s);
    }

    #[test]
    fn load_flat_length_checked() {
        let mut s = store();
        assert!(s.load_flat(&[0.0; 3]).is_err());
    }

    #[test]
    fn num_scalars() {
        assert_eq!(store().num_scalars(), 7);
    }
}
