//! SGD with momentum + the linear-scaling/warm-up schedule of
//! Goyal et al. (the paper's §IV accuracy-preservation strategy).

use crate::Result;

use super::ParamStore;

/// Optimizer hyperparameters.
///
/// The paper (citing Goyal et al.) prescribes (a) a learning rate
/// scaled linearly with the number of workers and (b) a warm-up that
/// ramps from `base_lr` to the scaled rate over `warmup_steps`.
#[derive(Debug, Clone, Copy)]
pub struct SgdConfig {
    pub base_lr: f32,
    pub momentum: f32,
    /// Linear-scaling multiplier: total cluster batch / reference
    /// batch (Goyal et al. scale lr with the *total* batch — in
    /// heterogeneous Stannis clusters worker counts and batch sizes
    /// decouple, so the ratio, not the worker count, is what scales).
    pub lr_scale: f32,
    /// Steps over which to linearly ramp from base_lr to the scaled lr.
    pub warmup_steps: u64,
}

impl Default for SgdConfig {
    fn default() -> Self {
        Self { base_lr: 0.01, momentum: 0.9, lr_scale: 1.0, warmup_steps: 0 }
    }
}

/// Plain SGD with momentum over a [`ParamStore`].
#[derive(Debug, Clone)]
pub struct Sgd {
    cfg: SgdConfig,
    velocity: Option<ParamStore>,
    step: u64,
}

impl Sgd {
    pub fn new(cfg: SgdConfig) -> Self {
        Self { cfg, velocity: None, step: 0 }
    }

    pub fn step_count(&self) -> u64 {
        self.step
    }

    /// Effective learning rate at the current step (warm-up + linear
    /// scaling). After warm-up this is `base_lr * lr_scale`.
    pub fn current_lr(&self) -> f32 {
        let scaled = self.cfg.base_lr * self.cfg.lr_scale;
        if self.cfg.warmup_steps == 0 || self.step >= self.cfg.warmup_steps {
            return scaled;
        }
        let frac = self.step as f32 / self.cfg.warmup_steps as f32;
        self.cfg.base_lr + (scaled - self.cfg.base_lr) * frac
    }

    /// In-place update: `v = m·v + g; p -= lr·v`.
    pub fn apply(&mut self, params: &mut ParamStore, grads: &ParamStore) -> Result<()> {
        anyhow::ensure!(
            params.len() == grads.len(),
            "param/grad tensor count mismatch: {} vs {}",
            params.len(),
            grads.len()
        );
        let lr = self.current_lr();
        let m = self.cfg.momentum;

        if m == 0.0 {
            for (p, g) in params.tensors_mut().iter_mut().zip(grads.tensors()) {
                for (pv, gv) in p.data_mut().iter_mut().zip(g.data()) {
                    *pv -= lr * gv;
                }
            }
        } else {
            let vel = self
                .velocity
                .get_or_insert_with(|| ParamStore::new(
                    grads.tensors().iter().map(|t| super::Tensor::zeros(t.shape().to_vec())).collect(),
                ));
            for ((p, g), v) in params
                .tensors_mut()
                .iter_mut()
                .zip(grads.tensors())
                .zip(vel.tensors_mut())
            {
                for ((pv, gv), vv) in p.data_mut().iter_mut().zip(g.data()).zip(v.data_mut()) {
                    *vv = m * *vv + gv;
                    *pv -= lr * *vv;
                }
            }
        }
        self.step += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Tensor;

    fn one(v: f32) -> ParamStore {
        ParamStore::new(vec![Tensor::new(vec![1], vec![v]).unwrap()])
    }

    #[test]
    fn plain_sgd_step() {
        let mut opt = Sgd::new(SgdConfig { base_lr: 0.1, momentum: 0.0, ..Default::default() });
        let mut p = one(1.0);
        opt.apply(&mut p, &one(2.0)).unwrap();
        assert!((p.tensors()[0].data()[0] - 0.8).abs() < 1e-6);
    }

    #[test]
    fn momentum_accumulates() {
        let mut opt = Sgd::new(SgdConfig { base_lr: 0.1, momentum: 0.5, ..Default::default() });
        let mut p = one(0.0);
        opt.apply(&mut p, &one(1.0)).unwrap(); // v=1,   p=-0.1
        opt.apply(&mut p, &one(1.0)).unwrap(); // v=1.5, p=-0.25
        assert!((p.tensors()[0].data()[0] + 0.25).abs() < 1e-6);
    }

    #[test]
    fn warmup_ramps_to_scaled_lr() {
        let cfg = SgdConfig { base_lr: 0.01, momentum: 0.0, lr_scale: 4.0, warmup_steps: 10 };
        let mut opt = Sgd::new(cfg);
        assert!((opt.current_lr() - 0.01).abs() < 1e-7);
        let mut p = one(0.0);
        for _ in 0..10 {
            opt.apply(&mut p, &one(0.0)).unwrap();
        }
        assert!((opt.current_lr() - 0.04).abs() < 1e-7);
    }

    #[test]
    fn mismatched_grads_error() {
        let mut opt = Sgd::new(SgdConfig::default());
        let mut p = one(0.0);
        let g = ParamStore::new(vec![]);
        assert!(opt.apply(&mut p, &g).is_err());
    }
}
