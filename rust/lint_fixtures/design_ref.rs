//! Fixture for the `design-ref` rule: one reference that resolves to a
//! real heading (clean) and one that does not (flagged).
//! This file is never compiled — `stannis lint` reads it as text.

/// Shard deal follows DESIGN.md §2.
pub fn resolves() {}

/// Allegedly specified by DESIGN.md §No-Such-Section.
pub fn dangles() {}
