//! Fixture for the `hash-iter` rule: one untagged default-hasher use
//! (flagged) and one tagged keyed-lookup-only use (suppressed).
//! This file is never compiled — `stannis lint` reads it as text.

use std::collections::HashMap;

pub fn suppressed_lookup_table() -> u32 {
    // lint: allow(hash-iter) — keyed lookup only, never iterated
    let m: HashMap<u32, u32> = HashMap::new();
    m.get(&1).copied().unwrap_or(0)
}
