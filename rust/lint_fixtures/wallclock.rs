//! Fixture for the `wallclock` rule: one untagged wall-clock read
//! (flagged) and one tagged read (suppressed).
//! This file is never compiled — `stannis lint` reads it as text.

pub fn flagged() -> u64 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_nanos() as u64
}

pub fn suppressed() -> u64 {
    let t0 = std::time::Instant::now(); // lint: allow(wallclock) — times the process, not the sim
    t0.elapsed().as_nanos() as u64
}
