//! Fixture for the `float-ledger` rule: a ledger struct whose impl has
//! one untagged float accumulation (flagged), an integer accumulation
//! (never flagged), and tagged float lines (suppressed).
//! This file is never compiled — `stannis lint` reads it as text.

pub struct FleetTotals {
    pub images: u64,
    pub energy_j: f64,
}

impl FleetTotals {
    pub fn absorb(&mut self, other: &FleetTotals) {
        self.images += other.images;
        self.energy_j += other.energy_j;
    }

    pub fn absorb_tagged(&mut self, other: &FleetTotals) {
        self.images += other.images;
        // lint: allow(float-ledger) — display-only joules, never compared bitwise
        self.energy_j += other.energy_j;
        // lint: allow(float-ledger) — display-only rate for the footer
        let _rate = other.images as f64;
    }
}
