//! Fixture for the `invariant-test` rule: `Covered` has a test naming
//! it next to an invariant call (clean); `Orphan` has none (flagged).
//! This file is never compiled — `stannis lint` reads it as text.

pub struct Covered {
    count: u64,
}

impl Covered {
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.count < u64::MAX {
            Ok(())
        } else {
            Err("count overflow".into())
        }
    }
}

pub struct Orphan {
    count: u64,
}

impl Orphan {
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.count < u64::MAX {
            Ok(())
        } else {
            Err("count overflow".into())
        }
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn covered_invariants_hold() {
        let c = super::Covered { count: 1 };
        c.check_invariants().unwrap();
    }
}
