//! Bench: the job-history ledger at fleet scale — append a synthetic
//! one-million-record ledger, then query it three ways: a full scan
//! (filter on a non-indexed field), a footer-pruned scan (filter on
//! `retired_at`, where segment min/max metadata skips most of the
//! ledger), and a keyset-paginated walk. Before recording anything the
//! bench asserts the pruned scan returns exactly what the unpruned
//! evaluation of the same filter returns, and that pagination over a
//! prefix walks the total order with no duplicates or gaps.
//!
//! Emits machine-readable numbers to `BENCH_9.json` (section
//! `"ledger"`).
//!
//! Run: `cargo bench --bench query`

// Benches are wall-clock consumers by definition; the crate-wide
// clippy gate on time sources is lifted per bench target.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use stannis::fleet::{JobId, JobReport, JobState, RetiredRecord};
use stannis::ledger::{aggregate, compile, decode_cursor, page, Agg, Field, Key, LedgerStore,
    LedgerWriter};
use stannis::metrics::{f, print_table, record_bench_json_to};
use stannis::sim::SimTime;
use stannis::util::rng::Rng;

const RECORDS: u64 = 1_000_000;

/// Deterministic synthetic retirement stream: times strictly increase
/// (as a real run's do), ids cycle a bounded live window, energies and
/// flags come from a seeded generator.
fn synth(i: u64, rng: &mut Rng) -> RetiredRecord {
    let retired_ns = 1_000_000_000 + i * 2_000_000 + rng.below(1_000_000);
    let energy = 20.0 + rng.f64() * 400.0;
    RetiredRecord {
        retired_at: SimTime(retired_ns),
        report: JobReport {
            id: JobId(i),
            state: if rng.bool(0.07) { JobState::Cancelled } else { JobState::Completed },
            network: if i % 3 == 0 { "mobilenet_v2".into() } else { "squeezenet".into() },
            devices: vec![(i % 24) as usize, ((i + 7) % 24) as usize],
            held_host: false,
            bs_csd: 25,
            bs_host: 0,
            steps_done: 20,
            steps_per_epoch: 10,
            images: 1000,
            submitted_at: SimTime(i * 2_000_000),
            admitted_at: SimTime(i * 2_000_000 + 500),
            finished_at: SimTime(retired_ns),
            queue_wait: SimTime(rng.below(5_000_000_000)),
            elapsed: SimTime(retired_ns - i * 2_000_000),
            images_per_sec: 50.0 + rng.f64() * 100.0,
            sync_fraction: rng.f64() * 0.4,
            energy_j: energy,
            j_per_image: energy / 1000.0,
            link_bytes: 1 << 22,
            bytes_moved: 0,
            images_moved: 0,
            lock_wait: SimTime(0),
            retunes: 0,
            drained: false,
            crashed: rng.bool(0.02),
            lost_steps: 0,
            checkpoint_bytes: 0,
        },
    }
}

fn main() {
    let dir = std::env::temp_dir().join(format!("stannis_bench_query_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // --- Append path ------------------------------------------------------
    let mut w = LedgerWriter::new(dir.clone());
    let mut rng = Rng::new(9);
    let t0 = Instant::now();
    for i in 0..RECORDS {
        w.append(&synth(i, &mut rng));
    }
    w.finish().expect("ledger seals");
    let append_wall = t0.elapsed().as_secs_f64();
    let append_mb = w.bytes_written() as f64 / 1e6;
    let append_mb_per_s = append_mb / append_wall.max(1e-9);

    let store = LedgerStore::open(&dir).expect("ledger opens");
    assert_eq!(store.records_total(), RECORDS, "every appended record is accounted for");
    let segments = store.segments().len();

    // --- Full scan: filter on a field footers cannot prune ---------------
    let full_filter = compile("energy_j > 380 and state = done").expect("filter compiles");
    let t0 = Instant::now();
    let full = aggregate(&store, Some(&full_filter), &[Agg::Count, Agg::Sum(Field::EnergyJ)])
        .expect("full scan");
    let full_wall = t0.elapsed().as_secs_f64();
    let full_hits = full[0].1 as u64;
    assert!(full_hits > 0, "the energy threshold must select a non-trivial set");

    // --- Pruned scan: a retired_at window covering ~1% of the ledger -----
    // Times span [1e9, 1e9 + 2e6*RECORDS); take a 1%-wide slice from the
    // middle. Footer min/max ranges let the store skip ~99% of segments.
    let lo = 1.0 + 2e-3 * (RECORDS as f64) * 0.50;
    let hi = 1.0 + 2e-3 * (RECORDS as f64) * 0.51;
    let pruned_filter =
        compile(&format!("retired_at >= {lo} and retired_at < {hi}")).expect("window compiles");
    let t0 = Instant::now();
    let pruned = aggregate(&store, Some(&pruned_filter), &[Agg::Count]).expect("pruned scan");
    let pruned_wall = t0.elapsed().as_secs_f64();
    let pruned_hits = pruned[0].1 as u64;
    assert!(pruned_hits > 0, "the window must be non-empty");
    assert!(
        pruned_hits < RECORDS / 20,
        "the window must be narrow enough for pruning to matter ({pruned_hits} hits)"
    );
    // Guard: pruning is an optimization, never a result change — the
    // same window evaluated record-by-record over every segment (no
    // footer skipping) must agree exactly.
    let mut by_hand = 0u64;
    for seg in store.segments() {
        for (_, r) in store.read_segment(seg).expect("segment reads") {
            let s = r.retired_at.as_secs_f64();
            if s >= lo && s < hi {
                by_hand += 1;
            }
        }
    }
    assert_eq!(by_hand, pruned_hits, "footer pruning changed the result set");

    // --- Paginated walk over the window -----------------------------------
    const PAGE: usize = 1000;
    let t0 = Instant::now();
    let mut cursor: Option<Key> = None;
    let mut walked = 0u64;
    let mut last: Option<Key> = None;
    loop {
        let p = page(&store, Some(&pruned_filter), cursor, PAGE).expect("page");
        for (k, _) in &p.records {
            if let Some(prev) = last {
                assert!(prev < *k, "pagination must walk a strictly increasing key order");
            }
            last = Some(*k);
        }
        walked += p.records.len() as u64;
        match p.next {
            Some(c) => cursor = Some(decode_cursor(&c).expect("own cursor decodes")),
            None => break,
        }
    }
    let page_wall = t0.elapsed().as_secs_f64();
    assert_eq!(walked, pruned_hits, "pagination must visit exactly the match set");
    let paged_records_per_s = walked as f64 / page_wall.max(1e-9);

    print_table(
        &format!("Ledger — {RECORDS} records, {segments} segment(s), {append_mb:.0} MB"),
        &["phase", "wall", "result"],
        &[
            vec!["append".into(), format!("{append_wall:.2} s"), format!("{append_mb_per_s:.0} MB/s")],
            vec!["full scan".into(), format!("{full_wall:.2} s"), format!("{full_hits} hit(s)")],
            vec![
                "pruned scan".into(),
                format!("{pruned_wall:.3} s"),
                format!("{pruned_hits} hit(s), {:.1}x full-scan", full_wall / pruned_wall.max(1e-9)),
            ],
            vec![
                "paginate".into(),
                format!("{page_wall:.2} s"),
                format!("{} page(s), {paged_records_per_s:.0} rec/s", walked.div_ceil(PAGE as u64)),
            ],
        ],
    );
    println!("pruned/full wall ratio: {}", f(pruned_wall / full_wall.max(1e-9), 4));

    record_bench_json_to(
        "BENCH_9.json",
        "ledger",
        &[
            ("records", RECORDS as f64),
            ("segments", segments as f64),
            ("ledger_mb", append_mb),
            ("append_mb_per_s", append_mb_per_s),
            ("full_scan_wall_s", full_wall),
            ("pruned_scan_wall_s", pruned_wall),
            ("pruned_over_full_wall", pruned_wall / full_wall.max(1e-9)),
            ("paginated_records_per_s", paged_records_per_s),
        ],
    );

    let _ = std::fs::remove_dir_all(&dir);
}
