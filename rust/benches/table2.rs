//! Bench: regenerate paper Table II — energy per image, energy saving
//! and FLOPS/W as Newport CSDs replace idle conventional SSDs, using
//! the component power model + the modeled cluster (with flash/NVMe
//! I/O staged through the CSD substrate so link/flash energy is real).
//!
//! Run: `cargo bench --bench table2`

use stannis::coordinator::{tune, ScheduleConfig, Scheduler, TuneConfig};
use stannis::csd::CsdConfig;
use stannis::metrics::{f, print_table};
use stannis::perfmodel::PerfModel;
use stannis::power::{account_interval, EnergyMeter, PowerConfig};
use stannis::tunnel::TunnelConfig;

const PAPER: [(usize, f64, f64, &str); 5] = [
    (0, 13.10, 0.0, "5.87M"),
    (4, 8.30, 37.0, "7.05M"),
    (8, 6.84, 48.0, "8.18M"),
    (16, 5.05, 62.0, "10.37M"),
    (24, 4.02, 69.0, "12.26M"),
];

fn run_point(n: usize, nbs: usize, hbs: usize) -> (f64, f64) {
    let mut sched = Scheduler::new(
        PerfModel::default(),
        n,
        TunnelConfig::default(),
        CsdConfig::default(),
    );
    sched.preload_data(64).unwrap();
    let r = sched
        .run(&ScheduleConfig {
            network: "mobilenet_v2".into(),
            num_csds: n,
            include_host: true,
            bs_csd: nbs,
            bs_host: hbs,
            steps: 3,
            image_bytes: 12 * 1024,
            stage_io: true,
            per_step: false,
        })
        .unwrap();

    let power = PowerConfig::default();
    let mut meter = EnergyMeter::new();
    account_interval(
        &mut meter,
        &power,
        r.elapsed,
        n,
        24,
        true,
        r.link_bytes,
        r.flash_reads,
        0,
    );
    let images = (r.images_per_sec * r.elapsed.as_secs_f64()).round();
    (meter.total_joules() / images, r.images_per_sec)
}

fn main() {
    let mut m = PerfModel::default();
    let t = tune(&mut m, "mobilenet_v2", &TuneConfig::default()).unwrap();

    let (base_j, _) = run_point(0, t.newport_bs, t.host_bs);
    let mut rows = Vec::new();
    for (n, paper_j, paper_saving, paper_fw) in PAPER {
        let (j_img, ips) = run_point(n, t.newport_bs, t.host_bs);
        let saving = 100.0 * (1.0 - j_img / base_j);
        let power = PowerConfig::default().system_power_w(n, 24, true);
        // FLOPS/W with the paper's own per-image FLOP count (7.16M * 2).
        let flops_w = ips * 7.16e6 * 2.0 / power;
        rows.push(vec![
            n.to_string(),
            f(j_img, 2),
            f(paper_j, 2),
            format!("{}%", f(saving, 0)),
            format!("{}%", f(paper_saving, 0)),
            format!("{:.2}M", flops_w / 1e6),
            paper_fw.to_string(),
        ]);
    }
    print_table(
        "Table II — energy per image, MobileNetV2 (ours vs paper)",
        &["CSDs", "J/img", "paper", "saving", "paper", "FLOPS/W", "paper"],
        &rows,
    );
    println!(
        "\nnote: the paper's FLOPS/W row is inconsistent with its own J/img row \
         (see EXPERIMENTS.md); we report the model's value."
    );

    // Shape assertions.
    let (j0, _) = run_point(0, t.newport_bs, t.host_bs);
    let (j24, _) = run_point(24, t.newport_bs, t.host_bs);
    let saving24 = 100.0 * (1.0 - j24 / j0);
    assert!((j0 - 13.10).abs() < 1.0, "0-CSD endpoint: {j0:.2} vs paper 13.10");
    assert!(
        (saving24 - 69.0).abs() < 8.0,
        "24-CSD saving: {saving24:.0}% vs paper 69%"
    );
    println!("shape checks passed: {j0:.2} J/img @0, {j24:.2} J/img @24 ({saving24:.0}% saving)");
}
