//! Bench: regenerate paper Fig. 7 — relative speedup vs host-alone for
//! each network as CSDs are added, with the paper's qualitative claims
//! checked: smaller networks speed up more; parameter count drives the
//! sync penalty (InceptionV3 worst); MobileNetV2 peaks near 2.7x.
//!
//! Run: `cargo bench --bench fig7`

// Benches are wall-clock consumers by definition; the crate-wide
// clippy gate on time sources is lifted per bench target.
#![allow(clippy::disallowed_methods)]

use stannis::coordinator::{modeled_throughput, tune, TuneConfig};
use stannis::metrics::{f, print_table, record_bench_json};
use stannis::perfmodel::{calib_for, PerfModel};

const COUNTS: [usize; 10] = [0, 1, 2, 4, 6, 8, 12, 16, 20, 24];
const NETS: [&str; 4] = ["mobilenet_v2", "nasnet", "inception_v3", "squeezenet"];

fn main() {
    let t0 = std::time::Instant::now();
    let cfg = TuneConfig::default();
    let mut speedup_at_24 = Vec::new();

    let mut rows = Vec::new();
    for net in NETS {
        let mut m = PerfModel::default();
        let t = tune(&mut m, net, &cfg).unwrap();
        let base = modeled_throughput(net, 0, true, t.newport_bs, t.host_bs, 3)
            .unwrap()
            .images_per_sec;
        let mut cells = vec![net.to_string()];
        for &n in &COUNTS {
            let r = modeled_throughput(net, n, true, t.newport_bs, t.host_bs, 3).unwrap();
            let s = r.images_per_sec / base;
            if n == 24 {
                speedup_at_24.push((net, s, r.sync_fraction));
            }
            cells.push(format!("{}x", f(s, 2)));
        }
        rows.push(cells);
    }
    let labels: Vec<String> = COUNTS.iter().map(|n| n.to_string()).collect();
    let mut headers = vec!["speedup @ #CSDs"];
    headers.extend(labels.iter().map(String::as_str));
    print_table("Fig. 7 — speedup vs host-alone", &headers, &rows);

    // --- The paper's explanatory row: params vs sync share ---------------
    let mut rows = Vec::new();
    for (net, s, sync) in &speedup_at_24 {
        let c = calib_for(net).unwrap();
        rows.push(vec![
            net.to_string(),
            format!("{:.2}M", c.params as f64 / 1e6),
            format!("{:.0}M", c.macs_per_image as f64 / 1e6),
            format!("{}x", f(*s, 2)),
            format!("{}%", f(sync * 100.0, 1)),
        ]);
    }
    print_table(
        "Speedup @24 CSDs vs model size (paper: more params => more sync => less speedup)",
        &["network", "params", "MACs/img", "speedup", "sync share"],
        &rows,
    );

    // --- Shape assertions (fail loudly if the reproduction drifts) -------
    let get = |name: &str| speedup_at_24.iter().find(|(n, _, _)| *n == name).unwrap().1;
    let (mv, nn, inc, sq) = (
        get("mobilenet_v2"),
        get("nasnet"),
        get("inception_v3"),
        get("squeezenet"),
    );
    assert!((mv - 2.7).abs() < 0.25, "paper headline: ~2.7x for MobileNetV2, got {mv:.2}");
    assert!(inc < nn && nn < mv, "ordering must hold: inception < nasnet < mobilenet");
    assert!(sq < mv, "squeezenet must trail mobilenet (paper §V-A)");
    println!("\nshape checks passed: mobilenet {mv:.2}x, squeezenet {sq:.2}x, nasnet {nn:.2}x, inception {inc:.2}x");

    let wall = t0.elapsed().as_secs_f64();
    println!("fig7 end-to-end wall time: {:.3} ms", wall * 1e3);
    record_bench_json("fig7", &[("end_to_end_wall_s", wall)]);
}
