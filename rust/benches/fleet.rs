//! Bench: the fleet coordinator under multi-tenancy — makespan,
//! aggregate throughput and energy as more concurrent jobs share one
//! 24-bay chassis, plus the cost of a mid-run degradation re-tune and
//! the simulator's own overhead.
//!
//! Run: `cargo bench --bench fleet`

use stannis::config::FleetExperimentConfig;
use stannis::fleet::{Fleet, FleetConfig, FleetReport};
use stannis::metrics::{bench, f, print_table};
use stannis::sim::SimTime;

const POOL: usize = 24;

fn run_mix(n_jobs: usize, fault: Option<(usize, u64, f64)>) -> FleetReport {
    let spec = FleetExperimentConfig::default_mix(n_jobs, POOL);
    let mut fleet = Fleet::new(FleetConfig { total_csds: POOL, ..Default::default() });
    for job in &spec.jobs {
        fleet.submit(job.clone());
    }
    if let Some((device, at_secs, factor)) = fault {
        fleet.inject_degradation(SimTime::secs(at_secs), device, factor);
    }
    fleet.run().expect("fleet run")
}

fn main() {
    // --- Multi-tenancy scaling: 1..12 jobs over 24 devices ----------------
    let mut rows = Vec::new();
    for n_jobs in [1usize, 2, 4, 8, 12] {
        let r = run_mix(n_jobs, None);
        rows.push(vec![
            n_jobs.to_string(),
            format!("{}", r.makespan),
            r.total_images.to_string(),
            f(r.aggregate_ips, 1),
            f(r.jobs_energy_j / r.total_images.max(1) as f64, 2),
            f(r.queue_wait.mean(), 1),
            f(r.queue_wait.max(), 1),
        ]);
    }
    print_table(
        "Fleet scaling — default mix on a 24-bay chassis",
        &["jobs", "makespan", "imgs", "agg img/s", "J/img (jobs)", "wait mean s", "wait max s"],
        &rows,
    );

    // --- Degradation: retune cost on a co-tenanted fleet ------------------
    let clean = run_mix(4, None);
    let faulted = run_mix(4, Some((0, 60, 0.6)));
    let mut rows = Vec::new();
    for (label, r) in [("healthy", &clean), ("device0 @60s -> 60%", &faulted)] {
        rows.push(vec![
            label.to_string(),
            format!("{}", r.makespan),
            f(r.aggregate_ips, 1),
            r.retunes.to_string(),
        ]);
    }
    print_table(
        "Degradation — one throttled device, 4-job fleet",
        &["scenario", "makespan", "agg img/s", "retunes"],
        &rows,
    );
    let slowdown = faulted.makespan.as_secs_f64() / clean.makespan.as_secs_f64().max(1e-12);
    println!("makespan slowdown from the fault: {}x", f(slowdown, 3));

    // --- Simulation cost --------------------------------------------------
    let r = bench("fleet_run(4 jobs, 24 CSDs, staged IO)", 1, 10, || {
        std::hint::black_box(run_mix(4, None));
    });
    println!("\n{}", r.summary());
    let r = bench("fleet_run(12 jobs, 24 CSDs, staged IO)", 1, 5, || {
        std::hint::black_box(run_mix(12, None));
    });
    println!("{}", r.summary());
}
