//! Bench: the fleet coordinator under multi-tenancy — makespan,
//! aggregate throughput and energy as more concurrent jobs share one
//! 24-bay chassis, the cost of a mid-run degradation re-tune, the
//! simulator's own overhead, and the steady-state fast-forward against
//! the per-step reference at production step counts (both measured in
//! the same run via the `fast_forward` switch — the CLI's `--per-step`).
//!
//! Emits machine-readable numbers to `BENCH_2.json` (section `"fleet"`).
//!
//! Run: `cargo bench --bench fleet`

// Benches are wall-clock consumers by definition; the crate-wide
// clippy gate on time sources is lifted per bench target.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use stannis::config::FleetExperimentConfig;
use stannis::fleet::{Fleet, FleetConfig, FleetReport};
use stannis::metrics::{bench, f, print_table, record_bench_json, RunningStat};
use stannis::sim::SimTime;

const POOL: usize = 24;
/// Step count for the fast-forward comparison: large enough that the
/// per-step event loop dominates wall time.
const LARGE_STEPS: usize = 20_000;

fn run_mix(n_jobs: usize, fault: Option<(usize, u64, f64)>) -> FleetReport {
    let spec = FleetExperimentConfig::default_mix(n_jobs, POOL);
    // Legacy per-step staging (data plane off) so this section keeps
    // measuring the stateful staged-IO executor; the data plane has
    // its own ledger in benches/dataplane.rs -> BENCH_3.json.
    let mut fleet = Fleet::new(FleetConfig {
        total_csds: POOL,
        data_plane: false,
        ..Default::default()
    });
    for job in &spec.jobs {
        fleet.submit(job.clone());
    }
    if let Some((device, at_secs, factor)) = fault {
        fleet.inject_degradation(SimTime::secs(at_secs), device, factor);
    }
    fleet.run().expect("fleet run")
}

fn run_large(n_jobs: usize, fast_forward: bool) -> (FleetReport, f64) {
    let mut spec = FleetExperimentConfig::default_mix(n_jobs, POOL);
    for job in &mut spec.jobs {
        job.steps = LARGE_STEPS;
    }
    let mut fleet = Fleet::new(FleetConfig {
        total_csds: POOL,
        stage_io: false,
        fast_forward,
        ..Default::default()
    });
    for job in &spec.jobs {
        fleet.submit(job.clone());
    }
    // A late fault forces one mid-run re-tune window split.
    fleet.inject_degradation(SimTime::secs(3600), 0, 0.8);
    let t0 = Instant::now();
    let report = fleet.run().expect("fleet run");
    (report, t0.elapsed().as_secs_f64())
}

fn main() {
    // --- Multi-tenancy scaling: 1..12 jobs over 24 devices ----------------
    let mut rows = Vec::new();
    let mut sweep_wait = RunningStat::new();
    for n_jobs in [1usize, 2, 4, 8, 12] {
        let r = run_mix(n_jobs, None);
        sweep_wait.merge(&r.queue_wait);
        rows.push(vec![
            n_jobs.to_string(),
            r.makespan.to_string(),
            r.total_images.to_string(),
            f(r.aggregate_ips, 1),
            f(r.jobs_energy_j / r.total_images.max(1) as f64, 2),
            f(r.queue_wait.mean(), 1),
            f(r.queue_wait.max(), 1),
        ]);
    }
    print_table(
        "Fleet scaling — default mix on a 24-bay chassis",
        &["jobs", "makespan", "imgs", "agg img/s", "J/img (jobs)", "wait mean s", "wait max s"],
        &rows,
    );
    println!(
        "whole-sweep queue wait: {} jobs, mean {}s, max {}s",
        sweep_wait.count(),
        f(sweep_wait.mean(), 1),
        f(sweep_wait.max(), 1),
    );

    // --- Degradation: retune cost on a co-tenanted fleet ------------------
    let clean = run_mix(4, None);
    let faulted = run_mix(4, Some((0, 60, 0.6)));
    let mut rows = Vec::new();
    for (label, r) in [("healthy", &clean), ("device0 @60s -> 60%", &faulted)] {
        rows.push(vec![
            label.to_string(),
            r.makespan.to_string(),
            f(r.aggregate_ips, 1),
            r.retunes.to_string(),
        ]);
    }
    print_table(
        "Degradation — one throttled device, 4-job fleet",
        &["scenario", "makespan", "agg img/s", "retunes"],
        &rows,
    );
    let slowdown = faulted.makespan.as_secs_f64() / clean.makespan.as_secs_f64().max(1e-12);
    println!("makespan slowdown from the fault: {}x", f(slowdown, 3));

    // --- Simulation cost --------------------------------------------------
    let r4 = bench("fleet_run(4 jobs, 24 CSDs, staged IO)", 1, 10, || {
        std::hint::black_box(run_mix(4, None));
    });
    println!("\n{}", r4.summary());
    let r12 = bench("fleet_run(12 jobs, 24 CSDs, staged IO)", 1, 5, || {
        std::hint::black_box(run_mix(12, None));
    });
    println!("{}", r12.summary());

    // --- Fast-forward vs per-step at production step counts ---------------
    let (ff_report, ff_wall) = run_large(4, true);
    let (ps_report, ps_wall) = run_large(4, false);
    assert_eq!(
        ff_report.makespan, ps_report.makespan,
        "fast-forward must be bit-identical to the per-step reference"
    );
    assert_eq!(ff_report.total_images, ps_report.total_images);
    assert_eq!(ff_report.link_bytes, ps_report.link_bytes);
    assert_eq!(ff_report.retunes, ps_report.retunes);
    let steps: usize = ps_report.jobs.iter().map(|j| j.steps_done).sum();
    let speedup = ps_wall / ff_wall.max(1e-9);
    let mut rows = Vec::new();
    for (label, wall) in [("per-step", ps_wall), ("fast-forward", ff_wall)] {
        rows.push(vec![
            label.to_string(),
            format!("{:.3} ms", wall * 1e3),
            f(steps as f64 / wall.max(1e-9), 0),
        ]);
    }
    print_table(
        &format!("Fast-forward — 4 jobs x {LARGE_STEPS} steps, one fault (identical reports)"),
        &["executor", "wall", "simulated steps/s"],
        &rows,
    );
    println!("fast-forward speedup: {}x", f(speedup, 1));

    record_bench_json(
        "fleet",
        &[
            ("staged_run_4_jobs_wall_s", r4.mean_secs()),
            ("staged_run_12_jobs_wall_s", r12.mean_secs()),
            ("large_steps", steps as f64),
            ("large_per_step_wall_s", ps_wall),
            ("large_fast_forward_wall_s", ff_wall),
            ("large_fast_forward_speedup", speedup),
            ("large_per_step_steps_per_sec", steps as f64 / ps_wall.max(1e-9)),
            ("large_fast_forward_steps_per_sec", steps as f64 / ff_wall.max(1e-9)),
        ],
    );
}
