//! Bench: regenerate paper Fig. 6 — aggregate and per-node throughput
//! vs number of CSDs, for all four networks, Stannis vs the naive
//! uniform-batch Horovod baseline the paper's §IV motivates against.
//!
//! Run: `cargo bench --bench fig6`

use stannis::coordinator::{modeled_throughput, tune, TuneConfig};
use stannis::metrics::{bench, f, print_table};
use stannis::perfmodel::PerfModel;

const COUNTS: [usize; 10] = [0, 1, 2, 4, 6, 8, 12, 16, 20, 24];
const NETS: [&str; 4] = ["mobilenet_v2", "nasnet", "inception_v3", "squeezenet"];

fn main() {
    let cfg = TuneConfig::default();

    // --- Aggregate throughput (the Fig. 6 series) -------------------------
    let mut rows = Vec::new();
    for net in NETS {
        let mut m = PerfModel::default();
        let t = tune(&mut m, net, &cfg).unwrap();
        let mut cells = vec![net.to_string()];
        for &n in &COUNTS {
            let r = modeled_throughput(net, n, true, t.newport_bs, t.host_bs, 3).unwrap();
            cells.push(f(r.images_per_sec, 1));
        }
        rows.push(cells);
    }
    let labels: Vec<String> = COUNTS.iter().map(|n| n.to_string()).collect();
    let mut headers = vec!["img/s @ #CSDs"];
    headers.extend(labels.iter().map(String::as_str));
    print_table("Fig. 6 — aggregate throughput (Stannis, tuned batches)", &headers, &rows);

    // --- Per-node throughput: the §V-A slowdown-and-convergence ----------
    let mut rows = Vec::new();
    for net in NETS {
        let mut m = PerfModel::default();
        let t = tune(&mut m, net, &cfg).unwrap();
        let mut cells = vec![net.to_string()];
        for &n in &COUNTS[1..] {
            let r = modeled_throughput(net, n, true, t.newport_bs, t.host_bs, 3).unwrap();
            // per-CSD images/sec (first worker is the host)
            cells.push(f(r.per_worker_ips[1], 2));
        }
        rows.push(cells);
    }
    let labels2: Vec<String> = COUNTS[1..].iter().map(|n| n.to_string()).collect();
    let mut headers = vec!["per-CSD img/s @ #CSDs"];
    headers.extend(labels2.iter().map(String::as_str));
    print_table("Fig. 6 inset — per-node slowdown converges beyond ~6 devices", &headers, &rows);

    // --- Baseline: naive Horovod (uniform batch = the slow device's) ------
    // Heterogeneous Horovod without Stannis pins every worker to the
    // same batch size, so the host runs tiny batches at terrible
    // efficiency — the gap below is the paper's motivation.
    let mut rows = Vec::new();
    for net in NETS {
        let mut m = PerfModel::default();
        let t = tune(&mut m, net, &cfg).unwrap();
        let mut cells = vec![net.to_string()];
        for &n in &COUNTS {
            let stannis =
                modeled_throughput(net, n, true, t.newport_bs, t.host_bs, 3).unwrap().images_per_sec;
            // Uniform batching only binds once a slow device is present.
            let naive_hbs = if n == 0 { t.host_bs } else { t.newport_bs };
            let naive = modeled_throughput(net, n, true, t.newport_bs, naive_hbs, 3)
                .unwrap()
                .images_per_sec;
            cells.push(format!("{}x", f(stannis / naive, 2)));
        }
        rows.push(cells);
    }
    let mut headers = vec!["Stannis / naive-Horovod"];
    headers.extend(labels.iter().map(String::as_str));
    print_table("Baseline gap — Stannis vs uniform-batch Horovod", &headers, &rows);

    // --- Simulation cost ---------------------------------------------------
    let r = bench("modeled_epoch(mobilenet_v2, 24 CSDs, 3 steps)", 2, 30, || {
        std::hint::black_box(modeled_throughput("mobilenet_v2", 24, true, 25, 315, 3).unwrap());
    });
    println!("\n{}", r.summary());
}
