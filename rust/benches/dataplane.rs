//! Bench: the fleet data plane — admission layout cost, the simulated
//! price of staged reads, DLM-locked rebalance movement under a
//! mid-run degradation, and the hot-path `Dataset::visibility` lookup
//! (binary search over the private ranges).
//!
//! Emits machine-readable numbers to `BENCH_3.json` (section
//! `"dataplane"`).
//!
//! Run: `cargo bench --bench dataplane`

// Benches are wall-clock consumers by definition; the crate-wide
// clippy gate on time sources is lifted per bench target.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use stannis::config::ExperimentConfig;
use stannis::data::{Dataset, DatasetConfig, Visibility};
use stannis::fleet::{Fleet, FleetConfig, FleetReport};
use stannis::metrics::{bench, f, print_table, record_bench_json_to};
use stannis::sim::SimTime;

const BENCH_JSON: &str = "BENCH_3.json";

fn run_fleet(data_plane: bool, fault: bool) -> (FleetReport, u64, f64) {
    let mut fleet = Fleet::new(FleetConfig {
        total_csds: 6,
        stage_io: false,
        data_plane,
        ..Default::default()
    });
    for (i, net) in ["mobilenet_v2", "squeezenet"].iter().enumerate() {
        fleet.submit(ExperimentConfig {
            network: (*net).into(),
            num_csds: 3,
            include_host: i == 0,
            steps: 25,
            ..Default::default()
        });
    }
    if fault {
        fleet.inject_degradation(SimTime::secs(60), 0, 0.6);
    }
    let t0 = Instant::now();
    let report = fleet.run().expect("fleet run");
    let wall = t0.elapsed().as_secs_f64();
    let layout_pages = fleet.data_plane().stats().layout_pages;
    (report, layout_pages, wall)
}

fn main() {
    // --- Simulated cost of the data plane ---------------------------------
    let (with_dp, layout_pages, _) = run_fleet(true, false);
    let (without_dp, _, _) = run_fleet(false, false);
    let overhead =
        with_dp.makespan.as_secs_f64() / without_dp.makespan.as_secs_f64().max(1e-12);
    let mut rows = Vec::new();
    for (label, r) in [("data plane", &with_dp), ("compute+sync only", &without_dp)] {
        rows.push(vec![
            label.to_string(),
            r.makespan.to_string(),
            f(r.aggregate_ips, 1),
            f(r.jobs_energy_j, 0),
        ]);
    }
    print_table(
        "Data plane — simulated cost of physical staging (2 jobs, 6 CSDs)",
        &["executor", "makespan", "agg img/s", "jobs J"],
        &rows,
    );
    println!(
        "staged reads stretch the makespan {}x; admission laid out {layout_pages} flash pages",
        f(overhead, 3)
    );

    // --- Rebalance movement under a mid-run degradation -------------------
    let (faulted, _, _) = run_fleet(true, true);
    let moved = faulted.bytes_moved;
    let lock_wait_ms = 1e3 * faulted.lock_wait.mean();
    println!(
        "\nrebalance: {} retune(s), {:.2} MB moved, mean shard-map lock wait {:.3} ms",
        faulted.retunes,
        moved as f64 / 1e6,
        lock_wait_ms
    );
    assert!(faulted.retunes > 0, "the fault must land mid-run");
    assert!(moved > 0, "the rebalance must move the public delta");

    // --- Simulator overhead ----------------------------------------------
    let r = bench("fleet_run(2 jobs, 6 CSDs, data plane, fault)", 1, 10, || {
        std::hint::black_box(run_fleet(true, true));
    });
    println!("\n{}", r.summary());

    // --- Hot-path visibility lookup (binary search) -----------------------
    let d = Dataset::new(DatasetConfig {
        public_images: 72_000,
        private_per_csd: vec![500; 24],
        ..Default::default()
    })
    .expect("dataset");
    let total = d.len();
    let mut acc = 0usize;
    let vis = bench("visibility(24 private shards)", 10, 200, || {
        for id in (0..total).step_by(97) {
            acc += match d.visibility(id).expect("in range") {
                Visibility::Public => 1,
                Visibility::Private { csd } => csd,
            };
        }
    });
    std::hint::black_box(acc);
    let lookups = total.div_ceil(97) as f64;
    let per_lookup_ns = vis.mean_ns / lookups;
    println!("{}", vis.summary());
    println!("visibility lookup: {per_lookup_ns:.1} ns over {} ids", total);

    record_bench_json_to(
        BENCH_JSON,
        "dataplane",
        &[
            ("run_2job_6csd_wall_s", r.mean_secs()),
            ("makespan_overhead_ratio", overhead),
            ("admission_layout_pages", layout_pages as f64),
            ("rebalance_bytes_moved", moved as f64),
            ("rebalance_lock_wait_ms", lock_wait_ms),
            ("visibility_lookup_ns", per_lookup_ns),
        ],
    );
}
