//! Bench: the endurance & failure pipeline at fleet scale
//! (DESIGN.md §Endurance).
//!
//! Three sections, guarded then measured:
//!
//! 1. **Off-identity guard** — a trace whose P/E limit can never fire
//!    (`pe_limit = u32::MAX`) must be bit-identical to the
//!    endurance-off default. Asserted before anything is recorded.
//! 2. **Rolling replacement** — a long data-plane trace on a pool of
//!    deliberately small-geometry devices with a tiny P/E budget:
//!    blocks retire, devices wear out, jobs drain and resubmit, fresh
//!    modules roll in. Measures WAF, device lifetime and sustained
//!    throughput under churn.
//! 3. **Million-arrival overhead** — the BENCH_6-shaped million-job
//!    streaming trace rerun with a finite (but unreached) P/E limit,
//!    so the per-event end-of-life scan is priced on the same workload
//!    the baseline bench prices.
//!
//! Emits machine-readable numbers to `BENCH_7.json` (section
//! `"endurance"`).
//!
//! Run: `cargo bench --bench endurance`

// Benches are wall-clock consumers by definition; the crate-wide
// clippy gate on time sources is lifted per bench target.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use stannis::config::{EnduranceSpec, ExperimentConfig, WeightedJob, WorkloadSpec};
use stannis::fleet::{run_trace, FleetConfig, FleetRuntime};
use stannis::metrics::{f, print_table, record_bench_json_to};

const POOL: usize = 24;

/// Host-free, small-dataset mix (same shape as the sweep bench): the
/// trace exercises admission/staging churn, not one shared bottleneck.
fn lean_mix() -> Vec<WeightedJob> {
    vec![
        WeightedJob {
            weight: 3.0,
            job: ExperimentConfig {
                network: "mobilenet_v2".into(),
                num_csds: 3,
                include_host: false,
                steps: 20,
                public_images: 384,
                private_per_csd: 64,
                ..Default::default()
            },
        },
        WeightedJob {
            weight: 1.0,
            job: ExperimentConfig {
                network: "squeezenet".into(),
                num_csds: 2,
                include_host: false,
                steps: 15,
                public_images: 256,
                private_per_csd: 64,
                ..Default::default()
            },
        },
    ]
}

fn main() {
    // --- Guard: an unreachable limit must be invisible, to the bit -------
    let base = WorkloadSpec {
        total_csds: POOL,
        stage_io: false,
        jobs: 300,
        mean_interarrival_secs: 12.0,
        seed: 23,
        mix: lean_mix(),
        ..Default::default()
    };
    let mut armed = base.clone();
    armed.endurance =
        EnduranceSpec { pe_limit: u32::MAX, read_retries: 0, ..Default::default() };
    let off = run_trace(&base).expect("endurance-off guard trace");
    let on = run_trace(&armed).expect("unreachable-limit guard trace");
    assert_eq!(
        off, on,
        "an unreachable pe_limit must leave the trace bit-identical to endurance off"
    );
    assert_eq!(on.drained, 0);
    assert_eq!(on.devices_replaced, 0);

    // --- Rolling replacement under a tiny P/E budget ----------------------
    //
    // Small-geometry devices (1024 blocks instead of 16384) so a few
    // thousand data-plane admissions rewrite each device several times
    // over; pe_limit 2 retires a block on its third erase. The raised
    // GC low-water mark gives every device multiple admissions' worth
    // of headroom between "worn out" (drain-and-replace fires at the
    // next event boundary) and actual write exhaustion.
    const WEAR_JOBS: usize = 10_000;
    let mut cfg = FleetConfig { total_csds: POOL, stage_io: false, ..Default::default() };
    cfg.csd.ftl.flash.blocks_per_die = 16;
    cfg.csd.ftl.gc_low_water = 64;
    cfg.csd.ftl.gc_high_water = 96;
    cfg.csd.ftl.pe_limit = 2;
    cfg.csd.ftl.read_retries = 4;
    let spec = WorkloadSpec {
        total_csds: POOL,
        stage_io: false,
        jobs: WEAR_JOBS,
        mean_interarrival_secs: 12.0,
        seed: 23,
        mix: lean_mix(),
        ..Default::default()
    };
    let mut rt = FleetRuntime::new(cfg);
    rt.load_workload(&spec).expect("wear trace loads");
    let t0 = Instant::now();
    rt.run_until_idle().expect("wear trace drains to idle");
    let wear_wall = t0.elapsed().as_secs_f64();
    let r = rt.report();
    // Drain conservation: every drain retires one (cancelled) victim
    // and submits exactly one successor, so terminal jobs = arrivals +
    // drains, and with no user cancels every original job completes.
    assert_eq!(r.retired, WEAR_JOBS + r.drained, "drain must conserve jobs");
    assert_eq!(r.cancelled, r.drained, "only drains cancel in this trace");
    if r.devices_replaced == 0 {
        println!("warning: no device reached end of life — wear metrics are degenerate");
    }
    let hours = r.makespan.as_secs_f64() / 3600.0;
    let device_lifetime_h = if r.devices_replaced > 0 {
        hours * POOL as f64 / r.devices_replaced as f64
    } else {
        0.0
    };
    let jobs_per_hour = (r.retired - r.cancelled) as f64 / hours.max(1e-12);
    print_table(
        &format!("Endurance — {WEAR_JOBS} arrivals, pe_limit 2, rolling replacement"),
        &["drained", "replaced", "retired blks", "erases", "retry recov", "waf", "jobs/h", "wall"],
        &[vec![
            r.drained.to_string(),
            r.devices_replaced.to_string(),
            r.wear.retired_blocks.to_string(),
            r.wear.erases.to_string(),
            r.wear.retry_recoveries.to_string(),
            f(r.wear.waf, 2),
            f(jobs_per_hour, 1),
            format!("{wear_wall:.2} s"),
        ]],
    );

    // --- Million-arrival trace with a finite, unreached limit -------------
    const TRACE_JOBS: usize = 1_000_000;
    let trace = WorkloadSpec {
        total_csds: POOL,
        stage_io: false,
        data_plane: false,
        jobs: TRACE_JOBS,
        mean_interarrival_secs: 12.0,
        seed: 17,
        mix: lean_mix(),
        endurance: EnduranceSpec { pe_limit: 1000, read_retries: 4, ..Default::default() },
        ..Default::default()
    };
    let t0 = Instant::now();
    let s = run_trace(&trace).expect("million-arrival endurance trace");
    let trace_wall = t0.elapsed().as_secs_f64();
    assert_eq!(
        s.completed, TRACE_JOBS,
        "every arrival must complete — a finite pe_limit alone must not drop jobs"
    );
    let events_per_sec = s.log_events as f64 / trace_wall.max(1e-9);
    println!(
        "1M-arrival endurance-armed trace: {} events in {:.2}s wall ({:.0} events/s), {} drained, {} replaced",
        s.log_events, trace_wall, events_per_sec, s.drained, s.devices_replaced,
    );

    record_bench_json_to(
        "BENCH_7.json",
        "endurance",
        &[
            ("wear_jobs", WEAR_JOBS as f64),
            ("wear_wall_s", wear_wall),
            ("wear_jobs_per_hour", jobs_per_hour),
            ("drained_jobs", r.drained as f64),
            ("devices_replaced", r.devices_replaced as f64),
            ("retired_blocks", r.wear.retired_blocks as f64),
            ("erases", r.wear.erases as f64),
            ("retry_recoveries", r.wear.retry_recoveries as f64),
            ("waf", r.wear.waf),
            ("device_lifetime_h", device_lifetime_h),
            ("trace_jobs", TRACE_JOBS as f64),
            ("trace_wall_s", trace_wall),
            ("trace_events_per_sec", events_per_sec),
        ],
    );
}
